//! Quickstart: load the FLASH-D attention artifact, run it through PJRT,
//! and cross-check against the Rust golden kernel.
//!
//!     make artifacts && cargo run --release --example quickstart

use flashd::kernels::{self, max_abs_diff};
use flashd::runtime::{lit_f32, lit_i32, open_default, to_vec_f32};
use flashd::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Open the artifact directory and the PJRT CPU client.
    let rt = open_default()?;
    println!("platform: {}", rt.platform());

    // 2. Pick the FLASH-D serving artifact for (4 heads, 128 seq, 32 dim).
    let name = "attn_flashd_h4_l128_d32";
    let (h, l, d) = (4usize, 128usize, 32usize);
    println!("artifact: {name}");

    // 3. Random attention problem.
    let mut rng = Rng::new(0xF1A5D);
    let q = rng.normal_vec(h * l * d, 0.5);
    let k = rng.normal_vec(h * l * d, 0.5);
    let v = rng.normal_vec(h * l * d, 1.0);

    // 4. Execute through PJRT (kv_len = full window).
    let t = std::time::Instant::now();
    let out = rt.execute(
        name,
        &[
            lit_f32(&q, &[h, l, d])?,
            lit_f32(&k, &[h, l, d])?,
            lit_f32(&v, &[h, l, d])?,
            lit_i32(&[l as i32], &[1, 1])?,
        ],
    )?;
    let pjrt_out = to_vec_f32(&out[0])?;
    println!("pjrt execute: {:?}", t.elapsed());

    // 5. Same problem through the Rust FLASH-D kernel (Alg. 3).
    let scale = (d as f32).powf(-0.5);
    let mut rust_out = Vec::with_capacity(h * l * d);
    for hh in 0..h {
        let off = hh * l * d;
        rust_out.extend(kernels::flashd::attention_multi(
            &q[off..off + l * d],
            &k[off..off + l * d],
            &v[off..off + l * d],
            l,
            l,
            d,
            scale,
        ));
    }

    let diff = max_abs_diff(&pjrt_out, &rust_out);
    println!("max |pjrt - rust| = {diff:.2e}");
    assert!(diff < 2e-4, "kernel mismatch");
    println!("OK: the Pallas FLASH-D kernel and the Rust Alg. 3 agree.");
    Ok(())
}
