//! Text generation with a trained zoo model through the pure-Rust FLASH-D
//! engine (KV-cached decode session), printing live skip statistics — the
//! Table I effect, visible per-prompt.
//!
//!     cargo run --release --example generate -- --model phi-tiny \
//!         --prompt "question: which planet is red?" --tokens 60

use flashd::kernels::flashd::SkipCriterion;
use flashd::model::engine::Engine;
use flashd::model::sampler;
use flashd::model::tokenizer::ByteTokenizer;
use flashd::util::cli::Args;
use flashd::util::rng::Rng;
use std::io::Write;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["exact"]);
    let dir = flashd::runtime::default_artifact_dir();
    let model = args.get_or("model", "phi-tiny");
    let prompt = args.get_or("prompt", "question: which planet is red?");
    let n = args.get_usize("tokens", 60);
    let temperature = args.get_f64("temperature", 0.0);

    let mut engine = Engine::from_artifacts(&dir, model)?;
    engine.criterion = if args.flag("exact") { SkipCriterion::None } else { SkipCriterion::Static };

    let tok = ByteTokenizer;
    let ids = tok.encode(prompt);
    let mut rng = Rng::new(args.get_u64("seed", 0));

    let mut sess = engine.start_session();
    print!("{prompt}");
    std::io::stdout().flush().ok();
    let start = ids.len().saturating_sub(engine.info.seq_len);
    let mut logits = Vec::new();
    for &t in &ids[start..] {
        logits = sess.push_token(t);
    }
    let t0 = std::time::Instant::now();
    let mut produced = 0usize;
    for _ in 0..n {
        if sess.remaining() == 0 {
            break;
        }
        let next = if temperature > 0.0 {
            sampler::sample_topk(&logits, 12, temperature, &mut rng)
        } else {
            sampler::greedy(&logits)
        };
        print!("{}", tok.decode(&[next]));
        std::io::stdout().flush().ok();
        logits = sess.push_token(next);
        produced += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\n\n[model={model} criterion={:?}] {produced} tokens in {dt:.2}s ({:.1} tok/s)",
        engine.criterion,
        produced as f64 / dt.max(1e-9)
    );
    println!(
        "[skips: {:.2}% of {} output updates ({} low / {} high)]",
        sess.stats.skip.percent(),
        sess.stats.skip.total,
        sess.stats.skip.skip_low,
        sess.stats.skip.skip_high
    );
    Ok(())
}
