//! Serving example: spin up the coordinator, run a multi-session
//! prefill + decode workload, and report latency/throughput percentiles —
//! the software analogue of the paper's parallel-query hardware block.
//!
//!     cargo run --release --example serve_attention -- --sessions 4 --decode 24

use flashd::bench_harness::workload::{session_requests, WorkloadSpec};
use flashd::coordinator::{Coordinator, CoordinatorConfig, Variant};
use flashd::util::cli::Args;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let sessions = args.get_usize("sessions", 4);
    let decode = args.get_usize("decode", 24);
    let variant = match args.get_or("variant", "flashd") {
        "flash2" => Variant::Flash2,
        _ => Variant::FlashD,
    };

    let coord = Coordinator::start(CoordinatorConfig::default())?;
    let spec = WorkloadSpec { sessions, decode_steps: decode, variant, ..Default::default() };

    println!("== sequential per-session decode ==");
    let t = Instant::now();
    let mut latencies = Vec::new();
    for s in 0..sessions as u64 {
        for req in session_requests(&spec, s, s * 10_000) {
            let resp = coord.submit_blocking(req);
            resp.output.map_err(|e| anyhow::anyhow!(e))?;
            latencies.push(resp.latency_us as f64);
        }
    }
    let wall = t.elapsed().as_secs_f64();
    let n = latencies.len();
    println!(
        "{n} requests in {wall:.2}s  ({:.1} req/s)  p50={:.0}µs p95={:.0}µs p99={:.0}µs",
        n as f64 / wall,
        flashd::util::percentile(&latencies, 50.0),
        flashd::util::percentile(&latencies, 95.0),
        flashd::util::percentile(&latencies, 99.0),
    );

    println!("\n== concurrent decode burst (dynamic batching) ==");
    // prefill one shared session, then hammer it from worker threads
    let s = 999u64;
    let mut reqs = session_requests(
        &WorkloadSpec { sessions: 1, decode_steps: 0, variant, ..Default::default() },
        s,
        10_000_000,
    );
    let prefill = reqs.remove(0);
    coord
        .submit_blocking(prefill)
        .output
        .map_err(|e| anyhow::anyhow!(e))?;

    let coord = std::sync::Arc::new(coord);
    let t = Instant::now();
    let burst = 64usize;
    let mut handles = Vec::new();
    for i in 0..burst as u64 {
        let c = coord.clone();
        let spec2 = WorkloadSpec { variant, ..Default::default() };
        handles.push(std::thread::spawn(move || {
            let mut reqs = session_requests(&spec2, s, 20_000_000 + i * 100);
            let dec = reqs.pop().unwrap(); // one decode request
            c.submit_blocking(dec)
        }));
    }
    let mut batched: Vec<f64> = Vec::new();
    let mut max_batch = 0usize;
    for h in handles {
        let resp = h.join().unwrap();
        resp.output.map_err(|e| anyhow::anyhow!(e))?;
        batched.push(resp.latency_us as f64);
        max_batch = max_batch.max(resp.batch_size);
    }
    let wall = t.elapsed().as_secs_f64();
    println!(
        "{burst} concurrent decodes in {wall:.3}s  ({:.1} req/s)  largest batch={max_batch}",
        burst as f64 / wall
    );
    println!("\nmetrics:\n{}", coord.metrics.snapshot().render());
    Ok(())
}
