//! §Perf measurement: full-window re-forward decode (baseline) vs the
//! KV-cached DecodeSession (optimized). Writes reports/perf_decode.txt.
//!
//!     cargo run --release --example perf_decode -- --model phi-tiny

use flashd::model::engine::Engine;
use flashd::model::tokenizer::ByteTokenizer;
use flashd::util::cli::Args;
use std::fmt::Write as _;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let dir = flashd::runtime::default_artifact_dir();
    let model = args.get_or("model", "phi-tiny");
    let tokens = args.get_usize("tokens", 12);
    let engine = Engine::from_artifacts(&dir, model)?;
    let tok = ByteTokenizer;

    let mut report = String::new();
    let _ = writeln!(report, "decode perf, model={model}, {tokens} new tokens");
    let _ = writeln!(
        report,
        "{:<12} {:>14} {:>14} {:>9}",
        "prompt_len", "baseline_ms", "kv_cached_ms", "speedup"
    );
    println!("{report}");

    for prompt_len in [16usize, 48, 96] {
        let prompt: Vec<i32> = tok
            .encode(&"the quick brown fox jumps over the lazy dog. ".repeat(4))
            .into_iter()
            .take(prompt_len)
            .collect();

        let t = Instant::now();
        let (slow, _) = engine.greedy_decode(&prompt, tokens);
        let slow_ms = t.elapsed().as_secs_f64() * 1e3;

        let t = Instant::now();
        let (fast, _) = engine.greedy_decode_fast(&prompt, tokens);
        let fast_ms = t.elapsed().as_secs_f64() * 1e3;

        assert_eq!(slow, fast, "optimization changed outputs!");
        let line = format!(
            "{:<12} {:>14.1} {:>14.2} {:>8.1}x",
            prompt_len,
            slow_ms,
            fast_ms,
            slow_ms / fast_ms
        );
        println!("{line}");
        let _ = writeln!(report, "{line}");
    }

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/perf_decode.txt", &report)?;
    println!("\nwrote reports/perf_decode.txt (outputs verified identical)");
    Ok(())
}
