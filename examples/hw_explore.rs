//! Design-space exploration of the hardware cost model: sweep hidden
//! dimension and number format, print area/power/latency for both blocks
//! and the structural breakdown of where FLASH-D saves.
//!
//!     cargo run --release --example hw_explore -- --dmax 512

use flashd::hw::activity::ActivityStats;
use flashd::hw::{area, datapath, power, CostDb, Design, Format};
use flashd::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]);
    let dmax = args.get_usize("dmax", 512);
    let db = CostDb::tsmc28();
    let act = ActivityStats { skip_fraction: 0.02, ..ActivityStats::default_random() };

    println!("== area / power sweep (28 nm @ 500 MHz) ==");
    println!(
        "{:<10} {:>5} {:>12} {:>12} {:>8} {:>10} {:>10} {:>8} {:>8}",
        "format", "d", "FA2 mm2", "FLASHD mm2", "Δarea", "FA2 mW", "FLASHD mW", "Δpower", "cycles"
    );
    for fmt in [Format::BF16, Format::FP8_E4M3, Format::FP32] {
        let mut d = 8usize;
        while d <= dmax {
            let a2 = Design::FlashAttention2.area_um2(d, fmt, &db) / 1e6;
            let ad = Design::FlashD.area_um2(d, fmt, &db) / 1e6;
            let p2 = power::block_power_mw(Design::FlashAttention2, d, fmt, &act, &db);
            let pd = power::block_power_mw(Design::FlashD, d, fmt, &act, &db);
            println!(
                "{:<10} {:>5} {:>12.4} {:>12.4} {:>7.1}% {:>10.3} {:>10.3} {:>7.1}% {:>8}",
                fmt.name(),
                d,
                a2,
                ad,
                100.0 * (a2 - ad) / a2,
                p2,
                pd,
                100.0 * (p2 - pd) / p2,
                datapath::latency_cycles(Design::FlashD, d),
            );
            d *= 2;
        }
        println!();
    }

    println!("== structural breakdown, bf16 d=64 (kGE) ==");
    for design in [Design::FlashAttention2, Design::FlashD] {
        let b = area::breakdown(design, 64, Format::BF16, &db);
        println!(
            "{:<16} dot={:>6.1} nonlin={:>6.1} update={:>7.1} state={:>5.1} epilogue={:>7.1} regs={:>6.1}  total={:>8.1}",
            design.name(),
            b.dot / 1e3,
            b.nonlinear / 1e3,
            b.update / 1e3,
            b.state / 1e3,
            b.epilogue / 1e3,
            b.regs / 1e3,
            b.total() / 1e3,
        );
    }

    println!("\n== skip-fraction sensitivity (FLASH-D power, bf16 d=64) ==");
    for skip in [0.0, 0.01, 0.02, 0.05, 0.1, 0.25, 0.5] {
        let a = ActivityStats { skip_fraction: skip, ..ActivityStats::default_random() };
        let p = power::block_power_mw(Design::FlashD, 64, Format::BF16, &a, &db);
        println!("  skip {:>5.1}%  ->  {:.3} mW", skip * 100.0, p);
    }
}
