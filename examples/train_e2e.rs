//! End-to-end driver (DESIGN.md §4): trains a zoo transformer for several
//! hundred steps through the full three-layer stack — the JAX train step
//! (with differentiable FLASH-D attention) AOT-lowered to HLO and executed
//! by the Rust PJRT runtime — then validates the trained model by decoding
//! with the pure-Rust FLASH-D engine and reporting skip statistics.
//!
//!     cargo run --release --example train_e2e -- --model phi-tiny --steps 300
//!
//! The loss curve is recorded in EXPERIMENTS.md.

use flashd::kernels::flashd::SkipCriterion;
use flashd::model::engine::Engine;
use flashd::model::tokenizer::ByteTokenizer;
use flashd::train::{train, TrainOptions};
use flashd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["no-save"]);
    let dir = flashd::runtime::default_artifact_dir();
    let opts = TrainOptions {
        model: args.get_or("model", "phi-tiny").to_string(),
        steps: args.get_usize("steps", 300),
        seed: args.get_u64("seed", 0),
        log_every: args.get_usize("log-every", 20),
        save: !args.flag("no-save"),
        quiet: false,
    };

    println!("== training {} for {} steps through PJRT ==", opts.model, opts.steps);
    let report = train(&dir, &opts)?;
    println!("\nloss curve:");
    for (step, loss) in &report.losses {
        let bar = "#".repeat((loss * 8.0) as usize);
        println!("  step {step:>4}  {loss:.4}  {bar}");
    }
    println!(
        "\n{} steps, {:.1} s, {:.0} tokens/s; loss {:.4} -> {:.4}",
        report.steps, report.wall_s, report.tokens_per_s, report.first_loss, report.final_loss
    );
    anyhow::ensure!(
        report.final_loss < report.first_loss - 0.5,
        "training did not converge enough"
    );

    // Validate: decode with the trained weights through the Rust engine.
    println!("\n== greedy decode with trained weights (Rust FLASH-D engine) ==");
    let mut engine = Engine::from_artifacts(&dir, &opts.model)?;
    engine.criterion = SkipCriterion::Static;
    let tok = ByteTokenizer;
    for prompt in [
        "question: why do people wear coats in winter?",
        "alice has 3 balls and buys 4 more.",
        "today is monday",
    ] {
        let (out, stats) = engine.greedy_decode_fast(&tok.encode(prompt), 40);
        println!("  prompt: {prompt}");
        println!("  output: {}", tok.decode(&out[prompt.len()..]));
        println!("  skips : {:.2}% of {} updates\n", stats.skip.percent(), stats.skip.total);
    }
    println!("e2e OK: all three layers compose.");
    Ok(())
}
