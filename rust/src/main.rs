//! `flashd` — the FLASH-D coordinator CLI.
//!
//! Subcommands:
//!   serve      run the attention-serving coordinator on a synthetic workload
//!   train      train a zoo model through the AOT train_step artifact
//!   generate   decode text with a trained model (Rust engine, FLASH-D)
//!   table1     reproduce Table I (skip percentages)
//!   fig2       reproduce Fig. 2 (weight function curves)
//!   fig4       reproduce Fig. 4 (area comparison)
//!   fig5       reproduce Fig. 5 (power comparison)
//!   info       list artifacts and models

use flashd::bench_harness::{table1, traces, workload};
use flashd::coordinator::{Coordinator, CoordinatorConfig};
use flashd::hw::{area, power, CostDb, Format};
use flashd::kernels::flashd::weight;
use flashd::model::engine::Engine;
use flashd::model::tokenizer::ByteTokenizer;
use flashd::train::{train, TrainOptions};
use flashd::util::cli::Args;

const HELP: &str = "flashd — FLASH-D attention coordinator

USAGE: flashd <command> [--options]

COMMANDS:
  info                               list artifacts + models
  serve    [--sessions N] [--decode N] [--variant flashd|flash2]
  train    [--model NAME] [--steps N] [--seed N] [--no-save]
  generate [--model NAME] [--prompt TEXT] [--tokens N]
  table1   [--prompts N] [--tokens N]
  fig2 | fig4 | fig5                 regenerate paper figures
  help                               this text

Artifacts default to ./artifacts (override with FLASHD_ARTIFACTS).";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let args = Args::parse(argv.into_iter().skip(1), &["no-save", "quiet"]);
    let dir = flashd::runtime::default_artifact_dir();

    let result = match cmd.as_str() {
        "info" => cmd_info(&dir),
        "serve" => cmd_serve(&dir, &args),
        "train" => cmd_train(&dir, &args),
        "generate" => cmd_generate(&dir, &args),
        "table1" => cmd_table1(&dir, &args),
        "fig2" => cmd_fig2(),
        "fig4" => cmd_fig4(),
        "fig5" => cmd_fig5(&dir),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_info(dir: &std::path::Path) -> anyhow::Result<()> {
    let man = flashd::runtime::Manifest::load(dir)?;
    println!("artifacts ({}):", man.artifacts.len());
    for (name, a) in &man.artifacts {
        println!("  {:<34} kind={:<10} inputs={} outputs={}", name, a.kind, a.inputs.len(), a.n_outputs);
    }
    println!("models ({}):", man.models.len());
    for (name, m) in &man.models {
        println!(
            "  {:<12} layers={} d_model={} heads={} params={}",
            name, m.n_layers, m.d_model, m.n_heads,
            flashd::util::fmt_thousands(m.n_params as f64)
        );
    }
    Ok(())
}

fn cmd_serve(dir: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let sessions = args.get_usize("sessions", 4);
    let decode = args.get_usize("decode", 16);
    let variant = match args.get_or("variant", "flashd") {
        "flash2" => flashd::coordinator::Variant::Flash2,
        _ => flashd::coordinator::Variant::FlashD,
    };
    let cfg = CoordinatorConfig { artifact_dir: dir.to_path_buf(), ..Default::default() };
    let coord = Coordinator::start(cfg)?;
    let spec = workload::WorkloadSpec { sessions, decode_steps: decode, variant, ..Default::default() };
    println!("serving {} sessions x {} decode steps ({:?}) ...", sessions, decode, variant);
    let t = std::time::Instant::now();
    for s in 0..sessions as u64 {
        for req in workload::session_requests(&spec, s, s * 1000) {
            let resp = coord.submit_blocking(req);
            if let Err(e) = resp.output {
                anyhow::bail!("request failed: {e}");
            }
        }
    }
    let wall = t.elapsed();
    let snap = coord.metrics.snapshot();
    println!("{}", snap.render());
    println!(
        "wall {:.2}s  ({:.1} req/s)",
        wall.as_secs_f64(),
        snap.responses as f64 / wall.as_secs_f64()
    );
    coord.shutdown();
    Ok(())
}

fn cmd_train(dir: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let opts = TrainOptions {
        model: args.get_or("model", "phi-tiny").to_string(),
        steps: args.get_usize("steps", 300),
        seed: args.get_u64("seed", 0),
        log_every: args.get_usize("log-every", 20),
        save: !args.flag("no-save"),
        quiet: args.flag("quiet"),
    };
    let report = train(dir, &opts)?;
    println!(
        "trained {}: loss {:.4} -> {:.4} over {} steps ({:.0} tok/s, {:.1}s)",
        report.model, report.first_loss, report.final_loss, report.steps,
        report.tokens_per_s, report.wall_s
    );
    Ok(())
}

fn cmd_generate(dir: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "phi-tiny");
    let prompt = args.get_or("prompt", "question: why do people wear coats in winter? answer:");
    let n = args.get_usize("tokens", 48);
    let mut engine = Engine::from_artifacts(dir, model)?;
    engine.criterion = flashd::kernels::flashd::SkipCriterion::Static;
    let tok = ByteTokenizer;
    let ids = tok.encode(prompt);
    let (out, stats) = engine.greedy_decode_fast(&ids, n);
    println!("{}", tok.decode(&out));
    println!(
        "\n[skips: {:.2}% of {} output updates ({} low / {} high)]",
        stats.skip.percent(), stats.skip.total, stats.skip.skip_low, stats.skip.skip_high
    );
    Ok(())
}

fn cmd_table1(dir: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let opts = table1::Table1Options {
        prompts_per_suite: args.get_usize("prompts", 6),
        decode_tokens: args.get_usize("tokens", 16),
        ..Default::default()
    };
    let cells = table1::run_all(dir, &opts)?;
    println!("{}", table1::render_table(&cells));
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/table1.csv", table1::to_csv(&cells))?;
    println!("wrote reports/table1.csv");
    Ok(())
}

fn cmd_fig2() -> anyhow::Result<()> {
    let mut csv = String::from("s_diff,w_prev_0.99,w_prev_0.5,w_prev_0.1,w_prev_0.01\n");
    println!("Fig. 2: w_i = sigmoid(s_diff + ln w_prev)");
    for i in -100..=140 {
        let x = i as f64 / 10.0;
        let row: Vec<f64> = [0.99, 0.5, 0.1, 0.01].iter().map(|&wp| weight(x, wp)).collect();
        csv.push_str(&format!("{x},{},{},{},{}\n", row[0], row[1], row[2], row[3]));
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig2.csv", csv)?;
    println!("wrote reports/fig2.csv");
    Ok(())
}

fn cmd_fig4() -> anyhow::Result<()> {
    let db = CostDb::tsmc28();
    let rows = area::fig4_rows(&db);
    println!("{}", area::render_table(&rows));
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig4.csv", area::to_csv(&rows))?;
    println!("wrote reports/fig4.csv");
    Ok(())
}

fn cmd_fig5(dir: &std::path::Path) -> anyhow::Result<()> {
    let db = CostDb::tsmc28();
    let dir = dir.to_path_buf();
    let rows = power::fig5_rows(
        &|fmt| match fmt {
            Format::BF16 => traces::measured_activity::<flashd::numerics::Bf16>(&dir, 2),
            Format::FP8_E4M3 => traces::measured_activity::<flashd::numerics::Fp8E4M3>(&dir, 2),
            Format::FP32 => traces::measured_activity::<f32>(&dir, 2),
        },
        &db,
    );
    println!("{}", power::render_table(&rows));
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig5.csv", power::to_csv(&rows))?;
    println!("wrote reports/fig5.csv");
    Ok(())
}
