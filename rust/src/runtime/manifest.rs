//! Typed view of `artifacts/manifest.json` — the contract between
//! `python/compile/aot.py` (producer) and the Rust runtime (consumer).

use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Shape + dtype of one executable input.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
    /// attention artifacts: kernel variant ("flashd" / "flash2")
    pub variant: Option<String>,
    pub causal: bool,
    pub heads: usize,
    pub seq: usize,
    pub head_dim: usize,
    /// model artifacts: zoo name
    pub model: Option<String>,
    pub batch: usize,
}

/// One model in the zoo: configuration + the flat parameter ABI.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub block_q: usize,
    pub block_k: usize,
    /// QK-norm attention temperature (score = qk_gain * q^.k^ / sqrt(dh)).
    pub qk_gain: f64,
    pub n_params: usize,
    /// (name, shape) in the exact order of the train/forward ABI.
    pub param_spec: Vec<(String, Vec<usize>)>,
    pub init_weights: String,
    pub train_lr: f64,
    pub train_batch: usize,
}

impl ModelInfo {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub models: BTreeMap<String, ModelInfo>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|x| x.as_usize().unwrap_or(0))
        .collect())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let mut man = Manifest::default();

        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, a) in arts {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: no inputs"))?
                .iter()
                .map(|i| {
                    Ok(TensorSpec {
                        shape: shape_of(i.get("shape").ok_or_else(|| anyhow!("no shape"))?)?,
                        dtype: i
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            man.artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact {name}: no file"))?
                        .to_string(),
                    kind: a.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
                    inputs,
                    n_outputs: a.get("n_outputs").and_then(Json::as_usize).unwrap_or(1),
                    variant: a.get("variant").and_then(Json::as_str).map(String::from),
                    causal: a.get("causal").and_then(Json::as_bool).unwrap_or(false),
                    heads: a.get("heads").and_then(Json::as_usize).unwrap_or(0),
                    seq: a.get("seq").and_then(Json::as_usize).unwrap_or(0),
                    head_dim: a.get("head_dim").and_then(Json::as_usize).unwrap_or(0),
                    model: a.get("model").and_then(Json::as_str).map(String::from),
                    batch: a.get("batch").and_then(Json::as_usize).unwrap_or(1),
                },
            );
        }

        if let Some(models) = root.get("models").and_then(Json::as_obj) {
            for (name, m) in models {
                let cfg = m.get("config").ok_or_else(|| anyhow!("model {name}: no config"))?;
                let g = |k: &str| -> Result<usize> {
                    cfg.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("model {name}: config missing {k}"))
                };
                let spec = m
                    .get("param_spec")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("model {name}: no param_spec"))?
                    .iter()
                    .map(|e| {
                        Ok((
                            e.get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| anyhow!("param name"))?
                                .to_string(),
                            shape_of(e.get("shape").ok_or_else(|| anyhow!("param shape"))?)?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?;
                man.models.insert(
                    name.clone(),
                    ModelInfo {
                        name: name.clone(),
                        vocab_size: g("vocab_size")?,
                        seq_len: g("seq_len")?,
                        d_model: g("d_model")?,
                        n_heads: g("n_heads")?,
                        n_layers: g("n_layers")?,
                        d_ff: g("d_ff")?,
                        block_q: g("block_q")?,
                        block_k: g("block_k")?,
                        qk_gain: cfg.get("qk_gain").and_then(Json::as_f64).unwrap_or(1.0),
                        n_params: m.get("n_params").and_then(Json::as_usize).unwrap_or(0),
                        param_spec: spec,
                        init_weights: m
                            .get("init_weights")
                            .and_then(Json::as_str)
                            .unwrap_or("")
                            .to_string(),
                        train_lr: m
                            .get("train")
                            .and_then(|t| t.get("lr"))
                            .and_then(Json::as_f64)
                            .unwrap_or(3e-3),
                        train_batch: m
                            .get("train")
                            .and_then(|t| t.get("batch"))
                            .and_then(Json::as_usize)
                            .unwrap_or(8),
                    },
                );
            }
        }
        Ok(man)
    }

    /// Resolve the attention artifact for a shape + variant + causality.
    pub fn find_attention(
        &self,
        variant: &str,
        heads: usize,
        seq: usize,
        head_dim: usize,
        causal: bool,
    ) -> Option<&ArtifactInfo> {
        self.artifacts.values().find(|a| {
            a.kind == "attention"
                && a.variant.as_deref() == Some(variant)
                && a.heads == heads
                && a.seq == seq
                && a.head_dim == head_dim
                && a.causal == causal
        })
    }

    /// All attention shapes available for a variant.
    pub fn attention_shapes(&self, variant: &str, causal: bool) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self
            .artifacts
            .values()
            .filter(|a| a.kind == "attention" && a.variant.as_deref() == Some(variant) && a.causal == causal)
            .map(|a| (a.heads, a.seq, a.head_dim))
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": {
        "attn_flashd_h4_l128_d32": {
          "file": "attn_flashd_h4_l128_d32.hlo.txt",
          "kind": "attention", "variant": "flashd", "causal": false,
          "heads": 4, "seq": 128, "head_dim": 32,
          "inputs": [
            {"shape": [4,128,32], "dtype": "float32"},
            {"shape": [4,128,32], "dtype": "float32"},
            {"shape": [4,128,32], "dtype": "float32"}],
          "n_outputs": 1
        }
      },
      "models": {
        "phi-tiny": {
          "config": {"vocab_size":256,"seq_len":128,"d_model":128,
                     "n_heads":4,"n_layers":4,"d_ff":344,
                     "block_q":32,"block_k":32},
          "n_params": 840832,
          "param_spec": [{"name":"tok_emb","shape":[256,128]}],
          "init_weights": "init_phi-tiny.fdw",
          "train": {"lr": 0.003, "batch": 8}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["attn_flashd_h4_l128_d32"];
        assert_eq!(a.heads, 4);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![4, 128, 32]);
        assert_eq!(a.inputs[0].numel(), 4 * 128 * 32);
        assert!(!a.causal);
        let mo = &m.models["phi-tiny"];
        assert_eq!(mo.d_model, 128);
        assert_eq!(mo.d_head(), 32);
        assert_eq!(mo.param_spec[0].0, "tok_emb");
        assert!((mo.train_lr - 0.003).abs() < 1e-12);
    }

    #[test]
    fn find_attention_matches() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find_attention("flashd", 4, 128, 32, false).is_some());
        assert!(m.find_attention("flashd", 4, 128, 32, true).is_none());
        assert!(m.find_attention("flash2", 4, 128, 32, false).is_none());
        assert_eq!(m.attention_shapes("flashd", false), vec![(4, 128, 32)]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    /// The real manifest (if built) parses and is self-consistent.
    #[test]
    fn real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for (name, a) in &m.artifacts {
            assert!(dir.join(&a.file).exists(), "{name}: missing {}", a.file);
        }
        for (name, mo) in &m.models {
            let total: usize = mo.param_spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
            assert_eq!(total, mo.n_params, "{name} param count");
            assert!(dir.join(&mo.init_weights).exists());
        }
    }
}
