//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the only place Python output crosses into the
//! request path — as compiled artifacts, never as a Python process.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod manifest;

pub use manifest::{ArtifactInfo, Manifest, ModelInfo, TensorSpec};

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A loaded PJRT runtime bound to an artifact directory.
///
/// Executables are compiled lazily on first use and cached. The runtime is
/// deliberately single-threaded (`!Send` buffers); the coordinator owns it
/// from a dedicated engine thread.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (must contain manifest.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of artifacts compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute an artifact on literal inputs; returns the decomposed output
    /// tuple (aot.py lowers everything with return_tuple=True).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let info = &self.manifest.artifacts[name];
        if inputs.len() != info.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            ));
        }
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != info.n_outputs {
            return Err(anyhow!(
                "artifact '{name}' declared {} outputs, produced {}",
                info.n_outputs,
                parts.len()
            ));
        }
        Ok(parts)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {:?} wants {n} elements, got {}", shape, data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(anyhow!("shape {:?} wants {n} elements, got {}", shape, data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 scalar literal.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a literal's f32 payload.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// Default artifact directory: $FLASHD_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("FLASHD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Open the default runtime, with a helpful error if artifacts are missing.
pub fn open_default() -> Result<Runtime> {
    let dir = default_artifact_dir();
    Runtime::open(&dir).with_context(|| {
        format!(
            "failed to open artifacts at {} — run `make artifacts` first",
            dir.display()
        )
    })
}
