//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the only place Python output crosses into the
//! request path — as compiled artifacts, never as a Python process.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## Backend gating
//!
//! The `xla` bindings crate is not available in the offline build image, so
//! the real runtime is compiled only under `--cfg pjrt_backend` (set via
//! RUSTFLAGS; deliberately not a cargo feature so `--all-features` stays
//! buildable), which additionally requires adding the vendored `xla` crate
//! as a dependency. Without the cfg this module exposes the same API over a
//! stub:
//! [`Literal`] is a plain host tensor, and [`Runtime::open`] fails with a
//! clear error, which every artifact-dependent test, bench, and example
//! already handles by skipping. The pure-Rust kernels, engine, and
//! coordinator (over [`crate::coordinator::server::NaiveEngine`]) never
//! touch this backend.

pub mod manifest;

pub use manifest::{ArtifactInfo, Manifest, ModelInfo, TensorSpec};

use std::path::PathBuf;

#[cfg(pjrt_backend)]
mod backend {
    use super::Manifest;
    use anyhow::{anyhow, Result};
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    /// Executable input/output tensor — the PJRT literal.
    pub use xla::Literal;

    /// A loaded PJRT runtime bound to an artifact directory.
    ///
    /// Executables are compiled lazily on first use and cached. The runtime
    /// is deliberately single-threaded (`!Send` buffers); the coordinator
    /// owns it from a dedicated engine thread.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: Manifest,
        cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        /// Open the artifact directory (must contain manifest.json).
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::load(&dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) the named artifact.
        pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.borrow().get(name) {
                return Ok(exe.clone());
            }
            let info = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let path = self.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Rc::new(
                self.client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {name}: {e:?}"))?,
            );
            self.cache.borrow_mut().insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Number of artifacts compiled so far (for tests/metrics).
        pub fn compiled_count(&self) -> usize {
            self.cache.borrow().len()
        }

        /// Execute an artifact on literal inputs; returns the decomposed
        /// output tuple (aot.py lowers everything with return_tuple=True).
        pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
            let exe = self.load(name)?;
            let info = &self.manifest.artifacts[name];
            if inputs.len() != info.inputs.len() {
                return Err(anyhow!(
                    "artifact '{name}' expects {} inputs, got {}",
                    info.inputs.len(),
                    inputs.len()
                ));
            }
            let out = exe
                .execute::<Literal>(inputs)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result {name}: {e:?}"))?;
            let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
            if parts.len() != info.n_outputs {
                return Err(anyhow!(
                    "artifact '{name}' declared {} outputs, produced {}",
                    info.n_outputs,
                    parts.len()
                ));
            }
            Ok(parts)
        }
    }

    /// Build an f32 literal of the given shape from a flat slice.
    pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {n} elements, got {}", shape, data.len()));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Build an i32 literal of the given shape.
    pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {n} elements, got {}", shape, data.len()));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Build an i32 scalar literal.
    pub fn lit_i32_scalar(v: i32) -> Literal {
        Literal::scalar(v)
    }

    /// Extract a literal's f32 payload.
    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }
}

#[cfg(not(pjrt_backend))]
mod backend {
    use super::Manifest;
    use anyhow::{anyhow, Result};
    use std::path::Path;

    const DISABLED: &str =
        "PJRT backend not compiled in (build with RUSTFLAGS=\"--cfg pjrt_backend\" and the \
         vendored `xla` crate); use the pure-Rust engine / NaiveEngine paths instead";

    /// Host-side stand-in for a PJRT literal: a flat tensor + shape.
    #[derive(Clone, Debug, PartialEq)]
    pub enum Literal {
        F32 { data: Vec<f32>, shape: Vec<usize> },
        I32 { data: Vec<i32>, shape: Vec<usize> },
    }

    /// Element types extractable from a stub [`Literal`].
    pub trait LiteralElem: Sized {
        fn extract(lit: &Literal) -> Result<Vec<Self>>;
    }

    impl LiteralElem for f32 {
        fn extract(lit: &Literal) -> Result<Vec<f32>> {
            match lit {
                Literal::F32 { data, .. } => Ok(data.clone()),
                Literal::I32 { .. } => Err(anyhow!("literal holds i32, wanted f32")),
            }
        }
    }

    impl LiteralElem for i32 {
        fn extract(lit: &Literal) -> Result<Vec<i32>> {
            match lit {
                Literal::I32 { data, .. } => Ok(data.clone()),
                Literal::F32 { .. } => Err(anyhow!("literal holds f32, wanted i32")),
            }
        }
    }

    impl Literal {
        pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>> {
            T::extract(self)
        }

        pub fn shape(&self) -> &[usize] {
            match self {
                Literal::F32 { shape, .. } | Literal::I32 { shape, .. } => shape,
            }
        }
    }

    /// Stub runtime: carries the parsed manifest so shape/routing logic can
    /// still be exercised, but cannot execute artifacts.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Always fails: there is no PJRT client in this build.
        pub fn open(_dir: impl AsRef<Path>) -> Result<Runtime> {
            Err(anyhow!("{DISABLED}"))
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn compiled_count(&self) -> usize {
            0
        }

        pub fn execute(&self, name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            Err(anyhow!("cannot execute '{name}': {DISABLED}"))
        }
    }

    /// Build an f32 literal of the given shape from a flat slice.
    pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {n} elements, got {}", shape, data.len()));
        }
        Ok(Literal::F32 { data: data.to_vec(), shape: shape.to_vec() })
    }

    /// Build an i32 literal of the given shape.
    pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {n} elements, got {}", shape, data.len()));
        }
        Ok(Literal::I32 { data: data.to_vec(), shape: shape.to_vec() })
    }

    /// Build an i32 scalar literal.
    pub fn lit_i32_scalar(v: i32) -> Literal {
        Literal::I32 { data: vec![v], shape: vec![] }
    }

    /// Extract a literal's f32 payload.
    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
    }
}

pub use backend::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32, Literal, Runtime};

#[cfg(not(pjrt_backend))]
pub use backend::LiteralElem;

/// Default artifact directory: $FLASHD_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("FLASHD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Open the default runtime, with a helpful error if artifacts are missing.
pub fn open_default() -> anyhow::Result<Runtime> {
    use anyhow::Context as _;
    let dir = default_artifact_dir();
    Runtime::open(&dir).with_context(|| {
        format!(
            "failed to open artifacts at {} — run `make artifacts` first",
            dir.display()
        )
    })
}

#[cfg(all(test, not(pjrt_backend)))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_literals_roundtrip() {
        let f = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(f.to_vec::<i32>().is_err());
        let i = lit_i32_scalar(41);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![41]);
        assert!(lit_f32(&[1.0], &[2]).is_err());
    }

    #[test]
    fn stub_runtime_fails_cleanly() {
        let err = Runtime::open("/nonexistent").unwrap_err();
        assert!(format!("{err}").contains("PJRT backend not compiled in"));
        let err = open_default().unwrap_err();
        assert!(format!("{err:#}").contains("failed to open artifacts"));
    }
}
