//! FP8-E4M3 per the OCP / Micikevicius et al. "FP8 formats for deep
//! learning" spec (the paper's second datapath format):
//!   1 sign, 4 exponent (bias 7), 3 mantissa bits,
//!   NO infinities, NaN at S.1111.111 (0x7F / 0xFF),
//!   max finite = 448, min normal = 2^-6, min subnormal = 2^-9.
//! Conversion from f32 uses round-to-nearest-even with saturation to the
//! max finite value (the standard ML-accelerator convention).

/// An FP8-E4M3 value stored as its raw 8 bits.
#[derive(Copy, Clone, PartialEq, Debug, Default)]
pub struct Fp8E4M3(pub u8);

const EXP_BIAS: i32 = 7;
const MAX_FINITE: f32 = 448.0;
const NAN_BITS: u8 = 0x7F;

impl Fp8E4M3 {
    pub const ZERO: Fp8E4M3 = Fp8E4M3(0);
    pub const ONE: Fp8E4M3 = Fp8E4M3(0x38); // exp=7 -> 2^0, mant=0
    pub const MAX: Fp8E4M3 = Fp8E4M3(0x7E); // 448.0

    pub fn from_f32(x: f32) -> Fp8E4M3 {
        if x.is_nan() {
            return Fp8E4M3(NAN_BITS);
        }
        let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
        let a = x.abs();
        if a == 0.0 {
            return Fp8E4M3(sign);
        }
        // Saturate (E4M3 has no inf).
        if a >= MAX_FINITE * (1.0 + 1.0 / 32.0) {
            // beyond the rounding boundary of max finite -> saturate
            return Fp8E4M3(sign | 0x7E);
        }

        // Decompose to exponent/mantissa at f64 precision for exact RNE.
        let af = a as f64;
        let e = af.log2().floor() as i32;
        let e = e.clamp(-9, 8);
        // Normal range: e in [-6, 8]; subnormal below.
        let (exp_field, scale) = if e < -6 {
            (0u8, 2f64.powi(-6 - 3)) // subnormal ulp = 2^-9
        } else {
            (0u8, 0.0) // placeholder; handled below
        };
        let _ = (exp_field, scale);

        let bits = if e < -6 {
            // subnormal: value = mant * 2^-9, mant in 0..8
            let ulp = 2f64.powi(-9);
            let mut mant = (af / ulp).round_ties_even() as u32;
            if mant >= 8 {
                // rounded up into the normal range
                0x08u8 // exp=1, mant=0 => 2^-6
            } else if mant == 0 {
                mant = 0;
                mant as u8
            } else {
                mant as u8
            }
        } else {
            // normal: value = (1 + m/8) * 2^e
            let mut e2 = e;
            let mut frac = af / 2f64.powi(e2);
            if frac >= 2.0 {
                e2 += 1;
                frac /= 2.0;
            }
            let mut mant = ((frac - 1.0) * 8.0).round_ties_even() as i32;
            if mant >= 8 {
                mant = 0;
                e2 += 1;
            }
            if e2 > 8 {
                return Fp8E4M3(sign | 0x7E); // saturate
            }
            let exp_field = (e2 + EXP_BIAS) as u8;
            if exp_field == 0x0F && mant == 7 {
                // would encode NaN; saturate to max finite instead
                return Fp8E4M3(sign | 0x7E);
            }
            (exp_field << 3) | mant as u8
        };
        Fp8E4M3(sign | bits)
    }

    pub fn to_f32(self) -> f32 {
        let sign = if self.0 & 0x80 != 0 { -1.0f32 } else { 1.0 };
        let exp = ((self.0 >> 3) & 0x0F) as i32;
        let mant = (self.0 & 0x07) as i32;
        if exp == 0x0F && mant == 0x07 {
            return f32::NAN;
        }
        if exp == 0 {
            // subnormal: mant * 2^-9
            sign * mant as f32 * 2f32.powi(-9)
        } else {
            sign * (1.0 + mant as f32 / 8.0) * 2f32.powi(exp - EXP_BIAS)
        }
    }

    #[inline]
    pub fn to_bits(self) -> u8 {
        self.0
    }

    #[inline]
    pub fn from_bits(b: u8) -> Fp8E4M3 {
        Fp8E4M3(b)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F) == NAN_BITS
    }
}

impl PartialOrd for Fp8E4M3 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.to_f32().partial_cmp(&other.to_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_constants() {
        assert_eq!(Fp8E4M3::ONE.to_f32(), 1.0);
        assert_eq!(Fp8E4M3::MAX.to_f32(), 448.0);
        assert_eq!(Fp8E4M3::ZERO.to_f32(), 0.0);
        assert!(Fp8E4M3(0x7F).is_nan());
        assert!(Fp8E4M3(0xFF).is_nan());
    }

    #[test]
    fn all_256_codes_roundtrip_through_f32() {
        for b in 0u16..=255 {
            let v = Fp8E4M3(b as u8);
            if v.is_nan() {
                assert!(v.to_f32().is_nan());
                continue;
            }
            let back = Fp8E4M3::from_f32(v.to_f32());
            assert_eq!(back.to_f32(), v.to_f32(), "code {b:#04x}");
        }
    }

    #[test]
    fn exact_values() {
        // From the OCP E4M3 table.
        assert_eq!(Fp8E4M3::from_f32(0.5).to_f32(), 0.5);
        assert_eq!(Fp8E4M3::from_f32(1.5).to_f32(), 1.5);
        assert_eq!(Fp8E4M3::from_f32(240.0).to_f32(), 240.0);
        assert_eq!(Fp8E4M3::from_f32(0.015625).to_f32(), 0.015625); // 2^-6 min normal
        assert_eq!(Fp8E4M3::from_f32(0.001953125).to_f32(), 0.001953125); // 2^-9 min subnormal
    }

    #[test]
    fn saturates_instead_of_inf() {
        assert_eq!(Fp8E4M3::from_f32(1e9).to_f32(), 448.0);
        assert_eq!(Fp8E4M3::from_f32(-1e9).to_f32(), -448.0);
        assert_eq!(Fp8E4M3::from_f32(f32::INFINITY).to_f32(), 448.0);
        assert!(!Fp8E4M3::from_f32(1e9).is_nan());
    }

    #[test]
    fn nan_from_f32_nan() {
        assert!(Fp8E4M3::from_f32(f32::NAN).is_nan());
    }

    #[test]
    fn subnormals() {
        // 3 * 2^-9
        let v = 3.0 * 2f32.powi(-9);
        assert_eq!(Fp8E4M3::from_f32(v).to_f32(), v);
        // tiny underflows to zero
        assert_eq!(Fp8E4M3::from_f32(1e-6).to_f32(), 0.0);
        // halfway between 0 and min subnormal: RNE -> 0 (even)
        assert_eq!(Fp8E4M3::from_f32(2f32.powi(-10)).to_f32(), 0.0);
    }

    #[test]
    fn round_to_nearest_even_normals() {
        // Between 1.0 (mant 0) and 1.125 (mant 1): halfway = 1.0625 -> even (1.0)
        assert_eq!(Fp8E4M3::from_f32(1.0625).to_f32(), 1.0);
        // Between 1.125 and 1.25: halfway = 1.1875 -> even (1.25, mant 2)
        assert_eq!(Fp8E4M3::from_f32(1.1875).to_f32(), 1.25);
        // just above halfway rounds up
        assert_eq!(Fp8E4M3::from_f32(1.07).to_f32(), 1.125);
    }

    #[test]
    fn mantissa_rollover_carries_exponent() {
        // 1.96875 is within half-ulp of 2.0: must carry to exponent.
        assert_eq!(Fp8E4M3::from_f32(1.97).to_f32(), 2.0);
    }

    #[test]
    fn values_near_448_dont_become_nan() {
        assert_eq!(Fp8E4M3::from_f32(460.0).to_f32(), 448.0);
        assert_eq!(Fp8E4M3::from_f32(447.0).to_f32(), 448.0);
    }

    #[test]
    fn relative_error_bounded() {
        let mut worst = 0.0f32;
        for i in 1..4000 {
            let x = i as f32 * 0.1;
            if x > 448.0 {
                break;
            }
            let err = ((Fp8E4M3::from_f32(x).to_f32() - x) / x).abs();
            worst = worst.max(err);
        }
        // half-ulp of 3 mantissa bits = 2^-4 = 0.0625
        assert!(worst <= 0.0625 + 1e-6, "worst {worst}");
    }

    #[test]
    fn ordering() {
        assert!(Fp8E4M3::from_f32(-1.0) < Fp8E4M3::from_f32(0.5));
        assert!(Fp8E4M3::from_f32(2.0) < Fp8E4M3::from_f32(3.0));
    }
}
