//! KV-cache quantization: the storage-precision half of the precision
//! ladder (see the `kernels` module docs).
//!
//! K and V rest in one of three formats — f32, BF16, or FP8-E4M3 — and are
//! dequantized tile-by-tile into per-worker f32 scratch right before the
//! score pass, so the attention recursion itself always runs in f32. The
//! contract is therefore *deterministic*: a kernel run over quantized KV is
//! bit-identical to the f32 kernel run over the dequantized arrays, and the
//! only error vs. a full-precision run is the round-to-nearest-even
//! quantization of the operands (bf16: 2^-9 relative per element, fp8:
//! 2^-4).
//!
//! [`KvRef`] is the borrowed view the kernels consume; the owning side
//! (`coordinator::kv_cache::KvStore`, `model::decode`) lives with the
//! caches. FP8 decode goes through a 256-entry table built once from
//! [`Fp8E4M3::to_f32`], so dequantization is a byte-indexed load — the
//! in-software analogue of the hardware decode ROM.

use std::sync::OnceLock;

use super::{Bf16, Fp8E4M3};

/// Storage precision for a KV cache. `F32` is the default and keeps every
/// path bit-identical to the unquantized kernels (stores borrow zero-copy).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum KvPrecision {
    #[default]
    F32,
    Bf16,
    Fp8,
}

impl KvPrecision {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvPrecision::F32 => 4,
            KvPrecision::Bf16 => 2,
            KvPrecision::Fp8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvPrecision::F32 => "f32",
            KvPrecision::Bf16 => "bf16",
            KvPrecision::Fp8 => "fp8_e4m3",
        }
    }
}

static FP8_DECODE: OnceLock<[f32; 256]> = OnceLock::new();

/// The 256-entry FP8-E4M3 decode table (hardware decode ROM analogue).
/// Entry `b` is exactly `Fp8E4M3(b).to_f32()`.
#[inline]
pub fn fp8_decode_table() -> &'static [f32; 256] {
    FP8_DECODE.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = Fp8E4M3(b as u8).to_f32();
        }
        t
    })
}

/// Quantize to BF16 bits with round-to-nearest-even.
pub fn quantize_bf16(src: &[f32]) -> Vec<u16> {
    src.iter().map(|&x| Bf16::from_f32(x).to_bits()).collect()
}

/// Quantize to FP8-E4M3 bits with round-to-nearest-even and saturation.
pub fn quantize_fp8(src: &[f32]) -> Vec<u8> {
    src.iter().map(|&x| Fp8E4M3::from_f32(x).to_bits()).collect()
}

/// Dequantize BF16 bits; `dst.len()` must equal `src.len()`.
#[inline]
pub fn dequantize_bf16_into(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = Bf16::from_bits(s).to_f32();
    }
}

/// Dequantize FP8-E4M3 bits through the decode table; `dst.len()` must
/// equal `src.len()`.
#[inline]
pub fn dequantize_fp8_into(src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    let lut = fp8_decode_table();
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = lut[s as usize];
    }
}

/// A borrowed, possibly-quantized K or V buffer, in the same flat row-major
/// element order as the f32 slices the kernels take. Lengths are in
/// *elements* (f32 lanes), not bytes.
#[derive(Copy, Clone, Debug)]
pub enum KvRef<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    Fp8(&'a [u8]),
}

impl<'a> KvRef<'a> {
    pub fn len(&self) -> usize {
        match self {
            KvRef::F32(s) => s.len(),
            KvRef::Bf16(s) => s.len(),
            KvRef::Fp8(s) => s.len(),
        }
    }

    pub fn precision(&self) -> KvPrecision {
        match self {
            KvRef::F32(_) => KvPrecision::F32,
            KvRef::Bf16(_) => KvPrecision::Bf16,
            KvRef::Fp8(_) => KvPrecision::Fp8,
        }
    }

    /// The zero-copy escape hatch: `Some` iff the buffer is already f32.
    pub fn as_f32(&self) -> Option<&'a [f32]> {
        match self {
            KvRef::F32(s) => Some(s),
            _ => None,
        }
    }

    /// Element sub-range `[a, b)`.
    pub fn slice(&self, a: usize, b: usize) -> KvRef<'a> {
        match self {
            KvRef::F32(s) => KvRef::F32(&s[a..b]),
            KvRef::Bf16(s) => KvRef::Bf16(&s[a..b]),
            KvRef::Fp8(s) => KvRef::Fp8(&s[a..b]),
        }
    }

    /// Dequantize elements `[a, b)` into `dst` (`dst.len() == b - a`). For
    /// `F32` this is a plain copy, so downstream f32 math is unchanged.
    pub fn load_into(&self, a: usize, b: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), b - a);
        match self {
            KvRef::F32(s) => dst.copy_from_slice(&s[a..b]),
            KvRef::Bf16(s) => dequantize_bf16_into(&s[a..b], dst),
            KvRef::Fp8(s) => dequantize_fp8_into(&s[a..b], dst),
        }
    }

    /// Dequantize the whole buffer into a fresh Vec.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.load_into(0, self.len(), &mut out);
        out
    }

    /// Identity: same variant, same starting address, same length. Used by
    /// the batch coalescer to detect shared KV / causal staircases.
    pub fn same(a: KvRef<'_>, b: KvRef<'_>) -> bool {
        match (a, b) {
            (KvRef::F32(x), KvRef::F32(y)) => std::ptr::eq(x.as_ptr(), y.as_ptr()) && x.len() == y.len(),
            (KvRef::Bf16(x), KvRef::Bf16(y)) => std::ptr::eq(x.as_ptr(), y.as_ptr()) && x.len() == y.len(),
            (KvRef::Fp8(x), KvRef::Fp8(y)) => std::ptr::eq(x.as_ptr(), y.as_ptr()) && x.len() == y.len(),
            _ => false,
        }
    }
}

/// A borrowed *paged* K or V buffer: an ordered list of per-block
/// [`KvRef`] fragments standing in for one logical flat buffer. Every
/// fragment except the last holds exactly `block_elems` elements; the last
/// may be shorter (a partially-filled tail block). Logical element `e`
/// lives at physical position `p = e + start` — offset `p % block_elems`
/// of fragment `p / block_elems` — so [`PagedKv::load_into`] over any
/// element range yields exactly the bytes the contiguous buffer
/// `physical[start..start + len]` would, and the kernels' tile streaming
/// is bit-identical over paged and contiguous storage by construction.
///
/// `start` is how sliding-window views skip the leading slop inside the
/// oldest retained block: the paged store trims whole out-of-window blocks
/// eagerly, and the `< block_elems`-sized remainder is hidden here rather
/// than copied out, so windowed kernels see exactly the attended suffix.
#[derive(Copy, Clone, Debug)]
pub struct PagedKv<'a> {
    /// Per-block element fragments, in logical order.
    pub blocks: &'a [KvRef<'a>],
    /// Elements per full block (fragments `0..blocks.len()-1` are exactly
    /// this long).
    pub block_elems: usize,
    /// Physical element offset of logical element 0 (`< block_elems`:
    /// fully-skipped leading blocks are dropped from `blocks` instead).
    pub start: usize,
    /// Logical length in elements (`start + len <= blocks.len() *
    /// block_elems`).
    pub len: usize,
}

impl<'a> PagedKv<'a> {
    /// Dequantize logical elements `[a, b)` into `dst` (`dst.len() ==
    /// b - a`), gathering across as many block fragments as the range
    /// covers. Equals [`KvRef::load_into`] over the concatenated buffer
    /// with the leading `start` elements dropped.
    pub fn load_into(&self, a: usize, b: usize, dst: &mut [f32]) {
        debug_assert!(a <= b && b <= self.len, "range [{a}, {b}) out of len {}", self.len);
        debug_assert_eq!(dst.len(), b - a);
        if a == b {
            return;
        }
        let (a, b) = (a + self.start, b + self.start);
        let bs = self.block_elems;
        let mut off = 0usize;
        for bi in a / bs..=(b - 1) / bs {
            let base = bi * bs;
            let lo = a.max(base) - base;
            let hi = b.min(base + bs) - base;
            self.blocks[bi].load_into(lo, hi, &mut dst[off..off + (hi - lo)]);
            off += hi - lo;
        }
    }
}

/// The KV operand the kernels consume: one logical buffer that is either a
/// single contiguous [`KvRef`] or a [`PagedKv`] gather over pool blocks.
/// Both answer the same element-range [`KvView::load_into`] queries, and a
/// contiguous `F32` view still exposes the zero-copy escape hatch
/// ([`KvView::as_contig_f32`]) the f32 fast paths delegate to.
#[derive(Copy, Clone, Debug)]
pub enum KvView<'a> {
    Contig(KvRef<'a>),
    Paged(PagedKv<'a>),
}

impl<'a> KvView<'a> {
    pub fn len(&self) -> usize {
        match self {
            KvView::Contig(r) => r.len(),
            KvView::Paged(p) => p.len,
        }
    }

    /// The zero-copy escape hatch: `Some` iff the view is one contiguous
    /// f32 buffer (the pre-paging fast path stays bit-identical *and*
    /// copy-free).
    pub fn as_contig_f32(&self) -> Option<&'a [f32]> {
        match self {
            KvView::Contig(r) => r.as_f32(),
            KvView::Paged(_) => None,
        }
    }

    /// Dequantize logical elements `[a, b)` into `dst` (`dst.len() ==
    /// b - a`).
    pub fn load_into(&self, a: usize, b: usize, dst: &mut [f32]) {
        match self {
            KvView::Contig(r) => r.load_into(a, b, dst),
            KvView::Paged(p) => p.load_into(a, b, dst),
        }
    }

    /// Dequantize the whole logical buffer into a fresh Vec.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.load_into(0, self.len(), &mut out);
        out
    }

    /// Identity (same underlying storage), used by the batch coalescer.
    /// Contiguous views compare via [`KvRef::same`]; paged views compare
    /// the block-list address, so two views are "same" only when they
    /// gather the identical fragment list.
    pub fn same(a: KvView<'_>, b: KvView<'_>) -> bool {
        match (a, b) {
            (KvView::Contig(x), KvView::Contig(y)) => KvRef::same(x, y),
            (KvView::Paged(x), KvView::Paged(y)) => {
                std::ptr::eq(x.blocks.as_ptr(), y.blocks.as_ptr())
                    && x.blocks.len() == y.blocks.len()
                    && x.block_elems == y.block_elems
                    && x.start == y.start
                    && x.len == y.len
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_table_matches_to_f32() {
        let t = fp8_decode_table();
        for b in 0u16..=255 {
            let want = Fp8E4M3(b as u8).to_f32();
            let got = t[b as usize];
            if want.is_nan() {
                assert!(got.is_nan(), "code {b:#04x}");
            } else {
                assert_eq!(got, want, "code {b:#04x}");
            }
        }
    }

    #[test]
    fn quantize_dequantize_roundtrip_is_projection() {
        // dequant(quant(x)) is a fixpoint: quantizing again changes nothing.
        let src: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) * 0.0371).collect();
        let b = quantize_bf16(&src);
        let mut d1 = vec![0.0f32; src.len()];
        dequantize_bf16_into(&b, &mut d1);
        assert_eq!(quantize_bf16(&d1), b);
        let f = quantize_fp8(&src);
        let mut d2 = vec![0.0f32; src.len()];
        dequantize_fp8_into(&f, &mut d2);
        assert_eq!(quantize_fp8(&d2), f);
    }

    #[test]
    fn kvref_slice_load_and_identity() {
        let src: Vec<f32> = (0..64).map(|i| i as f32 * 0.25 - 8.0).collect();
        let qb = quantize_bf16(&src);
        let qf = quantize_fp8(&src);
        for r in [KvRef::F32(&src), KvRef::Bf16(&qb), KvRef::Fp8(&qf)] {
            assert_eq!(r.len(), 64);
            let full = r.to_f32_vec();
            let mut mid = vec![0.0f32; 16];
            r.load_into(8, 24, &mut mid);
            assert_eq!(&full[8..24], &mid[..]);
            let sub = r.slice(8, 24).to_f32_vec();
            assert_eq!(sub, mid);
            assert!(KvRef::same(r, r));
        }
        assert!(!KvRef::same(KvRef::F32(&src), KvRef::Bf16(&qb)));
        assert!(!KvRef::same(KvRef::F32(&src[..32]), KvRef::F32(&src)));
    }

    #[test]
    fn paged_load_matches_contiguous_across_precisions() {
        // One logical 5.5-block buffer split into fragments; every element
        // range must load exactly what the contiguous buffer loads.
        let n = 44usize; // block_elems = 8 -> 5 full blocks + 4-elem tail
        let bs = 8usize;
        let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37 - 3.0).sin()).collect();
        let qb = quantize_bf16(&src);
        let qf = quantize_fp8(&src);
        let cases: Vec<(KvRef, Vec<KvRef>)> = vec![
            (
                KvRef::F32(&src),
                src.chunks(bs).map(KvRef::F32).collect(),
            ),
            (
                KvRef::Bf16(&qb),
                qb.chunks(bs).map(KvRef::Bf16).collect(),
            ),
            (
                KvRef::Fp8(&qf),
                qf.chunks(bs).map(KvRef::Fp8).collect(),
            ),
        ];
        for (contig, frags) in &cases {
            let paged = KvView::Paged(PagedKv { blocks: frags, block_elems: bs, start: 0, len: n });
            let flat = KvView::Contig(*contig);
            assert_eq!(paged.len(), flat.len());
            assert_eq!(paged.to_f32_vec(), flat.to_f32_vec());
            // ranges inside a block, spanning 2 blocks, spanning many,
            // block-aligned, and empty
            for (a, b) in [(0, 0), (1, 5), (6, 11), (3, 31), (8, 16), (40, 44), (0, 44)] {
                let mut want = vec![0.0f32; b - a];
                flat.load_into(a, b, &mut want);
                let mut got = vec![7.7f32; b - a];
                paged.load_into(a, b, &mut got);
                assert_eq!(got, want, "range [{a}, {b})");
            }
        }
    }

    #[test]
    fn kvview_identity_and_zero_copy() {
        let src: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let frags: Vec<KvRef> = src.chunks(8).map(KvRef::F32).collect();
        let paged = KvView::Paged(PagedKv { blocks: &frags, block_elems: 8, start: 0, len: 16 });
        let contig = KvView::Contig(KvRef::F32(&src));
        // zero-copy only for contiguous f32
        assert!(contig.as_contig_f32().is_some());
        assert!(paged.as_contig_f32().is_none());
        let qb = quantize_bf16(&src);
        assert!(KvView::Contig(KvRef::Bf16(&qb)).as_contig_f32().is_none());
        // identity
        assert!(KvView::same(contig, contig));
        assert!(KvView::same(paged, paged));
        assert!(!KvView::same(contig, paged));
        let other: Vec<KvRef> = src.chunks(8).map(KvRef::F32).collect();
        let paged2 = KvView::Paged(PagedKv { blocks: &other, block_elems: 8, start: 0, len: 16 });
        assert!(!KvView::same(paged, paged2), "distinct fragment lists are not identical");
        let shifted = KvView::Paged(PagedKv { blocks: &frags, block_elems: 8, start: 2, len: 14 });
        assert!(!KvView::same(paged, shifted), "differing start offsets are not identical");
    }

    #[test]
    fn paged_start_offset_matches_contiguous_suffix() {
        // A windowed view with a nonzero start must load exactly what the
        // contiguous buffer's suffix loads, at every precision.
        let n = 44usize;
        let bs = 8usize;
        let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.53 + 1.0).cos()).collect();
        let qb = quantize_bf16(&src);
        let qf = quantize_fp8(&src);
        let cases: Vec<(KvRef, Vec<KvRef>)> = vec![
            (KvRef::F32(&src), src.chunks(bs).map(KvRef::F32).collect()),
            (KvRef::Bf16(&qb), qb.chunks(bs).map(KvRef::Bf16).collect()),
            (KvRef::Fp8(&qf), qf.chunks(bs).map(KvRef::Fp8).collect()),
        ];
        for (contig, frags) in &cases {
            for start in [1usize, 3, 7] {
                let len = n - start;
                let paged = KvView::Paged(PagedKv { blocks: frags, block_elems: bs, start, len });
                assert_eq!(paged.len(), len);
                let flat = KvView::Contig(contig.slice(start, n));
                assert_eq!(paged.to_f32_vec(), flat.to_f32_vec(), "start {start}");
                // ranges inside the first partial block, crossing into the
                // next block, block-aligned after shift, and the full tail
                for (a, b) in [(0, 0), (0, 3), (2, 13), (bs - start, 2 * bs - start), (len - 4, len)] {
                    let mut want = vec![0.0f32; b - a];
                    flat.load_into(a, b, &mut want);
                    let mut got = vec![7.7f32; b - a];
                    paged.load_into(a, b, &mut got);
                    assert_eq!(got, want, "start {start} range [{a}, {b})");
                }
            }
        }
    }

    #[test]
    fn precision_metadata() {
        assert_eq!(KvPrecision::default(), KvPrecision::F32);
        assert_eq!(KvPrecision::F32.bytes_per_elem(), 4);
        assert_eq!(KvPrecision::Bf16.bytes_per_elem(), 2);
        assert_eq!(KvPrecision::Fp8.bytes_per_elem(), 1);
    }
}
