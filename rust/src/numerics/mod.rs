//! Reduced-precision floating-point emulation for the hardware study.
//!
//! The paper evaluates its datapaths in BFloat16 and FP8-E4M3. The image has
//! no half/float8 crates, so both formats are implemented from scratch with
//! round-to-nearest-even conversion. Arithmetic is performed as
//! convert -> f32 op -> convert, which models a hardware unit that keeps the
//! operand format at its interfaces (the paper's datapaths likewise compute
//! internal products at higher precision before renormalizing).
//!
//! Two distinct consumers share these formats:
//!
//! * the [`Scalar`] trait below runs *whole kernels* in a reduced format
//!   (every intermediate rounds) — the hardware-faithful datapath study;
//! * the [`quant`] module quantizes only the *KV storage* — operands round
//!   once at rest, tiles dequantize to f32 scratch, and the attention
//!   recursion itself stays in f32. That path is deterministic (bit-equal
//!   to the f32 kernel over the dequantized arrays) and is what the serving
//!   stack's `KvPrecision` knob toggles; see the `kernels` module docs for
//!   the full precision ladder.

pub mod bf16;
pub mod fp8;
pub mod quant;

pub use bf16::Bf16;
pub use fp8::Fp8E4M3;
pub use quant::{KvPrecision, KvRef};

/// A scalar number format the attention kernels can run in. This is the
/// seam that lets the same Rust kernel code execute in f64/f32 (for
//  correctness) and BF16/FP8 (for hardware-faithful numerics + activity
/// traces).
pub trait Scalar: Copy + Clone + PartialOrd + std::fmt::Debug {
    const NAME: &'static str;
    /// Bits in the storage format (used by the hardware cost model).
    const BITS: u32;

    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;

    fn zero() -> Self {
        Self::from_f64(0.0)
    }
    fn one() -> Self {
        Self::from_f64(1.0)
    }

    fn add(self, rhs: Self) -> Self {
        Self::from_f64(self.to_f64() + rhs.to_f64())
    }
    fn sub(self, rhs: Self) -> Self {
        Self::from_f64(self.to_f64() - rhs.to_f64())
    }
    fn mul(self, rhs: Self) -> Self {
        Self::from_f64(self.to_f64() * rhs.to_f64())
    }
    fn div(self, rhs: Self) -> Self {
        Self::from_f64(self.to_f64() / rhs.to_f64())
    }
    fn max(self, rhs: Self) -> Self {
        if self.to_f64() >= rhs.to_f64() { self } else { rhs }
    }
    fn exp(self) -> Self {
        Self::from_f64(self.to_f64().exp())
    }
    fn ln(self) -> Self {
        Self::from_f64(self.to_f64().ln())
    }
    fn sigmoid(self) -> Self {
        let x = self.to_f64();
        let y = if x >= 0.0 {
            1.0 / (1.0 + (-x).exp())
        } else {
            let e = x.exp();
            e / (1.0 + e)
        };
        Self::from_f64(y)
    }

    /// Raw storage bits, for switching-activity estimation.
    fn bits(self) -> u64;
}

impl Scalar for f64 {
    const NAME: &'static str = "f64";
    const BITS: u32 = 64;
    fn from_f64(x: f64) -> Self {
        x
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn bits(self) -> u64 {
        self.to_bits()
    }
}

impl Scalar for f32 {
    const NAME: &'static str = "f32";
    const BITS: u32 = 32;
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn bits(self) -> u64 {
        self.to_bits() as u64
    }
}

impl Scalar for Bf16 {
    const NAME: &'static str = "bf16";
    const BITS: u32 = 16;
    fn from_f64(x: f64) -> Self {
        Bf16::from_f32(x as f32)
    }
    fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
    fn bits(self) -> u64 {
        self.to_bits() as u64
    }
}

impl Scalar for Fp8E4M3 {
    const NAME: &'static str = "fp8_e4m3";
    const BITS: u32 = 8;
    fn from_f64(x: f64) -> Self {
        Fp8E4M3::from_f32(x as f32)
    }
    fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
    fn bits(self) -> u64 {
        self.to_bits() as u64
    }
}

/// Hamming distance between the storage bits of two consecutive values —
/// the toggling proxy used by the power model.
pub fn toggle_count<T: Scalar>(a: T, b: T) -> u32 {
    (a.bits() ^ b.bits()).count_ones()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip_identity_formats() {
        for &x in &[0.0, 1.0, -2.5, 1e-3, 12345.678] {
            assert_eq!(f64::from_f64(x).to_f64(), x);
            assert_eq!(f32::from_f64(x).to_f64(), x as f32 as f64);
        }
    }

    #[test]
    fn generic_ops_match_f64() {
        let a = f64::from_f64(1.5);
        let b = f64::from_f64(2.25);
        assert_eq!(a.add(b), 3.75);
        assert_eq!(a.mul(b), 3.375);
        assert_eq!(b.sub(a), 0.75);
        assert_eq!(b.div(a), 1.5);
        assert_eq!(a.max(b), 2.25);
    }

    #[test]
    fn sigmoid_stable_tails() {
        assert!(f64::from_f64(1000.0).sigmoid().to_f64() > 0.999999);
        assert!(f64::from_f64(-1000.0).sigmoid().to_f64() < 1e-12);
        let mid = f64::from_f64(0.0).sigmoid().to_f64();
        assert!((mid - 0.5).abs() < 1e-15);
    }

    #[test]
    fn toggle_count_counts_bits() {
        assert_eq!(toggle_count(0.0f32, 0.0f32), 0);
        let t = toggle_count(1.0f32, -1.0f32);
        assert_eq!(t, 1); // sign bit only
    }
}
