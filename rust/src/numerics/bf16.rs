//! BFloat16: 1 sign, 8 exponent, 7 mantissa bits — the top 16 bits of an
//! IEEE-754 binary32 value. Conversion uses round-to-nearest-even, matching
//! both TPU hardware and the paper's BFloat16 datapath.

/// A bfloat16 value stored as its raw 16 bits.
#[derive(Copy, Clone, PartialEq, PartialOrd, Debug, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3F80);
    pub const INFINITY: Bf16 = Bf16(0x7F80);
    pub const NEG_INFINITY: Bf16 = Bf16(0xFF80);
    /// Largest finite magnitude (~3.39e38).
    pub const MAX: Bf16 = Bf16(0x7F7F);

    /// Convert from f32 with round-to-nearest-even on the dropped 16 bits.
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet NaN, preserve sign
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = 0x0000_8000u32;
        let lower = bits & 0xFFFF;
        let mut upper = (bits >> 16) as u16;
        // round-to-nearest-even: round up if lower > half, or exactly half
        // and the kept LSB is odd.
        if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
            upper = upper.wrapping_add(1); // may carry into exponent -> inf (correct)
        }
        Bf16(upper)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn from_bits(b: u16) -> Bf16 {
        Bf16(b)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F80) == 0x7F80 && (self.0 & 0x007F) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7F80
    }

    /// Machine epsilon for bf16 (2^-7).
    pub fn epsilon() -> f32 {
        2.0_f32.powi(-7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -256..=256 {
            let x = i as f32;
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{i}");
        }
    }

    #[test]
    fn one_and_zero_bits() {
        assert_eq!(Bf16::from_f32(1.0), Bf16::ONE);
        assert_eq!(Bf16::from_f32(0.0), Bf16::ZERO);
        assert_eq!(Bf16::from_f32(-0.0).to_bits(), 0x8000);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between bf16(1.0) and the next
        // representable value; RNE keeps the even mantissa (1.0).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).to_bits(), 0x3F80);
        // Just above halfway must round up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).to_bits(), 0x3F81);
        // Halfway with odd kept-LSB rounds up to even.
        let halfway_odd = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(halfway_odd).to_bits(), 0x3F82);
    }

    #[test]
    fn overflow_to_infinity() {
        let big = f32::MAX; // rounds up past bf16 max -> inf
        assert!(Bf16::from_f32(big).is_infinite());
        assert!(Bf16::from_f32(-f32::MAX).to_f32().is_infinite());
    }

    #[test]
    fn nan_propagates() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn relative_error_bounded_by_epsilon() {
        let mut worst = 0.0f32;
        for i in 0..10_000 {
            let x = (i as f32 + 0.5) * 0.037 - 185.0;
            if x == 0.0 {
                continue;
            }
            let err = ((Bf16::from_f32(x).to_f32() - x) / x).abs();
            worst = worst.max(err);
        }
        assert!(worst <= Bf16::epsilon() * 0.5 + 1e-7, "worst {worst}");
    }

    #[test]
    fn ordering_matches_f32_for_positives() {
        let vals = [0.1f32, 0.5, 1.0, 3.25, 100.0, 1e10];
        for w in vals.windows(2) {
            assert!(Bf16::from_f32(w[0]) < Bf16::from_f32(w[1]));
        }
    }
}
