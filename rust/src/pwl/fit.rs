//! Continuous piece-wise-linear least-squares fitting with optimal-ish knot
//! placement (equi-curvature rule). Plays the role of the `pwlf` Python
//! library cited by the paper.

/// A continuous PWL function defined by knot abscissae and ordinates.
#[derive(Clone, Debug)]
pub struct Pwl {
    pub knots: Vec<f64>,
    pub vals: Vec<f64>,
}

impl Pwl {
    pub fn segments(&self) -> usize {
        self.knots.len() - 1
    }

    /// Evaluate with saturation outside [knots[0], knots[last]].
    pub fn eval(&self, x: f64) -> f64 {
        let k = &self.knots;
        let n = k.len();
        if x <= k[0] {
            return self.vals[0];
        }
        if x >= k[n - 1] {
            return self.vals[n - 1];
        }
        // binary search for the segment
        let mut lo = 0usize;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if k[mid] <= x {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let t = (x - k[lo]) / (k[lo + 1] - k[lo]);
        self.vals[lo] + t * (self.vals[lo + 1] - self.vals[lo])
    }

    /// Slope/intercept pairs per segment — what the hardware stores in its
    /// coefficient ROM.
    pub fn coefficients(&self) -> Vec<(f64, f64)> {
        (0..self.segments())
            .map(|i| {
                let a = (self.vals[i + 1] - self.vals[i]) / (self.knots[i + 1] - self.knots[i]);
                let b = self.vals[i] - a * self.knots[i];
                (a, b)
            })
            .collect()
    }

    pub fn max_error_against(&self, f: impl Fn(f64) -> f64, grid: usize) -> f64 {
        let (lo, hi) = (self.knots[0], *self.knots.last().unwrap());
        let mut worst: f64 = 0.0;
        for i in 0..=grid {
            let x = lo + (hi - lo) * i as f64 / grid as f64;
            worst = worst.max((self.eval(x) - f(x)).abs());
        }
        worst
    }
}

/// Least-squares fit of the knot ordinates for FIXED knot abscissae, using
/// the continuous hat-function basis over a dense sample grid.
fn fit_ordinates(f: &dyn Fn(f64) -> f64, knots: &[f64], grid: usize) -> Vec<f64> {
    let n = knots.len();
    let (lo, hi) = (knots[0], knots[n - 1]);
    // Normal equations A^T A c = A^T y. The hat basis makes A^T A
    // tridiagonal; build it densely (n <= ~16) and solve by Gaussian
    // elimination.
    let mut ata = vec![vec![0.0f64; n]; n];
    let mut aty = vec![0.0f64; n];
    for g in 0..=grid {
        let x = lo + (hi - lo) * g as f64 / grid as f64;
        let y = f(x);
        // Find segment (linear scan ok at fit time).
        let mut seg = 0;
        while seg + 2 < n && knots[seg + 1] <= x {
            seg += 1;
        }
        let t = (x - knots[seg]) / (knots[seg + 1] - knots[seg]);
        let (i, j, wi, wj) = (seg, seg + 1, 1.0 - t, t);
        ata[i][i] += wi * wi;
        ata[i][j] += wi * wj;
        ata[j][i] += wi * wj;
        ata[j][j] += wj * wj;
        aty[i] += wi * y;
        aty[j] += wj * y;
    }
    solve_dense(&mut ata, &mut aty);
    aty
}

/// In-place Gaussian elimination with partial pivoting; solution left in b.
fn solve_dense(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-300, "singular PWL normal equations");
        for r in col + 1..n {
            let factor = a[r][col] / d;
            if factor == 0.0 {
                continue;
            }
            for c in col..n {
                a[r][c] -= factor * a[col][c];
            }
            b[r] -= factor * b[col];
        }
    }
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in col + 1..n {
            acc -= a[col][c] * b[c];
        }
        b[col] = acc / a[col][col];
    }
}

/// Fit with uniformly spaced knots.
pub fn fit_uniform(f: impl Fn(f64) -> f64 + Copy, lo: f64, hi: f64, nseg: usize, grid: usize) -> Pwl {
    let knots: Vec<f64> = (0..=nseg).map(|i| lo + (hi - lo) * i as f64 / nseg as f64).collect();
    let vals = fit_ordinates(&f, &knots, grid);
    Pwl { knots, vals }
}

/// Fit with knots placed by the equi-curvature rule: knot density ∝ |f''|^½,
/// the asymptotically optimal distribution for piecewise-linear
/// approximation error.
pub fn fit_adaptive(f: impl Fn(f64) -> f64 + Copy, lo: f64, hi: f64, nseg: usize, grid: usize) -> Pwl {
    let h = (hi - lo) / grid as f64;
    // |f''|^(1/2) via central differences, with a floor so flat regions
    // still receive some knots.
    let mut density = Vec::with_capacity(grid + 1);
    for i in 0..=grid {
        let x = lo + h * i as f64;
        let xm = (x - h).max(lo);
        let xp = (x + h).min(hi);
        let d2 = (f(xp) - 2.0 * f(x) + f(xm)) / (h * h);
        density.push(d2.abs().sqrt().max(1e-4));
    }
    // cumulative integral of the density
    let mut cum = vec![0.0f64; grid + 1];
    for i in 1..=grid {
        cum[i] = cum[i - 1] + 0.5 * (density[i] + density[i - 1]) * h;
    }
    let total = cum[grid];
    // invert: find x where cum = k/nseg * total
    let mut knots = vec![lo];
    let mut idx = 0usize;
    for kseg in 1..nseg {
        let target = total * kseg as f64 / nseg as f64;
        while idx < grid && cum[idx + 1] < target {
            idx += 1;
        }
        let t = if cum[idx + 1] > cum[idx] {
            (target - cum[idx]) / (cum[idx + 1] - cum[idx])
        } else {
            0.0
        };
        knots.push(lo + h * (idx as f64 + t));
    }
    knots.push(hi);
    // guard against degenerate (coincident) knots
    for i in 1..knots.len() {
        if knots[i] <= knots[i - 1] {
            knots[i] = knots[i - 1] + 1e-9 * (hi - lo);
        }
    }
    let vals = fit_ordinates(&f, &knots, grid);
    Pwl { knots, vals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_a_line_exactly() {
        let p = fit_uniform(|x| 3.0 * x - 2.0, -1.0, 4.0, 8, 500);
        for i in 0..=50 {
            let x = -1.0 + 5.0 * i as f64 / 50.0;
            assert!((p.eval(x) - (3.0 * x - 2.0)).abs() < 1e-9);
        }
    }

    #[test]
    fn adaptive_beats_uniform_on_ln() {
        let lo = 0.0025;
        let u = fit_uniform(f64::ln, lo, 1.0, 8, 4000);
        let a = fit_adaptive(f64::ln, lo, 1.0, 8, 4000);
        let eu = u.max_error_against(f64::ln, 10_000);
        let ea = a.max_error_against(f64::ln, 10_000);
        assert!(ea < eu, "adaptive {ea} vs uniform {eu}");
        assert!(ea < 0.25, "{ea}");
    }

    #[test]
    fn saturation_outside_domain() {
        let p = fit_uniform(|x| x * x, 0.0, 1.0, 4, 200);
        assert_eq!(p.eval(-5.0), p.vals[0]);
        assert_eq!(p.eval(9.0), *p.vals.last().unwrap());
    }

    #[test]
    fn coefficients_reconstruct_eval() {
        let p = fit_adaptive(f64::exp, -1.0, 1.0, 6, 1000);
        let coefs = p.coefficients();
        for i in 0..p.segments() {
            let xm = 0.5 * (p.knots[i] + p.knots[i + 1]);
            let (a, b) = coefs[i];
            assert!((a * xm + b - p.eval(xm)).abs() < 1e-12);
        }
    }

    #[test]
    fn eval_is_continuous_at_knots() {
        let p = fit_adaptive(|x| (3.0 * x).sin(), 0.0, 3.0, 8, 2000);
        for i in 1..p.knots.len() - 1 {
            let k = p.knots[i];
            let eps = 1e-9;
            assert!((p.eval(k - eps) - p.eval(k + eps)).abs() < 1e-6);
        }
    }

    #[test]
    fn solver_handles_permuted_system() {
        let mut a = vec![
            vec![0.0, 2.0, 1.0],
            vec![1.0, 0.0, 0.0],
            vec![0.0, 1.0, 3.0],
        ];
        let mut b = vec![5.0, 1.0, 10.0];
        solve_dense(&mut a, &mut b);
        // x = 1; 2y + z = 5; y + 3z = 10 -> y = 1, z = 3
        assert!((b[0] - 1.0).abs() < 1e-12);
        assert!((b[1] - 1.0).abs() < 1e-12);
        assert!((b[2] - 3.0).abs() < 1e-12);
    }
}
