//! Piece-wise linear (PWL) function approximation — the in-Rust equivalent
//! of the paper's use of the `pwlf` Python library (§IV-B): both non-linear
//! units in the FLASH-D datapath (sigmoid on the active region [-6, 11] and
//! natural log on (0, 1)) are implemented as 8-segment PWL approximations.
//!
//! Fitting: knots are placed by the equi-curvature rule (density ∝ |f''|^½,
//! the asymptotically optimal placement for piecewise-linear interpolation),
//! then the knot ordinates are least-squares fitted over a dense grid with
//! the continuous hat-function basis. Evaluation saturates outside the
//! domain — exactly the saturation behaviour the paper exploits for its
//! skip criterion.

pub mod fit;

pub use fit::{fit_adaptive, fit_uniform, Pwl};

use crate::numerics::Scalar;

/// Number of segments used by the paper for both units.
pub const SEGMENTS: usize = 8;

/// The sigmoid active region from the paper (§III-C / Fig. 2).
pub const SIGMOID_LO: f64 = -6.0;
pub const SIGMOID_HI: f64 = 11.0;

/// ln() input domain: the previous weight w ∈ (0, 1). The smallest weight
/// the clamped recursion can produce is sigmoid(-6).
pub const LN_LO: f64 = 0.0024726231566347743; // sigmoid(-6)
pub const LN_HI: f64 = 1.0;

fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// The paired sigmoid + ln PWL tables the tiled kernel's
/// `SigmoidMode::Pwl { segments }` fast path evaluates through — the two
/// non-linear units of the paper's Fig. 3 datapath, with a configurable
/// segment count (the paper uses [`SEGMENTS`] = 8 for both).
///
/// Mirrors `flashd::attention_pwl`'s structure: the weight comes from the
/// sigmoid table (clamped to [0, 1]) and the carried `ln w` from the ln
/// table applied to that weight (clamped to <= 0), so the software fast
/// path models the same two ROMs the hardware would instantiate.
#[derive(Clone, Debug)]
pub struct SigTables {
    segments: usize,
    sig: Pwl,
    ln: Pwl,
}

impl SigTables {
    pub fn new(segments: usize) -> SigTables {
        let segments = segments.max(1);
        SigTables {
            segments,
            sig: fit_adaptive(sigmoid, SIGMOID_LO, SIGMOID_HI, segments, 4096),
            ln: fit_adaptive(f64::ln, LN_LO, LN_HI, segments, 4096),
        }
    }

    pub fn segments(&self) -> usize {
        self.segments
    }

    /// One weight-update step: `(w, ln w)` for sigmoid argument `x`.
    ///
    /// The sigmoid table saturates to ~sigmoid(-6) > 0 below the domain, so
    /// `w` stays positive and the ln table's domain `[sigmoid(-6), 1]`
    /// covers it; the `w <= 0` guard (pass-through `ln w := x`, the same
    /// low-tail identity the skip path uses) only protects against a
    /// degenerate fit.
    #[inline]
    pub fn weight_and_ln(&self, x: f64) -> (f64, f64) {
        let w = self.sig.eval(x).clamp(0.0, 1.0);
        let ln_w = if w <= 0.0 { x } else { self.ln.eval(w).min(0.0) };
        (w, ln_w)
    }

    /// Measured max abs error of the sigmoid table over its domain.
    pub fn sigmoid_max_error(&self) -> f64 {
        self.sig.max_error_against(sigmoid, 20_000)
    }

    /// Measured max abs error of the ln table over its domain.
    pub fn ln_max_error(&self) -> f64 {
        self.ln.max_error_against(f64::ln, 20_000)
    }
}

/// The hardware sigmoid unit: 8-segment PWL over [-6, 11], saturating to
/// (near) 0 / 1 outside — Fig. 3's σ block.
#[derive(Clone, Debug)]
pub struct SigmoidPwl {
    pwl: Pwl,
}

impl SigmoidPwl {
    pub fn new() -> SigmoidPwl {
        SigmoidPwl { pwl: fit_adaptive(sigmoid, SIGMOID_LO, SIGMOID_HI, SEGMENTS, 4096) }
    }

    /// Evaluate in format T: the multiply-add runs at the format's
    /// precision, modelling the hardware unit's internal rounding.
    pub fn eval<T: Scalar>(&self, x: T) -> T {
        T::from_f64(self.pwl.eval(x.to_f64()).clamp(0.0, 1.0))
    }

    pub fn eval_f64(&self, x: f64) -> f64 {
        self.pwl.eval(x).clamp(0.0, 1.0)
    }

    pub fn max_error(&self) -> f64 {
        self.pwl.max_error_against(sigmoid, 20_000)
    }

    pub fn table(&self) -> &Pwl {
        &self.pwl
    }
}

impl Default for SigmoidPwl {
    fn default() -> Self {
        Self::new()
    }
}

/// The hardware natural-log unit: 8-segment PWL over [sigmoid(-6), 1].
/// "we require one that consistently returns a negative result that follows
/// the value of the previous weight" (§IV-B) — outputs clamp to <= 0.
#[derive(Clone, Debug)]
pub struct LnPwl {
    pwl: Pwl,
}

impl LnPwl {
    pub fn new() -> LnPwl {
        LnPwl { pwl: fit_adaptive(f64::ln, LN_LO, LN_HI, SEGMENTS, 4096) }
    }

    pub fn eval<T: Scalar>(&self, x: T) -> T {
        T::from_f64(self.pwl.eval(x.to_f64()).min(0.0))
    }

    pub fn eval_f64(&self, x: f64) -> f64 {
        self.pwl.eval(x).min(0.0)
    }

    pub fn max_error(&self) -> f64 {
        self.pwl.max_error_against(f64::ln, 20_000)
    }

    pub fn table(&self) -> &Pwl {
        &self.pwl
    }
}

impl Default for LnPwl {
    fn default() -> Self {
        Self::new()
    }
}

/// The FlashAttention2 baseline's exponential unit: PWL after range
/// reduction (cf. [19] in the paper). exp(x) = 2^k * exp(r) with
/// r ∈ [-ln2/2, ln2/2); the PWL covers exp(r) and the 2^k is an exponent
/// add (free in FP hardware).
#[derive(Clone, Debug)]
pub struct ExpPwl {
    pwl: Pwl,
}

impl ExpPwl {
    pub fn new() -> ExpPwl {
        let half_ln2 = std::f64::consts::LN_2 / 2.0;
        ExpPwl { pwl: fit_adaptive(f64::exp, -half_ln2, half_ln2, SEGMENTS, 4096) }
    }

    pub fn eval_f64(&self, x: f64) -> f64 {
        // Range-reduce: x = k*ln2 + r.
        let k = (x / std::f64::consts::LN_2).round();
        let r = x - k * std::f64::consts::LN_2;
        let m = self.pwl.eval(r);
        let k = k.clamp(-1022.0, 1023.0) as i32;
        m * 2f64.powi(k)
    }

    pub fn eval<T: Scalar>(&self, x: T) -> T {
        T::from_f64(self.eval_f64(x.to_f64()))
    }

    pub fn max_rel_error(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..20_000 {
            let x = -20.0 + 25.0 * i as f64 / 20_000.0;
            let got = self.eval_f64(x);
            let want = x.exp();
            worst = worst.max(((got - want) / want).abs());
        }
        worst
    }
}

impl Default for ExpPwl {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::{Bf16, Fp8E4M3};

    #[test]
    fn sigmoid_pwl_accuracy() {
        let s = SigmoidPwl::new();
        // 8 optimized segments over a 17-wide domain: ~1% max error.
        assert!(s.max_error() < 0.015, "max err {}", s.max_error());
    }

    #[test]
    fn sigmoid_pwl_saturates() {
        let s = SigmoidPwl::new();
        assert!(s.eval_f64(-100.0) <= sigmoid(SIGMOID_LO) + 0.01);
        assert!(s.eval_f64(100.0) >= sigmoid(SIGMOID_HI) - 0.01);
        assert!(s.eval_f64(-1e30) >= 0.0 && s.eval_f64(1e30) <= 1.0);
    }

    #[test]
    fn sigmoid_pwl_monotone_on_grid() {
        let s = SigmoidPwl::new();
        let mut prev = -1.0;
        for i in 0..=1000 {
            let x = SIGMOID_LO + (SIGMOID_HI - SIGMOID_LO) * i as f64 / 1000.0;
            let y = s.eval_f64(x);
            assert!(y >= prev - 1e-12, "not monotone at {x}");
            prev = y;
        }
    }

    #[test]
    fn ln_pwl_accuracy_and_sign() {
        let l = LnPwl::new();
        // worst error concentrates near the steep end; bounded per DESIGN §6
        assert!(l.max_error() < 0.25, "max err {}", l.max_error());
        for i in 1..=100 {
            let x = LN_LO + (LN_HI - LN_LO) * i as f64 / 100.0;
            assert!(l.eval_f64(x) <= 0.0, "ln must stay negative, x={x}");
        }
        // good accuracy in the common region w in [0.2, 1]
        for i in 0..=100 {
            let x = 0.2 + 0.8 * i as f64 / 100.0;
            assert!((l.eval_f64(x) - x.ln()).abs() < 0.08, "x={x}");
        }
    }

    #[test]
    fn exp_pwl_range_reduction() {
        let e = ExpPwl::new();
        assert!(e.max_rel_error() < 0.005, "rel err {}", e.max_rel_error());
        assert!((e.eval_f64(0.0) - 1.0).abs() < 0.005);
        assert!((e.eval_f64(-10.0) - (-10.0f64).exp()).abs() < 1e-6);
    }

    #[test]
    fn eval_in_reduced_formats() {
        let s = SigmoidPwl::new();
        let y16 = s.eval(Bf16::from_f32(1.0)).to_f32();
        assert!((y16 as f64 - sigmoid(1.0)).abs() < 0.02, "{y16}");
        let y8 = s.eval(Fp8E4M3::from_f32(1.0)).to_f32();
        assert!((y8 as f64 - sigmoid(1.0)).abs() < 0.08, "{y8}");
    }

    #[test]
    fn segment_count_is_papers_eight() {
        assert_eq!(SigmoidPwl::new().table().segments(), SEGMENTS);
        assert_eq!(LnPwl::new().table().segments(), SEGMENTS);
    }

    #[test]
    fn sig_tables_weight_and_ln_envelope() {
        let t = SigTables::new(SEGMENTS);
        assert_eq!(t.segments(), SEGMENTS);
        let es = t.sigmoid_max_error();
        let el = t.ln_max_error();
        assert!(es < 0.015, "sigmoid table err {es}");
        assert!(el < 0.25, "ln table err {el}");
        for i in 0..=400 {
            let x = -12.0 + 26.0 * i as f64 / 400.0;
            let (w, lnw) = t.weight_and_ln(x);
            assert!((0.0..=1.0).contains(&w), "x={x} w={w}");
            assert!(lnw <= 0.0, "x={x} lnw={lnw}");
            if x >= SIGMOID_LO && x <= SIGMOID_HI {
                assert!((w - sigmoid(x)).abs() <= es + 1e-12, "x={x}");
            }
            if w >= LN_LO {
                assert!((lnw - w.ln()).abs() <= el + 1e-12, "x={x}");
            }
        }
    }

    #[test]
    fn sig_tables_more_segments_tighter() {
        let coarse = SigTables::new(4);
        let fine = SigTables::new(16);
        assert!(fine.sigmoid_max_error() < coarse.sigmoid_max_error());
        assert!(fine.ln_max_error() < coarse.ln_max_error());
    }
}
