//! Admission control and dispatch ordering: a bounded two-class queue with
//! decode-priority (latency-sensitive single-token steps preempt bulk
//! prefill work) and backpressure when full.
//!
//! Every admitted request is stamped with a monotone sequence number; the
//! `Fifo` policy dispatches strictly by it, so same-`Instant` arrivals can
//! never reorder. The continuous-batching worker builds cycles through the
//! incremental API ([`Scheduler::peek_next`] / [`Scheduler::pop_next`])
//! so it can apply token-budget and eviction checks per request;
//! [`Scheduler::drain_cycle`] remains the pure-policy drain used by the
//! property tests and width-bounded callers.

use super::request::AttentionRequest;
use std::collections::VecDeque;

/// Dispatch policies.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Strict arrival order.
    Fifo,
    /// Decode requests before prefill/stateless (vLLM-style decode-first).
    DecodeFirst,
}

/// Rejection reason surfaced to clients.
#[derive(Debug, PartialEq, Eq)]
pub enum Rejected {
    /// Queue at capacity. Carries the observed depth and the configured
    /// capacity so clients can distinguish transient pressure from a
    /// misconfigured limit and implement informed retry/backoff.
    QueueFull { depth: usize, capacity: usize },
    Invalid(String),
}

/// One queued request, stamped at admission.
#[derive(Debug)]
struct Queued {
    /// Monotone admission sequence number — the `Fifo` dispatch key.
    seq: u64,
    /// Value of the cycle counter when the request was admitted, for
    /// starvation accounting ([`Scheduler::oldest_other_wait`]).
    enq_cycle: u64,
    req: AttentionRequest,
}

/// Bounded scheduler queue.
#[derive(Debug)]
pub struct Scheduler {
    decode: VecDeque<Queued>,
    other: VecDeque<Queued>,
    pub capacity: usize,
    pub policy: Policy,
    /// Drain-cycle sizing knob: how many requests one dispatch cycle may
    /// pull ([`Scheduler::drain_cycle`]). This bounds the width of a fused
    /// kernel submission (in requests) without capping admission.
    pub drain_max: usize,
    pub admitted: u64,
    pub rejected: u64,
    seq: u64,
    cycles: u64,
}

impl Scheduler {
    pub fn new(capacity: usize, policy: Policy) -> Scheduler {
        Scheduler {
            decode: VecDeque::new(),
            other: VecDeque::new(),
            capacity,
            policy,
            drain_max: capacity,
            admitted: 0,
            rejected: 0,
            seq: 0,
            cycles: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.decode.len() + self.other.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a request, applying validation and backpressure.
    pub fn submit(&mut self, req: AttentionRequest) -> Result<(), Rejected> {
        if let Err(e) = req.validate() {
            self.rejected += 1;
            return Err(Rejected::Invalid(e));
        }
        let depth = self.len();
        if depth >= self.capacity {
            self.rejected += 1;
            return Err(Rejected::QueueFull { depth, capacity: self.capacity });
        }
        self.admitted += 1;
        self.seq += 1;
        let q = Queued { seq: self.seq, enq_cycle: self.cycles, req };
        if q.req.is_decode() {
            self.decode.push_back(q);
        } else {
            self.other.push_back(q);
        }
        Ok(())
    }

    /// Start a new admission cycle (starvation accounting tick).
    pub fn begin_cycle(&mut self) {
        self.cycles += 1;
    }

    /// Which queue the next pop comes from under the current policy:
    /// `Some(true)` for decode, `Some(false)` for other, `None` when empty.
    fn next_is_decode(&self) -> Option<bool> {
        match self.policy {
            Policy::DecodeFirst => {
                if !self.decode.is_empty() {
                    Some(true)
                } else if !self.other.is_empty() {
                    Some(false)
                } else {
                    None
                }
            }
            // strict admission order: dispatch by sequence number
            Policy::Fifo => match (self.decode.front(), self.other.front()) {
                (Some(d), Some(o)) => Some(d.seq < o.seq),
                (Some(_), None) => Some(true),
                (None, Some(_)) => Some(false),
                (None, None) => None,
            },
        }
    }

    /// The request the next [`Scheduler::pop_next`] would return, without
    /// removing it — the admission loop peeks to cost a request against
    /// its token budget before committing.
    pub fn peek_next(&self) -> Option<&AttentionRequest> {
        let decode = self.next_is_decode()?;
        let q = if decode { self.decode.front() } else { self.other.front() };
        q.map(|q| &q.req)
    }

    /// Pop the next request in dispatch order.
    pub fn pop_next(&mut self) -> Option<AttentionRequest> {
        let decode = self.next_is_decode()?;
        let q = if decode { self.decode.pop_front() } else { self.other.pop_front() };
        q.map(|q| q.req)
    }

    /// Admission cycles the oldest queued non-decode request has waited
    /// (0 when none queued). Under `DecodeFirst` a steady decode stream
    /// would otherwise starve prefills forever; the worker promotes the
    /// head of the other queue once this crosses its wait threshold.
    pub fn oldest_other_wait(&self) -> u64 {
        self.other.front().map_or(0, |q| self.cycles.saturating_sub(q.enq_cycle))
    }

    /// Pop the oldest non-decode request out of dispatch order (starvation
    /// promotion under `DecodeFirst`).
    pub fn pop_other(&mut self) -> Option<AttentionRequest> {
        self.other.pop_front().map(|q| q.req)
    }

    /// Drain one dispatch cycle: up to [`Scheduler::drain_max`] requests
    /// in dispatch order. The coordinator lowers everything one call
    /// returns into a single fused kernel submission.
    pub fn drain_cycle(&mut self) -> Vec<AttentionRequest> {
        self.begin_cycle();
        self.drain(self.drain_max)
    }

    /// Drain up to `max` requests in dispatch order.
    pub fn drain(&mut self, max: usize) -> Vec<AttentionRequest> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.pop_next() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestKind, ShapeSig, Variant};
    use std::time::Instant;

    fn req(id: u64, decode: bool) -> AttentionRequest {
        AttentionRequest {
            id,
            kind: if decode { RequestKind::Decode { session: 1 } } else { RequestKind::Stateless },
            variant: Variant::FlashD,
            sig: ShapeSig { heads: 1, head_dim: 2 },
            q: vec![0.0; 2],
            nq: 1,
            k: vec![0.0; 2],
            v: vec![0.0; 2],
            nkv: 1,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn backpressure_when_full() {
        let mut s = Scheduler::new(2, Policy::Fifo);
        s.submit(req(1, true)).unwrap();
        s.submit(req(2, false)).unwrap();
        assert_eq!(s.submit(req(3, true)), Err(Rejected::QueueFull { depth: 2, capacity: 2 }));
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn invalid_rejected_before_capacity() {
        let mut s = Scheduler::new(1, Policy::Fifo);
        let mut bad = req(1, true);
        bad.q.clear();
        assert!(matches!(s.submit(bad), Err(Rejected::Invalid(_))));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn decode_first_ordering() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        s.submit(req(1, false)).unwrap();
        s.submit(req(2, true)).unwrap();
        s.submit(req(3, false)).unwrap();
        s.submit(req(4, true)).unwrap();
        let order: Vec<u64> = s.drain(10).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 4, 1, 3]);
        assert!(s.is_empty());
    }

    /// Regression for the `submitted_at` tie-break: two requests admitted
    /// at the very same `Instant` (prefill first, decode second) must
    /// drain in admission order under `Fifo`. The old comparison let the
    /// decode win ties and reorder ahead of the earlier prefill.
    #[test]
    fn fifo_same_instant_keeps_arrival_order() {
        let mut s = Scheduler::new(10, Policy::Fifo);
        let now = Instant::now();
        let mut first = req(1, false);
        first.submitted_at = now;
        let mut second = req(2, true);
        second.submitted_at = now;
        s.submit(first).unwrap();
        s.submit(second).unwrap();
        let order: Vec<u64> = s.drain(10).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2]);
    }

    #[test]
    fn fifo_interleaves_classes_by_seq() {
        let mut s = Scheduler::new(10, Policy::Fifo);
        s.submit(req(1, true)).unwrap();
        s.submit(req(2, false)).unwrap();
        s.submit(req(3, true)).unwrap();
        s.submit(req(4, false)).unwrap();
        let order: Vec<u64> = s.drain(10).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2, 3, 4]);
    }

    #[test]
    fn peek_matches_pop() {
        for policy in [Policy::Fifo, Policy::DecodeFirst] {
            let mut s = Scheduler::new(10, policy);
            for i in 0..6 {
                s.submit(req(i, i % 2 == 0)).unwrap();
            }
            while let Some(peeked) = s.peek_next().map(|r| r.id) {
                let popped = s.pop_next().unwrap().id;
                assert_eq!(peeked, popped);
            }
            assert!(s.is_empty());
        }
    }

    #[test]
    fn oldest_other_wait_tracks_cycles() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        assert_eq!(s.oldest_other_wait(), 0);
        s.submit(req(1, false)).unwrap();
        assert_eq!(s.oldest_other_wait(), 0);
        s.begin_cycle();
        s.begin_cycle();
        assert_eq!(s.oldest_other_wait(), 2);
        assert_eq!(s.pop_other().unwrap().id, 1);
        assert_eq!(s.oldest_other_wait(), 0);
    }

    #[test]
    fn drain_cycle_respects_sizing_knob() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        s.drain_max = 3;
        for i in 0..7 {
            s.submit(req(i, i % 2 == 0)).unwrap();
        }
        assert_eq!(s.drain_cycle().len(), 3);
        assert_eq!(s.drain_cycle().len(), 3);
        assert_eq!(s.drain_cycle().len(), 1);
        assert!(s.is_empty());
    }

    #[test]
    fn drain_partial() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        for i in 0..5 {
            s.submit(req(i, true)).unwrap();
        }
        assert_eq!(s.drain(2).len(), 2);
        assert_eq!(s.len(), 3);
    }
}
