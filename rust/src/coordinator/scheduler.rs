//! Admission control and dispatch ordering: a bounded two-class queue with
//! decode-priority (latency-sensitive single-token steps preempt bulk
//! prefill work) and backpressure when full.

use super::request::AttentionRequest;
use std::collections::VecDeque;

/// Dispatch policies.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Strict arrival order.
    Fifo,
    /// Decode requests before prefill/stateless (vLLM-style decode-first).
    DecodeFirst,
}

/// Rejection reason surfaced to clients.
#[derive(Debug, PartialEq, Eq)]
pub enum Rejected {
    QueueFull,
    Invalid(String),
}

/// Bounded scheduler queue.
#[derive(Debug)]
pub struct Scheduler {
    decode: VecDeque<AttentionRequest>,
    other: VecDeque<AttentionRequest>,
    pub capacity: usize,
    pub policy: Policy,
    /// Drain-cycle sizing knob: how many requests one dispatch cycle may
    /// pull ([`Scheduler::drain_cycle`]). This bounds the width of a fused
    /// kernel submission (in requests) without capping admission.
    pub drain_max: usize,
    pub admitted: u64,
    pub rejected: u64,
    seq: u64,
}

impl Scheduler {
    pub fn new(capacity: usize, policy: Policy) -> Scheduler {
        Scheduler {
            decode: VecDeque::new(),
            other: VecDeque::new(),
            capacity,
            policy,
            drain_max: capacity,
            admitted: 0,
            rejected: 0,
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.decode.len() + self.other.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a request, applying validation and backpressure.
    pub fn submit(&mut self, req: AttentionRequest) -> Result<(), Rejected> {
        if let Err(e) = req.validate() {
            self.rejected += 1;
            return Err(Rejected::Invalid(e));
        }
        if self.len() >= self.capacity {
            self.rejected += 1;
            return Err(Rejected::QueueFull);
        }
        self.admitted += 1;
        self.seq += 1;
        if req.is_decode() {
            self.decode.push_back(req);
        } else {
            self.other.push_back(req);
        }
        Ok(())
    }

    /// Drain one dispatch cycle: up to [`Scheduler::drain_max`] requests
    /// in dispatch order. The coordinator lowers everything one call
    /// returns into a single fused kernel submission.
    pub fn drain_cycle(&mut self) -> Vec<AttentionRequest> {
        self.drain(self.drain_max)
    }

    /// Drain up to `max` requests in dispatch order.
    pub fn drain(&mut self, max: usize) -> Vec<AttentionRequest> {
        let mut out = Vec::new();
        match self.policy {
            Policy::DecodeFirst => {
                while out.len() < max {
                    if let Some(r) = self.decode.pop_front() {
                        out.push(r);
                    } else if let Some(r) = self.other.pop_front() {
                        out.push(r);
                    } else {
                        break;
                    }
                }
            }
            Policy::Fifo => {
                // merge by submission id (ids are client-assigned; use
                // arrival order within each queue and compare timestamps)
                while out.len() < max {
                    let take_decode = match (self.decode.front(), self.other.front()) {
                        (Some(d), Some(o)) => d.submitted_at <= o.submitted_at,
                        (Some(_), None) => true,
                        (None, Some(_)) => false,
                        (None, None) => break,
                    };
                    let r = if take_decode {
                        self.decode.pop_front().unwrap()
                    } else {
                        self.other.pop_front().unwrap()
                    };
                    out.push(r);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestKind, ShapeSig, Variant};
    use std::time::Instant;

    fn req(id: u64, decode: bool) -> AttentionRequest {
        AttentionRequest {
            id,
            kind: if decode { RequestKind::Decode { session: 1 } } else { RequestKind::Stateless },
            variant: Variant::FlashD,
            sig: ShapeSig { heads: 1, head_dim: 2 },
            q: vec![0.0; 2],
            nq: 1,
            k: vec![0.0; 2],
            v: vec![0.0; 2],
            nkv: 1,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn backpressure_when_full() {
        let mut s = Scheduler::new(2, Policy::Fifo);
        s.submit(req(1, true)).unwrap();
        s.submit(req(2, false)).unwrap();
        assert_eq!(s.submit(req(3, true)), Err(Rejected::QueueFull));
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
    }

    #[test]
    fn invalid_rejected_before_capacity() {
        let mut s = Scheduler::new(1, Policy::Fifo);
        let mut bad = req(1, true);
        bad.q.clear();
        assert!(matches!(s.submit(bad), Err(Rejected::Invalid(_))));
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn decode_first_ordering() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        s.submit(req(1, false)).unwrap();
        s.submit(req(2, true)).unwrap();
        s.submit(req(3, false)).unwrap();
        s.submit(req(4, true)).unwrap();
        let order: Vec<u64> = s.drain(10).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 4, 1, 3]);
        assert!(s.is_empty());
    }

    #[test]
    fn fifo_respects_arrival() {
        let mut s = Scheduler::new(10, Policy::Fifo);
        s.submit(req(1, false)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.submit(req(2, true)).unwrap();
        let order: Vec<u64> = s.drain(10).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2]);
    }

    #[test]
    fn drain_cycle_respects_sizing_knob() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        s.drain_max = 3;
        for i in 0..7 {
            s.submit(req(i, i % 2 == 0)).unwrap();
        }
        assert_eq!(s.drain_cycle().len(), 3);
        assert_eq!(s.drain_cycle().len(), 3);
        assert_eq!(s.drain_cycle().len(), 1);
        assert!(s.is_empty());
    }

    #[test]
    fn drain_partial() {
        let mut s = Scheduler::new(10, Policy::DecodeFirst);
        for i in 0..5 {
            s.submit(req(i, true)).unwrap();
        }
        assert_eq!(s.drain(2).len(), 2);
        assert_eq!(s.len(), 3);
    }
}
