//! Dynamic batching: groups decode requests that target the same session
//! (and therefore share K/V) into one parallel query block — the software
//! image of the paper's unrolled hardware, which serves "multiple preloaded
//! query vectors" against a single streamed K/V context.
//!
//! Stateless/prefill requests execute alone (their K/V is private), but
//! a stateless request's own `nq` query rows already fill the block.

use super::request::{AttentionRequest, ShapeSig, Variant};
use std::collections::HashMap;

/// Batch formation parameters.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Maximum decode queries fused into one block (bounded by the
    /// artifact's q_slots at dispatch time).
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32 }
    }
}

/// A formed batch: indices into the pending queue, all mergeable, plus the
/// block-lowering annotations the fused dispatcher reads — a batch lowers
/// to exactly `sig.heads` [`crate::kernels::batch::BlockJob`]s of
/// `total_q` query rows each, without re-inspecting the member requests.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    /// Session shared by all members (None = single stateless request).
    pub session: Option<u64>,
    pub members: Vec<usize>,
    /// Kernel variant shared by all members.
    pub variant: Variant,
    /// Shape signature shared by all members.
    pub sig: ShapeSig,
    /// Total query rows across members — the fused query-block height.
    pub total_q: usize,
    /// True for (mergeable) decode batches; false for the always-alone
    /// prefill/stateless batches.
    pub decode: bool,
}

/// Row span of each member inside its batch's fused query block: member
/// `m` owns rows `[spans[m].0, spans[m].0 + spans[m].1)` of every per-head
/// `BlockJob` the batch lowers to. `nqs` lists the members' query counts
/// in batch order. Shared by the fused gather/scatter and property tests.
pub fn member_row_spans(nqs: &[usize]) -> Vec<(usize, usize)> {
    let mut row = 0usize;
    nqs.iter()
        .map(|&nq| {
            let span = (row, nq);
            row += nq;
            span
        })
        .collect()
}

/// Partition `pending` into executable batches, preserving arrival order
/// within each batch.
///
/// Single pass over `pending` with a `(session, variant, sig) → open
/// batch` map: a decode joins its key's open batch until that batch is
/// full, at which point it opens (and registers) a fresh one. Batches
/// appear in first-member arrival order and fill earliest-first, exactly
/// as the previous greedy rescan did, but in O(n) over the drain width
/// instead of O(n²).
///
/// Invariants (checked by the property tests):
/// * every index appears in exactly one batch,
/// * a batch has at most `max_batch` members,
/// * all members of a multi-request batch are decode requests on the same
///   (session, variant, signature),
/// * non-decode requests are always alone.
pub fn form_batches(pending: &[AttentionRequest], policy: &BatchPolicy) -> Vec<Batch> {
    let mut batches: Vec<Batch> = Vec::new();
    let mut open: HashMap<(Option<u64>, Variant, ShapeSig), usize> = HashMap::new();
    for (i, r) in pending.iter().enumerate() {
        if !r.is_decode() {
            batches.push(Batch {
                session: r.session(),
                members: vec![i],
                variant: r.variant,
                sig: r.sig,
                total_q: r.nq,
                decode: false,
            });
            continue;
        }
        let key = (r.session(), r.variant, r.sig);
        if let Some(&bi) = open.get(&key) {
            let b = &mut batches[bi];
            if b.members.len() < policy.max_batch {
                b.members.push(i);
                b.total_q += r.nq;
                continue;
            }
        }
        let bi = batches.len();
        batches.push(Batch {
            session: r.session(),
            members: vec![i],
            variant: r.variant,
            sig: r.sig,
            total_q: r.nq,
            decode: true,
        });
        open.insert(key, bi);
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestKind, ShapeSig, Variant};
    use std::time::Instant;

    fn decode(id: u64, session: u64) -> AttentionRequest {
        let sig = ShapeSig { heads: 1, head_dim: 2 };
        AttentionRequest {
            id,
            kind: RequestKind::Decode { session },
            variant: Variant::FlashD,
            sig,
            q: vec![0.0; 2],
            nq: 1,
            k: vec![0.0; 2],
            v: vec![0.0; 2],
            nkv: 1,
            submitted_at: Instant::now(),
        }
    }

    fn stateless(id: u64) -> AttentionRequest {
        let mut r = decode(id, 0);
        r.kind = RequestKind::Stateless;
        r.nkv = 4;
        r.k = vec![0.0; 8];
        r.v = vec![0.0; 8];
        r
    }

    #[test]
    fn same_session_decodes_merge() {
        let pending = vec![decode(1, 7), decode(2, 7), decode(3, 7)];
        let batches = form_batches(&pending, &BatchPolicy::default());
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].members, vec![0, 1, 2]);
        assert_eq!(batches[0].session, Some(7));
    }

    #[test]
    fn different_sessions_split() {
        let pending = vec![decode(1, 7), decode(2, 8), decode(3, 7)];
        let batches = form_batches(&pending, &BatchPolicy::default());
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].members, vec![0, 2]);
        assert_eq!(batches[1].members, vec![1]);
    }

    #[test]
    fn stateless_never_merges() {
        let pending = vec![stateless(1), stateless(2), decode(3, 1), decode(4, 1)];
        let batches = form_batches(&pending, &BatchPolicy::default());
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].members, vec![0]);
        assert_eq!(batches[1].members, vec![1]);
        assert_eq!(batches[2].members, vec![2, 3]);
    }

    #[test]
    fn max_batch_respected() {
        let pending: Vec<_> = (0..10).map(|i| decode(i, 1)).collect();
        let batches = form_batches(&pending, &BatchPolicy { max_batch: 4 });
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].members.len(), 4);
        assert_eq!(batches[1].members.len(), 4);
        assert_eq!(batches[2].members.len(), 2);
    }

    /// Rollover after a full batch: later same-key decodes fill the
    /// newest open batch, never an earlier full one, and arrival order is
    /// preserved across the interleaved session.
    #[test]
    fn full_batch_rolls_over_preserving_arrival_order() {
        let mut pending = Vec::new();
        for i in 0..5u64 {
            pending.push(decode(i, 1));
        }
        pending.push(decode(5, 2));
        pending.push(decode(6, 1));
        let batches = form_batches(&pending, &BatchPolicy { max_batch: 3 });
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].members, vec![0, 1, 2]);
        assert_eq!(batches[1].members, vec![3, 4, 6]);
        assert_eq!(batches[1].session, Some(1));
        assert_eq!(batches[2].members, vec![5]);
        assert_eq!(batches[2].session, Some(2));
    }

    #[test]
    fn variant_mismatch_splits() {
        let mut a = decode(1, 5);
        let mut b = decode(2, 5);
        a.variant = Variant::FlashD;
        b.variant = Variant::Flash2;
        let batches = form_batches(&[a, b], &BatchPolicy::default());
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn empty_input() {
        assert!(form_batches(&[], &BatchPolicy::default()).is_empty());
    }

    #[test]
    fn lowering_annotations_filled() {
        let mut st = stateless(1);
        st.nq = 3;
        st.q = vec![0.0; 6];
        let pending = vec![st, decode(2, 7), decode(3, 7)];
        let batches = form_batches(&pending, &BatchPolicy::default());
        assert_eq!(batches.len(), 2);
        assert!(!batches[0].decode);
        assert_eq!(batches[0].total_q, 3);
        assert_eq!(batches[0].sig, ShapeSig { heads: 1, head_dim: 2 });
        assert_eq!(batches[0].variant, Variant::FlashD);
        assert!(batches[1].decode);
        assert_eq!(batches[1].total_q, 2);
        assert_eq!(batches[1].session, Some(7));
    }

    #[test]
    fn member_row_spans_partition_the_block() {
        assert_eq!(member_row_spans(&[]), Vec::<(usize, usize)>::new());
        assert_eq!(member_row_spans(&[4]), vec![(0, 4)]);
        assert_eq!(member_row_spans(&[1, 1, 1]), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(member_row_spans(&[2, 5, 1]), vec![(0, 2), (2, 5), (7, 1)]);
    }
}
