//! Request/response types for the attention service, including the
//! per-session [`AttnPolicy`] bound at session creation and the
//! streaming-response events yielded by
//! [`Coordinator::submit_stream`](super::Coordinator::submit_stream).

use crate::kernels::batch::KernelConfig;
use crate::kernels::flashd::{SigmoidMode, SkipCriterion};
use crate::numerics::quant::KvPrecision;
use std::time::Instant;

/// Which kernel variant serves the request (routing policy knob; the
/// paper's comparison pair).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    FlashD,
    Flash2,
}

impl Variant {
    pub fn artifact_str(self) -> &'static str {
        match self {
            Variant::FlashD => "flashd",
            Variant::Flash2 => "flash2",
        }
    }
}

/// Attention-problem shape signature used for routing and batching.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShapeSig {
    pub heads: usize,
    pub head_dim: usize,
}

impl ShapeSig {
    /// Flat f32 length of a `(heads, rows, head_dim)` tensor of this
    /// signature — the payload sizing shared by request validation and the
    /// fused gather/scatter plumbing.
    pub fn flat(&self, rows: usize) -> usize {
        self.heads * rows * self.head_dim
    }
}

/// The per-session attention policy — the single type that names every
/// per-session attention knob. A session binds its policy when it is
/// created: `Prefill`/`Fork` may carry an explicit override; otherwise a
/// fork inherits its source session's policy, and a fresh prefill gets
/// the coordinator-wide default derived from
/// [`KernelConfig`](crate::kernels::batch::KernelConfig). Resolution
/// order: request > source session (fork) > coordinator default.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct AttnPolicy {
    /// Sliding attention window in KV steps: queries attend only the most
    /// recent `window` live pairs and the paged store trims fully
    /// out-of-window leading blocks at block granularity. `None` attends
    /// the whole cache (unbounded).
    pub window: Option<usize>,
    /// KV storage precision. The coordinator's block pool is
    /// single-precision, so a policy whose precision differs from the
    /// pool's is rejected at session creation (typed error, not silent
    /// re-quantization).
    pub kv_precision: KvPrecision,
    /// Sigmoid evaluation mode the session's kernels run with.
    pub sigmoid: SigmoidMode,
    /// FLASH-D skip criterion the session's kernels run with.
    pub skip: SkipCriterion,
}

impl AttnPolicy {
    /// The coordinator-wide default policy for a kernel config: no window,
    /// the config's storage precision and execution knobs.
    pub fn from_kernel(cfg: &KernelConfig) -> AttnPolicy {
        AttnPolicy {
            window: None,
            kv_precision: cfg.kv_precision,
            sigmoid: cfg.sigmoid,
            skip: cfg.skip,
        }
    }

    /// This policy with a sliding window of `window` KV steps.
    pub fn with_window(self, window: usize) -> AttnPolicy {
        AttnPolicy { window: Some(window), ..self }
    }
}

/// How the request interacts with session state.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestKind {
    /// Stateless: the request carries its own K/V (prefill / offload style).
    Stateless,
    /// Create/extend a session cache with the carried K/V, then attend.
    /// `policy` overrides the coordinator-wide default attention policy
    /// for the (re)created session; `None` binds the default.
    Prefill { session: u64, policy: Option<AttnPolicy> },
    /// Decode step: append one K/V pair to the session, attend with the
    /// carried single query against the in-window cache.
    Decode { session: u64 },
    /// Fork session `src` into `session` (zero-copy prefix share in the
    /// paged store), append the carried divergent K/V, then attend.
    /// `policy` overrides the inherited source-session policy.
    Fork { src: u64, session: u64, policy: Option<AttnPolicy> },
}

impl RequestKind {
    /// A `Prefill` with the default (coordinator-wide) policy.
    pub fn prefill(session: u64) -> RequestKind {
        RequestKind::Prefill { session, policy: None }
    }

    /// A `Fork` inheriting the source session's policy.
    pub fn fork(src: u64, session: u64) -> RequestKind {
        RequestKind::Fork { src, session, policy: None }
    }
}

/// One attention request.
#[derive(Clone, Debug)]
pub struct AttentionRequest {
    pub id: u64,
    pub kind: RequestKind,
    pub variant: Variant,
    pub sig: ShapeSig,
    /// Queries, flat (heads, nq, head_dim).
    pub q: Vec<f32>,
    pub nq: usize,
    /// Keys/values, flat (heads, nkv, head_dim). For Decode, nkv == 1.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub nkv: usize,
    pub submitted_at: Instant,
}

impl AttentionRequest {
    pub fn validate(&self) -> Result<(), String> {
        if self.q.len() != self.sig.flat(self.nq) {
            return Err(format!("q len {} != H*nq*D {}", self.q.len(), self.sig.flat(self.nq)));
        }
        if self.k.len() != self.sig.flat(self.nkv) || self.v.len() != self.k.len() {
            return Err(format!(
                "k/v len {}/{} != H*nkv*D {}",
                self.k.len(),
                self.v.len(),
                self.sig.flat(self.nkv)
            ));
        }
        if self.nq == 0 {
            return Err("empty query".into());
        }
        match self.kind {
            RequestKind::Decode { .. } if self.nq != 1 || self.nkv != 1 => {
                Err("decode carries exactly one query and one kv pair".into())
            }
            RequestKind::Stateless if self.nkv == 0 => Err("stateless needs kv".into()),
            // a 0-length context would reach the kernels' n >= 1 assert on
            // the engine thread — reject it at admission instead
            RequestKind::Prefill { .. } if self.nkv == 0 => Err("prefill needs kv".into()),
            RequestKind::Fork { .. } if self.nkv == 0 => {
                Err("fork needs at least one divergent kv pair".into())
            }
            RequestKind::Fork { src, session, .. } if src == session => {
                Err("fork src == dst".into())
            }
            RequestKind::Prefill { policy: Some(p), .. } | RequestKind::Fork { policy: Some(p), .. }
                if p.window == Some(0) =>
            {
                Err("attention window must be >= 1 step".into())
            }
            _ => Ok(()),
        }
    }

    /// The session this request touches (for Fork: the one it mutates —
    /// the destination), if any.
    pub fn session(&self) -> Option<u64> {
        match self.kind {
            RequestKind::Stateless => None,
            RequestKind::Prefill { session, .. }
            | RequestKind::Decode { session }
            | RequestKind::Fork { session, .. } => Some(session),
        }
    }

    /// The attention-policy override carried by a session-creating request
    /// (`None` for decodes/stateless and for creation requests that bind
    /// the default).
    pub fn policy(&self) -> Option<AttnPolicy> {
        match self.kind {
            RequestKind::Prefill { policy, .. } | RequestKind::Fork { policy, .. } => policy,
            _ => None,
        }
    }

    pub fn is_decode(&self) -> bool {
        matches!(self.kind, RequestKind::Decode { .. })
    }
}

/// The served result.
#[derive(Clone, Debug)]
pub struct AttentionResponse {
    pub id: u64,
    /// Output rows, flat (heads, nq, head_dim) matching the request's q.
    pub output: Result<Vec<f32>, String>,
    /// Microseconds spent queued + executing.
    pub latency_us: u64,
    /// How many requests shared the executed batch.
    pub batch_size: usize,
}

/// One event on a stream's response channel. The worker serves a stream's
/// requests strictly in submission order, one in flight at a time, so
/// `Token` events arrive in the same order the requests were handed to
/// [`Coordinator::submit_stream`](super::Coordinator::submit_stream).
#[derive(Debug)]
pub enum StreamEvent {
    /// Per-cycle result for the stream's next request. An `Err` output
    /// aborts the stream; `Done` follows immediately.
    Token(AttentionResponse),
    /// Terminal event: no further events follow on this stream.
    Done {
        /// Microseconds from stream admission to its first token.
        ttft_us: u64,
        /// Microseconds from stream admission to its last token.
        total_us: u64,
        /// Tokens delivered (equals the request count unless aborted).
        tokens: u64,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(kind: RequestKind, nq: usize, nkv: usize) -> AttentionRequest {
        let sig = ShapeSig { heads: 2, head_dim: 4 };
        AttentionRequest {
            id: 1,
            kind,
            variant: Variant::FlashD,
            sig,
            q: vec![0.0; 2 * 4 * nq],
            nq,
            k: vec![0.0; 2 * 4 * nkv],
            v: vec![0.0; 2 * 4 * nkv],
            nkv,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn validates_shapes() {
        assert!(req(RequestKind::Stateless, 3, 8).validate().is_ok());
        let mut bad = req(RequestKind::Stateless, 3, 8);
        bad.q.pop();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn decode_must_be_single_step() {
        assert!(req(RequestKind::Decode { session: 9 }, 1, 1).validate().is_ok());
        assert!(req(RequestKind::Decode { session: 9 }, 2, 1).validate().is_err());
    }

    #[test]
    fn empty_context_rejected() {
        assert!(req(RequestKind::Stateless, 1, 0).validate().is_err());
        assert!(req(RequestKind::prefill(2), 1, 0).validate().is_err());
    }

    #[test]
    fn session_extraction() {
        assert_eq!(req(RequestKind::Stateless, 1, 1).session(), None);
        assert_eq!(req(RequestKind::prefill(5), 1, 1).session(), Some(5));
        assert_eq!(req(RequestKind::Decode { session: 7 }, 1, 1).session(), Some(7));
        assert_eq!(req(RequestKind::fork(5, 6), 1, 1).session(), Some(6));
    }

    #[test]
    fn fork_needs_divergence_and_distinct_ids() {
        assert!(req(RequestKind::fork(1, 2), 1, 3).validate().is_ok());
        assert!(req(RequestKind::fork(1, 2), 1, 0).validate().is_err());
        assert!(req(RequestKind::fork(2, 2), 1, 1).validate().is_err());
        assert!(!req(RequestKind::fork(1, 2), 1, 1).is_decode());
    }

    #[test]
    fn policy_carried_only_by_session_creators() {
        let default = AttnPolicy::from_kernel(&KernelConfig::default());
        assert_eq!(default.window, None);
        let windowed = default.with_window(64);
        assert_eq!(windowed.window, Some(64));

        let kind = RequestKind::Prefill { session: 1, policy: Some(windowed) };
        assert_eq!(req(kind, 1, 4).policy(), Some(windowed));
        let kind = RequestKind::Fork { src: 1, session: 2, policy: Some(windowed) };
        assert_eq!(req(kind, 1, 1).policy(), Some(windowed));
        assert_eq!(req(RequestKind::prefill(1), 1, 4).policy(), None);
        assert_eq!(req(RequestKind::Decode { session: 1 }, 1, 1).policy(), None);
    }

    #[test]
    fn zero_window_rejected() {
        let zero = AttnPolicy::from_kernel(&KernelConfig::default()).with_window(0);
        let kind = RequestKind::Prefill { session: 1, policy: Some(zero) };
        assert!(req(kind, 1, 4).validate().is_err());
        let kind = RequestKind::Fork { src: 1, session: 2, policy: Some(zero) };
        assert!(req(kind, 1, 1).validate().is_err());
        let one = AttnPolicy::from_kernel(&KernelConfig::default()).with_window(1);
        let kind = RequestKind::Prefill { session: 1, policy: Some(one) };
        assert!(req(kind, 1, 4).validate().is_ok());
    }
}
