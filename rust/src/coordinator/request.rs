//! Request/response types for the attention service, including the
//! streaming-response events yielded by
//! [`Coordinator::submit_stream`](super::Coordinator::submit_stream).

use std::time::Instant;

/// Which kernel variant serves the request (routing policy knob; the
/// paper's comparison pair).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    FlashD,
    Flash2,
}

impl Variant {
    pub fn artifact_str(self) -> &'static str {
        match self {
            Variant::FlashD => "flashd",
            Variant::Flash2 => "flash2",
        }
    }
}

/// Attention-problem shape signature used for routing and batching.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShapeSig {
    pub heads: usize,
    pub head_dim: usize,
}

impl ShapeSig {
    /// Flat f32 length of a `(heads, rows, head_dim)` tensor of this
    /// signature — the payload sizing shared by request validation and the
    /// fused gather/scatter plumbing.
    pub fn flat(&self, rows: usize) -> usize {
        self.heads * rows * self.head_dim
    }
}

/// How the request interacts with session state.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestKind {
    /// Stateless: the request carries its own K/V (prefill / offload style).
    Stateless,
    /// Create/extend a session cache with the carried K/V, then attend.
    Prefill { session: u64 },
    /// Decode step: append one K/V pair to the session, attend with the
    /// carried single query against the whole cache.
    Decode { session: u64 },
    /// Fork session `src` into `session` (zero-copy prefix share in the
    /// paged store), append the carried divergent K/V, then attend.
    Fork { src: u64, session: u64 },
}

/// One attention request.
#[derive(Clone, Debug)]
pub struct AttentionRequest {
    pub id: u64,
    pub kind: RequestKind,
    pub variant: Variant,
    pub sig: ShapeSig,
    /// Queries, flat (heads, nq, head_dim).
    pub q: Vec<f32>,
    pub nq: usize,
    /// Keys/values, flat (heads, nkv, head_dim). For Decode, nkv == 1.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub nkv: usize,
    pub submitted_at: Instant,
}

impl AttentionRequest {
    pub fn validate(&self) -> Result<(), String> {
        if self.q.len() != self.sig.flat(self.nq) {
            return Err(format!("q len {} != H*nq*D {}", self.q.len(), self.sig.flat(self.nq)));
        }
        if self.k.len() != self.sig.flat(self.nkv) || self.v.len() != self.k.len() {
            return Err(format!(
                "k/v len {}/{} != H*nkv*D {}",
                self.k.len(),
                self.v.len(),
                self.sig.flat(self.nkv)
            ));
        }
        if self.nq == 0 {
            return Err("empty query".into());
        }
        match self.kind {
            RequestKind::Decode { .. } if self.nq != 1 || self.nkv != 1 => {
                Err("decode carries exactly one query and one kv pair".into())
            }
            RequestKind::Stateless if self.nkv == 0 => Err("stateless needs kv".into()),
            // a 0-length context would reach the kernels' n >= 1 assert on
            // the engine thread — reject it at admission instead
            RequestKind::Prefill { .. } if self.nkv == 0 => Err("prefill needs kv".into()),
            RequestKind::Fork { .. } if self.nkv == 0 => {
                Err("fork needs at least one divergent kv pair".into())
            }
            RequestKind::Fork { src, session } if src == session => {
                Err("fork src == dst".into())
            }
            _ => Ok(()),
        }
    }

    /// The session this request touches (for Fork: the one it mutates —
    /// the destination), if any.
    pub fn session(&self) -> Option<u64> {
        match self.kind {
            RequestKind::Stateless => None,
            RequestKind::Prefill { session }
            | RequestKind::Decode { session }
            | RequestKind::Fork { session, .. } => Some(session),
        }
    }

    pub fn is_decode(&self) -> bool {
        matches!(self.kind, RequestKind::Decode { .. })
    }
}

/// The served result.
#[derive(Clone, Debug)]
pub struct AttentionResponse {
    pub id: u64,
    /// Output rows, flat (heads, nq, head_dim) matching the request's q.
    pub output: Result<Vec<f32>, String>,
    /// Microseconds spent queued + executing.
    pub latency_us: u64,
    /// How many requests shared the executed batch.
    pub batch_size: usize,
}

/// One event on a stream's response channel. The worker serves a stream's
/// requests strictly in submission order, one in flight at a time, so
/// `Token` events arrive in the same order the requests were handed to
/// [`Coordinator::submit_stream`](super::Coordinator::submit_stream).
#[derive(Debug)]
pub enum StreamEvent {
    /// Per-cycle result for the stream's next request. An `Err` output
    /// aborts the stream; `Done` follows immediately.
    Token(AttentionResponse),
    /// Terminal event: no further events follow on this stream.
    Done {
        /// Microseconds from stream admission to its first token.
        ttft_us: u64,
        /// Microseconds from stream admission to its last token.
        total_us: u64,
        /// Tokens delivered (equals the request count unless aborted).
        tokens: u64,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(kind: RequestKind, nq: usize, nkv: usize) -> AttentionRequest {
        let sig = ShapeSig { heads: 2, head_dim: 4 };
        AttentionRequest {
            id: 1,
            kind,
            variant: Variant::FlashD,
            sig,
            q: vec![0.0; 2 * 4 * nq],
            nq,
            k: vec![0.0; 2 * 4 * nkv],
            v: vec![0.0; 2 * 4 * nkv],
            nkv,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn validates_shapes() {
        assert!(req(RequestKind::Stateless, 3, 8).validate().is_ok());
        let mut bad = req(RequestKind::Stateless, 3, 8);
        bad.q.pop();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn decode_must_be_single_step() {
        assert!(req(RequestKind::Decode { session: 9 }, 1, 1).validate().is_ok());
        assert!(req(RequestKind::Decode { session: 9 }, 2, 1).validate().is_err());
    }

    #[test]
    fn empty_context_rejected() {
        assert!(req(RequestKind::Stateless, 1, 0).validate().is_err());
        assert!(req(RequestKind::Prefill { session: 2 }, 1, 0).validate().is_err());
    }

    #[test]
    fn session_extraction() {
        assert_eq!(req(RequestKind::Stateless, 1, 1).session(), None);
        assert_eq!(req(RequestKind::Prefill { session: 5 }, 1, 1).session(), Some(5));
        assert_eq!(req(RequestKind::Decode { session: 7 }, 1, 1).session(), Some(7));
        assert_eq!(req(RequestKind::Fork { src: 5, session: 6 }, 1, 1).session(), Some(6));
    }

    #[test]
    fn fork_needs_divergence_and_distinct_ids() {
        assert!(req(RequestKind::Fork { src: 1, session: 2 }, 1, 3).validate().is_ok());
        assert!(req(RequestKind::Fork { src: 1, session: 2 }, 1, 0).validate().is_err());
        assert!(req(RequestKind::Fork { src: 2, session: 2 }, 1, 1).validate().is_err());
        assert!(!req(RequestKind::Fork { src: 1, session: 2 }, 1, 1).is_decode());
    }
}
