//! The engine thread: owns the (deliberately single-threaded) PJRT
//! runtime, admits requests from the channel through the scheduler +
//! batcher, manages session KV state, executes attention blocks, and
//! responds.
//!
//! `AttnEngine` abstracts the executor so the entire coordination logic is
//! testable against a pure-Rust engine ([`NaiveEngine`]) without compiled
//! artifacts; production uses [`PjrtEngine`] over the AOT artifacts.
//!
//! # Continuous batching
//!
//! Admission is decoupled from execution (see
//! [`worker`](super::worker)): the [`Coordinator`] handle is the
//! front-end that enqueues requests onto the engine thread's channel, and
//! the persistent batching worker admits arrivals *into the running
//! batch* between kernel submissions — one budgeted cycle at a time —
//! instead of draining the whole backlog before looking at the channel
//! again. A long prefill therefore occupies exactly one cycle, and
//! decodes arriving behind it are served in the next cycle rather than
//! waiting for the backlog to empty. Streaming clients use
//! [`Coordinator::submit_stream`] to get per-cycle
//! [`StreamEvent`](super::request::StreamEvent)s instead of one blocking
//! reply.
//!
//! # Fused cross-session dispatch
//!
//! On engines that support it (the kernel-backed [`NaiveEngine`]), one
//! drain cycle is ONE kernel submission, not a loop over batches. The
//! drain-cycle → block-job lowering contract:
//!
//! 1. The scheduler drains up to [`CoordinatorConfig::drain_cycle`]
//!    requests; the batcher partitions them into annotated [`Batch`]es
//!    (decode fusions, prefills, stateless), in dispatch order.
//! 2. Each batch is *admitted* in order: its session mutations (prefill
//!    create, decode appends) are applied and its routing is validated.
//!    Admission failures answer the batch's members immediately; partial
//!    mutations are kept, exactly as in serial dispatch.
//! 3. Admitted batches accumulate into a *fusion group*. A batch that
//!    conflicts with the group — it touches a session the group already
//!    reads (for a fork: either endpoint), or its appends could LRU-evict
//!    blocks while the group still borrows block tables — flushes the
//!    group first, so fused results are bit-identical to serial dispatch.
//! 4. A flush lowers every batch in the group to one [`PagedKvBlockJob`]
//!    per head over its `(total_q, kv_len)` problem — query rows borrowed
//!    from the requests (gathered into a contiguous block only for
//!    multi-member decode fusions), K/V borrowed in place from the paged
//!    session store with no copies or padding: each session's block table
//!    is gathered once into per-head fragment lists, and the kernels
//!    stream tiles through the gather-aware [`KvView`] (quantized blocks
//!    are dequantized tile-by-tile inside the kernel workers) — and
//!    submits the whole job list through a single
//!    [`AttnEngine::execute_fused`] call on the batched driver's thread
//!    pool.
//! 5. The flat output is scattered back into per-member `(heads, nq,
//!    head_dim)` responses by member row span.
//!
//! Because the query-blocked kernel is bit-identical per query to the
//! per-request tiled kernel, the fused path returns bit-identical outputs
//! to per-request reference execution — the differential conformance
//! suite (`tests/conformance_serving.rs`) asserts exactly that.

use super::batcher::{member_row_spans, Batch, BatchPolicy};
use super::kv_cache::{PagedSessionKv, SessionStore};
use super::metrics::Metrics;
use super::request::{AttentionRequest, AttentionResponse, AttnPolicy, RequestKind, ShapeSig, StreamEvent};
use super::router::{Route, Router};
use super::scheduler::Policy;
use super::worker::{engine_loop, Msg};
use crate::kernels::batch::{
    run_blocks_into_with, run_paged_kv_blocks_flat_into_with, BatchScratch, BlockJob,
    KernelConfig, PagedKvBlockJob,
};
use crate::kernels::flashd::SkipStats;
use crate::numerics::quant::{KvRef, KvView};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::collections::HashSet;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Executes one routed attention block.
/// Inputs are flat (heads, slots, head_dim); `kv_len` marks the valid
/// prefix of the K/V tensors.
pub trait AttnEngine {
    fn execute(&self, route: &Route, q: &[f32], k: &[f32], v: &[f32], kv_len: usize) -> Result<Vec<f32>>;
    /// The router snapshot this engine can serve.
    fn router(&self) -> Router;

    /// Whether [`AttnEngine::execute_fused`] is available. Engines over
    /// fixed-shape compiled artifacts (PJRT) cannot execute arbitrary job
    /// lists and keep the per-batch serial path.
    fn supports_fused(&self) -> bool {
        false
    }

    /// Fused dispatch: execute a whole drain cycle's lowered block jobs
    /// as ONE kernel submission. `out` is the flat concatenation of job
    /// outputs (job `i` owns the next `nq_i * d_i` floats). K/V arrive as
    /// [`KvView`]s borrowed straight from the paged session store —
    /// per-head block-fragment lists the kernels stream tiles across
    /// (contiguous `F32` payloads still take the zero-copy bit-exact
    /// path), in whatever storage precision the store holds. Only called
    /// when [`AttnEngine::supports_fused`] returns true.
    fn execute_fused(&self, jobs: &[PagedKvBlockJob<'_>], out: &mut [f32]) -> Result<SkipStats> {
        let _ = (jobs, out);
        Err(anyhow!("engine does not support fused dispatch"))
    }
}

/// Production engine: compiled AOT artifacts via PJRT.
pub struct PjrtEngine {
    pub rt: Runtime,
}

impl PjrtEngine {
    pub fn open(dir: &std::path::Path) -> Result<PjrtEngine> {
        Ok(PjrtEngine { rt: Runtime::open(dir)? })
    }
}

impl AttnEngine for PjrtEngine {
    fn execute(&self, route: &Route, q: &[f32], k: &[f32], v: &[f32], kv_len: usize) -> Result<Vec<f32>> {
        let shape = [route.heads, route.q_slots, route.head_dim];
        let kshape = [route.heads, route.kv_slots, route.head_dim];
        let inputs = [
            lit_f32(q, &shape)?,
            lit_f32(k, &kshape)?,
            lit_f32(v, &kshape)?,
            lit_i32(&[kv_len as i32], &[1, 1])?,
        ];
        let out = self.rt.execute(&route.artifact, &inputs)?;
        to_vec_f32(&out[0])
    }

    fn router(&self) -> Router {
        Router::from_manifest(&self.rt.manifest)
    }
}

/// Test/bench engine: the query-blocked FLASH-D kernel driven through the
/// batched multi-thread driver (no PJRT). Serves the same shapes as the
/// given router and applies the artifacts' 1/sqrt(d) scale.
pub struct NaiveEngine {
    pub router: Router,
    /// Tile/block/thread/skip knobs for the kernel path (serving defaults
    /// to the exact kernel: `SkipCriterion::None`).
    pub kernel: KernelConfig,
    /// Reusable kernel scratch. The engine lives on one engine thread and
    /// `execute` takes `&self`, so interior mutability is enough; the
    /// kernel's score/state buffers are reused across batches (per batch
    /// only the output buffer and the small block/item plans allocate).
    scratch: RefCell<BatchScratch>,
}

impl NaiveEngine {
    pub fn new(router: Router) -> NaiveEngine {
        NaiveEngine::with_kernel(router, KernelConfig::default())
    }

    pub fn with_kernel(router: Router, kernel: KernelConfig) -> NaiveEngine {
        NaiveEngine { router, kernel, scratch: RefCell::new(BatchScratch::new()) }
    }
}

impl AttnEngine for NaiveEngine {
    fn execute(&self, route: &Route, q: &[f32], k: &[f32], v: &[f32], kv_len: usize) -> Result<Vec<f32>> {
        let (h, lq, lkv, d) = (route.heads, route.q_slots, route.kv_slots, route.head_dim);
        let scale = (d as f32).powf(-0.5);
        // One query block per head: all lq rows of a head share its KV
        // prefix, so the query-blocked kernel streams each KV tile once
        // per block instead of once per row. The driver splits blocks
        // across worker threads with deterministic output ordering.
        let mut blocks = Vec::with_capacity(h);
        for hh in 0..h {
            let koff = hh * lkv * d;
            blocks.push(BlockJob {
                q: &q[hh * lq * d..(hh + 1) * lq * d],
                k: &k[koff..koff + kv_len * d],
                v: &v[koff..koff + kv_len * d],
                nq: lq,
                n: kv_len,
                d,
                scale,
                causal: false,
            });
        }
        // blocks are in (head, query) order, so the flat driver writes the
        // response layout directly
        let mut out = vec![0.0f32; h * lq * d];
        run_blocks_into_with(&self.kernel, &blocks, d, &mut out, &mut self.scratch.borrow_mut());
        Ok(out)
    }

    fn router(&self) -> Router {
        self.router.clone()
    }

    fn supports_fused(&self) -> bool {
        true
    }

    fn execute_fused(&self, jobs: &[PagedKvBlockJob<'_>], out: &mut [f32]) -> Result<SkipStats> {
        Ok(run_paged_kv_blocks_flat_into_with(&self.kernel, jobs, out, &mut self.scratch.borrow_mut()))
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: Policy,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Session KV budget in bytes.
    pub kv_budget_bytes: usize,
    /// How long the engine waits for more arrivals before dispatching a
    /// non-full batch.
    pub batch_window: Duration,
    /// Tile/thread/skip/sigmoid/KV-precision knobs for the software kernel
    /// path (honored by [`NaiveEngine`]-backed coordinators via
    /// [`Coordinator::start_naive`]; the PJRT path executes whole compiled
    /// blocks and ignores all but `kv_precision`, which still selects the
    /// session cache storage format — quantized caches are dequantized
    /// into the padded block tensors at pack time).
    pub kernel: KernelConfig,
    /// Coordinator-wide default sliding attention window in KV steps,
    /// bound by sessions whose creating request carries no explicit
    /// [`AttnPolicy`] (see [`CoordinatorConfig::default_policy`]). `None`
    /// — the default — attends the whole cache. Request-level policies
    /// override this per session and may use any window `>= 1`; the
    /// validating builder additionally requires *this* coordinator-wide
    /// value to be block-aligned so steady-state trims reclaim whole
    /// blocks with zero slop.
    pub window: Option<usize>,
    /// Fused cross-session dispatch: lower a whole drain cycle into one
    /// kernel submission when the engine supports it. `false` restores
    /// per-batch serial dispatch (bit-identical outputs, more
    /// submissions) — the conformance suite runs both.
    pub fused: bool,
    /// Drain-cycle sizing knob: how many requests one dispatch cycle may
    /// pull from the scheduler, bounding the width of a fused submission.
    pub drain_cycle: usize,
    /// Token-budget admission control: the total live KV tokens one cycle
    /// may stream (each request costs the context length its query rows
    /// attend — `nkv` for prefill/stateless, session length + 1 for
    /// decode). A cycle always admits at least one request, so an
    /// over-budget problem still serves alone; the budget bounds how much
    /// *additional* work can ride along, which is what keeps one long
    /// prefill from dragging a cycle's decodes with it.
    pub max_batch_total_tokens: usize,
    /// Anti-starvation knob for `Policy::DecodeFirst` (the
    /// `waiting_served_ratio` analogue): once the oldest queued
    /// prefill/stateless request has waited this many admission cycles
    /// behind the decode stream, it is promoted to the front of the next
    /// cycle.
    pub prefill_max_wait_cycles: u32,
    /// Backpressure for [`Coordinator::submit_stream`]: streams active
    /// (one request in flight each) at any time. Further streams park in
    /// FIFO order until a slot frees.
    pub max_concurrent_streams: usize,
    /// Run the paged KV store's full refcount/byte-accounting invariant
    /// check after every drain cycle, panicking the engine thread on a
    /// violation. Debug/stress-test knob — O(sessions + blocks) per
    /// cycle, off by default.
    pub validate_invariants: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            policy: Policy::DecodeFirst,
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            kv_budget_bytes: 256 << 20,
            batch_window: Duration::from_micros(200),
            kernel: KernelConfig::default(),
            window: None,
            fused: true,
            drain_cycle: 256,
            max_batch_total_tokens: 32 * 1024,
            prefill_max_wait_cycles: 4,
            max_concurrent_streams: 64,
            validate_invariants: false,
        }
    }
}

impl CoordinatorConfig {
    /// Start a validating builder over the default configuration — the
    /// typed-error alternative to struct-update syntax for knobs whose
    /// bad values previously surfaced as silent clamps (`drain_cycle: 0`
    /// ran as 1) or engine-thread failures (a KV budget below one block
    /// rejects every append).
    pub fn builder() -> CoordinatorConfigBuilder {
        CoordinatorConfigBuilder { cfg: CoordinatorConfig::default() }
    }

    /// The coordinator-wide default [`AttnPolicy`]: the kernel config's
    /// execution/storage knobs plus the config-level default `window`.
    /// Sessions whose creating request carries no policy bind this one;
    /// resolution order is request > source session (fork) > this.
    pub fn default_policy(&self) -> AttnPolicy {
        AttnPolicy { window: self.window, ..AttnPolicy::from_kernel(&self.kernel) }
    }
}

/// Typed rejection from [`CoordinatorConfigBuilder::build`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `drain_cycle == 0`: a cycle that can admit nothing serves nothing.
    ZeroDrainCycle,
    /// `queue_capacity == 0`: every request would bounce at the door.
    ZeroQueueCapacity,
    /// KV budget below even one minimal pool block (1 head, head_dim 1),
    /// so no session could ever append.
    KvBudgetBelowOneBlock { budget: usize, min_block_bytes: usize },
    /// Coordinator-wide default window of zero or not a multiple of the
    /// pool block size. The store itself serves any window `>= 1`
    /// (sub-block slop is hidden behind the gathered view's element
    /// offset), but the coordinator-wide default must be block-aligned so
    /// steady-state trims reclaim whole blocks exactly.
    WindowNotBlockAligned { window: usize, block_steps: usize },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroDrainCycle => write!(f, "drain_cycle must be >= 1"),
            ConfigError::ZeroQueueCapacity => write!(f, "queue_capacity must be >= 1"),
            ConfigError::KvBudgetBelowOneBlock { budget, min_block_bytes } => write!(
                f,
                "kv_budget_bytes {budget} below one pool block ({min_block_bytes} bytes minimum)"
            ),
            ConfigError::WindowNotBlockAligned { window, block_steps } => write!(
                f,
                "default window {window} must be a nonzero multiple of block_steps {block_steps}"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Validating builder for [`CoordinatorConfig`], started via
/// [`CoordinatorConfig::builder`]. Unset knobs keep their
/// [`Default`] values; [`CoordinatorConfigBuilder::build`] returns the
/// config or the first [`ConfigError`] it finds.
#[derive(Clone, Debug)]
pub struct CoordinatorConfigBuilder {
    cfg: CoordinatorConfig,
}

impl CoordinatorConfigBuilder {
    pub fn artifact_dir(mut self, dir: std::path::PathBuf) -> Self {
        self.cfg.artifact_dir = dir;
        self
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.cfg.queue_capacity = capacity;
        self
    }

    pub fn batch(mut self, batch: BatchPolicy) -> Self {
        self.cfg.batch = batch;
        self
    }

    pub fn kv_budget_bytes(mut self, bytes: usize) -> Self {
        self.cfg.kv_budget_bytes = bytes;
        self
    }

    pub fn batch_window(mut self, window: Duration) -> Self {
        self.cfg.batch_window = window;
        self
    }

    pub fn kernel(mut self, kernel: KernelConfig) -> Self {
        self.cfg.kernel = kernel;
        self
    }

    /// Coordinator-wide default attention window (see
    /// [`CoordinatorConfig::window`]).
    pub fn window(mut self, window: Option<usize>) -> Self {
        self.cfg.window = window;
        self
    }

    pub fn fused(mut self, fused: bool) -> Self {
        self.cfg.fused = fused;
        self
    }

    pub fn drain_cycle(mut self, drain_cycle: usize) -> Self {
        self.cfg.drain_cycle = drain_cycle;
        self
    }

    pub fn max_batch_total_tokens(mut self, tokens: usize) -> Self {
        self.cfg.max_batch_total_tokens = tokens;
        self
    }

    pub fn prefill_max_wait_cycles(mut self, cycles: u32) -> Self {
        self.cfg.prefill_max_wait_cycles = cycles;
        self
    }

    pub fn max_concurrent_streams(mut self, streams: usize) -> Self {
        self.cfg.max_concurrent_streams = streams;
        self
    }

    pub fn validate_invariants(mut self, on: bool) -> Self {
        self.cfg.validate_invariants = on;
        self
    }

    pub fn build(self) -> Result<CoordinatorConfig, ConfigError> {
        let cfg = self.cfg;
        if cfg.drain_cycle == 0 {
            return Err(ConfigError::ZeroDrainCycle);
        }
        if cfg.queue_capacity == 0 {
            return Err(ConfigError::ZeroQueueCapacity);
        }
        // Block geometry depends on per-session heads/head_dim, unknown
        // here; one block of the smallest servable geometry (1 head,
        // head_dim 1) is the hard floor below which nothing ever fits.
        let block_steps = cfg.kernel.tile.max(1);
        let min_block_bytes = 2 * block_steps * cfg.kernel.kv_precision.bytes_per_elem();
        if cfg.kv_budget_bytes < min_block_bytes {
            return Err(ConfigError::KvBudgetBelowOneBlock { budget: cfg.kv_budget_bytes, min_block_bytes });
        }
        if let Some(w) = cfg.window {
            if w == 0 || w % block_steps != 0 {
                return Err(ConfigError::WindowNotBlockAligned { window: w, block_steps });
            }
        }
        Ok(cfg)
    }
}

/// Client handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    pub metrics: Arc<Metrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start with the production PJRT engine.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let dir = cfg.artifact_dir.clone();
        Coordinator::start_with(cfg, move || {
            PjrtEngine::open(&dir).map_err(|e| anyhow!("engine startup: {e}"))
        })
    }

    /// Start with the pure-Rust tiled kernel engine over the given router,
    /// honoring `cfg.kernel` — the no-PJRT serving path.
    pub fn start_naive(cfg: CoordinatorConfig, router: Router) -> Result<Coordinator> {
        let kernel = cfg.kernel;
        Coordinator::start_with(cfg, move || Ok(NaiveEngine::with_kernel(router, kernel)))
    }

    /// Start with an arbitrary engine factory (constructed *inside* the
    /// engine thread — PJRT handles are not Send).
    pub fn start_with<E, F>(cfg: CoordinatorConfig, factory: F) -> Result<Coordinator>
    where
        E: AttnEngine,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("flashd-engine".into())
            .spawn(move || {
                let engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(engine, rx, cfg, m2);
            })
            .expect("spawn engine thread");
        ready_rx.recv().map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator { tx, metrics, handle: Some(handle) })
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, req: AttentionRequest) -> Receiver<AttentionResponse> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        // engine gone => receiver errors out, surfaced to caller on recv
        let _ = self.tx.send(Msg::Request(req, tx));
        rx
    }

    /// Submit a whole request lifecycle (e.g. prefill + decode steps) as
    /// one stream: the worker keeps exactly one of the stream's requests
    /// in flight at a time, in order, and yields each result as a
    /// [`StreamEvent::Token`] as soon as its cycle completes — the
    /// streaming analogue of calling [`Coordinator::submit_blocking`] in
    /// a loop, without occupying a client thread per step. Request ids
    /// must be unique across in-flight requests. Streams beyond
    /// [`CoordinatorConfig::max_concurrent_streams`] park in FIFO order
    /// until a slot frees.
    pub fn submit_stream(&self, reqs: Vec<AttentionRequest>) -> StreamHandle {
        self.metrics.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let (tx, rx) = channel();
        // engine gone => receiver errors out, surfaced as recv() -> None
        let _ = self.tx.send(Msg::Stream(reqs, tx));
        StreamHandle { rx }
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, req: AttentionRequest) -> AttentionResponse {
        let id = req.id;
        match self.submit(req).recv() {
            Ok(r) => r,
            Err(_) => AttentionResponse {
                id,
                output: Err("engine unavailable".into()),
                latency_us: 0,
                batch_size: 0,
            },
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Receiver half of a stream opened by [`Coordinator::submit_stream`].
pub struct StreamHandle {
    rx: Receiver<StreamEvent>,
}

impl StreamHandle {
    /// Block for the next event; `None` once the stream's sender is gone
    /// (after `Done`, or if the engine died).
    pub fn recv(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll for the next event.
    pub fn try_recv(&self) -> Option<StreamEvent> {
        self.rx.try_recv().ok()
    }

    /// Block up to `timeout` for the next event; `None` on timeout or
    /// once the stream's sender is gone. Deadline-driven clients (the
    /// load harness's abandonment scenario) use this to walk away from a
    /// stream mid-generation — dropping the handle afterwards is what the
    /// worker observes as client-gone.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<StreamEvent> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Drain the stream to completion: all token responses in submission
    /// order, plus the `Done` summary when the stream terminated cleanly.
    pub fn collect_blocking(self) -> (Vec<AttentionResponse>, Option<StreamEvent>) {
        let mut tokens = Vec::new();
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Token(resp)) => tokens.push(resp),
                Ok(done @ StreamEvent::Done { .. }) => return (tokens, Some(done)),
                Err(_) => return (tokens, None),
            }
        }
    }
}

pub(crate) struct Pending {
    pub(crate) req: AttentionRequest,
    pub(crate) reply: Sender<AttentionResponse>,
}

/// Publish the paged store's pool gauges and sharing counters into the
/// metrics sink (store-latest: the engine thread owns the store, so each
/// drain cycle's value is the current truth).
pub(crate) fn publish_kv_metrics(sessions: &SessionStore, metrics: &Arc<Metrics>) {
    let pool = sessions.pool();
    metrics.kv_pool_bytes.store(pool.bytes as u64, Ordering::Relaxed);
    metrics.kv_pool_peak_bytes.store(pool.peak_bytes as u64, Ordering::Relaxed);
    metrics.kv_pool_blocks.store(pool.live_blocks() as u64, Ordering::Relaxed);
    metrics.kv_block_evictions.store(sessions.block_evictions, Ordering::Relaxed);
    metrics.kv_prefix_share_hits.store(sessions.prefix_share_hits, Ordering::Relaxed);
    metrics.kv_cow_copies.store(sessions.cow_copies, Ordering::Relaxed);
    metrics.kv_window_trims.store(sessions.window_trims, Ordering::Relaxed);
    metrics.kv_blocks_trimmed.store(sessions.blocks_trimmed, Ordering::Relaxed);
}

/// How a prepared batch's K/V is sourced at lowering time.
enum KvSrc {
    /// Borrow the session cache (decode/prefill).
    Session(u64),
    /// Borrow the first member's request payload (stateless).
    Inline,
}

/// A batch that survived phase A of dispatch (session mutations + routing
/// validation) and is ready to execute — serially or lowered into a fused
/// submission.
struct Ready {
    members: Vec<Pending>,
    sig: ShapeSig,
    route: Route,
    kv: KvSrc,
    /// *Attended* KV length captured at admission — `min(live, window)`
    /// for a windowed session, the full live length otherwise. The
    /// fusion-group conflict rule guarantees it cannot change before the
    /// group flushes.
    kv_len: usize,
    /// Total query rows across members — the fused query-block height.
    total_q: usize,
    /// Reported batch size (the formed batch's member count).
    batch_size: usize,
}

fn respond_error(members: Vec<Pending>, msg: &str, batch_size: usize, metrics: &Arc<Metrics>) {
    for m in members {
        metrics.errors.fetch_add(1, Ordering::Relaxed);
        let _ = m.reply.send(AttentionResponse {
            id: m.req.id,
            output: Err(msg.to_string()),
            latency_us: m.req.submitted_at.elapsed().as_micros() as u64,
            batch_size,
        });
    }
}

fn respond_ok(m: Pending, out: Vec<f32>, batch_size: usize, metrics: &Arc<Metrics>) {
    let latency_us = m.req.submitted_at.elapsed().as_micros() as u64;
    metrics.responses.fetch_add(1, Ordering::Relaxed);
    metrics.observe_latency(latency_us);
    let _ = m.reply.send(AttentionResponse { id: m.req.id, output: Ok(out), latency_us, batch_size });
}

/// Phase A of dispatch: claim the batch's members, apply its session
/// mutations in arrival order, capture the KV geometry, and validate
/// routing. Admission failures answer the members immediately and return
/// `None`; mutations applied before the failure are kept, exactly as in
/// serial dispatch.
fn admit_batch(
    router: &Router,
    sessions: &mut SessionStore,
    batch: &Batch,
    pend: &mut [Option<Pending>],
    default: &AttnPolicy,
    metrics: &Arc<Metrics>,
) -> Option<Ready> {
    let members: Vec<Pending> = batch.members.iter().filter_map(|&i| pend[i].take()).collect();
    if members.is_empty() {
        return None;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_requests.fetch_add(members.len() as u64, Ordering::Relaxed);
    match prepare_batch(router, sessions, &members, default, metrics) {
        Ok((route, kv, kv_len)) => {
            let total_q = members.iter().map(|m| m.req.nq).sum();
            Some(Ready {
                sig: members[0].req.sig,
                route,
                kv,
                kv_len,
                total_q,
                batch_size: batch.members.len(),
                members,
            })
        }
        Err(e) => {
            respond_error(members, &format!("{e}"), batch.members.len(), metrics);
            None
        }
    }
}

/// Resolve a session-creating request's attention policy against the
/// store and the coordinator-wide default. The block pool is
/// single-precision and the engine executes one kernel config per
/// process, so a request policy whose storage precision differs from the
/// pool's — or whose sigmoid/skip knobs differ from the coordinator's —
/// is a typed rejection, not a silently ignored knob; `window` is the
/// per-session axis the store and the lowering honor end to end.
fn bind_policy(
    policy: Option<AttnPolicy>,
    sessions: &SessionStore,
    default: &AttnPolicy,
) -> Result<AttnPolicy> {
    let Some(p) = policy else { return Ok(*default) };
    if p.kv_precision != sessions.precision {
        return Err(anyhow!(
            "policy kv_precision {:?} != pool precision {:?} (the block pool is single-precision; \
             start a coordinator at the desired precision)",
            p.kv_precision,
            sessions.precision
        ));
    }
    if p.sigmoid != default.sigmoid || p.skip != default.skip {
        return Err(anyhow!("per-session sigmoid/skip overrides must match the coordinator's kernel config"));
    }
    Ok(p)
}

/// Apply a batch's session mutations and resolve its KV source, attended
/// length, and route — the state half of dispatch, shared by the serial
/// and fused paths. Session-creating requests bind their attention
/// policy here (request > fork source > coordinator default).
fn prepare_batch(
    router: &Router,
    sessions: &mut SessionStore,
    members: &[Pending],
    default: &AttnPolicy,
    metrics: &Arc<Metrics>,
) -> Result<(Route, KvSrc, usize)> {
    let first = &members[0].req;
    let sig = first.sig;
    let variant = first.variant;
    let (h, d) = (sig.heads, sig.head_dim);

    // 1. Update session state (all appends land in the paged block pool).
    match &first.kind {
        RequestKind::Stateless => {}
        RequestKind::Prefill { session, policy } => {
            let pol = bind_policy(*policy, sessions, default)?;
            let cap = router.max_kv(variant, sig).ok_or_else(|| anyhow!("no artifacts for signature"))?;
            sessions
                .create_windowed(*session, h, d, cap, pol.window)
                .map_err(|e| anyhow!("session create: {e}"))?;
            sessions
                .append(*session, &first.k, &first.v, first.nkv)
                .map_err(|e| anyhow!("prefill append: {e}"))?;
            metrics.kv_appends.fetch_add(first.nkv as u64, Ordering::Relaxed);
        }
        RequestKind::Decode { session } => {
            let sid = *session;
            if !sessions.contains(sid) {
                return Err(anyhow!("unknown session {sid}"));
            }
            for m in members {
                sessions
                    .append(sid, &m.req.k, &m.req.v, 1)
                    .map_err(|e| anyhow!("decode append: {e}"))?;
            }
            metrics.kv_appends.fetch_add(members.len() as u64, Ordering::Relaxed);
        }
        RequestKind::Fork { src, session, policy } => {
            let (src, dst) = (*src, *session);
            let t = sessions.get(src).ok_or_else(|| anyhow!("unknown fork source {src}"))?;
            if t.heads != h || t.head_dim != d {
                return Err(anyhow!("fork source geometry mismatch"));
            }
            // Zero-copy prefix share; the carried K/V is the divergence.
            // The fork inherits the source's attention policy (the table
            // clone carries the window); an explicit override re-binds the
            // window before the divergent append — widening past trimmed
            // history is a typed error from the store.
            sessions.fork(src, dst).map_err(|e| anyhow!("fork: {e}"))?;
            if policy.is_some() {
                let pol = bind_policy(*policy, sessions, default)?;
                sessions.set_window(dst, pol.window).map_err(|e| anyhow!("fork policy: {e}"))?;
            }
            sessions
                .append(dst, &first.k, &first.v, first.nkv)
                .map_err(|e| anyhow!("fork append: {e}"))?;
            metrics.kv_appends.fetch_add(first.nkv as u64, Ordering::Relaxed);
        }
    }

    // 2. Resolve the KV source + attended length: `min(live, window)`,
    //    the element range the kernels stream (the gathered view hides
    //    retained-but-out-of-window slop behind its start offset), and
    //    the length routing sizes the problem by.
    let total_q: usize = members.iter().map(|m| m.req.nq).sum();
    let (kv, kv_len) = match first.session() {
        Some(sid) if !matches!(first.kind, RequestKind::Stateless) => {
            let table = sessions.get(sid).ok_or_else(|| anyhow!("session vanished"))?;
            (KvSrc::Session(sid), table.attended())
        }
        _ => (KvSrc::Inline, first.nkv),
    };

    // 3. Routing validation. The fused path executes exact shapes without
    //    padding, but a problem no compiled artifact could serve must be
    //    rejected identically on every engine.
    let route = router.route(variant, sig, total_q, kv_len).map_err(|e| anyhow!(e))?;
    Ok((route, kv, kv_len))
}

/// Serial dispatch: execute one batch end to end through the padded
/// per-route engine call and deliver its responses.
pub(crate) fn serve_batch<E: AttnEngine>(
    engine: &E,
    router: &Router,
    sessions: &mut SessionStore,
    batch: &Batch,
    pend: &mut [Option<Pending>],
    default: &AttnPolicy,
    metrics: &Arc<Metrics>,
) {
    let Some(ready) = admit_batch(router, sessions, batch, pend, default, metrics) else {
        return;
    };
    let batch_size = ready.batch_size;
    match pack_execute_split(engine, sessions, &ready) {
        Ok(outputs) => {
            for (m, out) in ready.members.into_iter().zip(outputs) {
                respond_ok(m, out, batch_size, metrics);
            }
        }
        Err(e) => respond_error(ready.members, &format!("{e}"), batch_size, metrics),
    }
}

/// The serial execute half: pack the padded `(heads, slots, head_dim)`
/// block tensors for the routed artifact, execute, split per-member
/// outputs.
fn pack_execute_split<E: AttnEngine>(
    engine: &E,
    sessions: &SessionStore,
    r: &Ready,
) -> Result<Vec<Vec<f32>>> {
    let (h, d) = (r.sig.heads, r.sig.head_dim);
    let route = &r.route;
    let kv_len = r.kv_len;

    let mut q = vec![0.0f32; h * route.q_slots * d];
    let mut row = 0usize;
    for m in &r.members {
        for rq in 0..m.req.nq {
            for hh in 0..h {
                let src = (hh * m.req.nq + rq) * d;
                let dst = (hh * route.q_slots + row) * d;
                q[dst..dst + d].copy_from_slice(&m.req.q[src..src + d]);
            }
            row += 1;
        }
    }
    let mut k = vec![0.0f32; h * route.kv_slots * d];
    let mut v = vec![0.0f32; h * route.kv_slots * d];
    // Session KV streams out of the paged store through the same
    // element-range `load_into` contract the fused path tiles over, so
    // the packed tensors are bit-identical to a contiguous cache. For
    // f32 blocks this is a straight copy; quantized blocks dequantize
    // into the padded block tensors (the per-route engines consume f32
    // regardless of storage precision).
    match r.kv {
        KvSrc::Session(sid) => {
            let kv = sessions.gather(sid).ok_or_else(|| anyhow!("session vanished"))?;
            debug_assert_eq!(kv.len, kv_len);
            let n = kv_len * d;
            for hh in 0..h {
                let dst = hh * route.kv_slots * d;
                kv.head_k(hh).load_into(0, n, &mut k[dst..dst + n]);
                kv.head_v(hh).load_into(0, n, &mut v[dst..dst + n]);
            }
        }
        KvSrc::Inline => {
            let first = &r.members[0].req;
            let n = kv_len * d;
            for hh in 0..h {
                let src = hh * first.nkv * d;
                let dst = hh * route.kv_slots * d;
                k[dst..dst + n].copy_from_slice(&first.k[src..src + n]);
                v[dst..dst + n].copy_from_slice(&first.v[src..src + n]);
            }
        }
    }

    let out = engine.execute(route, &q, &k, &v, kv_len)?;
    let mut outputs = Vec::with_capacity(r.members.len());
    let mut row = 0usize;
    for m in &r.members {
        let mut o = vec![0.0f32; r.sig.flat(m.req.nq)];
        for rq in 0..m.req.nq {
            for hh in 0..h {
                let src = (hh * route.q_slots + row + rq) * d;
                let dst = (hh * m.req.nq + rq) * d;
                o[dst..dst + d].copy_from_slice(&out[src..src + d]);
            }
        }
        row += m.req.nq;
        outputs.push(o);
    }
    Ok(outputs)
}

/// Fused dispatch: serve one drain cycle's batches through as few kernel
/// submissions as possible — one, absent session conflicts (see the
/// module docs for the full drain-cycle → block-job lowering contract).
pub(crate) fn serve_cycle_fused<E: AttnEngine>(
    engine: &E,
    router: &Router,
    sessions: &mut SessionStore,
    batches: &[Batch],
    pend: &mut [Option<Pending>],
    default: &AttnPolicy,
    metrics: &Arc<Metrics>,
) {
    if batches.is_empty() {
        return;
    }
    metrics.fused_cycles.fetch_add(1, Ordering::Relaxed);
    let mut group: Vec<Ready> = Vec::new();
    let mut group_sessions: HashSet<u64> = HashSet::new();
    let mut jobs_this_cycle = 0u64;
    for batch in batches {
        if fusion_conflict(router, sessions, &group_sessions, batch, pend, default) {
            jobs_this_cycle += flush_group(engine, sessions, &mut group, metrics);
            group_sessions.clear();
        }
        if let Some(r) = admit_batch(router, sessions, batch, pend, default, metrics) {
            if let KvSrc::Session(sid) = r.kv {
                group_sessions.insert(sid);
            }
            group.push(r);
        }
    }
    jobs_this_cycle += flush_group(engine, sessions, &mut group, metrics);
    metrics.observe_jobs_per_cycle(jobs_this_cycle);
}

/// Must the current fusion group flush before this batch is admitted?
/// True when the batch touches a session the group already reads — for a
/// fork, conservatively either endpoint — (its mutations would be visible
/// to the earlier batch's borrow); when its session's attention window
/// differs from one already in the group (mixed-policy isolation: each
/// submission serves one policy, so fused-vs-serial reasoning stays
/// per-window); or when its appends could LRU-evict blocks out of the
/// pool while the group still holds admitted-but-unflushed reads.
/// Creation is lazy in the paged store, so the eviction predicates mirror
/// `SessionStore::append`'s admission check exactly — per kind: decode
/// appends `members` steps, prefill re-creates then appends `nkv`, fork
/// shares then appends `nkv` (CoW-aware).
fn fusion_conflict(
    router: &Router,
    sessions: &SessionStore,
    group_sessions: &HashSet<u64>,
    batch: &Batch,
    pend: &[Option<Pending>],
    default: &AttnPolicy,
) -> bool {
    let Some(sid) = batch.session else {
        return false; // stateless: private KV, never conflicts
    };
    let first = pend[batch.members[0]].as_ref().map(|p| &p.req);
    let fork_src = first.and_then(|r| match r.kind {
        RequestKind::Fork { src, .. } => Some(src),
        _ => None,
    });
    if group_sessions.contains(&sid) || fork_src.is_some_and(|s| group_sessions.contains(&s)) {
        return true;
    }
    if group_sessions.is_empty() {
        return false;
    }
    // Mixed-policy isolation: the window this batch's session will run
    // with (post-binding, for creators) vs the windows already grouped.
    let incoming = match first.map(|r| &r.kind) {
        Some(RequestKind::Prefill { policy, .. }) => {
            policy.map_or(default.window, |p| p.window)
        }
        Some(RequestKind::Fork { src, policy, .. }) => match policy {
            Some(p) => p.window,
            None => sessions.get(*src).and_then(|t| t.window),
        },
        _ => sessions.get(sid).and_then(|t| t.window),
    };
    if group_sessions.iter().any(|&gs| sessions.get(gs).is_some_and(|t| t.window != incoming)) {
        return true;
    }
    if batch.decode {
        return sessions.append_would_evict(sid, batch.members.len());
    }
    let Some(first) = first else { return false };
    match first.kind {
        RequestKind::Fork { src, .. } => sessions.fork_would_evict(src, sid, first.nkv),
        // An unknown signature can't create a session, so it can't evict
        // either.
        RequestKind::Prefill { .. } => match router.max_kv(batch.variant, batch.sig) {
            Some(_) => {
                sessions.prefill_would_evict(sid, batch.sig.heads, batch.sig.head_dim, first.nkv)
            }
            None => false,
        },
        _ => false,
    }
}

/// Lower the accumulated fusion group into one flat job list, submit it
/// through a single [`AttnEngine::execute_fused`] call, and scatter the
/// outputs back to the members. Returns the number of jobs submitted.
fn flush_group<E: AttnEngine>(
    engine: &E,
    sessions: &SessionStore,
    group: &mut Vec<Ready>,
    metrics: &Arc<Metrics>,
) -> u64 {
    if group.is_empty() {
        return 0;
    }
    let group: Vec<Ready> = std::mem::take(group);
    metrics.fused_batches.fetch_add(group.len() as u64, Ordering::Relaxed);

    // Gather staging: only multi-member (decode fusion) batches need their
    // members' query rows copied into one (heads, total_q, d) block;
    // single-member batches borrow the request's q as-is.
    let staged: Vec<Option<Vec<f32>>> = group.iter().map(gather_queries).collect();

    // Simultaneous per-session KV gathers via `SessionStore::gather_many`:
    // all of the group's mutations are done, so every block table is
    // stable until the submission returns — each gather borrows the
    // session's pool blocks as per-head fragment lists. Inline
    // (stateless) batches borrow their first member's request payload
    // instead.
    let sess_ids: Vec<u64> = group
        .iter()
        .filter_map(|r| match r.kv {
            KvSrc::Session(sid) => Some(sid),
            KvSrc::Inline => None,
        })
        .collect();
    let sess_views = sessions.gather_many(&sess_ids);
    #[derive(Clone, Copy)]
    enum FusedSrc<'a> {
        Sess(&'a PagedSessionKv<'a>),
        Inline(&'a AttentionRequest),
    }
    let mut views = sess_views.iter();
    let srcs: Vec<Option<FusedSrc<'_>>> = group
        .iter()
        .map(|r| match r.kv {
            KvSrc::Session(_) => views
                .next()
                .expect("one gather per session-backed batch")
                .as_ref()
                .map(FusedSrc::Sess),
            KvSrc::Inline => Some(FusedSrc::Inline(&r.members[0].req)),
        })
        .collect();

    // Lower: one PagedKvBlockJob per (batch, head), covering the batch's
    // whole query block against the head's live KV prefix, borrowed in
    // place — session KV as block-table fragment views (kernel tiles
    // deliberately do not align with pool blocks; the view splits each
    // tile's element range across fragments, which is what keeps paged
    // output bit-identical to contiguous), quantized blocks referenced
    // as-is and only dequantized tile-by-tile inside the kernel workers.
    let mut jobs: Vec<PagedKvBlockJob<'_>> = Vec::new();
    let mut offsets: Vec<usize> = vec![usize::MAX; group.len()];
    let mut off = 0usize;
    for (bi, (r, src)) in group.iter().zip(&srcs).enumerate() {
        let Some(src) = src else {
            continue; // vanished session: answered after the submission
        };
        let (h, d) = (r.sig.heads, r.sig.head_dim);
        let scale = (d as f32).powf(-0.5);
        let q: &[f32] = staged[bi].as_deref().unwrap_or(&r.members[0].req.q);
        for hh in 0..h {
            let (k, v) = match *src {
                FusedSrc::Sess(p) => {
                    debug_assert_eq!(p.len, r.kv_len, "table mutated under the fusion group");
                    (p.head_k(hh), p.head_v(hh))
                }
                FusedSrc::Inline(first) => {
                    let ko = hh * first.nkv * d;
                    (
                        KvView::Contig(KvRef::F32(&first.k[ko..ko + r.kv_len * d])),
                        KvView::Contig(KvRef::F32(&first.v[ko..ko + r.kv_len * d])),
                    )
                }
            };
            jobs.push(PagedKvBlockJob {
                q: &q[hh * r.total_q * d..(hh + 1) * r.total_q * d],
                k,
                v,
                nq: r.total_q,
                n: r.kv_len,
                d,
                scale,
                causal: false,
            });
        }
        offsets[bi] = off;
        off += r.sig.flat(r.total_q);
    }

    let njobs = jobs.len() as u64;
    let rows: u64 = group
        .iter()
        .enumerate()
        .filter(|(bi, _)| offsets[*bi] != usize::MAX)
        .map(|(_, r)| r.total_q as u64)
        .sum();
    let mut out = vec![0.0f32; off];
    let exec = if jobs.is_empty() {
        Ok(SkipStats::default())
    } else {
        metrics.fused_submissions.fetch_add(1, Ordering::Relaxed);
        metrics.fused_jobs.fetch_add(njobs, Ordering::Relaxed);
        metrics.fused_rows.fetch_add(rows, Ordering::Relaxed);
        metrics.observe_fused_width(rows);
        engine.execute_fused(&jobs, &mut out)
    };
    drop(jobs);
    drop(srcs);
    match exec {
        Ok(st) => {
            metrics.skip_steps.fetch_add(st.total, Ordering::Relaxed);
            metrics.skip_skipped.fetch_add(st.skipped(), Ordering::Relaxed);
            for (bi, r) in group.into_iter().enumerate() {
                if offsets[bi] == usize::MAX {
                    let batch_size = r.batch_size;
                    respond_error(r.members, "session vanished", batch_size, metrics);
                    continue;
                }
                let end = offsets[bi] + r.sig.flat(r.total_q);
                scatter_batch(r, &out[offsets[bi]..end], metrics);
            }
        }
        Err(e) => {
            let msg = format!("{e}");
            for r in group {
                let batch_size = r.batch_size;
                respond_error(r.members, &msg, batch_size, metrics);
            }
        }
    }
    njobs
}

/// Staging for a decode fusion: copy the members' query rows into one
/// contiguous `(heads, total_q, d)` block. Single-member batches return
/// `None` — their request payload already has the block layout and is
/// borrowed directly.
fn gather_queries(r: &Ready) -> Option<Vec<f32>> {
    if r.members.len() == 1 {
        return None;
    }
    let (h, d) = (r.sig.heads, r.sig.head_dim);
    let nqs: Vec<usize> = r.members.iter().map(|m| m.req.nq).collect();
    let spans = member_row_spans(&nqs);
    let mut buf = vec![0.0f32; r.sig.flat(r.total_q)];
    for (m, (row0, nq)) in r.members.iter().zip(spans) {
        for hh in 0..h {
            for rq in 0..nq {
                let src = (hh * nq + rq) * d;
                let dst = (hh * r.total_q + row0 + rq) * d;
                buf[dst..dst + d].copy_from_slice(&m.req.q[src..src + d]);
            }
        }
    }
    Some(buf)
}

/// Scatter one batch's `(heads, total_q, d)` region of the fused output
/// back into per-member `(heads, nq, d)` responses by row span.
fn scatter_batch(r: Ready, region: &[f32], metrics: &Arc<Metrics>) {
    let (h, d) = (r.sig.heads, r.sig.head_dim);
    let total_q = r.total_q;
    let batch_size = r.batch_size;
    let nqs: Vec<usize> = r.members.iter().map(|m| m.req.nq).collect();
    let spans = member_row_spans(&nqs);
    for (m, (row0, nq)) in r.members.into_iter().zip(spans) {
        let mut o = vec![0.0f32; h * nq * d];
        for hh in 0..h {
            for rq in 0..nq {
                let src = (hh * total_q + row0 + rq) * d;
                let dst = (hh * nq + rq) * d;
                o[dst..dst + d].copy_from_slice(&region[src..src + d]);
            }
        }
        respond_ok(m, o, batch_size, metrics);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::form_batches;
    use crate::coordinator::request::{ShapeSig, Variant};
    use crate::runtime::Manifest;
    use std::time::Instant;

    fn test_router() -> Router {
        Router::from_manifest(
            &Manifest::parse(
                r#"{"artifacts": {
              "a128": {"file":"x","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":128,"head_dim":8,"inputs":[],"n_outputs":1},
              "a256": {"file":"y","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":256,"head_dim":8,"inputs":[],"n_outputs":1}
            }}"#,
            )
            .unwrap(),
        )
    }

    fn start_naive() -> Coordinator {
        let cfg = CoordinatorConfig {
            batch_window: Duration::from_micros(10),
            kernel: KernelConfig { tile: 8, threads: 2, ..KernelConfig::default() },
            // every engine-thread test doubles as a pool-invariant check
            validate_invariants: true,
            ..CoordinatorConfig::default()
        };
        Coordinator::start_naive(cfg, test_router()).unwrap()
    }

    fn rand_req(id: u64, kind: RequestKind, nq: usize, nkv: usize, seed: u64) -> AttentionRequest {
        let mut rng = crate::util::rng::Rng::new(seed);
        let sig = ShapeSig { heads: 2, head_dim: 8 };
        AttentionRequest {
            id,
            kind,
            variant: Variant::FlashD,
            sig,
            q: rng.normal_vec(2 * 8 * nq, 1.0),
            nq,
            k: rng.normal_vec(2 * 8 * nkv, 1.0),
            v: rng.normal_vec(2 * 8 * nkv, 1.0),
            nkv,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn stateless_roundtrip_matches_reference() {
        let c = start_naive();
        let req = rand_req(1, RequestKind::Stateless, 3, 20, 42);
        let (q, k, v) = (req.q.clone(), req.k.clone(), req.v.clone());
        let resp = c.submit_blocking(req);
        let out = resp.output.expect("ok");
        assert_eq!(out.len(), 2 * 3 * 8);
        // reference: per-head naive attention with 1/sqrt(8) scale
        let scale = (8f32).powf(-0.5);
        for hh in 0..2 {
            let ks = &k[hh * 20 * 8..(hh + 1) * 20 * 8];
            let vs = &v[hh * 20 * 8..(hh + 1) * 20 * 8];
            for r in 0..3 {
                let qs = &q[(hh * 3 + r) * 8..(hh * 3 + r + 1) * 8];
                let want = crate::kernels::naive::attention(qs, ks, vs, 20, 8, scale);
                let got = &out[(hh * 3 + r) * 8..(hh * 3 + r + 1) * 8];
                let diff = crate::kernels::max_abs_diff(got, &want);
                assert!(diff < 1e-4, "h={hh} r={r}: {diff}");
            }
        }
        c.shutdown();
    }

    #[test]
    fn prefill_then_decode_uses_cache() {
        let c = start_naive();
        let prefill = rand_req(1, RequestKind::prefill(5), 1, 16, 7);
        let (pk, pv) = (prefill.k.clone(), prefill.v.clone());
        assert!(c.submit_blocking(prefill).output.is_ok());

        let dec = rand_req(2, RequestKind::Decode { session: 5 }, 1, 1, 8);
        let (dq, dk, dv) = (dec.q.clone(), dec.k.clone(), dec.v.clone());
        let resp = c.submit_blocking(dec);
        let out = resp.output.expect("decode ok");

        // reference: attend 17 kv pairs (16 prefill + 1 decode)
        let scale = (8f32).powf(-0.5);
        for hh in 0..2 {
            let mut ks = pk[hh * 16 * 8..(hh + 1) * 16 * 8].to_vec();
            ks.extend_from_slice(&dk[hh * 8..(hh + 1) * 8]);
            let mut vs = pv[hh * 16 * 8..(hh + 1) * 16 * 8].to_vec();
            vs.extend_from_slice(&dv[hh * 8..(hh + 1) * 8]);
            let want = crate::kernels::naive::attention(&dq[hh * 8..(hh + 1) * 8], &ks, &vs, 17, 8, scale);
            let got = &out[hh * 8..(hh + 1) * 8];
            assert!(crate::kernels::max_abs_diff(got, &want) < 1e-4);
        }
        c.shutdown();
    }

    #[test]
    fn decode_without_session_errors() {
        let c = start_naive();
        let resp = c.submit_blocking(rand_req(1, RequestKind::Decode { session: 999 }, 1, 1, 1));
        assert!(resp.output.is_err());
        assert_eq!(c.metrics.snapshot().errors, 1);
        c.shutdown();
    }

    #[test]
    fn invalid_request_rejected() {
        let c = start_naive();
        let mut bad = rand_req(1, RequestKind::Stateless, 1, 4, 2);
        bad.q.pop();
        let resp = c.submit_blocking(bad);
        assert!(resp.output.unwrap_err().contains("invalid"));
        c.shutdown();
    }

    #[test]
    fn concurrent_decodes_batch_and_all_respond() {
        let c = start_naive();
        assert!(c.submit_blocking(rand_req(0, RequestKind::prefill(1), 1, 8, 3)).output.is_ok());
        // submit a burst of decodes from worker threads
        let c = std::sync::Arc::new(c);
        let mut handles = Vec::new();
        for i in 0..8u64 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                c2.submit_blocking(rand_req(100 + i, RequestKind::Decode { session: 1 }, 1, 1, 50 + i))
            }));
        }
        let mut ok = 0;
        for h in handles {
            let resp = h.join().unwrap();
            if resp.output.is_ok() {
                ok += 1;
            }
        }
        assert_eq!(ok, 8);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.responses, 9);
        assert!(snap.kv_appends >= 16);
        c.metrics.snapshot();
        if let Ok(c) = std::sync::Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn oversize_problem_surfaces_router_error() {
        let c = start_naive();
        let resp = c.submit_blocking(rand_req(1, RequestKind::Stateless, 1, 300, 4));
        assert!(resp.output.is_err());
        c.shutdown();
    }

    fn mk_pend(reqs: Vec<AttentionRequest>) -> (Vec<Option<Pending>>, Vec<Receiver<AttentionResponse>>) {
        let mut pend = Vec::new();
        let mut rxs = Vec::new();
        for req in reqs {
            let (tx, rx) = channel();
            pend.push(Some(Pending { req, reply: tx }));
            rxs.push(rx);
        }
        (pend, rxs)
    }

    fn recv_ok(rxs: &[Receiver<AttentionResponse>]) -> Vec<Vec<f32>> {
        rxs.iter().map(|rx| rx.recv().expect("response").output.expect("ok")).collect()
    }

    #[test]
    fn fused_cycle_is_one_submission_and_matches_serial() {
        let router = test_router();
        let kernel = KernelConfig { tile: 8, threads: 2, ..KernelConfig::default() };
        let engine = NaiveEngine::with_kernel(router.clone(), kernel);
        let policy = BatchPolicy::default();
        let default = AttnPolicy::from_kernel(&KernelConfig::default());

        // Cycle 1: two prefills (sessions 1, 2) + one stateless = 3
        // mergeable batches -> exactly one fused submission of 6 jobs.
        let reqs = vec![
            rand_req(1, RequestKind::prefill(1), 1, 12, 100),
            rand_req(2, RequestKind::prefill(2), 1, 9, 101),
            rand_req(3, RequestKind::Stateless, 2, 17, 102),
        ];
        let batches = form_batches(&reqs, &policy);
        assert_eq!(batches.len(), 3);

        let m_f = Arc::new(Metrics::new());
        let mut sess_f = SessionStore::new(256 << 20);
        let (mut pend_f, rxs_f) = mk_pend(reqs.clone());
        serve_cycle_fused(&engine, &router, &mut sess_f, &batches, &mut pend_f, &default, &m_f);
        let outs_f = recv_ok(&rxs_f);
        let snap = m_f.snapshot();
        assert_eq!(snap.fused_cycles, 1);
        assert_eq!(snap.fused_submissions, 1, "3 mergeable batches, 1 submission");
        assert_eq!(snap.fused_batches, 3);
        assert_eq!(snap.fused_jobs, 6); // 3 batches x 2 heads
        assert_eq!(snap.fused_rows, 4); // 1 + 1 + 2 query rows
        assert_eq!(snap.jobs_per_cycle_buckets.iter().sum::<u64>(), 1);

        let m_s = Arc::new(Metrics::new());
        let mut sess_s = SessionStore::new(256 << 20);
        let (mut pend_s, rxs_s) = mk_pend(reqs);
        for b in &batches {
            serve_batch(&engine, &router, &mut sess_s, b, &mut pend_s, &default, &m_s);
        }
        let outs_s = recv_ok(&rxs_s);
        assert_eq!(outs_f, outs_s, "fused outputs must be bit-identical to serial");
        assert_eq!(m_s.snapshot().fused_submissions, 0);

        // Cycle 2: a decode fusion on session 1 + a decode on session 2 =
        // 2 batches, still one submission; outputs still bit-identical.
        let reqs2 = vec![
            rand_req(10, RequestKind::Decode { session: 1 }, 1, 1, 110),
            rand_req(11, RequestKind::Decode { session: 2 }, 1, 1, 111),
            rand_req(12, RequestKind::Decode { session: 1 }, 1, 1, 112),
        ];
        let batches2 = form_batches(&reqs2, &policy);
        assert_eq!(batches2.len(), 2);
        let (mut pend2_f, rxs2_f) = mk_pend(reqs2.clone());
        serve_cycle_fused(&engine, &router, &mut sess_f, &batches2, &mut pend2_f, &default, &m_f);
        let outs2_f = recv_ok(&rxs2_f);
        let snap2 = m_f.snapshot();
        assert_eq!(snap2.fused_cycles, 2);
        assert_eq!(snap2.fused_submissions, 2);
        let (mut pend2_s, rxs2_s) = mk_pend(reqs2);
        for b in &batches2 {
            serve_batch(&engine, &router, &mut sess_s, b, &mut pend2_s, &default, &m_s);
        }
        assert_eq!(outs2_f, recv_ok(&rxs2_s));
        assert_eq!(sess_f.get(1).unwrap().len, sess_s.get(1).unwrap().len);
    }

    #[test]
    fn quantized_sessions_fused_matches_serial() {
        use crate::numerics::quant::KvPrecision;
        // Same drain cycle served fused and serially over bf16 session
        // caches: both paths read the identical quantized store, so the
        // outputs must be bit-identical to each other (and the store half
        // the bytes of an f32 one).
        let router = test_router();
        let kernel = KernelConfig {
            tile: 8,
            threads: 2,
            kv_precision: KvPrecision::Bf16,
            ..KernelConfig::default()
        };
        let engine = NaiveEngine::with_kernel(router.clone(), kernel);
        let policy = BatchPolicy::default();
        let default = AttnPolicy::from_kernel(&KernelConfig::default());
        let reqs = vec![
            rand_req(1, RequestKind::prefill(1), 1, 12, 200),
            rand_req(2, RequestKind::Stateless, 2, 17, 201),
        ];
        let batches = form_batches(&reqs, &policy);

        let m_f = Arc::new(Metrics::new());
        let mut sess_f = SessionStore::with_precision(256 << 20, KvPrecision::Bf16);
        let (mut pend_f, rxs_f) = mk_pend(reqs.clone());
        serve_cycle_fused(&engine, &router, &mut sess_f, &batches, &mut pend_f, &default, &m_f);
        let outs_f = recv_ok(&rxs_f);

        let m_s = Arc::new(Metrics::new());
        let mut sess_s = SessionStore::with_precision(256 << 20, KvPrecision::Bf16);
        let (mut pend_s, rxs_s) = mk_pend(reqs);
        for b in &batches {
            serve_batch(&engine, &router, &mut sess_s, b, &mut pend_s, &default, &m_s);
        }
        assert_eq!(outs_f, recv_ok(&rxs_s));
        // bf16 pool: the 12-step prefill occupies one 32-step block of
        // 2 tensors x 2 heads x 32 steps x 8 dims x 2 bytes — half the
        // bytes the f32 pool's block would hold.
        assert_eq!(sess_f.bytes(), sess_f.pool().block_bytes(2, 8));
        assert_eq!(sess_f.bytes(), 2 * 2 * 32 * 8 * 2);
        // follow-up decode over the quantized cache answers on both paths
        let dec = vec![rand_req(3, RequestKind::Decode { session: 1 }, 1, 1, 202)];
        let db = form_batches(&dec, &policy);
        let (mut pd_f, rd_f) = mk_pend(dec.clone());
        serve_cycle_fused(&engine, &router, &mut sess_f, &db, &mut pd_f, &default, &m_f);
        let (mut pd_s, rd_s) = mk_pend(dec);
        for b in &db {
            serve_batch(&engine, &router, &mut sess_s, b, &mut pd_s, &default, &m_s);
        }
        assert_eq!(recv_ok(&rd_f), recv_ok(&rd_s));
    }

    #[test]
    fn same_session_conflict_splits_submissions() {
        let router = test_router();
        let engine = NaiveEngine::new(router.clone());
        let m = Arc::new(Metrics::new());
        let mut sessions = SessionStore::new(256 << 20);
        let policy = BatchPolicy::default();
        let default = AttnPolicy::from_kernel(&KernelConfig::default());

        let pre = vec![rand_req(1, RequestKind::prefill(7), 1, 8, 7)];
        let b0 = form_batches(&pre, &policy);
        let (mut p0, r0) = mk_pend(pre);
        serve_cycle_fused(&engine, &router, &mut sessions, &b0, &mut p0, &default, &m);
        assert!(r0[0].recv().unwrap().output.is_ok());

        // One cycle: decode(7) then re-prefill(7). The re-prefill would
        // replace the cache the decode's job borrows -> group must flush,
        // giving 2 submissions and serial-identical state.
        let cyc = vec![
            rand_req(2, RequestKind::Decode { session: 7 }, 1, 1, 8),
            rand_req(3, RequestKind::prefill(7), 1, 6, 9),
        ];
        let batches = form_batches(&cyc, &policy);
        assert_eq!(batches.len(), 2);
        let (mut pend, rxs) = mk_pend(cyc);
        serve_cycle_fused(&engine, &router, &mut sessions, &batches, &mut pend, &default, &m);
        for rx in &rxs {
            assert!(rx.recv().unwrap().output.is_ok());
        }
        let snap = m.snapshot();
        assert_eq!(snap.fused_cycles, 2);
        assert_eq!(snap.fused_submissions, 3, "conflict must split the cycle");
        // the re-prefill replaced the cache after the decode executed
        assert_eq!(sessions.get(7).unwrap().len, 6);
    }

    #[test]
    fn eviction_risk_flushes_group() {
        let router = test_router();
        let engine = NaiveEngine::new(router.clone());
        let m = Arc::new(Metrics::new());
        // budget = exactly one full-capacity session: 8 blocks of
        // 2 heads x 32 steps x 8 dims x 2 tensors x 4B = 4096B each.
        let mut sessions = SessionStore::new(8 * 4096);
        let policy = BatchPolicy::default();
        let default = AttnPolicy::from_kernel(&KernelConfig::default());

        // fill the whole budget: 255 steps -> 8 blocks resident
        let pre = vec![rand_req(1, RequestKind::prefill(1), 1, 255, 20)];
        let b0 = form_batches(&pre, &policy);
        let (mut p0, r0) = mk_pend(pre);
        serve_cycle_fused(&engine, &router, &mut sessions, &b0, &mut p0, &default, &m);
        assert!(r0[0].recv().unwrap().output.is_ok());
        assert_eq!(sessions.bytes(), 8 * 4096);

        // decode(1) fits its partial tail block, but prefill(2) needs a
        // fresh block the pool can't hold -> its append must evict
        // session 1's blocks, so the group flushes before admission.
        let cyc = vec![
            rand_req(2, RequestKind::Decode { session: 1 }, 1, 1, 21),
            rand_req(3, RequestKind::prefill(2), 1, 5, 22),
        ];
        let batches = form_batches(&cyc, &policy);
        let (mut pend, rxs) = mk_pend(cyc);
        serve_cycle_fused(&engine, &router, &mut sessions, &batches, &mut pend, &default, &m);
        for rx in &rxs {
            assert!(rx.recv().unwrap().output.is_ok());
        }
        assert_eq!(m.snapshot().fused_submissions, 3);
        assert!(!sessions.contains(1) && sessions.contains(2));
        // block-granular accounting: eviction freed all 8 of session 1's
        // blocks (none shared), and session 2 holds exactly one
        assert_eq!(sessions.evictions, 1);
        assert_eq!(sessions.block_evictions, 8);
        assert_eq!(sessions.bytes(), 4096);
        sessions.check_invariants().unwrap();
    }

    #[test]
    fn fork_request_shares_prefix_and_matches_reference() {
        let c = start_naive();
        let pre = rand_req(1, RequestKind::prefill(1), 1, 16, 30);
        let (pk, pv) = (pre.k.clone(), pre.v.clone());
        assert!(c.submit_blocking(pre).output.is_ok());

        let fork = rand_req(2, RequestKind::fork(1, 2), 1, 2, 31);
        let (fq, fk, fv) = (fork.q.clone(), fork.k.clone(), fork.v.clone());
        let out = c.submit_blocking(fork).output.expect("fork ok");

        // reference: the fork's query attends 16 shared + 2 divergent kv
        let scale = (8f32).powf(-0.5);
        for hh in 0..2 {
            let mut ks = pk[hh * 16 * 8..(hh + 1) * 16 * 8].to_vec();
            ks.extend_from_slice(&fk[hh * 2 * 8..(hh + 1) * 2 * 8]);
            let mut vs = pv[hh * 16 * 8..(hh + 1) * 16 * 8].to_vec();
            vs.extend_from_slice(&fv[hh * 2 * 8..(hh + 1) * 2 * 8]);
            let want = crate::kernels::naive::attention(&fq[hh * 8..(hh + 1) * 8], &ks, &vs, 18, 8, scale);
            let got = &out[hh * 8..(hh + 1) * 8];
            assert!(crate::kernels::max_abs_diff(got, &want) < 1e-4, "h={hh}");
        }
        // both lineages stay independently decodable after the fork
        assert!(c.submit_blocking(rand_req(3, RequestKind::Decode { session: 2 }, 1, 1, 32)).output.is_ok());
        assert!(c.submit_blocking(rand_req(4, RequestKind::Decode { session: 1 }, 1, 1, 33)).output.is_ok());
        let snap = c.metrics.snapshot();
        assert!(snap.kv_prefix_share_hits >= 1, "fork must share prefix blocks");
        c.shutdown();
    }

    #[test]
    fn fork_from_unknown_session_errors() {
        let c = start_naive();
        let resp = c.submit_blocking(rand_req(1, RequestKind::fork(42, 2), 1, 1, 34));
        assert!(resp.output.unwrap_err().contains("unknown fork source"));
        c.shutdown();
    }

    /// A windowed session's decode attends exactly the window suffix —
    /// identical to the full kernel run over only that KV — and the trim
    /// counters surface through the metrics sink.
    #[test]
    fn windowed_prefill_decode_attends_window_suffix() {
        let c = start_naive(); // tile 8 -> 8-step pool blocks
        let policy = AttnPolicy::from_kernel(&KernelConfig::default()).with_window(8);
        let kind = RequestKind::Prefill { session: 5, policy: Some(policy) };
        let pre = rand_req(1, kind, 1, 20, 7);
        let (pk, pv) = (pre.k.clone(), pre.v.clone());
        assert!(c.submit_blocking(pre).output.is_ok());

        let dec = rand_req(2, RequestKind::Decode { session: 5 }, 1, 1, 8);
        let (dq, dk, dv) = (dec.q.clone(), dec.k.clone(), dec.v.clone());
        let out = c.submit_blocking(dec).output.expect("decode ok");

        // window 8 over 21 total steps: prefill steps 13..20 + the decode
        // pair. No rescaling fix-up — the FLASH-D recursion over exactly
        // this KV is the whole reference.
        let scale = (8f32).powf(-0.5);
        for hh in 0..2 {
            let mut ks = pk[(hh * 20 + 13) * 8..(hh * 20 + 20) * 8].to_vec();
            ks.extend_from_slice(&dk[hh * 8..(hh + 1) * 8]);
            let mut vs = pv[(hh * 20 + 13) * 8..(hh * 20 + 20) * 8].to_vec();
            vs.extend_from_slice(&dv[hh * 8..(hh + 1) * 8]);
            let want = crate::kernels::naive::attention(&dq[hh * 8..(hh + 1) * 8], &ks, &vs, 8, 8, scale);
            let got = &out[hh * 8..(hh + 1) * 8];
            assert!(crate::kernels::max_abs_diff(got, &want) < 1e-4, "h={hh}");
        }
        let snap = c.metrics.snapshot();
        assert!(snap.kv_window_trims >= 1, "prefill past the window must trim");
        assert!(snap.kv_blocks_trimmed >= 1, "sole-owner trimmed blocks must free");
        c.shutdown();
    }

    /// Mixed-policy isolation: sessions with different windows never
    /// share a fused submission.
    #[test]
    fn mixed_window_policies_split_submissions() {
        let router = test_router();
        let engine = NaiveEngine::new(router.clone());
        let m = Arc::new(Metrics::new());
        let mut sessions = SessionStore::new(256 << 20);
        let policy = BatchPolicy::default();
        let default = AttnPolicy::from_kernel(&KernelConfig::default());

        let windowed = RequestKind::Prefill { session: 1, policy: Some(default.with_window(32)) };
        let reqs = vec![
            rand_req(1, windowed, 1, 8, 50),
            rand_req(2, RequestKind::prefill(2), 1, 8, 51),
        ];
        let batches = form_batches(&reqs, &policy);
        assert_eq!(batches.len(), 2);
        let (mut pend, rxs) = mk_pend(reqs);
        serve_cycle_fused(&engine, &router, &mut sessions, &batches, &mut pend, &default, &m);
        for rx in &rxs {
            assert!(rx.recv().unwrap().output.is_ok());
        }
        assert_eq!(m.snapshot().fused_submissions, 2, "mixed windows must not fuse");
        assert_eq!(sessions.get(1).unwrap().window, Some(32));
        assert_eq!(sessions.get(2).unwrap().window, None);
        sessions.check_invariants().unwrap();
    }

    /// The pool is single-precision: a policy asking for a different
    /// storage precision is a typed rejection at session creation.
    #[test]
    fn policy_precision_mismatch_rejected_at_creation() {
        let c = start_naive(); // f32 pool
        let bad = AttnPolicy {
            kv_precision: crate::numerics::quant::KvPrecision::Bf16,
            ..AttnPolicy::from_kernel(&KernelConfig::default())
        };
        let kind = RequestKind::Prefill { session: 9, policy: Some(bad) };
        let err = c.submit_blocking(rand_req(1, kind, 1, 4, 60)).output.unwrap_err();
        assert!(err.contains("kv_precision"), "got: {err}");
        c.shutdown();
    }

    #[test]
    fn config_builder_rejects_bad_knobs() {
        assert!(CoordinatorConfig::builder().build().is_ok());
        assert_eq!(CoordinatorConfig::builder().drain_cycle(0).build().unwrap_err(), ConfigError::ZeroDrainCycle);
        assert_eq!(CoordinatorConfig::builder().queue_capacity(0).build().unwrap_err(), ConfigError::ZeroQueueCapacity);
        let err = CoordinatorConfig::builder().kv_budget_bytes(7).build().unwrap_err();
        assert!(matches!(err, ConfigError::KvBudgetBelowOneBlock { budget: 7, .. }), "{err}");
        // default tile = 32-step blocks: 33 is misaligned, 64 aligned
        let err = CoordinatorConfig::builder().window(Some(33)).build().unwrap_err();
        assert!(matches!(err, ConfigError::WindowNotBlockAligned { window: 33, .. }), "{err}");
        assert_eq!(
            CoordinatorConfig::builder().window(Some(0)).build().unwrap_err(),
            ConfigError::WindowNotBlockAligned { window: 0, block_steps: 32 }
        );
        let cfg = CoordinatorConfig::builder().window(Some(64)).build().unwrap();
        assert_eq!(cfg.default_policy().window, Some(64));
        assert_eq!(cfg.default_policy().kv_precision, cfg.kernel.kv_precision);
    }
}
