//! The engine thread: owns the (deliberately single-threaded) PJRT
//! runtime, drains the request channel through the scheduler + batcher,
//! manages session KV state, executes attention blocks, and responds.
//!
//! `AttnEngine` abstracts the executor so the entire coordination logic is
//! testable against a pure-Rust engine ([`NaiveEngine`]) without compiled
//! artifacts; production uses [`PjrtEngine`] over the AOT artifacts.

use super::batcher::{form_batches, Batch, BatchPolicy};
use super::kv_cache::SessionStore;
use super::metrics::Metrics;
use super::request::{AttentionRequest, AttentionResponse, RequestKind};
use super::router::{Route, Router};
use super::scheduler::{Policy, Rejected, Scheduler};
use crate::kernels::batch::{run_blocks_into_with, BatchScratch, BlockJob, KernelConfig};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Executes one routed attention block.
/// Inputs are flat (heads, slots, head_dim); `kv_len` marks the valid
/// prefix of the K/V tensors.
pub trait AttnEngine {
    fn execute(&self, route: &Route, q: &[f32], k: &[f32], v: &[f32], kv_len: usize) -> Result<Vec<f32>>;
    /// The router snapshot this engine can serve.
    fn router(&self) -> Router;
}

/// Production engine: compiled AOT artifacts via PJRT.
pub struct PjrtEngine {
    pub rt: Runtime,
}

impl PjrtEngine {
    pub fn open(dir: &std::path::Path) -> Result<PjrtEngine> {
        Ok(PjrtEngine { rt: Runtime::open(dir)? })
    }
}

impl AttnEngine for PjrtEngine {
    fn execute(&self, route: &Route, q: &[f32], k: &[f32], v: &[f32], kv_len: usize) -> Result<Vec<f32>> {
        let shape = [route.heads, route.q_slots, route.head_dim];
        let kshape = [route.heads, route.kv_slots, route.head_dim];
        let inputs = [
            lit_f32(q, &shape)?,
            lit_f32(k, &kshape)?,
            lit_f32(v, &kshape)?,
            lit_i32(&[kv_len as i32], &[1, 1])?,
        ];
        let out = self.rt.execute(&route.artifact, &inputs)?;
        to_vec_f32(&out[0])
    }

    fn router(&self) -> Router {
        Router::from_manifest(&self.rt.manifest)
    }
}

/// Test/bench engine: the query-blocked FLASH-D kernel driven through the
/// batched multi-thread driver (no PJRT). Serves the same shapes as the
/// given router and applies the artifacts' 1/sqrt(d) scale.
pub struct NaiveEngine {
    pub router: Router,
    /// Tile/block/thread/skip knobs for the kernel path (serving defaults
    /// to the exact kernel: `SkipCriterion::None`).
    pub kernel: KernelConfig,
    /// Reusable kernel scratch. The engine lives on one engine thread and
    /// `execute` takes `&self`, so interior mutability is enough; the
    /// kernel's score/state buffers are reused across batches (per batch
    /// only the output buffer and the small block/item plans allocate).
    scratch: RefCell<BatchScratch>,
}

impl NaiveEngine {
    pub fn new(router: Router) -> NaiveEngine {
        NaiveEngine::with_kernel(router, KernelConfig::default())
    }

    pub fn with_kernel(router: Router, kernel: KernelConfig) -> NaiveEngine {
        NaiveEngine { router, kernel, scratch: RefCell::new(BatchScratch::new()) }
    }
}

impl AttnEngine for NaiveEngine {
    fn execute(&self, route: &Route, q: &[f32], k: &[f32], v: &[f32], kv_len: usize) -> Result<Vec<f32>> {
        let (h, lq, lkv, d) = (route.heads, route.q_slots, route.kv_slots, route.head_dim);
        let scale = (d as f32).powf(-0.5);
        // One query block per head: all lq rows of a head share its KV
        // prefix, so the query-blocked kernel streams each KV tile once
        // per block instead of once per row. The driver splits blocks
        // across worker threads with deterministic output ordering.
        let mut blocks = Vec::with_capacity(h);
        for hh in 0..h {
            let koff = hh * lkv * d;
            blocks.push(BlockJob {
                q: &q[hh * lq * d..(hh + 1) * lq * d],
                k: &k[koff..koff + kv_len * d],
                v: &v[koff..koff + kv_len * d],
                nq: lq,
                n: kv_len,
                d,
                scale,
                causal: false,
            });
        }
        // blocks are in (head, query) order, so the flat driver writes the
        // response layout directly
        let mut out = vec![0.0f32; h * lq * d];
        run_blocks_into_with(&self.kernel, &blocks, d, &mut out, &mut self.scratch.borrow_mut());
        Ok(out)
    }

    fn router(&self) -> Router {
        self.router.clone()
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifact_dir: std::path::PathBuf,
    pub policy: Policy,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Session KV budget in bytes.
    pub kv_budget_bytes: usize,
    /// How long the engine waits for more arrivals before dispatching a
    /// non-full batch.
    pub batch_window: Duration,
    /// Tile/thread/skip knobs for the software kernel path (honored by
    /// [`NaiveEngine`]-backed coordinators via [`Coordinator::start_naive`];
    /// the PJRT path executes whole compiled blocks and ignores it).
    pub kernel: KernelConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            policy: Policy::DecodeFirst,
            queue_capacity: 1024,
            batch: BatchPolicy::default(),
            kv_budget_bytes: 256 << 20,
            batch_window: Duration::from_micros(200),
            kernel: KernelConfig::default(),
        }
    }
}

enum Msg {
    Request(AttentionRequest, Sender<AttentionResponse>),
    Shutdown,
}

/// Client handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    pub metrics: Arc<Metrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start with the production PJRT engine.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let dir = cfg.artifact_dir.clone();
        Coordinator::start_with(cfg, move || {
            PjrtEngine::open(&dir).map_err(|e| anyhow!("engine startup: {e}"))
        })
    }

    /// Start with the pure-Rust tiled kernel engine over the given router,
    /// honoring `cfg.kernel` — the no-PJRT serving path.
    pub fn start_naive(cfg: CoordinatorConfig, router: Router) -> Result<Coordinator> {
        let kernel = cfg.kernel;
        Coordinator::start_with(cfg, move || Ok(NaiveEngine::with_kernel(router, kernel)))
    }

    /// Start with an arbitrary engine factory (constructed *inside* the
    /// engine thread — PJRT handles are not Send).
    pub fn start_with<E, F>(cfg: CoordinatorConfig, factory: F) -> Result<Coordinator>
    where
        E: AttnEngine,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("flashd-engine".into())
            .spawn(move || {
                let engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(engine, rx, cfg, m2);
            })
            .expect("spawn engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator { tx, metrics, handle: Some(handle) })
    }

    /// Submit a request; returns the response receiver.
    pub fn submit(&self, req: AttentionRequest) -> Receiver<AttentionResponse> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        // engine gone => receiver errors out, surfaced to caller on recv
        let _ = self.tx.send(Msg::Request(req, tx));
        rx
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, req: AttentionRequest) -> AttentionResponse {
        let id = req.id;
        match self.submit(req).recv() {
            Ok(r) => r,
            Err(_) => AttentionResponse {
                id,
                output: Err("engine unavailable".into()),
                latency_us: 0,
                batch_size: 0,
            },
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Pending {
    req: AttentionRequest,
    reply: Sender<AttentionResponse>,
}

fn engine_loop<E: AttnEngine>(engine: E, rx: Receiver<Msg>, cfg: CoordinatorConfig, metrics: Arc<Metrics>) {
    let router = engine.router();
    let mut sessions = SessionStore::new(cfg.kv_budget_bytes);
    let mut sched = Scheduler::new(cfg.queue_capacity, cfg.policy);
    let mut replies: std::collections::HashMap<u64, Sender<AttentionResponse>> =
        std::collections::HashMap::new();

    'outer: loop {
        // Block for the first message, then greedily drain within the
        // batch window to give the batcher material.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut msgs = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        loop {
            match rx.try_recv() {
                Ok(m) => msgs.push(m),
                Err(_) => {
                    // Hold the window open briefly so near-simultaneous
                    // arrivals can share a batch.
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }

        let mut shutdown = false;
        for m in msgs {
            match m {
                Msg::Shutdown => shutdown = true,
                Msg::Request(req, reply) => {
                    let id = req.id;
                    match sched.submit(req) {
                        Ok(()) => {
                            replies.insert(id, reply);
                        }
                        Err(Rejected::QueueFull) => {
                            metrics.queue_rejections.fetch_add(1, Ordering::Relaxed);
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                            let _ = reply.send(AttentionResponse {
                                id,
                                output: Err("queue full".into()),
                                latency_us: 0,
                                batch_size: 0,
                            });
                        }
                        Err(Rejected::Invalid(e)) => {
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                            let _ = reply.send(AttentionResponse {
                                id,
                                output: Err(format!("invalid request: {e}")),
                                latency_us: 0,
                                batch_size: 0,
                            });
                        }
                    }
                }
            }
        }

        // Dispatch everything admitted so far.
        while !sched.is_empty() {
            let pending_reqs = sched.drain(cfg.queue_capacity);
            let batches = form_batches(&pending_reqs, &cfg.batch);
            let mut pend: Vec<Option<Pending>> = pending_reqs
                .into_iter()
                .map(|req| {
                    let reply = replies.remove(&req.id)?;
                    Some(Pending { req, reply })
                })
                .collect();
            for batch in batches {
                serve_batch(&engine, &router, &mut sessions, &batch, &mut pend, &metrics);
            }
        }
        if shutdown {
            break 'outer;
        }
    }
}

/// Execute one batch end to end and deliver its responses.
fn serve_batch<E: AttnEngine>(
    engine: &E,
    router: &Router,
    sessions: &mut SessionStore,
    batch: &Batch,
    pend: &mut [Option<Pending>],
    metrics: &Arc<Metrics>,
) {
    let members: Vec<Pending> = batch
        .members
        .iter()
        .filter_map(|&i| pend[i].take())
        .collect();
    if members.is_empty() {
        return;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_requests.fetch_add(members.len() as u64, Ordering::Relaxed);

    let result = build_and_execute(engine, router, sessions, &members, metrics);
    match result {
        Ok(outputs) => {
            for (m, out) in members.into_iter().zip(outputs) {
                let latency_us = m.req.submitted_at.elapsed().as_micros() as u64;
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                metrics.observe_latency(latency_us);
                let _ = m.reply.send(AttentionResponse {
                    id: m.req.id,
                    output: Ok(out),
                    latency_us,
                    batch_size: batch.members.len(),
                });
            }
        }
        Err(e) => {
            let msg = format!("{e}");
            for m in members {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = m.reply.send(AttentionResponse {
                    id: m.req.id,
                    output: Err(msg.clone()),
                    latency_us: m.req.submitted_at.elapsed().as_micros() as u64,
                    batch_size: batch.members.len(),
                });
            }
        }
    }
}

/// Assemble the padded block tensors for a batch, run it, split outputs.
fn build_and_execute<E: AttnEngine>(
    engine: &E,
    router: &Router,
    sessions: &mut SessionStore,
    members: &[Pending],
    metrics: &Arc<Metrics>,
) -> Result<Vec<Vec<f32>>> {
    let first = &members[0].req;
    let sig = first.sig;
    let variant = first.variant;
    let (h, d) = (sig.heads, sig.head_dim);

    // 1. Update session state.
    match &first.kind {
        RequestKind::Stateless => {}
        RequestKind::Prefill { session } => {
            let cap = router
                .max_kv(variant, sig)
                .ok_or_else(|| anyhow!("no artifacts for signature"))?;
            sessions
                .create(*session, h, d, cap)
                .map_err(|e| anyhow!("session create: {e}"))?;
            let cache = sessions.get_mut(*session).unwrap();
            cache
                .append(&first.k, &first.v, first.nkv)
                .map_err(|e| anyhow!("prefill append: {e}"))?;
            metrics.kv_appends.fetch_add(first.nkv as u64, Ordering::Relaxed);
        }
        RequestKind::Decode { session } => {
            let sid = *session;
            if !sessions.contains(sid) {
                return Err(anyhow!("unknown session {sid}"));
            }
            let cache = sessions.get_mut(sid).unwrap();
            for m in members {
                cache
                    .append(&m.req.k, &m.req.v, 1)
                    .map_err(|e| anyhow!("decode append: {e}"))?;
            }
            metrics.kv_appends.fetch_add(members.len() as u64, Ordering::Relaxed);
        }
    }

    // 2. Gather K/V + query rows.
    let total_q: usize = members.iter().map(|m| m.req.nq).sum();
    let (kv_src_k, kv_src_v, kv_len, kv_src_cap): (&[f32], &[f32], usize, usize) =
        match first.session() {
            Some(sid) if !matches!(first.kind, RequestKind::Stateless) => {
                let cache = sessions.get(sid).ok_or_else(|| anyhow!("session vanished"))?;
                (&cache.k, &cache.v, cache.len, cache.cap)
            }
            _ => (&first.k, &first.v, first.nkv, first.nkv),
        };

    let route = router.route(variant, sig, total_q, kv_len).map_err(|e| anyhow!(e))?;

    // 3. Pack tensors (heads, slots, d).
    let mut q = vec![0.0f32; h * route.q_slots * d];
    let mut row = 0usize;
    for m in members {
        for r in 0..m.req.nq {
            for hh in 0..h {
                let src = (hh * m.req.nq + r) * d;
                let dst = (hh * route.q_slots + row) * d;
                q[dst..dst + d].copy_from_slice(&m.req.q[src..src + d]);
            }
            row += 1;
        }
    }
    let mut k = vec![0.0f32; h * route.kv_slots * d];
    let mut v = vec![0.0f32; h * route.kv_slots * d];
    for hh in 0..h {
        let src = hh * kv_src_cap * d;
        let dst = hh * route.kv_slots * d;
        let n = kv_len * d;
        k[dst..dst + n].copy_from_slice(&kv_src_k[src..src + n]);
        v[dst..dst + n].copy_from_slice(&kv_src_v[src..src + n]);
    }

    // 4. Execute and split.
    let out = engine.execute(&route, &q, &k, &v, kv_len)?;
    let mut outputs = Vec::with_capacity(members.len());
    let mut row = 0usize;
    for m in members {
        let mut o = vec![0.0f32; h * m.req.nq * d];
        for r in 0..m.req.nq {
            for hh in 0..h {
                let src = (hh * route.q_slots + row + r) * d;
                let dst = (hh * m.req.nq + r) * d;
                o[dst..dst + d].copy_from_slice(&out[src..src + d]);
            }
        }
        row += m.req.nq;
        outputs.push(o);
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{ShapeSig, Variant};
    use crate::runtime::Manifest;

    fn test_router() -> Router {
        Router::from_manifest(
            &Manifest::parse(
                r#"{"artifacts": {
              "a128": {"file":"x","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":128,"head_dim":8,"inputs":[],"n_outputs":1},
              "a256": {"file":"y","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":256,"head_dim":8,"inputs":[],"n_outputs":1}
            }}"#,
            )
            .unwrap(),
        )
    }

    fn start_naive() -> Coordinator {
        let cfg = CoordinatorConfig {
            batch_window: Duration::from_micros(10),
            kernel: KernelConfig { tile: 8, threads: 2, ..KernelConfig::default() },
            ..CoordinatorConfig::default()
        };
        Coordinator::start_naive(cfg, test_router()).unwrap()
    }

    fn rand_req(id: u64, kind: RequestKind, nq: usize, nkv: usize, seed: u64) -> AttentionRequest {
        let mut rng = crate::util::rng::Rng::new(seed);
        let sig = ShapeSig { heads: 2, head_dim: 8 };
        AttentionRequest {
            id,
            kind,
            variant: Variant::FlashD,
            sig,
            q: rng.normal_vec(2 * 8 * nq, 1.0),
            nq,
            k: rng.normal_vec(2 * 8 * nkv, 1.0),
            v: rng.normal_vec(2 * 8 * nkv, 1.0),
            nkv,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn stateless_roundtrip_matches_reference() {
        let c = start_naive();
        let req = rand_req(1, RequestKind::Stateless, 3, 20, 42);
        let (q, k, v) = (req.q.clone(), req.k.clone(), req.v.clone());
        let resp = c.submit_blocking(req);
        let out = resp.output.expect("ok");
        assert_eq!(out.len(), 2 * 3 * 8);
        // reference: per-head naive attention with 1/sqrt(8) scale
        let scale = (8f32).powf(-0.5);
        for hh in 0..2 {
            let ks = &k[hh * 20 * 8..(hh + 1) * 20 * 8];
            let vs = &v[hh * 20 * 8..(hh + 1) * 20 * 8];
            for r in 0..3 {
                let qs = &q[(hh * 3 + r) * 8..(hh * 3 + r + 1) * 8];
                let want = crate::kernels::naive::attention(qs, ks, vs, 20, 8, scale);
                let got = &out[(hh * 3 + r) * 8..(hh * 3 + r + 1) * 8];
                let diff = crate::kernels::max_abs_diff(got, &want);
                assert!(diff < 1e-4, "h={hh} r={r}: {diff}");
            }
        }
        c.shutdown();
    }

    #[test]
    fn prefill_then_decode_uses_cache() {
        let c = start_naive();
        let prefill = rand_req(1, RequestKind::Prefill { session: 5 }, 1, 16, 7);
        let (pk, pv) = (prefill.k.clone(), prefill.v.clone());
        assert!(c.submit_blocking(prefill).output.is_ok());

        let dec = rand_req(2, RequestKind::Decode { session: 5 }, 1, 1, 8);
        let (dq, dk, dv) = (dec.q.clone(), dec.k.clone(), dec.v.clone());
        let resp = c.submit_blocking(dec);
        let out = resp.output.expect("decode ok");

        // reference: attend 17 kv pairs (16 prefill + 1 decode)
        let scale = (8f32).powf(-0.5);
        for hh in 0..2 {
            let mut ks = pk[hh * 16 * 8..(hh + 1) * 16 * 8].to_vec();
            ks.extend_from_slice(&dk[hh * 8..(hh + 1) * 8]);
            let mut vs = pv[hh * 16 * 8..(hh + 1) * 16 * 8].to_vec();
            vs.extend_from_slice(&dv[hh * 8..(hh + 1) * 8]);
            let want = crate::kernels::naive::attention(&dq[hh * 8..(hh + 1) * 8], &ks, &vs, 17, 8, scale);
            let got = &out[hh * 8..(hh + 1) * 8];
            assert!(crate::kernels::max_abs_diff(got, &want) < 1e-4);
        }
        c.shutdown();
    }

    #[test]
    fn decode_without_session_errors() {
        let c = start_naive();
        let resp = c.submit_blocking(rand_req(1, RequestKind::Decode { session: 999 }, 1, 1, 1));
        assert!(resp.output.is_err());
        assert_eq!(c.metrics.snapshot().errors, 1);
        c.shutdown();
    }

    #[test]
    fn invalid_request_rejected() {
        let c = start_naive();
        let mut bad = rand_req(1, RequestKind::Stateless, 1, 4, 2);
        bad.q.pop();
        let resp = c.submit_blocking(bad);
        assert!(resp.output.unwrap_err().contains("invalid"));
        c.shutdown();
    }

    #[test]
    fn concurrent_decodes_batch_and_all_respond() {
        let c = start_naive();
        assert!(c
            .submit_blocking(rand_req(0, RequestKind::Prefill { session: 1 }, 1, 8, 3))
            .output
            .is_ok());
        // submit a burst of decodes from worker threads
        let c = std::sync::Arc::new(c);
        let mut handles = Vec::new();
        for i in 0..8u64 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                c2.submit_blocking(rand_req(100 + i, RequestKind::Decode { session: 1 }, 1, 1, 50 + i))
            }));
        }
        let mut ok = 0;
        for h in handles {
            let resp = h.join().unwrap();
            if resp.output.is_ok() {
                ok += 1;
            }
        }
        assert_eq!(ok, 8);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.responses, 9);
        assert!(snap.kv_appends >= 16);
        c.metrics.snapshot();
        std::sync::Arc::try_unwrap(c).ok().map(|c| c.shutdown());
    }

    #[test]
    fn oversize_problem_surfaces_router_error() {
        let c = start_naive();
        let resp = c.submit_blocking(rand_req(1, RequestKind::Stateless, 1, 300, 4));
        assert!(resp.output.is_err());
        c.shutdown();
    }
}
