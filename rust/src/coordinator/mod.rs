//! Layer-3 coordinator: an attention-serving runtime in the style of a
//! vLLM-class request router, shaped after the paper's unrolled hardware
//! (Figs. 1/3): a *block of query vectors* is served in parallel against a
//! streamed KV context.
//!
//! Components:
//! * [`request`]   — request/response types and shape signatures,
//! * [`kv_cache`]  — paged KV block pool: per-session block tables,
//!   copy-on-write prefix sharing, block-granular LRU eviction,
//! * [`router`]    — maps (variant, shape) to a compiled artifact + pad,
//! * [`batcher`]   — dynamic batching of decode requests into query blocks,
//! * [`scheduler`] — bounded two-class (prefill/decode) admission queue,
//! * [`metrics`]   — counters + latency histograms,
//! * [`server`]    — the engine thread that owns the PJRT [`crate::runtime::Runtime`]
//!   and drives the request loop (std threads + mpsc; tokio is not in the
//!   offline vendor set).
//!
//! Python never appears here: the engine executes AOT artifacts only.

pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use request::{AttentionRequest, AttentionResponse, RequestKind, ShapeSig, Variant};
pub use server::{Coordinator, CoordinatorConfig};
