//! Layer-3 coordinator: an attention-serving runtime in the style of a
//! vLLM-class request router, shaped after the paper's unrolled hardware
//! (Figs. 1/3): a *block of query vectors* is served in parallel against a
//! streamed KV context.
//!
//! Components:
//! * [`request`]   — request/response types, shape signatures, and the
//!   streaming-response events,
//! * [`kv_cache`]  — paged KV block pool: per-session block tables,
//!   copy-on-write prefix sharing, block-granular LRU eviction,
//! * [`router`]    — maps (variant, shape) to a compiled artifact + pad,
//! * [`batcher`]   — dynamic batching of decode requests into query blocks,
//! * [`scheduler`] — bounded two-class (prefill/decode) admission queue
//!   with seq-stamped FIFO ordering,
//! * [`worker`]    — the continuous-batching worker: token-budgeted
//!   admission into the running batch between kernel submissions, stream
//!   lifecycle management, backpressure,
//! * [`metrics`]   — counters + latency/TTFT/inter-token histograms,
//! * [`server`]    — the engine thread that owns the PJRT [`crate::runtime::Runtime`]
//!   and executes admitted cycles (std threads + mpsc; tokio is not in the
//!   offline vendor set).
//!
//! Python never appears here: the engine executes AOT artifacts only.
//!
//! # Attention-policy resolution
//!
//! Every session binds one [`AttnPolicy`] (sliding window, KV storage
//! precision, sigmoid mode, skip criterion) at creation and keeps it for
//! life. The policy is resolved in precedence order:
//!
//! 1. **Request** — a `Prefill`/`Fork` carrying `Some(policy)` wins
//!    outright (subject to validation: the KV precision must match the
//!    pool's storage precision, and sigmoid/skip must match the
//!    coordinator's kernel configuration — the window is the only axis
//!    honored per session today; conflicts are rejected as typed errors
//!    rather than silently ignored).
//! 2. **Fork inheritance** — a `Fork` with `None` inherits the source
//!    session's bound policy, window included, so a forked conversation
//!    keeps attending exactly like its parent.
//! 3. **Coordinator default** — otherwise
//!    [`CoordinatorConfig::default_policy`] applies: the coordinator's
//!    kernel knobs plus [`CoordinatorConfig::window`].
//!
//! Sessions with different windows never share a fused submission — the
//! dispatcher splits the fusion group, keeping fused and serial dispatch
//! bit-identical.

pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use request::{AttentionRequest, AttentionResponse, AttnPolicy, RequestKind, ShapeSig, StreamEvent, Variant};
pub use server::{ConfigError, Coordinator, CoordinatorConfig, CoordinatorConfigBuilder, StreamHandle};
