//! Layer-3 coordinator: an attention-serving runtime in the style of a
//! vLLM-class request router, shaped after the paper's unrolled hardware
//! (Figs. 1/3): a *block of query vectors* is served in parallel against a
//! streamed KV context.
//!
//! Components:
//! * [`request`]   — request/response types, shape signatures, and the
//!   streaming-response events,
//! * [`kv_cache`]  — paged KV block pool: per-session block tables,
//!   copy-on-write prefix sharing, block-granular LRU eviction,
//! * [`router`]    — maps (variant, shape) to a compiled artifact + pad,
//! * [`batcher`]   — dynamic batching of decode requests into query blocks,
//! * [`scheduler`] — bounded two-class (prefill/decode) admission queue
//!   with seq-stamped FIFO ordering,
//! * [`worker`]    — the continuous-batching worker: token-budgeted
//!   admission into the running batch between kernel submissions, stream
//!   lifecycle management, backpressure,
//! * [`metrics`]   — counters + latency/TTFT/inter-token histograms,
//! * [`server`]    — the engine thread that owns the PJRT [`crate::runtime::Runtime`]
//!   and executes admitted cycles (std threads + mpsc; tokio is not in the
//!   offline vendor set).
//!
//! Python never appears here: the engine executes AOT artifacts only.

pub mod batcher;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use request::{AttentionRequest, AttentionResponse, RequestKind, ShapeSig, StreamEvent, Variant};
pub use server::{Coordinator, CoordinatorConfig, StreamHandle};
