//! The continuous-batching worker: decouples *admission* from
//! *execution* so one long prefill can no longer head-of-line-block the
//! decodes queued behind it.
//!
//! The old engine loop drained the whole scheduler backlog — every
//! admitted request, across as many drain cycles as it took — before
//! looking at the request channel again, so arrivals during a long cycle
//! sat in the channel for the full backlog. [`BatchWorker`] inverts
//! that: each [`BatchWorker::step`] plans ONE budgeted cycle, executes it
//! as one fused submission (absent conflicts), and the loop pumps the
//! channel *between* steps, admitting new arrivals into the running
//! batch. The worker is a plain struct over the scheduler, the paged
//! session store, and the per-request reply routes, so tests drive
//! `handle_msg` + `step` directly — no threads, fully deterministic.
//!
//! # Cycle planning
//!
//! [`BatchWorker::step`] pulls requests in policy order, admitting while
//! three limits hold (see [`CoordinatorConfig`] for the knobs):
//!
//! 1. **width** — at most `drain_cycle` requests per cycle,
//! 2. **token budget** — the cycle's summed context cost stays within
//!    `max_batch_total_tokens` (a cycle always admits at least one
//!    request, so an over-budget problem still serves alone),
//! 3. **memory** — a request whose session mutations would LRU-evict
//!    live pool blocks (per the [`SessionStore`] predicates) ends the
//!    cycle instead of joining it; it leads the next cycle, where
//!    evicting is legitimate. This is admission-time shedding — the
//!    fused dispatcher's conflict flush stays as the execution-time
//!    backstop.
//!
//! Under `Policy::DecodeFirst`, a prefill/stateless request that has
//! waited `prefill_max_wait_cycles` admission cycles is promoted to the
//! front of the next cycle so a steady decode stream cannot starve it.
//!
//! # Streams
//!
//! A stream ([`Coordinator::submit_stream`](super::Coordinator::submit_stream))
//! is a request lifecycle the worker feeds itself: exactly one of the
//! stream's requests is in flight at a time, and when its cycle answers,
//! the worker forwards the [`StreamEvent::Token`], records
//! time-to-first-token / inter-token latency, and enqueues the stream's
//! next request — so per-session submission order is preserved by
//! construction. At most `max_concurrent_streams` are active; the rest
//! park in FIFO order (the semaphore-style concurrency limit).

use super::batcher::form_batches;
use super::kv_cache::SessionStore;
use super::metrics::Metrics;
use super::request::{AttentionRequest, AttentionResponse, RequestKind, StreamEvent};
use super::router::Router;
use super::scheduler::{Policy, Rejected, Scheduler};
use super::server::{
    publish_kv_metrics, serve_batch, serve_cycle_fused, AttnEngine, CoordinatorConfig, Pending,
};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

/// Engine-thread mailbox.
pub(crate) enum Msg {
    Request(AttentionRequest, Sender<AttentionResponse>),
    Stream(Vec<AttentionRequest>, Sender<StreamEvent>),
    Shutdown,
}

/// One active stream's state.
struct Stream {
    tx: Sender<StreamEvent>,
    pending: VecDeque<AttentionRequest>,
    /// Response receiver for the stream's in-flight request.
    inflight: Option<Receiver<AttentionResponse>>,
    opened: Instant,
    first_token: Option<Instant>,
    last_token: Option<Instant>,
    tokens: u64,
}

fn dur_us(from: Instant, to: Instant) -> u64 {
    to.saturating_duration_since(from).as_micros() as u64
}

fn reject_msg(rej: &Rejected) -> String {
    match rej {
        Rejected::QueueFull { depth, capacity } => format!("queue full ({depth}/{capacity})"),
        Rejected::Invalid(e) => format!("invalid request: {e}"),
    }
}

/// The admission/execution state machine. [`engine_loop`] owns one per
/// engine thread; unit tests drive it synchronously.
pub(crate) struct BatchWorker {
    cfg: CoordinatorConfig,
    router: Router,
    fused: bool,
    sched: Scheduler,
    sessions: SessionStore,
    /// Reply routes for requests currently queued in the scheduler.
    replies: HashMap<u64, Sender<AttentionResponse>>,
    streams: Vec<Stream>,
    /// Streams beyond the concurrency limit, with their open timestamps
    /// (TTFT is measured from open, so park time counts against it).
    parked: VecDeque<(Vec<AttentionRequest>, Sender<StreamEvent>, Instant)>,
    metrics: Arc<Metrics>,
    shutdown: bool,
}

impl BatchWorker {
    pub(crate) fn new(
        cfg: CoordinatorConfig,
        router: Router,
        fused: bool,
        metrics: Arc<Metrics>,
    ) -> BatchWorker {
        // Session KV lives in the paged block pool at the kernel config's
        // precision, one kernel tile of steps per block; f32 (the
        // default) keeps every downstream path bit-identical to the
        // unquantized coordinator.
        let sessions = SessionStore::with_block_steps(
            cfg.kv_budget_bytes,
            cfg.kernel.kv_precision,
            cfg.kernel.tile.max(1),
        );
        let mut sched = Scheduler::new(cfg.queue_capacity, cfg.policy);
        sched.drain_max = cfg.drain_cycle.max(1);
        BatchWorker {
            cfg,
            router,
            fused,
            sched,
            sessions,
            replies: HashMap::new(),
            streams: Vec::new(),
            parked: VecDeque::new(),
            metrics,
            shutdown: false,
        }
    }

    /// No queued work, no live streams: the loop may block on the channel.
    fn is_idle(&self) -> bool {
        self.sched.is_empty() && self.streams.is_empty() && self.parked.is_empty()
    }

    fn handle_msg(&mut self, msg: Msg) {
        match msg {
            Msg::Shutdown => self.shutdown = true,
            Msg::Request(req, reply) => self.enqueue(req, reply),
            Msg::Stream(reqs, tx) => self.open_stream(reqs, tx),
        }
    }

    /// Admit one request into the scheduler, or answer its rejection.
    /// Returns whether the request was admitted.
    fn submit_to_sched(&mut self, req: AttentionRequest, reply: Sender<AttentionResponse>) -> bool {
        let id = req.id;
        match self.sched.submit(req) {
            Ok(()) => {
                self.replies.insert(id, reply);
                self.metrics.queue_depth.store(self.sched.len() as u64, Ordering::Relaxed);
                true
            }
            Err(rej) => {
                if matches!(rej, Rejected::QueueFull { .. }) {
                    self.metrics.queue_rejections.fetch_add(1, Ordering::Relaxed);
                }
                self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(AttentionResponse {
                    id,
                    output: Err(reject_msg(&rej)),
                    latency_us: 0,
                    batch_size: 0,
                });
                false
            }
        }
    }

    fn enqueue(&mut self, req: AttentionRequest, reply: Sender<AttentionResponse>) {
        self.submit_to_sched(req, reply);
    }

    fn open_stream(&mut self, reqs: Vec<AttentionRequest>, tx: Sender<StreamEvent>) {
        self.metrics.streams_opened.fetch_add(1, Ordering::Relaxed);
        let opened = Instant::now();
        if self.streams.len() >= self.cfg.max_concurrent_streams.max(1) {
            self.metrics.streams_parked.fetch_add(1, Ordering::Relaxed);
            self.parked.push_back((reqs, tx, opened));
        } else {
            self.activate_stream(reqs, tx, opened);
        }
    }

    fn activate_stream(
        &mut self,
        reqs: Vec<AttentionRequest>,
        tx: Sender<StreamEvent>,
        opened: Instant,
    ) {
        if reqs.is_empty() {
            self.metrics.streams_completed.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(StreamEvent::Done { ttft_us: 0, total_us: 0, tokens: 0 });
            return;
        }
        self.streams.push(Stream {
            tx,
            pending: reqs.into(),
            inflight: None,
            opened,
            first_token: None,
            last_token: None,
            tokens: 0,
        });
        let i = self.streams.len() - 1;
        if self.submit_stream_next(i).is_err() {
            self.finish_stream(i);
        }
    }

    /// Enqueue stream `i`'s next request, restamping its admission time
    /// (queue wait for a stream request is measured from the moment the
    /// worker feeds it in, not from when the client packaged the stream).
    fn submit_stream_next(&mut self, i: usize) -> Result<(), ()> {
        let mut req = self.streams[i].pending.pop_front().expect("stream has a next request");
        req.submitted_at = Instant::now();
        let (tx, rx) = channel();
        if self.submit_to_sched(req, tx) {
            self.streams[i].inflight = Some(rx);
            Ok(())
        } else {
            // submit_to_sched already delivered the error response into
            // `tx`; forward it as the stream's terminal token
            if let Ok(resp) = rx.try_recv() {
                let _ = self.streams[i].tx.send(StreamEvent::Token(resp));
            }
            Err(())
        }
    }

    /// Terminate stream `i`: send `Done`, release its slot, and activate
    /// parked streams into the freed capacity.
    fn finish_stream(&mut self, i: usize) {
        let st = self.streams.swap_remove(i);
        let ttft_us = st.first_token.map_or(0, |t| dur_us(st.opened, t));
        let total_us = st.last_token.map_or(0, |t| dur_us(st.opened, t));
        self.metrics.streams_completed.fetch_add(1, Ordering::Relaxed);
        let _ = st.tx.send(StreamEvent::Done { ttft_us, total_us, tokens: st.tokens });
        while self.streams.len() < self.cfg.max_concurrent_streams.max(1) {
            match self.parked.pop_front() {
                Some((reqs, tx, opened)) => self.activate_stream(reqs, tx, opened),
                None => break,
            }
        }
    }

    /// Deliver one response to stream `i` and advance it. Returns whether
    /// the stream is still live at index `i`.
    fn deliver_token(&mut self, i: usize, resp: AttentionResponse) -> bool {
        let now = Instant::now();
        {
            let st = &mut self.streams[i];
            st.inflight = None;
            if st.tokens == 0 {
                st.first_token = Some(now);
                self.metrics.ttft.observe(dur_us(st.opened, now));
            } else if let Some(prev) = st.last_token {
                self.metrics.itl.observe(dur_us(prev, now));
            }
            st.last_token = Some(now);
            st.tokens += 1;
        }
        let failed = resp.output.is_err();
        let client_gone = self.streams[i].tx.send(StreamEvent::Token(resp)).is_err();
        if client_gone {
            // Client dropped its StreamHandle mid-generation: abort the
            // stream (its queued requests die with it) and free the slot.
            self.metrics.streams_abandoned.fetch_add(1, Ordering::Relaxed);
        }
        if failed || client_gone || self.streams[i].pending.is_empty() {
            self.finish_stream(i);
            return false;
        }
        if self.submit_stream_next(i).is_err() {
            self.finish_stream(i);
            return false;
        }
        true
    }

    /// Poll every live stream's in-flight response; deliver tokens and
    /// feed next requests. Runs after each cycle, so a stream's next
    /// request joins the *next* cycle — continuous admission.
    fn advance_streams(&mut self) {
        let mut i = 0;
        while i < self.streams.len() {
            let polled = match self.streams[i].inflight.as_ref() {
                Some(rx) => match rx.try_recv() {
                    Ok(resp) => Some(Ok(resp)),
                    Err(TryRecvError::Empty) => None,
                    // reply route dropped without an answer (engine-side
                    // anomaly): abort the stream rather than hang it
                    Err(TryRecvError::Disconnected) => Some(Err(())),
                },
                None => Some(Err(())),
            };
            match polled {
                None => i += 1,
                Some(Err(())) => self.finish_stream(i),
                Some(Ok(resp)) => {
                    if self.deliver_token(i, resp) {
                        i += 1;
                    }
                }
            }
        }
    }

    /// The cycle-budget cost of a request in KV tokens: the *attended*
    /// context length its query rows will stream after its own mutations
    /// land. Window-aware — a decode against a windowed session costs
    /// `min(live, window) + 1` no matter how long the session has run,
    /// which is what keeps a sliding-window stream's admission cost flat.
    fn request_tokens(&self, req: &AttentionRequest) -> usize {
        match req.kind {
            RequestKind::Stateless | RequestKind::Prefill { .. } => req.nkv,
            RequestKind::Decode { session } => {
                self.sessions.get(session).map_or(1, |t| t.attended() + 1)
            }
            RequestKind::Fork { src, .. } => {
                self.sessions.get(src).map_or(req.nkv, |t| t.attended() + req.nkv)
            }
        }
    }

    /// Would this request's session mutations LRU-evict live pool blocks?
    /// Mirrors the fused dispatcher's conflict predicate, applied at
    /// admission time.
    fn would_evict(&self, req: &AttentionRequest) -> bool {
        match req.kind {
            RequestKind::Stateless => false,
            RequestKind::Decode { session } => self.sessions.append_would_evict(session, 1),
            // an unknown signature can't create a session, so it can't
            // evict either
            RequestKind::Prefill { session, .. } => match self.router.max_kv(req.variant, req.sig) {
                Some(_) => self.sessions.prefill_would_evict(
                    session,
                    req.sig.heads,
                    req.sig.head_dim,
                    req.nkv,
                ),
                None => false,
            },
            RequestKind::Fork { src, session, .. } => {
                self.sessions.fork_would_evict(src, session, req.nkv)
            }
        }
    }

    /// Admission half of one serving cycle (see the module docs for the
    /// width/budget/memory limits and the starvation promotion).
    fn plan_cycle(&mut self) -> Vec<AttentionRequest> {
        self.sched.begin_cycle();
        let budget = self.cfg.max_batch_total_tokens.max(1);
        let max_reqs = self.cfg.drain_cycle.max(1);
        let mut cycle: Vec<AttentionRequest> = Vec::new();
        let mut tokens = 0usize;

        if self.cfg.policy == Policy::DecodeFirst
            && self.sched.oldest_other_wait() >= self.cfg.prefill_max_wait_cycles.max(1) as u64
        {
            if let Some(req) = self.sched.pop_other() {
                tokens += self.request_tokens(&req);
                self.metrics.queue_wait.observe(req.submitted_at.elapsed().as_micros() as u64);
                cycle.push(req);
            }
        }

        while cycle.len() < max_reqs {
            let Some(next) = self.sched.peek_next() else { break };
            if !cycle.is_empty() {
                if tokens + self.request_tokens(next) > budget {
                    break;
                }
                if self.would_evict(next) {
                    self.metrics.admission_deferrals.fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
            let req = self.sched.pop_next().expect("peeked request");
            self.metrics.queue_wait.observe(req.submitted_at.elapsed().as_micros() as u64);
            tokens += self.request_tokens(&req);
            cycle.push(req);
        }
        self.metrics.queue_depth.store(self.sched.len() as u64, Ordering::Relaxed);
        cycle
    }

    /// Execution half: batch the cycle and run it through the fused (or
    /// serial) dispatch path.
    fn run_cycle<E: AttnEngine>(&mut self, engine: &E, cycle: Vec<AttentionRequest>) {
        if cycle.is_empty() {
            return;
        }
        let batches = form_batches(&cycle, &self.cfg.batch);
        let mut pend: Vec<Option<Pending>> = cycle
            .into_iter()
            .map(|req| {
                let reply = self.replies.remove(&req.id)?;
                Some(Pending { req, reply })
            })
            .collect();
        let default = self.cfg.default_policy();
        if self.fused {
            serve_cycle_fused(engine, &self.router, &mut self.sessions, &batches, &mut pend, &default, &self.metrics);
        } else {
            for batch in &batches {
                serve_batch(engine, &self.router, &mut self.sessions, batch, &mut pend, &default, &self.metrics);
            }
        }
        publish_kv_metrics(&self.sessions, &self.metrics);
        if self.cfg.validate_invariants {
            self.sessions.check_invariants().expect("kv store invariants violated");
        }
    }

    /// One admission+execution round. Returns whether any request was
    /// served.
    pub(crate) fn step<E: AttnEngine>(&mut self, engine: &E) -> bool {
        let cycle = self.plan_cycle();
        let worked = !cycle.is_empty();
        self.run_cycle(engine, cycle);
        self.advance_streams();
        worked
    }
}

/// The persistent engine-thread loop: pump the mailbox (blocking with the
/// batch window only when idle, non-blocking between kernel submissions),
/// then serve one cycle. On shutdown or channel disconnect, finish
/// serving everything pending — queued requests and open streams — before
/// exiting.
pub(crate) fn engine_loop<E: AttnEngine>(
    engine: E,
    rx: Receiver<Msg>,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
) {
    let fused = cfg.fused && engine.supports_fused();
    let router = engine.router();
    let batch_window = cfg.batch_window;
    let mut w = BatchWorker::new(cfg, router, fused, metrics);
    let mut disconnected = false;
    loop {
        if w.is_idle() && !w.shutdown && !disconnected {
            // Idle: block for the next arrival, then hold the batch
            // window open so near-simultaneous arrivals share a cycle.
            match rx.recv() {
                Ok(m) => {
                    w.handle_msg(m);
                    let deadline = Instant::now() + batch_window;
                    loop {
                        match rx.try_recv() {
                            Ok(m) => w.handle_msg(m),
                            Err(TryRecvError::Empty) => {
                                if Instant::now() >= deadline {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                            Err(TryRecvError::Disconnected) => {
                                disconnected = true;
                                break;
                            }
                        }
                    }
                }
                Err(_) => disconnected = true,
            }
        } else {
            // Busy: admit whatever has already arrived, without waiting —
            // new requests join the running batch between submissions.
            loop {
                match rx.try_recv() {
                    Ok(m) => w.handle_msg(m),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }
        let worked = w.step(&engine);
        if (w.shutdown || disconnected) && w.is_idle() {
            break;
        }
        if !worked {
            std::thread::yield_now();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{AttnPolicy, ShapeSig, Variant};
    use crate::coordinator::server::NaiveEngine;
    use crate::kernels::batch::KernelConfig;
    use crate::runtime::Manifest;

    fn test_router() -> Router {
        Router::from_manifest(
            &Manifest::parse(
                r#"{"artifacts": {
              "a128": {"file":"x","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":128,"head_dim":8,"inputs":[],"n_outputs":1},
              "a256": {"file":"y","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":256,"head_dim":8,"inputs":[],"n_outputs":1}
            }}"#,
            )
            .unwrap(),
        )
    }

    fn rand_req(id: u64, kind: RequestKind, nq: usize, nkv: usize, seed: u64) -> AttentionRequest {
        let mut rng = crate::util::rng::Rng::new(seed);
        let sig = ShapeSig { heads: 2, head_dim: 8 };
        AttentionRequest {
            id,
            kind,
            variant: Variant::FlashD,
            sig,
            q: rng.normal_vec(2 * 8 * nq, 1.0),
            nq,
            k: rng.normal_vec(2 * 8 * nkv, 1.0),
            v: rng.normal_vec(2 * 8 * nkv, 1.0),
            nkv,
            submitted_at: Instant::now(),
        }
    }

    fn mk_worker(cfg: CoordinatorConfig) -> (BatchWorker, NaiveEngine) {
        let router = test_router();
        let engine = NaiveEngine::with_kernel(router.clone(), cfg.kernel);
        let fused = cfg.fused && engine.supports_fused();
        let w = BatchWorker::new(cfg, router, fused, Arc::new(Metrics::new()));
        (w, engine)
    }

    /// Enqueue a one-shot request, returning its private response channel.
    fn push(w: &mut BatchWorker, req: AttentionRequest) -> Receiver<AttentionResponse> {
        let (tx, rx) = channel();
        w.handle_msg(Msg::Request(req, tx));
        rx
    }

    /// The acceptance scenario: decodes admitted *behind* a long prefill
    /// complete before the prefill finishes. Deterministic — the worker
    /// is stepped by hand, no threads, no timing.
    #[test]
    fn decodes_behind_long_prefill_complete_first() {
        let cfg = CoordinatorConfig {
            policy: Policy::DecodeFirst,
            max_batch_total_tokens: 16,
            validate_invariants: true,
            ..CoordinatorConfig::default()
        };
        let (mut w, engine) = mk_worker(cfg);

        // seed session 5 with a short prefill
        let seed = push(&mut w, rand_req(1, RequestKind::prefill(5), 1, 4, 1));
        assert!(w.step(&engine));
        assert!(seed.recv().unwrap().output.is_ok());

        // long prefill arrives FIRST, two decodes queue behind it
        let long = push(&mut w, rand_req(2, RequestKind::prefill(6), 1, 40, 2));
        let d1 = push(&mut w, rand_req(3, RequestKind::Decode { session: 5 }, 1, 1, 3));
        let d2 = push(&mut w, rand_req(4, RequestKind::Decode { session: 5 }, 1, 1, 4));

        // cycle 1: decode-first policy + the 16-token budget admit only
        // the decodes (cost 5 + 6; the 40-token prefill would blow it)
        assert!(w.step(&engine));
        assert!(d1.try_recv().expect("decode 1 served in cycle 1").output.is_ok());
        assert!(d2.try_recv().expect("decode 2 served in cycle 1").output.is_ok());
        assert!(long.try_recv().is_err(), "prefill must not have finished yet");

        // cycle 2 serves the prefill
        assert!(w.step(&engine));
        assert!(long.recv().unwrap().output.is_ok());
        assert!(w.is_idle());
    }

    /// Continuous admission: a decode arriving while a prefill backlog is
    /// mid-drain is served on the very next cycle, ahead of the remaining
    /// backlog.
    #[test]
    fn late_decode_overtakes_prefill_backlog() {
        let cfg = CoordinatorConfig {
            policy: Policy::DecodeFirst,
            max_batch_total_tokens: 16,
            ..CoordinatorConfig::default()
        };
        let (mut w, engine) = mk_worker(cfg);
        let p1 = push(&mut w, rand_req(1, RequestKind::prefill(11), 1, 40, 1));
        let p2 = push(&mut w, rand_req(2, RequestKind::prefill(12), 1, 40, 2));
        let p3 = push(&mut w, rand_req(3, RequestKind::prefill(13), 1, 40, 3));

        // the budget forces one prefill per cycle
        assert!(w.step(&engine));
        assert!(p1.try_recv().is_ok());
        assert!(p2.try_recv().is_err() && p3.try_recv().is_err());

        // decode arrives mid-backlog; next cycle serves it alone (its
        // 41-token cost + 40 for the next prefill exceed the budget)
        let d = push(&mut w, rand_req(4, RequestKind::Decode { session: 11 }, 1, 1, 4));
        assert!(w.step(&engine));
        assert!(d.try_recv().expect("decode overtakes backlog").output.is_ok());
        assert!(p2.try_recv().is_err() && p3.try_recv().is_err());

        assert!(w.step(&engine));
        assert!(p2.try_recv().is_ok());
        assert!(w.step(&engine));
        assert!(p3.try_recv().is_ok());
        assert!(w.is_idle());
    }

    /// Fifo keeps strict arrival order even when the budget splits cycles.
    #[test]
    fn fifo_budget_splits_cycles_in_order() {
        let cfg = CoordinatorConfig {
            policy: Policy::Fifo,
            max_batch_total_tokens: 16,
            ..CoordinatorConfig::default()
        };
        let (mut w, engine) = mk_worker(cfg);
        let seed = push(&mut w, rand_req(1, RequestKind::prefill(5), 1, 4, 1));
        assert!(w.step(&engine));
        assert!(seed.recv().unwrap().output.is_ok());

        let long = push(&mut w, rand_req(2, RequestKind::prefill(6), 1, 40, 2));
        let d = push(&mut w, rand_req(3, RequestKind::Decode { session: 5 }, 1, 1, 3));
        // Fifo: the earlier prefill serves first (alone — over budget);
        // the decode waits its turn
        assert!(w.step(&engine));
        assert!(long.try_recv().is_ok());
        assert!(d.try_recv().is_err());
        assert!(w.step(&engine));
        assert!(d.try_recv().expect("decode in cycle 2").output.is_ok());
    }

    /// DecodeFirst starvation guard: a prefill stuck behind a steady
    /// decode stream is promoted after `prefill_max_wait_cycles`.
    #[test]
    fn waiting_prefill_promoted_after_wait_cycles() {
        let cfg = CoordinatorConfig {
            policy: Policy::DecodeFirst,
            max_batch_total_tokens: 16,
            prefill_max_wait_cycles: 2,
            ..CoordinatorConfig::default()
        };
        let (mut w, engine) = mk_worker(cfg);
        let seed = push(&mut w, rand_req(1, RequestKind::prefill(31), 1, 4, 1));
        assert!(w.step(&engine));
        assert!(seed.recv().unwrap().output.is_ok());

        let p = push(&mut w, rand_req(2, RequestKind::prefill(32), 1, 40, 2));
        // cycle 1: wait=1 < 2 — the decode wins, the prefill's 40 tokens
        // don't fit behind it
        let d1 = push(&mut w, rand_req(3, RequestKind::Decode { session: 31 }, 1, 1, 3));
        assert!(w.step(&engine));
        assert!(d1.try_recv().is_ok());
        assert!(p.try_recv().is_err());
        // cycle 2: wait=2 — promoted ahead of the fresh decode
        let d2 = push(&mut w, rand_req(4, RequestKind::Decode { session: 31 }, 1, 1, 4));
        assert!(w.step(&engine));
        assert!(p.try_recv().expect("promoted prefill").output.is_ok());
        assert!(d2.try_recv().is_err());
        assert!(w.step(&engine));
        assert!(d2.try_recv().is_ok());
    }

    /// Admission-time shedding: a prefill whose append would evict live
    /// pool blocks is deferred out of a non-empty cycle and leads the
    /// next one (where evicting is legitimate).
    #[test]
    fn evicting_prefill_deferred_to_next_cycle() {
        let cfg = CoordinatorConfig {
            policy: Policy::Fifo,
            // room for exactly two 32-step blocks of 2 heads x 8 dims
            kv_budget_bytes: 2 * 2 * 2 * 32 * 8 * 4,
            kernel: KernelConfig { tile: 32, ..KernelConfig::default() },
            validate_invariants: true,
            ..CoordinatorConfig::default()
        };
        let (mut w, engine) = mk_worker(cfg);
        // fill the pool: 33 steps -> both blocks resident
        let seed = push(&mut w, rand_req(1, RequestKind::prefill(41), 1, 33, 1));
        assert!(w.step(&engine));
        assert!(seed.recv().unwrap().output.is_ok());

        // decode fits its partial tail block; the new session's prefill
        // needs a fresh block the pool can't hold
        let d = push(&mut w, rand_req(2, RequestKind::Decode { session: 41 }, 1, 1, 2));
        let p = push(&mut w, rand_req(3, RequestKind::prefill(42), 1, 8, 3));
        assert!(w.step(&engine));
        assert!(d.try_recv().is_ok());
        assert!(p.try_recv().is_err(), "evicting prefill must defer");
        assert_eq!(w.metrics.snapshot().admission_deferrals, 1);
        assert!(w.sessions.contains(41));

        assert!(w.step(&engine));
        assert!(p.try_recv().expect("deferred prefill served next cycle").output.is_ok());
        assert!(!w.sessions.contains(41), "deferred prefill legitimately evicted");
        assert!(w.sessions.contains(42));
    }

    /// Stream lifecycle: tokens arrive in submission order, one per
    /// cycle, with TTFT/ITL recorded and a terminal Done summary.
    #[test]
    fn stream_yields_per_cycle_tokens_in_order() {
        let cfg = CoordinatorConfig { validate_invariants: true, ..CoordinatorConfig::default() };
        let (mut w, engine) = mk_worker(cfg);
        let reqs = vec![
            rand_req(10, RequestKind::prefill(21), 1, 4, 10),
            rand_req(11, RequestKind::Decode { session: 21 }, 1, 1, 11),
            rand_req(12, RequestKind::Decode { session: 21 }, 1, 1, 12),
            rand_req(13, RequestKind::Decode { session: 21 }, 1, 1, 13),
        ];
        let (tx, rx) = channel();
        w.handle_msg(Msg::Stream(reqs, tx));
        let mut got = Vec::new();
        for _ in 0..4 {
            assert!(w.step(&engine), "one stream request per cycle");
            match rx.try_recv().expect("token after its cycle") {
                StreamEvent::Token(resp) => {
                    assert!(resp.output.is_ok());
                    got.push(resp.id);
                }
                other => panic!("expected token, got {other:?}"),
            }
        }
        assert_eq!(got, vec![10, 11, 12, 13]);
        match rx.try_recv().expect("terminal event") {
            StreamEvent::Done { tokens, ttft_us, total_us } => {
                assert_eq!(tokens, 4);
                assert!(total_us >= ttft_us);
            }
            other => panic!("expected done, got {other:?}"),
        }
        assert!(w.is_idle());
        let snap = w.metrics.snapshot();
        assert_eq!(snap.ttft.count, 1);
        assert_eq!(snap.itl.count, 3);
        assert_eq!(snap.streams_completed, 1);
        assert_eq!(snap.errors, 0);
    }

    /// Concurrency-limit backpressure: streams beyond the limit park and
    /// activate in FIFO order as slots free.
    #[test]
    fn streams_park_beyond_concurrency_limit() {
        let cfg = CoordinatorConfig { max_concurrent_streams: 1, ..CoordinatorConfig::default() };
        let (mut w, engine) = mk_worker(cfg);
        let a_reqs = vec![
            rand_req(1, RequestKind::prefill(1), 1, 4, 1),
            rand_req(2, RequestKind::Decode { session: 1 }, 1, 1, 2),
        ];
        let b_reqs = vec![rand_req(3, RequestKind::prefill(2), 1, 4, 3)];
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        w.handle_msg(Msg::Stream(a_reqs, atx));
        w.handle_msg(Msg::Stream(b_reqs, btx));
        assert_eq!(w.metrics.snapshot().streams_parked, 1);
        assert!(brx.try_recv().is_err(), "parked stream must not start");

        assert!(w.step(&engine)); // A token 1
        assert!(w.step(&engine)); // A token 2 -> A done -> B activated
        assert!(matches!(arx.try_recv(), Ok(StreamEvent::Token(_))));
        assert!(matches!(arx.try_recv(), Ok(StreamEvent::Token(_))));
        assert!(matches!(arx.try_recv(), Ok(StreamEvent::Done { .. })));
        assert!(w.step(&engine)); // B's request
        assert!(matches!(brx.try_recv(), Ok(StreamEvent::Token(_))));
        assert!(matches!(brx.try_recv(), Ok(StreamEvent::Done { .. })));
        assert_eq!(w.metrics.snapshot().streams_completed, 2);
        assert!(w.is_idle());
    }

    /// An error response aborts the stream: the error token is forwarded,
    /// queued stream requests are dropped, Done reports the short count.
    #[test]
    fn stream_aborts_on_error_token() {
        let (mut w, engine) = mk_worker(CoordinatorConfig::default());
        let reqs = vec![
            rand_req(1, RequestKind::Decode { session: 99 }, 1, 1, 1), // unknown session
            rand_req(2, RequestKind::Stateless, 1, 4, 2),
        ];
        let (tx, rx) = channel();
        w.handle_msg(Msg::Stream(reqs, tx));
        assert!(w.step(&engine));
        match rx.try_recv().expect("error token") {
            StreamEvent::Token(resp) => assert!(resp.output.is_err()),
            other => panic!("expected token, got {other:?}"),
        }
        match rx.try_recv().expect("terminal event") {
            StreamEvent::Done { tokens, .. } => assert_eq!(tokens, 1),
            other => panic!("expected done, got {other:?}"),
        }
        assert!(w.is_idle(), "aborted stream must release its slot and queue");
    }

    /// A client that drops its `StreamHandle` mid-generation is detected
    /// on the next token delivery: the stream aborts, its queued requests
    /// are dropped, the slot frees for parked streams, and the
    /// abandonment is counted.
    #[test]
    fn abandoned_stream_frees_slot_and_counts() {
        let cfg = CoordinatorConfig { max_concurrent_streams: 1, ..CoordinatorConfig::default() };
        let (mut w, engine) = mk_worker(cfg);
        let a_reqs = vec![
            rand_req(1, RequestKind::prefill(1), 1, 4, 1),
            rand_req(2, RequestKind::Decode { session: 1 }, 1, 1, 2),
            rand_req(3, RequestKind::Decode { session: 1 }, 1, 1, 3),
            rand_req(4, RequestKind::Decode { session: 1 }, 1, 1, 4),
        ];
        let b_reqs = vec![rand_req(5, RequestKind::prefill(2), 1, 4, 5)];
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        w.handle_msg(Msg::Stream(a_reqs, atx));
        w.handle_msg(Msg::Stream(b_reqs, btx));
        assert_eq!(w.metrics.snapshot().streams_parked, 1);

        assert!(w.step(&engine)); // A's first token
        assert!(matches!(arx.try_recv(), Ok(StreamEvent::Token(_))));
        drop(arx); // client walks away mid-generation

        // the next delivery hits the dropped receiver: A aborts (ids 3-4
        // never run), the freed slot activates B
        assert!(w.step(&engine));
        assert!(w.step(&engine)); // B's request
        assert!(matches!(brx.try_recv(), Ok(StreamEvent::Token(_))));
        assert!(matches!(brx.try_recv(), Ok(StreamEvent::Done { .. })));
        let snap = w.metrics.snapshot();
        assert_eq!(snap.streams_abandoned, 1);
        assert_eq!(snap.streams_completed, 2, "abandoned streams still terminate");
        assert_eq!(snap.errors, 0);
        assert!(w.is_idle(), "abandoned stream must free its slot and queue");
    }

    /// Queue-full rejections carry depth/capacity in the error message.
    #[test]
    fn queue_full_rejection_reports_depth() {
        let cfg = CoordinatorConfig { queue_capacity: 1, ..CoordinatorConfig::default() };
        let (mut w, _engine) = mk_worker(cfg);
        let _r1 = push(&mut w, rand_req(1, RequestKind::Stateless, 1, 4, 1));
        let r2 = push(&mut w, rand_req(2, RequestKind::Stateless, 1, 4, 2));
        let err = r2.try_recv().expect("immediate rejection").output.unwrap_err();
        assert!(err.contains("queue full (1/1)"), "got: {err}");
        let snap = w.metrics.snapshot();
        assert_eq!(snap.queue_rejections, 1);
        assert_eq!(snap.errors, 1);
    }

    /// Window-aware admission: a decode against a windowed session is
    /// budgeted at `min(live, window) + 1` tokens, not the full history.
    #[test]
    fn windowed_decode_admission_cost_uses_window() {
        let cfg = CoordinatorConfig {
            policy: Policy::Fifo,
            max_batch_total_tokens: 20,
            validate_invariants: true,
            ..CoordinatorConfig::default()
        };
        let (mut w, engine) = mk_worker(cfg);

        // default kernel -> 32-step blocks; a 40-step prefill with an
        // 8-step window retains one trimmed-off block's worth of slop
        let policy = AttnPolicy::from_kernel(&KernelConfig::default()).with_window(8);
        let kind = RequestKind::Prefill { session: 51, policy: Some(policy) };
        let seed = push(&mut w, rand_req(1, kind, 1, 40, 1));
        assert!(w.step(&engine));
        assert!(seed.recv().unwrap().output.is_ok());

        // each decode costs min(live, 8) + 1 = 9 tokens: both fit the
        // 20-token budget in one cycle; unwindowed they'd cost 41 each
        let d1 = push(&mut w, rand_req(2, RequestKind::Decode { session: 51 }, 1, 1, 2));
        let d2 = push(&mut w, rand_req(3, RequestKind::Decode { session: 51 }, 1, 1, 3));
        assert!(w.step(&engine));
        assert!(d1.try_recv().expect("decode 1 in cycle 1").output.is_ok());
        assert!(d2.try_recv().expect("decode 2 in cycle 1").output.is_ok());
        assert!(w.metrics.snapshot().kv_window_trims >= 1);
    }
}
