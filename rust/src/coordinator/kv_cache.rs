//! Paged KV cache: a block-pooled store with per-session block tables,
//! refcounted copy-on-write prefix sharing, and block-granular LRU
//! eviction — the state the decode path reads instead of re-shipping the
//! whole context on every token.
//!
//! # Why paged equals contiguous, bit for bit
//!
//! Storage is a [`BlockPool`] of fixed-size blocks, each holding
//! `block_steps` KV steps for every head of one session, laid out
//! `[head][step][dim]` flat inside the block. A session is a
//! [`BlockTable`]: an ordered list of pool slots whose concatenated
//! per-head fragments form exactly the same element sequence the old
//! contiguous cache held. The kernels never index KV storage directly —
//! they consume it through [`KvView::load_into`] element ranges (the tile
//! loop), and the paged view ([`crate::numerics::quant::PagedKv`]) splits
//! each requested range across block fragments, dequantizing the *same
//! stored values in the same order* as a contiguous buffer would. Kernel
//! tiles start at key index 1 (step 0 seeds the recursion), so tiles are
//! deliberately *not* aligned to pool blocks; correctness rests purely on
//! the range-splitting contract, which is why per-tile output is
//! bit-identical to the contiguous path by construction, at every
//! [`KvPrecision`].
//!
//! # Sharing and eviction
//!
//! Blocks are refcounted. [`SessionStore::fork`] shares *all* of a
//! session's blocks (including a partially filled tail) at zero copy
//! cost; the first divergent append to a shared tail triggers a
//! copy-on-write clone of just that block. Full blocks are never mutated
//! after they fill, so a shared prefix is stored once no matter how many
//! sessions hang off it. Eviction picks a victim session via an O(1) LRU
//! index but reclaims at *block* granularity: only blocks whose refcount
//! drops to zero free bytes, so evicting one fork never tears the shared
//! prefix out from under its siblings.
//!
//! # Sliding windows and block-granular trimming
//!
//! A session created with an attention window `w` keeps `len` counting
//! every step ever appended (absolute positions never shift), but only
//! the most recent `min(len, w)` steps are *attended*. Appends eagerly
//! drop leading blocks that lie fully outside the window
//! ([`BlockTable::start`] advances in whole blocks); the sub-block
//! remainder ("slop", `< block_steps` steps) stays resident and is hidden
//! from the kernels by the gathered view's element offset
//! ([`crate::numerics::quant::PagedKv::start`]), so a windowed kernel run
//! streams exactly the attended suffix — bit-identical to a full kernel
//! over only those steps, with no rescaling fix-up (the FLASH-D recursion
//! is a pure function of the KV it is fed).
//!
//! **Window-trim → block-refcount contract:** trimming *dereferences*
//! out-of-window blocks, it never frees them directly. Bytes are
//! reclaimed only when a block's refcount hits zero, so a trimmed lineage
//! can never free a prefix block a sibling fork or `share_prefix` child
//! still references — `blocks_trimmed` counts blocks actually freed,
//! `window_trims` counts trim events. Trimming runs *before* the eviction
//! loop on every append (trim-before-evict): a session's own dead prefix
//! is reclaimed before any other session is considered as an eviction
//! victim, and the `*_would_evict` predicates mirror that order exactly.
//!
//! # Quantization
//!
//! Quantization is unchanged from the contiguous design: each block's K
//! and V live in a [`KvStore`] (f32 / bf16 / fp8 at rest), quantized once
//! on append, dequantized tile-by-tile through [`KvRef`].

use std::collections::HashMap;

use crate::numerics::bf16::Bf16;
use crate::numerics::fp8::Fp8E4M3;
use crate::numerics::quant::{KvPrecision, KvRef, KvView, PagedKv};

/// Backing storage for one K or V tensor at a chosen [`KvPrecision`].
/// The f32 variant reads back bit-exactly; the quantized variants are a
/// round-to-nearest-even projection applied once at append time (so the
/// kernel output over a quantized store equals the f32 kernel run over
/// the dequantized array, bit for bit).
#[derive(Clone, Debug)]
pub enum KvStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    Fp8(Vec<u8>),
}

impl KvStore {
    /// An all-zero store of `n` elements (zero encodes exactly in every
    /// supported format, so padding stays deterministic).
    pub fn zeros(prec: KvPrecision, n: usize) -> KvStore {
        match prec {
            KvPrecision::F32 => KvStore::F32(vec![0.0; n]),
            KvPrecision::Bf16 => KvStore::Bf16(vec![0u16; n]),
            KvPrecision::Fp8 => KvStore::Fp8(vec![0u8; n]),
        }
    }

    pub fn precision(&self) -> KvPrecision {
        match self {
            KvStore::F32(_) => KvPrecision::F32,
            KvStore::Bf16(_) => KvPrecision::Bf16,
            KvStore::Fp8(_) => KvPrecision::Fp8,
        }
    }

    /// Element count (not bytes).
    pub fn len(&self) -> usize {
        match self {
            KvStore::F32(b) => b.len(),
            KvStore::Bf16(b) => b.len(),
            KvStore::Fp8(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the backing buffer.
    pub fn bytes(&self) -> usize {
        self.len() * self.precision().bytes_per_elem()
    }

    /// Borrow the storage as the kernel-facing [`KvRef`].
    pub fn as_kv(&self) -> KvRef<'_> {
        match self {
            KvStore::F32(b) => KvRef::F32(b),
            KvStore::Bf16(b) => KvRef::Bf16(b),
            KvStore::Fp8(b) => KvRef::Fp8(b),
        }
    }

    /// Quantize-and-write `src` at element offset `at` (the single
    /// rounding point of the storage path).
    pub fn store(&mut self, at: usize, src: &[f32]) {
        match self {
            KvStore::F32(b) => b[at..at + src.len()].copy_from_slice(src),
            KvStore::Bf16(b) => {
                for (dst, &x) in b[at..at + src.len()].iter_mut().zip(src) {
                    *dst = Bf16::from_f32(x).to_bits();
                }
            }
            KvStore::Fp8(b) => {
                for (dst, &x) in b[at..at + src.len()].iter_mut().zip(src) {
                    *dst = Fp8E4M3::from_f32(x).to_bits();
                }
            }
        }
    }

    /// Quantize-and-append `src` at the end of the buffer.
    pub fn extend_from_f32(&mut self, src: &[f32]) {
        match self {
            KvStore::F32(b) => b.extend_from_slice(src),
            KvStore::Bf16(b) => b.extend(src.iter().map(|&x| Bf16::from_f32(x).to_bits())),
            KvStore::Fp8(b) => b.extend(src.iter().map(|&x| Fp8E4M3::from_f32(x).to_bits())),
        }
    }

    /// Dequantize the whole buffer (test/debug convenience; the hot paths
    /// dequantize tile-by-tile through [`KvRef`] instead).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.as_kv().to_f32_vec()
    }
}

// ---------------------------------------------------------------------------
// O(1) LRU index
// ---------------------------------------------------------------------------

const NIL: usize = usize::MAX;

#[derive(Debug)]
struct LruNode {
    id: u64,
    prev: usize,
    next: usize,
}

/// Intrusive doubly-linked LRU over a slab, with a hash index for O(1)
/// `touch`/`remove` (the old store paid an O(n) `Vec` scan + shift on
/// every access). Front = least recently used, back = most recent.
#[derive(Debug)]
pub struct LruIndex {
    nodes: Vec<LruNode>,
    map: HashMap<u64, usize>,
    head: usize,
    tail: usize,
    free: Vec<usize>,
}

impl LruIndex {
    pub fn new() -> LruIndex {
        LruIndex { nodes: Vec::new(), map: HashMap::new(), head: NIL, tail: NIL, free: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.map.contains_key(&id)
    }

    /// Least recently used id, if any.
    pub fn front(&self) -> Option<u64> {
        (self.head != NIL).then(|| self.nodes[self.head].id)
    }

    /// Least recently used id that is not `skip` — the eviction victim
    /// query: the session being served must never evict itself.
    pub fn front_excluding(&self, skip: u64) -> Option<u64> {
        let mut idx = self.head;
        while idx != NIL {
            if self.nodes[idx].id != skip {
                return Some(self.nodes[idx].id);
            }
            idx = self.nodes[idx].next;
        }
        None
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.nodes[idx].prev, self.nodes[idx].next);
        if prev != NIL {
            self.nodes[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next].prev = prev;
        } else {
            self.tail = prev;
        }
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = NIL;
    }

    fn push_back(&mut self, idx: usize) {
        self.nodes[idx].prev = self.tail;
        self.nodes[idx].next = NIL;
        if self.tail != NIL {
            self.nodes[self.tail].next = idx;
        } else {
            self.head = idx;
        }
        self.tail = idx;
    }

    /// Mark `id` most recently used, inserting it if absent. O(1).
    pub fn touch(&mut self, id: u64) {
        if let Some(&idx) = self.map.get(&id) {
            self.unlink(idx);
            self.push_back(idx);
            return;
        }
        let idx = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot] = LruNode { id, prev: NIL, next: NIL };
                slot
            }
            None => {
                self.nodes.push(LruNode { id, prev: NIL, next: NIL });
                self.nodes.len() - 1
            }
        };
        self.map.insert(id, idx);
        self.push_back(idx);
    }

    /// Drop `id` from the order (no-op if absent). O(1).
    pub fn remove(&mut self, id: u64) {
        if let Some(idx) = self.map.remove(&id) {
            self.unlink(idx);
            self.free.push(idx);
        }
    }

    /// Full LRU→MRU order — O(n), for tests and invariant checks only.
    pub fn order(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut idx = self.head;
        while idx != NIL {
            out.push(self.nodes[idx].id);
            idx = self.nodes[idx].next;
        }
        out
    }
}

impl Default for LruIndex {
    fn default() -> Self {
        LruIndex::new()
    }
}

// ---------------------------------------------------------------------------
// Block pool
// ---------------------------------------------------------------------------

/// One pool block: `block_steps` KV steps for all heads of one session,
/// `[head][step][dim]` flat in each of `k`/`v`. `len` counts the filled
/// steps; `refs` counts the block tables pointing at this slot. A block
/// with `refs > 1` is immutable (appends copy-on-write it first), so a
/// shared fragment can never be corrupted through a sibling session.
#[derive(Debug)]
struct Block {
    heads: usize,
    head_dim: usize,
    len: usize,
    refs: u32,
    k: KvStore,
    v: KvStore,
}

/// Fixed-budget slab of KV blocks. Byte accounting is full-capacity per
/// block (allocation-sized, not fill-sized), so the budget check is a
/// simple block count and a partially filled tail costs what it reserves.
#[derive(Debug)]
pub struct BlockPool {
    pub precision: KvPrecision,
    /// KV steps per block (one kernel tile by default).
    pub block_steps: usize,
    slots: Vec<Option<Block>>,
    free: Vec<usize>,
    pub max_bytes: usize,
    pub bytes: usize,
    pub peak_bytes: usize,
    pub allocated: u64,
    pub freed: u64,
}

impl BlockPool {
    pub fn new(max_bytes: usize, precision: KvPrecision, block_steps: usize) -> BlockPool {
        assert!(block_steps >= 1, "block_steps must be >= 1");
        BlockPool {
            precision,
            block_steps,
            slots: Vec::new(),
            free: Vec::new(),
            max_bytes,
            bytes: 0,
            peak_bytes: 0,
            allocated: 0,
            freed: 0,
        }
    }

    /// Resident bytes of one block of this geometry (K and V tensors at
    /// full `block_steps` capacity).
    pub fn block_bytes(&self, heads: usize, head_dim: usize) -> usize {
        2 * heads * self.block_steps * head_dim * self.precision.bytes_per_elem()
    }

    pub fn live_blocks(&self) -> usize {
        (self.allocated - self.freed) as usize
    }

    /// Allocate an empty block (refs = 1). Fails — without allocating —
    /// if the budget would be exceeded; the caller evicts first.
    fn alloc(&mut self, heads: usize, head_dim: usize) -> Result<usize, String> {
        let bb = self.block_bytes(heads, head_dim);
        if self.bytes + bb > self.max_bytes {
            return Err(format!("block pool over budget: {} + {bb} > {}", self.bytes, self.max_bytes));
        }
        let elems = heads * self.block_steps * head_dim;
        let block = Block {
            heads,
            head_dim,
            len: 0,
            refs: 1,
            k: KvStore::zeros(self.precision, elems),
            v: KvStore::zeros(self.precision, elems),
        };
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(block);
                s
            }
            None => {
                self.slots.push(Some(block));
                self.slots.len() - 1
            }
        };
        self.bytes += bb;
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        self.allocated += 1;
        Ok(slot)
    }

    /// Bit-exact copy of a block's first `new_len` steps into a fresh
    /// block (refs = 1) — the copy-on-write primitive. Stored codes are
    /// cloned, not re-quantized, so the copy round-trips identically.
    fn clone_block(&mut self, slot: usize, new_len: usize) -> Result<usize, String> {
        let (heads, head_dim) = {
            let b = self.slots[slot].as_ref().expect("clone of free slot");
            debug_assert!(new_len <= b.len, "clone beyond filled steps");
            (b.heads, b.head_dim)
        };
        let bb = self.block_bytes(heads, head_dim);
        if self.bytes + bb > self.max_bytes {
            return Err(format!("block pool over budget: {} + {bb} > {}", self.bytes, self.max_bytes));
        }
        let src = self.slots[slot].as_ref().unwrap();
        let block = Block {
            heads,
            head_dim,
            len: new_len,
            refs: 1,
            k: src.k.clone(),
            v: src.v.clone(),
        };
        let dst = match self.free.pop() {
            Some(s) => {
                self.slots[s] = Some(block);
                s
            }
            None => {
                self.slots.push(Some(block));
                self.slots.len() - 1
            }
        };
        self.bytes += bb;
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        self.allocated += 1;
        Ok(dst)
    }

    fn incref(&mut self, slot: usize) {
        self.slots[slot].as_mut().expect("incref of free slot").refs += 1;
    }

    /// Drop one reference; frees the block (and its bytes) when the count
    /// hits zero. Returns whether the block was actually freed.
    fn decref(&mut self, slot: usize) -> bool {
        let b = self.slots[slot].as_mut().expect("decref of free slot");
        debug_assert!(b.refs > 0);
        b.refs -= 1;
        if b.refs > 0 {
            return false;
        }
        let bb = self.block_bytes(b.heads, b.head_dim);
        self.slots[slot] = None;
        self.free.push(slot);
        self.bytes -= bb;
        self.freed += 1;
        true
    }

    pub fn refs(&self, slot: usize) -> u32 {
        self.slots[slot].as_ref().map(|b| b.refs).unwrap_or(0)
    }

    /// Filled steps of a block.
    pub fn block_len(&self, slot: usize) -> usize {
        self.slots[slot].as_ref().expect("len of free slot").len
    }

    /// Append one step (`k_row`/`v_row` are `(heads, head_dim)` flat) to
    /// a block that must have spare capacity and a single owner.
    fn push_step(&mut self, slot: usize, k_row: &[f32], v_row: &[f32]) {
        let bs = self.block_steps;
        let b = self.slots[slot].as_mut().expect("push into free slot");
        debug_assert!(b.len < bs, "push into full block");
        debug_assert_eq!(b.refs, 1, "push into shared block (missing CoW)");
        let d = b.head_dim;
        debug_assert_eq!(k_row.len(), b.heads * d);
        for h in 0..b.heads {
            let at = (h * bs + b.len) * d;
            b.k.store(at, &k_row[h * d..(h + 1) * d]);
            b.v.store(at, &v_row[h * d..(h + 1) * d]);
        }
        b.len += 1;
    }

    /// Borrow head `h`'s first `steps` steps of a block as a contiguous
    /// [`KvRef`] fragment — the unit the paged kernel view streams.
    fn head_frag_k(&self, slot: usize, h: usize, steps: usize) -> KvRef<'_> {
        let b = self.slots[slot].as_ref().expect("frag of free slot");
        debug_assert!(steps <= b.len, "frag beyond filled steps");
        let (bs, d) = (self.block_steps, b.head_dim);
        b.k.as_kv().slice(h * bs * d, h * bs * d + steps * d)
    }

    fn head_frag_v(&self, slot: usize, h: usize, steps: usize) -> KvRef<'_> {
        let b = self.slots[slot].as_ref().expect("frag of free slot");
        debug_assert!(steps <= b.len, "frag beyond filled steps");
        let (bs, d) = (self.block_steps, b.head_dim);
        b.v.as_kv().slice(h * bs * d, h * bs * d + steps * d)
    }

    /// Pool-side consistency check: byte accounting, refcounts matching
    /// the table references handed in, free-list/slot agreement.
    pub fn check_invariants(&self, table_refs: &HashMap<usize, u32>) -> Result<(), String> {
        let mut accounted = 0usize;
        let mut live = 0usize;
        let on_free: std::collections::HashSet<usize> = self.free.iter().copied().collect();
        if on_free.len() != self.free.len() {
            return Err("duplicate slot on free list".into());
        }
        for (i, slot) in self.slots.iter().enumerate() {
            match slot {
                Some(b) => {
                    live += 1;
                    accounted += self.block_bytes(b.heads, b.head_dim);
                    if b.len > self.block_steps {
                        return Err(format!("block {i}: len {} > block_steps {}", b.len, self.block_steps));
                    }
                    if b.refs == 0 {
                        return Err(format!("block {i}: live with zero refs"));
                    }
                    let want = *table_refs.get(&i).unwrap_or(&0);
                    if b.refs != want {
                        return Err(format!("block {i}: refs {} != table references {want}", b.refs));
                    }
                    if on_free.contains(&i) {
                        return Err(format!("block {i}: live but on free list"));
                    }
                    if b.k.precision() != self.precision || b.v.precision() != self.precision {
                        return Err(format!("block {i}: precision mismatch"));
                    }
                }
                None => {
                    if !on_free.contains(&i) {
                        return Err(format!("slot {i}: empty but not on free list"));
                    }
                }
            }
        }
        if let Some(&ghost) = table_refs.keys().find(|s| {
            **s >= self.slots.len() || self.slots[**s].is_none()
        }) {
            return Err(format!("table references freed/unknown slot {ghost}"));
        }
        if accounted != self.bytes {
            return Err(format!("bytes {} != accounted {accounted}", self.bytes));
        }
        if self.bytes > self.max_bytes {
            return Err(format!("over budget: {} > {}", self.bytes, self.max_bytes));
        }
        if live != self.live_blocks() {
            return Err(format!("live {} != allocated-freed {}", live, self.live_blocks()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Block tables and the gathered kernel view
// ---------------------------------------------------------------------------

/// One session's logical KV sequence: an ordered list of pool slots
/// covering absolute steps `[start, len)`. Entry `j` covers steps
/// `[start + j*block_steps, ..)`; the final entry (if `len % block_steps
/// != 0`) is a partially filled tail. `start` is the window-trimmed
/// prefix (always a multiple of `block_steps`, always 0 for unwindowed
/// sessions), so in-block offsets stay congruent to the absolute step mod
/// `block_steps` no matter how much has been trimmed.
#[derive(Debug, Clone)]
pub struct BlockTable {
    pub heads: usize,
    pub head_dim: usize,
    /// Bound on *retained* steps ([`BlockTable::live`]); `len` itself
    /// grows without bound on a windowed session.
    pub cap: usize,
    /// Total steps ever appended (absolute end position).
    pub len: usize,
    /// Steps trimmed off the front (multiple of `block_steps`).
    pub start: usize,
    /// Sliding attention window in steps; `None` attends everything.
    pub window: Option<usize>,
    blocks: Vec<usize>,
}

impl BlockTable {
    /// Pool slots in logical order.
    pub fn blocks(&self) -> &[usize] {
        &self.blocks
    }

    /// Retained (resident) steps: `len` minus the trimmed prefix.
    pub fn live(&self) -> usize {
        self.len - self.start
    }

    /// Steps the kernels attend: the last `min(live, window)` — what a
    /// decode against this session pays for per token.
    pub fn attended(&self) -> usize {
        match self.window {
            Some(w) => self.live().min(w),
            None => self.live(),
        }
    }

    /// Steps appendable before the retained length hits `cap`.
    pub fn remaining(&self) -> usize {
        self.cap - self.live()
    }
}

/// A session's KV gathered as borrowed per-head fragment lists, ready to
/// lower into paged kernel jobs. Lives as long as the store borrow: the
/// fused drain cycle gathers every session once, after all of the cycle's
/// mutations are done.
#[derive(Debug)]
pub struct PagedSessionKv<'p> {
    pub heads: usize,
    pub head_dim: usize,
    /// Attended KV steps (the kernel's `n`): `min(live, window)`.
    pub len: usize,
    /// Sub-block slop preceding the attended range inside the first
    /// fragment — hidden from the kernels via the paged view's element
    /// offset. Always `< block_steps`.
    slop: usize,
    block_steps: usize,
    k: Vec<Vec<KvRef<'p>>>,
    v: Vec<Vec<KvRef<'p>>>,
}

impl<'p> PagedSessionKv<'p> {
    /// Head `h`'s keys as a paged kernel view of `len * head_dim` elements.
    pub fn head_k(&self, h: usize) -> KvView<'_> {
        KvView::Paged(PagedKv {
            blocks: &self.k[h],
            block_elems: self.block_steps * self.head_dim,
            start: self.slop * self.head_dim,
            len: self.len * self.head_dim,
        })
    }

    pub fn head_v(&self, h: usize) -> KvView<'_> {
        KvView::Paged(PagedKv {
            blocks: &self.v[h],
            block_elems: self.block_steps * self.head_dim,
            start: self.slop * self.head_dim,
            len: self.len * self.head_dim,
        })
    }
}

// ---------------------------------------------------------------------------
// Session store
// ---------------------------------------------------------------------------

/// Session store over a shared [`BlockPool`] with LRU eviction under a
/// byte budget. All sessions share one storage precision and block size,
/// fixed at construction.
///
/// Creation is lazy (a new session owns zero blocks), so `create` never
/// evicts; all eviction pressure lands on `append`/`share_prefix`, which
/// the fused dispatcher predicts exactly via the `*_would_evict` queries
/// before lowering a batch.
#[derive(Debug)]
pub struct SessionStore {
    pool: BlockPool,
    sessions: HashMap<u64, BlockTable>,
    lru: LruIndex,
    pub evictions: u64,
    pub block_evictions: u64,
    pub prefix_share_hits: u64,
    pub cow_copies: u64,
    /// Window-trim events (one per append/set_window that dropped blocks).
    pub window_trims: u64,
    /// Out-of-window blocks whose refcount hit zero and freed bytes —
    /// mirrors `block_evictions`: dereferenced-but-shared blocks don't
    /// count.
    pub blocks_trimmed: u64,
    pub precision: KvPrecision,
}

impl SessionStore {
    pub fn new(max_bytes: usize) -> SessionStore {
        SessionStore::with_precision(max_bytes, KvPrecision::F32)
    }

    pub fn with_precision(max_bytes: usize, precision: KvPrecision) -> SessionStore {
        SessionStore::with_block_steps(max_bytes, precision, crate::kernels::tiled::DEFAULT_TILE)
    }

    pub fn with_block_steps(
        max_bytes: usize,
        precision: KvPrecision,
        block_steps: usize,
    ) -> SessionStore {
        SessionStore {
            pool: BlockPool::new(max_bytes, precision, block_steps),
            sessions: HashMap::new(),
            lru: LruIndex::new(),
            evictions: 0,
            block_evictions: 0,
            prefix_share_hits: 0,
            cow_copies: 0,
            window_trims: 0,
            blocks_trimmed: 0,
            precision,
        }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Resident pool bytes (full-capacity accounting per block).
    pub fn bytes(&self) -> usize {
        self.pool.bytes
    }

    pub fn max_bytes(&self) -> usize {
        self.pool.max_bytes
    }

    pub fn block_steps(&self) -> usize {
        self.pool.block_steps
    }

    /// The underlying pool — counters for the metrics export.
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn get(&self, id: u64) -> Option<&BlockTable> {
        self.sessions.get(&id)
    }

    fn blocks_for(&self, steps: usize) -> usize {
        steps.div_ceil(self.pool.block_steps)
    }

    /// New block allocations an `n`-step append to table `t` performs:
    /// fresh blocks to cover the growth, plus one copy-on-write clone if
    /// the partial tail is currently shared. Invariant under pre-trim
    /// (dropping a leading block shrinks `blocks` and advances `start`
    /// by the same block count), so predicates can evaluate it on the
    /// untrimmed table.
    fn blocks_needed(&self, t: &BlockTable, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let fresh = (t.len + n - t.start).div_ceil(self.pool.block_steps) - t.blocks.len();
        let cow = if t.len % self.pool.block_steps != 0
            && self.pool.refs(*t.blocks.last().expect("partial len with no blocks")) > 1
        {
            1
        } else {
            0
        };
        fresh + cow
    }

    /// Leading blocks an `n`-step append would trim before evicting:
    /// blocks fully outside the window at the post-append length, clamped
    /// to blocks already fully filled (a partial tail is never trimmed —
    /// it becomes trimmable once later appends fill it).
    fn pretrim_drop(&self, t: &BlockTable, n: usize) -> usize {
        let Some(w) = t.window else { return 0 };
        let bs = self.pool.block_steps;
        let target = ((t.len + n).saturating_sub(w) / bs) * bs;
        if target <= t.start {
            return 0;
        }
        ((target - t.start) / bs).min((t.len - t.start) / bs)
    }

    /// Bytes an `n`-step append's pre-trim would free: only trimmed
    /// blocks this table is the last owner of release memory.
    fn pretrim_frees(&self, t: &BlockTable, n: usize) -> usize {
        let drop = self.pretrim_drop(t, n);
        let sole = t.blocks[..drop].iter().filter(|&&b| self.pool.refs(b) == 1).count();
        sole * self.pool.block_bytes(t.heads, t.head_dim)
    }

    /// Drop session `id`'s leading blocks that lie fully outside its
    /// window at length `len + lookahead` (`lookahead = n` for the
    /// pre-append trim, 0 for the settle pass after streaming). Only
    /// dereferences — bytes free solely through the refcount, so shared
    /// lineage blocks survive for their siblings.
    fn trim_to_window(&mut self, id: u64, lookahead: usize) {
        let drop = match self.sessions.get(&id) {
            Some(t) => self.pretrim_drop(t, lookahead),
            None => return,
        };
        if drop == 0 {
            return;
        }
        let SessionStore { pool, sessions, window_trims, blocks_trimmed, .. } = self;
        let t = sessions.get_mut(&id).unwrap();
        let bs = pool.block_steps;
        *window_trims += 1;
        for b in t.blocks.drain(..drop) {
            if pool.decref(b) {
                *blocks_trimmed += 1;
            }
        }
        t.start += drop * bs;
    }

    /// Bytes freed by removing session `id`: only blocks this table is
    /// the last owner of actually release memory.
    fn removal_frees(&self, id: u64) -> usize {
        let Some(t) = self.sessions.get(&id) else { return 0 };
        let sole = t.blocks.iter().filter(|&&b| self.pool.refs(b) == 1).count();
        sole * self.pool.block_bytes(t.heads, t.head_dim)
    }

    /// Would appending `n` steps to session `id` evict another session?
    /// Exact mirror of `append`'s admission check — trim-before-evict
    /// included: bytes the append's own window trim frees are credited
    /// before the budget comparison. The fused dispatcher flushes its
    /// current group before any append this returns true for, so KV an
    /// earlier batch in the cycle reads can't vanish between lowering and
    /// kernel submission.
    pub fn append_would_evict(&self, id: u64, n: usize) -> bool {
        let Some(t) = self.sessions.get(&id) else { return false };
        let need = self.blocks_needed(t, n) * self.pool.block_bytes(t.heads, t.head_dim);
        self.pool.bytes - self.pretrim_frees(t, n) + need > self.pool.max_bytes
    }

    /// Would a prefill (re-create + `n`-step append) of this geometry
    /// evict another session? Re-creating `id` first frees the blocks it
    /// solely owns.
    pub fn prefill_would_evict(&self, id: u64, heads: usize, head_dim: usize, n: usize) -> bool {
        let need = self.blocks_for(n) * self.pool.block_bytes(heads, head_dim);
        self.pool.bytes - self.removal_frees(id) + need > self.pool.max_bytes
    }

    /// Would forking `src` into `dst` and appending `n` divergent steps
    /// evict another session? The fork itself is free; the append pays
    /// for growth blocks plus a CoW of any partial tail (always shared
    /// right after a fork). Re-creating `dst` frees its solely owned
    /// blocks first.
    /// Right after the fork every block is shared (refcount >= 2), so the
    /// divergent append's trim-before-evict frees nothing — the predicate
    /// credits no trim bytes.
    pub fn fork_would_evict(&self, src: u64, dst: u64, n: usize) -> bool {
        let Some(t) = self.sessions.get(&src) else { return false };
        let mut blocks =
            if n == 0 { 0 } else { (t.len + n - t.start).div_ceil(self.pool.block_steps) - t.blocks.len() };
        if n > 0 && t.len % self.pool.block_steps != 0 {
            blocks += 1;
        }
        let need = blocks * self.pool.block_bytes(t.heads, t.head_dim);
        self.pool.bytes - self.removal_frees(dst) + need > self.pool.max_bytes
    }

    /// Create a session with zero blocks. Replaces any existing table
    /// under the same id. Fails only if the session could never fit: a
    /// full-capacity table alone must stay within the byte budget (which
    /// is what guarantees the append eviction loop always converges).
    pub fn create(&mut self, id: u64, heads: usize, head_dim: usize, cap: usize) -> Result<(), String> {
        self.create_windowed(id, heads, head_dim, cap, None)
    }

    /// [`SessionStore::create`] with a sliding attention window: the
    /// session retains at most `cap` steps at any instant (including the
    /// pre-trim peak of an in-flight append), attends the last
    /// `min(len, window)`, and appends trim fully-out-of-window leading
    /// blocks eagerly. A steady decode needs `cap >= window +
    /// block_steps` to run unbounded.
    pub fn create_windowed(
        &mut self,
        id: u64,
        heads: usize,
        head_dim: usize,
        cap: usize,
        window: Option<usize>,
    ) -> Result<(), String> {
        if window == Some(0) {
            return Err("attention window must be >= 1 step".into());
        }
        let worst = self.blocks_for(cap) * self.pool.block_bytes(heads, head_dim);
        if worst > self.pool.max_bytes {
            return Err(format!("session of {worst} bytes exceeds budget {}", self.pool.max_bytes));
        }
        self.remove(id);
        self.sessions.insert(id, BlockTable { heads, head_dim, cap, len: 0, start: 0, window, blocks: Vec::new() });
        self.lru.touch(id);
        Ok(())
    }

    /// Rebind session `id`'s attention window (the fork-with-policy
    /// path), trimming immediately if the new window strands leading
    /// blocks. Trimmed history is gone for good, so widening (or
    /// unsetting) beyond what the session still retains is a typed error
    /// rather than a window that silently attends fewer steps than it
    /// promises.
    pub fn set_window(&mut self, id: u64, window: Option<usize>) -> Result<(), String> {
        if window == Some(0) {
            return Err("attention window must be >= 1 step".into());
        }
        match self.sessions.get_mut(&id) {
            Some(t) => {
                let widened_past_trim = match window {
                    None => t.start != 0,
                    Some(w) => t.start != 0 && w > t.live(),
                };
                if widened_past_trim {
                    return Err(format!(
                        "cannot widen window past trimmed history (session {id} retains {} of {} steps)",
                        t.live(),
                        t.len
                    ));
                }
                t.window = window;
            }
            None => return Err(format!("set_window on unknown session {id}")),
        }
        self.trim_to_window(id, 0);
        Ok(())
    }

    /// Append `n` KV pairs given as `(heads, n, head_dim)` flat slices,
    /// evicting LRU sessions (never `id` itself) to make room. Fails
    /// (leaving the table untouched) on capacity overflow; the byte
    /// budget cannot fail for a validly created session.
    pub fn append(&mut self, id: u64, k_new: &[f32], v_new: &[f32], n: usize) -> Result<(), String> {
        let (heads, head_dim) = match self.sessions.get(&id) {
            Some(t) => (t.heads, t.head_dim),
            None => return Err(format!("append to unknown session {id}")),
        };
        let hd = heads * head_dim;
        if k_new.len() != hd * n || v_new.len() != hd * n {
            return Err(format!("append: expected {} elems, got {}", hd * n, k_new.len()));
        }
        {
            // Capacity bounds the *peak* retained length: post-append len
            // minus what the pre-trim can reclaim. An append never trims
            // mid-stream, so a single append larger than the window is
            // rejected rather than silently truncated (and the peak is
            // what the create-time worst-case budget check covered).
            let t = &self.sessions[&id];
            let peak = t.len + n - (t.start + self.pretrim_drop(t, n) * self.pool.block_steps);
            if peak > t.cap {
                return Err(format!("kv cache full: {peak} retained > cap {}", t.cap));
            }
        }
        self.lru.touch(id);
        if n == 0 {
            return Ok(());
        }
        // Trim-before-evict: reclaim this session's own dead prefix
        // (blocks fully out of window at the post-append length) before
        // any other session is considered as a victim.
        self.trim_to_window(id, n);
        // Make room. Recompute per iteration: evicting a sibling fork can
        // drop the shared-tail refcount and cancel the CoW allocation.
        loop {
            let t = &self.sessions[&id];
            let need = self.blocks_needed(t, n) * self.pool.block_bytes(heads, head_dim);
            if self.pool.bytes + need <= self.pool.max_bytes {
                break;
            }
            let victim = self
                .lru
                .front_excluding(id)
                .ok_or_else(|| format!("append of {need} bytes cannot fit budget {}", self.pool.max_bytes))?;
            self.evict(victim);
        }
        // Copy-on-write a shared partial tail before mutating it.
        let bs = self.pool.block_steps;
        let tail_len = self.sessions[&id].len % bs;
        if tail_len != 0 {
            let tail = *self.sessions[&id].blocks.last().unwrap();
            if self.pool.refs(tail) > 1 {
                let fresh = self.pool.clone_block(tail, tail_len)?;
                self.pool.decref(tail);
                *self.sessions.get_mut(&id).unwrap().blocks.last_mut().unwrap() = fresh;
                self.cow_copies += 1;
            }
        }
        // Stream the steps in, allocating blocks at block boundaries.
        let SessionStore { pool, sessions, .. } = self;
        let t = sessions.get_mut(&id).unwrap();
        let d = head_dim;
        let mut krow = vec![0.0f32; hd];
        let mut vrow = vec![0.0f32; hd];
        for i in 0..n {
            if t.len % bs == 0 {
                let slot = pool.alloc(heads, d).expect("append: eviction loop reserved space");
                t.blocks.push(slot);
            }
            for h in 0..heads {
                let src = (h * n + i) * d;
                krow[h * d..(h + 1) * d].copy_from_slice(&k_new[src..src + d]);
                vrow[h * d..(h + 1) * d].copy_from_slice(&v_new[src..src + d]);
            }
            let slot = *t.blocks.last().unwrap();
            debug_assert_eq!(pool.block_len(slot), t.len % bs);
            pool.push_step(slot, &krow, &vrow);
            t.len += 1;
        }
        // Settle pass: the streamed steps may have pushed earlier blocks
        // (including a tail the pre-trim had to leave partial) fully out
        // of window.
        self.trim_to_window(id, 0);
        Ok(())
    }

    /// Fork `src` into `dst`: `dst` shares *every* block of `src` —
    /// including a partial tail — at zero copy cost. The first divergent
    /// append to either side copy-on-writes just the tail; full blocks
    /// are immutable once filled and stay shared forever. Replaces any
    /// existing `dst`.
    pub fn fork(&mut self, src: u64, dst: u64) -> Result<(), String> {
        if src == dst {
            return Err("fork: src == dst".into());
        }
        let table = match self.sessions.get(&src) {
            Some(t) => t.clone(),
            None => return Err(format!("fork from unknown session {src}")),
        };
        self.remove(dst);
        for &b in &table.blocks {
            self.pool.incref(b);
        }
        self.prefix_share_hits += table.blocks.len() as u64;
        self.sessions.insert(dst, table);
        self.lru.touch(src);
        self.lru.touch(dst);
        Ok(())
    }

    /// Create `dst` sharing exactly the first `steps` of `src`: full
    /// blocks are shared by reference; a partial tail block is
    /// materialized as a truncated bit-exact copy (one CoW up front,
    /// since the prefix boundary splits a block). Replaces any existing
    /// `dst`.
    pub fn share_prefix(&mut self, src: u64, dst: u64, steps: usize) -> Result<(), String> {
        if src == dst {
            return Err("share_prefix: src == dst".into());
        }
        let (heads, head_dim, cap, src_len, window) = match self.sessions.get(&src) {
            Some(t) => {
                // A window-trimmed source no longer holds its absolute
                // prefix [0, steps) — sharing it would silently hand out
                // the wrong steps.
                if t.start != 0 {
                    return Err(format!(
                        "share_prefix from window-trimmed session {src} (first {} steps gone)",
                        t.start
                    ));
                }
                (t.heads, t.head_dim, t.cap, t.len, t.window)
            }
            None => return Err(format!("share_prefix from unknown session {src}")),
        };
        if steps > src_len {
            return Err(format!("share_prefix: {steps} > source len {src_len}"));
        }
        self.remove(dst);
        let bs = self.pool.block_steps;
        let (full, partial) = (steps / bs, steps % bs);
        if partial != 0 {
            // Reserve room for the one materialized tail block.
            let bb = self.pool.block_bytes(heads, head_dim);
            while self.pool.bytes + bb > self.pool.max_bytes {
                let victim = self
                    .lru
                    .front_excluding(src)
                    .ok_or_else(|| format!("share_prefix of {bb} bytes cannot fit budget {}", self.pool.max_bytes))?;
                self.evict(victim);
            }
        }
        let src_blocks: Vec<usize> = self.sessions[&src].blocks.clone();
        let mut blocks = Vec::with_capacity(full + usize::from(partial != 0));
        for &b in &src_blocks[..full] {
            self.pool.incref(b);
            blocks.push(b);
        }
        self.prefix_share_hits += full as u64;
        if partial != 0 {
            let clone = self.pool.clone_block(src_blocks[full], partial)?;
            blocks.push(clone);
            self.cow_copies += 1;
        }
        self.sessions
            .insert(dst, BlockTable { heads, head_dim, cap, len: steps, start: 0, window, blocks });
        self.lru.touch(src);
        self.lru.touch(dst);
        Ok(())
    }

    /// Evict a session under budget pressure: drops its table and every
    /// reference, but only blocks it solely owned free bytes — a shared
    /// prefix survives for the sibling sessions that still point at it.
    fn evict(&mut self, id: u64) {
        if let Some(t) = self.sessions.remove(&id) {
            self.lru.remove(id);
            self.evictions += 1;
            for &b in &t.blocks {
                if self.pool.decref(b) {
                    self.block_evictions += 1;
                }
            }
        }
    }

    /// Drop a session (client-initiated; not counted as an eviction).
    pub fn remove(&mut self, id: u64) {
        if let Some(t) = self.sessions.remove(&id) {
            self.lru.remove(id);
            for &b in &t.blocks {
                self.pool.decref(b);
            }
        }
    }

    /// Gather one session's KV as borrowed per-head fragment lists
    /// covering exactly the *attended* suffix: fragments for the last
    /// `attended()` steps per head in logical order, with any sub-block
    /// slop in the first fragment hidden behind the paged view's element
    /// offset. For an unwindowed session this is the whole cache — the
    /// contract the paged kernel view streams tiles from either way.
    pub fn gather(&self, id: u64) -> Option<PagedSessionKv<'_>> {
        let t = self.sessions.get(&id)?;
        let bs = self.pool.block_steps;
        // Skip whole retained-but-dead leading blocks (possible when a
        // fork re-bound a narrower window and hasn't appended yet); the
        // sub-block remainder becomes the view's start offset.
        let skip = t.live() - t.attended();
        let (skip_blocks, slop) = (skip / bs, skip % bs);
        let mut k = Vec::with_capacity(t.heads);
        let mut v = Vec::with_capacity(t.heads);
        for h in 0..t.heads {
            let mut kh = Vec::with_capacity(t.blocks.len() - skip_blocks);
            let mut vh = Vec::with_capacity(t.blocks.len() - skip_blocks);
            for (j, &slot) in t.blocks.iter().enumerate().skip(skip_blocks) {
                let covered = (t.len - (t.start + j * bs)).min(bs);
                kh.push(self.pool.head_frag_k(slot, h, covered));
                vh.push(self.pool.head_frag_v(slot, h, covered));
            }
            k.push(kh);
            v.push(vh);
        }
        Some(PagedSessionKv {
            heads: t.heads,
            head_dim: t.head_dim,
            len: t.attended(),
            slop,
            block_steps: bs,
            k,
            v,
        })
    }

    /// Gather several sessions simultaneously — the fused dispatch gather
    /// phase: one drain cycle reads many sessions at once, after all of
    /// the cycle's mutations (creates/appends/forks) are done. Duplicates
    /// are allowed; a missing id yields `None` in its slot so the caller
    /// can degrade per session instead of failing the whole cycle.
    pub fn gather_many(&self, ids: &[u64]) -> Vec<Option<PagedSessionKv<'_>>> {
        ids.iter().map(|&id| self.gather(id)).collect()
    }

    /// Internal-consistency check used by the property tests and (when
    /// `validate_invariants` is set) the serving engine loop: table
    /// geometry, LRU membership, and pool refcount/byte accounting.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.lru.len() != self.sessions.len() {
            return Err(format!("lru {} != sessions {}", self.lru.len(), self.sessions.len()));
        }
        let bs = self.pool.block_steps;
        let mut refs: HashMap<usize, u32> = HashMap::new();
        for (&id, t) in &self.sessions {
            if !self.lru.contains(id) {
                return Err(format!("session {id} missing from lru"));
            }
            if t.window == Some(0) {
                return Err(format!("session {id}: zero attention window"));
            }
            if t.start % bs != 0 {
                return Err(format!("session {id}: trim start {} not block-aligned (block_steps {bs})", t.start));
            }
            if t.start > t.len {
                return Err(format!("session {id}: trim start {} > len {}", t.start, t.len));
            }
            // A trim may never reach into the attended window: the first
            // retained step must be at or before the window's first step.
            if let Some(w) = t.window {
                if t.start > t.len.saturating_sub(w) {
                    return Err(format!(
                        "session {id}: over-trimmed — start {} strands window {w} of len {}",
                        t.start, t.len
                    ));
                }
            } else if t.start != 0 {
                return Err(format!("session {id}: unwindowed but trimmed to {}", t.start));
            }
            if t.live() > t.cap {
                return Err(format!("session {id}: live {} > cap {}", t.live(), t.cap));
            }
            if t.blocks.len() != t.live().div_ceil(bs) {
                return Err(format!(
                    "session {id}: {} blocks for live {} (block_steps {bs})",
                    t.blocks.len(),
                    t.live()
                ));
            }
            for (j, &slot) in t.blocks.iter().enumerate() {
                let covered = (t.len - (t.start + j * bs)).min(bs);
                if covered > self.pool.block_len(slot) {
                    return Err(format!(
                        "session {id} block {j}: covers {covered} steps but block holds {}",
                        self.pool.block_len(slot)
                    ));
                }
                *refs.entry(slot).or_insert(0) += 1;
            }
        }
        self.pool.check_invariants(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BIG: usize = 1 << 30;

    fn gather_head_k(s: &SessionStore, id: u64, h: usize) -> Vec<f32> {
        s.gather(id).unwrap().head_k(h).to_f32_vec()
    }

    #[test]
    fn lru_index_is_ordered_and_o1_shaped() {
        let mut l = LruIndex::new();
        assert!(l.is_empty() && l.front().is_none());
        l.touch(1);
        l.touch(2);
        l.touch(3);
        assert_eq!(l.order(), [1, 2, 3]);
        l.touch(1); // move-to-back, not duplicate
        assert_eq!(l.order(), [2, 3, 1]);
        assert_eq!(l.len(), 3);
        assert_eq!(l.front(), Some(2));
        assert_eq!(l.front_excluding(2), Some(3));
        assert_eq!(l.front_excluding(9), Some(2));
        l.remove(3);
        assert_eq!(l.order(), [2, 1]);
        l.remove(3); // absent remove is a no-op
        l.touch(4); // reuses the freed slab slot
        assert_eq!(l.order(), [2, 1, 4]);
        assert!(l.contains(4) && !l.contains(3));
        l.remove(2);
        l.remove(1);
        l.remove(4);
        assert!(l.is_empty() && l.front().is_none() && l.front_excluding(0).is_none());
    }

    #[test]
    fn append_layout_round_trips_across_blocks() {
        // block_steps 2 so three appended steps span two blocks.
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create(1, 2, 3, 4).unwrap();
        // (heads, n, head_dim) flat: head0 = [1,2,3], head1 = [4,5,6]
        s.append(1, &[1., 2., 3., 4., 5., 6.], &[9., 9., 9., 8., 8., 8.], 1).unwrap();
        assert_eq!(s.get(1).unwrap().len, 1);
        assert_eq!(gather_head_k(&s, 1, 0), [1., 2., 3.]);
        assert_eq!(gather_head_k(&s, 1, 1), [4., 5., 6.]);
        // two more steps: n=2 layout is (h*n + i)*d
        s.append(
            1,
            &[10., 11., 12., 20., 21., 22., 13., 14., 15., 23., 24., 25.],
            &[0.; 12],
            2,
        )
        .unwrap();
        assert_eq!(s.get(1).unwrap().blocks().len(), 2);
        assert_eq!(gather_head_k(&s, 1, 0), [1., 2., 3., 10., 11., 12., 20., 21., 22.]);
        assert_eq!(gather_head_k(&s, 1, 1), [4., 5., 6., 13., 14., 15., 23., 24., 25.]);
        assert_eq!(s.get(1).unwrap().remaining(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn append_over_capacity_fails_cleanly() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create(1, 1, 2, 2).unwrap();
        s.append(1, &[1., 2.], &[3., 4.], 1).unwrap();
        s.append(1, &[5., 6.], &[7., 8.], 1).unwrap();
        let before = gather_head_k(&s, 1, 0);
        assert!(s.append(1, &[9., 9.], &[9., 9.], 1).is_err());
        assert_eq!(gather_head_k(&s, 1, 0), before);
        assert_eq!(s.get(1).unwrap().len, 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn quantized_append_is_single_rounding_projection() {
        use crate::numerics::quant::{quantize_bf16, quantize_fp8};
        let vals = [0.1f32, -1.75, 3.25, 0.0, 448.0, -0.007];
        for prec in [KvPrecision::Bf16, KvPrecision::Fp8] {
            let mut s = SessionStore::with_block_steps(BIG, prec, 2);
            s.create(1, 1, 3, 2).unwrap();
            s.append(1, &vals[..3], &vals[3..], 1).unwrap();
            let kf = gather_head_k(&s, 1, 0);
            let want: Vec<f32> = match prec {
                KvPrecision::Bf16 => {
                    quantize_bf16(&vals[..3]).iter().map(|&b| Bf16(b).to_f32()).collect()
                }
                _ => quantize_fp8(&vals[..3]).iter().map(|&b| Fp8E4M3(b).to_f32()).collect(),
            };
            assert_eq!(kf, want, "{prec:?}");
            // appending the dequantized values back is a fixed point
            let mut s2 = SessionStore::with_block_steps(BIG, prec, 2);
            s2.create(1, 1, 3, 2).unwrap();
            let vf = s.gather(1).unwrap().head_v(0).to_f32_vec();
            s2.append(1, &kf, &vf, 1).unwrap();
            assert_eq!(gather_head_k(&s2, 1, 0), kf, "{prec:?}");
        }
    }

    #[test]
    fn bytes_are_block_granular_and_track_precision() {
        // 1 head, dim 2, block_steps 4 → f32 block = 2*1*4*2*4 = 64 bytes.
        let mut stores: Vec<SessionStore> = [KvPrecision::F32, KvPrecision::Bf16, KvPrecision::Fp8]
            .into_iter()
            .map(|p| SessionStore::with_block_steps(BIG, p, 4))
            .collect();
        for s in &mut stores {
            s.create(1, 1, 2, 16).unwrap();
            assert_eq!(s.bytes(), 0, "lazy create allocates nothing");
            assert_eq!(s.pool().live_blocks(), 0);
            // one step allocates one full-capacity block
            s.append(1, &[1., 2.], &[3., 4.], 1).unwrap();
        }
        assert_eq!(stores[0].bytes(), 64);
        assert_eq!(stores[1].bytes(), 32);
        assert_eq!(stores[2].bytes(), 16);
        // a second step fits the same block: no new bytes
        stores[0].append(1, &[5., 6.], &[7., 8.], 1).unwrap();
        assert_eq!(stores[0].bytes(), 64);
        assert_eq!(stores[0].pool().peak_bytes, 64);
    }

    #[test]
    fn store_lru_eviction_on_append() {
        // 1 head, dim 2, block_steps 2 → block = 2*1*2*2*4 = 32B; budget 64 = 2 blocks.
        let mut s = SessionStore::with_block_steps(64, KvPrecision::F32, 2);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        s.append(1, &[1., 1.], &[1., 1.], 1).unwrap();
        s.append(2, &[2., 2.], &[2., 2.], 1).unwrap();
        assert_eq!(s.bytes(), 64);
        // touch 1 (fills its existing tail block — no allocation) so 2 is LRU
        s.append(1, &[1., 1.], &[1., 1.], 1).unwrap();
        s.create(3, 1, 2, 4).unwrap(); // lazy: still no eviction
        assert!(s.contains(2));
        s.append(3, &[3., 3.], &[3., 3.], 1).unwrap(); // needs a block → evicts 2
        assert!(s.contains(1) && s.contains(3) && !s.contains(2));
        assert_eq!(s.evictions, 1);
        assert_eq!(s.block_evictions, 1);
        assert_eq!(s.bytes(), 64);
        s.check_invariants().unwrap();
    }

    #[test]
    fn append_would_evict_predicts_append() {
        let mut s = SessionStore::with_block_steps(64, KvPrecision::F32, 2);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        assert!(!s.append_would_evict(1, 1));
        s.append(1, &[1., 1.], &[1., 1.], 1).unwrap();
        assert!(!s.append_would_evict(1, 1), "tail block has room");
        assert!(s.append_would_evict(1, 3), "two more blocks cannot fit");
        assert!(!s.append_would_evict(2, 2));
        s.append(2, &[2., 2., 2., 2.], &[2., 2., 2., 2.], 2).unwrap();
        assert!(s.append_would_evict(1, 2), "second block for 1 must evict");
        assert!(!s.append_would_evict(2, 0), "empty append never evicts");
        s.check_invariants().unwrap();
    }

    #[test]
    fn create_too_large_rejected_and_lazy() {
        let mut s = SessionStore::with_block_steps(32, KvPrecision::F32, 2);
        // cap 4 needs 2 blocks = 64B worst case > 32B budget
        assert!(s.create(1, 1, 2, 4).is_err());
        assert!(s.is_empty());
        // cap 2 fits (one 32B block worst case) and allocates nothing yet
        s.create(1, 1, 2, 2).unwrap();
        assert_eq!(s.bytes(), 0);
        s.check_invariants().unwrap();
    }

    #[test]
    fn recreate_replaces_and_frees() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create(7, 1, 2, 4).unwrap();
        s.append(7, &[1., 2.], &[3., 4.], 1).unwrap();
        assert!(s.bytes() > 0);
        s.create(7, 1, 2, 4).unwrap();
        assert_eq!(s.get(7).unwrap().len, 0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), 0, "old blocks freed on replace");
        s.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_blocks_and_cows_on_divergence() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create(1, 1, 2, 8).unwrap();
        // 3 steps = one full block + a partial tail
        s.append(1, &[1., 1., 2., 2., 3., 3.], &[4., 4., 5., 5., 6., 6.], 3).unwrap();
        let bytes_before = s.bytes();
        let src_before = gather_head_k(&s, 1, 0);
        s.fork(1, 2).unwrap();
        assert_eq!(s.bytes(), bytes_before, "fork allocates nothing");
        assert_eq!(s.get(2).unwrap().blocks(), s.get(1).unwrap().blocks());
        for &b in s.get(1).unwrap().blocks() {
            assert_eq!(s.pool().refs(b), 2);
        }
        assert_eq!(s.prefix_share_hits, 2);
        assert_eq!(gather_head_k(&s, 2, 0), src_before);
        s.check_invariants().unwrap();
        // divergent append on the fork CoWs only the partial tail
        s.append(2, &[7., 7.], &[8., 8.], 1).unwrap();
        assert_eq!(s.cow_copies, 1);
        let (t1, t2) = (s.get(1).unwrap().blocks().to_vec(), s.get(2).unwrap().blocks().to_vec());
        assert_eq!(t1[0], t2[0], "full prefix block still shared");
        assert_ne!(t1[1], t2[1], "tail copied on write");
        assert_eq!(s.pool().refs(t1[0]), 2);
        assert_eq!(s.pool().refs(t1[1]), 1);
        assert_eq!(s.pool().refs(t2[1]), 1);
        assert_eq!(gather_head_k(&s, 1, 0), src_before, "source bits untouched");
        assert_eq!(gather_head_k(&s, 2, 0), [1., 1., 2., 2., 3., 3., 7., 7.]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn fork32_stores_prefix_exactly_once() {
        // block-aligned prefix: 8 steps over block_steps 4 = 2 full blocks
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 4);
        s.create(0, 1, 2, 64).unwrap();
        let prefix: Vec<f32> = (0..16).map(|x| x as f32).collect();
        s.append(0, &prefix, &prefix, 8).unwrap();
        let prefix_bytes = s.bytes();
        assert_eq!(prefix_bytes, 2 * 2 * 4 * 2 * 4);
        for id in 1..=32 {
            s.fork(0, id).unwrap();
        }
        assert_eq!(s.bytes(), prefix_bytes, "32 forks add zero bytes");
        for &b in s.get(0).unwrap().blocks() {
            assert_eq!(s.pool().refs(b), 33, "prefix stored once, referenced 33x");
        }
        s.check_invariants().unwrap();
        // one divergent step per fork: tail is block-aligned, so no CoW —
        // each fork allocates exactly one fresh block
        for id in 1..=32 {
            s.append(id, &[id as f32, 0.], &[0., 0.], 1).unwrap();
        }
        assert_eq!(s.cow_copies, 0);
        assert_eq!(s.bytes(), prefix_bytes + 32 * (prefix_bytes / 2));
        for &b in s.get(0).unwrap().blocks() {
            assert_eq!(s.pool().refs(b), 33);
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn share_prefix_materializes_partial_tail() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 4);
        s.create(1, 1, 1, 16).unwrap();
        let data: Vec<f32> = (0..6).map(|x| x as f32 + 1.0).collect();
        s.append(1, &data, &data, 6).unwrap();
        // steps=5 splits block 1: share block 0, clone one step of block 1
        s.share_prefix(1, 2, 5).unwrap();
        assert_eq!(s.get(2).unwrap().len, 5);
        assert_eq!(s.pool().refs(s.get(1).unwrap().blocks()[0]), 2);
        assert_ne!(s.get(1).unwrap().blocks()[1], s.get(2).unwrap().blocks()[1]);
        assert_eq!(s.cow_copies, 1);
        assert_eq!(s.prefix_share_hits, 1);
        assert_eq!(gather_head_k(&s, 2, 0), [1., 2., 3., 4., 5.]);
        s.check_invariants().unwrap();
        // block-aligned prefix shares everything, clones nothing
        s.share_prefix(1, 3, 4).unwrap();
        assert_eq!(s.cow_copies, 1, "aligned prefix needs no copy");
        assert_eq!(s.get(3).unwrap().blocks()[0], s.get(1).unwrap().blocks()[0]);
        assert_eq!(gather_head_k(&s, 3, 0), [1., 2., 3., 4.]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn eviction_preserves_shared_prefix_blocks() {
        // block = 2*1*2*2*4 = 32B; budget 96 = 3 blocks.
        let mut s = SessionStore::with_block_steps(96, KvPrecision::F32, 2);
        s.create(1, 1, 2, 4).unwrap();
        s.append(1, &[1., 1., 2., 2.], &[1., 1., 2., 2.], 2).unwrap(); // block A (full)
        s.fork(1, 2).unwrap(); // 2 shares A
        s.append(2, &[3., 3., 4., 4.], &[3., 3., 4., 4.], 2).unwrap(); // + exclusive block B
        s.append(1, &[5., 5., 6., 6.], &[5., 5., 6., 6.], 2).unwrap(); // + exclusive block C
        assert_eq!(s.bytes(), 96);
        // session 2 is now LRU; a third session's first append evicts it...
        s.create(3, 1, 2, 4).unwrap(); // lazy: no eviction yet
        s.append(3, &[9., 9., 8., 8.], &[9., 9., 8., 8.], 2).unwrap();
        assert!(s.contains(1) && !s.contains(2) && s.contains(3));
        assert_eq!(s.evictions, 1);
        // ...freeing only its exclusive block B — shared A survives for 1
        assert_eq!(s.block_evictions, 1, "only the unshared block frees");
        assert_eq!(s.bytes(), 96);
        assert_eq!(s.pool().refs(s.get(1).unwrap().blocks()[0]), 1);
        assert_eq!(gather_head_k(&s, 1, 0), [1., 1., 2., 2., 5., 5., 6., 6.]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn prefill_and_fork_would_evict_predict() {
        let mut s = SessionStore::with_block_steps(64, KvPrecision::F32, 2);
        s.create(1, 1, 2, 4).unwrap();
        s.append(1, &[1., 1., 2., 2., 3., 3.], &[0.; 6], 3).unwrap(); // 2 blocks = 64B
        assert!(!s.prefill_would_evict(1, 1, 2, 4), "replacing self frees own blocks");
        assert!(s.prefill_would_evict(2, 1, 2, 1), "any new block must evict");
        // fork+append: tail is partial and will CoW, plus growth
        assert!(s.fork_would_evict(1, 2, 1), "CoW block cannot fit");
        s.fork(1, 2).unwrap(); // sharing itself is free
        assert_eq!(s.bytes(), 64);
        assert!(s.append_would_evict(2, 1), "divergence needs the CoW block");
        s.check_invariants().unwrap();
    }

    #[test]
    fn gather_many_takes_simultaneous_refs() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        s.append(1, &[1., 2.], &[3., 4.], 1).unwrap();
        s.append(2, &[5., 6.], &[7., 8.], 1).unwrap();
        let views = s.gather_many(&[1, 2, 1]);
        assert_eq!(views.len(), 3);
        assert_eq!(views[0].as_ref().unwrap().head_k(0).to_f32_vec()[0], 1.0);
        assert_eq!(views[1].as_ref().unwrap().head_k(0).to_f32_vec()[0], 5.0);
        assert_eq!(
            views[2].as_ref().unwrap().head_k(0).to_f32_vec(),
            views[0].as_ref().unwrap().head_k(0).to_f32_vec()
        );
        let partial = s.gather_many(&[1, 9]);
        assert!(partial[0].is_some() && partial[1].is_none());
    }

    #[test]
    fn windowed_append_trims_leading_blocks() {
        // bs 2, window 4: at len 8 the eager trim start is ((8-4)/2)*2 = 4.
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create_windowed(1, 1, 1, 8, Some(4)).unwrap();
        for i in 0..8 {
            s.append(1, &[i as f32], &[i as f32], 1).unwrap();
            s.check_invariants().unwrap();
        }
        let t = s.get(1).unwrap();
        assert_eq!((t.len, t.start, t.live(), t.attended()), (8, 4, 4, 4));
        assert_eq!(t.blocks().len(), 2);
        assert_eq!(s.window_trims, 2, "trimmed at len 6 and len 8");
        assert_eq!(s.blocks_trimmed, 2);
        assert_eq!(s.bytes(), 2 * s.pool().block_bytes(1, 1), "freed bytes left the pool");
        assert_eq!(gather_head_k(&s, 1, 0), [4., 5., 6., 7.]);
    }

    #[test]
    fn window_trim_never_frees_shared_lineage_blocks() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create(1, 1, 1, 8).unwrap();
        s.append(1, &[0., 1., 2., 3.], &[0., 1., 2., 3.], 4).unwrap(); // blocks A, B (full)
        s.fork(1, 2).unwrap();
        s.set_window(2, Some(2)).unwrap(); // strands A in 2's table
        assert_eq!(s.window_trims, 1);
        assert_eq!(s.blocks_trimmed, 0, "shared block dereferenced, not freed");
        assert_eq!(s.get(2).unwrap().start, 2);
        assert_eq!(s.pool().refs(s.get(1).unwrap().blocks()[0]), 1);
        assert_eq!(gather_head_k(&s, 1, 0), [0., 1., 2., 3.], "sibling reads the full prefix");
        assert_eq!(gather_head_k(&s, 2, 0), [2., 3.]);
        s.check_invariants().unwrap();
        // decoding on the fork pushes shared B out of window: deref only
        s.append(2, &[4.], &[4.], 1).unwrap();
        s.append(2, &[5.], &[5.], 1).unwrap(); // len 6 → start 4, drops B
        assert_eq!(s.blocks_trimmed, 0, "B still lives for session 1");
        assert_eq!(gather_head_k(&s, 1, 0), [0., 1., 2., 3.]);
        assert_eq!(gather_head_k(&s, 2, 0), [4., 5.]);
        // two more steps push 2's exclusive block out: that one frees
        s.append(2, &[6.], &[6.], 1).unwrap();
        s.append(2, &[7.], &[7.], 1).unwrap();
        assert_eq!(s.blocks_trimmed, 1);
        // a trimmed source can't hand out its absolute prefix
        assert!(s.share_prefix(2, 3, 1).unwrap_err().contains("window-trimmed"));
        s.check_invariants().unwrap();
    }

    #[test]
    fn append_would_evict_credits_trim_before_evict() {
        // block = 2*1*2*2*4 = 32B; budget 64 = 2 blocks.
        let mut s = SessionStore::with_block_steps(64, KvPrecision::F32, 2);
        s.create_windowed(1, 1, 2, 4, Some(2)).unwrap();
        s.create(2, 1, 2, 2).unwrap();
        s.append(1, &[1., 1., 2., 2.], &[1., 1., 2., 2.], 2).unwrap();
        s.append(2, &[9., 9.], &[9., 9.], 1).unwrap();
        assert_eq!(s.bytes(), 64);
        // 1's next block fits because its own dead prefix frees first
        assert!(!s.append_would_evict(1, 2), "trim-before-evict frees own dead prefix");
        s.append(1, &[3., 3., 4., 4.], &[3., 3., 4., 4.], 2).unwrap();
        assert!(s.contains(2), "no eviction needed");
        assert_eq!(s.evictions, 0);
        assert_eq!(s.blocks_trimmed, 1);
        assert_eq!(gather_head_k(&s, 1, 0), [3., 3., 4., 4.]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn windowed_capacity_bounds_peak_not_absolute_len() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create_windowed(1, 1, 1, 4, Some(2)).unwrap();
        // a single append larger than cap is rejected, window notwithstanding
        assert!(s.append(1, &[0.; 5], &[0.; 5], 5).is_err());
        assert_eq!(s.get(1).unwrap().len, 0, "failed append leaves the table untouched");
        // but a steady decode runs far past cap: retained length stays bounded
        for i in 0..32 {
            s.append(1, &[i as f32], &[i as f32], 1).unwrap();
        }
        let t = s.get(1).unwrap();
        assert_eq!(t.len, 32);
        assert!(t.live() <= 4);
        assert_eq!(t.attended(), 2);
        assert_eq!(gather_head_k(&s, 1, 0), [30., 31.]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn window_geq_len_matches_unwindowed_and_odd_windows_use_slop() {
        let mut a = SessionStore::with_block_steps(BIG, KvPrecision::F32, 4);
        let mut b = SessionStore::with_block_steps(BIG, KvPrecision::F32, 4);
        a.create(1, 1, 1, 64).unwrap();
        b.create_windowed(1, 1, 1, 64, Some(64)).unwrap();
        let d: Vec<f32> = (0..10).map(|x| x as f32).collect();
        a.append(1, &d, &d, 10).unwrap();
        b.append(1, &d, &d, 10).unwrap();
        assert_eq!(b.window_trims, 0);
        assert_eq!(gather_head_k(&a, 1, 0), gather_head_k(&b, 1, 0));
        // window 3 over block_steps 4: the attended suffix crosses a block
        // boundary and the sub-block slop hides behind the view offset
        let mut c = SessionStore::with_block_steps(BIG, KvPrecision::F32, 4);
        c.create_windowed(1, 1, 1, 64, Some(3)).unwrap();
        c.append(1, &d, &d, 10).unwrap();
        assert_eq!(c.get(1).unwrap().attended(), 3);
        assert_eq!(gather_head_k(&c, 1, 0), [7., 8., 9.]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn fork_inherits_window_and_set_window_guards() {
        let mut s = SessionStore::with_block_steps(BIG, KvPrecision::F32, 2);
        s.create_windowed(1, 1, 1, 8, Some(4)).unwrap();
        let d: Vec<f32> = (0..6).map(|x| x as f32).collect();
        s.append(1, &d, &d, 6).unwrap(); // start 2, live 4
        s.fork(1, 2).unwrap();
        let t = s.get(2).unwrap();
        assert_eq!(t.window, Some(4));
        assert_eq!(t.start, 2);
        // widening past trimmed history is a typed error...
        assert!(s.set_window(2, Some(8)).is_err());
        assert!(s.set_window(2, None).is_err());
        // ...narrowing trims immediately, without touching the sibling
        s.set_window(2, Some(2)).unwrap();
        assert_eq!(gather_head_k(&s, 2, 0), [4., 5.]);
        assert_eq!(gather_head_k(&s, 1, 0), [2., 3., 4., 5.], "sibling window unaffected");
        assert!(s.create_windowed(9, 1, 1, 4, Some(0)).is_err());
        s.check_invariants().unwrap();
    }

    #[test]
    fn paged_gather_bitmatches_contiguous_reference() {
        // Deterministic pseudo-data, odd block size, all three precisions:
        // gathered per-head views must equal the quantize-projected
        // contiguous sequence element for element.
        for prec in [KvPrecision::F32, KvPrecision::Bf16, KvPrecision::Fp8] {
            let (heads, d, bs) = (2, 3, 5);
            let mut s = SessionStore::with_block_steps(BIG, prec, bs);
            s.create(1, heads, d, 64).unwrap();
            let mut expect_k: Vec<KvStore> = (0..heads).map(|_| KvStore::zeros(prec, 0)).collect();
            let mut x = 0.0f32;
            let mut total = 0usize;
            for n in [1usize, 4, 7, 2, 9] {
                let mut k_new = vec![0.0f32; heads * n * d];
                for h in 0..heads {
                    for i in 0..n {
                        for e in 0..d {
                            x += 0.37;
                            k_new[(h * n + i) * d + e] = x * if e % 2 == 0 { 1.0 } else { -1.0 };
                        }
                    }
                }
                let v_new = k_new.clone();
                s.append(1, &k_new, &v_new, n).unwrap();
                for h in 0..heads {
                    expect_k[h].extend_from_f32(&k_new[h * n * d..(h + 1) * n * d]);
                }
                total += n;
            }
            assert_eq!(s.get(1).unwrap().len, total);
            for h in 0..heads {
                assert_eq!(gather_head_k(&s, 1, h), expect_k[h].to_f32_vec(), "{prec:?} head {h}");
            }
            s.check_invariants().unwrap();
        }
    }
}
