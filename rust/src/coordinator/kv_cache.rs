//! Per-session KV cache with capacity accounting and LRU eviction — the
//! state the decode path reads instead of re-shipping the whole context on
//! every token.
//!
//! Layout matches the attention artifacts: K and V are (heads, cap,
//! head_dim) flat with the live prefix `len` valid and the tail zero-padded
//! (the artifacts mask by `kv_len`, so padding content is irrelevant —
//! zeros keep buffers deterministic).
//!
//! Since the quantized-KV PR, both tensors live in a [`KvStore`]: f32 at
//! full precision (the default, bit-identical to the old layout) or
//! bf16/fp8 quantized *at rest*. Quantization happens once on append;
//! reads hand out a [`KvRef`] that the kernels dequantize tile-by-tile
//! into per-worker scratch, so a bf16 session holds half — and an fp8
//! session a quarter — of the f32 cache bytes, which the LRU byte budget
//! accounts for exactly.

use std::collections::HashMap;

use crate::numerics::bf16::Bf16;
use crate::numerics::fp8::Fp8E4M3;
use crate::numerics::quant::{KvPrecision, KvRef};

/// Backing storage for one K or V tensor at a chosen [`KvPrecision`].
/// The f32 variant reads back bit-exactly; the quantized variants are a
/// round-to-nearest-even projection applied once at append time (so the
/// kernel output over a quantized store equals the f32 kernel run over
/// the dequantized array, bit for bit).
#[derive(Clone, Debug)]
pub enum KvStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    Fp8(Vec<u8>),
}

impl KvStore {
    /// An all-zero store of `n` elements (zero encodes exactly in every
    /// supported format, so padding stays deterministic).
    pub fn zeros(prec: KvPrecision, n: usize) -> KvStore {
        match prec {
            KvPrecision::F32 => KvStore::F32(vec![0.0; n]),
            KvPrecision::Bf16 => KvStore::Bf16(vec![0u16; n]),
            KvPrecision::Fp8 => KvStore::Fp8(vec![0u8; n]),
        }
    }

    pub fn precision(&self) -> KvPrecision {
        match self {
            KvStore::F32(_) => KvPrecision::F32,
            KvStore::Bf16(_) => KvPrecision::Bf16,
            KvStore::Fp8(_) => KvPrecision::Fp8,
        }
    }

    /// Element count (not bytes).
    pub fn len(&self) -> usize {
        match self {
            KvStore::F32(b) => b.len(),
            KvStore::Bf16(b) => b.len(),
            KvStore::Fp8(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the backing buffer.
    pub fn bytes(&self) -> usize {
        self.len() * self.precision().bytes_per_elem()
    }

    /// Borrow the storage as the kernel-facing [`KvRef`].
    pub fn as_kv(&self) -> KvRef<'_> {
        match self {
            KvStore::F32(b) => KvRef::F32(b),
            KvStore::Bf16(b) => KvRef::Bf16(b),
            KvStore::Fp8(b) => KvRef::Fp8(b),
        }
    }

    /// Quantize-and-write `src` at element offset `at` (the single
    /// rounding point of the storage path).
    pub fn store(&mut self, at: usize, src: &[f32]) {
        match self {
            KvStore::F32(b) => b[at..at + src.len()].copy_from_slice(src),
            KvStore::Bf16(b) => {
                for (dst, &x) in b[at..at + src.len()].iter_mut().zip(src) {
                    *dst = Bf16::from_f32(x).to_bits();
                }
            }
            KvStore::Fp8(b) => {
                for (dst, &x) in b[at..at + src.len()].iter_mut().zip(src) {
                    *dst = Fp8E4M3::from_f32(x).to_bits();
                }
            }
        }
    }

    /// Quantize-and-append `src` at the end of the buffer.
    pub fn extend_from_f32(&mut self, src: &[f32]) {
        match self {
            KvStore::F32(b) => b.extend_from_slice(src),
            KvStore::Bf16(b) => b.extend(src.iter().map(|&x| Bf16::from_f32(x).to_bits())),
            KvStore::Fp8(b) => b.extend(src.iter().map(|&x| Fp8E4M3::from_f32(x).to_bits())),
        }
    }

    /// Dequantize the whole buffer (test/debug convenience; the hot paths
    /// dequantize tile-by-tile through [`KvRef`] instead).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.as_kv().to_f32_vec()
    }
}

/// One session's cached keys/values.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub heads: usize,
    pub head_dim: usize,
    pub cap: usize,
    pub len: usize,
    /// (heads, cap, head_dim) flat, zero-padded beyond `len`.
    pub k: KvStore,
    pub v: KvStore,
}

impl KvCache {
    pub fn new(heads: usize, head_dim: usize, cap: usize) -> KvCache {
        KvCache::with_precision(heads, head_dim, cap, KvPrecision::F32)
    }

    pub fn with_precision(
        heads: usize,
        head_dim: usize,
        cap: usize,
        prec: KvPrecision,
    ) -> KvCache {
        KvCache {
            heads,
            head_dim,
            cap,
            len: 0,
            k: KvStore::zeros(prec, heads * cap * head_dim),
            v: KvStore::zeros(prec, heads * cap * head_dim),
        }
    }

    pub fn precision(&self) -> KvPrecision {
        self.k.precision()
    }

    pub fn bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes()
    }

    pub fn remaining(&self) -> usize {
        self.cap - self.len
    }

    /// Append `n` KV pairs given as (heads, n, head_dim) flat slices.
    /// Fails (leaving the cache untouched) if capacity would be exceeded.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], n: usize) -> Result<(), String> {
        let hd = self.heads * self.head_dim;
        if k_new.len() != hd * n || v_new.len() != hd * n {
            return Err(format!("append: expected {} elems, got {}", hd * n, k_new.len()));
        }
        if self.len + n > self.cap {
            return Err(format!("kv cache full: {} + {n} > {}", self.len, self.cap));
        }
        for h in 0..self.heads {
            for i in 0..n {
                let src = (h * n + i) * self.head_dim;
                let dst = (h * self.cap + self.len + i) * self.head_dim;
                self.k.store(dst, &k_new[src..src + self.head_dim]);
                self.v.store(dst, &v_new[src..src + self.head_dim]);
            }
        }
        self.len += n;
        Ok(())
    }
}

/// Session store with LRU eviction under a byte budget. All sessions
/// share one storage precision, fixed at construction.
#[derive(Debug)]
pub struct SessionStore {
    sessions: HashMap<u64, KvCache>,
    /// Recency order: front = least recently used.
    lru: Vec<u64>,
    pub max_bytes: usize,
    pub bytes: usize,
    pub evictions: u64,
    pub precision: KvPrecision,
}

impl SessionStore {
    pub fn new(max_bytes: usize) -> SessionStore {
        SessionStore::with_precision(max_bytes, KvPrecision::F32)
    }

    pub fn with_precision(max_bytes: usize, precision: KvPrecision) -> SessionStore {
        SessionStore {
            sessions: HashMap::new(),
            lru: Vec::new(),
            max_bytes,
            bytes: 0,
            evictions: 0,
            precision,
        }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    fn touch(&mut self, id: u64) {
        if let Some(pos) = self.lru.iter().position(|&x| x == id) {
            self.lru.remove(pos);
        }
        self.lru.push(id);
    }

    /// Create a session (evicting LRU sessions if needed). Replaces any
    /// existing cache under the same id.
    pub fn create(&mut self, id: u64, heads: usize, head_dim: usize, cap: usize) -> Result<(), String> {
        let cache = KvCache::with_precision(heads, head_dim, cap, self.precision);
        let need = cache.bytes();
        if need > self.max_bytes {
            return Err(format!("session of {need} bytes exceeds budget {}", self.max_bytes));
        }
        self.remove(id);
        while self.bytes + need > self.max_bytes {
            let victim = *self.lru.first().ok_or("lru empty but over budget")?;
            self.remove(victim);
            self.evictions += 1;
        }
        self.bytes += need;
        self.sessions.insert(id, cache);
        self.touch(id);
        Ok(())
    }

    /// Access a session mutably, refreshing its recency.
    pub fn get_mut(&mut self, id: u64) -> Option<&mut KvCache> {
        if self.sessions.contains_key(&id) {
            self.touch(id);
        }
        self.sessions.get_mut(&id)
    }

    pub fn get(&self, id: u64) -> Option<&KvCache> {
        self.sessions.get(&id)
    }

    /// Borrow several sessions' caches simultaneously — the fused dispatch
    /// gather phase: one drain cycle reads many sessions at once, after all
    /// of the cycle's mutations (creates/appends) are done. Duplicates are
    /// allowed; a missing id yields `None` in its slot so the caller can
    /// degrade per session instead of failing the whole cycle.
    pub fn borrow_many(&self, ids: &[u64]) -> Vec<Option<&KvCache>> {
        ids.iter().map(|&id| self.get(id)).collect()
    }

    /// Would creating (or re-creating) session `id` with this geometry
    /// evict any *other* session to fit the byte budget? The fused
    /// dispatcher flushes its current fusion group before such a create,
    /// so caches an earlier batch in the cycle reads can't vanish between
    /// lowering and kernel submission.
    pub fn would_evict(&self, id: u64, heads: usize, head_dim: usize, cap: usize) -> bool {
        let need = 2 * heads * cap * head_dim * self.precision.bytes_per_elem();
        let freed = self.sessions.get(&id).map(KvCache::bytes).unwrap_or(0);
        self.bytes - freed + need > self.max_bytes
    }

    pub fn remove(&mut self, id: u64) {
        if let Some(c) = self.sessions.remove(&id) {
            self.bytes -= c.bytes();
        }
        if let Some(pos) = self.lru.iter().position(|&x| x == id) {
            self.lru.remove(pos);
        }
    }

    /// Internal-consistency check used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.lru.len() != self.sessions.len() {
            return Err(format!("lru {} != sessions {}", self.lru.len(), self.sessions.len()));
        }
        let bytes: usize = self.sessions.values().map(KvCache::bytes).sum();
        if bytes != self.bytes {
            return Err(format!("bytes {} != accounted {}", bytes, self.bytes));
        }
        if self.bytes > self.max_bytes {
            return Err(format!("over budget: {} > {}", self.bytes, self.max_bytes));
        }
        for c in self.sessions.values() {
            if c.len > c.cap {
                return Err("cache len > cap".into());
            }
            if c.precision() != self.precision || c.v.precision() != self.precision {
                return Err("cache precision != store precision".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_layout_round_trips() {
        let mut c = KvCache::new(2, 3, 4);
        // two heads, one pair: head0 = [1,2,3], head1 = [4,5,6]
        c.append(&[1., 2., 3., 4., 5., 6.], &[9., 9., 9., 8., 8., 8.], 1).unwrap();
        assert_eq!(c.len, 1);
        let kf = c.k.to_f32_vec();
        assert_eq!(&kf[0..3], &[1., 2., 3.]); // head 0, slot 0
        assert_eq!(&kf[4 * 3..4 * 3 + 3], &[4., 5., 6.]); // head 1, slot 0
        c.append(&[10., 11., 12., 13., 14., 15.], &[0.; 6], 1).unwrap();
        assert_eq!(&c.k.to_f32_vec()[3..6], &[10., 11., 12.]); // head 0, slot 1
        assert_eq!(c.remaining(), 2);
    }

    #[test]
    fn append_over_capacity_fails_cleanly() {
        let mut c = KvCache::new(1, 2, 2);
        c.append(&[1., 2.], &[3., 4.], 1).unwrap();
        c.append(&[5., 6.], &[7., 8.], 1).unwrap();
        let before = c.k.to_f32_vec();
        assert!(c.append(&[9., 9.], &[9., 9.], 1).is_err());
        assert_eq!(c.k.to_f32_vec(), before);
        assert_eq!(c.len, 2);
    }

    #[test]
    fn quantized_append_is_single_rounding_projection() {
        use crate::numerics::quant::{quantize_bf16, quantize_fp8};
        let vals = [0.1f32, -1.75, 3.25, 0.0, 448.0, -0.007];
        for prec in [KvPrecision::Bf16, KvPrecision::Fp8] {
            let mut c = KvCache::with_precision(1, 3, 2, prec);
            c.append(&vals[..3], &vals[3..], 1).unwrap();
            let kf = c.k.to_f32_vec();
            let want: Vec<f32> = match prec {
                KvPrecision::Bf16 => {
                    quantize_bf16(&vals[..3]).iter().map(|&b| Bf16(b).to_f32()).collect()
                }
                _ => quantize_fp8(&vals[..3]).iter().map(|&b| Fp8E4M3(b).to_f32()).collect(),
            };
            assert_eq!(&kf[..3], &want[..], "{prec:?}");
            // appending the dequantized values back is a fixed point
            let mut c2 = KvCache::with_precision(1, 3, 2, prec);
            c2.append(&kf[..3], &c.v.to_f32_vec()[..3], 1).unwrap();
            assert_eq!(c2.k.to_f32_vec()[..3], kf[..3], "{prec:?}");
        }
    }

    #[test]
    fn bytes_track_precision() {
        let f = KvCache::new(2, 4, 8);
        let b = KvCache::with_precision(2, 4, 8, KvPrecision::Bf16);
        let q = KvCache::with_precision(2, 4, 8, KvPrecision::Fp8);
        assert_eq!(f.bytes(), 2 * 2 * 4 * 8 * 4);
        assert_eq!(b.bytes(), f.bytes() / 2);
        assert_eq!(q.bytes(), f.bytes() / 4);
        assert_eq!(b.precision(), KvPrecision::Bf16);
    }

    #[test]
    fn store_lru_eviction() {
        // each session: 1 head * cap 4 * dim 2 * 2 tensors * 4B = 64B
        let mut s = SessionStore::new(128);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        s.check_invariants().unwrap();
        // touch 1 so 2 becomes LRU
        s.get_mut(1).unwrap();
        s.create(3, 1, 2, 4).unwrap(); // evicts 2
        assert!(s.contains(1) && s.contains(3) && !s.contains(2));
        assert_eq!(s.evictions, 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn quantized_store_fits_more_sessions_in_budget() {
        // 128B fits two f32 sessions of this geometry, but four bf16 ones.
        let mut s = SessionStore::with_precision(128, KvPrecision::Bf16);
        for id in 1..=4 {
            s.create(id, 1, 2, 4).unwrap();
        }
        assert_eq!(s.len(), 4);
        assert_eq!(s.evictions, 0);
        s.check_invariants().unwrap();
        s.create(5, 1, 2, 4).unwrap(); // fifth evicts the LRU
        assert_eq!(s.evictions, 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn borrow_many_takes_simultaneous_refs() {
        let mut s = SessionStore::new(1024);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        s.get_mut(1).unwrap().append(&[1., 2.], &[3., 4.], 1).unwrap();
        s.get_mut(2).unwrap().append(&[5., 6.], &[7., 8.], 1).unwrap();
        // duplicates and repeats are fine; all refs are alive at once
        let caches = s.borrow_many(&[1, 2, 1]);
        assert_eq!(caches.len(), 3);
        assert_eq!(caches[0].unwrap().k.to_f32_vec()[0], 1.0);
        assert_eq!(caches[1].unwrap().k.to_f32_vec()[0], 5.0);
        assert_eq!(
            caches[2].unwrap().k.to_f32_vec()[0],
            caches[0].unwrap().k.to_f32_vec()[0]
        );
        // a missing id degrades to None in its slot, not a whole failure
        let partial = s.borrow_many(&[1, 9]);
        assert!(partial[0].is_some() && partial[1].is_none());
    }

    #[test]
    fn would_evict_predicts_create() {
        // budget fits exactly two sessions of this geometry (64B each)
        let mut s = SessionStore::new(128);
        s.create(1, 1, 2, 4).unwrap();
        assert!(!s.would_evict(2, 1, 2, 4), "second session fits");
        s.create(2, 1, 2, 4).unwrap();
        assert!(s.would_evict(3, 1, 2, 4), "third must evict");
        // re-creating an existing id frees its own bytes first
        assert!(!s.would_evict(1, 1, 2, 4), "replace never evicts others");
        assert!(s.would_evict(1, 1, 2, 8), "larger replace does");
    }

    #[test]
    fn create_too_large_rejected() {
        let mut s = SessionStore::new(32);
        assert!(s.create(1, 4, 64, 128).is_err());
        assert!(s.is_empty());
    }

    #[test]
    fn recreate_replaces() {
        let mut s = SessionStore::new(1024);
        s.create(7, 1, 2, 4).unwrap();
        s.get_mut(7).unwrap().append(&[1., 2.], &[3., 4.], 1).unwrap();
        s.create(7, 1, 2, 4).unwrap();
        assert_eq!(s.get(7).unwrap().len, 0);
        assert_eq!(s.len(), 1);
        s.check_invariants().unwrap();
    }
}
