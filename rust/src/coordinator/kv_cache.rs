//! Per-session KV cache with capacity accounting and LRU eviction — the
//! state the decode path reads instead of re-shipping the whole context on
//! every token.
//!
//! Layout matches the attention artifacts: K and V are (heads, cap,
//! head_dim) flat with the live prefix `len` valid and the tail zero-padded
//! (the artifacts mask by `kv_len`, so padding content is irrelevant —
//! zeros keep buffers deterministic).

use std::collections::HashMap;

/// One session's cached keys/values.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub heads: usize,
    pub head_dim: usize,
    pub cap: usize,
    pub len: usize,
    /// (heads, cap, head_dim) flat, zero-padded beyond `len`.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvCache {
    pub fn new(heads: usize, head_dim: usize, cap: usize) -> KvCache {
        KvCache {
            heads,
            head_dim,
            cap,
            len: 0,
            k: vec![0.0; heads * cap * head_dim],
            v: vec![0.0; heads * cap * head_dim],
        }
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    pub fn remaining(&self) -> usize {
        self.cap - self.len
    }

    /// Append `n` KV pairs given as (heads, n, head_dim) flat slices.
    /// Fails (leaving the cache untouched) if capacity would be exceeded.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], n: usize) -> Result<(), String> {
        let hd = self.heads * self.head_dim;
        if k_new.len() != hd * n || v_new.len() != hd * n {
            return Err(format!("append: expected {} elems, got {}", hd * n, k_new.len()));
        }
        if self.len + n > self.cap {
            return Err(format!("kv cache full: {} + {n} > {}", self.len, self.cap));
        }
        for h in 0..self.heads {
            for i in 0..n {
                let src = (h * n + i) * self.head_dim;
                let dst = (h * self.cap + self.len + i) * self.head_dim;
                self.k[dst..dst + self.head_dim].copy_from_slice(&k_new[src..src + self.head_dim]);
                self.v[dst..dst + self.head_dim].copy_from_slice(&v_new[src..src + self.head_dim]);
            }
        }
        self.len += n;
        Ok(())
    }
}

/// Session store with LRU eviction under a byte budget.
#[derive(Debug)]
pub struct SessionStore {
    sessions: HashMap<u64, KvCache>,
    /// Recency order: front = least recently used.
    lru: Vec<u64>,
    pub max_bytes: usize,
    pub bytes: usize,
    pub evictions: u64,
}

impl SessionStore {
    pub fn new(max_bytes: usize) -> SessionStore {
        SessionStore { sessions: HashMap::new(), lru: Vec::new(), max_bytes, bytes: 0, evictions: 0 }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    fn touch(&mut self, id: u64) {
        if let Some(pos) = self.lru.iter().position(|&x| x == id) {
            self.lru.remove(pos);
        }
        self.lru.push(id);
    }

    /// Create a session (evicting LRU sessions if needed). Replaces any
    /// existing cache under the same id.
    pub fn create(&mut self, id: u64, heads: usize, head_dim: usize, cap: usize) -> Result<(), String> {
        let cache = KvCache::new(heads, head_dim, cap);
        let need = cache.bytes();
        if need > self.max_bytes {
            return Err(format!("session of {need} bytes exceeds budget {}", self.max_bytes));
        }
        self.remove(id);
        while self.bytes + need > self.max_bytes {
            let victim = *self.lru.first().ok_or("lru empty but over budget")?;
            self.remove(victim);
            self.evictions += 1;
        }
        self.bytes += need;
        self.sessions.insert(id, cache);
        self.touch(id);
        Ok(())
    }

    /// Access a session mutably, refreshing its recency.
    pub fn get_mut(&mut self, id: u64) -> Option<&mut KvCache> {
        if self.sessions.contains_key(&id) {
            self.touch(id);
        }
        self.sessions.get_mut(&id)
    }

    pub fn get(&self, id: u64) -> Option<&KvCache> {
        self.sessions.get(&id)
    }

    /// Borrow several sessions' caches simultaneously — the fused dispatch
    /// gather phase: one drain cycle reads many sessions at once, after all
    /// of the cycle's mutations (creates/appends) are done. Duplicates are
    /// allowed; a missing id yields `None` in its slot so the caller can
    /// degrade per session instead of failing the whole cycle.
    pub fn borrow_many(&self, ids: &[u64]) -> Vec<Option<&KvCache>> {
        ids.iter().map(|&id| self.get(id)).collect()
    }

    /// Would creating (or re-creating) session `id` with this geometry
    /// evict any *other* session to fit the byte budget? The fused
    /// dispatcher flushes its current fusion group before such a create,
    /// so caches an earlier batch in the cycle reads can't vanish between
    /// lowering and kernel submission.
    pub fn would_evict(&self, id: u64, heads: usize, head_dim: usize, cap: usize) -> bool {
        let need = 2 * heads * cap * head_dim * std::mem::size_of::<f32>();
        let freed = self.sessions.get(&id).map(KvCache::bytes).unwrap_or(0);
        self.bytes - freed + need > self.max_bytes
    }

    pub fn remove(&mut self, id: u64) {
        if let Some(c) = self.sessions.remove(&id) {
            self.bytes -= c.bytes();
        }
        if let Some(pos) = self.lru.iter().position(|&x| x == id) {
            self.lru.remove(pos);
        }
    }

    /// Internal-consistency check used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.lru.len() != self.sessions.len() {
            return Err(format!("lru {} != sessions {}", self.lru.len(), self.sessions.len()));
        }
        let bytes: usize = self.sessions.values().map(KvCache::bytes).sum();
        if bytes != self.bytes {
            return Err(format!("bytes {} != accounted {}", bytes, self.bytes));
        }
        if self.bytes > self.max_bytes {
            return Err(format!("over budget: {} > {}", self.bytes, self.max_bytes));
        }
        for c in self.sessions.values() {
            if c.len > c.cap {
                return Err("cache len > cap".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_layout_round_trips() {
        let mut c = KvCache::new(2, 3, 4);
        // two heads, one pair: head0 = [1,2,3], head1 = [4,5,6]
        c.append(&[1., 2., 3., 4., 5., 6.], &[9., 9., 9., 8., 8., 8.], 1).unwrap();
        assert_eq!(c.len, 1);
        assert_eq!(&c.k[0..3], &[1., 2., 3.]); // head 0, slot 0
        assert_eq!(&c.k[4 * 3..4 * 3 + 3], &[4., 5., 6.]); // head 1, slot 0
        c.append(&[10., 11., 12., 13., 14., 15.], &[0.; 6], 1).unwrap();
        assert_eq!(&c.k[3..6], &[10., 11., 12.]); // head 0, slot 1
        assert_eq!(c.remaining(), 2);
    }

    #[test]
    fn append_over_capacity_fails_cleanly() {
        let mut c = KvCache::new(1, 2, 2);
        c.append(&[1., 2.], &[3., 4.], 1).unwrap();
        c.append(&[5., 6.], &[7., 8.], 1).unwrap();
        let before = c.k.clone();
        assert!(c.append(&[9., 9.], &[9., 9.], 1).is_err());
        assert_eq!(c.k, before);
        assert_eq!(c.len, 2);
    }

    #[test]
    fn store_lru_eviction() {
        // each session: 1 head * cap 4 * dim 2 * 2 tensors * 4B = 64B
        let mut s = SessionStore::new(128);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        s.check_invariants().unwrap();
        // touch 1 so 2 becomes LRU
        s.get_mut(1).unwrap();
        s.create(3, 1, 2, 4).unwrap(); // evicts 2
        assert!(s.contains(1) && s.contains(3) && !s.contains(2));
        assert_eq!(s.evictions, 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn borrow_many_takes_simultaneous_refs() {
        let mut s = SessionStore::new(1024);
        s.create(1, 1, 2, 4).unwrap();
        s.create(2, 1, 2, 4).unwrap();
        s.get_mut(1).unwrap().append(&[1., 2.], &[3., 4.], 1).unwrap();
        s.get_mut(2).unwrap().append(&[5., 6.], &[7., 8.], 1).unwrap();
        // duplicates and repeats are fine; all refs are alive at once
        let caches = s.borrow_many(&[1, 2, 1]);
        assert_eq!(caches.len(), 3);
        assert_eq!(caches[0].unwrap().k[0], 1.0);
        assert_eq!(caches[1].unwrap().k[0], 5.0);
        assert_eq!(caches[2].unwrap().k[0], caches[0].unwrap().k[0]);
        // a missing id degrades to None in its slot, not a whole failure
        let partial = s.borrow_many(&[1, 9]);
        assert!(partial[0].is_some() && partial[1].is_none());
    }

    #[test]
    fn would_evict_predicts_create() {
        // budget fits exactly two sessions of this geometry (64B each)
        let mut s = SessionStore::new(128);
        s.create(1, 1, 2, 4).unwrap();
        assert!(!s.would_evict(2, 1, 2, 4), "second session fits");
        s.create(2, 1, 2, 4).unwrap();
        assert!(s.would_evict(3, 1, 2, 4), "third must evict");
        // re-creating an existing id frees its own bytes first
        assert!(!s.would_evict(1, 1, 2, 4), "replace never evicts others");
        assert!(s.would_evict(1, 1, 2, 8), "larger replace does");
    }

    #[test]
    fn create_too_large_rejected() {
        let mut s = SessionStore::new(32);
        assert!(s.create(1, 4, 64, 128).is_err());
        assert!(s.is_empty());
    }

    #[test]
    fn recreate_replaces() {
        let mut s = SessionStore::new(1024);
        s.create(7, 1, 2, 4).unwrap();
        s.get_mut(7).unwrap().append(&[1., 2.], &[3., 4.], 1).unwrap();
        s.create(7, 1, 2, 4).unwrap();
        assert_eq!(s.get(7).unwrap().len, 0);
        assert_eq!(s.len(), 1);
        s.check_invariants().unwrap();
    }
}
