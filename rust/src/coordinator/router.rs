//! Shape routing: picks the compiled attention artifact for a request and
//! decides how the problem pads into it.
//!
//! The AOT artifacts are fixed-shape (heads, seq, head_dim); the router
//! selects, per (variant, signature), the smallest compiled `seq` that fits
//! the live KV length — exactly how a fixed-function accelerator with a few
//! provisioned context sizes would be driven.

use super::request::{ShapeSig, Variant};
use crate::runtime::Manifest;

/// A routing decision: which artifact, and the padded geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct Route {
    pub artifact: String,
    pub heads: usize,
    pub head_dim: usize,
    /// Compiled query-row capacity (the parallel query block size).
    pub q_slots: usize,
    /// Compiled KV capacity.
    pub kv_slots: usize,
}

/// The router: a snapshot of available (variant, shape) -> artifact entries.
#[derive(Clone, Debug, Default)]
pub struct Router {
    /// (variant, heads, head_dim) -> sorted [(seq, artifact_name)]
    entries: Vec<(Variant, usize, usize, Vec<(usize, String)>)>,
}

impl Router {
    /// Build from the manifest (non-causal serving artifacts only).
    pub fn from_manifest(man: &Manifest) -> Router {
        let mut r = Router::default();
        for variant in [Variant::FlashD, Variant::Flash2] {
            let vs = variant.artifact_str();
            let mut by_sig: Vec<(usize, usize, Vec<(usize, String)>)> = Vec::new();
            for a in man.artifacts.values() {
                if a.kind != "attention" || a.causal || a.variant.as_deref() != Some(vs) {
                    continue;
                }
                match by_sig.iter_mut().find(|(h, d, _)| *h == a.heads && *d == a.head_dim) {
                    Some((_, _, v)) => v.push((a.seq, a.name.clone())),
                    None => by_sig.push((a.heads, a.head_dim, vec![(a.seq, a.name.clone())])),
                }
            }
            for (h, d, mut v) in by_sig {
                v.sort();
                r.entries.push((variant, h, d, v));
            }
        }
        r
    }

    /// All signatures servable for a variant.
    pub fn signatures(&self, variant: Variant) -> Vec<ShapeSig> {
        self.entries
            .iter()
            .filter(|(v, _, _, _)| *v == variant)
            .map(|(_, h, d, _)| ShapeSig { heads: *h, head_dim: *d })
            .collect()
    }

    /// Route a problem: `nq` query rows against `nkv` live KV pairs.
    pub fn route(&self, variant: Variant, sig: ShapeSig, nq: usize, nkv: usize) -> Result<Route, String> {
        let (_, _, _, seqs) = self
            .entries
            .iter()
            .find(|(v, h, d, _)| *v == variant && *h == sig.heads && *d == sig.head_dim)
            .ok_or_else(|| {
                format!(
                    "no compiled artifact for variant={variant:?} heads={} head_dim={}",
                    sig.heads, sig.head_dim
                )
            })?;
        let need = nkv.max(nq); // q rows and kv pairs share the seq axis
        let (seq, name) = seqs
            .iter()
            .find(|(s, _)| *s >= need)
            .ok_or_else(|| {
                format!("problem size {need} exceeds largest compiled seq {}", seqs.last().map(|(s, _)| *s).unwrap_or(0))
            })?;
        Ok(Route {
            artifact: name.clone(),
            heads: sig.heads,
            head_dim: sig.head_dim,
            q_slots: *seq,
            kv_slots: *seq,
        })
    }

    /// The maximum KV capacity servable for a signature (used to size
    /// session caches).
    pub fn max_kv(&self, variant: Variant, sig: ShapeSig) -> Option<usize> {
        self.entries
            .iter()
            .find(|(v, h, d, _)| *v == variant && *h == sig.heads && *d == sig.head_dim)
            .and_then(|(_, _, _, seqs)| seqs.last().map(|(s, _)| *s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "artifacts": {
            "attn_flashd_h4_l128_d32": {"file":"a","kind":"attention","variant":"flashd","causal":false,
              "heads":4,"seq":128,"head_dim":32,"inputs":[],"n_outputs":1},
            "attn_flashd_h4_l256_d32": {"file":"b","kind":"attention","variant":"flashd","causal":false,
              "heads":4,"seq":256,"head_dim":32,"inputs":[],"n_outputs":1},
            "attn_flashd_h4_l128_d32_causal": {"file":"c","kind":"attention","variant":"flashd","causal":true,
              "heads":4,"seq":128,"head_dim":32,"inputs":[],"n_outputs":1},
            "attn_flash2_h4_l128_d32": {"file":"d","kind":"attention","variant":"flash2","causal":false,
              "heads":4,"seq":128,"head_dim":32,"inputs":[],"n_outputs":1}
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn routes_to_smallest_fitting_seq() {
        let r = Router::from_manifest(&manifest());
        let sig = ShapeSig { heads: 4, head_dim: 32 };
        let route = r.route(Variant::FlashD, sig, 1, 100).unwrap();
        assert_eq!(route.artifact, "attn_flashd_h4_l128_d32");
        assert_eq!(route.kv_slots, 128);
        let route = r.route(Variant::FlashD, sig, 1, 129).unwrap();
        assert_eq!(route.artifact, "attn_flashd_h4_l256_d32");
    }

    #[test]
    fn causal_artifacts_not_served() {
        let r = Router::from_manifest(&manifest());
        let sig = ShapeSig { heads: 4, head_dim: 32 };
        // only two non-causal flashd seqs exist
        assert_eq!(r.max_kv(Variant::FlashD, sig), Some(256));
        assert_eq!(r.max_kv(Variant::Flash2, sig), Some(128));
    }

    #[test]
    fn unknown_signature_and_oversize_rejected() {
        let r = Router::from_manifest(&manifest());
        assert!(r.route(Variant::FlashD, ShapeSig { heads: 9, head_dim: 32 }, 1, 1).is_err());
        let sig = ShapeSig { heads: 4, head_dim: 32 };
        assert!(r.route(Variant::FlashD, sig, 1, 1000).is_err());
    }

    #[test]
    fn q_rows_also_constrain_route() {
        let r = Router::from_manifest(&manifest());
        let sig = ShapeSig { heads: 4, head_dim: 32 };
        let route = r.route(Variant::FlashD, sig, 200, 10).unwrap();
        assert_eq!(route.q_slots, 256);
    }
}
