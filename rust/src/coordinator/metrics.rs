//! Service metrics: lock-free counters + fixed-bucket latency histograms,
//! snapshotted by the serving bench and the `flashd serve` CLI.
//!
//! Besides the per-response latency histogram, the continuous-batching
//! worker publishes serving SLO signals: queue-wait (admission → cycle
//! dispatch), time-to-first-token and inter-token latency for streams, a
//! queue-depth gauge, and admission-deferral / stream-backpressure
//! counters.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram buckets in microseconds (upper bounds).
pub const BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, u64::MAX];

/// Fused-dispatch histogram buckets (upper bounds): block jobs per drain
/// cycle and query rows per fused submission.
pub const FUSE_BUCKETS: [u64; 9] = [1, 2, 4, 8, 16, 32, 64, 128, u64::MAX];

/// A lock-free duration histogram over [`BUCKETS_US`], reusable for any
/// microsecond-scale signal (queue wait, TTFT, inter-token gaps).
#[derive(Debug, Default)]
pub struct LatencyHisto {
    buckets: [AtomicU64; 12],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl LatencyHisto {
    pub fn observe(&self, us: u64) {
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        for (i, ub) in BUCKETS_US.iter().enumerate() {
            if us <= *ub {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }

    fn snap(&self) -> HistoSnap {
        HistoSnap {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`LatencyHisto`].
#[derive(Clone, Debug, Default)]
pub struct HistoSnap {
    pub buckets: Vec<u64>,
    pub sum_us: u64,
    pub count: u64,
}

impl HistoSnap {
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Approximate percentile (upper bound of the bucket containing the
    /// quantile).
    pub fn percentile_us(&self, p: f64) -> u64 {
        bucket_percentile(&self.buckets, p)
    }
}

/// Saturation value reported when a quantile lands in the unbounded
/// overflow bucket: one past the largest finite bucket bound, so render
/// shows `>100000µs` and JSON consumers see a finite number instead of
/// `u64::MAX` µs.
pub const SATURATED_US: u64 = BUCKETS_US[BUCKETS_US.len() - 2] + 1;

/// Upper bound of the [`BUCKETS_US`] bucket containing quantile `p` (in
/// percent) of the recorded samples; 0 when empty. A quantile in the
/// unbounded overflow bucket saturates to [`SATURATED_US`] rather than
/// reporting the bucket's `u64::MAX` bound.
fn bucket_percentile(buckets: &[u64], p: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (p / 100.0 * total as f64).ceil() as u64;
    let mut seen = 0;
    for (i, c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= target {
            return BUCKETS_US[i].min(SATURATED_US);
        }
    }
    SATURATED_US
}

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub kv_appends: AtomicU64,
    pub queue_rejections: AtomicU64,
    /// Drain cycles served through the fused cross-session path.
    pub fused_cycles: AtomicU64,
    /// Fused kernel submissions (`run_blocks` calls). Equal to
    /// `fused_cycles` when no cycle ever had to split on a session
    /// conflict — the acceptance signal that one cycle is one submission.
    pub fused_submissions: AtomicU64,
    /// Batches lowered through fused submissions.
    pub fused_batches: AtomicU64,
    /// Block jobs submitted (one per (batch, head)).
    pub fused_jobs: AtomicU64,
    /// Query rows served through fused submissions.
    pub fused_rows: AtomicU64,
    /// FLASH-D weight-update steps executed by fused submissions
    /// ([`crate::kernels::flashd::SkipStats::total`] sums).
    pub skip_steps: AtomicU64,
    /// Saturation-skipped steps (zero under `SkipCriterion::None`).
    pub skip_skipped: AtomicU64,
    /// Resident KV block-pool bytes (gauge: engine publishes the store's
    /// current value each drain cycle).
    pub kv_pool_bytes: AtomicU64,
    /// High-water mark of `kv_pool_bytes` over the store's lifetime.
    pub kv_pool_peak_bytes: AtomicU64,
    /// Live KV pool blocks (gauge).
    pub kv_pool_blocks: AtomicU64,
    /// Blocks actually freed by LRU eviction (a shared prefix block whose
    /// refcount stays positive is *not* counted — it survived).
    pub kv_block_evictions: AtomicU64,
    /// Blocks shared by reference instead of copied (fork/share_prefix).
    pub kv_prefix_share_hits: AtomicU64,
    /// Copy-on-write block clones (first divergent append to a shared
    /// tail, or a prefix share splitting a block).
    pub kv_cow_copies: AtomicU64,
    /// Sliding-window trim events: appends/prefills that advanced a
    /// windowed session's trimmed-prefix boundary.
    pub kv_window_trims: AtomicU64,
    /// Blocks released by window trimming (a block shared with a fork
    /// survives under its other owners and still counts — it left *this*
    /// session's table).
    pub kv_blocks_trimmed: AtomicU64,
    /// Scheduler queue depth after the most recent admission event
    /// (gauge).
    pub queue_depth: AtomicU64,
    /// Cycles that stopped admitting early because the next request's
    /// session mutations would evict live pool blocks mid-cycle (the
    /// deferred request leads the next cycle instead).
    pub admission_deferrals: AtomicU64,
    /// Streams opened via `submit_stream`.
    pub streams_opened: AtomicU64,
    /// Streams that reached their terminal `Done` event.
    pub streams_completed: AtomicU64,
    /// Streams parked by the concurrency limit before activation.
    pub streams_parked: AtomicU64,
    /// Streams whose client went away mid-generation (the event receiver
    /// was dropped): the worker aborts the stream, drops its queued
    /// requests, and frees the slot. Abandoned streams still count under
    /// `streams_completed` — they reached their terminal state.
    pub streams_abandoned: AtomicU64,
    /// Admission → cycle-dispatch wait per request.
    pub queue_wait: LatencyHisto,
    /// Stream admission → first token.
    pub ttft: LatencyHisto,
    /// Gap between consecutive tokens of a stream.
    pub itl: LatencyHisto,
    latency_buckets: [AtomicU64; 12],
    latency_sum_us: AtomicU64,
    jobs_per_cycle_buckets: [AtomicU64; 9],
    fused_width_buckets: [AtomicU64; 9],
}

fn bump_bucket(buckets: &[AtomicU64; 9], n: u64) {
    for (i, ub) in FUSE_BUCKETS.iter().enumerate() {
        if n <= *ub {
            buckets[i].fetch_add(1, Ordering::Relaxed);
            break;
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_latency(&self, us: u64) {
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        for (i, ub) in BUCKETS_US.iter().enumerate() {
            if us <= *ub {
                self.latency_buckets[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }

    /// Record one drain cycle's fused job count (0-job cycles — everything
    /// rejected in phase A — are not observed).
    pub fn observe_jobs_per_cycle(&self, jobs: u64) {
        if jobs > 0 {
            bump_bucket(&self.jobs_per_cycle_buckets, jobs);
        }
    }

    /// Record one fused submission's width in query rows.
    pub fn observe_fused_width(&self, rows: u64) {
        if rows > 0 {
            bump_bucket(&self.fused_width_buckets, rows);
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            kv_appends: self.kv_appends.load(Ordering::Relaxed),
            queue_rejections: self.queue_rejections.load(Ordering::Relaxed),
            fused_cycles: self.fused_cycles.load(Ordering::Relaxed),
            fused_submissions: self.fused_submissions.load(Ordering::Relaxed),
            fused_batches: self.fused_batches.load(Ordering::Relaxed),
            fused_jobs: self.fused_jobs.load(Ordering::Relaxed),
            fused_rows: self.fused_rows.load(Ordering::Relaxed),
            skip_steps: self.skip_steps.load(Ordering::Relaxed),
            skip_skipped: self.skip_skipped.load(Ordering::Relaxed),
            kv_pool_bytes: self.kv_pool_bytes.load(Ordering::Relaxed),
            kv_pool_peak_bytes: self.kv_pool_peak_bytes.load(Ordering::Relaxed),
            kv_pool_blocks: self.kv_pool_blocks.load(Ordering::Relaxed),
            kv_block_evictions: self.kv_block_evictions.load(Ordering::Relaxed),
            kv_prefix_share_hits: self.kv_prefix_share_hits.load(Ordering::Relaxed),
            kv_cow_copies: self.kv_cow_copies.load(Ordering::Relaxed),
            kv_window_trims: self.kv_window_trims.load(Ordering::Relaxed),
            kv_blocks_trimmed: self.kv_blocks_trimmed.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            admission_deferrals: self.admission_deferrals.load(Ordering::Relaxed),
            streams_opened: self.streams_opened.load(Ordering::Relaxed),
            streams_completed: self.streams_completed.load(Ordering::Relaxed),
            streams_parked: self.streams_parked.load(Ordering::Relaxed),
            streams_abandoned: self.streams_abandoned.load(Ordering::Relaxed),
            queue_wait: self.queue_wait.snap(),
            ttft: self.ttft.snap(),
            itl: self.itl.snap(),
            latency_buckets: self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            latency_sum_us: self.latency_sum_us.load(Ordering::Relaxed),
            jobs_per_cycle_buckets: self.jobs_per_cycle_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            fused_width_buckets: self.fused_width_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Point-in-time copy of the metrics.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub kv_appends: u64,
    pub queue_rejections: u64,
    pub fused_cycles: u64,
    pub fused_submissions: u64,
    pub fused_batches: u64,
    pub fused_jobs: u64,
    pub fused_rows: u64,
    pub skip_steps: u64,
    pub skip_skipped: u64,
    pub kv_pool_bytes: u64,
    pub kv_pool_peak_bytes: u64,
    pub kv_pool_blocks: u64,
    pub kv_block_evictions: u64,
    pub kv_prefix_share_hits: u64,
    pub kv_cow_copies: u64,
    pub kv_window_trims: u64,
    pub kv_blocks_trimmed: u64,
    pub queue_depth: u64,
    pub admission_deferrals: u64,
    pub streams_opened: u64,
    pub streams_completed: u64,
    pub streams_parked: u64,
    pub streams_abandoned: u64,
    pub queue_wait: HistoSnap,
    pub ttft: HistoSnap,
    pub itl: HistoSnap,
    pub latency_buckets: Vec<u64>,
    pub latency_sum_us: u64,
    pub jobs_per_cycle_buckets: Vec<u64>,
    pub fused_width_buckets: Vec<u64>,
}

impl Snapshot {
    /// Mean block jobs per fused drain cycle.
    pub fn mean_jobs_per_cycle(&self) -> f64 {
        if self.fused_cycles == 0 {
            0.0
        } else {
            self.fused_jobs as f64 / self.fused_cycles as f64
        }
    }
    pub fn mean_latency_us(&self) -> f64 {
        if self.responses == 0 {
            0.0
        } else {
            self.latency_sum_us as f64 / self.responses as f64
        }
    }

    /// Approximate percentile from the histogram (upper bound of the
    /// bucket containing the quantile).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        bucket_percentile(&self.latency_buckets, p)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    pub fn render(&self) -> String {
        let fmt_b = |us: u64| -> String {
            if us >= SATURATED_US { ">100000".into() } else { us.to_string() }
        };
        format!(
            "requests={} responses={} errors={} rejections={}\n\
             batches={} mean_batch={:.2} kv_appends={}\n\
             fused: cycles={} submissions={} batches={} jobs={} rows={} \
             jobs/cycle={:.2}\n\
             kernel steps={} skipped={}\n\
             kv pool: bytes={} peak={} blocks={} block_evictions={} \
             prefix_share_hits={} cow_copies={} window_trims={} \
             blocks_trimmed={}\n\
             queue: depth={} wait mean={:.0}µs p99<={}µs deferrals={}\n\
             streams: opened={} completed={} parked={} abandoned={} \
             ttft p50<={}µs p99<={}µs itl p50<={}µs p99<={}µs\n\
             latency: mean={:.0}µs p50<={}µs p95<={}µs p99<={}µs",
            self.requests,
            self.responses,
            self.errors,
            self.queue_rejections,
            self.batches,
            self.mean_batch_size(),
            self.kv_appends,
            self.fused_cycles,
            self.fused_submissions,
            self.fused_batches,
            self.fused_jobs,
            self.fused_rows,
            self.mean_jobs_per_cycle(),
            self.skip_steps,
            self.skip_skipped,
            self.kv_pool_bytes,
            self.kv_pool_peak_bytes,
            self.kv_pool_blocks,
            self.kv_block_evictions,
            self.kv_prefix_share_hits,
            self.kv_cow_copies,
            self.kv_window_trims,
            self.kv_blocks_trimmed,
            self.queue_depth,
            self.queue_wait.mean_us(),
            fmt_b(self.queue_wait.percentile_us(99.0)),
            self.admission_deferrals,
            self.streams_opened,
            self.streams_completed,
            self.streams_parked,
            self.streams_abandoned,
            fmt_b(self.ttft.percentile_us(50.0)),
            fmt_b(self.ttft.percentile_us(99.0)),
            fmt_b(self.itl.percentile_us(50.0)),
            fmt_b(self.itl.percentile_us(99.0)),
            self.mean_latency_us(),
            fmt_b(self.latency_percentile_us(50.0)),
            fmt_b(self.latency_percentile_us(95.0)),
            fmt_b(self.latency_percentile_us(99.0)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets() {
        let m = Metrics::new();
        m.responses.store(3, Ordering::Relaxed);
        m.observe_latency(40);
        m.observe_latency(900);
        m.observe_latency(70_000);
        let s = m.snapshot();
        assert_eq!(s.latency_buckets[0], 1); // <=50
        assert_eq!(s.latency_buckets[4], 1); // <=1000
        assert_eq!(s.latency_buckets[10], 1); // <=100000
        assert!((s.mean_latency_us() - (40.0 + 900.0 + 70_000.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for us in [10, 20, 30, 400, 5000, 99_000] {
            m.observe_latency(us);
        }
        let s = m.snapshot();
        assert!(s.latency_percentile_us(50.0) <= s.latency_percentile_us(95.0));
        assert!(s.latency_percentile_us(95.0) <= s.latency_percentile_us(99.9));
    }

    #[test]
    fn batch_size_mean() {
        let m = Metrics::new();
        m.batches.store(2, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size(), 5.0);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.latency_percentile_us(99.0), 0);
        assert_eq!(s.mean_jobs_per_cycle(), 0.0);
        assert!(s.render().contains("requests=0"));
        assert!(s.render().contains("fused: cycles=0"));
        assert!(s.render().contains("kv pool: bytes=0"));
        assert!(s.render().contains("queue: depth=0"));
        assert!(s.render().contains("streams: opened=0"));
    }

    #[test]
    fn latency_histo_observes_and_quantiles() {
        let h = LatencyHisto::default();
        for us in [10, 60, 300, 2_000, 200_000] {
            h.observe(us);
        }
        let s = h.snap();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_us, 10 + 60 + 300 + 2_000 + 200_000);
        assert_eq!(s.buckets[0], 1); // <=50
        assert_eq!(s.buckets[11], 1); // unbounded tail
        assert!((s.mean_us() - s.sum_us as f64 / 5.0).abs() < 1e-9);
        assert!(s.percentile_us(50.0) <= s.percentile_us(99.0));
        assert_eq!(s.percentile_us(99.0), SATURATED_US);
        assert_eq!(HistoSnap::default().percentile_us(99.0), 0);
    }

    /// Regression: a quantile landing in the unbounded overflow bucket
    /// must report the finite saturation sentinel, not `u64::MAX` µs —
    /// both through `HistoSnap::percentile_us` and the latency histogram.
    #[test]
    fn overflow_bucket_percentile_saturates_finite() {
        let h = LatencyHisto::default();
        for _ in 0..4 {
            h.observe(250_000); // all samples beyond the 100ms bound
        }
        let s = h.snap();
        assert_eq!(s.percentile_us(50.0), SATURATED_US);
        assert_eq!(s.percentile_us(99.0), SATURATED_US);
        assert!(s.percentile_us(99.0) < u64::MAX, "must stay finite");
        assert_eq!(SATURATED_US, 100_001);

        let m = Metrics::new();
        m.observe_latency(10);
        m.observe_latency(500_000);
        let snap = m.snapshot();
        assert_eq!(snap.latency_percentile_us(99.0), SATURATED_US);
        let r = snap.render();
        assert!(r.contains(">100000"), "render must show the saturated sentinel: {r}");
        assert!(!r.contains(&u64::MAX.to_string()), "u64::MAX must never render: {r}");
    }

    #[test]
    fn serving_histograms_land_in_snapshot_and_render() {
        let m = Metrics::new();
        m.queue_wait.observe(120);
        m.ttft.observe(800);
        m.ttft.observe(900);
        m.itl.observe(40);
        m.queue_depth.store(3, Ordering::Relaxed);
        m.admission_deferrals.store(2, Ordering::Relaxed);
        m.streams_opened.store(4, Ordering::Relaxed);
        m.streams_completed.store(4, Ordering::Relaxed);
        m.streams_parked.store(1, Ordering::Relaxed);
        m.streams_abandoned.store(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.queue_wait.count, 1);
        assert_eq!(s.ttft.count, 2);
        assert_eq!(s.itl.count, 1);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.admission_deferrals, 2);
        assert_eq!(s.streams_parked, 1);
        assert_eq!(s.streams_abandoned, 2);
        let r = s.render();
        assert!(r.contains("queue: depth=3"));
        assert!(r.contains("deferrals=2"));
        assert!(r.contains("streams: opened=4 completed=4 parked=1 abandoned=2"));
    }

    #[test]
    fn kv_pool_gauges_render_and_snapshot() {
        let m = Metrics::new();
        m.kv_pool_bytes.store(4096, Ordering::Relaxed);
        m.kv_pool_peak_bytes.store(8192, Ordering::Relaxed);
        m.kv_pool_blocks.store(4, Ordering::Relaxed);
        m.kv_block_evictions.store(2, Ordering::Relaxed);
        m.kv_prefix_share_hits.store(7, Ordering::Relaxed);
        m.kv_cow_copies.store(1, Ordering::Relaxed);
        m.kv_window_trims.store(3, Ordering::Relaxed);
        m.kv_blocks_trimmed.store(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.kv_pool_bytes, 4096);
        assert_eq!(s.kv_pool_peak_bytes, 8192);
        assert_eq!(s.kv_pool_blocks, 4);
        assert_eq!(s.kv_block_evictions, 2);
        assert_eq!(s.kv_prefix_share_hits, 7);
        assert_eq!(s.kv_cow_copies, 1);
        assert_eq!(s.kv_window_trims, 3);
        assert_eq!(s.kv_blocks_trimmed, 6);
        let r = s.render();
        assert!(r.contains("kv pool: bytes=4096 peak=8192 blocks=4"));
        assert!(r.contains("block_evictions=2 prefix_share_hits=7 cow_copies=1"));
        assert!(r.contains("cow_copies=1 window_trims=3 blocks_trimmed=6"));
    }

    #[test]
    fn fused_histograms_bucket_correctly() {
        let m = Metrics::new();
        m.observe_jobs_per_cycle(0); // not recorded
        m.observe_jobs_per_cycle(1);
        m.observe_jobs_per_cycle(2);
        m.observe_jobs_per_cycle(9);
        m.observe_jobs_per_cycle(1_000);
        m.observe_fused_width(64);
        m.observe_fused_width(65);
        let s = m.snapshot();
        assert_eq!(s.jobs_per_cycle_buckets.iter().sum::<u64>(), 4);
        assert_eq!(s.jobs_per_cycle_buckets[0], 1); // <=1
        assert_eq!(s.jobs_per_cycle_buckets[1], 1); // <=2
        assert_eq!(s.jobs_per_cycle_buckets[4], 1); // <=16
        assert_eq!(s.jobs_per_cycle_buckets[8], 1); // unbounded tail
        assert_eq!(s.fused_width_buckets[6], 1); // <=64
        assert_eq!(s.fused_width_buckets[7], 1); // <=128
    }

    #[test]
    fn mean_jobs_per_cycle_counts() {
        let m = Metrics::new();
        m.fused_cycles.store(2, Ordering::Relaxed);
        m.fused_jobs.store(10, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_jobs_per_cycle(), 5.0);
    }
}
