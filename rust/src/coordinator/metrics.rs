//! Service metrics: lock-free counters + a fixed-bucket latency histogram,
//! snapshotted by the serving bench and the `flashd serve` CLI.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram buckets in microseconds (upper bounds).
pub const BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, u64::MAX];

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub kv_appends: AtomicU64,
    pub queue_rejections: AtomicU64,
    latency_buckets: [AtomicU64; 12],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_latency(&self, us: u64) {
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        for (i, ub) in BUCKETS_US.iter().enumerate() {
            if us <= *ub {
                self.latency_buckets[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            kv_appends: self.kv_appends.load(Ordering::Relaxed),
            queue_rejections: self.queue_rejections.load(Ordering::Relaxed),
            latency_buckets: self
                .latency_buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            latency_sum_us: self.latency_sum_us.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the metrics.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub kv_appends: u64,
    pub queue_rejections: u64,
    pub latency_buckets: Vec<u64>,
    pub latency_sum_us: u64,
}

impl Snapshot {
    pub fn mean_latency_us(&self) -> f64 {
        if self.responses == 0 {
            0.0
        } else {
            self.latency_sum_us as f64 / self.responses as f64
        }
    }

    /// Approximate percentile from the histogram (upper bound of the
    /// bucket containing the quantile).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.latency_buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return BUCKETS_US[i];
            }
        }
        BUCKETS_US[BUCKETS_US.len() - 1]
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    pub fn render(&self) -> String {
        let fmt_b = |us: u64| -> String {
            if us == u64::MAX { ">100000".into() } else { us.to_string() }
        };
        format!(
            "requests={} responses={} errors={} rejections={}\n\
             batches={} mean_batch={:.2} kv_appends={}\n\
             latency: mean={:.0}µs p50<={}µs p95<={}µs p99<={}µs",
            self.requests,
            self.responses,
            self.errors,
            self.queue_rejections,
            self.batches,
            self.mean_batch_size(),
            self.kv_appends,
            self.mean_latency_us(),
            fmt_b(self.latency_percentile_us(50.0)),
            fmt_b(self.latency_percentile_us(95.0)),
            fmt_b(self.latency_percentile_us(99.0)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets() {
        let m = Metrics::new();
        m.responses.store(3, Ordering::Relaxed);
        m.observe_latency(40);
        m.observe_latency(900);
        m.observe_latency(70_000);
        let s = m.snapshot();
        assert_eq!(s.latency_buckets[0], 1); // <=50
        assert_eq!(s.latency_buckets[4], 1); // <=1000
        assert_eq!(s.latency_buckets[10], 1); // <=100000
        assert!((s.mean_latency_us() - (40.0 + 900.0 + 70_000.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for us in [10, 20, 30, 400, 5000, 99_000] {
            m.observe_latency(us);
        }
        let s = m.snapshot();
        assert!(s.latency_percentile_us(50.0) <= s.latency_percentile_us(95.0));
        assert!(s.latency_percentile_us(95.0) <= s.latency_percentile_us(99.9));
    }

    #[test]
    fn batch_size_mean() {
        let m = Metrics::new();
        m.batches.store(2, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size(), 5.0);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.latency_percentile_us(99.0), 0);
        assert!(s.render().contains("requests=0"));
    }
}
