//! Switching-activity extraction: runs the FLASH-D recursion over real
//! attention problems and measures the toggle densities (average fraction
//! of storage bits flipping between consecutive operands) that feed the
//! power model, plus the skip fraction under the paper's static criterion.
//!
//! This plays the role of the paper's PowerPro stimulus: "average power
//! measured after executing attention kernels for various LLMs".

use crate::kernels::flashd::{self, SkipCriterion};
use crate::kernels::AttnProblem;
use crate::numerics::{toggle_count, Scalar};

/// Average toggle densities per operand stream, in [0, 1].
#[derive(Clone, Debug)]
pub struct ActivityStats {
    /// Toggle density of the streamed key/value elements (drives the dot
    /// product and output-update operand switching).
    pub alpha_kv: f64,
    /// Toggle density of consecutive attention scores.
    pub alpha_score: f64,
    /// Toggle density of the nonlinear-unit outputs (exp/sigmoid stream).
    pub alpha_nonlin: f64,
    /// Fraction of KV steps skipped under the static criterion.
    pub skip_fraction: f64,
    /// Queries measured.
    pub n_queries: usize,
}

impl ActivityStats {
    /// A conservative default (used when no trace is available): typical
    /// random-data toggle densities.
    pub fn default_random() -> ActivityStats {
        ActivityStats {
            alpha_kv: 0.35,
            alpha_score: 0.30,
            alpha_nonlin: 0.25,
            skip_fraction: 0.0,
            n_queries: 0,
        }
    }
}

/// Measure toggle densities in format `T` for a batch of problems.
pub fn measure<T: Scalar>(problems: &[AttnProblem]) -> ActivityStats {
    let mut kv_toggles = 0u64;
    let mut kv_bits = 0u64;
    let mut sc_toggles = 0u64;
    let mut sc_bits = 0u64;
    let mut nl_toggles = 0u64;
    let mut nl_bits = 0u64;
    let mut skipped = 0u64;
    let mut total = 0u64;
    let mut n_queries = 0usize;

    for p in problems {
        for iq in 0..p.nq {
            n_queries += 1;
            let q = p.q_row(iq);
            let (_, tr) = flashd::attention_traced(q, &p.k, &p.v, p.nkv, p.d, p.scale);

            // KV element stream: consecutive value-vector elements through
            // the same physical multiplier port.
            for i in 1..p.nkv {
                for j in 0..p.d {
                    let a = T::from_f64(p.v[(i - 1) * p.d + j] as f64);
                    let b = T::from_f64(p.v[i * p.d + j] as f64);
                    kv_toggles += toggle_count(a, b) as u64;
                    kv_bits += T::BITS as u64;
                }
            }
            // Score stream.
            for w in tr.scores.windows(2) {
                let a = T::from_f64(w[0] as f64);
                let b = T::from_f64(w[1] as f64);
                sc_toggles += toggle_count(a, b) as u64;
                sc_bits += T::BITS as u64;
            }
            // Nonlinear output stream (weights).
            for w in tr.weights.windows(2) {
                let a = T::from_f64(w[0] as f64);
                let b = T::from_f64(w[1] as f64);
                nl_toggles += toggle_count(a, b) as u64;
                nl_bits += T::BITS as u64;
            }
            let st = flashd::skip_stats_from_scores(&tr.scores, SkipCriterion::Static);
            skipped += st.skipped();
            total += st.total;
        }
    }

    ActivityStats {
        alpha_kv: kv_toggles as f64 / kv_bits.max(1) as f64,
        alpha_score: sc_toggles as f64 / sc_bits.max(1) as f64,
        alpha_nonlin: nl_toggles as f64 / nl_bits.max(1) as f64,
        skip_fraction: skipped as f64 / total.max(1) as f64,
        n_queries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::{Bf16, Fp8E4M3};
    use crate::util::rng::Rng;

    fn problems(seed: u64, n: usize) -> Vec<AttnProblem> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| AttnProblem::random(&mut rng, 2, 64, 16, 2.0)).collect()
    }

    #[test]
    fn densities_in_unit_interval() {
        let a = measure::<Bf16>(&problems(1, 3));
        for v in [a.alpha_kv, a.alpha_score, a.alpha_nonlin, a.skip_fraction] {
            assert!((0.0..=1.0).contains(&v), "{a:?}");
        }
        assert_eq!(a.n_queries, 6);
    }

    #[test]
    fn random_data_has_substantial_activity() {
        let a = measure::<Bf16>(&problems(2, 3));
        assert!(a.alpha_kv > 0.15 && a.alpha_kv < 0.6, "{}", a.alpha_kv);
    }

    #[test]
    fn fp8_and_bf16_measurable() {
        let a8 = measure::<Fp8E4M3>(&problems(3, 2));
        let a16 = measure::<Bf16>(&problems(3, 2));
        assert!(a8.alpha_kv > 0.0 && a16.alpha_kv > 0.0);
    }

    #[test]
    fn empty_input_safe() {
        let a = measure::<Bf16>(&[]);
        assert_eq!(a.n_queries, 0);
        assert_eq!(a.alpha_kv, 0.0);
    }
}
