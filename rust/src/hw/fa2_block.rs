//! Operator inventory of the paper's Fig. 1: the parallel FlashAttention2
//! per-query block.
//!
//! Per KV step (one key + one value vector per cycle, Alg. 2 lines 3-6):
//!   * QK dot product: d multipliers + (d-1)-adder reduction tree,
//!   * running max: one compare-select,
//!   * two subtractors forming (m_{i-1} - m_i) and (s_i - m_i),
//!   * two exponential units (range-reduced 8-segment PWL),
//!   * sum-of-exponents update: one multiplier + one adder,
//!   * output update (line 6): two vector multipliers + one vector adder,
//! and per query epilogue (line 8, the lazy division):
//!   * one divider producing 1/l_N + a dedicated vector multiplier lane.
//!
//! The epilogue is dedicated hardware: in the fully-pipelined block the
//! division of query block b overlaps the accumulation of block b+1, so it
//! cannot reuse the update multipliers without a stall — the paper's
//! "no performance penalty" framing implies the same choice.
//!
//! Architectural registers: m, l (scalars) and the d-wide output
//! accumulator, plus the previous-score pipeline register.

use super::cost::{Format, Op};

/// Full operator inventory for one query lane at hidden dimension `d`.
pub fn inventory(d: usize, _fmt: Format) -> Vec<(Op, usize)> {
    vec![
        // --- QK dot product front end ---
        (Op::Mul, d),
        (Op::Add, d - 1),
        // --- softmax state (Alg. 2 lines 4-5) ---
        (Op::Max, 1),
        (Op::Sub, 2),
        (Op::Exp, 2),
        (Op::Mul, 1), // l * alpha
        (Op::Add, 1), // + e^{s-m}
        // --- output update (line 6): o*alpha + v*p ---
        (Op::Mul, 2 * d),
        (Op::Add, d),
        // --- lazy-division epilogue (line 8) ---
        (Op::Div, 1),    // reciprocal of l_N
        (Op::Mul, d),    // o_N * (1/l_N), dedicated lane
        // --- architectural registers: o (d-wide), m, l, s_prev ---
        (Op::Reg, d + 3),
    ]
}

/// Operator invocation counts for processing `n_kv` KV pairs for one query
/// (used by the power model; epilogue ops fire once per query).
pub fn invocations(d: usize, n_kv: usize) -> Vec<(Op, u64)> {
    let n = n_kv as u64;
    let du = d as u64;
    vec![
        (Op::Mul, du * n),       // dot
        (Op::Add, (du - 1) * n), // dot tree
        (Op::Max, n),
        (Op::Sub, 2 * n),
        (Op::Exp, 2 * n),
        (Op::Mul, n),            // l update mul
        (Op::Add, n),            // l update add
        (Op::Mul, 2 * du * n),   // output update muls
        (Op::Add, du * n),       // output update adds
        (Op::Div, 1),
        (Op::Mul, du),           // epilogue vector mul
        (Op::Reg, (du + 3) * n),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::cost::CostDb;

    #[test]
    fn inventory_counts_scale_with_d() {
        let small = inventory(16, Format::BF16);
        let big = inventory(256, Format::BF16);
        let muls = |inv: &[(Op, usize)]| -> usize {
            inv.iter().filter(|(o, _)| *o == Op::Mul).map(|(_, n)| n).sum()
        };
        // 3d+1 multipliers at hidden dim d (d dot + 2d update + d epilogue + 1)
        assert_eq!(muls(&small), 3 * 16 + 16 + 1);
        assert_eq!(muls(&big), 3 * 256 + 256 + 1);
    }

    #[test]
    fn has_divider_and_two_exp_units() {
        let inv = inventory(64, Format::BF16);
        let count = |op: Op| -> usize {
            inv.iter().filter(|(o, _)| *o == op).map(|(_, n)| n).sum()
        };
        assert_eq!(count(Op::Div), 1);
        assert_eq!(count(Op::Exp), 2);
        assert_eq!(count(Op::Max), 1);
    }

    #[test]
    fn area_grows_monotonically_with_d() {
        let db = CostDb::tsmc28();
        let area = |d: usize| -> f64 {
            inventory(d, Format::BF16)
                .iter()
                .map(|(op, n)| db.area_ge(*op, Format::BF16) * *n as f64)
                .sum()
        };
        assert!(area(16) < area(64));
        assert!(area(64) < area(256));
        // roughly linear in d
        let ratio = area(256) / area(64);
        assert!((3.0..5.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn invocations_match_inventory_structure() {
        let inv = invocations(16, 100);
        let total_mul: u64 = inv.iter().filter(|(o, _)| *o == Op::Mul).map(|(_, n)| n).sum();
        // d*n dot + 2d*n update + n l-update + d epilogue
        assert_eq!(total_mul, 16 * 100 + 2 * 16 * 100 + 100 + 16);
    }
}
