//! Gate-equivalent (GE) cost database for 28 nm floating-point operators.
//!
//! Area and energy are expressed in NAND2-gate equivalents, the standard
//! technology-portable unit: 1 GE ≈ 0.49 µm² in a 28 nm HPM standard-cell
//! library, with a dynamic energy of ~0.8 fJ per switching GE at nominal
//! voltage and ~25% wire load. The per-operator gate counts follow the
//! classic decompositions (array multiplier cells, align-add-normalize
//! adders, radix-4 SRT dividers, ROM-backed PWL units) calibrated so that
//! well-known reference points hold: a bf16 multiplier lands at ~0.5 kGE,
//! a bf16 adder slightly below it, an fp8 multiplier at ~0.2 kGE, and a
//! pipelined divider at ~3 multipliers.
//!
//! What matters downstream (Figs. 4-5) is the *relative* cost of the two
//! inventories, which is robust to the absolute calibration.

/// A reduced-precision floating-point storage format.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
#[allow(non_camel_case_types)]
pub enum Format {
    BF16,
    FP8_E4M3,
    FP32,
}

impl Format {
    pub fn exp_bits(self) -> u32 {
        match self {
            Format::BF16 => 8,
            Format::FP8_E4M3 => 4,
            Format::FP32 => 8,
        }
    }

    pub fn mant_bits(self) -> u32 {
        match self {
            Format::BF16 => 7,
            Format::FP8_E4M3 => 3,
            Format::FP32 => 23,
        }
    }

    pub fn bits(self) -> u32 {
        1 + self.exp_bits() + self.mant_bits()
    }

    /// Mantissa width including the hidden bit.
    pub fn mant_full(self) -> u32 {
        self.mant_bits() + 1
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::BF16 => "bf16",
            Format::FP8_E4M3 => "fp8-e4m3",
            Format::FP32 => "fp32",
        }
    }
}

/// Datapath operator classes appearing in the two block inventories.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum Op {
    /// Floating-point adder (also used for subtractors: identical datapath
    /// plus a sign flip).
    Add,
    Sub,
    Mul,
    /// Pipelined divider (radix-4 SRT / Newton reciprocal class).
    Div,
    /// Compare-and-select (running max).
    Max,
    /// Exponential unit: range reduction (mul + add) + 8-segment PWL + the
    /// exponent-field add that applies 2^k.
    Exp,
    /// Sigmoid unit: 8-segment PWL with saturation (no range reduction —
    /// the active region [-6, 11] is the whole domain).
    Sigmoid,
    /// Natural-log unit: 8-segment PWL over (0, 1].
    Ln,
    /// Architectural register, one operand wide.
    Reg,
    /// Coefficient ROM for one PWL unit (counted inside Exp/Sigmoid/Ln;
    /// exposed for ablations).
    Rom,
}

/// Technology calibration + per-operator cost model.
#[derive(Clone, Debug)]
pub struct CostDb {
    /// µm² per gate equivalent (28 nm HPM: ~0.49).
    pub um2_per_ge: f64,
    /// Dynamic energy per switching GE, femtojoules.
    pub fj_per_ge_switch: f64,
    /// Leakage power per kGE, microwatts (28 nm HVT-dominant mix).
    pub uw_leak_per_kge: f64,
    /// Fraction of datapath area added for pipeline registers + control.
    pub pipeline_overhead: f64,
    /// Clock frequency the paper synthesizes at.
    pub clock_hz: f64,
}

impl CostDb {
    /// Calibration used throughout the reproduction (28 nm, 500 MHz).
    pub fn tsmc28() -> CostDb {
        CostDb {
            um2_per_ge: 0.49,
            fj_per_ge_switch: 0.8,
            uw_leak_per_kge: 0.12,
            pipeline_overhead: 0.20,
            clock_hz: 500.0e6,
        }
    }

    /// Area of one operator instance in gate equivalents.
    pub fn area_ge(&self, op: Op, fmt: Format) -> f64 {
        let m = fmt.mant_full() as f64; // mantissa incl. hidden bit
        let e = fmt.exp_bits() as f64;
        let bits = fmt.bits() as f64;
        let log2m = (fmt.mant_full() as f64).log2().ceil().max(1.0);
        match op {
            // align (shifter) + mantissa add + LZD/normalize + round + exp
            Op::Add | Op::Sub => 10.0 * m * log2m + 8.0 * m + 10.0 * e + 40.0,
            // array multiplier cells dominate + exponent add + normalize
            Op::Mul => 6.0 * m * m + 10.0 * e + 60.0,
            // pipelined divider ~ 3 multipliers of the same format
            Op::Div => 3.0 * (6.0 * m * m + 10.0 * e + 60.0),
            // exponent compare + mantissa compare + select
            Op::Max => 4.0 * bits + 20.0,
            // range reduction (mul+add) + PWL (mul+add+ROM+select) + exp add
            Op::Exp => {
                2.0 * self.area_ge(Op::Mul, fmt)
                    + 2.0 * self.area_ge(Op::Add, fmt)
                    + self.area_ge(Op::Rom, fmt)
                    + 60.0
            }
            // PWL only: mul + add + ROM + segment select + saturation
            Op::Sigmoid | Op::Ln => {
                self.area_ge(Op::Mul, fmt)
                    + self.area_ge(Op::Add, fmt)
                    + self.area_ge(Op::Rom, fmt)
                    + 60.0
            }
            // one flop ~ 6 GE per bit
            Op::Reg => 6.0 * bits,
            // 8 segments x (slope + intercept) x bits, ~0.25 GE per ROM bit
            Op::Rom => 8.0 * 2.0 * bits * 0.25 + 30.0,
        }
    }

    /// Dynamic energy of one invocation of `op` at toggle density `alpha`
    /// (fraction of the operator's gates that switch), picojoules.
    pub fn energy_pj(&self, op: Op, fmt: Format, alpha: f64) -> f64 {
        self.area_ge(op, fmt) * alpha * self.fj_per_ge_switch / 1000.0
    }

    /// Leakage power for an area in GE, milliwatts.
    pub fn leakage_mw(&self, area_ge: f64) -> f64 {
        area_ge / 1000.0 * self.uw_leak_per_kge / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_fields() {
        assert_eq!(Format::BF16.bits(), 16);
        assert_eq!(Format::FP8_E4M3.bits(), 8);
        assert_eq!(Format::BF16.mant_full(), 8);
        assert_eq!(Format::FP8_E4M3.mant_full(), 4);
    }

    #[test]
    fn calibration_anchor_points() {
        let db = CostDb::tsmc28();
        let mul16 = db.area_ge(Op::Mul, Format::BF16);
        let add16 = db.area_ge(Op::Add, Format::BF16);
        // bf16 multiplier ~0.5 kGE, adder slightly smaller
        assert!((400.0..700.0).contains(&mul16), "{mul16}");
        assert!(add16 < mul16, "add {add16} !< mul {mul16}");
        assert!(add16 > 0.6 * mul16, "add implausibly small: {add16}");
        // divider ~ 3 multipliers
        assert!((db.area_ge(Op::Div, Format::BF16) / mul16 - 3.0).abs() < 1e-9);
        // fp8 ops substantially smaller than bf16
        assert!(db.area_ge(Op::Mul, Format::FP8_E4M3) < 0.5 * mul16);
    }

    #[test]
    fn nonlinear_units_order() {
        let db = CostDb::tsmc28();
        for &f in &[Format::BF16, Format::FP8_E4M3] {
            // exp (range reduction + PWL) costs more than sigmoid (PWL only)
            assert!(db.area_ge(Op::Exp, f) > db.area_ge(Op::Sigmoid, f));
            assert_eq!(db.area_ge(Op::Sigmoid, f), db.area_ge(Op::Ln, f));
            // max unit is far cheaper than an adder
            assert!(db.area_ge(Op::Max, f) < 0.3 * db.area_ge(Op::Add, f));
        }
    }

    #[test]
    fn energy_scales_with_alpha_and_area() {
        let db = CostDb::tsmc28();
        let e_half = db.energy_pj(Op::Mul, Format::BF16, 0.5);
        let e_full = db.energy_pj(Op::Mul, Format::BF16, 1.0);
        assert!((e_full / e_half - 2.0).abs() < 1e-9);
        assert!(db.energy_pj(Op::Mul, Format::FP8_E4M3, 0.5) < e_half);
    }

    #[test]
    fn fp32_larger_than_bf16() {
        let db = CostDb::tsmc28();
        assert!(db.area_ge(Op::Mul, Format::FP32) > 4.0 * db.area_ge(Op::Mul, Format::BF16));
    }
}
