//! Pipeline timing model for the two blocks.
//!
//! Both designs run at the same 500 MHz clock and the same pipelined
//! latency (paper §V-A): one KV pair enters the block every cycle, and the
//! result of one step is available after
//!
//!   1 cycle   multiplier stage of the dot product,
//!   log2(d)   adder-tree levels (one level per cycle),
//!   3 cycles  kernel tail (argument/state formation, nonlinear unit,
//!             output update),
//!
//! which reproduces the paper's 8 / 10 / 12 cycles for d = 16 / 64 / 256.
//! FLASH-D's tail has the same depth — sigmoid argument, sigmoid+ln,
//! FMA — as FA2's max/exp, l/o update, so the latencies are identical and
//! the comparison is iso-performance.

use super::Design;

/// Pipelined latency (cycles) for one KV step at hidden dimension `d`.
pub fn latency_cycles(_design: Design, d: usize) -> u32 {
    let tree = (d.max(2) as f64).log2().ceil() as u32;
    1 + tree + 3
}

/// Cycles to process one query against `n_kv` key/value pairs: pipeline
/// fill + one KV pair per cycle (+1 epilogue cycle for FA2's division,
/// hidden by the next block's fill in steady state).
pub fn query_cycles(design: Design, d: usize, n_kv: usize) -> u64 {
    latency_cycles(design, d) as u64 + n_kv as u64 - 1
}

/// Steady-state throughput in KV-pairs/s per query lane at `clock_hz`.
pub fn throughput_pairs_per_s(clock_hz: f64) -> f64 {
    clock_hz // 1 KV pair per cycle per lane, both designs
}

/// Latency in nanoseconds at the given clock.
pub fn latency_ns(design: Design, d: usize, clock_hz: f64) -> f64 {
    latency_cycles(design, d) as f64 / clock_hz * 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_papers_cycle_counts() {
        // Paper §V-A: 8, 10, 12 cycles for d = 16, 64, 256.
        assert_eq!(latency_cycles(Design::FlashD, 16), 8);
        assert_eq!(latency_cycles(Design::FlashD, 64), 10);
        assert_eq!(latency_cycles(Design::FlashD, 256), 12);
        assert_eq!(latency_cycles(Design::FlashAttention2, 16), 8);
        assert_eq!(latency_cycles(Design::FlashAttention2, 64), 10);
        assert_eq!(latency_cycles(Design::FlashAttention2, 256), 12);
    }

    #[test]
    fn query_cycles_pipeline() {
        // 128 KV pairs at d=64: 10-cycle fill + 127 more pairs
        assert_eq!(query_cycles(Design::FlashD, 64, 128), 137);
        assert_eq!(query_cycles(Design::FlashD, 64, 1), 10);
    }

    #[test]
    fn latency_ns_at_500mhz() {
        let ns = latency_ns(Design::FlashD, 16, 500e6);
        assert!((ns - 16.0).abs() < 1e-9); // 8 cycles * 2 ns
    }
}
