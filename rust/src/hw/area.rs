//! Fig. 4 reproduction: block area for FLASH-D vs the FlashAttention2
//! kernel across hidden dimensions and number formats.

use super::cost::{CostDb, Format, Op};
use super::{datapath, Design};

/// One row of the Fig. 4 data.
#[derive(Clone, Debug)]
pub struct AreaRow {
    pub fmt: Format,
    pub d: usize,
    pub fa2_um2: f64,
    pub flashd_um2: f64,
    pub saving_pct: f64,
    pub latency_cycles: u32,
}

/// The paper's evaluation grid: BF16 and FP8-E4M3 at d ∈ {16, 64, 256}.
pub const PAPER_DIMS: [usize; 3] = [16, 64, 256];
pub const PAPER_FORMATS: [Format; 2] = [Format::BF16, Format::FP8_E4M3];

/// Compute all Fig. 4 rows.
pub fn fig4_rows(db: &CostDb) -> Vec<AreaRow> {
    let mut rows = Vec::new();
    for &fmt in &PAPER_FORMATS {
        for &d in &PAPER_DIMS {
            let fa2 = Design::FlashAttention2.area_um2(d, fmt, db);
            let fd = Design::FlashD.area_um2(d, fmt, db);
            rows.push(AreaRow {
                fmt,
                d,
                fa2_um2: fa2,
                flashd_um2: fd,
                saving_pct: 100.0 * (fa2 - fd) / fa2,
                latency_cycles: datapath::latency_cycles(Design::FlashD, d),
            });
        }
    }
    rows
}

/// Coarse module-level area breakdown (for DESIGN.md and the ablation
/// bench): dot front end, nonlinear units, output update, softmax state,
/// division epilogue, architectural registers.
#[derive(Clone, Debug, Default)]
pub struct AreaBreakdown {
    pub dot: f64,
    pub nonlinear: f64,
    pub update: f64,
    pub state: f64,
    pub epilogue: f64,
    pub regs: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.dot + self.nonlinear + self.update + self.state + self.epilogue + self.regs
    }
}

/// Break a design's inventory into the module groups above (GE).
pub fn breakdown(design: Design, d: usize, fmt: Format, db: &CostDb) -> AreaBreakdown {
    let mut b = AreaBreakdown::default();
    let a = |op: Op, n: usize| db.area_ge(op, fmt) * n as f64;
    match design {
        Design::FlashAttention2 => {
            b.dot = a(Op::Mul, d) + a(Op::Add, d - 1);
            b.state = a(Op::Max, 1) + a(Op::Sub, 2) + a(Op::Mul, 1) + a(Op::Add, 1);
            b.nonlinear = a(Op::Exp, 2);
            b.update = a(Op::Mul, 2 * d) + a(Op::Add, d);
            b.epilogue = a(Op::Div, 1) + a(Op::Mul, d);
            b.regs = a(Op::Reg, d + 3);
        }
        Design::FlashD => {
            b.dot = a(Op::Mul, d) + a(Op::Add, d - 1);
            b.state = a(Op::Sub, 1) + a(Op::Add, 1);
            b.nonlinear = a(Op::Sigmoid, 1) + a(Op::Ln, 1);
            b.update = a(Op::Sub, d) + a(Op::Mul, d) + a(Op::Add, d);
            b.epilogue = 0.0;
            b.regs = a(Op::Reg, d + 2);
        }
    }
    b
}

/// Render the Fig. 4 table as aligned text (what the bench prints).
pub fn render_table(rows: &[AreaRow]) -> String {
    let mut out = String::from(
        "format     d    FA2 area (mm^2)  FLASH-D area (mm^2)  saving   latency\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<9} {:>4}  {:>15.4}  {:>19.4}  {:>5.1}%  {:>4} cyc\n",
            r.fmt.name(),
            r.d,
            r.fa2_um2 / 1e6,
            r.flashd_um2 / 1e6,
            r.saving_pct,
            r.latency_cycles,
        ));
    }
    out
}

/// CSV for reports/fig4.csv.
pub fn to_csv(rows: &[AreaRow]) -> String {
    let mut out = String::from("format,d,fa2_um2,flashd_um2,saving_pct,latency_cycles\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{:.1},{:.1},{:.3},{}\n",
            r.fmt.name(), r.d, r.fa2_um2, r.flashd_um2, r.saving_pct, r.latency_cycles
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_rows_on_paper_grid() {
        let rows = fig4_rows(&CostDb::tsmc28());
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.saving_pct > 0.0, "FLASH-D must be smaller: {r:?}");
        }
    }

    /// The paper's Fig. 4 trend: the relative saving shrinks as d grows
    /// (the shared dot-product front end dilutes the kernel savings).
    #[test]
    fn saving_decreases_with_d() {
        let rows = fig4_rows(&CostDb::tsmc28());
        for fmt_rows in rows.chunks(3) {
            assert!(fmt_rows[0].saving_pct > fmt_rows[1].saving_pct);
            assert!(fmt_rows[1].saving_pct > fmt_rows[2].saving_pct);
        }
    }

    #[test]
    fn average_saving_near_papers_22_8() {
        let rows = fig4_rows(&CostDb::tsmc28());
        let avg = crate::util::mean(&rows.iter().map(|r| r.saving_pct).collect::<Vec<_>>());
        assert!((avg - 22.8).abs() < 8.0, "avg {avg:.1}% too far from paper's 22.8%");
    }

    #[test]
    fn breakdown_total_matches_inventory_area() {
        let db = CostDb::tsmc28();
        for &design in &[Design::FlashAttention2, Design::FlashD] {
            for &d in &PAPER_DIMS {
                let b = breakdown(design, d, Format::BF16, &db).total();
                let inv: f64 = design
                    .inventory(d, Format::BF16)
                    .iter()
                    .map(|(op, n)| db.area_ge(*op, Format::BF16) * *n as f64)
                    .sum();
                assert!((b - inv).abs() < 1e-6, "{design:?} d={d}: {b} vs {inv}");
            }
        }
    }

    #[test]
    fn csv_and_table_render() {
        let rows = fig4_rows(&CostDb::tsmc28());
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), 7);
        assert!(render_table(&rows).contains("FLASH-D"));
    }
}
