//! Operator inventory of the paper's Fig. 3: the FLASH-D per-query block.
//!
//! Per KV step (Alg. 3 lines 3-9):
//!   * QK dot product: identical front end to Fig. 1,
//!   * sigmoid argument: one subtractor (s_i - s_{i-1}) + one adder
//!     (+ ln w_{i-1}),
//!   * one sigmoid PWL unit (8 segments over the active region [-6, 11]),
//!   * one ln PWL unit (8 segments over (0, 1]),
//!   * output update (Eq. 12): one vector subtractor, one vector
//!     multiplier, one vector adder — o += (v - o) * w.
//!
//! Gone relative to Fig. 1 (the paper's three structural savings):
//!   * the divider and its dedicated epilogue multiplier lane,
//!   * the running max compare-select and the sum-of-exponents mul+add,
//!   * one of the two vector multipliers (replaced by a subtractor).
//!
//! Architectural registers: o (d-wide), s_prev, ln_w.

use super::cost::{Format, Op};

/// Full operator inventory for one query lane at hidden dimension `d`.
pub fn inventory(d: usize, _fmt: Format) -> Vec<(Op, usize)> {
    vec![
        // --- QK dot product front end (same as Fig. 1) ---
        (Op::Mul, d),
        (Op::Add, d - 1),
        // --- sigmoid argument: (s_i - s_{i-1}) + ln w_{i-1} ---
        (Op::Sub, 1),
        (Op::Add, 1),
        // --- the two nonlinear units ---
        (Op::Sigmoid, 1),
        (Op::Ln, 1),
        // --- output update (Eq. 12): o + (v - o) * w ---
        (Op::Sub, d),
        (Op::Mul, d),
        (Op::Add, d),
        // --- architectural registers: o (d-wide), s_prev, ln_w ---
        (Op::Reg, d + 2),
    ]
}

/// Operator invocation counts for processing `n_kv` KV pairs for one query.
/// `skipped` KV steps bypass the value load and the entire output update
/// (the paper's §III-C saving); the dot product and argument formation
/// still run (they produce the skip decision itself).
pub fn invocations(d: usize, n_kv: usize, skipped: u64) -> Vec<(Op, u64)> {
    let n = n_kv as u64;
    let du = d as u64;
    let active = n - skipped.min(n);
    vec![
        (Op::Mul, du * n),       // dot
        (Op::Add, (du - 1) * n), // dot tree
        (Op::Sub, n),            // s diff
        (Op::Add, n),            // + ln w
        (Op::Sigmoid, active),   // saturated steps bypass the PWL mul/add
        (Op::Ln, active),
        (Op::Sub, du * active),  // output update only on active steps
        (Op::Mul, du * active),
        (Op::Add, du * active),
        (Op::Reg, (du + 2) * n),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::cost::CostDb;
    use crate::hw::Design;

    #[test]
    fn no_divider_no_max_no_exp() {
        let inv = inventory(64, Format::BF16);
        for (op, _) in &inv {
            assert!(!matches!(op, Op::Div | Op::Max | Op::Exp), "{op:?}");
        }
    }

    #[test]
    fn one_vector_multiplier_in_update() {
        let inv = inventory(32, Format::BF16);
        let muls: usize = inv.iter().filter(|(o, _)| *o == Op::Mul).map(|(_, n)| n).sum();
        // d dot + d update (vs FA2's 3d+1 + epilogue)
        assert_eq!(muls, 2 * 32);
    }

    #[test]
    fn fewer_registers_than_fa2() {
        let regs = |inv: &[(Op, usize)]| -> usize {
            inv.iter().filter(|(o, _)| *o == Op::Reg).map(|(_, n)| n).sum()
        };
        let fd = regs(&inventory(64, Format::BF16));
        let fa2 = regs(&crate::hw::fa2_block::inventory(64, Format::BF16));
        assert!(fd < fa2, "{fd} !< {fa2}");
    }

    #[test]
    fn skipping_reduces_invocations() {
        let no_skip = invocations(16, 100, 0);
        let with_skip = invocations(16, 100, 30);
        let update_muls = |inv: &[(Op, u64)]| -> u64 {
            // second Mul entry is the output-update multiplier bank
            inv.iter().filter(|(o, _)| *o == Op::Mul).map(|(_, n)| n).sum()
        };
        assert!(update_muls(&with_skip) < update_muls(&no_skip));
    }

    /// The structural decomposition of the area saving, per the paper §V-A:
    /// divider gone, one vector multiplier swapped for a subtractor,
    /// max + sum-of-exponents logic gone, exp units -> sigmoid + ln.
    #[test]
    fn saving_decomposition_adds_up() {
        let db = CostDb::tsmc28();
        let fmt = Format::BF16;
        let d = 64usize;
        let a = |op: Op| db.area_ge(op, fmt);

        let fa2: f64 = Design::FlashAttention2
            .inventory(d, fmt)
            .iter()
            .map(|(op, n)| a(*op) * *n as f64)
            .sum();
        let fd: f64 = Design::FlashD
            .inventory(d, fmt)
            .iter()
            .map(|(op, n)| a(*op) * *n as f64)
            .sum();

        let divider_saving = a(Op::Div) + d as f64 * a(Op::Mul);
        let update_saving = d as f64 * (a(Op::Mul) - a(Op::Sub));
        let state_saving = a(Op::Max) + a(Op::Mul) + a(Op::Add) + a(Op::Reg) + a(Op::Sub);
        let nonlin_delta = 2.0 * a(Op::Exp) - (a(Op::Sigmoid) + a(Op::Ln)) - a(Op::Add);

        let predicted = divider_saving + update_saving + state_saving + nonlin_delta;
        assert!(
            ((fa2 - fd) - predicted).abs() < 1.0,
            "decomposition mismatch: {} vs {}",
            fa2 - fd,
            predicted
        );
    }
}
