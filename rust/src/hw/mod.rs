//! The 28 nm hardware cost model + datapath simulator that substitutes for
//! the paper's Catapult-HLS → Cadence synthesis flow (DESIGN.md §2).
//!
//! The paper's evaluation compares two fully-unrolled per-query datapaths:
//!
//! * **Fig. 1** — the FlashAttention2 kernel block: QK dot-product unit,
//!   running max, two exponential (PWL) units, running sum-of-exponents,
//!   an output-update module with two vector multipliers + one vector
//!   adder, and a dedicated lazy-division epilogue (reciprocal + vector
//!   multiplier) so back-to-back query blocks never stall.
//! * **Fig. 3** — the FLASH-D block: the same dot-product front end, one
//!   sigmoid PWL unit + one ln PWL unit, and an output-update module with
//!   one vector subtractor, one vector multiplier and one vector adder
//!   (Eq. 12). No max, no sum-of-exponents, no divider.
//!
//! Both blocks are modelled as inventories of floating-point operators
//! whose area/energy come from a gate-equivalent (GE) cost database
//! ([`cost`]). Area (Fig. 4) is a roll-up of the inventory; power (Fig. 5)
//! is activity-based: operator energies weighted by measured toggle
//! densities from attention traces, at the paper's 500 MHz clock.
//!
//! The absolute numbers are a model, not silicon; what the reproduction
//! preserves is the *relative* comparison (who wins, by what factor, and
//! how the gap moves with hidden dimension and number format), which is
//! the paper's claim.

pub mod activity;
pub mod area;
pub mod cost;
pub mod datapath;
pub mod fa2_block;
pub mod flashd_block;
pub mod power;

pub use cost::{CostDb, Format, Op};
pub use datapath::latency_cycles;

/// The two competing designs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Design {
    FlashAttention2,
    FlashD,
}

impl Design {
    pub fn name(self) -> &'static str {
        match self {
            Design::FlashAttention2 => "FlashAttention2",
            Design::FlashD => "FLASH-D",
        }
    }

    /// Operator inventory of the per-query block at hidden dimension `d`.
    pub fn inventory(self, d: usize, fmt: Format) -> Vec<(Op, usize)> {
        match self {
            Design::FlashAttention2 => fa2_block::inventory(d, fmt),
            Design::FlashD => flashd_block::inventory(d, fmt),
        }
    }

    /// Block area in gate equivalents.
    pub fn area_ge(self, d: usize, fmt: Format, db: &CostDb) -> f64 {
        let base: f64 = self
            .inventory(d, fmt)
            .iter()
            .map(|(op, n)| db.area_ge(*op, fmt) * *n as f64)
            .sum();
        // Pipeline registers / control overhead: proportional to datapath
        // width and depth (same factor for both designs — they share the
        // pipeline structure and clock).
        base * (1.0 + db.pipeline_overhead)
    }

    pub fn area_um2(self, d: usize, fmt: Format, db: &CostDb) -> f64 {
        self.area_ge(d, fmt, db) * db.um2_per_ge
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flashd_block_is_smaller_for_all_paper_points() {
        let db = CostDb::tsmc28();
        for &fmt in &[Format::BF16, Format::FP8_E4M3] {
            for &d in &[16usize, 64, 256] {
                let fa2 = Design::FlashAttention2.area_ge(d, fmt, &db);
                let fd = Design::FlashD.area_ge(d, fmt, &db);
                assert!(fd < fa2, "d={d} fmt={fmt:?}: {fd} !< {fa2}");
            }
        }
    }

    /// Paper headline: 22.8% average area reduction (range ~20-28%).
    #[test]
    fn area_savings_in_papers_band() {
        let db = CostDb::tsmc28();
        let mut savings = Vec::new();
        for &fmt in &[Format::BF16, Format::FP8_E4M3] {
            for &d in &[16usize, 64, 256] {
                let fa2 = Design::FlashAttention2.area_ge(d, fmt, &db);
                let fd = Design::FlashD.area_ge(d, fmt, &db);
                let pct = 100.0 * (fa2 - fd) / fa2;
                assert!(pct > 12.0 && pct < 35.0, "d={d} fmt={fmt:?}: {pct:.1}%");
                savings.push(pct);
            }
        }
        let avg = crate::util::mean(&savings);
        assert!((15.0..30.0).contains(&avg), "avg savings {avg:.1}%");
    }

    #[test]
    fn both_designs_same_latency() {
        for &d in &[16usize, 64, 256] {
            assert_eq!(
                datapath::latency_cycles(Design::FlashAttention2, d),
                datapath::latency_cycles(Design::FlashD, d)
            );
        }
    }
}
