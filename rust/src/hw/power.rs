//! Fig. 5 reproduction: average power of the two blocks under real
//! attention stimulus, at the paper's 500 MHz clock. Memory/IO power is
//! excluded (identical for both designs — same dataflow), exactly as in the
//! paper.
//!
//! Model: per-cycle dynamic energy =
//!     Σ_units area(unit) × data-toggle density × utilization
//!   + Σ_units area(unit) × clock/sequential factor        (always on)
//! plus area-proportional leakage.
//!
//! Microarchitectural notes that matter for the comparison:
//!  * The FA2 division epilogue (divider + dedicated vector-multiplier
//!    lane) produces a result once per query but its operand inputs (o, l)
//!    change *every cycle*; without operand isolation — which neither the
//!    paper's HLS flow nor ours inserts — the lane toggles continuously.
//!    This is the classic HLS power sink and a large part of the measured
//!    gap.
//!  * FLASH-D's saturation skips (§III-C) gate the sigmoid/ln units and
//!    the whole output-update bank on skipped steps.

use super::activity::ActivityStats;
use super::cost::{CostDb, Format, Op};
use super::Design;

/// Clock/sequential always-on toggle factor: the fraction of a unit's
/// gates that switch every cycle regardless of data (clock buffers, flop
/// internals, enables).
const ALPHA_CLOCK: f64 = 0.10;

/// One row of the Fig. 5 data.
#[derive(Clone, Debug)]
pub struct PowerRow {
    pub fmt: Format,
    pub d: usize,
    pub fa2_mw: f64,
    pub flashd_mw: f64,
    pub saving_pct: f64,
}

/// Average power (mW) of one per-query lane of `design` at hidden dim `d`,
/// under measured activity `act`.
pub fn block_power_mw(design: Design, d: usize, fmt: Format, act: &ActivityStats, db: &CostDb) -> f64 {
    let a = |op: Op| db.area_ge(op, fmt);
    let du = d as f64;
    // Per-cycle switched GE (data component).
    let data_ge = match design {
        Design::FlashAttention2 => {
            let dot = (a(Op::Mul) * du + a(Op::Add) * (du - 1.0)) * act.alpha_kv;
            let state = (a(Op::Max) + 2.0 * a(Op::Sub)) * act.alpha_score
                + (a(Op::Mul) + a(Op::Add)) * act.alpha_nonlin; // l update
            let nonlin = 2.0 * a(Op::Exp) * act.alpha_nonlin;
            let update = (2.0 * du * a(Op::Mul) + du * a(Op::Add)) * act.alpha_kv;
            // Epilogue lane: fed by o/l every cycle, no operand isolation.
            let epilogue = (a(Op::Div) + du * a(Op::Mul)) * act.alpha_kv;
            let regs = a(Op::Reg) * (du + 3.0) * act.alpha_kv;
            dot + state + nonlin + update + epilogue + regs
        }
        Design::FlashD => {
            let active = 1.0 - act.skip_fraction;
            let dot = (a(Op::Mul) * du + a(Op::Add) * (du - 1.0)) * act.alpha_kv;
            let state = (a(Op::Sub) + a(Op::Add)) * act.alpha_score;
            // sigmoid + ln gated off on skipped steps
            let nonlin = (a(Op::Sigmoid) + a(Op::Ln)) * act.alpha_nonlin * active;
            // update bank gated off on skipped steps
            let update =
                du * (a(Op::Sub) + a(Op::Mul) + a(Op::Add)) * act.alpha_kv * active;
            let regs = a(Op::Reg) * (du + 2.0) * act.alpha_kv;
            dot + state + nonlin + update + regs
        }
    };
    // Clock/sequential component over the whole block (incl. pipeline regs).
    let total_area_ge = design.area_ge(d, fmt, db);
    let clock_ge = total_area_ge * ALPHA_CLOCK;

    let energy_pj_per_cycle = (data_ge + clock_ge) * db.fj_per_ge_switch / 1000.0;
    let dynamic_mw = energy_pj_per_cycle * 1e-12 * db.clock_hz * 1e3;
    dynamic_mw + db.leakage_mw(total_area_ge)
}

/// Compute the Fig. 5 rows from per-format activity measurements.
pub fn fig5_rows(
    acts: &dyn Fn(Format) -> ActivityStats,
    db: &CostDb,
) -> Vec<PowerRow> {
    let mut rows = Vec::new();
    for &fmt in &super::area::PAPER_FORMATS {
        let act = acts(fmt);
        for &d in &super::area::PAPER_DIMS {
            let fa2 = block_power_mw(Design::FlashAttention2, d, fmt, &act, db);
            let fd = block_power_mw(Design::FlashD, d, fmt, &act, db);
            rows.push(PowerRow {
                fmt,
                d,
                fa2_mw: fa2,
                flashd_mw: fd,
                saving_pct: 100.0 * (fa2 - fd) / fa2,
            });
        }
    }
    rows
}

pub fn render_table(rows: &[PowerRow]) -> String {
    let mut out =
        String::from("format     d    FA2 power (mW)  FLASH-D power (mW)  saving\n");
    for r in rows {
        out.push_str(&format!(
            "{:<9} {:>4}  {:>14.3}  {:>18.3}  {:>5.1}%\n",
            r.fmt.name(), r.d, r.fa2_mw, r.flashd_mw, r.saving_pct,
        ));
    }
    out
}

pub fn to_csv(rows: &[PowerRow]) -> String {
    let mut out = String::from("format,d,fa2_mw,flashd_mw,saving_pct\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{:.4},{:.4},{:.3}\n",
            r.fmt.name(), r.d, r.fa2_mw, r.flashd_mw, r.saving_pct
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn act() -> ActivityStats {
        ActivityStats {
            alpha_kv: 0.35,
            alpha_score: 0.30,
            alpha_nonlin: 0.25,
            skip_fraction: 0.02,
            n_queries: 1,
        }
    }

    #[test]
    fn flashd_uses_less_power_everywhere() {
        let db = CostDb::tsmc28();
        let rows = fig5_rows(&|_| act(), &db);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.saving_pct > 0.0, "{r:?}");
        }
    }

    /// Paper headline: 20.3% average power reduction (range 16-27%).
    #[test]
    fn power_savings_in_papers_band() {
        let db = CostDb::tsmc28();
        let rows = fig5_rows(&|_| act(), &db);
        let savings: Vec<f64> = rows.iter().map(|r| r.saving_pct).collect();
        let avg = crate::util::mean(&savings);
        assert!((12.0..30.0).contains(&avg), "avg power saving {avg:.1}%");
        for r in &rows {
            assert!(r.saving_pct > 8.0 && r.saving_pct < 35.0, "{r:?}");
        }
    }

    #[test]
    fn power_scales_with_d_and_format() {
        let db = CostDb::tsmc28();
        let a = act();
        let p16 = block_power_mw(Design::FlashD, 16, Format::BF16, &a, &db);
        let p256 = block_power_mw(Design::FlashD, 256, Format::BF16, &a, &db);
        assert!(p256 > 8.0 * p16, "{p16} vs {p256}");
        let p8 = block_power_mw(Design::FlashD, 64, Format::FP8_E4M3, &a, &db);
        let pb = block_power_mw(Design::FlashD, 64, Format::BF16, &a, &db);
        assert!(p8 < pb);
    }

    #[test]
    fn skipping_reduces_flashd_power() {
        let db = CostDb::tsmc28();
        let mut a = act();
        a.skip_fraction = 0.0;
        let p0 = block_power_mw(Design::FlashD, 64, Format::BF16, &a, &db);
        a.skip_fraction = 0.5;
        let p50 = block_power_mw(Design::FlashD, 64, Format::BF16, &a, &db);
        assert!(p50 < p0);
        // FA2 is insensitive to the skip fraction
        let f0 = block_power_mw(Design::FlashAttention2, 64, Format::BF16, &a, &db);
        a.skip_fraction = 0.0;
        let f1 = block_power_mw(Design::FlashAttention2, 64, Format::BF16, &a, &db);
        assert_eq!(f0, f1);
    }

    #[test]
    fn csv_renders() {
        let db = CostDb::tsmc28();
        let rows = fig5_rows(&|_| act(), &db);
        assert_eq!(to_csv(&rows).lines().count(), 7);
        assert!(render_table(&rows).contains("saving"));
    }
}
