//! Synthetic benchmark suites standing in for the paper's PromptBench
//! tasks (Table I columns): CSQA, GSM8K, QASC, MMLU, Date, Object
//! Tracking. Each suite generates prompts with the same *shape* as its
//! namesake — commonsense QA, arithmetic word problems, science QA,
//! multi-domain multiple choice, date reasoning, and object state
//! tracking — from templated grammars with deterministic randomness.
//!
//! The training corpus samples from the same grammars, so the trained zoo
//! models see in-distribution text at evaluation time (mirroring how the
//! paper's LLMs are evaluated on natural language they model well).

use crate::util::rng::Rng;

/// The six Table I benchmark columns.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Suite {
    Csqa,
    Gsm8k,
    Qasc,
    Mmlu,
    Date,
    ObjectTracking,
}

pub const ALL_SUITES: [Suite; 6] =
    [Suite::Csqa, Suite::Gsm8k, Suite::Qasc, Suite::Mmlu, Suite::Date, Suite::ObjectTracking];

impl Suite {
    pub fn name(self) -> &'static str {
        match self {
            Suite::Csqa => "CSQA",
            Suite::Gsm8k => "GSM8K",
            Suite::Qasc => "QASC",
            Suite::Mmlu => "MMLU",
            Suite::Date => "Date",
            Suite::ObjectTracking => "ObjectTracking",
        }
    }

    /// Generate one prompt.
    pub fn prompt(self, rng: &mut Rng) -> String {
        match self {
            Suite::Csqa => csqa(rng),
            Suite::Gsm8k => gsm8k(rng),
            Suite::Qasc => qasc(rng),
            Suite::Mmlu => mmlu(rng),
            Suite::Date => date(rng),
            Suite::ObjectTracking => tracking(rng),
        }
    }

    /// Generate `n` prompts.
    pub fn prompts(self, n: usize, seed: u64) -> Vec<String> {
        let mut rng = Rng::new(seed ^ (self as u64).wrapping_mul(0x9E3779B9));
        (0..n).map(|_| self.prompt(&mut rng)).collect()
    }
}

const PEOPLE: [&str; 8] = ["alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi"];
const OBJECTS: [&str; 8] = ["ball", "book", "key", "apple", "coin", "cup", "hat", "pen"];
const COLORS: [&str; 6] = ["red", "blue", "green", "yellow", "black", "white"];
const PLACES: [&str; 6] = ["kitchen", "garden", "office", "park", "library", "garage"];
const ANIMALS: [&str; 6] = ["dog", "cat", "bird", "fish", "horse", "bee"];
const NEEDS: [&str; 6] = ["water", "food", "sleep", "light", "air", "warmth"];
const SUBJECTS: [&str; 6] = ["plants", "metals", "magnets", "planets", "rivers", "clouds"];
const VERBS: [&str; 4] = ["grow", "shine", "move", "change"];
const MONTHS: [&str; 12] = [
    "january", "february", "march", "april", "may", "june",
    "july", "august", "september", "october", "november", "december",
];
const DAYS: [&str; 7] =
    ["monday", "tuesday", "wednesday", "thursday", "friday", "saturday", "sunday"];

fn csqa(rng: &mut Rng) -> String {
    let why = [
        ("why do people wear coats in winter?", "to stay warm"),
        ("why do people drink water?", "they are thirsty"),
        ("where do books belong?", "on the shelf"),
        ("what do you use to cut paper?", "scissors"),
        ("why do people sleep at night?", "they are tired"),
        ("where does bread come from?", "the bakery"),
    ];
    let (q, a) = why[rng.below(why.len())];
    let subj = PEOPLE[rng.below(PEOPLE.len())];
    format!("question: {q} answer: {a}. {subj} agrees with the answer. ")
}

fn gsm8k(rng: &mut Rng) -> String {
    let a = rng.below(40) + 2;
    let b = rng.below(30) + 1;
    let who = PEOPLE[rng.below(PEOPLE.len())];
    let obj = OBJECTS[rng.below(OBJECTS.len())];
    match rng.below(3) {
        0 => format!(
            "{who} has {a} {obj}s and buys {b} more. now {who} has {} {obj}s. ",
            a + b
        ),
        1 => format!(
            "{who} had {a} {obj}s and gave away {b}. now {who} has {} {obj}s. ",
            a.saturating_sub(b)
        ),
        _ => format!(
            "there are {a} boxes with {b} {obj}s each, so {} {obj}s in total. ",
            a * b
        ),
    }
}

fn qasc(rng: &mut Rng) -> String {
    let s = SUBJECTS[rng.below(SUBJECTS.len())];
    let v = VERBS[rng.below(VERBS.len())];
    let n = NEEDS[rng.below(NEEDS.len())];
    let an = ANIMALS[rng.below(ANIMALS.len())];
    format!("fact: {s} {v} when given {n}. a {an} also needs {n} to live. ")
}

fn mmlu(rng: &mut Rng) -> String {
    let qs = [
        ("which planet is red?", ["mars", "venus", "pluto", "luna"], 0usize),
        ("what gas do plants breathe?", ["carbon", "helium", "neon", "argon"], 0),
        ("how many legs has a spider?", ["eight", "six", "four", "ten"], 0),
        ("what melts ice?", ["heat", "cold", "dark", "wind"], 0),
    ];
    let (q, opts, ans) = qs[rng.below(qs.len())];
    format!(
        "question: {q} (a) {} (b) {} (c) {} (d) {} answer: (a) {}. ",
        opts[0], opts[1], opts[2], opts[3], opts[ans]
    )
}

fn date(rng: &mut Rng) -> String {
    let d = rng.below(27) + 1;
    let m = rng.below(12);
    let wd = rng.below(7);
    format!(
        "today is {} {} {}. yesterday was {}. tomorrow is {}. ",
        DAYS[wd],
        MONTHS[m],
        d + 1,
        DAYS[(wd + 6) % 7],
        DAYS[(wd + 1) % 7]
    )
}

fn tracking(rng: &mut Rng) -> String {
    let p1 = PEOPLE[rng.below(PEOPLE.len())];
    let mut p2 = PEOPLE[rng.below(PEOPLE.len())];
    while p2 == p1 {
        p2 = PEOPLE[rng.below(PEOPLE.len())];
    }
    let c = COLORS[rng.below(COLORS.len())];
    let o = OBJECTS[rng.below(OBJECTS.len())];
    let pl = PLACES[rng.below(PLACES.len())];
    format!(
        "{p1} holds the {c} {o} in the {pl}. {p1} gives the {c} {o} to {p2}. now {p2} holds the {c} {o}. "
    )
}

/// The tiled/blocked/threaded kernel sweep grid consumed by
/// `benches/kernel_throughput.rs` and emitted into `BENCH_kernels.json`:
/// every shape × tile at one thread (tiled-vs-scalar), every shape ×
/// thread count at the default tile (batched-driver scaling), and every
/// query count at the prefill shape (query-blocked vs per-query).
///
/// Tile sizes swept for the tiled-vs-scalar comparison.
pub const SWEEP_TILES: [usize; 3] = [16, 32, 64];

/// Thread counts swept on the batched driver.
pub const SWEEP_THREADS: [usize; 4] = [1, 2, 4, 8];

/// Problem shapes swept; (2048, 64) is the acceptance headline point.
pub const SWEEP_SHAPES: [(usize, usize); 2] = [(512, 64), (2048, 64)];

/// Query counts swept for the query-blocked vs per-query comparison at
/// the prefill shape (nkv=2048, d=64); nq=512 is the acceptance headline
/// point (blocked/per-query throughput ratio).
pub const SWEEP_NQ: [usize; 4] = [1, 8, 64, 512];

/// Build a training corpus of roughly `target_bytes` by concatenating
/// prompts from all suites (the zoo models train on this mixture).
pub fn training_corpus(target_bytes: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(target_bytes + 128);
    while out.len() < target_bytes {
        let suite = ALL_SUITES[rng.below(ALL_SUITES.len())];
        out.push_str(&suite.prompt(&mut rng));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_nonempty_ascii_and_deterministic() {
        for suite in ALL_SUITES {
            let a = suite.prompts(5, 42);
            let b = suite.prompts(5, 42);
            assert_eq!(a, b, "{}", suite.name());
            for p in &a {
                assert!(!p.is_empty());
                assert!(p.is_ascii(), "{}: {p}", suite.name());
                assert!(p.len() < 300);
            }
        }
    }

    #[test]
    fn suites_differ() {
        let a = Suite::Csqa.prompts(3, 1);
        let b = Suite::Gsm8k.prompts(3, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn gsm8k_arithmetic_is_correct() {
        // the generated text must contain internally consistent numbers
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let p = gsm8k(&mut rng);
            assert!(p.contains("now") || p.contains("total"), "{p}");
        }
    }

    #[test]
    fn sweep_constants_cover_the_acceptance_point() {
        // the acceptance headline point (n=2048, d=64) with a 1-thread entry
        assert!(SWEEP_SHAPES.contains(&(2048, 64)));
        assert!(SWEEP_THREADS.contains(&1));
        assert!(SWEEP_TILES.iter().all(|&t| t >= 1));
        assert!(SWEEP_THREADS.windows(2).all(|w| w[0] < w[1]));
        // the blocked-vs-per-query headline point (nq=512) plus the
        // per-query anchor nq=1
        assert!(SWEEP_NQ.contains(&512) && SWEEP_NQ.contains(&1));
        assert!(SWEEP_NQ.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn corpus_reaches_target_and_mixes() {
        let c = training_corpus(10_000, 3);
        assert!(c.len() >= 10_000);
        assert!(c.contains("question:"));
        assert!(c.contains("fact:"));
        assert!(c.contains("today is"));
    }
}
