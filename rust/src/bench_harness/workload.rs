//! Serving workload generation for the coordinator benches: session
//! lifecycles (prefill then a decode stream) with deterministic pseudo-
//! random arrival interleaving.

use crate::coordinator::request::{AttentionRequest, RequestKind, ShapeSig, Variant};
use crate::util::rng::Rng;
use std::time::Instant;

/// Workload parameters.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub sessions: usize,
    pub prefill_len: usize,
    pub decode_steps: usize,
    pub sig: ShapeSig,
    pub variant: Variant,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            sessions: 4,
            prefill_len: 64,
            decode_steps: 16,
            sig: ShapeSig { heads: 4, head_dim: 32 },
            variant: Variant::FlashD,
            seed: 1,
        }
    }
}

/// Generate the request sequence for one session.
pub fn session_requests(spec: &WorkloadSpec, session: u64, base_id: u64) -> Vec<AttentionRequest> {
    let mut rng = Rng::new(spec.seed ^ session.wrapping_mul(0x9E37));
    let hd = spec.sig.heads * spec.sig.head_dim;
    // score scale ~ trained-model range
    let std = (2.0 / (spec.sig.head_dim as f32).sqrt()).sqrt();
    let mut reqs = Vec::new();
    reqs.push(AttentionRequest {
        id: base_id,
        kind: RequestKind::Prefill { session },
        variant: spec.variant,
        sig: spec.sig,
        q: rng.normal_vec(hd, std),
        nq: 1,
        k: rng.normal_vec(hd * spec.prefill_len, std),
        v: rng.normal_vec(hd * spec.prefill_len, 1.0),
        nkv: spec.prefill_len,
        submitted_at: Instant::now(),
    });
    for i in 0..spec.decode_steps {
        reqs.push(AttentionRequest {
            id: base_id + 1 + i as u64,
            kind: RequestKind::Decode { session },
            variant: spec.variant,
            sig: spec.sig,
            q: rng.normal_vec(hd, std),
            nq: 1,
            k: rng.normal_vec(hd, std),
            v: rng.normal_vec(hd, 1.0),
            nkv: 1,
            submitted_at: Instant::now(),
        });
    }
    reqs
}

/// A stateless prefill-style request (carries its own K/V).
pub fn stateless_request(spec: &WorkloadSpec, id: u64, nq: usize, nkv: usize) -> AttentionRequest {
    let mut rng = Rng::new(spec.seed ^ id.wrapping_mul(0x2545F491));
    let hd = spec.sig.heads * spec.sig.head_dim;
    let std = (2.0 / (spec.sig.head_dim as f32).sqrt()).sqrt();
    AttentionRequest {
        id,
        kind: RequestKind::Stateless,
        variant: spec.variant,
        sig: spec.sig,
        q: rng.normal_vec(hd * nq, std),
        nq,
        k: rng.normal_vec(hd * nkv, std),
        v: rng.normal_vec(hd * nkv, 1.0),
        nkv,
        submitted_at: Instant::now(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_shape() {
        let spec = WorkloadSpec::default();
        let reqs = session_requests(&spec, 3, 100);
        assert_eq!(reqs.len(), 1 + spec.decode_steps);
        assert!(matches!(reqs[0].kind, RequestKind::Prefill { session: 3 }));
        for r in &reqs {
            assert!(r.validate().is_ok(), "{:?}", r.kind);
        }
        assert_eq!(reqs[1].id, 101);
    }

    #[test]
    fn stateless_valid() {
        let r = stateless_request(&WorkloadSpec::default(), 9, 4, 32);
        assert!(r.validate().is_ok());
        assert_eq!(r.nq, 4);
    }
}
