//! Serving workload generation for the coordinator benches: session
//! lifecycles (prefill then a decode stream) with deterministic pseudo-
//! random arrival interleaving.

use crate::coordinator::request::{AttentionRequest, RequestKind, ShapeSig, Variant};
use crate::util::rng::Rng;
use std::time::Instant;

/// Workload parameters.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub sessions: usize,
    pub prefill_len: usize,
    pub decode_steps: usize,
    pub sig: ShapeSig,
    pub variant: Variant,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            sessions: 4,
            prefill_len: 64,
            decode_steps: 16,
            sig: ShapeSig { heads: 4, head_dim: 32 },
            variant: Variant::FlashD,
            seed: 1,
        }
    }
}

/// Generate the request sequence for one session.
pub fn session_requests(spec: &WorkloadSpec, session: u64, base_id: u64) -> Vec<AttentionRequest> {
    let mut rng = Rng::new(spec.seed ^ session.wrapping_mul(0x9E37));
    let hd = spec.sig.heads * spec.sig.head_dim;
    // score scale ~ trained-model range
    let std = (2.0 / (spec.sig.head_dim as f32).sqrt()).sqrt();
    let mut reqs = Vec::new();
    reqs.push(AttentionRequest {
        id: base_id,
        kind: RequestKind::Prefill { session },
        variant: spec.variant,
        sig: spec.sig,
        q: rng.normal_vec(hd, std),
        nq: 1,
        k: rng.normal_vec(hd * spec.prefill_len, std),
        v: rng.normal_vec(hd * spec.prefill_len, 1.0),
        nkv: spec.prefill_len,
        submitted_at: Instant::now(),
    });
    for i in 0..spec.decode_steps {
        reqs.push(AttentionRequest {
            id: base_id + 1 + i as u64,
            kind: RequestKind::Decode { session },
            variant: spec.variant,
            sig: spec.sig,
            q: rng.normal_vec(hd, std),
            nq: 1,
            k: rng.normal_vec(hd, std),
            v: rng.normal_vec(hd, 1.0),
            nkv: 1,
            submitted_at: Instant::now(),
        });
    }
    reqs
}

/// A mixed prefill+decode scenario: mostly short-prefill sessions with a
/// periodic long-prefill session salted in — the head-of-line-blocking
/// stimulus the continuous-batching serving bench measures TTFT and
/// inter-token latency under.
#[derive(Clone, Debug)]
pub struct MixedSpec {
    /// Base session shape (count, short prefill length, decode steps).
    pub spec: WorkloadSpec,
    /// Every `long_every`-th session (0 disables) prefills
    /// `long_prefill_len` tokens instead of `spec.prefill_len`.
    pub long_every: usize,
    pub long_prefill_len: usize,
}

impl Default for MixedSpec {
    fn default() -> Self {
        MixedSpec {
            spec: WorkloadSpec::default(),
            long_every: 4,
            long_prefill_len: 1024,
        }
    }
}

/// Generate one request lifecycle per session for a mixed scenario —
/// each inner `Vec` is ready for `Coordinator::submit_stream`. Session
/// ids are the stream index; request ids are disjoint across streams.
pub fn mixed_streams(mix: &MixedSpec, base_id: u64) -> Vec<Vec<AttentionRequest>> {
    let stride = mix.spec.decode_steps as u64 + 1;
    (0..mix.spec.sessions)
        .map(|s| {
            let mut spec = mix.spec.clone();
            if mix.long_every > 0 && s % mix.long_every == 0 {
                spec.prefill_len = mix.long_prefill_len;
            }
            session_requests(&spec, s as u64, base_id + s as u64 * stride)
        })
        .collect()
}

/// A stateless prefill-style request (carries its own K/V).
pub fn stateless_request(spec: &WorkloadSpec, id: u64, nq: usize, nkv: usize) -> AttentionRequest {
    let mut rng = Rng::new(spec.seed ^ id.wrapping_mul(0x2545F491));
    let hd = spec.sig.heads * spec.sig.head_dim;
    let std = (2.0 / (spec.sig.head_dim as f32).sqrt()).sqrt();
    AttentionRequest {
        id,
        kind: RequestKind::Stateless,
        variant: spec.variant,
        sig: spec.sig,
        q: rng.normal_vec(hd * nq, std),
        nq,
        k: rng.normal_vec(hd * nkv, std),
        v: rng.normal_vec(hd * nkv, 1.0),
        nkv,
        submitted_at: Instant::now(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_shape() {
        let spec = WorkloadSpec::default();
        let reqs = session_requests(&spec, 3, 100);
        assert_eq!(reqs.len(), 1 + spec.decode_steps);
        assert!(matches!(reqs[0].kind, RequestKind::Prefill { session: 3 }));
        for r in &reqs {
            assert!(r.validate().is_ok(), "{:?}", r.kind);
        }
        assert_eq!(reqs[1].id, 101);
    }

    #[test]
    fn mixed_streams_salt_long_prefills() {
        let mix = MixedSpec {
            spec: WorkloadSpec { sessions: 6, prefill_len: 32, decode_steps: 4, ..Default::default() },
            long_every: 3,
            long_prefill_len: 200,
        };
        let streams = mixed_streams(&mix, 500);
        assert_eq!(streams.len(), 6);
        let mut ids = std::collections::HashSet::new();
        for (s, stream) in streams.iter().enumerate() {
            assert_eq!(stream.len(), 5);
            let want = if s % 3 == 0 { 200 } else { 32 };
            assert_eq!(stream[0].nkv, want, "session {s}");
            for r in stream {
                assert!(r.validate().is_ok());
                assert!(ids.insert(r.id), "duplicate request id {}", r.id);
            }
        }
    }

    #[test]
    fn stateless_valid() {
        let r = stateless_request(&WorkloadSpec::default(), 9, 4, 32);
        assert!(r.validate().is_ok());
        assert_eq!(r.nq, 4);
    }
}
