//! Serving workload generation for the coordinator benches: session
//! lifecycles (prefill then a decode stream) with deterministic pseudo-
//! random arrival interleaving, plus ShareGPT-like sampled length
//! distributions ([`LengthDist`]) so the load harness can replay
//! realistic long-tailed prompt/response mixes instead of fixed shapes.

use crate::coordinator::request::{AttentionRequest, RequestKind, ShapeSig, Variant};
use crate::util::rng::Rng;
use std::time::Instant;

/// A clamped lognormal length sampler — the standard model for
/// ShareGPT-style prompt/response token counts, whose empirical
/// distributions are long-tailed in exactly this way. Sampling is
/// deterministic for a given [`Rng`] state, so a seeded workload replays
/// bit-identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LengthDist {
    /// Mean of `ln(length)` — `exp(mu)` is the median length.
    pub mu: f64,
    /// Stddev of `ln(length)`; larger means a heavier tail.
    pub sigma: f64,
    /// Inclusive clamp bounds (tokens).
    pub min: usize,
    pub max: usize,
}

impl LengthDist {
    /// Lognormal with median `median` tokens and log-stddev `sigma`,
    /// clamped to `[min, max]`.
    pub fn lognormal(median: f64, sigma: f64, min: usize, max: usize) -> LengthDist {
        assert!(median > 0.0 && sigma >= 0.0 && min >= 1 && min <= max);
        LengthDist { mu: median.ln(), sigma, min, max }
    }

    /// Draw one length. Consumes exactly one normal variate, so sample
    /// streams stay aligned across spec changes.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = (self.mu + self.sigma * rng.normal()).exp();
        (x.round() as usize).clamp(self.min, self.max)
    }
}

/// Workload parameters.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub sessions: usize,
    pub prefill_len: usize,
    pub decode_steps: usize,
    pub sig: ShapeSig,
    pub variant: Variant,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            sessions: 4,
            prefill_len: 64,
            decode_steps: 16,
            sig: ShapeSig { heads: 4, head_dim: 32 },
            variant: Variant::FlashD,
            seed: 1,
        }
    }
}

/// Generate the request sequence for one session.
pub fn session_requests(spec: &WorkloadSpec, session: u64, base_id: u64) -> Vec<AttentionRequest> {
    let mut rng = Rng::new(spec.seed ^ session.wrapping_mul(0x9E37));
    let hd = spec.sig.heads * spec.sig.head_dim;
    // score scale ~ trained-model range
    let std = (2.0 / (spec.sig.head_dim as f32).sqrt()).sqrt();
    let mut reqs = Vec::new();
    reqs.push(AttentionRequest {
        id: base_id,
        kind: RequestKind::prefill(session),
        variant: spec.variant,
        sig: spec.sig,
        q: rng.normal_vec(hd, std),
        nq: 1,
        k: rng.normal_vec(hd * spec.prefill_len, std),
        v: rng.normal_vec(hd * spec.prefill_len, 1.0),
        nkv: spec.prefill_len,
        submitted_at: Instant::now(),
    });
    for i in 0..spec.decode_steps {
        reqs.push(AttentionRequest {
            id: base_id + 1 + i as u64,
            kind: RequestKind::Decode { session },
            variant: spec.variant,
            sig: spec.sig,
            q: rng.normal_vec(hd, std),
            nq: 1,
            k: rng.normal_vec(hd, std),
            v: rng.normal_vec(hd, 1.0),
            nkv: 1,
            submitted_at: Instant::now(),
        });
    }
    reqs
}

/// A mixed prefill+decode scenario: mostly short-prefill sessions with a
/// periodic long-prefill session salted in — the head-of-line-blocking
/// stimulus the continuous-batching serving bench measures TTFT and
/// inter-token latency under.
#[derive(Clone, Debug)]
pub struct MixedSpec {
    /// Base session shape (count, short prefill length, decode steps).
    pub spec: WorkloadSpec,
    /// Every `long_every`-th session (0 disables) prefills
    /// `long_prefill_len` tokens instead of `spec.prefill_len`.
    pub long_every: usize,
    pub long_prefill_len: usize,
    /// When set, each session's prefill length is drawn from this
    /// distribution (seeded off `spec.seed`) instead of the fixed
    /// `spec.prefill_len`; `long_every` salting still applies on top.
    pub prompt_len: Option<LengthDist>,
    /// When set, each session's decode-step count is drawn from this
    /// distribution instead of the fixed `spec.decode_steps`.
    pub response_len: Option<LengthDist>,
}

impl Default for MixedSpec {
    fn default() -> Self {
        MixedSpec {
            spec: WorkloadSpec::default(),
            long_every: 4,
            long_prefill_len: 1024,
            prompt_len: None,
            response_len: None,
        }
    }
}

/// Generate one request lifecycle per session for a mixed scenario —
/// each inner `Vec` is ready for `Coordinator::submit_stream`. Session
/// ids are the stream index; request ids are allocated from a running
/// offset, so they stay disjoint across streams even when per-session
/// lengths vary (a fixed stride of `decode_steps + 1` would collide the
/// moment a sampled session outgrows the shared spec).
pub fn mixed_streams(mix: &MixedSpec, base_id: u64) -> Vec<Vec<AttentionRequest>> {
    let mut len_rng = Rng::new(mix.spec.seed ^ 0x5A3D_C0DE);
    let mut next_id = base_id;
    (0..mix.spec.sessions)
        .map(|s| {
            let mut spec = mix.spec.clone();
            if let Some(d) = mix.prompt_len {
                spec.prefill_len = d.sample(&mut len_rng);
            }
            if let Some(d) = mix.response_len {
                spec.decode_steps = d.sample(&mut len_rng);
            }
            if mix.long_every > 0 && s % mix.long_every == 0 {
                spec.prefill_len = mix.long_prefill_len;
            }
            let reqs = session_requests(&spec, s as u64, next_id);
            next_id += reqs.len() as u64;
            reqs
        })
        .collect()
}

/// A stateless prefill-style request (carries its own K/V).
pub fn stateless_request(spec: &WorkloadSpec, id: u64, nq: usize, nkv: usize) -> AttentionRequest {
    let mut rng = Rng::new(spec.seed ^ id.wrapping_mul(0x2545F491));
    let hd = spec.sig.heads * spec.sig.head_dim;
    let std = (2.0 / (spec.sig.head_dim as f32).sqrt()).sqrt();
    AttentionRequest {
        id,
        kind: RequestKind::Stateless,
        variant: spec.variant,
        sig: spec.sig,
        q: rng.normal_vec(hd * nq, std),
        nq,
        k: rng.normal_vec(hd * nkv, std),
        v: rng.normal_vec(hd * nkv, 1.0),
        nkv,
        submitted_at: Instant::now(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_shape() {
        let spec = WorkloadSpec::default();
        let reqs = session_requests(&spec, 3, 100);
        assert_eq!(reqs.len(), 1 + spec.decode_steps);
        assert!(matches!(reqs[0].kind, RequestKind::Prefill { session: 3, .. }));
        for r in &reqs {
            assert!(r.validate().is_ok(), "{:?}", r.kind);
        }
        assert_eq!(reqs[1].id, 101);
    }

    #[test]
    fn mixed_streams_salt_long_prefills() {
        let mix = MixedSpec {
            spec: WorkloadSpec { sessions: 6, prefill_len: 32, decode_steps: 4, ..Default::default() },
            long_every: 3,
            long_prefill_len: 200,
            ..Default::default()
        };
        let streams = mixed_streams(&mix, 500);
        assert_eq!(streams.len(), 6);
        let mut ids = std::collections::HashSet::new();
        for (s, stream) in streams.iter().enumerate() {
            assert_eq!(stream.len(), 5);
            let want = if s % 3 == 0 { 200 } else { 32 };
            assert_eq!(stream[0].nkv, want, "session {s}");
            for r in stream {
                assert!(r.validate().is_ok());
                assert!(ids.insert(r.id), "duplicate request id {}", r.id);
            }
        }
    }

    #[test]
    fn stateless_valid() {
        let r = stateless_request(&WorkloadSpec::default(), 9, 4, 32);
        assert!(r.validate().is_ok());
        assert_eq!(r.nq, 4);
    }

    /// Same Rng state => same sample stream; different seeds diverge.
    #[test]
    fn length_dist_deterministic() {
        let d = LengthDist::lognormal(128.0, 1.0, 8, 2048);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..256).map(|_| d.sample(&mut rng)).collect()
        };
        assert_eq!(draw(7), draw(7), "same seed must replay the same lengths");
        assert_ne!(draw(7), draw(8));
    }

    /// Shape bounds: samples respect the clamp, straddle the median, and
    /// show the lognormal long tail (mean pulled above the median).
    #[test]
    fn length_dist_shape_bounds() {
        let d = LengthDist::lognormal(128.0, 0.8, 8, 4096);
        let mut rng = Rng::new(0x10C_A1);
        let xs: Vec<usize> = (0..4096).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| (8..=4096).contains(&x)));
        let below = xs.iter().filter(|&&x| x < 128).count() as f64 / xs.len() as f64;
        // exp(mu) is the median: ~half the mass on each side
        assert!((0.4..=0.6).contains(&below), "median split off: {below}");
        let mean = xs.iter().sum::<usize>() as f64 / xs.len() as f64;
        // lognormal mean = median * exp(sigma^2/2) ~ 1.38x the median
        assert!(mean > 128.0 * 1.15, "no long tail: mean {mean}");
        // the clamp actually binds somewhere in a 4096-draw tail
        let tight = LengthDist::lognormal(128.0, 0.8, 100, 160);
        let mut rng = Rng::new(0x10C_A2);
        assert!((0..512).map(|_| tight.sample(&mut rng)).all(|x| (100..=160).contains(&x)));
    }

    /// Request ids must stay globally unique when per-session lengths
    /// vary — the old fixed `decode_steps + 1` stride collided as soon as
    /// a sampled session was longer than the shared spec.
    #[test]
    fn mixed_streams_ids_unique_with_sampled_lengths() {
        let mix = MixedSpec {
            spec: WorkloadSpec { sessions: 24, decode_steps: 2, ..Default::default() },
            long_every: 5,
            long_prefill_len: 96,
            prompt_len: Some(LengthDist::lognormal(24.0, 1.0, 4, 128)),
            response_len: Some(LengthDist::lognormal(6.0, 1.0, 2, 40)),
        };
        let streams = mixed_streams(&mix, 9_000);
        let lens: Vec<usize> = streams.iter().map(Vec::len).collect();
        assert!(lens.iter().any(|&l| l != lens[0]), "sampled lengths must vary: {lens:?}");
        let mut ids = std::collections::HashSet::new();
        for stream in &streams {
            for r in stream {
                assert!(r.validate().is_ok());
                assert!(ids.insert(r.id), "duplicate request id {}", r.id);
            }
        }
        // and the whole construction replays bit-identically
        let replay = mixed_streams(&mix, 9_000);
        let ids2: Vec<u64> = replay.iter().flatten().map(|r| r.id).collect();
        let ids1: Vec<u64> = streams.iter().flatten().map(|r| r.id).collect();
        assert_eq!(ids1, ids2);
        assert_eq!(
            streams.iter().flatten().map(|r| r.nkv).collect::<Vec<_>>(),
            replay.iter().flatten().map(|r| r.nkv).collect::<Vec<_>>(),
        );
    }
}
