//! Shared benchmark infrastructure: the PromptBench-substitute suites, the
//! Table I skip study, trace capture for the power model, and serving
//! workload generation.

pub mod suites;
pub mod table1;
pub mod traces;
pub mod workload;
