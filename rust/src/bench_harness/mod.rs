//! Shared benchmark infrastructure: the PromptBench-substitute suites, the
//! Table I skip study, trace capture for the power model, and the
//! trace-driven serving load harness.
//!
//! # Serving load harness
//!
//! [`workload`] generates request lifecycles (prefill + decode streams,
//! ShareGPT-like lognormal [`workload::LengthDist`] prompt/response
//! lengths) and [`traces`] generates arrival processes (plain Poisson
//! and on-off modulated bursty gaps via [`traces::bursty_arrival_gaps`]).
//! `benches/coordinator_serving.rs` combines them into the scenario
//! matrix written to the committed `BENCH_serving.json`:
//!
//! | cell | stimulus |
//! |------|----------|
//! | `mixed_{fifo,decodefirst}_{fused,serial}` | policy x dispatch matrix, every 4th stream fronted by a long prefill |
//! | `sampled_lengths_*` | lognormal prompt/response token counts (long-tail lengths) |
//! | `bursty_*` | on-off modulated Poisson arrivals (overload-then-drain) |
//! | `abandonment_*` | clients drop their `StreamHandle` mid-generation |
//! | `long_context_nkv64k_*` | 64k-token prefills through the paged KV pool |
//! | `churn_tiny_sessions_*` | hundreds of tiny sessions under a small KV budget (LRU eviction) |
//! | `conflict_storm_same_session_*` | every stream on one session (fusion-group splits) |
//!
//! Every cell carries an SLO block: client-measured `ttft_us`, `itl_us`,
//! and `latency_us` percentile objects (`{p50, p99, count}`, µs) plus the
//! `rejected` / `evicted` / `abandoned` / `errors` / `completed` counters
//! from the server metrics snapshot. CI validates the full schema for
//! every cell after the smoke run. Everything is seeded and replays
//! deterministically; only walltimes vary between runs.

pub mod suites;
pub mod table1;
pub mod traces;
pub mod workload;
