//! Attention-trace capture: runs zoo models over suite prompts and collects
//! per-layer/head attention problems — the stimulus the power model (Fig. 5)
//! measures toggle activity on, mirroring the paper's "average power
//! measured after executing attention kernels for various LLMs".
//!
//! Also provides deterministic open-loop arrival traces for the serving
//! load harness: [`poisson_arrival_gaps`] (memoryless arrivals) and
//! [`bursty_arrival_gaps`] (an on-off modulated Poisson process, the
//! standard model for bursty production traffic).

use crate::bench_harness::suites::ALL_SUITES;
use crate::hw::activity::{self, ActivityStats};
use crate::kernels::AttnProblem;
use crate::model::engine::Engine;
use crate::model::tokenizer::ByteTokenizer;
use crate::numerics::Scalar;
use crate::runtime::Manifest;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::time::Duration;

/// Deterministic inter-arrival gaps for an open-loop Poisson arrival
/// process at `rate_hz`, via inverse-CDF sampling of the exponential
/// distribution. Gap `i` is the wait *before* arrival `i`, so a load
/// generator replays the trace by sleeping each gap before submitting.
pub fn poisson_arrival_gaps(seed: u64, rate_hz: f64, n: usize) -> Vec<Duration> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| {
            // uniform() is in [0, 1); flip so the log argument is in (0, 1]
            let u = 1.0 - rng.uniform();
            Duration::from_secs_f64(-u.ln() / rate_hz)
        })
        .collect()
}

/// Parameters of an on-off modulated Poisson arrival process (a 2-state
/// MMPP): arrivals are Poisson at `burst_rate_hz` while the modulating
/// state is ON and at `idle_rate_hz` while OFF, with exponentially
/// distributed state dwell times. The result is the super-Poisson
/// burstiness (squared coefficient of variation well above 1) that
/// production request traces show and a plain Poisson trace cannot.
#[derive(Clone, Copy, Debug)]
pub struct BurstSpec {
    /// Arrival rate while bursting (Hz).
    pub burst_rate_hz: f64,
    /// Background arrival rate between bursts (Hz).
    pub idle_rate_hz: f64,
    /// Mean dwell time in the bursting state (seconds).
    pub mean_burst_s: f64,
    /// Mean dwell time in the idle state (seconds).
    pub mean_idle_s: f64,
}

impl Default for BurstSpec {
    fn default() -> Self {
        BurstSpec {
            burst_rate_hz: 2_000.0,
            idle_rate_hz: 20.0,
            mean_burst_s: 0.05,
            mean_idle_s: 0.05,
        }
    }
}

/// Deterministic inter-arrival gaps for the on-off modulated Poisson
/// process described by `spec` — same contract as
/// [`poisson_arrival_gaps`] (gap `i` is the wait before arrival `i`).
/// The process starts in the bursting state; by the exponential's
/// memorylessness, the time-to-next-arrival is resampled at the new rate
/// whenever the modulating state flips mid-wait.
pub fn bursty_arrival_gaps(seed: u64, spec: &BurstSpec, n: usize) -> Vec<Duration> {
    assert!(spec.burst_rate_hz > 0.0 && spec.idle_rate_hz > 0.0, "rates must be positive");
    assert!(spec.mean_burst_s > 0.0 && spec.mean_idle_s > 0.0, "dwell means must be positive");
    let mut rng = crate::util::rng::Rng::new(seed);
    let exp = |rng: &mut crate::util::rng::Rng, rate: f64| -(1.0 - rng.uniform()).ln() / rate;
    let mut bursting = true;
    let mut dwell_left = exp(&mut rng, 1.0 / spec.mean_burst_s);
    let mut gaps = Vec::with_capacity(n);
    // time already waited on the current gap, across state flips
    let mut elapsed = 0.0;
    while gaps.len() < n {
        let rate = if bursting { spec.burst_rate_hz } else { spec.idle_rate_hz };
        let wait = exp(&mut rng, rate);
        if wait <= dwell_left {
            dwell_left -= wait;
            gaps.push(Duration::from_secs_f64(elapsed + wait));
            elapsed = 0.0;
        } else {
            // the state flips before the arrival lands: the remaining
            // dwell is waited out, then the wait restarts at the new
            // state's rate. Discarding the partial wait is legitimate —
            // exponential waits are memoryless.
            elapsed += dwell_left;
            bursting = !bursting;
            let mean = if bursting { spec.mean_burst_s } else { spec.mean_idle_s };
            dwell_left = exp(&mut rng, 1.0 / mean);
        }
    }
    gaps
}

/// The model trace capture uses when a manifest lists several: the
/// lexicographically-first name. Explicit ordering (not map iteration
/// order) so the Fig. 5 power stimulus cannot silently switch models
/// between two loads of the same manifest.
pub fn representative_model(man: &Manifest) -> Option<&str> {
    man.models.keys().map(String::as_str).min()
}

/// Capture attention problems from a model over suite prompts.
pub fn capture_problems(engine: &Engine, prompts_per_suite: usize, seed: u64) -> Vec<AttnProblem> {
    let tok = ByteTokenizer;
    let mut problems = Vec::new();
    for suite in ALL_SUITES {
        for prompt in suite.prompts(prompts_per_suite, seed) {
            let len = prompt.len().clamp(8, engine.info.seq_len);
            let ids = tok.encode_window(&prompt, len);
            let (_, _, probs) = engine.forward_capture(&ids);
            problems.extend(probs);
        }
    }
    problems
}

/// Where trace-capture activity stats came from: a real model, or the
/// synthetic fallback (with the reason measurement was impossible — a
/// corrupt manifest reads differently from "no models trained yet").
#[derive(Clone, Debug, PartialEq)]
pub enum TraceSource {
    /// Measured from `model`'s attention traces over the suite prompts.
    Measured { model: String },
    /// Synthetic random stimulus; `reason` says why measurement failed.
    Synthetic { reason: String },
}

/// Measure activity for a format from real model traces, reporting where
/// the stats came from. Falls back to a synthetic stimulus when no
/// models/weights are available — the [`TraceSource::Synthetic`] reason
/// distinguishes a corrupt manifest from a merely absent one.
pub fn measured_activity_traced<T: Scalar>(
    dir: &Path,
    prompts_per_suite: usize,
) -> (ActivityStats, TraceSource) {
    let reason = match activity_from_models::<T>(dir, prompts_per_suite) {
        Ok((a, model)) if a.n_queries > 0 => {
            return (a, TraceSource::Measured { model });
        }
        Ok((_, model)) => format!("model {model} produced no attention traces"),
        Err(e) => e.to_string(),
    };
    // Synthetic fallback: random attention problems at a trained-model
    // score scale.
    let mut rng = crate::util::rng::Rng::new(0xAC71);
    let problems: Vec<AttnProblem> =
        (0..8).map(|_| AttnProblem::random(&mut rng, 4, 64, 32, 2.0)).collect();
    (activity::measure::<T>(&problems), TraceSource::Synthetic { reason })
}

/// [`measured_activity_traced`] minus the provenance, logging the
/// fallback reason to stderr instead of swallowing it.
pub fn measured_activity<T: Scalar>(dir: &Path, prompts_per_suite: usize) -> ActivityStats {
    let (a, src) = measured_activity_traced::<T>(dir, prompts_per_suite);
    if let TraceSource::Synthetic { reason } = &src {
        eprintln!("trace capture: synthetic fallback ({reason})");
    }
    a
}

fn activity_from_models<T: Scalar>(
    dir: &Path,
    prompts_per_suite: usize,
) -> Result<(ActivityStats, String)> {
    let man = Manifest::load(dir)?;
    // One model is representative for toggle statistics; the selection
    // must be deterministic across loads (see `representative_model`).
    let name = representative_model(&man)
        .ok_or_else(|| anyhow!("manifest at {} lists no models", dir.display()))?
        .to_string();
    let engine = Engine::from_artifacts(dir, &name)?;
    let problems = capture_problems(&engine, prompts_per_suite, 11);
    Ok((activity::measure::<T>(&problems), name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Bf16;

    #[test]
    fn fallback_activity_is_sane() {
        let a = measured_activity::<Bf16>(Path::new("/nonexistent"), 1);
        assert!(a.alpha_kv > 0.05 && a.alpha_kv < 0.7);
        assert!(a.n_queries > 0);
    }

    /// The fallback must say *why* it fell back — a missing manifest is
    /// a diagnosable reason, not a silently swallowed error.
    #[test]
    fn fallback_reason_is_surfaced() {
        let (a, src) = measured_activity_traced::<Bf16>(Path::new("/nonexistent"), 1);
        assert!(a.n_queries > 0);
        match src {
            TraceSource::Synthetic { reason } => {
                assert!(!reason.is_empty(), "fallback reason must be non-empty");
            }
            other => panic!("expected synthetic fallback, got {other:?}"),
        }
    }

    /// Regression: trace capture must pick the same model on every load
    /// of the same manifest — the lexicographically-first name, not
    /// whatever a map's iteration order happens to yield.
    #[test]
    fn representative_model_is_deterministic_lexicographic() {
        let model = r#"{"config": {"vocab_size": 256, "seq_len": 64, "d_model": 32,
            "n_heads": 4, "n_layers": 2, "d_ff": 64, "block_q": 16, "block_k": 16},
            "param_spec": []}"#;
        let text = format!(
            r#"{{"artifacts": {{}}, "models": {{"zeta-late": {model}, "alpha-first": {model}, "mid-way": {model}}}}}"#
        );
        let a = Manifest::parse(&text).expect("manifest parses");
        let b = Manifest::parse(&text).expect("manifest parses");
        assert_eq!(representative_model(&a), representative_model(&b));
        assert_eq!(representative_model(&a), Some("alpha-first"));
        assert_eq!(representative_model(&Manifest::parse(r#"{"artifacts": {}}"#).unwrap()), None);
    }

    #[test]
    fn bursty_gaps_deterministic_and_separate_rates() {
        let spec = BurstSpec::default();
        let a = bursty_arrival_gaps(0xB005, &spec, 4096);
        let b = bursty_arrival_gaps(0xB005, &spec, 4096);
        assert_eq!(a, b, "same seed must replay the same trace");
        assert_ne!(a[..8], bursty_arrival_gaps(0x1D1E, &spec, 8)[..]);
        let xs: Vec<f64> = a.iter().map(Duration::as_secs_f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // the blended arrival rate sits strictly between the two phase
        // rates — and clear of either, proving both phases contribute
        assert!(mean > 1.0 / spec.burst_rate_hz * 1.25, "mean gap {mean} ~ pure burst");
        assert!(mean < 1.0 / spec.idle_rate_hz / 4.0, "mean gap {mean} ~ pure idle");
        // burstiness: squared coefficient of variation far above the
        // exponential's 1 — the whole point of the on-off modulation
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        let cv2 = var / (mean * mean);
        assert!(cv2 > 3.0, "gaps are not super-Poisson: cv^2 = {cv2}");
        // most arrivals land inside bursts: the median gap is a burst-
        // phase gap, an order of magnitude under the idle phase's mean
        let mut sorted = xs.clone();
        sorted.sort_by(|p, q| p.partial_cmp(q).unwrap());
        assert!(sorted[xs.len() / 2] < 1.0 / spec.idle_rate_hz / 10.0);
    }

    #[test]
    fn poisson_gaps_deterministic_with_exponential_mean() {
        let a = poisson_arrival_gaps(0xA11CE, 100.0, 4096);
        let b = poisson_arrival_gaps(0xA11CE, 100.0, 4096);
        assert_eq!(a, b, "same seed must replay the same trace");
        assert_ne!(a[..8], poisson_arrival_gaps(0xBEEF, 100.0, 8)[..]);
        let mean_s: f64 = a.iter().map(Duration::as_secs_f64).sum::<f64>() / a.len() as f64;
        // exponential(rate=100) has mean 10ms; 4096 samples keep the
        // sample mean within a comfortable 15%
        assert!((mean_s - 0.01).abs() < 0.0015, "mean {mean_s}");
        assert!(a.iter().all(|g| g.as_secs_f64() >= 0.0));
    }
}
