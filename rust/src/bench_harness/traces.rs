//! Attention-trace capture: runs zoo models over suite prompts and collects
//! per-layer/head attention problems — the stimulus the power model (Fig. 5)
//! measures toggle activity on, mirroring the paper's "average power
//! measured after executing attention kernels for various LLMs".
//!
//! Also provides deterministic open-loop arrival traces
//! ([`poisson_arrival_gaps`]) for the serving benches.

use crate::bench_harness::suites::ALL_SUITES;
use crate::hw::activity::{self, ActivityStats};
use crate::kernels::AttnProblem;
use crate::model::engine::Engine;
use crate::model::tokenizer::ByteTokenizer;
use crate::numerics::Scalar;
use anyhow::Result;
use std::path::Path;
use std::time::Duration;

/// Deterministic inter-arrival gaps for an open-loop Poisson arrival
/// process at `rate_hz`, via inverse-CDF sampling of the exponential
/// distribution. Gap `i` is the wait *before* arrival `i`, so a load
/// generator replays the trace by sleeping each gap before submitting.
pub fn poisson_arrival_gaps(seed: u64, rate_hz: f64, n: usize) -> Vec<Duration> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| {
            // uniform() is in [0, 1); flip so the log argument is in (0, 1]
            let u = 1.0 - rng.uniform();
            Duration::from_secs_f64(-u.ln() / rate_hz)
        })
        .collect()
}

/// Capture attention problems from a model over suite prompts.
pub fn capture_problems(engine: &Engine, prompts_per_suite: usize, seed: u64) -> Vec<AttnProblem> {
    let tok = ByteTokenizer;
    let mut problems = Vec::new();
    for suite in ALL_SUITES {
        for prompt in suite.prompts(prompts_per_suite, seed) {
            let len = prompt.len().clamp(8, engine.info.seq_len);
            let ids = tok.encode_window(&prompt, len);
            let (_, _, probs) = engine.forward_capture(&ids);
            problems.extend(probs);
        }
    }
    problems
}

/// Measure activity for a format from real model traces; falls back to the
/// synthetic default when no models/weights are available.
pub fn measured_activity<T: Scalar>(dir: &Path, prompts_per_suite: usize) -> ActivityStats {
    match activity_from_models::<T>(dir, prompts_per_suite) {
        Ok(a) if a.n_queries > 0 => a,
        _ => {
            // Synthetic fallback: random attention problems at a trained-
            // model score scale.
            let mut rng = crate::util::rng::Rng::new(0xAC71);
            let problems: Vec<AttnProblem> = (0..8)
                .map(|_| AttnProblem::random(&mut rng, 4, 64, 32, 2.0))
                .collect();
            activity::measure::<T>(&problems)
        }
    }
}

fn activity_from_models<T: Scalar>(dir: &Path, prompts_per_suite: usize) -> Result<ActivityStats> {
    let man = crate::runtime::Manifest::load(dir)?;
    let mut problems = Vec::new();
    // One model is representative for toggle statistics; use the first.
    if let Some(name) = man.models.keys().next() {
        let engine = Engine::from_artifacts(dir, name)?;
        problems.extend(capture_problems(&engine, prompts_per_suite, 11));
    }
    Ok(activity::measure::<T>(&problems))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Bf16;

    #[test]
    fn fallback_activity_is_sane() {
        let a = measured_activity::<Bf16>(Path::new("/nonexistent"), 1);
        assert!(a.alpha_kv > 0.05 && a.alpha_kv < 0.7);
        assert!(a.n_queries > 0);
    }

    #[test]
    fn poisson_gaps_deterministic_with_exponential_mean() {
        let a = poisson_arrival_gaps(0xA11CE, 100.0, 4096);
        let b = poisson_arrival_gaps(0xA11CE, 100.0, 4096);
        assert_eq!(a, b, "same seed must replay the same trace");
        assert_ne!(a[..8], poisson_arrival_gaps(0xBEEF, 100.0, 8)[..]);
        let mean_s: f64 = a.iter().map(Duration::as_secs_f64).sum::<f64>() / a.len() as f64;
        // exponential(rate=100) has mean 10ms; 4096 samples keep the
        // sample mean within a comfortable 15%
        assert!((mean_s - 0.01).abs() < 0.0015, "mean {mean_s}");
        assert!(a.iter().all(|g| g.as_secs_f64() >= 0.0));
    }
}
