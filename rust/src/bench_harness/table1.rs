//! Table I reproduction: percentage of skipped output updates during
//! inference, per (model, benchmark suite).
//!
//! For each zoo model the engine decodes/scores prompts from all six
//! suites with the instrumented FLASH-D attention and the paper's static
//! [-6, 11] criterion, counting how often the output update simplifies.
//!
//! NOTE (PR 1): the engine now runs the tiled kernel, whose block-skip
//! fast path generalizes the static low rule from score differences to
//! the telescoped full sigmoid argument (`kernels::tiled` docs). Counts
//! here therefore reflect the updates the tiled engine actually skipped —
//! at least as many as the paper's per-step static rule, and mildly
//! dependent on the tile length. For the strict per-step static numbers
//! use `flashd::attention_instrumented` / `flashd::skip_stats_from_scores`
//! (the hw activity model still does).

use crate::bench_harness::suites::ALL_SUITES;
use crate::kernels::flashd::SkipCriterion;
use crate::model::engine::Engine;
use crate::model::tokenizer::ByteTokenizer;
use anyhow::Result;
use std::path::Path;

/// One Table I cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub model: String,
    pub suite: &'static str,
    pub skip_pct: f64,
    pub skip_low: u64,
    pub skip_high: u64,
    pub total: u64,
}

/// Study parameters.
#[derive(Clone, Debug)]
pub struct Table1Options {
    pub prompts_per_suite: usize,
    pub decode_tokens: usize,
    pub seed: u64,
    pub criterion: SkipCriterion,
}

impl Default for Table1Options {
    fn default() -> Self {
        Table1Options {
            prompts_per_suite: 6,
            decode_tokens: 16,
            seed: 7,
            criterion: SkipCriterion::Static,
        }
    }
}

/// Run the study for one model engine across all suites.
pub fn run_model(engine: &mut Engine, opts: &Table1Options) -> Vec<Cell> {
    let tok = ByteTokenizer;
    engine.criterion = opts.criterion;
    let mut cells = Vec::new();
    for suite in ALL_SUITES {
        let mut agg = crate::kernels::flashd::SkipStats::default();
        for (i, prompt) in suite
            .prompts(opts.prompts_per_suite, opts.seed)
            .iter()
            .enumerate()
        {
            let window = engine.info.seq_len.saturating_sub(opts.decode_tokens).max(8);
            let ids = tok.encode_window(prompt, window.min(tok_len(prompt).max(8)));
            let (_, stats) = engine.greedy_decode_fast(&ids, opts.decode_tokens);
            agg.merge(&stats.skip);
            let _ = i;
        }
        cells.push(Cell {
            model: engine.info.name.clone(),
            suite: suite.name(),
            skip_pct: agg.percent(),
            skip_low: agg.skip_low,
            skip_high: agg.skip_high,
            total: agg.total,
        });
    }
    cells
}

fn tok_len(s: &str) -> usize {
    s.len()
}

/// Run the study for every model in the artifact directory's zoo.
pub fn run_all(dir: &Path, opts: &Table1Options) -> Result<Vec<Cell>> {
    let man = crate::runtime::Manifest::load(dir)?;
    let mut cells = Vec::new();
    for name in man.models.keys() {
        let mut engine = Engine::from_artifacts(dir, name)?;
        cells.extend(run_model(&mut engine, opts));
    }
    Ok(cells)
}

/// Render in the paper's row-per-model layout.
pub fn render_table(cells: &[Cell]) -> String {
    let mut models: Vec<&str> = cells.iter().map(|c| c.model.as_str()).collect();
    models.dedup();
    let mut out = format!("{:<14}", "LLM");
    for s in ALL_SUITES {
        out.push_str(&format!("{:>16}", s.name()));
    }
    out.push('\n');
    for m in models {
        out.push_str(&format!("{m:<14}"));
        for s in ALL_SUITES {
            let cell = cells
                .iter()
                .find(|c| c.model == m && c.suite == s.name());
            match cell {
                Some(c) => out.push_str(&format!("{:>15.2}%", c.skip_pct)),
                None => out.push_str(&format!("{:>16}", "-")),
            }
        }
        out.push('\n');
    }
    out
}

pub fn to_csv(cells: &[Cell]) -> String {
    let mut out = String::from("model,suite,skip_pct,skip_low,skip_high,total\n");
    for c in cells {
        out.push_str(&format!(
            "{},{},{:.4},{},{},{}\n",
            c.model, c.suite, c.skip_pct, c.skip_low, c.skip_high, c.total
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_handles_multiple_models() {
        let cells = vec![
            Cell { model: "a".into(), suite: "CSQA", skip_pct: 1.5, skip_low: 3, skip_high: 0, total: 200 },
            Cell { model: "b".into(), suite: "CSQA", skip_pct: 2.5, skip_low: 5, skip_high: 0, total: 200 },
        ];
        let t = render_table(&cells);
        assert!(t.contains("1.50%"));
        assert!(t.contains("2.50%"));
        assert_eq!(to_csv(&cells).lines().count(), 3);
    }
}
