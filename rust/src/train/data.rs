//! Training data: token batches sampled from the synthetic suite corpus.

use crate::bench_harness::suites;
use crate::model::tokenizer::ByteTokenizer;
use crate::util::rng::Rng;

/// Samples fixed-length token windows from a generated corpus.
pub struct BatchSampler {
    corpus: Vec<i32>,
    rng: Rng,
    batch: usize,
    seq: usize,
}

impl BatchSampler {
    pub fn new(seed: u64, batch: usize, seq: usize) -> BatchSampler {
        // ~256 KiB of mixed suite text is plenty for a byte-level tiny model.
        let text = suites::training_corpus(256 * 1024, seed ^ 0xC0FFEE);
        let corpus = ByteTokenizer.encode(&text);
        BatchSampler { corpus, rng: Rng::new(seed), batch, seq }
    }

    /// Next (batch, seq) token window, flat row-major.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq);
        let max_start = self.corpus.len() - self.seq - 1;
        for _ in 0..self.batch {
            let start = self.rng.below(max_start);
            out.extend_from_slice(&self.corpus[start..start + self.seq]);
        }
        out
    }

    pub fn corpus_len(&self) -> usize {
        self.corpus.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_right_shape_and_range() {
        let mut s = BatchSampler::new(1, 4, 32);
        let b = s.next_batch();
        assert_eq!(b.len(), 4 * 32);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = BatchSampler::new(9, 2, 16);
        let mut b = BatchSampler::new(9, 2, 16);
        assert_eq!(a.next_batch(), b.next_batch());
        let mut c = BatchSampler::new(10, 2, 16);
        assert_ne!(a.next_batch(), c.next_batch());
    }

    #[test]
    fn corpus_is_substantial() {
        let s = BatchSampler::new(2, 1, 8);
        assert!(s.corpus_len() > 200_000);
    }
}
