//! The Rust training driver: runs the AOT-lowered `train_step_<model>`
//! artifact in a loop, carrying parameters and AdamW state across steps.
//! This is the end-to-end proof that all three layers compose — the JAX
//! train step (with the differentiable FLASH-D attention inside) executes
//! under the Rust event loop with Python long gone.

pub mod data;

use crate::model::weights::NamedTensor;
use crate::runtime::{lit_i32, lit_i32_scalar, to_vec_f32, Literal, Runtime};
use anyhow::{anyhow, Result};
use std::path::Path;
use std::time::Instant;

/// Training run configuration.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub model: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// Write weights_<model>.fdw into the artifact dir at the end.
    pub save: bool,
    pub quiet: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            model: "phi-tiny".into(),
            steps: 300,
            seed: 0,
            log_every: 20,
            save: true,
            quiet: false,
        }
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub model: String,
    pub steps: usize,
    /// (step, loss) samples at log_every cadence plus first/last.
    pub losses: Vec<(usize, f32)>,
    pub first_loss: f32,
    pub final_loss: f32,
    pub tokens_per_s: f64,
    pub wall_s: f64,
}

/// Run training through the PJRT train_step artifact.
pub fn train(dir: &Path, opts: &TrainOptions) -> Result<TrainReport> {
    let rt = Runtime::open(dir)?;
    let info = rt
        .manifest
        .models
        .get(&opts.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", opts.model))?
        .clone();
    let artifact = format!("train_step_{}", opts.model);
    if !rt.manifest.artifacts.contains_key(&artifact) {
        return Err(anyhow!("missing artifact {artifact}"));
    }
    let batch = rt.manifest.artifacts[&artifact].batch;
    let seq = info.seq_len;

    // Initial parameters + zeroed AdamW moments.
    let init = crate::model::weights::read_fdw(dir.join(&info.init_weights))?;
    if init.len() != info.param_spec.len() {
        return Err(anyhow!("init weights/spec mismatch"));
    }
    let mut params: Vec<Literal> = Vec::with_capacity(init.len());
    let mut m_state: Vec<Literal> = Vec::with_capacity(init.len());
    let mut v_state: Vec<Literal> = Vec::with_capacity(init.len());
    for t in &init {
        params.push(crate::runtime::lit_f32(&t.data, &t.shape)?);
        let zeros = vec![0.0f32; t.numel()];
        m_state.push(crate::runtime::lit_f32(&zeros, &t.shape)?);
        v_state.push(crate::runtime::lit_f32(&zeros, &t.shape)?);
    }

    // Token stream from the synthetic corpus.
    let mut sampler = data::BatchSampler::new(opts.seed, batch, seq);

    let started = Instant::now();
    let mut losses = Vec::new();
    let mut first_loss = f32::NAN;
    let mut final_loss = f32::NAN;
    let n = info.param_spec.len();

    for step in 0..opts.steps {
        let tokens = sampler.next_batch();
        let mut inputs: Vec<Literal> = Vec::with_capacity(3 * n + 2);
        // Order must match aot.py::tstep: params, m, v, step, tokens.
        inputs.extend(params.drain(..));
        inputs.extend(m_state.drain(..));
        inputs.extend(v_state.drain(..));
        inputs.push(lit_i32_scalar(step as i32));
        inputs.push(lit_i32(&tokens, &[batch, seq])?);

        let mut out = rt.execute(&artifact, &inputs)?;
        let loss_lit = out.pop().ok_or_else(|| anyhow!("missing loss output"))?;
        let loss = to_vec_f32(&loss_lit)?[0];
        if !loss.is_finite() {
            return Err(anyhow!("loss diverged at step {step}: {loss}"));
        }
        v_state = out.split_off(2 * n);
        m_state = out.split_off(n);
        params = out;

        if step == 0 {
            first_loss = loss;
        }
        final_loss = loss;
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            losses.push((step, loss));
            if !opts.quiet {
                let tps = ((step + 1) * batch * seq) as f64 / started.elapsed().as_secs_f64();
                println!(
                    "[train {}] step {:>4}  loss {:.4}  ({:.0} tok/s)",
                    opts.model, step, loss, tps
                );
            }
        }
    }

    let wall_s = started.elapsed().as_secs_f64();
    let report = TrainReport {
        model: opts.model.clone(),
        steps: opts.steps,
        losses,
        first_loss,
        final_loss,
        tokens_per_s: (opts.steps * batch * seq) as f64 / wall_s,
        wall_s,
    };

    if opts.save {
        let tensors: Vec<NamedTensor> = info
            .param_spec
            .iter()
            .zip(&params)
            .map(|((name, shape), lit)| {
                Ok(NamedTensor {
                    name: name.clone(),
                    shape: shape.clone(),
                    data: to_vec_f32(lit)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let out = dir.join(format!("weights_{}.fdw", opts.model));
        crate::model::weights::write_fdw(&out, &tensors)?;
        if !opts.quiet {
            println!("[train {}] saved {}", opts.model, out.display());
        }
    }
    Ok(report)
}
