//! Tiny CLI argument parser — replaces clap (not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments. `known_flags` lists the options
    /// that take no value (everything else with a `--` prefix consumes the
    /// next token unless written as `--k=v`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.opts.insert(body.to_string(), v);
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.pos.push(a);
            }
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn key_value_styles() {
        let a = parse("--steps 100 --lr=0.003 pos1 --verbose", &["verbose"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.003);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("--steps 5 --dry-run", &[]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get_usize("steps", 0), 5);
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("--fast --out file.txt", &["fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("file.txt"));
    }

    #[test]
    fn defaults() {
        let a = parse("", &[]);
        assert_eq!(a.get_or("model", "phi-tiny"), "phi-tiny");
        assert_eq!(a.get_usize("steps", 7), 7);
        assert!(!a.flag("x"));
    }
}
