//! Minimal JSON reader/writer — replaces serde_json (not in the offline
//! vendor set). Supports the full JSON grammar we emit/consume: objects,
//! arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (callers use the blanket `ToString::to_string`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

/// Convenience builder for writing report/metric JSON.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "hi\n\"q\""}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e").unwrap().as_str(), Some("hi\n\"q\""));
        // serialize -> parse -> equal
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{"version":1,"artifacts":{"x":{"file":"x.hlo.txt","inputs":[{"shape":[4,128,32],"dtype":"float32"}],"n_outputs":1}}}"#;
        let v = Json::parse(text).unwrap();
        let art = v.get("artifacts").unwrap().get("x").unwrap();
        let shape: Vec<usize> = art
            .get("inputs").unwrap().idx(0).unwrap()
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|j| j.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![4, 128, 32]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str(), Some("éx"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
