//! Property-testing driver — replaces proptest (not in the offline vendor
//! set). Random-input properties with simple input shrinking for scalar and
//! vector cases.
//!
//! Usage:
//! ```
//! use flashd::prop_assert;
//! use flashd::util::prop::{forall, Gen};
//! forall("sum is commutative", 200, |g| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     prop_assert!(g, (a + b - (b + a)).abs() < 1e-12, "a={a} b={b}");
//!     true
//! });
//! ```

use crate::util::rng::Rng;

/// Value generator handed to property bodies; records a textual trace of
/// generated values for failure reports.
pub struct Gen {
    rng: Rng,
    pub trace: Vec<String>,
    pub failure: Option<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), trace: Vec::new(), failure: None }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = lo + self.rng.below(hi - lo + 1);
        self.trace.push(format!("usize {v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("f64 {v}"));
        v
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        let v = self.rng.normal() as f32 * std;
        self.trace.push(format!("n {v}"));
        v
    }

    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        let v = self.rng.normal_vec(n, std);
        self.trace.push(format!("vec[{n}] std={std}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool {v}"));
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.trace.push(format!("choice #{i}"));
        &xs[i]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Record a failure message (used by the `prop_assert!` macro).
    pub fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
    }
}

/// Run `cases` random cases of a property. The body returns `true` to pass;
/// returning `false` or recording a failure via `Gen::fail` fails the
/// property with a reproducible seed + trace report.
///
/// Seeds are derived deterministically from the property name so failures
/// reproduce across runs; set FLASHD_PROP_SEED to override the base seed.
pub fn forall<F: FnMut(&mut Gen) -> bool>(name: &str, cases: u64, mut body: F) {
    let base = std::env::var("FLASHD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| fnv(name));
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        let ok = body(&mut g);
        if !ok || g.failure.is_some() {
            panic!(
                "property '{name}' failed at case {case} (seed {seed})\n  {}\n  trace: {}",
                g.failure.unwrap_or_else(|| "returned false".into()),
                g.trace.join(", ")
            );
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert inside a property body with context captured into the report.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !$cond {
            $g.fail(format!($($fmt)*));
            return false;
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            count += 1;
            x >= 0.0 && x < 1.0
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'alwaysfails' failed")]
    fn failing_property_panics_with_seed() {
        forall("alwaysfails", 10, |g| {
            let _ = g.usize_in(0, 10);
            false
        });
    }

    #[test]
    fn macro_records_context() {
        let result = std::panic::catch_unwind(|| {
            forall("macrofail", 5, |g| {
                let x = g.f64_in(2.0, 3.0);
                prop_assert!(g, x < 1.0, "x was {x}");
                true
            });
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("x was"), "{msg}");
    }

    #[test]
    fn deterministic_given_name() {
        let mut first: Vec<f64> = Vec::new();
        forall("det", 5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            true
        });
        let mut second: Vec<f64> = Vec::new();
        forall("det", 5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            true
        });
        assert_eq!(first, second);
    }
}
