//! Criterion-like micro/bench harness — replaces criterion (not in the
//! offline vendor set). Used by the `cargo bench` targets (harness = false).
//!
//! Features: warmup, adaptive iteration count targeting a fixed measurement
//! time, mean/stddev/percentile reporting, throughput annotation, and CSV
//! report emission under `reports/`.

use std::fmt::Write as _;
use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl Stats {
    pub fn report_line(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12}  ±{:>9}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.p95_ns),
            self.iters
        );
        if let Some((items, unit)) = self.throughput {
            let per_sec = items / (self.mean_ns / 1e9);
            let _ = write!(s, "  {:>12.2} {}/s", per_sec, unit);
        }
        s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A bench suite: collects results, prints criterion-style lines, and can
/// dump a CSV into reports/.
pub struct Bench {
    pub suite: String,
    pub results: Vec<Stats>,
    /// Derived headline quantities (e.g. speedup ratios between two
    /// measured entries), emitted under `"derived"` in the JSON report.
    pub derived: Vec<(String, f64)>,
    pub measure_time: Duration,
    pub warmup_time: Duration,
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        // Respect a quick mode for CI-ish runs: FLASHD_BENCH_FAST=1.
        let fast = std::env::var("FLASHD_BENCH_FAST").is_ok();
        Bench {
            suite: suite.to_string(),
            results: Vec::new(),
            derived: Vec::new(),
            measure_time: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            warmup_time: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
        }
    }

    /// Record a derived headline number (printed and emitted under
    /// `"derived"` in the JSON report) — the perf-trajectory quantities
    /// (tiled/scalar, blocked/per-query) are tracked this way.
    pub fn note(&mut self, name: &str, value: f64) {
        println!("-- derived {name} = {value:.3}");
        self.derived.push((name.to_string(), value));
    }

    /// Benchmark a closure; returns its mean ns/iter.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        self.bench_with_throughput(name, None, &mut f)
    }

    /// Benchmark annotated with a throughput quantity (e.g. tokens, rows).
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        mut f: F,
    ) -> f64 {
        self.bench_with_throughput(name, Some((items, unit)), &mut f)
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        f: &mut dyn FnMut(),
    ) -> f64 {
        // Warmup + estimate per-iter cost.
        let wstart = Instant::now();
        let mut witers = 0u64;
        while wstart.elapsed() < self.warmup_time || witers < 3 {
            f();
            witers += 1;
            if witers > 1_000_000 {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_nanos() as f64 / witers as f64;

        // Pick a batch size so one sample is ~1/50 of measure time.
        let target_sample_ns = self.measure_time.as_nanos() as f64 / 50.0;
        let batch = ((target_sample_ns / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut samples = Vec::new();
        let mstart = Instant::now();
        let mut total_iters = 0u64;
        while mstart.elapsed() < self.measure_time || samples.len() < 5 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() > 10_000 {
                break;
            }
        }

        let mean = crate::util::mean(&samples);
        let stats = Stats {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            stddev_ns: crate::util::stddev(&samples),
            p50_ns: crate::util::percentile(&samples, 50.0),
            p95_ns: crate::util::percentile(&samples, 95.0),
            throughput,
        };
        println!("{}", stats.report_line());
        self.results.push(stats);
        mean
    }

    /// Write all collected results as CSV (plus a JSON twin) under reports/.
    pub fn write_csv(&self) {
        std::fs::create_dir_all("reports").ok();
        let mut csv = String::from("name,iters,mean_ns,stddev_ns,p50_ns,p95_ns\n");
        for r in &self.results {
            let _ = writeln!(
                csv,
                "{},{},{:.1},{:.1},{:.1},{:.1}",
                r.name, r.iters, r.mean_ns, r.stddev_ns, r.p50_ns, r.p95_ns
            );
        }
        let path = format!("reports/bench_{}.csv", self.suite);
        std::fs::write(&path, csv).ok();
        println!("-- wrote {path}");
        self.write_json(&format!("reports/bench_{}.json", self.suite));
    }

    /// Machine-readable results: name, ns/iter, spread, and throughput.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, num, obj, s};
        let results = self
            .results
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("name", s(&r.name)),
                    ("iters", num(r.iters as f64)),
                    ("ns_per_iter", num(r.mean_ns)),
                    ("stddev_ns", num(r.stddev_ns)),
                    ("p50_ns", num(r.p50_ns)),
                    ("p95_ns", num(r.p95_ns)),
                ];
                if let Some((items, unit)) = r.throughput {
                    pairs.push(("throughput_per_s", num(items / (r.mean_ns / 1e9))));
                    pairs.push(("throughput_unit", s(unit)));
                }
                obj(pairs)
            })
            .collect();
        let derived = obj(self
            .derived
            .iter()
            .map(|(k, v)| (k.as_str(), num(*v)))
            .collect());
        obj(vec![
            ("suite", s(&self.suite)),
            ("results", arr(results)),
            ("derived", derived),
        ])
    }

    /// Write [`Bench::to_json`] to an arbitrary path (e.g. the committed
    /// `BENCH_kernels.json` perf-trajectory file).
    pub fn write_json(&self, path: &str) {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).ok();
            }
        }
        std::fs::write(path, self.to_json().to_string()).ok();
        println!("-- wrote {path}");
    }
}

/// Time a single invocation (for coarse end-to-end steps).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = black_box(f());
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("FLASHD_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        let mean = b.bench("noop-ish", || {
            acc = bb(acc.wrapping_add(1));
        });
        assert!(mean > 0.0 && mean < 1e6, "mean {mean}");
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000_000);
    }

    #[test]
    fn json_report_is_parseable_and_complete() {
        let mut b = Bench::new("jsontest");
        // shrink windows directly — avoid mutating process-global env in a
        // concurrently-running test harness
        b.measure_time = Duration::from_millis(40);
        b.warmup_time = Duration::from_millis(5);
        b.bench_throughput("with-tp", 100.0, "row", || {
            bb(1 + 1);
        });
        b.bench("no-tp", || {
            bb(2 + 2);
        });
        b.note("speedup_x", 1.75);
        let text = b.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(parsed.get("suite").unwrap().as_str(), Some("jsontest"));
        let rs = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("with-tp"));
        assert!(rs[0].get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
        assert!(rs[0].get("throughput_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(rs[0].get("throughput_unit").unwrap().as_str(), Some("row"));
        assert!(rs[1].get("throughput_per_s").is_none());
        let derived = parsed.get("derived").unwrap();
        assert_eq!(derived.get("speedup_x").unwrap().as_f64(), Some(1.75));
    }
}
