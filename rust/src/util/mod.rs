//! Small self-contained utilities that replace crates unavailable in the
//! offline build image (rand, serde_json, clap, criterion, proptest).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a float with engineering-style thousands separators for reports.
pub fn fmt_thousands(x: f64) -> String {
    if x.abs() >= 1e6 {
        format!("{:.3}M", x / 1e6)
    } else if x.abs() >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
    s[idx.min(s.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_basic() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn fmt_thousands_ranges() {
        assert_eq!(fmt_thousands(12.0), "12.00");
        assert_eq!(fmt_thousands(1200.0), "1.20k");
        assert_eq!(fmt_thousands(3_400_000.0), "3.400M");
    }
}
