//! Deterministic xoshiro256++ PRNG — replaces the `rand` crate (not in the
//! offline vendor set). Used by tests, property checks, workload generators
//! and the hardware-simulator stimulus.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals as f32.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from a discrete distribution given unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
