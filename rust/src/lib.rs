//! # FLASH-D — FlashAttention with Hidden Softmax Division
//!
//! Rust reproduction of *FLASH-D* (Alexandridis, Titopoulos,
//! Dimitrakopoulos, 2025): a mathematically equivalent reformulation of the
//! FlashAttention forward pass that hides the softmax division inside a
//! sigmoid evaluation, removes the running max / sum-of-exponents state, and
//! enables skipping output updates when consecutive attention-score
//! differences saturate the sigmoid.
//!
//! The crate is the Layer-3 side of a three-layer stack:
//!  * Layer 1 (build time): Pallas kernels in `python/compile/kernels/`,
//!  * Layer 2 (build time): the JAX transformer in `python/compile/model.py`,
//!  * Layer 3 (this crate): PJRT runtime, serving coordinator, training
//!    driver, software kernels, and the 28 nm hardware cost model used to
//!    reproduce the paper's figures.
//!
//! See DESIGN.md for the system inventory and the experiment index.

#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod bench_harness;
pub mod coordinator;
pub mod hw;
pub mod kernels;
pub mod model;
pub mod numerics;
pub mod pwl;
pub mod runtime;
pub mod train;
pub mod util;
