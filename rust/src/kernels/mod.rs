//! Software reference implementations of the four attention formulations
//! the paper discusses, all over the same flat-slice data layout:
//!
//! * [`naive`]  — safe-softmax attention (mathematical ground truth),
//! * [`flash1`] — Alg. 1, baseline FlashAttention (incremental division),
//! * [`flash2`] — Alg. 2, FlashAttention2 (lazy division) — the baseline
//!   the paper's hardware comparison is against,
//! * [`flashd`] — Alg. 3, the paper's contribution (division hidden in the
//!   sigmoid), plus instrumented / reduced-precision / PWL variants.
//!
//! Layout convention: `k` and `v` are row-major `(n, d)` flat slices; `q`
//! is a single query of length `d`. Multi-query helpers take `(nq, d)`.

pub mod flash1;
pub mod flash2;
pub mod flashd;
pub mod naive;

/// Dot product of two length-`d` slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Maximum absolute difference between two vectors.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// A bundle of Q/K/V for one attention head, in the flat layout all
/// kernels consume.
#[derive(Clone, Debug)]
pub struct AttnProblem {
    pub nq: usize,
    pub nkv: usize,
    pub d: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub scale: f32,
}

impl AttnProblem {
    /// Random Gaussian problem (queries/keys scaled so scores are O(score_std)).
    pub fn random(rng: &mut crate::util::rng::Rng, nq: usize, nkv: usize, d: usize, score_std: f32) -> Self {
        let qk_std = (score_std / (d as f32).sqrt()).sqrt();
        AttnProblem {
            nq,
            nkv,
            d,
            q: rng.normal_vec(nq * d, qk_std),
            k: rng.normal_vec(nkv * d, qk_std),
            v: rng.normal_vec(nkv * d, 1.0),
            scale: 1.0,
        }
    }

    pub fn q_row(&self, i: usize) -> &[f32] {
        &self.q[i * self.d..(i + 1) * self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The paper's headline equivalence: all four formulations compute the
    /// same function.
    #[test]
    fn all_four_formulations_agree() {
        let mut rng = Rng::new(0xF1A5D);
        for &(n, d) in &[(1usize, 4usize), (3, 8), (64, 16), (257, 32)] {
            let p = AttnProblem::random(&mut rng, 1, n, d, 4.0);
            let gold = naive::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            let f1 = flash1::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            let f2 = flash2::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            let fd = flashd::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            assert!(max_abs_diff(&gold, &f1) < 2e-5, "flash1 n={n} d={d}");
            assert!(max_abs_diff(&gold, &f2) < 2e-5, "flash2 n={n} d={d}");
            assert!(max_abs_diff(&gold, &fd) < 2e-5, "flashd n={n} d={d}");
        }
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn random_problem_score_scale() {
        let mut rng = Rng::new(1);
        let p = AttnProblem::random(&mut rng, 1, 512, 32, 4.0);
        let scores: Vec<f32> = (0..p.nkv)
            .map(|i| dot(&p.q[0..p.d], &p.k[i * p.d..(i + 1) * p.d]))
            .collect();
        let std = crate::util::stddev(&scores.iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!(std > 1.0 && std < 16.0, "score std {std}");
    }
}
