//! Software reference implementations of the four attention formulations
//! the paper discusses, all over the same flat-slice data layout:
//!
//! * [`naive`]  — safe-softmax attention (mathematical ground truth),
//! * [`flash1`] — Alg. 1, baseline FlashAttention (incremental division),
//! * [`flash2`] — Alg. 2, FlashAttention2 (lazy division) — the baseline
//!   the paper's hardware comparison is against,
//! * [`flashd`] — Alg. 3, the paper's contribution (division hidden in the
//!   sigmoid), plus instrumented / reduced-precision / PWL variants.
//!
//! Layout convention: `k` and `v` are row-major `(n, d)` flat slices; `q`
//! is a single query of length `d`. Multi-query helpers take `(nq, d)`.
//!
//! ## The tiled + query-blocked + batched engine
//!
//! The scalar kernels above are the one-key-at-a-time references. The
//! production hot path is three layers on top of them:
//!
//! * [`tiled`] — a tile-granular FLASH-D kernel. KV is walked in blocks of
//!   `Bc` keys with the carried state `(s_prev, ln_w, o)` crossing tile
//!   boundaries unchanged — the FLASH-D recursion has no per-tile epilogue,
//!   which is exactly the tiled-computation property §III of the paper
//!   proves is preserved. Per tile the kernel (1) scores all keys through
//!   the shared unrolled [`dot`], (2) applies a **block-skip fast path**:
//!   because skip-low passes `ln w` through as the raw sigmoid argument,
//!   the argument telescopes across consecutive skipped steps
//!   (`x_t = s_t - s_entry + ln_w_entry`), so a single comparison of the
//!   tile's score maximum against the saturation threshold proves the whole
//!   tile contributes nothing to the output — its value loads and Eq. 12
//!   updates are skipped entirely; (3) otherwise falls back to the exact
//!   per-step recursion using [`axpy_blend`]. With
//!   [`flashd::SkipCriterion::None`] the tiled kernel is bit-identical to
//!   [`flashd::attention`] for every tile size.
//! * [`qblock`] — the query-blocked kernel: `Bq` queries run against each
//!   KV tile in a single pass with `Bq` independent carried states, so a
//!   KV tile is streamed from memory once per query *block* instead of
//!   once per query. Because FLASH-D has no cross-query reduction, the
//!   per-query op sequence is untouched by blocking and every query's
//!   output and [`flashd::SkipStats`] are bit-identical to the
//!   single-query tiled kernel (see the [`qblock`] module docs). The
//!   per-query block-skip mask also supports causal "staircase" blocks
//!   (nested prefixes) for prefill.
//! * [`batch`] — a multi-query/multi-head driver that coalesces
//!   independent attention rows into query blocks ([`batch::BlockJob`],
//!   [`batch::run_blocks_into`], with a row-grouping pass behind
//!   [`batch::run_rows`]) and partitions the blocks across
//!   `std::thread::scope` workers with deterministic output ordering,
//!   cost-balanced chunks (in `nq * n * d` units), reusable per-worker
//!   scratch ([`batch::BatchScratch`]), and exact [`flashd::SkipStats`]
//!   aggregation. [`batch::KernelConfig`] (`tile`, `block_q`, `threads`,
//!   `skip`, `sigmoid`, `kv_precision`) is the knob bundle threaded through
//!   `model::engine`,
//!   `model::decode`, and the serving coordinator so every layer runs the
//!   same kernel path.
//!
//! Data layout note: jobs reference `(n, d)` row-major K/V slices; outputs
//! land at the job's index, so multi-threaded runs are bitwise
//! reproducible and independent of the thread count.
//!
//! ## The precision ladder
//!
//! Three independently toggleable speed layers sit on the same hot path,
//! ordered from bit-exact to enveloped:
//!
//! 1. **SIMD primitives** (`--features simd`, nightly `portable_simd`):
//!    [`dot`] and [`axpy_blend`] switch to explicit `f32x8`/`f32x4`
//!    implementations whose lanes mirror the scalar unroll's accumulator
//!    array and reduce in the same tree order — **bit-exact** with the
//!    default scalar build ([`scalar::dot`] / [`scalar::axpy_blend`] stay
//!    compiled either way as the reference).
//! 2. **Quantized KV streaming** ([`batch::KvRowJob`] /
//!    [`batch::KvBlockJob`] over [`crate::numerics::quant::KvRef`]): K/V
//!    rest in BF16 or FP8-E4M3 and are dequantized tile-by-tile into
//!    per-worker scratch; the f32 inner recursion and the carried
//!    `(s_prev, ln_w, o)` state are unchanged, so the result is **bit-exact
//!    vs. the f32 kernel run on the dequantized operands** and enveloped
//!    (bf16 ≲ 1e-2, fp8 ≲ 5e-2 max-abs-diff) vs. the full-precision run.
//!    Skipped tiles never touch V, so block-skip stacks with the bandwidth
//!    win. `KvPrecision::F32` stores borrow zero-copy and reproduce the
//!    unquantized path exactly.
//! 3. **PWL sigmoid** ([`batch::KernelConfig::sigmoid`] =
//!    [`flashd::SigmoidMode::Pwl`]): the per-step sigmoid / log-sigmoid
//!    pair evaluates through [`crate::pwl::SigTables`] piecewise-linear
//!    tables (the paper's §IV-B hardware trick); **enveloped** by the
//!    tables' measured `max_error_against`. The default
//!    [`flashd::SigmoidMode::Exact`] is bit-identical to the scalar
//!    FLASH-D reference.

pub mod batch;
pub mod flash1;
pub mod flash2;
pub mod flashd;
pub mod naive;
pub mod qblock;
pub mod tiled;

pub use batch::{
    run_blocks, run_blocks_into, run_kv_blocks_flat_into_with, run_kv_rows_into_with,
    run_paged_kv_blocks_flat_into_with, run_rows, run_rows_into, BatchScratch, BlockJob,
    KernelConfig, KvBlockJob, KvRowJob, PagedKvBlockJob, RowJob,
};
pub use crate::numerics::quant::{KvPrecision, KvRef, KvView, PagedKv};
pub use flashd::SigmoidMode;

/// The scalar reference implementations of the two hot-loop primitives.
///
/// Always compiled — with `--features simd` the crate-level [`dot`] /
/// [`axpy_blend`] switch to the vectorized versions and these remain the
/// bit-exactness oracle for tests and benches.
pub mod scalar {
    /// Dot product of two length-`d` slices.
    ///
    /// Eight-wide unrolled accumulation over `chunks_exact` so the compiler
    /// drops bounds checks and vectorizes; shared by every kernel (scalar and
    /// tiled) so all formulations see the same summation order.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n8 = a.len() & !7;
        let mut acc = [0.0f32; 8];
        for (x, y) in a[..n8].chunks_exact(8).zip(b[..n8].chunks_exact(8)) {
            acc[0] += x[0] * y[0];
            acc[1] += x[1] * y[1];
            acc[2] += x[2] * y[2];
            acc[3] += x[3] * y[3];
            acc[4] += x[4] * y[4];
            acc[5] += x[5] * y[5];
            acc[6] += x[6] * y[6];
            acc[7] += x[7] * y[7];
        }
        let mut tail = 0.0f32;
        for (x, y) in a[n8..].iter().zip(&b[n8..]) {
            tail += x * y;
        }
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
    }

    /// The fused Eq. 12 output update `o[j] += (v[j] - o[j]) * w`, four-wide
    /// unrolled over `chunks_exact` — the single vector op FLASH-D performs
    /// per active KV step, shared by the scalar and tiled kernels.
    #[inline]
    pub fn axpy_blend(o: &mut [f32], v: &[f32], w: f32) {
        debug_assert_eq!(o.len(), v.len());
        let n4 = o.len() & !3;
        let (o4, o_tail) = o.split_at_mut(n4);
        let (v4, v_tail) = v.split_at(n4);
        for (oc, vc) in o4.chunks_exact_mut(4).zip(v4.chunks_exact(4)) {
            oc[0] += (vc[0] - oc[0]) * w;
            oc[1] += (vc[1] - oc[1]) * w;
            oc[2] += (vc[2] - oc[2]) * w;
            oc[3] += (vc[3] - oc[3]) * w;
        }
        for (oo, vv) in o_tail.iter_mut().zip(v_tail) {
            *oo += (*vv - *oo) * w;
        }
    }
}

/// Explicit `std::simd` implementations of the hot-loop primitives.
///
/// Bit-exact with [`scalar`]: the `f32x8` accumulator's lane `j` sees the
/// identical sequence of `x[8i+j] * y[8i+j]` multiply-adds the scalar
/// unroll's `acc[j]` sees (Rust never contracts `a + b * c` into an FMA),
/// and the final reduction uses the same `((0+1)+(2+3)) + ((4+5)+(6+7))`
/// tree. Likewise `axpy_blend`'s per-lane `o + (v - o) * w` is the scalar
/// expression verbatim.
#[cfg(feature = "simd")]
mod simd_ops {
    use std::simd::{f32x4, f32x8};

    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n8 = a.len() & !7;
        let mut acc = f32x8::splat(0.0);
        for (x, y) in a[..n8].chunks_exact(8).zip(b[..n8].chunks_exact(8)) {
            acc += f32x8::from_slice(x) * f32x8::from_slice(y);
        }
        let mut tail = 0.0f32;
        for (x, y) in a[n8..].iter().zip(&b[n8..]) {
            tail += x * y;
        }
        let acc = acc.to_array();
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
    }

    #[inline]
    pub fn axpy_blend(o: &mut [f32], v: &[f32], w: f32) {
        debug_assert_eq!(o.len(), v.len());
        let n4 = o.len() & !3;
        let (o4, o_tail) = o.split_at_mut(n4);
        let (v4, v_tail) = v.split_at(n4);
        let wv = f32x4::splat(w);
        for (oc, vc) in o4.chunks_exact_mut(4).zip(v4.chunks_exact(4)) {
            let ov = f32x4::from_slice(oc);
            let r = ov + (f32x4::from_slice(vc) - ov) * wv;
            r.copy_to_slice(oc);
        }
        for (oo, vv) in o_tail.iter_mut().zip(v_tail) {
            *oo += (*vv - *oo) * w;
        }
    }
}

#[cfg(not(feature = "simd"))]
pub use scalar::{axpy_blend, dot};
#[cfg(feature = "simd")]
pub use simd_ops::{axpy_blend, dot};

/// Maximum absolute difference between two vectors.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// A bundle of Q/K/V for one attention head, in the flat layout all
/// kernels consume.
#[derive(Clone, Debug)]
pub struct AttnProblem {
    pub nq: usize,
    pub nkv: usize,
    pub d: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub scale: f32,
}

impl AttnProblem {
    /// Random Gaussian problem (queries/keys scaled so scores are O(score_std)).
    pub fn random(rng: &mut crate::util::rng::Rng, nq: usize, nkv: usize, d: usize, score_std: f32) -> Self {
        let qk_std = (score_std / (d as f32).sqrt()).sqrt();
        AttnProblem {
            nq,
            nkv,
            d,
            q: rng.normal_vec(nq * d, qk_std),
            k: rng.normal_vec(nkv * d, qk_std),
            v: rng.normal_vec(nkv * d, 1.0),
            scale: 1.0,
        }
    }

    pub fn q_row(&self, i: usize) -> &[f32] {
        &self.q[i * self.d..(i + 1) * self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The paper's headline equivalence: all four formulations compute the
    /// same function.
    #[test]
    fn all_four_formulations_agree() {
        let mut rng = Rng::new(0xF1A5D);
        for &(n, d) in &[(1usize, 4usize), (3, 8), (64, 16), (257, 32)] {
            let p = AttnProblem::random(&mut rng, 1, n, d, 4.0);
            let gold = naive::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            let f1 = flash1::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            let f2 = flash2::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            let fd = flashd::attention(&p.q, &p.k, &p.v, n, d, p.scale);
            assert!(max_abs_diff(&gold, &f1) < 2e-5, "flash1 n={n} d={d}");
            assert!(max_abs_diff(&gold, &f2) < 2e-5, "flash2 n={n} d={d}");
            assert!(max_abs_diff(&gold, &fd) < 2e-5, "flashd n={n} d={d}");
        }
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_matches_scalar_reference_all_lengths() {
        let mut rng = Rng::new(99);
        for len in 0..40usize {
            let a = rng.normal_vec(len, 1.0);
            let b = rng.normal_vec(len, 1.0);
            let reference: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - reference).abs() < 1e-4 * (1.0 + reference.abs()), "len={len}");
        }
    }

    #[test]
    fn axpy_blend_matches_scalar_update_all_lengths() {
        let mut rng = Rng::new(100);
        for len in 0..33usize {
            let mut o = rng.normal_vec(len, 1.0);
            let v = rng.normal_vec(len, 1.0);
            let w = 0.37f32;
            let mut want = o.clone();
            for j in 0..len {
                want[j] += (v[j] - want[j]) * w;
            }
            axpy_blend(&mut o, &v, w);
            assert_eq!(o, want, "len={len}");
        }
    }

    #[test]
    fn axpy_blend_endpoints() {
        // w = 0 leaves o untouched; w = 1 replaces o by v.
        let mut o = vec![1.0f32, -2.0, 3.0, 4.0, 5.0];
        let v = vec![9.0f32, 8.0, 7.0, 6.0, 5.0];
        let before = o.clone();
        axpy_blend(&mut o, &v, 0.0);
        assert_eq!(o, before);
        axpy_blend(&mut o, &v, 1.0);
        assert_eq!(o, v);
    }

    #[test]
    fn random_problem_score_scale() {
        let mut rng = Rng::new(1);
        let p = AttnProblem::random(&mut rng, 1, 512, 32, 4.0);
        let scores: Vec<f32> = (0..p.nkv)
            .map(|i| dot(&p.q[0..p.d], &p.k[i * p.d..(i + 1) * p.d]))
            .collect();
        let std = crate::util::stddev(&scores.iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!(std > 1.0 && std < 16.0, "score std {std}");
    }
}
