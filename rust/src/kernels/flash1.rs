//! Alg. 1 — baseline FlashAttention (Dao et al. 2022) with the softmax
//! division performed *incrementally* during output accumulation. Kept as a
//! faithful transcription of the paper's pseudocode: two divisions and three
//! vector multiplies per key/value step.

use super::dot;

/// Single-query baseline FlashAttention.
pub fn attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    assert!(n > 0);
    let mut m = f32::NEG_INFINITY; // running max  (Alg.1 line 4)
    let mut ell = 0.0f32;          // running sum-of-exponents (line 5)
    let mut o = vec![0.0f32; d];
    for i in 0..n {
        let s = dot(q, &k[i * d..(i + 1) * d]) * scale;
        let m_new = m.max(s);
        let alpha = (m - m_new).exp(); // e^{m_{i-1}-m_i}; exp(-inf)=0 at i=0
        let p = (s - m_new).exp();
        let ell_new = ell * alpha + p;
        let co = ell * alpha / ell_new; // coefficient on o_{i-1}
        let cv = p / ell_new;           // coefficient on v_i
        let vi = &v[i * d..(i + 1) * d];
        for j in 0..d {
            o[j] = o[j] * co + vi[j] * cv; // Alg.1 line 6
        }
        m = m_new;
        ell = ell_new;
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{max_abs_diff, naive};
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(10);
        let (n, d) = (33, 8);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        let a = attention(&q, &k, &v, n, d, 0.5);
        let b = naive::attention(&q, &k, &v, n, d, 0.5);
        assert!(max_abs_diff(&a, &b) < 1e-5);
    }

    #[test]
    fn first_iteration_sets_output_to_v0() {
        let q = [1.0, 2.0];
        let k = [0.5, -0.5];
        let v = [3.0, 4.0];
        assert_eq!(attention(&q, &k, &v, 1, 2, 1.0), vec![3.0, 4.0]);
    }

    #[test]
    fn monotone_decreasing_scores_need_no_rescale() {
        // max never changes after i=0 -> alpha stays 1; still correct.
        let q = [1.0];
        let k = [5.0, 4.0, 3.0];
        let v = [1.0, 2.0, 3.0];
        let a = attention(&q, &k, &v, 3, 1, 1.0);
        let b = naive::attention(&q, &k, &v, 3, 1, 1.0);
        assert!((a[0] - b[0]).abs() < 1e-6);
    }

    #[test]
    fn increasing_scores_trigger_rescale_path() {
        let q = [1.0];
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [1.0, 2.0, 3.0, 4.0];
        let a = attention(&q, &k, &v, 4, 1, 1.0);
        let b = naive::attention(&q, &k, &v, 4, 1, 1.0);
        assert!((a[0] - b[0]).abs() < 1e-6);
    }
}
