//! Alg. 3 — **FLASH-D**, the paper's contribution: FlashAttention with the
//! softmax division hidden inside a sigmoid evaluation.
//!
//! Per key/value step the kernel computes
//!
//! ```text
//!   s_i = dot(q, k_i) * scale
//!   w_i = sigmoid(s_i - s_{i-1} + ln w_{i-1})        (w_1 = 1)
//!   o_i = o_{i-1} + (v_i - o_{i-1}) * w_i            (Eq. 12)
//! ```
//!
//! There is no running maximum, no running sum-of-exponents and no division
//! anywhere — the division lives inside the sigmoid. Numerical stability is
//! inherent: the sigmoid argument only needs to be evaluated in the active
//! region [-6, 11]; outside it the weight saturates to ~0/~1 and the entire
//! output update (value load + FMA) can be **skipped** — the effect Table I
//! quantifies.

use super::{axpy_blend, dot};
use crate::numerics::Scalar;
use crate::pwl::{LnPwl, SigmoidPwl};

/// Numerically stable sigmoid (never exponentiates a positive argument).
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable ln(sigmoid(x)).
#[inline]
pub fn log_sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        -(-x).exp().ln_1p()
    } else {
        x - x.exp().ln_1p()
    }
}

/// The weight-update function of Eq. (11): `w_i` as a function of the
/// consecutive-score difference and the previous weight. This is exactly
/// the family of curves in the paper's Fig. 2.
#[inline]
pub fn weight(s_diff: f64, w_prev: f64) -> f64 {
    sigmoid(s_diff + w_prev.ln())
}

/// The paper's static active region for the sigmoid argument (§III-C).
pub const ACTIVE_LO: f64 = -6.0;
pub const ACTIVE_HI: f64 = 11.0;

/// Single-query FLASH-D in f32 (exact nonlinearities).
pub fn attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    assert!(n > 0);
    let mut o = vec![0.0f32; d];
    let mut s_prev = 0.0f64;
    let mut ln_w = 0.0f64;
    for i in 0..n {
        let s = (dot(q, &k[i * d..(i + 1) * d]) * scale) as f64;
        let w = if i == 0 {
            ln_w = 0.0;
            1.0
        } else {
            let x = s - s_prev + ln_w;
            ln_w = log_sigmoid(x);
            sigmoid(x)
        } as f32;
        axpy_blend(&mut o, &v[i * d..(i + 1) * d], w); // Eq. (12): sub + mul + add
        s_prev = s;
    }
    o
}

/// Multi-query FLASH-D mirroring the unrolled Fig. 3 hardware.
pub fn attention_multi(q: &[f32], k: &[f32], v: &[f32], nq: usize, nkv: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(nq * d);
    for iq in 0..nq {
        out.extend(attention(&q[iq * d..(iq + 1) * d], k, v, nkv, d, scale));
    }
    out
}

/// How the per-step sigmoid / log-sigmoid pair is evaluated inside the
/// tiled engines (threaded through `batch::KernelConfig`).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum SigmoidMode {
    /// Exact `exp`/`ln_1p` nonlinearities — bit-identical to
    /// [`attention`]. The default.
    #[default]
    Exact,
    /// Piecewise-linear sigmoid + ln tables with `segments` segments each
    /// (the paper's §IV-B hardware units, via [`crate::pwl::SigTables`]).
    /// Error is enveloped by the tables' `max_error_against`; the skip
    /// fast paths are unaffected by the mode.
    Pwl { segments: usize },
}

/// Which saturation rule decides that an output update can be skipped.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum SkipCriterion {
    /// No skipping: always evaluate (exact Alg. 3).
    None,
    /// The paper's static rule: skip when `s_i - s_{i-1}` leaves [-6, 11].
    Static,
    /// The paper's proposed future-work rule: test the *full* sigmoid
    /// argument `s_i - s_{i-1} + ln w_{i-1}` against a symmetric band.
    Adaptive { lo: f64, hi: f64 },
}

/// Counters for the skip study (Table I).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SkipStats {
    /// Output updates where w saturated to ~0 (o unchanged, v load skipped).
    pub skip_low: u64,
    /// Updates where w saturated to ~1 (o replaced by v, FMA skipped).
    pub skip_high: u64,
    /// Total weight-update steps (excludes the fixed w_1 = 1 step).
    pub total: u64,
}

impl SkipStats {
    pub fn skipped(&self) -> u64 {
        self.skip_low + self.skip_high
    }

    /// Percentage of output updates simplified — the Table I quantity.
    pub fn percent(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            100.0 * self.skipped() as f64 / self.total as f64
        }
    }

    pub fn merge(&mut self, other: &SkipStats) {
        self.skip_low += other.skip_low;
        self.skip_high += other.skip_high;
        self.total += other.total;
    }
}

/// Instrumented FLASH-D: applies a [`SkipCriterion`] and counts how often
/// the output update simplifies. Returns `(output, stats)`.
pub fn attention_instrumented(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    scale: f32,
    crit: SkipCriterion,
) -> (Vec<f32>, SkipStats) {
    let mut stats = SkipStats::default();
    let mut o = vec![0.0f32; d];
    let mut s_prev = 0.0f64;
    let mut ln_w = 0.0f64;
    for i in 0..n {
        let s = (dot(q, &k[i * d..(i + 1) * d]) * scale) as f64;
        let vi = &v[i * d..(i + 1) * d];
        if i == 0 {
            o.copy_from_slice(vi);
            ln_w = 0.0;
            s_prev = s;
            continue;
        }
        stats.total += 1;
        let s_diff = s - s_prev;
        let x = s_diff + ln_w;
        let (lo_hit, hi_hit) = match crit {
            SkipCriterion::None => (false, false),
            SkipCriterion::Static => (s_diff <= ACTIVE_LO, s_diff >= ACTIVE_HI),
            SkipCriterion::Adaptive { lo, hi } => (x <= lo, x >= hi),
        };
        if lo_hit {
            // w ~ 0: output unchanged, v_i never loaded, and the ln unit is
            // bypassed too — for x <= -6, ln sigmoid(x) = x to within
            // e^-6, so the carried ln w is just the pass-through of the
            // already-computed argument. Cheapest possible step.
            stats.skip_low += 1;
            ln_w = x;
            s_prev = s;
            continue;
        }
        if hi_hit {
            // w ~ 1: output forgets the past, becomes v_i. ln 1 = 0.
            stats.skip_high += 1;
            o.copy_from_slice(vi);
            ln_w = 0.0;
            s_prev = s;
            continue;
        }
        let w = sigmoid(x) as f32;
        ln_w = log_sigmoid(x);
        axpy_blend(&mut o, vi, w);
        s_prev = s;
    }
    (o, stats)
}

/// Skip statistics straight from a score trace (no values needed) — used by
/// the Table I harness where the model engine already produced per-step
/// attention scores.
pub fn skip_stats_from_scores(scores: &[f32], crit: SkipCriterion) -> SkipStats {
    let mut stats = SkipStats::default();
    if scores.is_empty() {
        return stats;
    }
    let mut s_prev = scores[0] as f64;
    let mut ln_w = 0.0f64;
    for &sf in &scores[1..] {
        let s = sf as f64;
        stats.total += 1;
        let s_diff = s - s_prev;
        let x = s_diff + ln_w;
        let (lo_hit, hi_hit) = match crit {
            SkipCriterion::None => (false, false),
            SkipCriterion::Static => (s_diff <= ACTIVE_LO, s_diff >= ACTIVE_HI),
            SkipCriterion::Adaptive { lo, hi } => (x <= lo, x >= hi),
        };
        if lo_hit {
            stats.skip_low += 1;
            ln_w = x; // ln sigmoid(x) ~ x on the low tail (pass-through)
        } else if hi_hit {
            stats.skip_high += 1;
            ln_w = 0.0;
        } else {
            ln_w = log_sigmoid(x);
        }
        s_prev = s;
    }
    stats
}

/// FLASH-D in an arbitrary scalar format with *exact* nonlinearities —
/// isolates pure quantization effects from PWL-approximation effects.
pub fn attention_generic<T: Scalar>(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut o: Vec<T> = vec![T::zero(); d];
    let mut s_prev = T::zero();
    let mut ln_w = T::zero();
    for i in 0..n {
        let s = T::from_f64((dot(q, &k[i * d..(i + 1) * d]) * scale) as f64);
        let w = if i == 0 {
            ln_w = T::zero();
            T::one()
        } else {
            let x = s.sub(s_prev).add(ln_w);
            let w = x.sigmoid();
            ln_w = if w.to_f64() <= 0.0 { T::from_f64(x.to_f64()) } else { w.ln() };
            w
        };
        for j in 0..d {
            let vi = T::from_f64(v[i * d + j] as f64);
            o[j] = o[j].add(vi.sub(o[j]).mul(w)); // Eq. (12)
        }
        s_prev = s;
    }
    o.iter().map(|x| x.to_f64() as f32).collect()
}

/// The fully hardware-faithful FLASH-D: reduced-precision format `T` AND
/// 8-segment PWL sigmoid/ln units (the datapath of Fig. 3).
pub fn attention_pwl<T: Scalar>(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    scale: f32,
    sig: &SigmoidPwl,
    ln: &LnPwl,
) -> Vec<f32> {
    let mut o: Vec<T> = vec![T::zero(); d];
    let mut s_prev = T::zero();
    let mut ln_w = T::zero();
    for i in 0..n {
        let s = T::from_f64((dot(q, &k[i * d..(i + 1) * d]) * scale) as f64);
        let w = if i == 0 {
            ln_w = T::zero();
            T::one()
        } else {
            let x = s.sub(s_prev).add(ln_w);
            let xf = x.to_f64();
            if xf <= crate::pwl::SIGMOID_LO {
                // saturated low: skip the update entirely (paper §III-C);
                // ln sigmoid(x) ~ x passes through as the carried ln w
                ln_w = x;
                s_prev = s;
                continue;
            }
            if xf >= crate::pwl::SIGMOID_HI {
                // saturated high: output := v_i
                for j in 0..d {
                    o[j] = T::from_f64(v[i * d + j] as f64);
                }
                ln_w = T::zero();
                s_prev = s;
                continue;
            }
            let w = sig.eval(x);
            ln_w = ln.eval(w);
            w
        };
        for j in 0..d {
            let vi = T::from_f64(v[i * d + j] as f64);
            o[j] = o[j].add(vi.sub(o[j]).mul(w));
        }
        s_prev = s;
    }
    o.iter().map(|x| x.to_f64() as f32).collect()
}

/// Per-step trace of the FLASH-D recursion for one query: the sigmoid
/// argument stream feeding the hardware activity model.
#[derive(Clone, Debug, Default)]
pub struct FlashDTrace {
    /// attention scores s_i
    pub scores: Vec<f32>,
    /// sigmoid arguments x_i = s_i - s_{i-1} + ln w_{i-1} (x_0 unused)
    pub args: Vec<f32>,
    /// weights w_i
    pub weights: Vec<f32>,
}

/// Run FLASH-D and capture its internal trace.
pub fn attention_traced(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> (Vec<f32>, FlashDTrace) {
    let mut tr = FlashDTrace::default();
    let mut o = vec![0.0f32; d];
    let mut s_prev = 0.0f64;
    let mut ln_w = 0.0f64;
    for i in 0..n {
        let s = (dot(q, &k[i * d..(i + 1) * d]) * scale) as f64;
        let (x, w) = if i == 0 {
            ln_w = 0.0;
            (0.0, 1.0)
        } else {
            let x = s - s_prev + ln_w;
            let w = sigmoid(x);
            ln_w = log_sigmoid(x);
            (x, w)
        };
        tr.scores.push(s as f32);
        tr.args.push(x as f32);
        tr.weights.push(w as f32);
        let wf = w as f32;
        axpy_blend(&mut o, &v[i * d..(i + 1) * d], wf);
        s_prev = s;
    }
    (o, tr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{flash2, max_abs_diff, naive};
    use crate::numerics::{Bf16, Fp8E4M3};
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize, d: usize, std: f32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(d, std), rng.normal_vec(n * d, std), rng.normal_vec(n * d, 1.0))
    }

    #[test]
    fn weight_function_matches_fig2_anchor_points() {
        // w_{i-1}=0.99: essentially the plain sigmoid.
        assert!((weight(0.0, 0.99) - sigmoid(0.99f64.ln())).abs() < 1e-12);
        assert!((weight(0.0, 0.99) - 0.4975).abs() < 0.01);
        // As w_prev decreases the curve shifts right: need larger s_diff
        // for the same w.
        let w_at = |wp: f64| weight(3.0, wp);
        assert!(w_at(0.99) > w_at(0.5));
        assert!(w_at(0.5) > w_at(0.1));
        assert!(w_at(0.1) > w_at(0.01));
        // All curves live in (0,1).
        for &wp in &[0.99, 0.5, 0.1, 0.01] {
            for i in -100..=140 {
                let w = weight(i as f64 / 10.0, wp);
                assert!(w > 0.0 && w < 1.0);
            }
        }
    }

    #[test]
    fn second_step_reproduces_papers_worked_example() {
        // Paper §III-C: w2 = e^{s2}/(e^{s1}+e^{s2}).
        let (s1, s2) = (1.3f64, -0.4f64);
        let w2 = weight(s2 - s1, 1.0);
        let direct = s2.exp() / (s1.exp() + s2.exp());
        assert!((w2 - direct).abs() < 1e-12);
    }

    #[test]
    fn matches_naive_various_sizes() {
        for &(n, d) in &[(1usize, 8usize), (2, 4), (65, 16), (512, 32)] {
            let (q, k, v) = problem(n as u64 * 7 + d as u64, n, d, 0.9);
            let a = attention(&q, &k, &v, n, d, 0.4);
            let b = naive::attention(&q, &k, &v, n, d, 0.4);
            assert!(max_abs_diff(&a, &b) < 3e-5, "n={n} d={d}: {}", max_abs_diff(&a, &b));
        }
    }

    #[test]
    fn stable_without_max_subtraction() {
        // Scores of magnitude O(1000): naive exp would overflow; FLASH-D
        // never exponentiates anything outside the sigmoid's active region.
        let (q, k, v) = problem(3, 64, 16, 9.0); // scores ~ O(1000)
        let a = attention(&q, &k, &v, 64, 16, 1.0);
        assert!(a.iter().all(|x| x.is_finite()));
        let b = naive::attention(&q, &k, &v, 64, 16, 1.0);
        assert!(max_abs_diff(&a, &b) < 1e-4);
    }

    #[test]
    fn instrumented_none_matches_exact() {
        let (q, k, v) = problem(5, 128, 16, 1.0);
        let exact = attention(&q, &k, &v, 128, 16, 0.25);
        let (got, stats) = attention_instrumented(&q, &k, &v, 128, 16, 0.25, SkipCriterion::None);
        assert_eq!(stats.skipped(), 0);
        assert_eq!(stats.total, 127);
        assert!(max_abs_diff(&exact, &got) < 1e-6);
    }

    #[test]
    fn static_skip_changes_output_negligibly() {
        // Score std ~2 (realistic trained-attention scale — cf. the
        // Table I study): the static criterion fires on the low tail and
        // the output barely moves.
        //
        // NOTE the static rule's skip-high branch is *pessimistic by
        // design*: it tests s_i - s_{i-1} alone, ignoring ln w_{i-1}. On
        // adversarial synthetic traces (score std >> trained-model scale)
        // a +11 jump can coincide with a deeply negative ln w and clobber
        // the output; the paper accepts this because the criterion is
        // validated on real LLM score distributions where it never bites
        // (their Table I / llama2.c check, our model::engine tests). The
        // ablation bench quantifies the criterion's error/skip trade-off.
        let (q, k, v) = problem(6, 256, 16, 0.7);
        let exact = attention(&q, &k, &v, 256, 16, 1.0);
        let (got, stats) = attention_instrumented(&q, &k, &v, 256, 16, 1.0, SkipCriterion::Static);
        assert!(max_abs_diff(&exact, &got) < 2e-2, "{}", max_abs_diff(&exact, &got));
        assert!(stats.total == 255);
    }

    #[test]
    fn skip_fires_on_engineered_sequences() {
        // Monotone steeply increasing scores: every diff >= 11 -> skip_high.
        let d = 2;
        let n = 8;
        let q = vec![1.0, 0.0];
        let mut k = Vec::new();
        let mut v = Vec::new();
        for i in 0..n {
            k.extend([i as f32 * 12.0, 0.0]);
            v.extend([i as f32, 1.0]);
        }
        let (o, stats) = attention_instrumented(&q, &k, &v, n, d, 1.0, SkipCriterion::Static);
        assert_eq!(stats.skip_high, (n - 1) as u64);
        // output = last value vector
        assert!((o[0] - (n - 1) as f32).abs() < 1e-6);

        // Steeply decreasing: every diff <= -6 -> skip_low, o stays v_0.
        let mut k2 = Vec::new();
        for i in 0..n {
            k2.extend([-(i as f32) * 7.0, 0.0]);
        }
        let (o2, st2) = attention_instrumented(&q, &k2, &v, n, d, 1.0, SkipCriterion::Static);
        assert_eq!(st2.skip_low, (n - 1) as u64);
        assert!((o2[0] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn adaptive_skips_at_least_as_much_as_static_on_smooth_traces() {
        // ln w_{i-1} <= 0 pushes x below s_diff, so the adaptive low test
        // fires whenever the static one does (with equal thresholds).
        let (q, k, v) = problem(7, 512, 16, 2.5);
        let (_, s_static) = attention_instrumented(&q, &k, &v, 512, 16, 1.0, SkipCriterion::Static);
        let (_, s_adapt) = attention_instrumented(
            &q, &k, &v, 512, 16, 1.0,
            SkipCriterion::Adaptive { lo: ACTIVE_LO, hi: ACTIVE_HI },
        );
        assert!(s_adapt.skip_low >= s_static.skip_low);
    }

    #[test]
    fn score_trace_stats_match_instrumented() {
        let (q, k, v) = problem(8, 300, 8, 2.0);
        let (_, tr) = attention_traced(&q, &k, &v, 300, 8, 1.0);
        let from_trace = skip_stats_from_scores(&tr.scores, SkipCriterion::Static);
        let (_, direct) = attention_instrumented(&q, &k, &v, 300, 8, 1.0, SkipCriterion::Static);
        assert_eq!(from_trace, direct);
    }

    #[test]
    fn generic_f32_matches_exact() {
        let (q, k, v) = problem(9, 96, 8, 1.0);
        let a = attention(&q, &k, &v, 96, 8, 0.35);
        let b = attention_generic::<f32>(&q, &k, &v, 96, 8, 0.35);
        assert!(max_abs_diff(&a, &b) < 1e-5);
    }

    #[test]
    fn bf16_flashd_close_to_bf16_flash2() {
        // Both datapaths at bf16 should agree with each other to within
        // format precision — the paper's "same replies from llama2.c" check.
        let (q, k, v) = problem(10, 128, 16, 1.0);
        let a = attention_generic::<Bf16>(&q, &k, &v, 128, 16, 0.25);
        let b = flash2::attention_generic::<Bf16>(&q, &k, &v, 128, 16, 0.25);
        assert!(max_abs_diff(&a, &b) < 0.08, "{}", max_abs_diff(&a, &b));
    }

    #[test]
    fn pwl_variant_tracks_exact_bf16() {
        let sig = SigmoidPwl::new();
        let ln = LnPwl::new();
        let (q, k, v) = problem(11, 128, 16, 1.0);
        let gold = naive::attention(&q, &k, &v, 128, 16, 0.25);
        let got = attention_pwl::<Bf16>(&q, &k, &v, 128, 16, 0.25, &sig, &ln);
        assert!(got.iter().all(|x| x.is_finite()));
        // 8-segment PWL nonlinearities drift the recursion state; the paper
        // validates this operating point at the *reply* level (llama2.c),
        // not bitwise — we bound the numeric drift and check argmax-level
        // agreement in model::engine tests.
        assert!(max_abs_diff(&gold, &got) < 0.6, "{}", max_abs_diff(&gold, &got));
    }

    #[test]
    fn pwl_variant_fp8_finite() {
        let sig = SigmoidPwl::new();
        let ln = LnPwl::new();
        let (q, k, v) = problem(12, 64, 8, 0.7);
        let got = attention_pwl::<Fp8E4M3>(&q, &k, &v, 64, 8, 0.35, &sig, &ln);
        assert!(got.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn traced_weights_in_unit_interval_and_first_is_one() {
        let (q, k, v) = problem(13, 64, 8, 1.5);
        let (_, tr) = attention_traced(&q, &k, &v, 64, 8, 1.0);
        assert_eq!(tr.weights[0], 1.0);
        for &w in &tr.weights {
            assert!((0.0..=1.0).contains(&w));
        }
        assert_eq!(tr.scores.len(), 64);
    }
}
