//! Safe-softmax attention — the mathematical ground truth every other
//! kernel is validated against (paper §II-A).

use super::dot;

/// Single-query attention: `q` is `(d,)`, `k`/`v` are `(n, d)` flat.
pub fn attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    assert_eq!(q.len(), d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let mut scores = Vec::with_capacity(n);
    let mut m = f32::NEG_INFINITY;
    for i in 0..n {
        let s = dot(q, &k[i * d..(i + 1) * d]) * scale;
        m = m.max(s);
        scores.push(s);
    }
    // safe softmax: subtract the max before exponentiating
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        denom += *s;
    }
    let mut out = vec![0.0f32; d];
    for i in 0..n {
        let w = scores[i] / denom;
        let vi = &v[i * d..(i + 1) * d];
        for j in 0..d {
            out[j] += w * vi[j];
        }
    }
    out
}

/// Multi-query attention; `q` is `(nq, d)` flat, output `(nq, d)` flat.
pub fn attention_multi(q: &[f32], k: &[f32], v: &[f32], nq: usize, nkv: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(nq * d);
    for iq in 0..nq {
        out.extend(attention(&q[iq * d..(iq + 1) * d], k, v, nkv, d, scale));
    }
    out
}

/// Causal multi-query attention for `nq == nkv` (token i attends to 0..=i).
pub fn attention_causal(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(n * d);
    for iq in 0..n {
        let nkv = iq + 1;
        out.extend(attention(&q[iq * d..(iq + 1) * d], &k[..nkv * d], &v[..nkv * d], nkv, d, scale));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_key_returns_value() {
        let q = [1.0, 0.0];
        let k = [0.3, 0.4];
        let v = [5.0, -7.0];
        assert_eq!(attention(&q, &k, &v, 1, 2, 1.0), vec![5.0, -7.0]);
    }

    #[test]
    fn uniform_scores_average_values() {
        // orthogonal q -> all scores equal -> output = mean of values
        let q = [0.0, 0.0];
        let k = [1.0, 0.0, 0.0, 1.0];
        let v = [2.0, 0.0, 4.0, 6.0];
        let out = attention(&q, &k, &v, 2, 2, 1.0);
        assert!((out[0] - 3.0).abs() < 1e-6);
        assert!((out[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn dominant_score_selects_value() {
        let q = [10.0];
        let k = [10.0, -10.0];
        let v = [1.0, -1.0];
        let out = attention(&q, &k, &v, 2, 1, 1.0);
        assert!((out[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn huge_scores_stay_finite() {
        let q = [300.0, 300.0];
        let k = [300.0, 300.0, -300.0, 300.0];
        let v = [1.0, 2.0, 3.0, 4.0];
        let out = attention(&q, &k, &v, 2, 2, 1.0);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causal_last_row_matches_full() {
        let mut rng = crate::util::rng::Rng::new(2);
        let n = 16;
        let d = 8;
        let q = rng.normal_vec(n * d, 0.5);
        let k = rng.normal_vec(n * d, 0.5);
        let v = rng.normal_vec(n * d, 1.0);
        let causal = attention_causal(&q, &k, &v, n, d, 1.0);
        let last_full = attention(&q[(n - 1) * d..], &k, &v, n, d, 1.0);
        for j in 0..d {
            assert!((causal[(n - 1) * d + j] - last_full[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn scale_changes_sharpness() {
        let q = [1.0];
        let k = [1.0, -1.0];
        let v = [1.0, 0.0];
        let soft = attention(&q, &k, &v, 2, 1, 0.1)[0];
        let sharp = attention(&q, &k, &v, 2, 1, 10.0)[0];
        assert!(sharp > soft);
        assert!(sharp > 0.99 && soft < 0.6);
    }
}
