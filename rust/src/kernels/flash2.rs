//! Alg. 2 — FlashAttention2 (Dao 2023): same recursion as Alg. 1 but with
//! the softmax division *postponed* to a single epilogue division ("lazy
//! softmax"). This is the algorithm implemented by the paper's baseline
//! hardware (Fig. 1): per step it needs the running max, the running
//! sum-of-exponents, two exponentials, two vector multipliers and one vector
//! adder, plus the final vector division.
//!
//! The generic variant runs in any [`Scalar`] format and the instrumented
//! variant additionally records the operand stream consumed by the power
//! model (hw::power).

use super::dot;
use crate::numerics::Scalar;

/// Single-query FlashAttention2 in f32.
pub fn attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    assert!(n > 0);
    let mut m = f32::NEG_INFINITY;
    let mut ell = 0.0f32;
    let mut o = vec![0.0f32; d];
    for i in 0..n {
        let s = dot(q, &k[i * d..(i + 1) * d]) * scale;
        let m_new = m.max(s);
        let alpha = (m - m_new).exp();
        let p = (s - m_new).exp();
        let vi = &v[i * d..(i + 1) * d];
        for j in 0..d {
            o[j] = o[j] * alpha + vi[j] * p; // Alg.2 line 6: two mults + add
        }
        ell = ell * alpha + p;
        m = m_new;
    }
    // Alg.2 line 8: the lazy division epilogue.
    for j in 0..d {
        o[j] /= ell;
    }
    o
}

/// Multi-query helper mirroring the unrolled hardware of Fig. 1: each query
/// keeps independent (m, l, o) state while K/V stream past.
pub fn attention_multi(q: &[f32], k: &[f32], v: &[f32], nq: usize, nkv: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(nq * d);
    for iq in 0..nq {
        out.extend(attention(&q[iq * d..(iq + 1) * d], k, v, nkv, d, scale));
    }
    out
}

/// FlashAttention2 in an arbitrary scalar format `T` — the hardware-faithful
/// path (all intermediate state held at format precision, dot products
/// accumulated in f32 like the fused vector units of [25], [26]).
pub fn attention_generic<T: Scalar>(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32) -> Vec<f32> {
    let mut m = T::from_f64(-3.0e38);
    let mut ell = T::zero();
    let mut o: Vec<T> = vec![T::zero(); d];
    for i in 0..n {
        let s = T::from_f64((dot(q, &k[i * d..(i + 1) * d]) * scale) as f64);
        let m_new = m.max(s);
        let alpha = m.sub(m_new).exp();
        let p = s.sub(m_new).exp();
        for j in 0..d {
            let vi = T::from_f64(v[i * d + j] as f64);
            o[j] = o[j].mul(alpha).add(vi.mul(p));
        }
        ell = ell.mul(alpha).add(p);
        m = m_new;
    }
    o.iter().map(|x| x.div(ell).to_f64() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{flash1, max_abs_diff, naive};
    use crate::numerics::{Bf16, Fp8E4M3};
    use crate::util::rng::Rng;

    #[test]
    fn matches_flash1_exactly_in_structure() {
        let mut rng = Rng::new(20);
        let (n, d) = (129, 16);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(n * d, 0.7);
        let v = rng.normal_vec(n * d, 1.0);
        let a = attention(&q, &k, &v, n, d, 0.25);
        let b = flash1::attention(&q, &k, &v, n, d, 0.25);
        assert!(max_abs_diff(&a, &b) < 1e-5);
    }

    #[test]
    fn multi_matches_per_query() {
        let mut rng = Rng::new(21);
        let (nq, nkv, d) = (4, 64, 8);
        let q = rng.normal_vec(nq * d, 1.0);
        let k = rng.normal_vec(nkv * d, 1.0);
        let v = rng.normal_vec(nkv * d, 1.0);
        let multi = attention_multi(&q, &k, &v, nq, nkv, d, 1.0);
        for iq in 0..nq {
            let single = attention(&q[iq * d..(iq + 1) * d], &k, &v, nkv, d, 1.0);
            assert!(max_abs_diff(&multi[iq * d..(iq + 1) * d], &single) < 1e-7);
        }
    }

    #[test]
    fn generic_f32_matches_plain() {
        let mut rng = Rng::new(22);
        let (n, d) = (48, 8);
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        let a = attention(&q, &k, &v, n, d, 0.3);
        let b = attention_generic::<f32>(&q, &k, &v, n, d, 0.3);
        assert!(max_abs_diff(&a, &b) < 1e-6);
    }

    #[test]
    fn bf16_tracks_reference_loosely() {
        let mut rng = Rng::new(23);
        let (n, d) = (64, 16);
        let q = rng.normal_vec(d, 0.8);
        let k = rng.normal_vec(n * d, 0.8);
        let v = rng.normal_vec(n * d, 1.0);
        let gold = naive::attention(&q, &k, &v, n, d, 0.25);
        let b16 = attention_generic::<Bf16>(&q, &k, &v, n, d, 0.25);
        assert!(max_abs_diff(&gold, &b16) < 0.06, "{}", max_abs_diff(&gold, &b16));
    }

    #[test]
    fn fp8_stays_finite_and_plausible() {
        let mut rng = Rng::new(24);
        let (n, d) = (32, 8);
        let q = rng.normal_vec(d, 0.5);
        let k = rng.normal_vec(n * d, 0.5);
        let v = rng.normal_vec(n * d, 0.5);
        let gold = naive::attention(&q, &k, &v, n, d, 0.35);
        let f8 = attention_generic::<Fp8E4M3>(&q, &k, &v, n, d, 0.35);
        assert!(f8.iter().all(|x| x.is_finite()));
        assert!(max_abs_diff(&gold, &f8) < 0.4, "{}", max_abs_diff(&gold, &f8));
    }
}
