//! Tile-granular FLASH-D: the cache-blocked production kernel.
//!
//! KV is walked in blocks of `Bc` keys ("tiles") with an explicit carried
//! state `(s_prev, ln_w, o)`. Because FLASH-D has no running maximum, no
//! sum-of-exponents and no division, the state crosses tile boundaries
//! completely unchanged — there is no per-tile rescaling epilogue. This is
//! the tiled-computation property §III of the paper proves is preserved,
//! realized in software.
//!
//! Per tile the kernel does three things:
//!
//! 1. **Score pass** — every key in the tile goes through the shared
//!    unrolled [`dot`], producing the tile's score vector and its maximum
//!    in one streaming sweep over K (V is not touched yet).
//! 2. **Block-skip fast path** (§III-C generalized from steps to tiles) —
//!    the skip-low rule passes the sigmoid argument through as the carried
//!    `ln w`, so across consecutively skipped steps the argument
//!    *telescopes*: `x_t = s_t - s_entry + ln_w_entry`. A single
//!    comparison `s_max - s_prev + ln_w <= lo` therefore proves every
//!    argument in the tile saturates low, i.e. the whole tile's weights
//!    vanish and its value loads + Eq. 12 updates can be skipped
//!    entirely. The cheap scalar chain is still replayed (and re-verified
//!    step by step, so floating-point edge cases cannot diverge from the
//!    per-step kernel) to carry `(s_prev, ln_w)` forward bit-exactly.
//! 3. **Fallback** — the exact per-step recursion of
//!    [`flashd::attention_instrumented`], using [`axpy_blend`] for the
//!    Eq. 12 update.
//!
//! Steps 2 + 3 live in [`process_scored_tile`], which is shared verbatim
//! with the query-blocked kernel [`super::qblock`] — the multi-query path
//! is bit-identical to this kernel per query *by construction*, not by
//! parallel maintenance of two copies of the recursion.
//!
//! Equivalences (enforced by unit + property tests):
//! * `SkipCriterion::None`   → bit-identical to [`flashd::attention`] for
//!   every tile size (the fast path never fires; the per-step sequence of
//!   float ops is the same).
//! * `SkipCriterion::Adaptive` → bit-identical to
//!   [`flashd::attention_instrumented`], output *and* [`SkipStats`]: the
//!   fast path fires exactly when every step in the tile would have taken
//!   the per-step adaptive skip-low branch.
//! * `SkipCriterion::Static` → the tile test upgrades the static low rule
//!   (score difference alone) to the telescoped full-argument test, which
//!   is sound — the weights truly saturate — and skips at least as often;
//!   `SkipStats::total` stays exact, and the output stays within the
//!   static-skip error envelope.

use super::flashd::{log_sigmoid, sigmoid, SkipCriterion, SkipStats, ACTIVE_HI, ACTIVE_LO};
use super::{axpy_blend, dot};
use crate::numerics::quant::{KvRef, KvView};
use crate::pwl::SigTables;

/// Default KV tile length (keys per block). 32 keys × d=64 × 4 B ≈ 8 KiB
/// of K plus 8 KiB of V per tile — comfortably L1-resident.
pub const DEFAULT_TILE: usize = 32;

/// Largest tile held in a stack-resident score buffer; bigger tiles fall
/// back to one heap allocation (avoided entirely on the batched driver's
/// hot paths, which thread caller-owned scratch through
/// [`attention_tiled_into_with`]).
const STACK_TILE: usize = 64;

/// The tile-skip threshold on the *full* sigmoid argument. The static
/// criterion's step rule tests the score difference alone; at tile
/// granularity the telescoped argument test (threshold [`ACTIVE_LO`]) is
/// the sound generalization — it subsumes every static skip-low step
/// because `ln w <= 0` only pushes the argument lower.
pub(crate) fn tile_skip_lo(crit: SkipCriterion) -> f64 {
    match crit {
        SkipCriterion::None => f64::NEG_INFINITY,
        SkipCriterion::Static => ACTIVE_LO,
        SkipCriterion::Adaptive { lo, .. } => lo,
    }
}

/// Carried FLASH-D recursion state for one query row. Crosses tile
/// boundaries unchanged (the §III property); the output row `o` is the
/// third component of the carried state and lives in the caller's buffer.
#[derive(Copy, Clone, Debug, Default)]
pub(crate) struct RowState {
    pub s_prev: f64,
    pub ln_w: f64,
}

/// Resolved per-step nonlinearity evaluator, the runtime form of
/// [`super::flashd::SigmoidMode`]: either the exact `exp`/`ln_1p` pair or a borrowed set
/// of PWL tables (owned by the per-worker scratch so table fits are
/// amortized across calls). The skip fast paths never evaluate the
/// nonlinearities, so they are identical under both variants.
#[derive(Copy, Clone)]
pub(crate) enum SigmoidEval<'a> {
    Exact,
    Pwl(&'a SigTables),
}

impl SigmoidEval<'_> {
    /// `(w, ln w)` for sigmoid argument `x`. The `Exact` arm performs the
    /// same two calls, in the same order, as the scalar reference kernel —
    /// the default path stays bit-identical.
    #[inline]
    fn weight_and_ln(self, x: f64) -> (f64, f64) {
        match self {
            SigmoidEval::Exact => (sigmoid(x), log_sigmoid(x)),
            SigmoidEval::Pwl(t) => t.weight_and_ln(x),
        }
    }
}

/// Step 1 of the tiled kernel, fused: score every key of a tile through the
/// shared [`dot`] and track the running maximum in the same sweep. `k` is
/// the tile's rows only (`scores.len()` rows of length `d`, starting at
/// element 0), so it works equally over a zero-copy f32 sub-slice and over
/// a dequantized tile buffer. Returns the tile's score maximum.
#[inline]
pub(crate) fn score_pass(q: &[f32], k: &[f32], d: usize, scale: f32, scores: &mut [f64]) -> f64 {
    debug_assert!(k.len() >= scores.len() * d);
    let mut s_max = f64::NEG_INFINITY;
    for (t, srow) in scores.iter_mut().enumerate() {
        let s = (dot(q, &k[t * d..(t + 1) * d]) * scale) as f64;
        *srow = s;
        if s > s_max {
            s_max = s;
        }
    }
    s_max
}

/// Steps 2 + 3 of the tiled kernel for one query and one already-scored
/// tile: the telescoped block-skip fast path, then the exact per-step
/// recursion fallback. `scores[t]` is the score of absolute KV row
/// `base + t`; `s_max` is their maximum. Shared by the single-query tiled
/// kernel and the query-blocked kernel ([`super::qblock`]) so both execute
/// the identical sequence of float ops per query.
#[allow(clippy::too_many_arguments)]
pub(crate) fn process_scored_tile(
    scores: &[f64],
    s_max: f64,
    base: usize,
    v: &[f32],
    d: usize,
    crit: SkipCriterion,
    tile_lo: f64,
    sig: SigmoidEval<'_>,
    st: &mut RowState,
    o: &mut [f32],
    stats: &mut SkipStats,
) {
    if try_skip_tile(scores, s_max, tile_lo, st, stats) {
        return;
    }
    process_tile_fallback(scores, base, v, 0, d, crit, sig, st, o, stats);
}

/// The block-skip fast path alone: commits state and stats and returns
/// `true` iff the whole tile saturates low. Split out so the quantized-KV
/// path can run it *before* resolving (dequantizing) the tile's V rows —
/// a fully-skipped tile never touches V in any precision.
///
/// The telescoped bound proves saturation for the whole tile; the scalar
/// chain re-verifies it step by step so the committed state (and stats)
/// are bit-identical to the per-step kernel even in floating-point corner
/// cases.
pub(crate) fn try_skip_tile(
    scores: &[f64],
    s_max: f64,
    tile_lo: f64,
    st: &mut RowState,
    stats: &mut SkipStats,
) -> bool {
    if s_max - st.s_prev + st.ln_w <= tile_lo {
        let mut sp = st.s_prev;
        let mut lw = st.ln_w;
        let mut all_low = true;
        for &s in scores {
            let x = s - sp + lw;
            if x > tile_lo {
                all_low = false;
                break;
            }
            lw = x; // skip-low pass-through: ln sigmoid(x) ~ x
            sp = s;
        }
        if all_low {
            // Whole tile saturates low: no value loads, no output
            // updates, state carried by the scalar chain alone.
            stats.total += scores.len() as u64;
            stats.skip_low += scores.len() as u64;
            st.s_prev = sp;
            st.ln_w = lw;
            return true;
        }
    }
    false
}

/// The exact per-step recursion fallback. `v` holds rows starting at
/// absolute KV row `voff`, so the value row for `scores[t]` (absolute row
/// `base + t`) is `v[(base + t - voff) * d ..]` — `voff = 0` with the full
/// V slice reproduces the historical indexing exactly, while the
/// quantized-KV path passes the dequantized tile buffer with `voff = base`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn process_tile_fallback(
    scores: &[f64],
    base: usize,
    v: &[f32],
    voff: usize,
    d: usize,
    crit: SkipCriterion,
    sig: SigmoidEval<'_>,
    st: &mut RowState,
    o: &mut [f32],
    stats: &mut SkipStats,
) {
    for (t, &s) in scores.iter().enumerate() {
        let row = base + t - voff;
        let vi = &v[row * d..(row + 1) * d];
        stats.total += 1;
        let s_diff = s - st.s_prev;
        let x = s_diff + st.ln_w;
        let (lo_hit, hi_hit) = match crit {
            SkipCriterion::None => (false, false),
            SkipCriterion::Static => (s_diff <= ACTIVE_LO, s_diff >= ACTIVE_HI),
            SkipCriterion::Adaptive { lo, hi } => (x <= lo, x >= hi),
        };
        if lo_hit {
            stats.skip_low += 1;
            st.ln_w = x;
            st.s_prev = s;
            continue;
        }
        if hi_hit {
            stats.skip_high += 1;
            o.copy_from_slice(vi);
            st.ln_w = 0.0;
            st.s_prev = s;
            continue;
        }
        let (w, ln_w) = sig.weight_and_ln(x);
        st.ln_w = ln_w;
        axpy_blend(o, vi, w as f32);
        st.s_prev = s;
    }
}

/// Tiled single-query FLASH-D with exact nonlinearities and no skipping.
/// Bit-identical to [`super::flashd::attention`] for every `tile >= 1`.
pub fn attention_tiled(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, scale: f32, tile: usize) -> Vec<f32> {
    attention_tiled_instrumented(q, k, v, n, d, scale, tile, SkipCriterion::None).0
}

/// Tiled single-query FLASH-D with a [`SkipCriterion`] and exact
/// [`SkipStats`] accounting. See the module docs for the per-criterion
/// equivalence guarantees.
#[allow(clippy::too_many_arguments)]
pub fn attention_tiled_instrumented(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
) -> (Vec<f32>, SkipStats) {
    let mut o = vec![0.0f32; d];
    let stats = attention_tiled_into(q, k, v, n, d, scale, tile, crit, &mut o);
    (o, stats)
}

/// Shared core behind both `into` variants: `scores` is a scratch slice of
/// exactly `tile` elements.
#[allow(clippy::too_many_arguments)]
pub(crate) fn tiled_core(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    sig: SigmoidEval<'_>,
    scores: &mut [f64],
    o: &mut [f32],
) -> SkipStats {
    assert!(n > 0, "empty KV context");
    assert!(tile > 0, "tile must be >= 1");
    assert_eq!(o.len(), d);
    debug_assert_eq!(q.len(), d);
    debug_assert!(k.len() >= n * d && v.len() >= n * d);
    debug_assert_eq!(scores.len(), tile);

    let mut stats = SkipStats::default();

    // Step 0 (w_1 = 1): output becomes v_0, no weight-update counted —
    // mirrors `attention_instrumented`.
    let s0 = (dot(q, &k[..d]) * scale) as f64;
    o.copy_from_slice(&v[..d]);
    let mut st = RowState { s_prev: s0, ln_w: 0.0 };

    let tile_lo = tile_skip_lo(crit);
    let mut i = 1usize;
    while i < n {
        let t_len = tile.min(n - i);

        // Step 1, fused: score the tile's keys and track the max in one
        // sweep (V is not touched yet).
        let s_max = score_pass(q, &k[i * d..(i + t_len) * d], d, scale, &mut scores[..t_len]);

        process_scored_tile(&scores[..t_len], s_max, i, v, d, crit, tile_lo, sig, &mut st, o, &mut stats);
        i += t_len;
    }
    stats
}

/// Tiled single-query FLASH-D over possibly-quantized KV ([`KvRef`]): K and
/// V tiles are dequantized into the caller-owned `ktile`/`vtile` f32
/// scratch right before use, so the recursion itself (and its carried
/// state) is the plain f32 kernel. Guarantees:
///
/// * `KvRef::F32` operands take the zero-copy path and are **bit-identical**
///   to [`attention_tiled_into_with`];
/// * quantized operands are **bit-identical to the f32 kernel run over the
///   dequantized arrays** (dequantization is pointwise);
/// * a tile proven skippable by the block-skip test never dequantizes its
///   V rows (K must be scored regardless), so block-skip stacks with the
///   bandwidth saving.
#[allow(clippy::too_many_arguments)]
pub fn attention_kv_into_with(
    q: &[f32],
    k: KvRef<'_>,
    v: KvRef<'_>,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    o: &mut [f32],
    scores: &mut Vec<f64>,
    ktile: &mut Vec<f32>,
    vtile: &mut Vec<f32>,
) -> SkipStats {
    attention_kv_core(
        q,
        KvView::Contig(k),
        KvView::Contig(v),
        n,
        d,
        scale,
        tile,
        crit,
        SigmoidEval::Exact,
        o,
        scores,
        ktile,
        vtile,
    )
}

/// The KV-general core: K and V arrive as [`KvView`]s — contiguous
/// (possibly quantized) buffers or paged gathers over pool blocks. All
/// element-range loads go through [`KvView::load_into`], which yields the
/// same f32 values for paged and contiguous storage of the same logical
/// buffer, so the paged path is bit-identical to the contiguous path by
/// construction. A contiguous all-f32 view delegates to the zero-copy
/// [`tiled_core`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_kv_core(
    q: &[f32],
    k: KvView<'_>,
    v: KvView<'_>,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    sig: SigmoidEval<'_>,
    o: &mut [f32],
    scores: &mut Vec<f64>,
    ktile: &mut Vec<f32>,
    vtile: &mut Vec<f32>,
) -> SkipStats {
    if scores.len() < tile {
        scores.resize(tile, 0.0);
    }
    if let (Some(kf), Some(vf)) = (k.as_contig_f32(), v.as_contig_f32()) {
        return tiled_core(q, kf, vf, n, d, scale, tile, crit, sig, &mut scores[..tile], o);
    }

    assert!(n > 0, "empty KV context");
    assert!(tile > 0, "tile must be >= 1");
    assert_eq!(o.len(), d);
    debug_assert_eq!(q.len(), d);
    debug_assert!(k.len() >= n * d && v.len() >= n * d);
    if ktile.len() < tile * d {
        ktile.resize(tile * d, 0.0);
    }
    if vtile.len() < tile * d {
        vtile.resize(tile * d, 0.0);
    }

    let mut stats = SkipStats::default();

    // Step 0: dequantize row 0 of K and V through the tile buffers.
    k.load_into(0, d, &mut ktile[..d]);
    v.load_into(0, d, &mut vtile[..d]);
    let s0 = (dot(q, &ktile[..d]) * scale) as f64;
    o.copy_from_slice(&vtile[..d]);
    let mut st = RowState { s_prev: s0, ln_w: 0.0 };

    let tile_lo = tile_skip_lo(crit);
    let mut i = 1usize;
    while i < n {
        let t_len = tile.min(n - i);
        k.load_into(i * d, (i + t_len) * d, &mut ktile[..t_len * d]);
        let s_max = score_pass(q, &ktile[..t_len * d], d, scale, &mut scores[..t_len]);
        if !try_skip_tile(&scores[..t_len], s_max, tile_lo, &mut st, &mut stats) {
            // Tile is active: resolve its V rows now.
            v.load_into(i * d, (i + t_len) * d, &mut vtile[..t_len * d]);
            process_tile_fallback(
                &scores[..t_len],
                i,
                &vtile[..t_len * d],
                i,
                d,
                crit,
                sig,
                &mut st,
                o,
                &mut stats,
            );
        }
        i += t_len;
    }
    stats
}

/// Allocating convenience wrapper over [`attention_kv_into_with`] —
/// the single-query quantized-KV entry used by tests and benches.
#[allow(clippy::too_many_arguments)]
pub fn attention_kv(
    q: &[f32],
    k: KvRef<'_>,
    v: KvRef<'_>,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
) -> (Vec<f32>, SkipStats) {
    let mut o = vec![0.0f32; d];
    let (mut scores, mut ktile, mut vtile) = (Vec::new(), Vec::new(), Vec::new());
    let stats = attention_kv_into_with(
        q, k, v, n, d, scale, tile, crit, &mut o, &mut scores, &mut ktile, &mut vtile,
    );
    (o, stats)
}

/// Allocation-free core: writes the output row into the caller-provided
/// `o` (length `d`, fully overwritten). Score scratch is stack-resident
/// for `tile <= 64`; oversized tiles pay one heap allocation — hot-path
/// callers (the batched driver) use [`attention_tiled_into_with`] instead,
/// which never allocates after warm-up.
#[allow(clippy::too_many_arguments)]
pub fn attention_tiled_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    o: &mut [f32],
) -> SkipStats {
    let mut stack_buf = [0.0f64; STACK_TILE];
    let mut heap_buf: Vec<f64> = Vec::new();
    let scores: &mut [f64] = if tile <= STACK_TILE {
        &mut stack_buf[..tile]
    } else {
        heap_buf.resize(tile, 0.0);
        &mut heap_buf
    };
    tiled_core(q, k, v, n, d, scale, tile, crit, SigmoidEval::Exact, scores, o)
}

/// [`attention_tiled_into`] with a caller-owned score scratch: `scores` is
/// grown to `tile` elements once and reused across calls, so per-call heap
/// traffic is zero regardless of tile size — the form the batched driver's
/// per-worker scratch uses on the decode/serving hot paths (previously a
/// `tile > 64` configuration re-allocated once per (layer, head, token)).
#[allow(clippy::too_many_arguments)]
pub fn attention_tiled_into_with(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    o: &mut [f32],
    scores: &mut Vec<f64>,
) -> SkipStats {
    if scores.len() < tile {
        scores.resize(tile, 0.0);
    }
    tiled_core(q, k, v, n, d, scale, tile, crit, SigmoidEval::Exact, &mut scores[..tile], o)
}

/// Multi-query tiled FLASH-D: independent `(nq, d)` queries over a shared
/// KV context (the per-head serving shape). Since PR 2 this runs the
/// query-blocked kernel in blocks of [`super::qblock::DEFAULT_BLOCK_Q`]
/// queries — each KV tile is streamed from memory once per query *block*
/// instead of once per query — and remains bit-identical per query to
/// [`attention_tiled`].
#[allow(clippy::too_many_arguments)]
pub fn attention_tiled_multi(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nkv: usize,
    d: usize,
    scale: f32,
    tile: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; nq * d];
    let mut scratch = super::qblock::QScratch::default();
    let mut a = 0usize;
    while a < nq {
        let e = (a + super::qblock::DEFAULT_BLOCK_Q).min(nq);
        super::qblock::attention_qblock_into(
            &q[a * d..e * d],
            k,
            v,
            e - a,
            nkv,
            d,
            scale,
            tile,
            SkipCriterion::None,
            false,
            &mut scratch,
            &mut out[a * d..e * d],
        );
        a = e;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::flashd;
    use crate::kernels::{max_abs_diff, naive};
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize, d: usize, std: f32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(d, std), rng.normal_vec(n * d, std), rng.normal_vec(n * d, 1.0))
    }

    #[test]
    fn none_bitmatches_scalar_flashd_across_tiles() {
        for &(n, d) in &[(1usize, 8usize), (5, 4), (64, 16), (257, 32), (300, 64)] {
            let (q, k, v) = problem(n as u64 * 31 + d as u64, n, d, 0.9);
            let gold = flashd::attention(&q, &k, &v, n, d, 0.4);
            for tile in [1usize, 7, 16, 64, n] {
                let got = attention_tiled(&q, &k, &v, n, d, 0.4, tile);
                assert_eq!(got, gold, "n={n} d={d} tile={tile}");
            }
        }
    }

    #[test]
    fn none_matches_naive() {
        for &(n, d) in &[(2usize, 4usize), (65, 16), (512, 32)] {
            let (q, k, v) = problem(n as u64 * 13 + d as u64, n, d, 0.9);
            let a = attention_tiled(&q, &k, &v, n, d, 0.4, DEFAULT_TILE);
            let b = naive::attention(&q, &k, &v, n, d, 0.4);
            assert!(max_abs_diff(&a, &b) < 3e-5, "n={n} d={d}: {}", max_abs_diff(&a, &b));
        }
    }

    #[test]
    fn adaptive_bitmatches_per_step_instrumented() {
        let crit = SkipCriterion::Adaptive { lo: ACTIVE_LO, hi: ACTIVE_HI };
        for &std in &[0.7f32, 2.0, 4.0] {
            let (q, k, v) = problem(1000 + (std * 10.0) as u64, 400, 16, std);
            let (want_o, want_st) = flashd::attention_instrumented(&q, &k, &v, 400, 16, 1.0, crit);
            for tile in [1usize, 8, 32, 100, 400] {
                let (got_o, got_st) =
                    attention_tiled_instrumented(&q, &k, &v, 400, 16, 1.0, tile, crit);
                assert_eq!(got_o, want_o, "std={std} tile={tile}");
                assert_eq!(got_st, want_st, "std={std} tile={tile}");
            }
        }
    }

    #[test]
    fn static_totals_exact_and_error_bounded() {
        // Realistic trained-attention score scale (cf. the Table I study).
        let (q, k, v) = problem(6, 512, 16, 0.7);
        let exact = flashd::attention(&q, &k, &v, 512, 16, 1.0);
        let (_, step_stats) =
            flashd::attention_instrumented(&q, &k, &v, 512, 16, 1.0, SkipCriterion::Static);
        for tile in [4usize, 16, 64] {
            let (got, st) =
                attention_tiled_instrumented(&q, &k, &v, 512, 16, 1.0, tile, SkipCriterion::Static);
            assert_eq!(st.total, step_stats.total, "tile={tile}");
            assert_eq!(st.total, 511);
            assert!(
                max_abs_diff(&exact, &got) < 2e-2,
                "tile={tile}: {}",
                max_abs_diff(&exact, &got)
            );
        }
    }

    #[test]
    fn block_skip_fires_on_engineered_decreasing_scores() {
        // Steeply decreasing scores: after the first key every step
        // saturates low, so with tile=4 whole tiles skip and the output
        // stays exactly v_0.
        let d = 8usize;
        let n = 33usize;
        let mut rng = Rng::new(9);
        let q: Vec<f32> = {
            let mut x = vec![0.0f32; d];
            x[0] = 1.0;
            x
        };
        let mut k = Vec::new();
        for i in 0..n {
            let mut row = vec![0.0f32; d];
            row[0] = -(i as f32) * 8.0;
            k.extend(row);
        }
        let v = rng.normal_vec(n * d, 1.0);
        let (o, st) =
            attention_tiled_instrumented(&q, &k, &v, n, d, 1.0, 4, SkipCriterion::Static);
        assert_eq!(st.skip_low, (n - 1) as u64);
        assert_eq!(st.total, (n - 1) as u64);
        assert_eq!(o, v[..d].to_vec());
    }

    #[test]
    fn into_with_matches_into_and_reuses_scratch() {
        let (n, d) = (300usize, 16usize);
        let (q, k, v) = problem(41, n, d, 0.9);
        let mut scratch: Vec<f64> = Vec::new();
        for tile in [1usize, 16, 64, 100, 300] {
            let (want, want_st) =
                attention_tiled_instrumented(&q, &k, &v, n, d, 0.5, tile, SkipCriterion::Static);
            let mut got = vec![0.0f32; d];
            let got_st = attention_tiled_into_with(
                &q, &k, &v, n, d, 0.5, tile,
                SkipCriterion::Static,
                &mut got,
                &mut scratch,
            );
            assert_eq!(got, want, "tile={tile}");
            assert_eq!(got_st, want_st, "tile={tile}");
            assert!(scratch.len() >= tile);
        }
        // scratch grew to the largest tile and is reused, never shrunk
        assert_eq!(scratch.len(), 300);
    }

    #[test]
    fn multi_matches_per_query() {
        let mut rng = Rng::new(77);
        // nq > DEFAULT_BLOCK_Q so the blocked path spans several blocks
        let (nq, nkv, d) = (37usize, 100usize, 16usize);
        let q = rng.normal_vec(nq * d, 0.8);
        let k = rng.normal_vec(nkv * d, 0.8);
        let v = rng.normal_vec(nkv * d, 1.0);
        let multi = attention_tiled_multi(&q, &k, &v, nq, nkv, d, 0.3, 16);
        assert_eq!(multi.len(), nq * d);
        for iq in 0..nq {
            let single = attention_tiled(&q[iq * d..(iq + 1) * d], &k, &v, nkv, d, 0.3, 16);
            assert_eq!(&multi[iq * d..(iq + 1) * d], &single[..], "query {iq}");
        }
    }

    #[test]
    fn stable_without_max_subtraction() {
        // Scores of magnitude O(1000): the tiled path inherits FLASH-D's
        // inherent stability (nothing outside the sigmoid is exponentiated).
        let (q, k, v) = problem(3, 64, 16, 9.0);
        let a = attention_tiled(&q, &k, &v, 64, 16, 1.0, 8);
        assert!(a.iter().all(|x| x.is_finite()));
        let b = naive::attention(&q, &k, &v, 64, 16, 1.0);
        assert!(max_abs_diff(&a, &b) < 1e-4);
    }

    #[test]
    fn kv_f32_path_bitmatches_tiled() {
        use crate::numerics::quant::KvRef;
        let (n, d) = (257usize, 16usize);
        let (q, k, v) = problem(51, n, d, 0.9);
        for crit in [SkipCriterion::None, SkipCriterion::Static] {
            for tile in [1usize, 8, 32, 100] {
                let (want, want_st) =
                    attention_tiled_instrumented(&q, &k, &v, n, d, 0.5, tile, crit);
                let (got, got_st) =
                    attention_kv(&q, KvRef::F32(&k), KvRef::F32(&v), n, d, 0.5, tile, crit);
                assert_eq!(got, want, "tile={tile} crit={crit:?}");
                assert_eq!(got_st, want_st, "tile={tile} crit={crit:?}");
            }
        }
    }

    #[test]
    fn quantized_kv_bitmatches_f32_over_dequantized_operands() {
        // The quantized path's contract is deterministic: it must equal the
        // f32 kernel run over dequantize(quantize(K)), dequantize(quantize(V))
        // bit for bit — dequantization is pointwise, the recursion is f32
        // either way.
        use crate::numerics::quant::{quantize_bf16, quantize_fp8, KvRef};
        let (n, d) = (300usize, 8usize);
        let (q, k, v) = problem(52, n, d, 0.8);
        let kb = quantize_bf16(&k);
        let vb = quantize_bf16(&v);
        let k8 = quantize_fp8(&k);
        let v8 = quantize_fp8(&v);
        for (kr, vr) in [(KvRef::Bf16(&kb), KvRef::Bf16(&vb)), (KvRef::Fp8(&k8), KvRef::Fp8(&v8))] {
            let kd = kr.to_f32_vec();
            let vd = vr.to_f32_vec();
            for tile in [4usize, 32, 300] {
                for crit in [SkipCriterion::None, SkipCriterion::Static] {
                    let (want, want_st) =
                        attention_tiled_instrumented(&q, &kd, &vd, n, d, 0.5, tile, crit);
                    let (got, got_st) = attention_kv(&q, kr, vr, n, d, 0.5, tile, crit);
                    assert_eq!(got, want, "tile={tile} crit={crit:?} {:?}", kr.precision());
                    assert_eq!(got_st, want_st, "tile={tile} crit={crit:?}");
                }
            }
        }
    }

    #[test]
    fn paged_kv_bitmatches_contiguous_across_precisions() {
        // Paged storage (arbitrary block length, partial tail, block size
        // deliberately misaligned with the kernel tile) must be
        // bit-identical to the contiguous run — for f32 (which loses the
        // zero-copy path and goes through the tile buffers, itself
        // bit-identical by the pointwise-copy argument) and for quantized
        // blocks.
        use crate::numerics::quant::{quantize_bf16, quantize_fp8, KvView, PagedKv};
        let (n, d) = (123usize, 8usize);
        let (q, k, v) = problem(53, n, d, 0.8);
        let kb = quantize_bf16(&k);
        let vb = quantize_fp8(&v);
        // block of 10 steps -> 80 elems: misaligned with tiles {8, 32}
        let bs_elems = 10 * d;
        for (kr, vr) in [
            (KvRef::F32(&k), KvRef::F32(&v)),
            (KvRef::Bf16(&kb), KvRef::Fp8(&vb)),
        ] {
            let kfr: Vec<KvRef> = (0..n * d)
                .step_by(bs_elems)
                .map(|a| kr.slice(a, (a + bs_elems).min(n * d)))
                .collect();
            let vfr: Vec<KvRef> = (0..n * d)
                .step_by(bs_elems)
                .map(|a| vr.slice(a, (a + bs_elems).min(n * d)))
                .collect();
            let kp = KvView::Paged(PagedKv { blocks: &kfr, block_elems: bs_elems, start: 0, len: n * d });
            let vp = KvView::Paged(PagedKv { blocks: &vfr, block_elems: bs_elems, start: 0, len: n * d });
            for tile in [8usize, 32, 200] {
                for crit in [SkipCriterion::None, SkipCriterion::Static] {
                    let (want, want_st) = attention_kv(&q, kr, vr, n, d, 0.5, tile, crit);
                    let mut got = vec![0.0f32; d];
                    let (mut sc, mut kt, mut vt) = (Vec::new(), Vec::new(), Vec::new());
                    let got_st = attention_kv_core(
                        &q,
                        kp,
                        vp,
                        n,
                        d,
                        0.5,
                        tile,
                        crit,
                        SigmoidEval::Exact,
                        &mut got,
                        &mut sc,
                        &mut kt,
                        &mut vt,
                    );
                    assert_eq!(got, want, "tile={tile} crit={crit:?} {:?}", kr.precision());
                    assert_eq!(got_st, want_st, "tile={tile} crit={crit:?}");
                }
            }
        }
    }
}
