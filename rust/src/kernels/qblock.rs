//! Query-blocked FLASH-D: amortize KV bandwidth across a block of queries.
//!
//! The tiled kernel ([`super::tiled`]) streams the whole K and V once *per
//! query*: a prefill of `nq` queries reads the KV context `nq` times from
//! memory. Attention is IO-bound (the FlashAttention observation), so the
//! fix is classic register/cache blocking over the query dimension: process
//! `Bq` queries against each `Bc`-key KV tile in a single pass, carrying
//! `Bq` independent `(s_prev, ln_w, o)` states. Each KV tile is then loaded
//! from DRAM once per query *block* instead of once per query — a `Bq`-fold
//! reduction in KV traffic — while the K/V tile stays L1-resident across
//! the block's inner loops.
//!
//! Per KV tile the kernel runs two phases:
//!
//! 1. **Score pass** — for every query in the block, every key in the tile
//!    goes through the shared unrolled [`dot`], packing the tile's scores
//!    into a `Bq × Bc` scratch (one row per query) and tracking each
//!    query's tile maximum. Only K is touched; the tile is read from
//!    memory once and served from cache for the remaining `Bq - 1`
//!    queries.
//! 2. **Skip + value pass** — per query, the telescoped block-skip test
//!    and the exact per-step fallback of the tiled kernel, via the shared
//!    [`tiled::process_scored_tile`]. Queries whose telescoped argument
//!    test proves the whole tile saturates low never touch V; the rest
//!    stream the V tile from cache.
//!
//! ## Why per-query state isolation preserves FLASH-D's bit-exactness
//!
//! FLASH-D's recursion for one query depends only on that query's own
//! score sequence and carried `(s_prev, ln_w, o)` — there is no softmax
//! normalizer shared across queries, no running max, and no cross-query
//! reduction of any kind. Blocking therefore only *interleaves* the work
//! of `Bq` independent recursions; it never reorders or fuses the float
//! ops *within* one query's recursion. Concretely, for every query `iq`:
//!
//! * the tile boundaries are the same (`1, 1 + Bc, 1 + 2·Bc, …`, truncated
//!   at that query's own KV length),
//! * the score pass performs the same [`dot`]s in the same key order,
//! * the skip test and per-step fallback are literally the same code
//!   ([`tiled::process_scored_tile`]) operating on a per-query
//!   [`tiled::RowState`] no other query can touch.
//!
//! Hence the output row and [`SkipStats`] contribution of each query are
//! bit-identical to running [`tiled::attention_tiled_instrumented`] on
//! that query alone — for every block size, tile size, and
//! [`SkipCriterion`] — and all of PR 1's equivalence guarantees (exact
//! `None`/`Adaptive` bit-match against the per-step kernel, exact `Static`
//! totals) survive blocking unchanged. Property tests in
//! `tests/prop_kernels.rs` enforce this per query.
//!
//! ## Causal staircase blocks
//!
//! For causal prefill the queries of a block attend *nested* prefixes of
//! the same KV buffer. With `causal = true`, query `iq` of the block
//! attends the first `n - nq + 1 + iq` keys (so the last query attends all
//! `n`). The kernel simply masks each query out of tiles beyond its own
//! prefix — a per-query active length — which keeps the per-query op
//! sequence identical to the single-query kernel run on that prefix.

use super::flashd::{SkipCriterion, SkipStats};
use super::tiled::{
    process_scored_tile, process_tile_fallback, score_pass, tile_skip_lo, try_skip_tile, RowState,
    SigmoidEval,
};
use super::dot;
use crate::numerics::quant::{KvRef, KvView};

/// Default query block length. 16 queries × d=64 × 4 B = 4 KiB of Q plus
/// the `Bq × Bc` f64 score scratch (4 KiB at the default tile) alongside
/// the ~16 KiB KV tile — the whole working set stays L1-resident while
/// cutting KV traffic 16-fold.
pub const DEFAULT_BLOCK_Q: usize = 16;

/// Reusable scratch for the query-blocked kernel: the `Bq × Bc` score
/// matrix, per-query tile maxima, and per-query carried recursion state.
/// Grown on demand, never shrunk — hold one per worker/session and every
/// call after warm-up is allocation-free.
#[derive(Debug, Default)]
pub struct QScratch {
    /// `Bq × Bc` tile scores, row `iq` at `[iq * tile .. iq * tile + t_len]`.
    scores: Vec<f64>,
    /// Per-query maximum score within the current tile.
    s_max: Vec<f64>,
    /// Per-query carried `(s_prev, ln_w)` state.
    states: Vec<RowState>,
    /// Per-query "tile not skipped" marks for the quantized-KV path (V is
    /// dequantized only if at least one query's tile survives the skip
    /// test).
    active: Vec<bool>,
}

impl QScratch {
    pub fn new() -> QScratch {
        QScratch::default()
    }

    fn ensure(&mut self, nq: usize, tile: usize) {
        if self.scores.len() < nq * tile {
            self.scores.resize(nq * tile, 0.0);
        }
        if self.s_max.len() < nq {
            self.s_max.resize(nq, f64::NEG_INFINITY);
        }
        if self.states.len() < nq {
            self.states.resize(nq, RowState::default());
        }
        if self.active.len() < nq {
            self.active.resize(nq, false);
        }
    }
}

/// Query-blocked FLASH-D over `nq` queries sharing one KV context, writing
/// the `(nq, d)` output into `out` (fully overwritten). Bit-identical per
/// query to [`super::tiled::attention_tiled_instrumented`] with the same
/// `(tile, crit)` — see the module docs for why — and the returned
/// [`SkipStats`] are the exact sum of the per-query stats.
///
/// With `causal = true`, query `iq` attends the first `n - nq + 1 + iq`
/// keys (requires `n >= nq`); otherwise every query attends all `n`.
#[allow(clippy::too_many_arguments)]
pub fn attention_qblock_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    causal: bool,
    scratch: &mut QScratch,
    out: &mut [f32],
) -> SkipStats {
    qblock_core(q, k, v, nq, n, d, scale, tile, crit, causal, SigmoidEval::Exact, scratch, out)
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn qblock_core(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    causal: bool,
    sig: SigmoidEval<'_>,
    scratch: &mut QScratch,
    out: &mut [f32],
) -> SkipStats {
    assert!(nq >= 1, "empty query block");
    assert!(n > 0, "empty KV context");
    assert!(tile > 0, "tile must be >= 1");
    assert_eq!(out.len(), nq * d);
    if causal {
        assert!(n >= nq, "causal block needs n >= nq (got n={n}, nq={nq})");
    }
    debug_assert!(q.len() >= nq * d);
    debug_assert!(k.len() >= n * d && v.len() >= n * d);

    scratch.ensure(nq, tile);
    let QScratch { scores, s_max, states, .. } = scratch;

    let mut stats = SkipStats::default();
    // Per-query KV length: the causal staircase nests prefixes so the
    // block's last query attends all n keys. Always >= 1.
    let n_of = |iq: usize| if causal { n - nq + 1 + iq } else { n };

    // Step 0 (w_1 = 1) for every query: output becomes v_0 — same fixed
    // first step as the single-query kernel.
    for iq in 0..nq {
        let s0 = (dot(&q[iq * d..(iq + 1) * d], &k[..d]) * scale) as f64;
        out[iq * d..(iq + 1) * d].copy_from_slice(&v[..d]);
        states[iq] = RowState { s_prev: s0, ln_w: 0.0 };
    }

    let tile_lo = tile_skip_lo(crit);
    let mut i = 1usize;
    while i < n {
        let t_end = (i + tile).min(n);

        // --- phase 1: fused score pass, K tile shared across the block --
        for iq in 0..nq {
            let ni = n_of(iq);
            if ni <= i {
                continue; // this query's prefix ended before the tile
            }
            let e = t_end.min(ni);
            s_max[iq] = score_pass(
                &q[iq * d..(iq + 1) * d],
                &k[i * d..e * d],
                d,
                scale,
                &mut scores[iq * tile..iq * tile + (e - i)],
            );
        }

        // --- phase 2: per-query skip test + fallback, V tile shared -----
        for iq in 0..nq {
            let ni = n_of(iq);
            if ni <= i {
                continue;
            }
            let e = t_end.min(ni);
            process_scored_tile(
                &scores[iq * tile..iq * tile + (e - i)],
                s_max[iq],
                i,
                v,
                d,
                crit,
                tile_lo,
                sig,
                &mut states[iq],
                &mut out[iq * d..(iq + 1) * d],
                &mut stats,
            );
        }
        i = t_end;
    }
    stats
}

/// Query-blocked FLASH-D over possibly-quantized KV ([`KvRef`]). The K tile
/// is dequantized into `ktile` **once per query block** (the bandwidth win
/// compounds with query blocking); the V tile is dequantized into `vtile`
/// only if at least one query's tile survives the block-skip test. `F32`
/// operands take the zero-copy path and are bit-identical to
/// [`attention_qblock_into`]; quantized operands are bit-identical to the
/// f32 kernel over the dequantized arrays (stats accumulate in a different
/// but commutative order).
#[allow(clippy::too_many_arguments)]
pub fn attention_qblock_kv_into(
    q: &[f32],
    k: KvRef<'_>,
    v: KvRef<'_>,
    nq: usize,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    causal: bool,
    scratch: &mut QScratch,
    ktile: &mut Vec<f32>,
    vtile: &mut Vec<f32>,
    out: &mut [f32],
) -> SkipStats {
    qblock_kv_core(
        q,
        KvView::Contig(k),
        KvView::Contig(v),
        nq,
        n,
        d,
        scale,
        tile,
        crit,
        causal,
        SigmoidEval::Exact,
        scratch,
        ktile,
        vtile,
        out,
    )
}

/// The KV-general query-blocked core: K and V arrive as [`KvView`]s —
/// contiguous (possibly quantized) buffers or paged gathers over pool
/// blocks. The tile loop consumes KV exclusively through element-range
/// [`KvView::load_into`] calls, which yield the same f32 tile for paged and
/// contiguous storage of the same logical buffer — so the paged path is
/// bit-identical to the contiguous path by construction. A contiguous
/// all-f32 view delegates to the zero-copy [`qblock_core`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn qblock_kv_core(
    q: &[f32],
    k: KvView<'_>,
    v: KvView<'_>,
    nq: usize,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    causal: bool,
    sig: SigmoidEval<'_>,
    scratch: &mut QScratch,
    ktile: &mut Vec<f32>,
    vtile: &mut Vec<f32>,
    out: &mut [f32],
) -> SkipStats {
    if let (Some(kf), Some(vf)) = (k.as_contig_f32(), v.as_contig_f32()) {
        return qblock_core(q, kf, vf, nq, n, d, scale, tile, crit, causal, sig, scratch, out);
    }

    assert!(nq >= 1, "empty query block");
    assert!(n > 0, "empty KV context");
    assert!(tile > 0, "tile must be >= 1");
    assert_eq!(out.len(), nq * d);
    if causal {
        assert!(n >= nq, "causal block needs n >= nq (got n={n}, nq={nq})");
    }
    debug_assert!(q.len() >= nq * d);
    debug_assert!(k.len() >= n * d && v.len() >= n * d);

    scratch.ensure(nq, tile);
    if ktile.len() < tile * d {
        ktile.resize(tile * d, 0.0);
    }
    if vtile.len() < tile * d {
        vtile.resize(tile * d, 0.0);
    }
    let QScratch { scores, s_max, states, active } = scratch;

    let mut stats = SkipStats::default();
    let n_of = |iq: usize| if causal { n - nq + 1 + iq } else { n };

    // Step 0: dequantize row 0 of K and V through the tile buffers.
    k.load_into(0, d, &mut ktile[..d]);
    v.load_into(0, d, &mut vtile[..d]);
    for iq in 0..nq {
        let s0 = (dot(&q[iq * d..(iq + 1) * d], &ktile[..d]) * scale) as f64;
        out[iq * d..(iq + 1) * d].copy_from_slice(&vtile[..d]);
        states[iq] = RowState { s_prev: s0, ln_w: 0.0 };
    }

    let tile_lo = tile_skip_lo(crit);
    let mut i = 1usize;
    while i < n {
        let t_end = (i + tile).min(n);

        // K tile: one dequantization serves the whole query block.
        k.load_into(i * d, t_end * d, &mut ktile[..(t_end - i) * d]);
        for iq in 0..nq {
            let ni = n_of(iq);
            if ni <= i {
                continue;
            }
            let e = t_end.min(ni);
            s_max[iq] = score_pass(
                &q[iq * d..(iq + 1) * d],
                &ktile[..(e - i) * d],
                d,
                scale,
                &mut scores[iq * tile..iq * tile + (e - i)],
            );
        }

        // Skip tests first: V is only dequantized if some query needs it.
        let mut need_v = false;
        for iq in 0..nq {
            active[iq] = false;
            let ni = n_of(iq);
            if ni <= i {
                continue;
            }
            let e = t_end.min(ni);
            if !try_skip_tile(
                &scores[iq * tile..iq * tile + (e - i)],
                s_max[iq],
                tile_lo,
                &mut states[iq],
                &mut stats,
            ) {
                active[iq] = true;
                need_v = true;
            }
        }
        if need_v {
            v.load_into(i * d, t_end * d, &mut vtile[..(t_end - i) * d]);
            for iq in 0..nq {
                if !active[iq] {
                    continue;
                }
                let e = t_end.min(n_of(iq));
                process_tile_fallback(
                    &scores[iq * tile..iq * tile + (e - i)],
                    i,
                    &vtile[..(t_end - i) * d],
                    i,
                    d,
                    crit,
                    sig,
                    &mut states[iq],
                    &mut out[iq * d..(iq + 1) * d],
                    &mut stats,
                );
            }
        }
        i = t_end;
    }
    stats
}

/// Allocating convenience wrapper around [`attention_qblock_into`].
#[allow(clippy::too_many_arguments)]
pub fn attention_qblock(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    n: usize,
    d: usize,
    scale: f32,
    tile: usize,
    crit: SkipCriterion,
    causal: bool,
) -> (Vec<f32>, SkipStats) {
    let mut out = vec![0.0f32; nq * d];
    let mut scratch = QScratch::default();
    let stats =
        attention_qblock_into(q, k, v, nq, n, d, scale, tile, crit, causal, &mut scratch, &mut out);
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::flashd::{ACTIVE_HI, ACTIVE_LO};
    use crate::kernels::tiled;
    use crate::util::rng::Rng;

    fn problem(seed: u64, nq: usize, n: usize, d: usize, std: f32) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(nq * d, std), rng.normal_vec(n * d, std), rng.normal_vec(n * d, 1.0))
    }

    #[test]
    fn shared_bitmatches_tiled_per_query_all_criteria() {
        let crits = [
            SkipCriterion::None,
            SkipCriterion::Static,
            SkipCriterion::Adaptive { lo: ACTIVE_LO, hi: ACTIVE_HI },
        ];
        for &(nq, n, d) in &[(1usize, 40usize, 8usize), (4, 97, 16), (16, 256, 32)] {
            let (q, k, v) = problem(nq as u64 * 7 + n as u64, nq, n, d, 1.5);
            for crit in crits {
                for tile in [1usize, 7, 32, n] {
                    let (got, got_st) =
                        attention_qblock(&q, &k, &v, nq, n, d, 0.6, tile, crit, false);
                    let mut want_st = SkipStats::default();
                    for iq in 0..nq {
                        let (o, st) = tiled::attention_tiled_instrumented(
                            &q[iq * d..(iq + 1) * d],
                            &k,
                            &v,
                            n,
                            d,
                            0.6,
                            tile,
                            crit,
                        );
                        assert_eq!(
                            &got[iq * d..(iq + 1) * d],
                            &o[..],
                            "nq={nq} n={n} tile={tile} crit={crit:?} query {iq}"
                        );
                        want_st.merge(&st);
                    }
                    assert_eq!(got_st, want_st, "nq={nq} n={n} tile={tile} crit={crit:?}");
                }
            }
        }
    }

    #[test]
    fn causal_staircase_bitmatches_per_prefix() {
        let (nq, n, d) = (8usize, 30usize, 8usize);
        let (q, k, v) = problem(99, nq, n, d, 1.0);
        for tile in [1usize, 4, 16, 32] {
            let (got, got_st) =
                attention_qblock(&q, &k, &v, nq, n, d, 0.5, tile, SkipCriterion::Static, true);
            let mut want_st = SkipStats::default();
            for iq in 0..nq {
                let ni = n - nq + 1 + iq;
                let (o, st) = tiled::attention_tiled_instrumented(
                    &q[iq * d..(iq + 1) * d],
                    &k[..ni * d],
                    &v[..ni * d],
                    ni,
                    d,
                    0.5,
                    tile,
                    SkipCriterion::Static,
                );
                assert_eq!(&got[iq * d..(iq + 1) * d], &o[..], "tile={tile} query {iq}");
                want_st.merge(&st);
            }
            assert_eq!(got_st, want_st, "tile={tile}");
        }
    }

    #[test]
    fn causal_full_square_matches_causal_rows() {
        // n == nq: query iq attends iq + 1 keys — the engine's prefill shape.
        let (l, d) = (12usize, 8usize);
        let (q, k, v) = problem(5, l, l, d, 0.9);
        let (got, _) = attention_qblock(&q, &k, &v, l, l, d, 0.4, 4, SkipCriterion::None, true);
        for r in 0..l {
            let want = tiled::attention_tiled(
                &q[r * d..(r + 1) * d],
                &k[..(r + 1) * d],
                &v[..(r + 1) * d],
                r + 1,
                d,
                0.4,
                4,
            );
            assert_eq!(&got[r * d..(r + 1) * d], &want[..], "row {r}");
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        // A warm scratch carrying state from a larger problem must not leak
        // into a smaller one.
        let mut scratch = QScratch::new();
        let (q1, k1, v1) = problem(1, 16, 128, 16, 1.2);
        let mut out1 = vec![0.0f32; 16 * 16];
        attention_qblock_into(
            &q1, &k1, &v1, 16, 128, 16, 1.0, 32,
            SkipCriterion::Static,
            false,
            &mut scratch,
            &mut out1,
        );
        let (q2, k2, v2) = problem(2, 3, 20, 8, 1.2);
        let mut out2 = vec![0.0f32; 3 * 8];
        let st = attention_qblock_into(
            &q2, &k2, &v2, 3, 20, 8, 1.0, 7,
            SkipCriterion::Static,
            false,
            &mut scratch,
            &mut out2,
        );
        let (want, want_st) =
            attention_qblock(&q2, &k2, &v2, 3, 20, 8, 1.0, 7, SkipCriterion::Static, false);
        assert_eq!(out2, want);
        assert_eq!(st, want_st);
    }

    #[test]
    fn single_key_context() {
        // n = 1: output is v_0 for every query, zero weight-update steps.
        let (nq, d) = (5usize, 8usize);
        let (q, k, v) = problem(8, nq, 1, d, 1.0);
        let (got, st) = attention_qblock(&q, &k, &v, nq, 1, d, 1.0, 32, SkipCriterion::None, false);
        assert_eq!(st.total, 0);
        for iq in 0..nq {
            assert_eq!(&got[iq * d..(iq + 1) * d], &v[..d], "query {iq}");
        }
    }

    #[test]
    fn block_skip_fires_per_query_on_engineered_scores() {
        // Query 0 sees steeply decreasing scores (every tile skips); query 1
        // sees flat scores (no tile skips). The per-query mask must keep
        // them independent.
        let d = 8usize;
        let n = 33usize;
        let mut rng = Rng::new(17);
        let mut q = vec![0.0f32; 2 * d];
        q[0] = 1.0; // query 0 keys off k[.., 0]
        q[d + 1] = 1.0; // query 1 keys off k[.., 1] (all zeros -> flat)
        let mut k = Vec::new();
        for i in 0..n {
            let mut row = vec![0.0f32; d];
            row[0] = -(i as f32) * 8.0;
            k.extend(row);
        }
        let v = rng.normal_vec(n * d, 1.0);
        let (got, st) =
            attention_qblock(&q, &k, &v, 2, n, d, 1.0, 4, SkipCriterion::Static, false);
        // query 0: all n-1 updates skip low, output stays v_0
        assert_eq!(&got[..d], &v[..d]);
        assert_eq!(st.total, 2 * (n as u64 - 1));
        assert!(st.skip_low >= (n as u64 - 1));
        // query 1 must bit-match its single-query run
        let (want1, _) = tiled::attention_tiled_instrumented(
            &q[d..2 * d],
            &k,
            &v,
            n,
            d,
            1.0,
            4,
            SkipCriterion::Static,
        );
        assert_eq!(&got[d..2 * d], &want1[..]);
    }

    #[test]
    fn kv_qblock_bitmatches_f32_over_dequantized_operands() {
        use crate::numerics::quant::{quantize_bf16, quantize_fp8, KvRef};
        let (nq, n, d) = (5usize, 97usize, 16usize);
        let (q, k, v) = problem(123, nq, n, d, 1.2);
        let kb = quantize_bf16(&k);
        let vb = quantize_bf16(&v);
        let k8 = quantize_fp8(&k);
        let v8 = quantize_fp8(&v);
        let refs = [
            (KvRef::F32(&k), KvRef::F32(&v)),
            (KvRef::Bf16(&kb), KvRef::Bf16(&vb)),
            (KvRef::Fp8(&k8), KvRef::Fp8(&v8)),
        ];
        for causal in [false, true] {
            for (kr, vr) in refs {
                let kd = kr.to_f32_vec();
                let vd = vr.to_f32_vec();
                for tile in [4usize, 16, 97] {
                    let (want, want_st) = attention_qblock(
                        &q, &kd, &vd, nq, n, d, 0.5, tile,
                        SkipCriterion::Static,
                        causal,
                    );
                    let mut scratch = QScratch::new();
                    let (mut ktile, mut vtile) = (Vec::new(), Vec::new());
                    let mut got = vec![0.0f32; nq * d];
                    let got_st = attention_qblock_kv_into(
                        &q, kr, vr, nq, n, d, 0.5, tile,
                        SkipCriterion::Static,
                        causal,
                        &mut scratch,
                        &mut ktile,
                        &mut vtile,
                        &mut got,
                    );
                    let p = kr.precision();
                    assert_eq!(got, want, "tile={tile} causal={causal} {p:?}");
                    // SkipStats are commutative sums, so the reordered
                    // (skips-then-fallbacks) accumulation matches exactly.
                    assert_eq!(got_st, want_st, "tile={tile} causal={causal} {p:?}");
                }
            }
        }
    }
}
