//! Batched multi-query/multi-head attention driver over the tiled and
//! query-blocked FLASH-D kernels.
//!
//! A forward pass (or a serving batch) decomposes into many *independent*
//! attention rows — one per (layer, head, query). Since PR 2 the driver
//! thinks in **query blocks** ([`BlockJob`]): `nq` contiguous queries
//! sharing one KV context run through [`super::qblock`] so each KV tile is
//! streamed from memory once per block instead of once per query. Row-level
//! callers keep the [`RowJob`] API — [`run_rows`]/[`run_rows_into`] contain
//! a grouping pass that coalesces adjacent rows sharing a KV prefix
//! (identical `(k, v, n)`, or a causal `n, n+1, n+2, …` staircase over the
//! same buffers) into blocks automatically. Because the blocked kernel is
//! bit-identical per query to the single-query tiled kernel, grouping never
//! changes a result or a statistic.
//!
//! Work is partitioned across `std::thread::scope` workers:
//!
//! * **Deterministic output ordering** — blocks are partitioned into
//!   contiguous chunks by cost (now in `nq * n * d` units); each worker
//!   writes its results into the output slots of the same indices
//!   (disjoint `split_at_mut` regions, no locks), so the result is bitwise
//!   identical for every thread count.
//! * **Exact skip accounting** — each worker fills its own [`SkipStats`];
//!   the parts are merged in worker order afterwards (u64 sums,
//!   order-independent anyway).
//! * **Small-problem guard** — thread spawning is skipped when the total
//!   work is too small to amortize it, so single-token decode steps don't
//!   pay ~10 µs of spawn latency per layer.
//! * **Reusable per-worker scratch** — score/state/gather buffers live in
//!   a [`BatchScratch`] (either caller-owned via the `_with` variants, as
//!   on the decode and serving hot paths, or per-call otherwise), so the
//!   kernels allocate nothing after warm-up; the driver's remaining
//!   per-call allocations are the small job-count-sized bookkeeping
//!   lists, not KV-sized buffers.
//!
//! [`KernelConfig`] bundles the knobs every caller threads through:
//! KV tile length, query block length, worker count, skip criterion,
//! sigmoid evaluation mode ([`SigmoidMode`]), and KV storage precision
//! ([`KvPrecision`]). The quantized entry points ([`KvRowJob`],
//! [`KvBlockJob`], [`run_kv_rows_into_with`],
//! [`run_kv_blocks_flat_into_with`]) accept K/V as [`KvRef`] in any
//! storage precision; `F32` references take a zero-copy path that is
//! bit-identical to the plain drivers. The paged entry points
//! ([`PagedKvBlockJob`], [`run_paged_kv_blocks_flat_into_with`], and
//! [`KvRowJob`]'s [`KvView`] fields) additionally accept KV gathered from
//! non-contiguous pool blocks (`coordinator::kv_cache::BlockPool`); the
//! kernels consume contiguous and paged storage through the same
//! element-range tile loads, so paged results are bit-identical to
//! contiguous ones by construction.

use super::flashd::{SigmoidMode, SkipCriterion, SkipStats};
use super::qblock::{self, QScratch, DEFAULT_BLOCK_Q};
use super::tiled::{self, SigmoidEval, DEFAULT_TILE};
use crate::numerics::quant::{KvPrecision, KvRef, KvView};
use crate::pwl::SigTables;

/// Tuning knobs for the tiled/batched kernel engine, threaded through
/// `model::engine`, `model::decode`, and `coordinator::server`.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct KernelConfig {
    /// KV tile length (keys per block) for the tiled kernel.
    pub tile: usize,
    /// Query block length: how many queries share one KV-tile stream in
    /// the query-blocked kernel (1 = per-query, PR 1 behavior).
    pub block_q: usize,
    /// Maximum worker threads for [`run_rows`] (1 = fully serial).
    pub threads: usize,
    /// Saturation-skip criterion applied per row.
    pub skip: SkipCriterion,
    /// Per-step nonlinearity evaluation: the exact `exp`/`ln_1p` pair
    /// (default, bit-identical to the scalar reference) or the paper's
    /// §IV-B piecewise-linear sigmoid/ln tables (opt-in fast path with a
    /// measured error envelope). Tables are fitted once per worker and
    /// cached in its [`BatchScratch`] slot.
    pub sigmoid: SigmoidMode,
    /// Storage precision for KV operands. The kernels themselves accept
    /// any [`KvRef`] regardless of this knob; the storage layers
    /// (`coordinator::kv_cache`, `model::decode`) read it to decide how
    /// caches are held at rest. `F32` keeps every path bit-identical to
    /// the unquantized engine.
    pub kv_precision: KvPrecision,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            tile: DEFAULT_TILE,
            block_q: DEFAULT_BLOCK_Q,
            threads: default_threads(),
            skip: SkipCriterion::None,
            sigmoid: SigmoidMode::Exact,
            kv_precision: KvPrecision::F32,
        }
    }
}

/// Default worker count: the machine's parallelism, capped so tiny models
/// don't drown in spawn overhead.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// One independent attention row: a single query over an `(n, d)` KV
/// prefix. All slices borrow from the caller.
#[derive(Copy, Clone, Debug)]
pub struct RowJob<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub n: usize,
    pub d: usize,
    pub scale: f32,
}

/// A block of `nq` contiguous queries (`(nq, d)` row-major in `q`) sharing
/// one KV context — the unit the query-blocked kernel executes. With
/// `causal = true` query `iq` attends the first `n - nq + 1 + iq` keys
/// (the last query attends all `n`; requires `n >= nq`); otherwise every
/// query attends all `n`.
#[derive(Copy, Clone, Debug)]
pub struct BlockJob<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub nq: usize,
    pub n: usize,
    pub d: usize,
    pub scale: f32,
    pub causal: bool,
}

/// [`RowJob`] over possibly-quantized, possibly-paged KV: the query stays
/// f32, while K and V arrive as [`KvView`] — either one contiguous
/// [`KvRef`] in whatever storage precision the cache holds, or a paged
/// gather over pool blocks. Contiguous `F32` views execute the zero-copy
/// bit-exact path; everything else is dequantized/gathered tile-by-tile
/// into worker scratch, bit-identically.
#[derive(Copy, Clone, Debug)]
pub struct KvRowJob<'a> {
    pub q: &'a [f32],
    pub k: KvView<'a>,
    pub v: KvView<'a>,
    pub n: usize,
    pub d: usize,
    pub scale: f32,
}

/// [`BlockJob`] over possibly-quantized KV — the fused serving submission
/// unit once session caches hold compressed KV. Semantics (causal
/// staircase, splitting, determinism) match [`BlockJob`] exactly; an
/// all-`F32` submission is bit-identical to the f32 driver.
#[derive(Copy, Clone, Debug)]
pub struct KvBlockJob<'a> {
    pub q: &'a [f32],
    pub k: KvRef<'a>,
    pub v: KvRef<'a>,
    pub nq: usize,
    pub n: usize,
    pub d: usize,
    pub scale: f32,
    pub causal: bool,
}

impl<'a> From<&BlockJob<'a>> for KvBlockJob<'a> {
    fn from(b: &BlockJob<'a>) -> Self {
        KvBlockJob {
            q: b.q,
            k: KvRef::F32(b.k),
            v: KvRef::F32(b.v),
            nq: b.nq,
            n: b.n,
            d: b.d,
            scale: b.scale,
            causal: b.causal,
        }
    }
}

/// [`KvBlockJob`] over [`KvView`] KV — the fused serving submission unit
/// once session caches are paged: K and V may each be a gather over
/// non-contiguous, refcounted pool blocks ([`crate::numerics::quant::PagedKv`]),
/// or a plain contiguous reference (stateless requests fuse into the same
/// submission). Semantics (causal staircase, splitting, determinism) match
/// [`KvBlockJob`] exactly, and the output is bit-identical to a contiguous
/// submission over the same logical KV — the kernels consume both through
/// the same element-range tile loads.
#[derive(Copy, Clone, Debug)]
pub struct PagedKvBlockJob<'a> {
    pub q: &'a [f32],
    pub k: KvView<'a>,
    pub v: KvView<'a>,
    pub nq: usize,
    pub n: usize,
    pub d: usize,
    pub scale: f32,
    pub causal: bool,
}

impl<'a> From<&KvBlockJob<'a>> for PagedKvBlockJob<'a> {
    fn from(b: &KvBlockJob<'a>) -> Self {
        PagedKvBlockJob {
            q: b.q,
            k: KvView::Contig(b.k),
            v: KvView::Contig(b.v),
            nq: b.nq,
            n: b.n,
            d: b.d,
            scale: b.scale,
            causal: b.causal,
        }
    }
}

/// Per-worker scratch: query-block kernel scratch, single-row score
/// buffer, gather/output staging for the row-grouping path, dequantized
/// KV tile buffers, and the worker's cached PWL sigmoid tables.
#[derive(Debug, Default)]
struct WorkerScratch {
    qs: QScratch,
    row_scores: Vec<f64>,
    qbuf: Vec<f32>,
    obuf: Vec<f32>,
    ktile: Vec<f32>,
    vtile: Vec<f32>,
    sig: Option<SigTables>,
}

/// Resolve the configured [`SigmoidMode`] into the kernel-level evaluator,
/// (re)fitting the worker's cached PWL tables only when the requested
/// segment count differs from the cached fit.
fn sigmoid_eval<'s>(cfg: &KernelConfig, slot: &'s mut Option<SigTables>) -> SigmoidEval<'s> {
    match cfg.sigmoid {
        SigmoidMode::Exact => SigmoidEval::Exact,
        SigmoidMode::Pwl { segments } => {
            let segments = segments.max(1);
            if slot.as_ref().map(SigTables::segments) != Some(segments) {
                *slot = Some(SigTables::new(segments));
            }
            SigmoidEval::Pwl(slot.as_ref().expect("table fitted above"))
        }
    }
}

/// Reusable scratch for the batched driver: one [`WorkerScratch`] slot per
/// worker thread. Hold one per session/engine and pass it to the `_with`
/// entry points so the kernels themselves allocate nothing after warm-up
/// (the driver still builds small per-call bookkeeping lists — the item
/// plan and, on the threaded path, cost/stat vectors — whose size is the
/// job count, not the KV length).
#[derive(Debug, Default)]
pub struct BatchScratch {
    slots: Vec<WorkerScratch>,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    fn ensure(&mut self, workers: usize) {
        while self.slots.len() < workers {
            self.slots.push(WorkerScratch::default());
        }
    }
}

/// Internal unit of kernel work: either a contiguous query block (`q` set)
/// or a coalesced run of row jobs (`q == None`; queries live in
/// `jobs[row0 .. row0 + nq]` and are gathered into worker scratch at
/// execution time — grouping never assumes the rows' query slices are
/// adjacent in memory).
#[derive(Copy, Clone, Debug)]
struct Item<'a> {
    q: Option<&'a [f32]>,
    row0: usize,
    k: KvView<'a>,
    v: KvView<'a>,
    nq: usize,
    n: usize,
    d: usize,
    scale: f32,
    causal: bool,
}

/// Job types the row-grouping machinery can gather query rows from —
/// lets [`Item`] and the chunk runners serve both the f32 [`RowJob`]
/// path and the quantized [`KvRowJob`] path with one implementation.
trait QRow<'a> {
    fn q_row(&self) -> &'a [f32];
}

impl<'a> QRow<'a> for RowJob<'a> {
    fn q_row(&self) -> &'a [f32] {
        self.q
    }
}

impl<'a> QRow<'a> for KvRowJob<'a> {
    fn q_row(&self) -> &'a [f32] {
        self.q
    }
}

impl<'a> Item<'a> {
    /// Work estimate in multiply-accumulate units (`sum_iq n_iq * d`).
    fn cost(&self) -> usize {
        if self.causal {
            // per-query lengths n0 ..= n with n0 = n - nq + 1: arithmetic
            // series, nq * (n0 + n) is always even
            let n0 = self.n - self.nq + 1;
            self.nq * (n0 + self.n) / 2 * self.d
        } else {
            self.nq * self.n * self.d
        }
    }

    /// The single query row of an `nq == 1` item.
    fn single_query<J: QRow<'a>>(&self, jobs: &[J]) -> &'a [f32] {
        match self.q {
            Some(q) => &q[..self.d],
            None => &jobs[self.row0].q_row()[..self.d],
        }
    }

    /// The `(nq, d)` query rows, gathering from `jobs` into `qbuf` when
    /// the item came from the row-grouping pass.
    fn queries<'b, J: QRow<'a>>(&self, jobs: &[J], qbuf: &'b mut Vec<f32>) -> &'b [f32]
    where
        'a: 'b,
    {
        if let Some(q) = self.q {
            return &q[..self.nq * self.d];
        }
        qbuf.clear();
        for j in 0..self.nq {
            qbuf.extend_from_slice(&jobs[self.row0 + j].q_row()[..self.d]);
        }
        &qbuf[..]
    }
}

fn same_slice(a: &[f32], b: &[f32]) -> bool {
    std::ptr::eq(a.as_ptr(), b.as_ptr()) && a.len() == b.len()
}

/// Grouping pass: coalesce adjacent row jobs into query blocks of at most
/// `max_bq`. Two consecutive rows join the same block when they share the
/// exact KV slices (same `(k, v, n, d, scale)` — the serving-batch shape)
/// or form a causal staircase (`n` increasing by 1 over the same K/V
/// buffers — the prefill shape). Grouping is a pure performance decision:
/// the blocked kernel is bit-identical per query, so any grouping yields
/// identical outputs and stats.
fn coalesce<'a>(jobs: &[RowJob<'a>], max_bq: usize) -> Vec<Item<'a>> {
    let max_bq = max_bq.max(1);
    let mut items = Vec::new();
    let mut i = 0usize;
    while i < jobs.len() {
        let mut nq = 1usize;
        let mut causal = false;
        while nq < max_bq && i + nq < jobs.len() {
            let p = &jobs[i + nq - 1];
            let nx = &jobs[i + nq];
            if nx.d != p.d || nx.scale != p.scale {
                break;
            }
            let shared = !causal && same_slice(p.k, nx.k) && same_slice(p.v, nx.v) && nx.n == p.n;
            let stair = (causal || nq == 1)
                && std::ptr::eq(p.k.as_ptr(), nx.k.as_ptr())
                && std::ptr::eq(p.v.as_ptr(), nx.v.as_ptr())
                && nx.n == p.n + 1
                && nx.k.len() >= nx.n * nx.d
                && nx.v.len() >= nx.n * nx.d;
            if shared {
                nq += 1;
            } else if stair {
                causal = true;
                nq += 1;
            } else {
                break;
            }
        }
        let last = &jobs[i + nq - 1];
        items.push(Item {
            q: None,
            row0: i,
            // the last row's K/V cover every query's prefix in both modes
            k: KvView::Contig(KvRef::F32(last.k)),
            v: KvView::Contig(KvRef::F32(last.v)),
            nq,
            n: last.n,
            d: last.d,
            scale: last.scale,
            causal,
        });
        i += nq;
    }
    items
}

/// Grouping pass for [`KvRowJob`]s: adjacent rows sharing the exact same
/// KV references (same variant, base pointer, length, `n`, `d`, `scale`)
/// coalesce into one query block, so a serving batch over one quantized
/// cache dequantizes each KV tile once per block instead of once per row.
/// (The causal-staircase pattern is submitted through [`KvBlockJob`]s by
/// the block-level callers, so row-level staircase detection isn't
/// replicated here.)
fn coalesce_kv<'a>(jobs: &[KvRowJob<'a>], max_bq: usize) -> Vec<Item<'a>> {
    let max_bq = max_bq.max(1);
    let mut items = Vec::new();
    let mut i = 0usize;
    while i < jobs.len() {
        let mut nq = 1usize;
        while nq < max_bq && i + nq < jobs.len() {
            let p = &jobs[i + nq - 1];
            let nx = &jobs[i + nq];
            if nx.d != p.d
                || nx.scale != p.scale
                || nx.n != p.n
                || !KvView::same(p.k, nx.k)
                || !KvView::same(p.v, nx.v)
            {
                break;
            }
            nq += 1;
        }
        let last = &jobs[i + nq - 1];
        items.push(Item {
            q: None,
            row0: i,
            k: last.k,
            v: last.v,
            nq,
            n: last.n,
            d: last.d,
            scale: last.scale,
            causal: false,
        });
        i += nq;
    }
    items
}

/// Expand explicit blocks into execution items, splitting any block wider
/// than the configured query block length.
fn items_of_blocks<'a>(blocks: &[BlockJob<'a>], cfg: &KernelConfig) -> Vec<Item<'a>> {
    let max_bq = cfg.block_q.max(1);
    let mut items = Vec::new();
    for b in blocks {
        push_block_items(&PagedKvBlockJob::from(&KvBlockJob::from(b)), max_bq, &mut items);
    }
    items
}

/// [`items_of_blocks`] over quantized-KV blocks.
fn items_of_kv_blocks<'a>(blocks: &[KvBlockJob<'a>], cfg: &KernelConfig) -> Vec<Item<'a>> {
    let max_bq = cfg.block_q.max(1);
    let mut items = Vec::new();
    for b in blocks {
        push_block_items(&PagedKvBlockJob::from(b), max_bq, &mut items);
    }
    items
}

/// [`items_of_blocks`] over paged/view-KV blocks.
fn items_of_paged_blocks<'a>(blocks: &[PagedKvBlockJob<'a>], cfg: &KernelConfig) -> Vec<Item<'a>> {
    let max_bq = cfg.block_q.max(1);
    let mut items = Vec::new();
    for b in blocks {
        push_block_items(b, max_bq, &mut items);
    }
    items
}

/// Split a [`PagedKvBlockJob`] into items of at most `max_bq` queries.
/// Causal sub-blocks keep the global staircase: sub-block queries `a..e` of
/// a causal block attend `n - nq + 1 + iq` keys for their global index
/// `iq`.
fn push_block_items<'a>(b: &PagedKvBlockJob<'a>, max_bq: usize, items: &mut Vec<Item<'a>>) {
    assert!(b.nq >= 1, "empty BlockJob");
    assert!(b.n >= 1, "BlockJob with empty KV context");
    if b.causal {
        assert!(b.n >= b.nq, "causal BlockJob needs n >= nq (got n={}, nq={})", b.n, b.nq);
    }
    let mut a = 0usize;
    while a < b.nq {
        let e = (a + max_bq).min(b.nq);
        items.push(Item {
            q: Some(&b.q[a * b.d..e * b.d]),
            row0: 0,
            k: b.k,
            v: b.v,
            nq: e - a,
            n: if b.causal { b.n - (b.nq - e) } else { b.n },
            d: b.d,
            scale: b.scale,
            causal: b.causal,
        });
        a = e;
    }
}

/// Minimum per-thread work (in `n * d` multiply-accumulate units) before a
/// worker thread is worth spawning.
const MIN_WORK_PER_THREAD: usize = 1 << 15;

/// Contiguous cost-balanced partition: returns per-worker chunk lengths
/// (summing to `costs.len()`). Each worker takes jobs until it reaches an
/// even share of the remaining cost — important for causal workloads,
/// where job cost grows linearly with row index and equal-count chunks
/// would leave the tail worker with ~2x the mean work. Deterministic in
/// `(costs, workers)`.
fn partition_by_cost(costs: &[usize], workers: usize) -> Vec<usize> {
    let total: usize = costs.iter().sum();
    let mut takes = Vec::with_capacity(workers);
    let mut idx = 0usize;
    let mut spent = 0usize;
    for w in 0..workers {
        if idx >= costs.len() {
            break;
        }
        let left = workers - w;
        if left == 1 {
            takes.push(costs.len() - idx);
            idx = costs.len();
            break;
        }
        let target = (total - spent).div_ceil(left);
        let mut take = 0usize;
        let mut cost = 0usize;
        while idx + take < costs.len() && (take == 0 || cost < target) {
            cost += costs[idx + take];
            take += 1;
        }
        idx += take;
        spent += cost;
        takes.push(take);
    }
    takes
}

/// Execute one chunk of items into a flat `f32` output (each item owns the
/// next `nq * d` floats, with `d` the item's own head dimension — mixed-`d`
/// chunks are fine). `nq == 1` items run the single-query tiled kernel with
/// the worker's score scratch; larger items run the query-blocked kernel.
/// All-`F32` items take the zero-copy delegation inside the KV cores, so
/// this compiles to the same float-op sequence the pre-quantization driver
/// executed; quantized items stream through the worker's tile buffers.
fn run_chunk_into<'a, J: QRow<'a>>(
    cfg: &KernelConfig,
    jobs: &[J],
    items: &[Item<'a>],
    out: &mut [f32],
    ws: &mut WorkerScratch,
    stats: &mut SkipStats,
) {
    let WorkerScratch { qs, row_scores, qbuf, ktile, vtile, sig, .. } = ws;
    let sig = sigmoid_eval(cfg, sig);
    let mut off = 0usize;
    for it in items {
        let slot = &mut out[off..off + it.nq * it.d];
        off += it.nq * it.d;
        let st = if it.nq == 1 {
            tiled::attention_kv_core(
                it.single_query(jobs),
                it.k, it.v, it.n, it.d, it.scale, cfg.tile, cfg.skip, sig, slot, row_scores,
                ktile, vtile,
            )
        } else {
            let q = it.queries(jobs, qbuf);
            qblock::qblock_kv_core(
                q, it.k, it.v, it.nq, it.n, it.d, it.scale, cfg.tile, cfg.skip, it.causal,
                sig, qs, ktile, vtile, slot,
            )
        };
        stats.merge(&st);
    }
}

/// Execute one chunk of items into per-query `Vec<f32>` output slots.
fn run_chunk<'a, J: QRow<'a>>(
    cfg: &KernelConfig,
    jobs: &[J],
    items: &[Item<'a>],
    out: &mut [Vec<f32>],
    ws: &mut WorkerScratch,
    stats: &mut SkipStats,
) {
    let WorkerScratch { qs, row_scores, qbuf, obuf, ktile, vtile, sig } = ws;
    let sig = sigmoid_eval(cfg, sig);
    let mut slot = 0usize;
    for it in items {
        if it.nq == 1 {
            let mut o = vec![0.0f32; it.d];
            let st = tiled::attention_kv_core(
                it.single_query(jobs),
                it.k, it.v, it.n, it.d, it.scale, cfg.tile, cfg.skip, sig, &mut o, row_scores,
                ktile, vtile,
            );
            stats.merge(&st);
            out[slot] = o;
        } else {
            let q = it.queries(jobs, qbuf);
            obuf.clear();
            obuf.resize(it.nq * it.d, 0.0);
            let st = qblock::qblock_kv_core(
                q, it.k, it.v, it.nq, it.n, it.d, it.scale, cfg.tile, cfg.skip, it.causal,
                sig, qs, ktile, vtile, &mut obuf[..],
            );
            stats.merge(&st);
            for (j, row) in obuf[..it.nq * it.d].chunks_exact(it.d).enumerate() {
                out[slot + j] = row.to_vec();
            }
        }
        slot += it.nq;
    }
}

/// Shared driver: size the worker pool from total work, partition items
/// into contiguous cost-balanced chunks, and run `chunk_fn` on each chunk
/// with its output slots and its own scratch slot, serially or on scoped
/// threads. `flat` selects the output unit: `nq * d` floats per item
/// (flat `f32` outputs, mixed `d` allowed) versus `nq` per-query slots.
/// All decisions depend only on `(cfg, items)`, so results are bitwise
/// identical for every thread count.
fn run_items<'j, T, F>(
    cfg: &KernelConfig,
    items: &[Item<'j>],
    out: &mut [T],
    flat: bool,
    scratch: &mut BatchScratch,
    chunk_fn: F,
) -> SkipStats
where
    T: Send,
    F: Fn(&[Item<'j>], &mut [T], &mut WorkerScratch, &mut SkipStats) + Sync,
{
    let mut stats = SkipStats::default();
    if items.is_empty() {
        return stats;
    }

    let work: usize = items.iter().map(Item::cost).sum();
    let by_work = (work / MIN_WORK_PER_THREAD).max(1);
    let threads = cfg.threads.max(1).min(items.len()).min(by_work);
    scratch.ensure(threads);

    if threads <= 1 {
        chunk_fn(items, out, &mut scratch.slots[0], &mut stats);
        return stats;
    }

    let costs: Vec<usize> = items.iter().map(Item::cost).collect();
    let takes = partition_by_cost(&costs, threads);
    let mut stat_parts = vec![SkipStats::default(); takes.len()];
    std::thread::scope(|scope| {
        let chunk_fn = &chunk_fn;
        let mut rem_items = items;
        let mut rem_out = out;
        let mut rem_slots = &mut scratch.slots[..];
        for (part, &take) in stat_parts.iter_mut().zip(&takes) {
            let (item_chunk, items_rest) = rem_items.split_at(take);
            let units: usize = item_chunk
                .iter()
                .map(|it| if flat { it.nq * it.d } else { it.nq })
                .sum();
            let (out_chunk, out_rest) = rem_out.split_at_mut(units);
            let (slot_chunk, slots_rest) = rem_slots.split_at_mut(1);
            rem_items = items_rest;
            rem_out = out_rest;
            rem_slots = slots_rest;
            let ws = &mut slot_chunk[0];
            scope.spawn(move || chunk_fn(item_chunk, out_chunk, ws, part));
        }
    });
    for part in &stat_parts {
        stats.merge(part);
    }
    stats
}

/// Execute every job and return `(outputs, stats)`, with `outputs[i]` the
/// result of `jobs[i]`. Adjacent jobs sharing a KV prefix are coalesced
/// into query blocks (see [`coalesce`]); results are bitwise identical to
/// the ungrouped per-row kernel and for every `cfg.threads` value.
pub fn run_rows(cfg: &KernelConfig, jobs: &[RowJob<'_>]) -> (Vec<Vec<f32>>, SkipStats) {
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); jobs.len()];
    let items = coalesce(jobs, cfg.block_q);
    let mut scratch = BatchScratch::new();
    let stats = run_items(cfg, &items, &mut outputs, false, &mut scratch, |ic, oc, ws, st| {
        run_chunk(cfg, jobs, ic, oc, ws, st)
    });
    (outputs, stats)
}

/// Flat-output variant of [`run_rows`] for the uniform-`d` hot paths
/// (decode steps, serving blocks, per-layer forward): writes job `i`'s
/// output row into `out[i * d..(i + 1) * d]` with no per-row allocation.
/// Same determinism guarantee as [`run_rows`].
pub fn run_rows_into(cfg: &KernelConfig, jobs: &[RowJob<'_>], d: usize, out: &mut [f32]) -> SkipStats {
    run_rows_into_with(cfg, jobs, d, out, &mut BatchScratch::new())
}

/// [`run_rows_into`] with caller-owned scratch: the kernel-side score,
/// state, and gather buffers are reused across calls (in particular the
/// `tile > 64` score buffer no longer reallocates once per call) — the
/// form the decode session uses once per (layer, token). Only the small
/// per-call item plan is still allocated.
pub fn run_rows_into_with(
    cfg: &KernelConfig,
    jobs: &[RowJob<'_>],
    d: usize,
    out: &mut [f32],
    scratch: &mut BatchScratch,
) -> SkipStats {
    assert_eq!(out.len(), jobs.len() * d, "output buffer must be jobs.len() * d");
    debug_assert!(jobs.iter().all(|j| j.d == d));
    let items = coalesce(jobs, cfg.block_q);
    run_items(cfg, &items, out, true, scratch, |ic, oc, ws, st| {
        run_chunk_into(cfg, jobs, ic, oc, ws, st)
    })
}

/// Execute explicit query blocks, returning one `Vec<f32>` per query row
/// in block order. Blocks larger than `cfg.block_q` are split on query
/// boundaries (bit-identical either way).
pub fn run_blocks(cfg: &KernelConfig, blocks: &[BlockJob<'_>]) -> (Vec<Vec<f32>>, SkipStats) {
    let total_q: usize = blocks.iter().map(|b| b.nq).sum();
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); total_q];
    let items = items_of_blocks(blocks, cfg);
    let mut scratch = BatchScratch::new();
    let no_rows: &[RowJob] = &[];
    let stats = run_items(cfg, &items, &mut outputs, false, &mut scratch, |ic, oc, ws, st| {
        run_chunk(cfg, no_rows, ic, oc, ws, st)
    });
    (outputs, stats)
}

/// Flat-output block driver: block `b`'s query `iq` lands at the
/// `(sum of earlier blocks' nq) + iq`-th `d`-row of `out`. The serving
/// engine's hot path.
pub fn run_blocks_into(cfg: &KernelConfig, blocks: &[BlockJob<'_>], d: usize, out: &mut [f32]) -> SkipStats {
    run_blocks_into_with(cfg, blocks, d, out, &mut BatchScratch::new())
}

/// [`run_blocks_into`] with caller-owned scratch (kernel buffers reused
/// across calls; only the per-call item plan is allocated).
pub fn run_blocks_into_with(
    cfg: &KernelConfig,
    blocks: &[BlockJob<'_>],
    d: usize,
    out: &mut [f32],
    scratch: &mut BatchScratch,
) -> SkipStats {
    let total_q: usize = blocks.iter().map(|b| b.nq).sum();
    assert_eq!(out.len(), total_q * d, "output buffer must be sum(nq) * d");
    debug_assert!(blocks.iter().all(|b| b.d == d));
    run_blocks_flat_into_with(cfg, blocks, out, scratch)
}

/// Flat-output block driver without the uniform-`d` requirement: block
/// `b`'s output occupies the next `nq_b * d_b` floats of `out`, in block
/// order. This is the fused serving entry point — one drain cycle's whole
/// job graph (every session, head, and shape signature the coordinator
/// lowered) goes through a single call, so the thread pool is sized and
/// balanced over the cycle's total work instead of per batch. The KV
/// slices of each job may borrow from anywhere (session caches, request
/// payloads); nothing is copied or required to be contiguous across jobs.
/// Same determinism guarantee as [`run_blocks_into`].
pub fn run_blocks_flat_into_with(
    cfg: &KernelConfig,
    blocks: &[BlockJob<'_>],
    out: &mut [f32],
    scratch: &mut BatchScratch,
) -> SkipStats {
    let total: usize = blocks.iter().map(|b| b.nq * b.d).sum();
    assert_eq!(out.len(), total, "output buffer must be sum(nq * d)");
    let items = items_of_blocks(blocks, cfg);
    let no_rows: &[RowJob] = &[];
    run_items(cfg, &items, out, true, scratch, |ic, oc, ws, st| {
        run_chunk_into(cfg, no_rows, ic, oc, ws, st)
    })
}

/// [`run_rows_into_with`] over possibly-quantized KV: job `i`'s output row
/// lands at `out[i * d..(i + 1) * d]`. Adjacent jobs sharing the exact
/// same KV references coalesce into query blocks (see [`coalesce_kv`]);
/// all-`F32` jobs are bit-identical to [`run_rows_into_with`], and
/// quantized jobs are bit-identical to the f32 driver run over the
/// dequantized arrays. The decode hot path once the layer caches hold
/// compressed KV.
pub fn run_kv_rows_into_with(
    cfg: &KernelConfig,
    jobs: &[KvRowJob<'_>],
    d: usize,
    out: &mut [f32],
    scratch: &mut BatchScratch,
) -> SkipStats {
    assert_eq!(out.len(), jobs.len() * d, "output buffer must be jobs.len() * d");
    debug_assert!(jobs.iter().all(|j| j.d == d));
    let items = coalesce_kv(jobs, cfg.block_q);
    run_items(cfg, &items, out, true, scratch, |ic, oc, ws, st| {
        run_chunk_into(cfg, jobs, ic, oc, ws, st)
    })
}

/// [`run_blocks_flat_into_with`] over possibly-quantized KV — the fused
/// serving entry point once session caches hold compressed KV. Block `b`'s
/// output occupies the next `nq_b * d_b` floats of `out`, in block order;
/// mixed head dims and mixed precisions in one submission are fine. Same
/// determinism guarantee as the f32 driver, and bit-identical to it for
/// all-`F32` submissions.
pub fn run_kv_blocks_flat_into_with(
    cfg: &KernelConfig,
    blocks: &[KvBlockJob<'_>],
    out: &mut [f32],
    scratch: &mut BatchScratch,
) -> SkipStats {
    let total: usize = blocks.iter().map(|b| b.nq * b.d).sum();
    assert_eq!(out.len(), total, "output buffer must be sum(nq * d)");
    let items = items_of_kv_blocks(blocks, cfg);
    let no_rows: &[KvRowJob] = &[];
    run_items(cfg, &items, out, true, scratch, |ic, oc, ws, st| {
        run_chunk_into(cfg, no_rows, ic, oc, ws, st)
    })
}

/// [`run_kv_blocks_flat_into_with`] over [`PagedKvBlockJob`]s — the fused
/// serving entry point over the paged session pool. Each block's K/V may be
/// a gather over non-contiguous pool blocks, a contiguous quantized buffer,
/// or a plain f32 slice (which keeps the zero-copy path); mixed head dims,
/// precisions, and storage layouts in one submission are fine. Block `b`'s
/// output occupies the next `nq_b * d_b` floats of `out`, in block order.
/// Bit-identical to [`run_kv_blocks_flat_into_with`] over contiguous
/// buffers holding the same logical KV, and carries the same determinism
/// guarantee across thread counts.
pub fn run_paged_kv_blocks_flat_into_with(
    cfg: &KernelConfig,
    blocks: &[PagedKvBlockJob<'_>],
    out: &mut [f32],
    scratch: &mut BatchScratch,
) -> SkipStats {
    let total: usize = blocks.iter().map(|b| b.nq * b.d).sum();
    assert_eq!(out.len(), total, "output buffer must be sum(nq * d)");
    let items = items_of_paged_blocks(blocks, cfg);
    let no_rows: &[KvRowJob] = &[];
    run_items(cfg, &items, out, true, scratch, |ic, oc, ws, st| {
        run_chunk_into(cfg, no_rows, ic, oc, ws, st)
    })
}

/// Causal per-head convenience: for each head buffer `(qh, kh, vh)` of `l`
/// rows × `d` columns, row `r` attends over the `r + 1` KV prefix. Returns
/// a flat output with row `(head * l + r)` at `[(head * l + r) * d..][..d]`
/// plus merged stats — the shape `model::engine::forward` consumes. Each
/// head is one causal [`BlockJob`], so prefill KV tiles stream once per
/// query block instead of once per row.
pub fn run_causal_heads(
    cfg: &KernelConfig,
    heads: &[(Vec<f32>, Vec<f32>, Vec<f32>)],
    l: usize,
    d: usize,
    scale: f32,
) -> (Vec<f32>, SkipStats) {
    let mut blocks = Vec::with_capacity(heads.len());
    if l > 0 {
        for (qh, kh, vh) in heads {
            blocks.push(BlockJob {
                q: &qh[..l * d],
                k: &kh[..l * d],
                v: &vh[..l * d],
                nq: l,
                n: l,
                d,
                scale,
                causal: true,
            });
        }
    }
    let mut out = vec![0.0f32; heads.len() * l * d];
    let stats = run_blocks_into(cfg, &blocks, d, &mut out);
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::flashd;
    use crate::util::rng::Rng;

    fn jobs_fixture(seed: u64, rows: usize, n: usize, d: usize) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        (0..rows)
            .map(|_| {
                (
                    rng.normal_vec(d, 0.8),
                    rng.normal_vec(n * d, 0.8),
                    rng.normal_vec(n * d, 1.0),
                )
            })
            .collect()
    }

    fn as_jobs<'a>(
        data: &'a [(Vec<f32>, Vec<f32>, Vec<f32>)],
        n: usize,
        d: usize,
    ) -> Vec<RowJob<'a>> {
        data.iter()
            .map(|(q, k, v)| RowJob { q, k, v, n, d, scale: 0.5 })
            .collect()
    }

    #[test]
    fn identical_across_thread_counts() {
        let (n, d) = (257usize, 32usize);
        let data = jobs_fixture(1, 13, n, d);
        let jobs = as_jobs(&data, n, d);
        let base_cfg = KernelConfig {
            tile: 16,
            threads: 1,
            skip: SkipCriterion::Static,
            ..KernelConfig::default()
        };
        let (want, want_st) = run_rows(&base_cfg, &jobs);
        for threads in [2usize, 3, 4, 8] {
            let cfg = KernelConfig { threads, ..base_cfg };
            let (got, got_st) = run_rows(&cfg, &jobs);
            assert_eq!(got, want, "threads={threads}");
            assert_eq!(got_st, want_st, "threads={threads}");
        }
    }

    #[test]
    fn matches_scalar_kernel_rowwise() {
        let (n, d) = (120usize, 16usize);
        let data = jobs_fixture(2, 6, n, d);
        let jobs = as_jobs(&data, n, d);
        let cfg = KernelConfig { tile: 32, threads: 4, ..KernelConfig::default() };
        let (outs, stats) = run_rows(&cfg, &jobs);
        assert_eq!(stats.skipped(), 0);
        assert_eq!(stats.total, 6 * (n as u64 - 1));
        for (i, (q, k, v)) in data.iter().enumerate() {
            let want = flashd::attention(q, k, v, n, d, 0.5);
            assert_eq!(outs[i], want, "row {i}");
        }
    }

    #[test]
    fn empty_and_single_job() {
        let cfg = KernelConfig::default();
        let (outs, stats) = run_rows(&cfg, &[]);
        assert!(outs.is_empty());
        assert_eq!(stats.total, 0);

        let data = jobs_fixture(3, 1, 9, 8);
        let jobs = as_jobs(&data, 9, 8);
        let (outs, _) = run_rows(&cfg, &jobs);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), 8);
    }

    #[test]
    fn causal_heads_matches_manual_rows() {
        let (l, d) = (12usize, 8usize);
        let mut rng = Rng::new(4);
        let heads: Vec<_> = (0..3)
            .map(|_| {
                (
                    rng.normal_vec(l * d, 0.7),
                    rng.normal_vec(l * d, 0.7),
                    rng.normal_vec(l * d, 1.0),
                )
            })
            .collect();
        let cfg = KernelConfig {
            tile: 4,
            threads: 2,
            block_q: 5,
            skip: SkipCriterion::Static,
            ..KernelConfig::default()
        };
        let (outs, stats) = run_causal_heads(&cfg, &heads, l, d, 0.35);
        assert_eq!(outs.len(), 3 * l * d);
        // rows per head: each row r contributes r weight-update steps
        assert_eq!(stats.total, 3 * (l as u64) * (l as u64 - 1) / 2);
        for (h, (qh, kh, vh)) in heads.iter().enumerate() {
            for r in 0..l {
                let (want, _) = tiled::attention_tiled_instrumented(
                    &qh[r * d..(r + 1) * d],
                    &kh[..(r + 1) * d],
                    &vh[..(r + 1) * d],
                    r + 1,
                    d,
                    0.35,
                    4,
                    SkipCriterion::Static,
                );
                let got = &outs[(h * l + r) * d..(h * l + r + 1) * d];
                assert_eq!(got, &want[..], "head {h} row {r}");
            }
        }
    }

    #[test]
    fn run_rows_into_matches_run_rows() {
        let (n, d) = (90usize, 16usize);
        let data = jobs_fixture(7, 9, n, d);
        let jobs = as_jobs(&data, n, d);
        for threads in [1usize, 3, 8] {
            let cfg = KernelConfig {
                tile: 16,
                threads,
                skip: SkipCriterion::Static,
                ..KernelConfig::default()
            };
            let (vec_outs, vec_st) = run_rows(&cfg, &jobs);
            let mut flat = vec![0.0f32; jobs.len() * d];
            let flat_st = run_rows_into(&cfg, &jobs, d, &mut flat);
            assert_eq!(flat_st, vec_st, "threads={threads}");
            assert_eq!(flat, vec_outs.concat(), "threads={threads}");
        }
        // empty input
        let mut empty: Vec<f32> = Vec::new();
        let st = run_rows_into(&KernelConfig::default(), &[], d, &mut empty);
        assert_eq!(st.total, 0);
    }

    #[test]
    fn grouping_coalesces_shared_and_causal_runs() {
        let (n, d) = (40usize, 8usize);
        let mut rng = Rng::new(11);
        let k = rng.normal_vec(n * d, 0.8);
        let v = rng.normal_vec(n * d, 1.0);
        let q = rng.normal_vec(10 * d, 0.8);
        // 6 rows sharing the full KV, then 4 causal staircase rows
        let mut jobs: Vec<RowJob> = (0..6)
            .map(|i| RowJob { q: &q[i * d..(i + 1) * d], k: &k, v: &v, n, d, scale: 0.5 })
            .collect();
        for (j, i) in (6..10).enumerate() {
            let nn = 20 + j;
            jobs.push(RowJob {
                q: &q[i * d..(i + 1) * d],
                k: &k[..nn * d],
                v: &v[..nn * d],
                n: nn,
                d,
                scale: 0.5,
            });
        }
        let items = coalesce(&jobs, 16);
        assert_eq!(items.len(), 2, "expected one shared + one causal block");
        assert!(!items[0].causal && items[0].nq == 6 && items[0].n == n);
        assert!(items[1].causal && items[1].nq == 4 && items[1].n == 23);
        // block_q caps group length
        let items4 = coalesce(&jobs, 4);
        assert_eq!(items4.iter().map(|it| it.nq).sum::<usize>(), 10);
        assert!(items4.iter().all(|it| it.nq <= 4));
        // and the grouped driver still matches the per-row kernel bitwise
        let cfg = KernelConfig { tile: 8, threads: 2, ..KernelConfig::default() };
        let (outs, _) = run_rows(&cfg, &jobs);
        for (i, j) in jobs.iter().enumerate() {
            let want = tiled::attention_tiled(j.q, j.k, j.v, j.n, j.d, j.scale, 8);
            assert_eq!(outs[i], want, "row {i}");
        }
    }

    #[test]
    fn run_blocks_matches_rows_and_splits_oversize() {
        let (nq, n, d) = (23usize, 64usize, 16usize);
        let mut rng = Rng::new(12);
        let q = rng.normal_vec(nq * d, 0.8);
        let k = rng.normal_vec(n * d, 0.8);
        let v = rng.normal_vec(n * d, 1.0);
        let block = BlockJob { q: &q, k: &k, v: &v, nq, n, d, scale: 0.4, causal: false };
        for threads in [1usize, 4] {
            let cfg = KernelConfig {
                tile: 16,
                block_q: 8,
                threads,
                skip: SkipCriterion::Static,
                ..KernelConfig::default()
            };
            let mut flat = vec![0.0f32; nq * d];
            let st = run_blocks_into(&cfg, &[block], d, &mut flat);
            let (vecs, vst) = run_blocks(&cfg, &[block]);
            assert_eq!(flat, vecs.concat(), "threads={threads}");
            assert_eq!(st, vst, "threads={threads}");
            let mut want_st = SkipStats::default();
            for iq in 0..nq {
                let (want, wst) = tiled::attention_tiled_instrumented(
                    &q[iq * d..(iq + 1) * d],
                    &k,
                    &v,
                    n,
                    d,
                    0.4,
                    16,
                    SkipCriterion::Static,
                );
                assert_eq!(&flat[iq * d..(iq + 1) * d], &want[..], "query {iq}");
                want_st.merge(&wst);
            }
            assert_eq!(st, want_st, "threads={threads}");
        }
    }

    #[test]
    fn mixed_d_flat_blocks_match_per_block_runs() {
        // Two different head dims in one submission — the fused serving
        // shape. Each block's slice of the flat output must equal a
        // standalone uniform-d run of that block, for every thread count.
        let mut rng = Rng::new(21);
        let qa = rng.normal_vec(3 * 8, 0.8);
        let ka = rng.normal_vec(33 * 8, 0.8);
        let va = rng.normal_vec(33 * 8, 1.0);
        let qb = rng.normal_vec(5 * 16, 0.8);
        let kb = rng.normal_vec(17 * 16, 0.8);
        let vb = rng.normal_vec(17 * 16, 1.0);
        let ba = BlockJob { q: &qa, k: &ka, v: &va, nq: 3, n: 33, d: 8, scale: 0.5, causal: false };
        let bb = BlockJob { q: &qb, k: &kb, v: &vb, nq: 5, n: 17, d: 16, scale: 0.3, causal: false };
        for threads in [1usize, 4] {
            let cfg = KernelConfig {
                tile: 8,
                block_q: 2,
                threads,
                skip: SkipCriterion::Static,
                ..KernelConfig::default()
            };
            let mut flat = vec![0.0f32; 3 * 8 + 5 * 16];
            let st = run_blocks_flat_into_with(&cfg, &[ba, bb], &mut flat, &mut BatchScratch::new());
            let mut wa = vec![0.0f32; 3 * 8];
            let sa = run_blocks_into(&cfg, &[ba], 8, &mut wa);
            let mut wb = vec![0.0f32; 5 * 16];
            let sb = run_blocks_into(&cfg, &[bb], 16, &mut wb);
            assert_eq!(&flat[..3 * 8], &wa[..], "threads={threads}");
            assert_eq!(&flat[3 * 8..], &wb[..], "threads={threads}");
            let mut want_st = sa;
            want_st.merge(&sb);
            assert_eq!(st, want_st, "threads={threads}");
        }
    }

    #[test]
    fn partition_by_cost_is_exact_and_balanced() {
        // covers every job exactly once
        let costs: Vec<usize> = (1..=40).collect(); // linearly growing (causal shape)
        for workers in [1usize, 2, 3, 4, 8] {
            let takes = partition_by_cost(&costs, workers);
            assert!(takes.len() <= workers);
            assert_eq!(takes.iter().sum::<usize>(), costs.len(), "workers={workers}");
            assert!(takes.iter().all(|&t| t > 0));
            // balance: no chunk carries more than ~1.6x the ideal share
            let total: usize = costs.iter().sum();
            let ideal = total as f64 / workers as f64;
            let mut idx = 0;
            for &t in &takes {
                let c: usize = costs[idx..idx + t].iter().sum();
                idx += t;
                assert!(
                    (c as f64) < 1.6 * ideal + *costs.iter().max().unwrap() as f64,
                    "workers={workers}: chunk cost {c} vs ideal {ideal}"
                );
            }
        }
        // degenerate inputs
        assert_eq!(partition_by_cost(&[], 4), Vec::<usize>::new());
        assert_eq!(partition_by_cost(&[0, 0, 0], 2).iter().sum::<usize>(), 3);
        assert_eq!(partition_by_cost(&[5], 8), vec![1]);
    }

    #[test]
    fn causal_item_cost_is_exact_series_sum() {
        let it = Item {
            q: None,
            row0: 0,
            k: KvView::Contig(KvRef::F32(&[])),
            v: KvView::Contig(KvRef::F32(&[])),
            nq: 4,
            n: 10,
            d: 2,
            scale: 1.0,
            causal: true,
        };
        // lengths 7, 8, 9, 10 -> 34 rows * d=2
        assert_eq!(it.cost(), 34 * 2);
        let sh = Item { causal: false, ..it };
        assert_eq!(sh.cost(), 4 * 10 * 2);
    }

    #[test]
    fn default_config_is_sane() {
        let cfg = KernelConfig::default();
        assert!(cfg.tile >= 1);
        assert!(cfg.block_q >= 1);
        assert!(cfg.threads >= 1 && cfg.threads <= 8);
        assert_eq!(cfg.skip, SkipCriterion::None);
        assert_eq!(cfg.sigmoid, SigmoidMode::Exact);
        assert_eq!(cfg.kv_precision, KvPrecision::F32);
    }

    #[test]
    fn kv_rows_f32_bitmatch_plain_rows() {
        let (n, d) = (130usize, 16usize);
        let data = jobs_fixture(31, 7, n, d);
        let jobs = as_jobs(&data, n, d);
        let kv_jobs: Vec<KvRowJob> = data
            .iter()
            .map(|(q, k, v)| KvRowJob {
                q,
                k: KvView::Contig(KvRef::F32(k.as_slice())),
                v: KvView::Contig(KvRef::F32(v.as_slice())),
                n,
                d,
                scale: 0.5,
            })
            .collect();
        for threads in [1usize, 3] {
            let cfg = KernelConfig {
                tile: 16,
                threads,
                skip: SkipCriterion::Static,
                ..KernelConfig::default()
            };
            let mut want = vec![0.0f32; jobs.len() * d];
            let want_st = run_rows_into(&cfg, &jobs, d, &mut want);
            let mut got = vec![0.0f32; jobs.len() * d];
            let got_st =
                run_kv_rows_into_with(&cfg, &kv_jobs, d, &mut got, &mut BatchScratch::new());
            assert_eq!(got, want, "threads={threads}");
            assert_eq!(got_st, want_st, "threads={threads}");
        }
    }

    #[test]
    fn kv_rows_quantized_match_dequantized_f32_run() {
        use crate::numerics::quant::{quantize_bf16, quantize_fp8};
        let (n, d) = (90usize, 8usize);
        let data = jobs_fixture(32, 5, n, d);
        let kq: Vec<Vec<u16>> = data.iter().map(|(_, k, _)| quantize_bf16(k)).collect();
        let vq: Vec<Vec<u8>> = data.iter().map(|(_, _, v)| quantize_fp8(v)).collect();
        let cfg = KernelConfig {
            tile: 16,
            threads: 2,
            skip: SkipCriterion::Static,
            ..KernelConfig::default()
        };
        let kv_jobs: Vec<KvRowJob> = data
            .iter()
            .zip(kq.iter().zip(&vq))
            .map(|((q, _, _), (kb, vb))| KvRowJob {
                q,
                k: KvView::Contig(KvRef::Bf16(kb.as_slice())),
                v: KvView::Contig(KvRef::Fp8(vb.as_slice())),
                n,
                d,
                scale: 0.5,
            })
            .collect();
        let mut got = vec![0.0f32; data.len() * d];
        let got_st = run_kv_rows_into_with(&cfg, &kv_jobs, d, &mut got, &mut BatchScratch::new());
        // reference: the plain f32 driver over the dequantized arrays
        let kd: Vec<Vec<f32>> = kv_jobs.iter().map(|j| j.k.to_f32_vec()).collect();
        let vd: Vec<Vec<f32>> = kv_jobs.iter().map(|j| j.v.to_f32_vec()).collect();
        let ref_jobs: Vec<RowJob> = data
            .iter()
            .zip(kd.iter().zip(&vd))
            .map(|((q, _, _), (k, v))| RowJob { q, k, v, n, d, scale: 0.5 })
            .collect();
        let mut want = vec![0.0f32; data.len() * d];
        let want_st = run_rows_into(&cfg, &ref_jobs, d, &mut want);
        assert_eq!(got, want);
        assert_eq!(got_st, want_st);
    }

    #[test]
    fn kv_blocks_f32_bitmatch_plain_blocks_and_pwl_stays_close() {
        let (nq, n, d) = (6usize, 70usize, 8usize);
        let mut rng = Rng::new(33);
        let q = rng.normal_vec(nq * d, 0.8);
        let k = rng.normal_vec(n * d, 0.8);
        let v = rng.normal_vec(n * d, 1.0);
        let fb = BlockJob { q: &q, k: &k, v: &v, nq, n, d, scale: 0.4, causal: true };
        let kb = KvBlockJob::from(&fb);
        let cfg = KernelConfig {
            tile: 8,
            block_q: 4,
            threads: 2,
            skip: SkipCriterion::Static,
            ..KernelConfig::default()
        };
        let mut want = vec![0.0f32; nq * d];
        let want_st = run_blocks_into(&cfg, &[fb], d, &mut want);
        let mut got = vec![0.0f32; nq * d];
        let got_st = run_kv_blocks_flat_into_with(&cfg, &[kb], &mut got, &mut BatchScratch::new());
        assert_eq!(got, want);
        assert_eq!(got_st, want_st);
        // PWL sigmoid mode: not bit-identical, but within a loose envelope
        // (per-step table error is damped by the convex output recursion).
        let pwl_cfg = KernelConfig { sigmoid: SigmoidMode::Pwl { segments: 8 }, ..cfg };
        let mut pwl = vec![0.0f32; nq * d];
        run_kv_blocks_flat_into_with(&pwl_cfg, &[kb], &mut pwl, &mut BatchScratch::new());
        let vmax = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in pwl.iter().zip(&want) {
            assert!((a - b).abs() <= 0.5 * vmax, "pwl={a} exact={b}");
        }
    }

    #[test]
    fn paged_blocks_bitmatch_contiguous_blocks() {
        // A fused submission over paged (block-pooled) KV must equal the
        // same submission over contiguous buffers bit for bit, for every
        // precision and thread count — including causal staircases whose
        // per-query lengths truncate mid-block.
        use crate::numerics::quant::{quantize_bf16, quantize_fp8, PagedKv};
        let (nq, n, d) = (6usize, 70usize, 8usize);
        let mut rng = Rng::new(41);
        let q = rng.normal_vec(nq * d, 0.8);
        let k = rng.normal_vec(n * d, 0.8);
        let v = rng.normal_vec(n * d, 1.0);
        let kb = quantize_bf16(&k);
        let v8 = quantize_fp8(&v);
        // 9-step blocks: misaligned with the 8-step kernel tile, with a
        // partial tail block
        let bs = 9 * d;
        for (kr, vr) in [(KvRef::F32(&k), KvRef::F32(&v)), (KvRef::Bf16(&kb), KvRef::Fp8(&v8))] {
            let kfr: Vec<KvRef> =
                (0..n * d).step_by(bs).map(|a| kr.slice(a, (a + bs).min(n * d))).collect();
            let vfr: Vec<KvRef> =
                (0..n * d).step_by(bs).map(|a| vr.slice(a, (a + bs).min(n * d))).collect();
            for causal in [false, true] {
                for threads in [1usize, 4] {
                    let cfg = KernelConfig {
                        tile: 8,
                        block_q: 4,
                        threads,
                        skip: SkipCriterion::Static,
                        ..KernelConfig::default()
                    };
                    let contig = KvBlockJob { q: &q, k: kr, v: vr, nq, n, d, scale: 0.4, causal };
                    let mut want = vec![0.0f32; nq * d];
                    let want_st = run_kv_blocks_flat_into_with(
                        &cfg,
                        &[contig],
                        &mut want,
                        &mut BatchScratch::new(),
                    );
                    let paged = PagedKvBlockJob {
                        q: &q,
                        k: KvView::Paged(PagedKv { blocks: &kfr, block_elems: bs, start: 0, len: n * d }),
                        v: KvView::Paged(PagedKv { blocks: &vfr, block_elems: bs, start: 0, len: n * d }),
                        nq,
                        n,
                        d,
                        scale: 0.4,
                        causal,
                    };
                    let mut got = vec![0.0f32; nq * d];
                    let got_st = run_paged_kv_blocks_flat_into_with(
                        &cfg,
                        &[paged],
                        &mut got,
                        &mut BatchScratch::new(),
                    );
                    assert_eq!(got, want, "causal={causal} threads={threads} {:?}", kr.precision());
                    assert_eq!(got_st, want_st, "causal={causal} threads={threads}");
                }
            }
        }
    }
}
