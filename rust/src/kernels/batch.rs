//! Batched multi-query/multi-head attention driver over the tiled FLASH-D
//! kernel.
//!
//! A forward pass (or a serving batch) decomposes into many *independent*
//! attention rows — one per (layer, head, query). [`run_rows`] partitions a
//! flat list of such rows into contiguous chunks and executes them on
//! `std::thread::scope` workers:
//!
//! * **Deterministic output ordering** — worker `w` owns jobs
//!   `[w*chunk, (w+1)*chunk)` and writes each result into the output slot
//!   of the same index (disjoint `split_at_mut` regions, no locks), so the
//!   result is bitwise identical for every thread count.
//! * **Exact skip accounting** — each worker fills its own
//!   [`SkipStats`]; the parts are merged in worker order afterwards
//!   (u64 sums, order-independent anyway).
//! * **Small-problem guard** — thread spawning is skipped when the total
//!   work is too small to amortize it, so single-token decode steps don't
//!   pay ~10 µs of spawn latency per layer.
//!
//! [`KernelConfig`] bundles the three knobs every caller threads through:
//! KV tile length, worker count, and the skip criterion.

use super::flashd::{SkipCriterion, SkipStats};
use super::tiled::{self, DEFAULT_TILE};

/// Tuning knobs for the tiled/batched kernel engine, threaded through
/// `model::engine`, `model::decode`, and `coordinator::server`.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct KernelConfig {
    /// KV tile length (keys per block) for the tiled kernel.
    pub tile: usize,
    /// Maximum worker threads for [`run_rows`] (1 = fully serial).
    pub threads: usize,
    /// Saturation-skip criterion applied per row.
    pub skip: SkipCriterion,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            tile: DEFAULT_TILE,
            threads: default_threads(),
            skip: SkipCriterion::None,
        }
    }
}

/// Default worker count: the machine's parallelism, capped so tiny models
/// don't drown in spawn overhead.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// One independent attention row: a single query over an `(n, d)` KV
/// prefix. All slices borrow from the caller.
#[derive(Copy, Clone, Debug)]
pub struct RowJob<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub n: usize,
    pub d: usize,
    pub scale: f32,
}

/// Minimum per-thread work (in `n * d` multiply-accumulate units) before a
/// worker thread is worth spawning.
const MIN_WORK_PER_THREAD: usize = 1 << 15;

/// Contiguous cost-balanced partition: returns per-worker chunk lengths
/// (summing to `costs.len()`). Each worker takes jobs until it reaches an
/// even share of the remaining cost — important for causal workloads,
/// where job cost grows linearly with row index and equal-count chunks
/// would leave the tail worker with ~2x the mean work. Deterministic in
/// `(costs, workers)`.
fn partition_by_cost(costs: &[usize], workers: usize) -> Vec<usize> {
    let total: usize = costs.iter().sum();
    let mut takes = Vec::with_capacity(workers);
    let mut idx = 0usize;
    let mut spent = 0usize;
    for w in 0..workers {
        if idx >= costs.len() {
            break;
        }
        let left = workers - w;
        if left == 1 {
            takes.push(costs.len() - idx);
            idx = costs.len();
            break;
        }
        let target = (total - spent).div_ceil(left);
        let mut take = 0usize;
        let mut cost = 0usize;
        while idx + take < costs.len() && (take == 0 || cost < target) {
            cost += costs[idx + take];
            take += 1;
        }
        idx += take;
        spent += cost;
        takes.push(take);
    }
    takes
}

fn run_chunk(cfg: &KernelConfig, jobs: &[RowJob<'_>], out: &mut [Vec<f32>], stats: &mut SkipStats) {
    for (slot, job) in out.iter_mut().zip(jobs) {
        let (o, st) = tiled::attention_tiled_instrumented(
            job.q, job.k, job.v, job.n, job.d, job.scale, cfg.tile, cfg.skip,
        );
        stats.merge(&st);
        *slot = o;
    }
}

fn run_chunk_into(cfg: &KernelConfig, jobs: &[RowJob<'_>], d: usize, out: &mut [f32], stats: &mut SkipStats) {
    for (slot, job) in out.chunks_exact_mut(d).zip(jobs) {
        let st = tiled::attention_tiled_into(
            job.q, job.k, job.v, job.n, job.d, job.scale, cfg.tile, cfg.skip, slot,
        );
        stats.merge(&st);
    }
}

/// Shared driver: size the worker pool from total work, partition jobs into
/// contiguous cost-balanced chunks, and run `chunk_fn` on each chunk with
/// its `take * per` output slots, serially or on scoped threads. All
/// decisions depend only on `(cfg, jobs)`, so results are bitwise identical
/// for every thread count.
fn run_partitioned<'j, T, F>(
    cfg: &KernelConfig,
    jobs: &[RowJob<'j>],
    out: &mut [T],
    per: usize,
    chunk_fn: F,
) -> SkipStats
where
    T: Send,
    F: Fn(&[RowJob<'j>], &mut [T], &mut SkipStats) + Sync,
{
    let mut stats = SkipStats::default();
    if jobs.is_empty() {
        return stats;
    }

    let work: usize = jobs.iter().map(|j| j.n * j.d).sum();
    let by_work = (work / MIN_WORK_PER_THREAD).max(1);
    let threads = cfg.threads.max(1).min(jobs.len()).min(by_work);

    if threads <= 1 {
        chunk_fn(jobs, out, &mut stats);
        return stats;
    }

    let costs: Vec<usize> = jobs.iter().map(|j| j.n * j.d).collect();
    let takes = partition_by_cost(&costs, threads);
    let mut stat_parts = vec![SkipStats::default(); takes.len()];
    std::thread::scope(|scope| {
        let chunk_fn = &chunk_fn;
        let mut rem_jobs = jobs;
        let mut rem_out = out;
        for (part, &take) in stat_parts.iter_mut().zip(&takes) {
            let (job_chunk, jobs_rest) = rem_jobs.split_at(take);
            let (out_chunk, out_rest) = rem_out.split_at_mut(take * per);
            rem_jobs = jobs_rest;
            rem_out = out_rest;
            scope.spawn(move || chunk_fn(job_chunk, out_chunk, part));
        }
    });
    for part in &stat_parts {
        stats.merge(part);
    }
    stats
}

/// Execute every job and return `(outputs, stats)`, with `outputs[i]` the
/// result of `jobs[i]`. Bitwise identical for every `cfg.threads` value.
pub fn run_rows(cfg: &KernelConfig, jobs: &[RowJob<'_>]) -> (Vec<Vec<f32>>, SkipStats) {
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); jobs.len()];
    let stats = run_partitioned(cfg, jobs, &mut outputs, 1, |jc, oc, st| {
        run_chunk(cfg, jc, oc, st)
    });
    (outputs, stats)
}

/// Flat-output variant of [`run_rows`] for the uniform-`d` hot paths
/// (decode steps, serving blocks, per-layer forward): writes job `i`'s
/// output row into `out[i * d..(i + 1) * d]` with no per-row allocation.
/// Same determinism guarantee as [`run_rows`].
pub fn run_rows_into(cfg: &KernelConfig, jobs: &[RowJob<'_>], d: usize, out: &mut [f32]) -> SkipStats {
    assert_eq!(out.len(), jobs.len() * d, "output buffer must be jobs.len() * d");
    debug_assert!(jobs.iter().all(|j| j.d == d));
    run_partitioned(cfg, jobs, out, d, |jc, oc, st| {
        run_chunk_into(cfg, jc, d, oc, st)
    })
}

/// Causal per-head convenience: for each head buffer `(qh, kh, vh)` of `l`
/// rows × `d` columns, row `r` attends over the `r + 1` KV prefix. Returns
/// a flat output with row `(head * l + r)` at `[(head * l + r) * d..][..d]`
/// plus merged stats — the shape `model::engine::forward` consumes.
pub fn run_causal_heads(
    cfg: &KernelConfig,
    heads: &[(Vec<f32>, Vec<f32>, Vec<f32>)],
    l: usize,
    d: usize,
    scale: f32,
) -> (Vec<f32>, SkipStats) {
    let mut jobs = Vec::with_capacity(heads.len() * l);
    for (qh, kh, vh) in heads {
        for r in 0..l {
            jobs.push(RowJob {
                q: &qh[r * d..(r + 1) * d],
                k: &kh[..(r + 1) * d],
                v: &vh[..(r + 1) * d],
                n: r + 1,
                d,
                scale,
            });
        }
    }
    let mut out = vec![0.0f32; jobs.len() * d];
    let stats = run_rows_into(cfg, &jobs, d, &mut out);
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::flashd;
    use crate::util::rng::Rng;

    fn jobs_fixture(seed: u64, rows: usize, n: usize, d: usize) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        (0..rows)
            .map(|_| {
                (
                    rng.normal_vec(d, 0.8),
                    rng.normal_vec(n * d, 0.8),
                    rng.normal_vec(n * d, 1.0),
                )
            })
            .collect()
    }

    fn as_jobs<'a>(
        data: &'a [(Vec<f32>, Vec<f32>, Vec<f32>)],
        n: usize,
        d: usize,
    ) -> Vec<RowJob<'a>> {
        data.iter()
            .map(|(q, k, v)| RowJob { q, k, v, n, d, scale: 0.5 })
            .collect()
    }

    #[test]
    fn identical_across_thread_counts() {
        let (n, d) = (257usize, 32usize);
        let data = jobs_fixture(1, 13, n, d);
        let jobs = as_jobs(&data, n, d);
        let base_cfg = KernelConfig { tile: 16, threads: 1, skip: SkipCriterion::Static };
        let (want, want_st) = run_rows(&base_cfg, &jobs);
        for threads in [2usize, 3, 4, 8] {
            let cfg = KernelConfig { threads, ..base_cfg };
            let (got, got_st) = run_rows(&cfg, &jobs);
            assert_eq!(got, want, "threads={threads}");
            assert_eq!(got_st, want_st, "threads={threads}");
        }
    }

    #[test]
    fn matches_scalar_kernel_rowwise() {
        let (n, d) = (120usize, 16usize);
        let data = jobs_fixture(2, 6, n, d);
        let jobs = as_jobs(&data, n, d);
        let cfg = KernelConfig { tile: 32, threads: 4, skip: SkipCriterion::None };
        let (outs, stats) = run_rows(&cfg, &jobs);
        assert_eq!(stats.skipped(), 0);
        assert_eq!(stats.total, 6 * (n as u64 - 1));
        for (i, (q, k, v)) in data.iter().enumerate() {
            let want = flashd::attention(q, k, v, n, d, 0.5);
            assert_eq!(outs[i], want, "row {i}");
        }
    }

    #[test]
    fn empty_and_single_job() {
        let cfg = KernelConfig::default();
        let (outs, stats) = run_rows(&cfg, &[]);
        assert!(outs.is_empty());
        assert_eq!(stats.total, 0);

        let data = jobs_fixture(3, 1, 9, 8);
        let jobs = as_jobs(&data, 9, 8);
        let (outs, _) = run_rows(&cfg, &jobs);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), 8);
    }

    #[test]
    fn causal_heads_matches_manual_rows() {
        let (l, d) = (12usize, 8usize);
        let mut rng = Rng::new(4);
        let heads: Vec<_> = (0..3)
            .map(|_| {
                (
                    rng.normal_vec(l * d, 0.7),
                    rng.normal_vec(l * d, 0.7),
                    rng.normal_vec(l * d, 1.0),
                )
            })
            .collect();
        let cfg = KernelConfig { tile: 4, threads: 2, skip: SkipCriterion::Static };
        let (outs, stats) = run_causal_heads(&cfg, &heads, l, d, 0.35);
        assert_eq!(outs.len(), 3 * l * d);
        // rows per head: each row r contributes r weight-update steps
        assert_eq!(stats.total, 3 * (l as u64) * (l as u64 - 1) / 2);
        for (h, (qh, kh, vh)) in heads.iter().enumerate() {
            for r in 0..l {
                let (want, _) = tiled::attention_tiled_instrumented(
                    &qh[r * d..(r + 1) * d],
                    &kh[..(r + 1) * d],
                    &vh[..(r + 1) * d],
                    r + 1,
                    d,
                    0.35,
                    4,
                    SkipCriterion::Static,
                );
                let got = &outs[(h * l + r) * d..(h * l + r + 1) * d];
                assert_eq!(got, &want[..], "head {h} row {r}");
            }
        }
    }

    #[test]
    fn run_rows_into_matches_run_rows() {
        let (n, d) = (90usize, 16usize);
        let data = jobs_fixture(7, 9, n, d);
        let jobs = as_jobs(&data, n, d);
        for threads in [1usize, 3, 8] {
            let cfg = KernelConfig { tile: 16, threads, skip: SkipCriterion::Static };
            let (vec_outs, vec_st) = run_rows(&cfg, &jobs);
            let mut flat = vec![0.0f32; jobs.len() * d];
            let flat_st = run_rows_into(&cfg, &jobs, d, &mut flat);
            assert_eq!(flat_st, vec_st, "threads={threads}");
            assert_eq!(flat, vec_outs.concat(), "threads={threads}");
        }
        // empty input
        let mut empty: Vec<f32> = Vec::new();
        let st = run_rows_into(&KernelConfig::default(), &[], d, &mut empty);
        assert_eq!(st.total, 0);
    }

    #[test]
    fn partition_by_cost_is_exact_and_balanced() {
        // covers every job exactly once
        let costs: Vec<usize> = (1..=40).collect(); // linearly growing (causal shape)
        for workers in [1usize, 2, 3, 4, 8] {
            let takes = partition_by_cost(&costs, workers);
            assert!(takes.len() <= workers);
            assert_eq!(takes.iter().sum::<usize>(), costs.len(), "workers={workers}");
            assert!(takes.iter().all(|&t| t > 0));
            // balance: no chunk carries more than ~1.6x the ideal share
            let total: usize = costs.iter().sum();
            let ideal = total as f64 / workers as f64;
            let mut idx = 0;
            for &t in &takes {
                let c: usize = costs[idx..idx + t].iter().sum();
                idx += t;
                assert!(
                    (c as f64) < 1.6 * ideal + *costs.iter().max().unwrap() as f64,
                    "workers={workers}: chunk cost {c} vs ideal {ideal}"
                );
            }
        }
        // degenerate inputs
        assert_eq!(partition_by_cost(&[], 4), Vec::<usize>::new());
        assert_eq!(partition_by_cost(&[0, 0, 0], 2).iter().sum::<usize>(), 3);
        assert_eq!(partition_by_cost(&[5], 8), vec![1]);
    }

    #[test]
    fn default_config_is_sane() {
        let cfg = KernelConfig::default();
        assert!(cfg.tile >= 1);
        assert!(cfg.threads >= 1 && cfg.threads <= 8);
        assert_eq!(cfg.skip, SkipCriterion::None);
    }
}
