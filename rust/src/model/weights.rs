//! FDW1 binary weight files — the flat-tensor ABI shared with
//! `python/compile/aot.py::write_fdw`.
//!
//! layout:  b"FDW1" | u32 n | n x ( u16 name_len | name | u8 ndim |
//!          ndim x u32 dim | f32-LE data )

use anyhow::{anyhow, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A named f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NamedTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Read an FDW1 file.
pub fn read_fdw(path: impl AsRef<Path>) -> Result<Vec<NamedTensor>> {
    let mut f = std::fs::File::open(path.as_ref())
        .map_err(|e| anyhow!("open {}: {e}", path.as_ref().display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_fdw(&buf)
}

pub fn parse_fdw(buf: &[u8]) -> Result<Vec<NamedTensor>> {
    if buf.len() < 8 || &buf[0..4] != b"FDW1" {
        return Err(anyhow!("not an FDW1 file"));
    }
    let n = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    let mut off = 8usize;
    let mut out = Vec::with_capacity(n);
    let need = |off: usize, len: usize, total: usize| -> Result<()> {
        if off + len > total {
            Err(anyhow!("truncated FDW1 at byte {off}"))
        } else {
            Ok(())
        }
    };
    for _ in 0..n {
        need(off, 2, buf.len())?;
        let nl = u16::from_le_bytes(buf[off..off + 2].try_into().unwrap()) as usize;
        off += 2;
        need(off, nl, buf.len())?;
        let name = String::from_utf8(buf[off..off + nl].to_vec())
            .map_err(|_| anyhow!("bad tensor name"))?;
        off += nl;
        need(off, 1, buf.len())?;
        let ndim = buf[off] as usize;
        off += 1;
        need(off, 4 * ndim, buf.len())?;
        let mut shape = Vec::with_capacity(ndim);
        for i in 0..ndim {
            shape.push(u32::from_le_bytes(buf[off + 4 * i..off + 4 * i + 4].try_into().unwrap()) as usize);
        }
        off += 4 * ndim;
        let cnt: usize = if ndim == 0 { 1 } else { shape.iter().product() };
        need(off, 4 * cnt, buf.len())?;
        let mut data = Vec::with_capacity(cnt);
        for i in 0..cnt {
            data.push(f32::from_le_bytes(buf[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
        }
        off += 4 * cnt;
        out.push(NamedTensor { name, shape, data });
    }
    if off != buf.len() {
        return Err(anyhow!("trailing bytes in FDW1 file"));
    }
    Ok(out)
}

/// Write an FDW1 file.
pub fn write_fdw(path: impl AsRef<Path>, tensors: &[NamedTensor]) -> Result<()> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(b"FDW1");
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        if t.numel() != t.data.len() {
            return Err(anyhow!("tensor {}: shape/data mismatch", t.name));
        }
        let nb = t.name.as_bytes();
        out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        out.extend_from_slice(nb);
        out.push(t.shape.len() as u8);
        for d in &t.shape {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        for x in &t.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut f = std::fs::File::create(path.as_ref())
        .map_err(|e| anyhow!("create {}: {e}", path.as_ref().display()))?;
    f.write_all(&out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tensors = vec![
            NamedTensor { name: "a".into(), shape: vec![2, 3], data: (0..6).map(|x| x as f32).collect() },
            NamedTensor { name: "l0.wq".into(), shape: vec![4], data: vec![1.5, -2.5, 0.0, 3.25] },
        ];
        let dir = std::env::temp_dir().join("flashd_fdw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.fdw");
        write_fdw(&path, &tensors).unwrap();
        let back = read_fdw(&path).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_fdw(b"NOPE").is_err());
        assert!(parse_fdw(b"FDW1\x01\x00\x00\x00").is_err()); // truncated
        // trailing bytes
        let tensors = vec![NamedTensor { name: "x".into(), shape: vec![1], data: vec![1.0] }];
        let dir = std::env::temp_dir().join("flashd_fdw_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.fdw");
        write_fdw(&path, &tensors).unwrap();
        let mut buf = std::fs::read(&path).unwrap();
        buf.push(0);
        assert!(parse_fdw(&buf).is_err());
    }

    #[test]
    fn shape_data_mismatch_rejected_on_write() {
        let t = NamedTensor { name: "bad".into(), shape: vec![3], data: vec![1.0] };
        let path = std::env::temp_dir().join("flashd_fdw_bad.fdw");
        assert!(write_fdw(path, &[t]).is_err());
    }

    /// The python-written init weights parse (when artifacts exist).
    #[test]
    fn reads_python_written_file() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let path = dir.join("init_phi-tiny.fdw");
        if !path.exists() {
            return;
        }
        let tensors = read_fdw(&path).unwrap();
        assert!(!tensors.is_empty());
        assert_eq!(tensors[0].name, "tok_emb");
        assert_eq!(tensors[0].shape, vec![256, 128]);
        assert!(tensors.iter().all(|t| t.numel() == t.data.len()));
    }
}
