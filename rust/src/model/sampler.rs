//! Sampling strategies over engine logits.

use crate::util::rng::Rng;

/// Pick the argmax token.
pub fn greedy(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Temperature sampling (temperature 0 degrades to greedy).
pub fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> i32 {
    if temperature <= 1e-6 {
        return greedy(logits);
    }
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| ((x as f64 - m) / temperature).exp())
        .collect();
    rng.categorical(&weights) as i32
}

/// Top-k filtering + temperature sampling.
pub fn sample_topk(logits: &[f32], k: usize, temperature: f64, rng: &mut Rng) -> i32 {
    if k == 0 || k >= logits.len() {
        return sample(logits, temperature, rng);
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    let keep = &idx[..k];
    let m = logits[keep[0]] as f64;
    let weights: Vec<f64> = keep
        .iter()
        .map(|&i| ((logits[i] as f64 - m) / temperature.max(1e-6)).exp())
        .collect();
    keep[rng.categorical(&weights)] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(greedy(&[5.0]), 0);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.0, 9.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sampling_respects_distribution() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 2.0, 0.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[sample(&logits, 1.0, &mut rng) as usize] += 1;
        }
        assert!(counts[1] > counts[0] * 3);
        assert!(counts[0] > 0 && counts[2] > 0);
    }

    #[test]
    fn topk_excludes_tail() {
        let mut rng = Rng::new(3);
        let logits = [1.0f32, 0.9, -10.0, -11.0];
        for _ in 0..200 {
            let t = sample_topk(&logits, 2, 1.0, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }
}
