//! Byte-level tokenizer (vocab 256) — the zoo models are byte-level so no
//! external vocabulary files are needed; any UTF-8 text round-trips.

/// Stateless byte tokenizer.
#[derive(Copy, Clone, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    /// Decode, replacing invalid UTF-8 with the replacement character.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|&i| (i.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Encode into a fixed window: right-truncate, left-pad with spaces.
    pub fn encode_window(&self, text: &str, window: usize) -> Vec<i32> {
        let mut ids = self.encode(text);
        if ids.len() > window {
            ids.truncate(window);
        }
        while ids.len() < window {
            ids.insert(0, b' ' as i32);
        }
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer;
        let ids = t.encode("hello, FLASH-D!");
        assert_eq!(t.decode(&ids), "hello, FLASH-D!");
        assert!(ids.iter().all(|&i| (0..256).contains(&i)));
    }

    #[test]
    fn window_pads_and_truncates() {
        let t = ByteTokenizer;
        let w = t.encode_window("abc", 5);
        assert_eq!(w.len(), 5);
        assert_eq!(&w[2..], &[97, 98, 99]);
        assert_eq!(w[0], 32);
        let w = t.encode_window("abcdefgh", 4);
        assert_eq!(t.decode(&w), "abcd");
    }

    #[test]
    fn out_of_range_ids_clamped() {
        let t = ByteTokenizer;
        // 300 clamps to byte 0xFF (invalid UTF-8 alone -> replacement char),
        // -5 clamps to 0, 65 is 'A'.
        assert_eq!(t.decode(&[300, -5, 65]), "\u{fffd}\0A");
    }
}
