//! KV-cached incremental decoding — the §Perf optimization of the engine
//! hot path. `Engine::greedy_decode` recomputes the full window forward for
//! every generated token (O(n · L · full-forward)); a [`DecodeSession`]
//! carries per-layer KV caches so each new token costs one projection set,
//! one FLASH-D attention *row* per head, and one MLP row.
//!
//! Numerically identical to the full forward (same FLASH-D recursion, same
//! QK-norm), verified in tests and in `EXPERIMENTS.md` §Perf.

use crate::coordinator::kv_cache::SessionStore;
use crate::kernels::batch::{self, BatchScratch, KernelConfig, KvRowJob};
use crate::model::engine::{Engine, ForwardStats};
use crate::numerics::quant::KvPrecision;

/// A streaming decode session over an [`Engine`].
///
/// KV rows live in a paged [`SessionStore`]: one single-head block chain
/// per `(layer, head)`, appended step by step and streamed to the kernels
/// through the block-table gather view. Rows are quantized once on append
/// at the session's [`KvPrecision`] and dequantized tile-by-tile; the
/// FLASH-D recursion itself stays f32, so the default `F32` precision is
/// bit-identical to an unquantized cache.
pub struct DecodeSession<'a> {
    engine: &'a Engine,
    /// Paged KV pool, unbounded budget (capacity is enforced by the
    /// positional window, not by eviction).
    kv: SessionStore,
    /// Sliding attention window: each step attends only the last
    /// `window` positions; fully out-of-window blocks are trimmed from
    /// the pool. `None` = full attention.
    window: Option<usize>,
    pub pos: usize,
    pub stats: ForwardStats,
    /// Effective kernel config, snapshotted from [`Engine::kernel_config`]
    /// (so its `skip` already carries the engine's criterion).
    kernel: KernelConfig,
    /// Session-owned kernel scratch: the kernel's score/state buffers are
    /// reused across every (layer, token) call instead of being
    /// reallocated per step.
    scratch: BatchScratch,
}

fn rms_inv(row: &[f32]) -> f32 {
    let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
    1.0 / (ms + 1e-6).sqrt()
}

fn vecmat(x: &[f32], w: &[f32], k: usize, n: usize) -> Vec<f32> {
    // (1,k) @ (k,n)
    let mut out = vec![0.0f32; n];
    for (kk, &xv) in x.iter().enumerate().take(k) {
        if xv == 0.0 {
            continue;
        }
        let row = &w[kk * n..(kk + 1) * n];
        for j in 0..n {
            out[j] += xv * row[j];
        }
    }
    out
}

/// Pool session id of one `(layer, head)` KV chain.
fn chain_id(layer: usize, head: usize, n_heads: usize) -> u64 {
    (layer * n_heads + head) as u64
}

impl<'a> DecodeSession<'a> {
    pub fn new(engine: &'a Engine) -> DecodeSession<'a> {
        DecodeSession::with_window(engine, None)
    }

    /// Like [`DecodeSession::new`], but each step attends only the last
    /// `window` positions. Fully out-of-window KV blocks are trimmed
    /// from the pool, so resident bytes stay bounded by the window (plus
    /// at most one block of slop per chain) no matter how long the
    /// generation runs. Positional capacity (`seq_len`) still bounds the
    /// total step count.
    pub fn with_window(engine: &'a Engine, window: Option<usize>) -> DecodeSession<'a> {
        assert!(window != Some(0), "sliding window must be >= 1");
        let kernel = engine.kernel_config();
        let nl = engine.info.n_layers;
        let nh = engine.info.n_heads;
        let dh = engine.info.d_head();
        let prec = kernel.kv_precision;
        // block size = one kernel tile, so the paged gather hands the
        // tiled drivers fragments they can stream without re-splitting
        let mut kv = SessionStore::with_block_steps(usize::MAX, prec, kernel.tile.max(1));
        for layer in 0..nl {
            for head in 0..nh {
                kv.create_windowed(chain_id(layer, head, nh), 1, dh, engine.info.seq_len, window)
                    .expect("valid window, unbounded pool rejects nothing");
            }
        }
        DecodeSession {
            engine,
            kv,
            window,
            pos: 0,
            stats: ForwardStats::default(),
            kernel,
            scratch: BatchScratch::new(),
        }
    }

    /// Storage precision of this session's KV caches.
    pub fn kv_precision(&self) -> KvPrecision {
        self.kernel.kv_precision
    }

    /// Resident pool bytes of the per-layer KV chains (block-granular:
    /// a partially filled tail block costs its full reservation).
    pub fn kv_bytes(&self) -> usize {
        self.kv.bytes()
    }

    /// Remaining capacity before the positional table runs out.
    pub fn remaining(&self) -> usize {
        self.engine.info.seq_len - self.pos
    }

    /// Feed one token; returns the logits row (vocab,) for predicting the
    /// next token.
    pub fn push_token(&mut self, token: i32) -> Vec<f32> {
        let info = &self.engine.info;
        assert!(self.pos < info.seq_len, "positional capacity exhausted");
        let dm = info.d_model;
        let nh = info.n_heads;
        let dh = info.d_head();
        let scale = info.qk_gain as f32 * (dh as f32).powf(-0.5);

        let tok_emb = &self.engine.param("tok_emb").data;
        let pos_emb = &self.engine.param("pos_emb").data;
        let t = token.clamp(0, info.vocab_size as i32 - 1) as usize;
        let mut x: Vec<f32> = (0..dm)
            .map(|j| tok_emb[t * dm + j] + pos_emb[self.pos * dm + j])
            .collect();

        for layer in 0..info.n_layers {
            let pfx = format!("l{layer}");
            // attention
            let g1 = &self.engine.param(&format!("{pfx}.ln1")).data;
            let inv = rms_inv(&x);
            let h: Vec<f32> = x.iter().zip(g1).map(|(v, g)| v * inv * g).collect();
            let q = vecmat(&h, &self.engine.param(&format!("{pfx}.wq")).data, dm, dm);
            let k = vecmat(&h, &self.engine.param(&format!("{pfx}.wk")).data, dm, dm);
            let v = vecmat(&h, &self.engine.param(&format!("{pfx}.wv")).data, dm, dm);

            let mut attn = vec![0.0f32; dm];
            // Append the new (normalized) K/V row per head into the block
            // pool, then run all heads' attention rows through the batched
            // tiled driver over the gathered paged views.
            let mut qhs: Vec<Vec<f32>> = Vec::with_capacity(nh);
            for head in 0..nh {
                let mut qh = q[head * dh..(head + 1) * dh].to_vec();
                let mut kh = k[head * dh..(head + 1) * dh].to_vec();
                // QK-norm on the new row only (cache already stores
                // normalized keys)
                let qi = rms_inv(&qh);
                qh.iter_mut().for_each(|v| *v *= qi);
                let ki = rms_inv(&kh);
                kh.iter_mut().for_each(|v| *v *= ki);

                self.kv
                    .append(chain_id(layer, head, nh), &kh, &v[head * dh..(head + 1) * dh], 1)
                    .expect("append within positional capacity");
                qhs.push(qh);
            }
            // Attended KV length this step: `min(pos + 1, window)`. The
            // gathered views hide the trimmed/slop prefix, so the kernel
            // streams exactly the in-window rows — the FLASH-D recursion
            // over that suffix IS the windowed answer, no rescaling
            // fix-up (asserted bit-exactly in the tests below).
            let n = self.window.map_or(self.pos + 1, |w| (self.pos + 1).min(w));
            let kcfg = self.kernel;
            // head-ordered jobs write straight into the (nh * dh) attention
            // row — no per-head output allocation, and the session-owned
            // scratch keeps the kernel's score/state buffers off the
            // per-step allocation path
            let st = {
                let ids: Vec<u64> = (0..nh).map(|head| chain_id(layer, head, nh)).collect();
                let views: Vec<_> = self
                    .kv
                    .gather_many(&ids)
                    .into_iter()
                    .map(|o| o.expect("decode chain exists"))
                    .collect();
                debug_assert!(views.iter().all(|p| p.len == n));
                let jobs: Vec<KvRowJob<'_>> = (0..nh)
                    .map(|head| KvRowJob {
                        q: &qhs[head],
                        k: views[head].head_k(0),
                        v: views[head].head_v(0),
                        n,
                        d: dh,
                        scale,
                    })
                    .collect();
                batch::run_kv_rows_into_with(&kcfg, &jobs, dh, &mut attn, &mut self.scratch)
            };
            self.stats.skip.merge(&st);
            self.stats.rows += nh as u64;
            let proj = vecmat(&attn, &self.engine.param(&format!("{pfx}.wo")).data, dm, dm);
            for j in 0..dm {
                x[j] += proj[j];
            }
            // MLP
            let g2 = &self.engine.param(&format!("{pfx}.ln2")).data;
            let inv = rms_inv(&x);
            let h2: Vec<f32> = x.iter().zip(g2).map(|(v, g)| v * inv * g).collect();
            let dff = info.d_ff;
            let mut gate = vecmat(&h2, &self.engine.param(&format!("{pfx}.w_gate")).data, dm, dff);
            let up = vecmat(&h2, &self.engine.param(&format!("{pfx}.w_up")).data, dm, dff);
            for j in 0..dff {
                let g = gate[j];
                gate[j] = g / (1.0 + (-g).exp()) * up[j];
            }
            let down = vecmat(&gate, &self.engine.param(&format!("{pfx}.w_down")).data, dff, dm);
            for j in 0..dm {
                x[j] += down[j];
            }
        }

        // final norm + tied logits
        let gf = &self.engine.param("ln_f").data;
        let inv = rms_inv(&x);
        let xf: Vec<f32> = x.iter().zip(gf).map(|(v, g)| v * inv * g).collect();
        let vocab = info.vocab_size;
        let mut logits = vec![0.0f32; vocab];
        for tt in 0..vocab {
            logits[tt] = crate::kernels::dot(&xf, &tok_emb[tt * dm..(tt + 1) * dm]);
        }
        self.pos += 1;
        logits
    }
}

impl Engine {
    /// Start a KV-cached decode session.
    pub fn start_session(&self) -> DecodeSession<'_> {
        DecodeSession::new(self)
    }

    /// Start a KV-cached decode session with a sliding attention window
    /// (see [`DecodeSession::with_window`]).
    pub fn start_windowed_session(&self, window: usize) -> DecodeSession<'_> {
        DecodeSession::with_window(self, Some(window))
    }

    /// Fast greedy decode via the KV cache (same function as
    /// [`Engine::greedy_decode`], ~O(window) faster per token).
    pub fn greedy_decode_fast(&self, prompt: &[i32], n: usize) -> (Vec<i32>, ForwardStats) {
        let mut toks = prompt.to_vec();
        let mut sess = self.start_session();
        let mut last_logits = Vec::new();
        // clamp prompt into the positional window (keep the tail)
        let start = toks.len().saturating_sub(self.info.seq_len);
        for &t in &toks[start..] {
            last_logits = sess.push_token(t);
        }
        for _ in 0..n {
            if sess.remaining() == 0 {
                break;
            }
            let next = crate::model::sampler::greedy(&last_logits);
            toks.push(next);
            last_logits = sess.push_token(next);
        }
        (toks, sess.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::test_support::tiny_engine;

    #[test]
    fn incremental_logits_match_full_forward() {
        let e = tiny_engine(21);
        let toks: Vec<i32> = (0..12).map(|i| (i * 5 + 2) % 32).collect();
        let (full, _) = e.forward(&toks);
        let v = e.info.vocab_size;
        let mut sess = e.start_session();
        for (i, &t) in toks.iter().enumerate() {
            let row = sess.push_token(t);
            let want = &full[i * v..(i + 1) * v];
            let diff = crate::kernels::max_abs_diff(&row, want);
            assert!(diff < 2e-4, "position {i}: {diff}");
        }
    }

    #[test]
    fn fast_greedy_matches_slow_greedy() {
        let e = tiny_engine(22);
        let prompt = [3i32, 1, 4, 1, 5];
        let (slow, _) = e.greedy_decode(&prompt, 8);
        let (fast, _) = e.greedy_decode_fast(&prompt, 8);
        assert_eq!(slow, fast);
    }

    #[test]
    fn skip_stats_accumulate() {
        let e = tiny_engine(23);
        let (_, stats) = e.greedy_decode_fast(&[1, 2, 3], 6);
        // rows = layers * heads * tokens_pushed
        assert_eq!(stats.rows, (2 * 2 * (3 + 6)) as u64);
    }

    #[test]
    fn quantized_session_stays_close_and_halves_bytes() {
        let toks: Vec<i32> = (0..10).map(|i| (i * 7 + 1) % 32).collect();
        let e32 = tiny_engine(25);
        let mut sess32 = e32.start_session();
        let mut last32 = Vec::new();
        for &t in &toks {
            last32 = sess32.push_token(t);
        }

        let mut e16 = tiny_engine(25);
        e16.configure(KernelConfig { kv_precision: KvPrecision::Bf16, ..e16.kernel_config() });
        let mut sess16 = e16.start_session();
        assert_eq!(sess16.kv_precision(), KvPrecision::Bf16);
        let mut last16 = Vec::new();
        for &t in &toks {
            last16 = sess16.push_token(t);
        }

        // bf16 storage perturbs K/V by <0.4% relative; after two layers the
        // logits stay well inside this envelope on the tiny model.
        let diff = crate::kernels::max_abs_diff(&last32, &last16);
        assert!(diff < 5e-2, "bf16 session drifted: {diff}");
        // same block count, half the bytes at rest (block-granular
        // accounting scales linearly with bytes-per-element)
        assert_eq!(sess16.kv_bytes() * 2, sess32.kv_bytes());

        let mut e8 = tiny_engine(25);
        e8.configure(KernelConfig { kv_precision: KvPrecision::Fp8, ..e8.kernel_config() });
        let mut sess8 = e8.start_session();
        for &t in &toks {
            sess8.push_token(t);
        }
        assert_eq!(sess8.kv_bytes() * 4, sess32.kv_bytes());
    }

    /// A window covering the whole positional capacity takes the windowed
    /// code path (attended-length n, slop arithmetic) but must stay
    /// *bit-identical* to the unwindowed session: the FLASH-D recursion
    /// over the in-window KV is the complete answer — no rescaling fix-up.
    #[test]
    fn window_covering_capacity_is_bit_identical() {
        let e = tiny_engine(26);
        let toks: Vec<i32> = (0..16).map(|i| (i * 11 + 3) % 32).collect();
        let mut full = e.start_session();
        let mut win = e.start_windowed_session(e.info.seq_len);
        for &t in &toks {
            let a = full.push_token(t);
            let b = win.push_token(t);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn sliding_window_trims_pool_and_restricts_attention() {
        let mut e = tiny_engine(27);
        // 4-step blocks so a 16-token generation crosses trim boundaries
        e.configure(KernelConfig { tile: 4, threads: 1, ..KernelConfig::default() });
        let toks: Vec<i32> = (0..16).map(|i| (i * 7 + 5) % 32).collect();
        let mut full = e.start_session();
        let mut win = e.start_windowed_session(8);
        let mut diverged = false;
        let mut last_w = Vec::new();
        for (i, &t) in toks.iter().enumerate() {
            let a = full.push_token(t);
            last_w = win.push_token(t);
            assert!(last_w.iter().all(|x| x.is_finite()));
            if i < 8 {
                assert_eq!(a, last_w, "inside the window the paths are identical");
            } else if crate::kernels::max_abs_diff(&a, &last_w) > 1e-6 {
                diverged = true;
            }
        }
        assert!(diverged, "a slid window must change late logits");
        assert!(win.kv_bytes() < full.kv_bytes(), "trim must bound resident bytes");

        // trim path (4-step blocks) vs pure-slop path (16-step blocks
        // never fill, prefix hidden by the view offset): same attended
        // rows, same recursion, same logits
        let mut e_big = tiny_engine(27);
        e_big.configure(KernelConfig { tile: 16, threads: 1, ..KernelConfig::default() });
        let mut slop = e_big.start_windowed_session(8);
        let mut last_s = Vec::new();
        for &t in &toks {
            last_s = slop.push_token(t);
        }
        let diff = crate::kernels::max_abs_diff(&last_w, &last_s);
        assert!(diff < 2e-4, "trim vs slop windowing drifted: {diff}");
    }

    #[test]
    fn capacity_guard() {
        let e = tiny_engine(24);
        let long: Vec<i32> = (0..e.info.seq_len as i32).collect();
        let (out, _) = e.greedy_decode_fast(&long, 10);
        // window full: no room to extend
        assert_eq!(out.len(), e.info.seq_len);
    }
}
