//! Pure-Rust transformer inference engine with instrumented FLASH-D
//! attention — the Table I measurement vehicle (the paper integrated its
//! kernel into HuggingFace models; we integrate ours into the models
//! trained end-to-end through the three-layer stack).
//!
//! The engine mirrors `python/compile/model.py` exactly (same parameter
//! ABI, RMSNorm/SwiGLU/tied-embedding architecture) so weights trained via
//! the AOT `train_step` artifact load directly.

pub mod decode;
pub mod engine;
pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use engine::{Engine, ForwardStats};
pub use tokenizer::ByteTokenizer;
pub use weights::{read_fdw, write_fdw, NamedTensor};
