//! The pure-Rust transformer forward pass with instrumented FLASH-D
//! attention. Mirrors `python/compile/model.py` exactly: same parameter
//! ABI (manifest `param_spec` order/names), RMSNorm(eps=1e-6), SwiGLU MLP,
//! learned positional embeddings, tied output embedding.
//!
//! Correctness is cross-validated against the AOT `model_fwd_*` artifact in
//! `rust/tests/e2e_runtime.rs` — the same weights must produce the same
//! logits through the PJRT path and through this engine.

use crate::kernels::batch::{self, KernelConfig};
use crate::kernels::flashd::{SigmoidMode, SkipCriterion, SkipStats};
use crate::kernels::AttnProblem;
use crate::numerics::quant::KvPrecision;
use crate::model::weights::NamedTensor;
use crate::runtime::ModelInfo;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Aggregated statistics from one forward pass.
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    /// FLASH-D skip statistics across all layers/heads/rows.
    pub skip: SkipStats,
    /// Total attention rows evaluated.
    pub rows: u64,
}

impl ForwardStats {
    pub fn merge(&mut self, other: &ForwardStats) {
        self.skip.merge(&other.skip);
        self.rows += other.rows;
    }
}

/// The inference engine for one zoo model.
pub struct Engine {
    pub info: ModelInfo,
    params: HashMap<String, NamedTensor>,
    /// Skip criterion applied by the instrumented attention — the single
    /// skip knob (the CLI, Table I harness, and tests set this; every
    /// other kernel knob lives behind [`Engine::configure`]).
    pub criterion: SkipCriterion,
    /// Tile/thread tuning for the batched kernel driver. Private so the
    /// engine has exactly one skip knob: `criterion` is substituted into
    /// the config by [`Engine::kernel_config`].
    kernel: KernelConfig,
}

fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

fn rmsnorm(x: &[f32], g: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for j in 0..d {
            out[r * d + j] = row[j] * inv * g[j];
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Gain-free RMS normalization of each row (QK-norm), in place.
fn qk_normalize(x: &mut [f32], rows: usize, d: usize) {
    for r in 0..rows {
        let row = &mut x[r * d..(r + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

impl Engine {
    /// Build from a model description + its weight tensors, verifying the
    /// parameter ABI.
    pub fn new(info: ModelInfo, tensors: Vec<NamedTensor>) -> Result<Engine> {
        if tensors.len() != info.param_spec.len() {
            return Err(anyhow!(
                "weight count {} != spec {}",
                tensors.len(),
                info.param_spec.len()
            ));
        }
        let mut params = HashMap::new();
        for (t, (name, shape)) in tensors.into_iter().zip(&info.param_spec) {
            if &t.name != name || &t.shape != shape {
                return Err(anyhow!(
                    "ABI mismatch: got {}{:?}, spec wants {}{:?}",
                    t.name, t.shape, name, shape
                ));
            }
            params.insert(t.name.clone(), t);
        }
        Ok(Engine {
            info,
            params,
            criterion: SkipCriterion::Static,
            kernel: KernelConfig::default(),
        })
    }

    /// The effective kernel configuration (tile/threads from the private
    /// tuning, skip from `criterion`).
    pub fn kernel_config(&self) -> KernelConfig {
        KernelConfig { skip: self.criterion, ..self.kernel }
    }

    /// Apply a complete kernel configuration in one call: tile/thread
    /// tuning, query block length, KV storage precision, sigmoid mode,
    /// and the skip criterion (`cfg.skip` becomes [`Engine::criterion`]).
    /// Replaces the former `set_kernel_tuning` / `set_query_block` /
    /// `set_kv_precision` / `set_sigmoid_mode` setter quartet, which now
    /// forward here.
    pub fn configure(&mut self, cfg: KernelConfig) {
        assert!(cfg.tile >= 1 && cfg.threads >= 1 && cfg.block_q >= 1);
        self.criterion = cfg.skip;
        self.kernel = cfg;
    }

    /// Tune the batched kernel driver (KV tile length and worker threads).
    #[deprecated(note = "use `Engine::configure` with a full `KernelConfig`")]
    pub fn set_kernel_tuning(&mut self, tile: usize, threads: usize) {
        self.configure(KernelConfig { tile, threads, ..self.kernel_config() });
    }

    /// Tune the query block length of the query-blocked kernel (how many
    /// queries share one KV-tile stream; 1 = per-query, the PR 1
    /// behavior). Results are bit-identical for every value.
    #[deprecated(note = "use `Engine::configure` with a full `KernelConfig`")]
    pub fn set_query_block(&mut self, block_q: usize) {
        self.configure(KernelConfig { block_q, ..self.kernel_config() });
    }

    /// Storage precision for KV caches opened by [`Engine::start_session`]
    /// (and honored by any layer that reads [`Engine::kernel_config`]).
    /// Quantization is storage-only: the FLASH-D recursion stays f32, so
    /// the default `F32` is bit-identical to the unquantized path.
    #[deprecated(note = "use `Engine::configure` with a full `KernelConfig`")]
    pub fn set_kv_precision(&mut self, precision: KvPrecision) {
        self.configure(KernelConfig { kv_precision: precision, ..self.kernel_config() });
    }

    /// Sigmoid evaluation mode for the attention kernels: exact `libm`
    /// transcendentals (default) or the piecewise-linear fast path of
    /// paper §IV-B (opt-in, bounded error).
    #[deprecated(note = "use `Engine::configure` with a full `KernelConfig`")]
    pub fn set_sigmoid_mode(&mut self, mode: SigmoidMode) {
        self.configure(KernelConfig { sigmoid: mode, ..self.kernel_config() });
    }

    /// Load a zoo model from the artifact directory (weights default to the
    /// trained file `weights_<name>.fdw` if present, else the init file).
    pub fn from_artifacts(dir: &std::path::Path, name: &str) -> Result<Engine> {
        let man = crate::runtime::Manifest::load(dir)?;
        let info = man
            .models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}'"))?
            .clone();
        let trained = dir.join(format!("weights_{name}.fdw"));
        let path = if trained.exists() { trained } else { dir.join(&info.init_weights) };
        let tensors = crate::model::weights::read_fdw(&path)?;
        Engine::new(info, tensors)
    }

    fn p(&self, name: &str) -> &NamedTensor {
        &self.params[name]
    }

    /// Parameter access for sibling modules (decode session).
    pub(crate) fn param(&self, name: &str) -> &NamedTensor {
        &self.params[name]
    }

    /// Forward pass: logits (L, vocab) for a token window (L <= seq_len).
    pub fn forward(&self, tokens: &[i32]) -> (Vec<f32>, ForwardStats) {
        let (logits, stats, _) = self.forward_inner(tokens, false);
        (logits, stats)
    }

    /// Forward pass that also captures per-layer/head attention problems
    /// (the stimulus source for the hardware power model).
    pub fn forward_capture(&self, tokens: &[i32]) -> (Vec<f32>, ForwardStats, Vec<AttnProblem>) {
        self.forward_inner(tokens, true)
    }

    fn forward_inner(&self, tokens: &[i32], capture: bool) -> (Vec<f32>, ForwardStats, Vec<AttnProblem>) {
        let info = &self.info;
        let l = tokens.len();
        assert!(l >= 1 && l <= info.seq_len, "window {l} vs seq_len {}", info.seq_len);
        let dm = info.d_model;
        let nh = info.n_heads;
        let dh = info.d_head();
        // QK-norm attention: score = qk_gain * (q^ . k^) / sqrt(dh)
        let scale = info.qk_gain as f32 * (dh as f32).powf(-0.5);

        let tok_emb = &self.p("tok_emb").data;
        let pos_emb = &self.p("pos_emb").data;
        let mut x = vec![0.0f32; l * dm];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t.clamp(0, info.vocab_size as i32 - 1) as usize;
            for j in 0..dm {
                x[i * dm + j] = tok_emb[t * dm + j] + pos_emb[i * dm + j];
            }
        }

        let mut stats = ForwardStats::default();
        let mut problems = Vec::new();

        for layer in 0..info.n_layers {
            let pfx = format!("l{layer}");
            // --- attention ---
            let h = rmsnorm(&x, &self.p(&format!("{pfx}.ln1")).data, l, dm);
            let q = matmul(&h, &self.p(&format!("{pfx}.wq")).data, l, dm, dm);
            let k = matmul(&h, &self.p(&format!("{pfx}.wk")).data, l, dm, dm);
            let v = matmul(&h, &self.p(&format!("{pfx}.wv")).data, l, dm, dm);
            let mut attn_out = vec![0.0f32; l * dm];
            // Split into contiguous (L, dh) per-head buffers, then submit
            // each head as one causal query block to the batched driver —
            // prefill KV tiles stream once per query block (not once per
            // row), and the work partitions across worker threads with
            // deterministic output ordering.
            let mut head_bufs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::with_capacity(nh);
            for head in 0..nh {
                let mut qh = vec![0.0f32; l * dh];
                let mut kh = vec![0.0f32; l * dh];
                let mut vh = vec![0.0f32; l * dh];
                for r in 0..l {
                    let src = r * dm + head * dh;
                    qh[r * dh..(r + 1) * dh].copy_from_slice(&q[src..src + dh]);
                    kh[r * dh..(r + 1) * dh].copy_from_slice(&k[src..src + dh]);
                    vh[r * dh..(r + 1) * dh].copy_from_slice(&v[src..src + dh]);
                }
                // gain-free QK-RMSNorm over the head dimension
                qk_normalize(&mut qh, l, dh);
                qk_normalize(&mut kh, l, dh);
                if capture {
                    problems.push(AttnProblem {
                        nq: l,
                        nkv: l,
                        d: dh,
                        q: qh.clone(),
                        k: kh.clone(),
                        v: vh.clone(),
                        scale,
                    });
                }
                head_bufs.push((qh, kh, vh));
            }
            let (outs, skip) = batch::run_causal_heads(&self.kernel_config(), &head_bufs, l, dh, scale);
            stats.skip.merge(&skip);
            stats.rows += (nh * l) as u64;
            for head in 0..nh {
                for r in 0..l {
                    let src = (head * l + r) * dh;
                    attn_out[r * dm + head * dh..r * dm + (head + 1) * dh]
                        .copy_from_slice(&outs[src..src + dh]);
                }
            }
            let proj = matmul(&attn_out, &self.p(&format!("{pfx}.wo")).data, l, dm, dm);
            for i in 0..x.len() {
                x[i] += proj[i];
            }
            // --- SwiGLU MLP ---
            let h2 = rmsnorm(&x, &self.p(&format!("{pfx}.ln2")).data, l, dm);
            let dff = info.d_ff;
            let mut gate = matmul(&h2, &self.p(&format!("{pfx}.w_gate")).data, l, dm, dff);
            let up = matmul(&h2, &self.p(&format!("{pfx}.w_up")).data, l, dm, dff);
            for i in 0..gate.len() {
                gate[i] = silu(gate[i]) * up[i];
            }
            let down = matmul(&gate, &self.p(&format!("{pfx}.w_down")).data, l, dff, dm);
            for i in 0..x.len() {
                x[i] += down[i];
            }
        }

        // final norm + tied logits: (L, dm) @ (vocab, dm)^T
        let xf = rmsnorm(&x, &self.p("ln_f").data, l, dm);
        let vocab = info.vocab_size;
        let mut logits = vec![0.0f32; l * vocab];
        for r in 0..l {
            let row = &xf[r * dm..(r + 1) * dm];
            for t in 0..vocab {
                let emb = &tok_emb[t * dm..(t + 1) * dm];
                logits[r * vocab + t] = crate::kernels::dot(row, emb);
            }
        }
        (logits, stats, problems)
    }

    /// Mean next-token negative log-likelihood of a window (teacher-forced).
    pub fn score(&self, tokens: &[i32]) -> (f64, ForwardStats) {
        let (logits, stats) = self.forward(tokens);
        let v = self.info.vocab_size;
        let l = tokens.len();
        let mut nll = 0.0f64;
        for r in 0..l - 1 {
            let row = &logits[r * v..(r + 1) * v];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let logz: f32 = m + row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln();
            let gold = tokens[r + 1].clamp(0, v as i32 - 1) as usize;
            nll += (logz - row[gold]) as f64;
        }
        (nll / (l - 1).max(1) as f64, stats)
    }

    /// Greedy decode: extend `prompt` by `n` tokens (window-clipped).
    pub fn greedy_decode(&self, prompt: &[i32], n: usize) -> (Vec<i32>, ForwardStats) {
        let mut toks = prompt.to_vec();
        let mut stats = ForwardStats::default();
        let v = self.info.vocab_size;
        for _ in 0..n {
            let start = toks.len().saturating_sub(self.info.seq_len);
            let window = &toks[start..];
            let (logits, st) = self.forward(window);
            stats.merge(&st);
            let last = &logits[(window.len() - 1) * v..window.len() * v];
            let argmax = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
            toks.push(argmax);
        }
        (toks, stats)
    }
}

/// Shared fixtures for sibling-module tests.
#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::runtime::ModelInfo;
    use crate::util::rng::Rng;

    pub(crate) fn tiny_info() -> ModelInfo {
        let (vocab, seq, dm, nh, nl, dff) = (32usize, 16usize, 16usize, 2usize, 2usize, 24usize);
        let mut spec = vec![
            ("tok_emb".to_string(), vec![vocab, dm]),
            ("pos_emb".to_string(), vec![seq, dm]),
        ];
        for i in 0..nl {
            for (n, s) in [
                ("ln1", vec![dm]),
                ("wq", vec![dm, dm]),
                ("wk", vec![dm, dm]),
                ("wv", vec![dm, dm]),
                ("wo", vec![dm, dm]),
                ("ln2", vec![dm]),
                ("w_gate", vec![dm, dff]),
                ("w_up", vec![dm, dff]),
                ("w_down", vec![dff, dm]),
            ] {
                spec.push((format!("l{i}.{n}"), s));
            }
        }
        spec.push(("ln_f".to_string(), vec![dm]));
        let n_params = spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        ModelInfo {
            name: "test".into(),
            vocab_size: vocab,
            seq_len: seq,
            d_model: dm,
            n_heads: nh,
            n_layers: nl,
            d_ff: dff,
            block_q: 8,
            block_k: 8,
            qk_gain: 2.75,
            n_params,
            param_spec: spec,
            init_weights: String::new(),
            train_lr: 1e-3,
            train_batch: 2,
        }
    }

    pub(crate) fn tiny_engine(seed: u64) -> Engine {
        let info = tiny_info();
        let mut rng = Rng::new(seed);
        let tensors = info
            .param_spec
            .iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name.contains("ln") {
                    vec![1.0; n]
                } else {
                    rng.normal_vec(n, 0.08)
                };
                NamedTensor { name: name.clone(), shape: shape.clone(), data }
            })
            .collect();
        Engine::new(info, tensors).unwrap()
    }

    #[test]
    fn forward_shapes_and_finite() {
        let e = tiny_engine(1);
        let toks: Vec<i32> = (0..12).map(|i| i % 32).collect();
        let (logits, stats) = e.forward(&toks);
        assert_eq!(logits.len(), 12 * 32);
        assert!(logits.iter().all(|x| x.is_finite()));
        // rows = layers * heads * L
        assert_eq!(stats.rows, 2 * 2 * 12);
    }

    #[test]
    fn causality_future_token_does_not_change_past() {
        let e = tiny_engine(2);
        let mut a: Vec<i32> = (0..10).map(|i| (i * 3) % 32).collect();
        let la = e.forward(&a).0;
        a[9] = 31;
        let lb = e.forward(&a).0;
        for i in 0..9 * 32 {
            assert!((la[i] - lb[i]).abs() < 1e-5, "position {} changed", i / 32);
        }
    }

    #[test]
    fn abi_mismatch_detected() {
        let info = tiny_info();
        let mut tensors: Vec<NamedTensor> = info
            .param_spec
            .iter()
            .map(|(name, shape)| NamedTensor {
                name: name.clone(),
                shape: shape.clone(),
                data: vec![0.0; shape.iter().product()],
            })
            .collect();
        tensors.swap(0, 1);
        assert!(Engine::new(info, tensors).is_err());
    }

    #[test]
    fn score_near_uniform_for_random_weights() {
        let e = tiny_engine(3);
        let toks: Vec<i32> = (0..16).map(|i| (i * 7) % 32).collect();
        let (nll, _) = e.score(&toks);
        assert!((nll - (32f64).ln()).abs() < 1.0, "nll {nll}");
    }

    #[test]
    fn greedy_decode_deterministic_and_extends() {
        let e = tiny_engine(4);
        let prompt = [1i32, 2, 3];
        let (a, stats) = e.greedy_decode(&prompt, 5);
        let (b, _) = e.greedy_decode(&prompt, 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(stats.rows > 0);
    }

    #[test]
    fn capture_yields_layer_head_problems() {
        let e = tiny_engine(5);
        let toks: Vec<i32> = (0..8).collect();
        let (_, _, problems) = e.forward_capture(&toks);
        assert_eq!(problems.len(), 2 * 2);
        for p in &problems {
            assert_eq!(p.nq, 8);
            assert_eq!(p.d, 8);
        }
    }

    #[test]
    fn configure_applies_whole_kernel_config() {
        let mut e = tiny_engine(7);
        let cfg = KernelConfig {
            tile: 4,
            threads: 1,
            block_q: 2,
            kv_precision: KvPrecision::Bf16,
            sigmoid: SigmoidMode::Pwl { segments: 16 },
            skip: SkipCriterion::None,
        };
        e.configure(cfg);
        assert_eq!(e.criterion, SkipCriterion::None);
        let got = e.kernel_config();
        assert_eq!(got.tile, 4);
        assert_eq!(got.threads, 1);
        assert_eq!(got.block_q, 2);
        assert_eq!(got.kv_precision, KvPrecision::Bf16);
        assert_eq!(got.sigmoid, SigmoidMode::Pwl { segments: 16 });
        assert_eq!(got.skip, SkipCriterion::None);
        // criterion stays the live skip knob after configure
        e.criterion = SkipCriterion::Static;
        assert_eq!(e.kernel_config().skip, SkipCriterion::Static);
    }

    /// The deprecated setter quartet must keep forwarding to `configure`
    /// without clobbering unrelated knobs.
    #[test]
    #[allow(deprecated)]
    fn deprecated_setters_forward_to_configure() {
        let mut e = tiny_engine(8);
        e.set_kernel_tuning(4, 1);
        e.set_query_block(2);
        e.set_kv_precision(KvPrecision::Bf16);
        e.set_sigmoid_mode(SigmoidMode::Pwl { segments: 16 });
        let got = e.kernel_config();
        assert_eq!(got.tile, 4);
        assert_eq!(got.threads, 1);
        assert_eq!(got.block_q, 2);
        assert_eq!(got.kv_precision, KvPrecision::Bf16);
        assert_eq!(got.sigmoid, SigmoidMode::Pwl { segments: 16 });
    }

    #[test]
    fn skip_criterion_none_vs_static_same_decode() {
        // On a trained-scale random model the static skips must not change
        // the greedy decode (the paper's llama2.c "same replies" check).
        let mut e = tiny_engine(6);
        let prompt: Vec<i32> = (0..6).map(|i| (i * 5) % 32).collect();
        e.criterion = SkipCriterion::Static;
        let (a, _) = e.greedy_decode(&prompt, 6);
        e.criterion = SkipCriterion::None;
        let (b, _) = e.greedy_decode(&prompt, 6);
        assert_eq!(a, b);
    }
}
