//! Continuous-batching conformance: streamed sessions through
//! `Coordinator::submit_stream` must deliver tokens in submission order,
//! bit-exact against the per-request reference, across every scheduling
//! policy × dispatch mode × token-budget cell — admission into a running
//! batch must never change *what* is computed, only *when*.

mod common;

use common::{expect_for, mk_req, test_router, RefKv};
use flashd::coordinator::request::{AttentionRequest, RequestKind};
use flashd::coordinator::scheduler::Policy;
use flashd::coordinator::{Coordinator, CoordinatorConfig, StreamEvent, StreamHandle};
use flashd::kernels::batch::KernelConfig;
use flashd::prop_assert;
use flashd::util::prop::forall;
use flashd::util::rng::Rng;
use std::time::Duration;

fn start(policy: Policy, fused: bool, budget: usize) -> Coordinator {
    let cfg = CoordinatorConfig {
        policy,
        fused,
        max_batch_total_tokens: budget,
        batch_window: Duration::from_micros(50),
        kernel: KernelConfig { tile: 8, threads: 2, ..KernelConfig::default() },
        validate_invariants: true,
        ..CoordinatorConfig::default()
    };
    Coordinator::start_naive(cfg, test_router()).expect("start coordinator")
}

/// Build one session lifecycle (prefill + `steps` decodes) and its
/// reference outputs, computed before submission so the expectation is
/// independent of how cycles slice the stream.
fn session_script(
    rng: &mut Rng,
    session: u64,
    base_id: u64,
    prefill: usize,
    steps: usize,
) -> (Vec<AttentionRequest>, Vec<(u64, Vec<f32>)>) {
    let mut kv = RefKv::new();
    let mut reqs = vec![mk_req(rng, base_id, RequestKind::prefill(session), 1, prefill)];
    for i in 0..steps {
        reqs.push(mk_req(rng, base_id + 1 + i as u64, RequestKind::Decode { session }, 1, 1));
    }
    let expected = reqs.iter().map(|r| (r.id, expect_for(r, &mut kv))).collect();
    (reqs, expected)
}

/// Drain a stream and assert order, bit-exactness, and the `Done` summary.
fn check_stream(handle: StreamHandle, expected: &[(u64, Vec<f32>)], tag: &str) {
    let (tokens, done) = handle.collect_blocking();
    assert_eq!(tokens.len(), expected.len(), "{tag}: token count");
    for (resp, (id, want)) in tokens.iter().zip(expected) {
        assert_eq!(resp.id, *id, "{tag}: tokens out of submission order");
        let out = resp.output.as_ref().unwrap_or_else(|e| panic!("{tag}: id {id} failed: {e}"));
        assert_eq!(out, want, "{tag}: id {id} diverged from reference");
    }
    match done {
        Some(StreamEvent::Done { ttft_us, total_us, tokens: n }) => {
            assert_eq!(n, expected.len() as u64, "{tag}: Done token count");
            assert!(total_us >= ttft_us, "{tag}: total {total_us} < ttft {ttft_us}");
        }
        other => panic!("{tag}: stream ended without Done: {other:?}"),
    }
}

fn run_matrix_cell(policy: Policy, fused: bool, budget: usize) {
    let tag = format!("{policy:?}/fused={fused}/budget={budget}");
    let coord = start(policy, fused, budget);
    let mut rng = Rng::new(0xC0FFEE ^ budget as u64 ^ u64::from(fused));
    let (sessions, steps, prefill) = (3u64, 5usize, 8usize);
    let mut handles = Vec::new();
    let mut expected = Vec::new();
    for s in 0..sessions {
        let (reqs, exp) = session_script(&mut rng, s, 1000 * (s + 1), prefill, steps);
        expected.push(exp);
        handles.push(coord.submit_stream(reqs));
    }
    for (s, (h, exp)) in handles.into_iter().zip(&expected).enumerate() {
        check_stream(h, exp, &format!("{tag}/stream {s}"));
    }
    let snap = coord.metrics.snapshot();
    let total = sessions * (steps as u64 + 1);
    assert_eq!(snap.errors, 0, "{tag}");
    assert_eq!(snap.responses, total, "{tag}");
    assert_eq!(snap.queue_wait.count, total, "{tag}: every admission observed");
    assert_eq!(snap.streams_opened, sessions, "{tag}");
    assert_eq!(snap.streams_completed, sessions, "{tag}");
    assert_eq!(snap.ttft.count, sessions, "{tag}: one TTFT sample per stream");
    assert_eq!(snap.itl.count, total - sessions, "{tag}: inter-token samples");
    coord.shutdown();
}

/// The full conformance matrix: both policies × fused/serial dispatch ×
/// a starved token budget (every cycle splits) and an unbounded one.
#[test]
fn streamed_sessions_bit_exact_across_policy_dispatch_budget() {
    for policy in [Policy::Fifo, Policy::DecodeFirst] {
        for fused in [true, false] {
            for budget in [8usize, usize::MAX] {
                run_matrix_cell(policy, fused, budget);
            }
        }
    }
}

/// Streams beyond `max_concurrent_streams` park at admission and still
/// complete in full once a slot frees, with order and outputs intact.
#[test]
fn parked_streams_complete_bit_exact() {
    let cfg = CoordinatorConfig {
        max_concurrent_streams: 2,
        batch_window: Duration::from_micros(50),
        kernel: KernelConfig { tile: 8, threads: 2, ..KernelConfig::default() },
        validate_invariants: true,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_naive(cfg, test_router()).expect("start");
    let mut rng = Rng::new(0xBACC);
    let nstreams = 5u64;
    let mut handles = Vec::new();
    let mut expected = Vec::new();
    for s in 0..nstreams {
        let (reqs, exp) = session_script(&mut rng, 20 + s, 5000 + 100 * s, 6, 3);
        expected.push(exp);
        handles.push(coord.submit_stream(reqs));
    }
    for (s, (h, exp)) in handles.into_iter().zip(&expected).enumerate() {
        check_stream(h, exp, &format!("parked/stream {s}"));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.streams_opened, nstreams);
    assert_eq!(snap.streams_completed, nstreams);
    assert_eq!(snap.errors, 0);
    coord.shutdown();
}

/// Fork lineages driven as sequential streams: the forked session must
/// see the source's prefix bit-exactly, and the source must keep
/// decoding from its own unmutated state afterwards.
#[test]
fn forked_lineage_streams_bit_exact() {
    let coord = start(Policy::DecodeFirst, true, usize::MAX);
    let mut rng = Rng::new(0xF0BC);
    let mut kv_src = RefKv::new();
    let reqs = vec![
        mk_req(&mut rng, 7000, RequestKind::prefill(70), 1, 8),
        mk_req(&mut rng, 7001, RequestKind::Decode { session: 70 }, 1, 1),
    ];
    let exp: Vec<(u64, Vec<f32>)> = reqs.iter().map(|r| (r.id, expect_for(r, &mut kv_src))).collect();
    check_stream(coord.submit_stream(reqs), &exp, "fork/source");

    // fork 70 -> 71 with 2 fresh appends, then decode the fork
    let mut kv_fork = kv_src.clone();
    let reqs = vec![
        mk_req(&mut rng, 7100, RequestKind::fork(70, 71), 1, 2),
        mk_req(&mut rng, 7101, RequestKind::Decode { session: 71 }, 1, 1),
    ];
    let exp: Vec<(u64, Vec<f32>)> = reqs.iter().map(|r| (r.id, expect_for(r, &mut kv_fork))).collect();
    check_stream(coord.submit_stream(reqs), &exp, "fork/child");

    // the source lineage is untouched by the fork's appends
    let req = mk_req(&mut rng, 7002, RequestKind::Decode { session: 70 }, 1, 1);
    let want = expect_for(&req, &mut kv_src);
    let resp = coord.submit_blocking(req);
    assert_eq!(resp.output.expect("source decode"), want, "fork mutated the source lineage");
    coord.shutdown();
}

/// Property: under randomized policy, dispatch mode, token budget, and
/// session scripts, continuous admission never reorders responses within
/// a session and never perturbs their numerics.
#[test]
fn prop_continuous_admission_preserves_per_session_streams() {
    forall("continuous-admission-order", 20, |g| {
        let policy = if g.bool() { Policy::Fifo } else { Policy::DecodeFirst };
        let fused = g.bool();
        let budget = if g.bool() { g.usize_in(4, 24) } else { usize::MAX };
        let coord = start(policy, fused, budget);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let nstreams = g.usize_in(1, 3);
        let mut handles = Vec::new();
        let mut expected = Vec::new();
        for s in 0..nstreams {
            let prefill = g.usize_in(1, 10);
            let steps = g.usize_in(1, 5);
            let (reqs, exp) = session_script(&mut rng, s as u64, 1 + 100 * s as u64, prefill, steps);
            expected.push(exp);
            handles.push(coord.submit_stream(reqs));
        }
        for (h, exp) in handles.into_iter().zip(&expected) {
            let (tokens, done) = h.collect_blocking();
            prop_assert!(g, tokens.len() == exp.len(), "token count mismatch");
            for (resp, (id, want)) in tokens.iter().zip(exp) {
                prop_assert!(g, resp.id == *id, "responses reordered within a session");
                prop_assert!(g, resp.output.as_ref().ok() == Some(want), "stream output diverged from reference");
            }
            prop_assert!(g, matches!(done, Some(StreamEvent::Done { .. })), "missing Done");
        }
        let snap = coord.metrics.snapshot();
        prop_assert!(g, snap.errors == 0, "errors under continuous admission");
        prop_assert!(g, snap.streams_completed == nstreams as u64, "streams lost");
        coord.shutdown();
        true
    });
}
