//! Differential serving conformance suite: randomized interleavings of
//! prefill/decode/stateless requests across many sessions run through the
//! FULL coordinator (scheduler, batcher, KV store, fused dispatch, kernel
//! engine) and are asserted **bit-identical** to direct per-request
//! `kernels::flashd` reference execution — for both scheduler policies and
//! with fused dispatch on and off.
//!
//! The client contract the driver follows: a session submits its next
//! request only after its previous response arrived (so per-session KV
//! order is defined); cross-session and stateless submissions interleave
//! randomly, exercising multi-batch fused cycles with arbitrary timing.
//! Outputs must not depend on that timing, on the drain batching, or on
//! `KernelConfig::threads` — equality to the timing-free reference proves
//! all three at once.

mod common;

use common::{expect_for, mk_req, reference_output, test_router, RefKv, HEADS};
use flashd::coordinator::request::{AttentionRequest, AttentionResponse, RequestKind};
use flashd::coordinator::scheduler::Policy;
use flashd::coordinator::{Coordinator, CoordinatorConfig};
use flashd::kernels::batch::KernelConfig;
use flashd::numerics::quant::KvPrecision;
use flashd::util::rng::Rng;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Receiver;
use std::time::Duration;

/// Scripted lifecycle for one session: prefill, decode stream, sometimes a
/// re-prefill (cache replacement) with a short second decode stream.
fn session_script(rng: &mut Rng, session: u64, next_id: &mut u64) -> VecDeque<AttentionRequest> {
    let mut plan: Vec<(RequestKind, usize, usize)> = Vec::new();
    let prefill_len = 4 + rng.below(9);
    plan.push((RequestKind::prefill(session), 1, prefill_len));
    for _ in 0..(3 + rng.below(6)) {
        plan.push((RequestKind::Decode { session }, 1, 1));
    }
    if rng.below(3) == 0 {
        let re_len = 3 + rng.below(7);
        plan.push((RequestKind::prefill(session), 1, re_len));
        for _ in 0..2 {
            plan.push((RequestKind::Decode { session }, 1, 1));
        }
    }
    let mut script = VecDeque::new();
    for (kind, nq, nkv) in plan {
        script.push_back(mk_req(rng, *next_id, kind, nq, nkv));
        *next_id += 1;
    }
    script
}

struct InFlight {
    rx: Receiver<AttentionResponse>,
    expected: Vec<f32>,
    id: u64,
}

fn check(fl: InFlight) {
    let resp = fl.rx.recv().expect("engine dropped a response");
    assert_eq!(resp.id, fl.id);
    let out = resp.output.expect("request failed");
    assert_eq!(out, fl.expected, "request {} not bit-identical to reference", fl.id);
}

/// One randomized interleaving through a full coordinator.
fn run_interleaving(policy: Policy, fused: bool, seed: u64) {
    let threads = 1 + (seed as usize % 4);
    let cfg = CoordinatorConfig {
        policy,
        fused,
        batch_window: Duration::from_micros(100),
        kernel: KernelConfig { tile: 8, block_q: 4, threads, ..KernelConfig::default() },
        // every conformance cycle doubles as a pool-invariant audit
        validate_invariants: true,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_naive(cfg, test_router()).expect("start coordinator");
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let mut next_id = 1u64;

    let nsessions = 2 + rng.below(3);
    let mut scripts: Vec<VecDeque<AttentionRequest>> = (0..nsessions)
        .map(|s| session_script(&mut rng, s as u64, &mut next_id))
        .collect();
    let mut kvs: Vec<RefKv> = (0..nsessions).map(|_| RefKv::new()).collect();
    let mut inflight: Vec<Option<InFlight>> = (0..nsessions).map(|_| None).collect();
    let mut stateless_left = 2 + rng.below(4);
    let mut stateless_inflight: Vec<InFlight> = Vec::new();
    let mut served = 0u64;

    let mut guard = 0u32;
    loop {
        guard += 1;
        assert!(guard < 100_000, "conformance driver stuck");
        let mut progressed = false;

        // Randomly submit the next request of idle sessions.
        for s in 0..nsessions {
            if inflight[s].is_none() && !scripts[s].is_empty() && rng.below(2) == 0 {
                let req = scripts[s].pop_front().unwrap();
                let expected = expect_for(&req, &mut kvs[s]);
                let id = req.id;
                let rx = coord.submit(req);
                inflight[s] = Some(InFlight { rx, expected, id });
                progressed = true;
            }
        }
        // Occasionally add a stateless request.
        if stateless_left > 0 && rng.below(3) == 0 {
            stateless_left -= 1;
            let nq = 1 + rng.below(3);
            let nkv = 2 + rng.below(20);
            let req = mk_req(&mut rng, next_id, RequestKind::Stateless, nq, nkv);
            next_id += 1;
            let mut own = RefKv::new();
            let expected = expect_for(&req, &mut own);
            let id = req.id;
            let rx = coord.submit(req);
            stateless_inflight.push(InFlight { rx, expected, id });
            progressed = true;
        }
        // Randomly collect responses (blocking), freeing sessions.
        for s in 0..nsessions {
            if inflight[s].is_some() && rng.below(2) == 0 {
                check(inflight[s].take().unwrap());
                served += 1;
                progressed = true;
            }
        }
        if !stateless_inflight.is_empty() && rng.below(2) == 0 {
            check(stateless_inflight.remove(0));
            served += 1;
            progressed = true;
        }

        let done = scripts.iter().all(VecDeque::is_empty)
            && inflight.iter().all(Option::is_none)
            && stateless_inflight.is_empty()
            && stateless_left == 0;
        if done {
            break;
        }
        if !progressed {
            // Force progress so the loop terminates: drain one in-flight
            // response if any, otherwise submit the next available request.
            if let Some(s) = (0..nsessions).find(|&s| inflight[s].is_some()) {
                check(inflight[s].take().unwrap());
                served += 1;
            } else if !stateless_inflight.is_empty() {
                check(stateless_inflight.remove(0));
                served += 1;
            }
        }
    }

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.errors, 0, "no request may fail in a conformance run");
    assert_eq!(snap.responses, served, "every request exactly one response");
    if fused {
        assert!(snap.fused_cycles > 0, "fused path must have served the run");
        assert!(snap.fused_submissions >= snap.fused_cycles);
        assert_eq!(snap.fused_jobs, HEADS as u64 * snap.fused_batches);
        assert_eq!(snap.skip_skipped, 0, "serving uses the exact kernel");
    } else {
        assert_eq!(snap.fused_submissions, 0, "serial mode must not fuse");
    }
    coord.shutdown();
}

/// ≥ 100 randomized interleavings across the 2×2 (policy × fused) grid —
/// the acceptance bar for the differential suite.
const REPS: u64 = 30;

#[test]
fn conformance_fifo_fused() {
    for rep in 0..REPS {
        run_interleaving(Policy::Fifo, true, 1_000 + rep);
    }
}

#[test]
fn conformance_fifo_serial() {
    for rep in 0..REPS {
        run_interleaving(Policy::Fifo, false, 2_000 + rep);
    }
}

#[test]
fn conformance_decode_first_fused() {
    for rep in 0..REPS {
        run_interleaving(Policy::DecodeFirst, true, 3_000 + rep);
    }
}

#[test]
fn conformance_decode_first_serial() {
    for rep in 0..REPS {
        run_interleaving(Policy::DecodeFirst, false, 4_000 + rep);
    }
}

/// Randomized lineage trees over the paged store: prefill base sessions,
/// fork each into children (copy-on-write prefix sharing in the block
/// pool), then drive a randomized interleaved decode stream across every
/// lineage — all outputs bit-identical to per-request `kernels::flashd`
/// over the reference KV at the serving storage precision.
fn run_forked_interleaving(prec: KvPrecision, fused: bool, seed: u64) {
    let cfg = CoordinatorConfig {
        fused,
        batch_window: Duration::from_micros(100),
        kernel: KernelConfig {
            tile: 8,
            block_q: 4,
            threads: 1 + (seed as usize % 3),
            kv_precision: prec,
            ..KernelConfig::default()
        },
        validate_invariants: true,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_naive(cfg, test_router()).expect("start coordinator");
    let mut rng = Rng::new(seed ^ 0xF0_4D);
    let mut next_id = 1u64;
    let mut kvs: HashMap<u64, RefKv> = HashMap::new();

    // Phase 1: prefill 2-3 base sessions (blocking, so fork sources are
    // quiescent and their reference length is defined).
    let nbase = 2 + rng.below(2) as u64;
    for s in 0..nbase {
        let req = mk_req(
            &mut rng,
            next_id,
            RequestKind::prefill(s),
            1,
            4 + rng.below(12),
        );
        next_id += 1;
        let mut kv = RefKv::with_precision(prec);
        let want = expect_for(&req, &mut kv);
        let got = coord.submit_blocking(req).output.expect("prefill ok");
        assert_eq!(got, want, "prefill of {s} not bit-identical");
        kvs.insert(s, kv);
    }

    // Phase 2: fork each base into 1-2 children with a short divergence.
    let mut next_sess = nbase;
    for s in 0..nbase {
        for _ in 0..(1 + rng.below(2)) {
            let dst = next_sess;
            next_sess += 1;
            let req = mk_req(
                &mut rng,
                next_id,
                RequestKind::fork(s, dst),
                1,
                1 + rng.below(3),
            );
            next_id += 1;
            // reference: child inherits the source's exact stored prefix
            let mut kv = kvs[&s].clone();
            let want = expect_for(&req, &mut kv);
            let got = coord.submit_blocking(req).output.expect("fork ok");
            assert_eq!(got, want, "fork {s} -> {dst} not bit-identical");
            kvs.insert(dst, kv);
        }
    }

    // Phase 3: randomized interleaved decode streams over every lineage.
    let ids: Vec<u64> = kvs.keys().copied().collect();
    let mut remaining: HashMap<u64, usize> =
        ids.iter().map(|&s| (s, 2 + rng.below(4))).collect();
    let mut inflight: HashMap<u64, InFlight> = HashMap::new();
    let mut guard = 0u32;
    loop {
        guard += 1;
        assert!(guard < 100_000, "forked conformance driver stuck");
        let mut progressed = false;
        for &s in &ids {
            if !inflight.contains_key(&s) && remaining[&s] > 0 && rng.below(2) == 0 {
                *remaining.get_mut(&s).unwrap() -= 1;
                let req = mk_req(&mut rng, next_id, RequestKind::Decode { session: s }, 1, 1);
                next_id += 1;
                let expected = expect_for(&req, kvs.get_mut(&s).unwrap());
                let id = req.id;
                let rx = coord.submit(req);
                inflight.insert(s, InFlight { rx, expected, id });
                progressed = true;
            }
        }
        for &s in &ids {
            if inflight.contains_key(&s) && rng.below(2) == 0 {
                check(inflight.remove(&s).unwrap());
                progressed = true;
            }
        }
        if remaining.values().all(|&r| r == 0) && inflight.is_empty() {
            break;
        }
        if !progressed {
            if let Some(&s) = ids.iter().find(|s| inflight.contains_key(s)) {
                check(inflight.remove(&s).unwrap());
            }
        }
    }

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.errors, 0, "no request may fail in a conformance run");
    assert!(snap.kv_prefix_share_hits > 0, "forks must share prefix blocks");
    coord.shutdown();
}

#[test]
fn conformance_forked_sessions_f32() {
    for rep in 0..10 {
        run_forked_interleaving(KvPrecision::F32, rep % 2 == 0, 5_000 + rep);
    }
}

#[test]
fn conformance_forked_sessions_bf16() {
    for rep in 0..10 {
        run_forked_interleaving(KvPrecision::Bf16, rep % 2 == 0, 6_000 + rep);
    }
}

#[test]
fn conformance_forked_sessions_fp8() {
    for rep in 0..10 {
        run_forked_interleaving(KvPrecision::Fp8, rep % 2 == 0, 7_000 + rep);
    }
}

/// `window >= nkv` conformance: a session whose window covers every KV
/// row it will ever hold must stay bit-identical to an unwindowed session
/// fed the same stream (and both to the kernel reference) — nothing is
/// trimmed, nothing rescaled.
#[test]
fn window_covering_all_kv_identical_to_unwindowed() {
    use flashd::coordinator::request::AttnPolicy;
    let cfg = CoordinatorConfig {
        batch_window: Duration::from_micros(100),
        kernel: KernelConfig { tile: 8, block_q: 4, threads: 2, ..KernelConfig::default() },
        validate_invariants: true,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_naive(cfg, test_router()).expect("start");
    let mut rng = Rng::new(8_100);
    let mut kv = RefKv::new();

    // the session peaks at 10 + 6 KV rows; window 64 covers all of it
    let policy = AttnPolicy::from_kernel(&KernelConfig::default()).with_window(64);
    let prefill = mk_req(&mut rng, 1, RequestKind::prefill(1), 1, 10);
    let mut wpre = prefill.clone();
    wpre.id = 101;
    wpre.kind = RequestKind::Prefill { session: 2, policy: Some(policy) };
    let want = expect_for(&prefill, &mut kv);
    let a = coord.submit_blocking(prefill).output.expect("prefill ok");
    let b = coord.submit_blocking(wpre).output.expect("windowed prefill ok");
    assert_eq!(a, want);
    assert_eq!(b, want, "covering window diverged at prefill");

    for i in 0..6u64 {
        let dec = mk_req(&mut rng, 10 + i, RequestKind::Decode { session: 1 }, 1, 1);
        let mut wdec = dec.clone();
        wdec.id = 110 + i;
        wdec.kind = RequestKind::Decode { session: 2 };
        let want = expect_for(&dec, &mut kv);
        let a = coord.submit_blocking(dec).output.expect("decode ok");
        let b = coord.submit_blocking(wdec).output.expect("windowed decode ok");
        assert_eq!(a, want);
        assert_eq!(b, want, "covering window diverged at decode {i}");
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.kv_window_trims, 0, "covering window must never trim");
    assert_eq!(snap.kv_blocks_trimmed, 0);
    coord.shutdown();
}

/// A same-session decode burst that merges into ONE multi-member batch
/// must equal the block reference: every member's query attends the full
/// post-append KV (all burst pairs included), bit-exactly.
#[test]
fn fused_decode_burst_matches_block_reference() {
    let burst = 6usize;
    'attempt: for attempt in 0..5 {
        let cfg = CoordinatorConfig {
            // wide window so a one-thread burst lands in one drain cycle
            batch_window: Duration::from_millis(50),
            kernel: KernelConfig { tile: 8, block_q: 4, threads: 2, ..KernelConfig::default() },
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start_naive(cfg, test_router()).expect("start");
        let mut rng = Rng::new(9_000 + attempt);
        let mut kv = RefKv::new();

        let prefill = mk_req(&mut rng, 1, RequestKind::prefill(1), 1, 10);
        let expected = expect_for(&prefill, &mut kv);
        let got = coord.submit_blocking(prefill).output.expect("prefill ok");
        assert_eq!(got, expected);

        // Submit the burst without waiting; channel order fixes member order.
        let decodes: Vec<AttentionRequest> = (0..burst)
            .map(|i| mk_req(&mut rng, 10 + i as u64, RequestKind::Decode { session: 1 }, 1, 1))
            .collect();
        // Reference: all appends land before any member executes.
        for d in &decodes {
            kv.append(&d.k, &d.v, 1);
        }
        let expects: Vec<Vec<f32>> = decodes.iter().map(|d| reference_output(&d.q, 1, &kv)).collect();
        let rxs: Vec<Receiver<AttentionResponse>> =
            decodes.into_iter().map(|d| coord.submit(d)).collect();
        let resps: Vec<AttentionResponse> = rxs.iter().map(|rx| rx.recv().expect("resp")).collect();
        if resps.iter().any(|r| r.batch_size != burst) {
            // Timing fluke: the burst split across cycles; try again.
            coord.shutdown();
            continue 'attempt;
        }
        for (resp, want) in resps.into_iter().zip(expects) {
            assert_eq!(resp.output.expect("decode ok"), want, "burst member diverged");
        }
        coord.shutdown();
        return;
    }
    panic!("decode burst never merged into one batch in 5 attempts");
}
