//! Property-based tests over the attention kernels (proptest-style, using
//! the in-tree `util::prop` driver): the algebraic identities the paper's
//! derivation rests on must hold for arbitrary random problems.

use flashd::kernels::flashd::{log_sigmoid, sigmoid, weight, SkipCriterion, SkipStats, ACTIVE_HI, ACTIVE_LO};
use flashd::kernels::flashd as fd;
use flashd::kernels::{
    batch, flash1, flash2, max_abs_diff, naive, qblock, scalar, tiled, BatchScratch, KernelConfig,
    KvRef, KvRowJob, KvView, RowJob, SigmoidMode,
};
use flashd::numerics::quant::{quantize_bf16, quantize_fp8};
use flashd::numerics::{Bf16, Fp8E4M3, Scalar};
use flashd::prop_assert;
use flashd::pwl::SigTables;
use flashd::util::prop::forall;

#[test]
fn prop_all_formulations_equal_softmax() {
    forall("formulations-equal", 120, |g| {
        let n = g.usize_in(1, 96);
        let d = *g.choose(&[2usize, 4, 8, 16]);
        let std = g.f64_in(0.2, 2.5) as f32;
        let q = g.vec_normal(d, std);
        let k = g.vec_normal(n * d, std);
        let v = g.vec_normal(n * d, 1.0);
        let scale = g.f64_in(0.1, 1.5) as f32;
        let gold = naive::attention(&q, &k, &v, n, d, scale);
        let f1 = flash1::attention(&q, &k, &v, n, d, scale);
        let f2 = flash2::attention(&q, &k, &v, n, d, scale);
        let fd = fd::attention(&q, &k, &v, n, d, scale);
        prop_assert!(g, max_abs_diff(&gold, &f1) < 5e-5, "flash1 diverged n={n} d={d}");
        prop_assert!(g, max_abs_diff(&gold, &f2) < 5e-5, "flash2 diverged n={n} d={d}");
        prop_assert!(g, max_abs_diff(&gold, &fd) < 5e-5, "flashd diverged n={n} d={d}");
        true
    });
}

#[test]
fn prop_output_is_convex_combination() {
    // o_i is a convex combination of value vectors: each output coordinate
    // lies within [min_j v_j, max_j v_j].
    forall("convex-combination", 120, |g| {
        let n = g.usize_in(1, 64);
        let d = g.usize_in(1, 8);
        let q = g.vec_normal(d, 1.0);
        let k = g.vec_normal(n * d, 1.0);
        let v = g.vec_normal(n * d, 1.0);
        let out = fd::attention(&q, &k, &v, n, d, 1.0);
        for j in 0..d {
            let lo = (0..n).map(|i| v[i * d + j]).fold(f32::MAX, f32::min);
            let hi = (0..n).map(|i| v[i * d + j]).fold(f32::MIN, f32::max);
            prop_assert!(
                g,
                out[j] >= lo - 1e-4 && out[j] <= hi + 1e-4,
                "coord {j}: {} outside [{lo}, {hi}]",
                out[j]
            );
        }
        true
    });
}

#[test]
fn prop_lse_identity_exact() {
    // Direct check of Eq. (8) unrolled: s_i - ln w_i == logsumexp(s_1..s_i).
    // This is the disguised sum-of-exponents the sigmoid carries.
    forall("lse-exact", 150, |g| {
        let n = g.usize_in(1, 30);
        let scores: Vec<f64> = (0..n).map(|_| g.f64_in(-10.0, 10.0)).collect();
        let mut ln_w = 0.0f64;
        for i in 0..n {
            if i > 0 {
                let x = scores[i] - scores[i - 1] + ln_w;
                let w = sigmoid(x);
                prop_assert!(g, w > 0.0 && w < 1.0, "w out of (0,1): {w}");
                ln_w = log_sigmoid(x);
            }
            let m = scores[..=i].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = m + scores[..=i].iter().map(|s| (s - m).exp()).sum::<f64>().ln();
            let carried = scores[i] - ln_w;
            prop_assert!(
                g,
                (carried - lse).abs() < 1e-9,
                "step {i}: carried {carried} vs lse {lse}"
            );
        }
        true
    });
}

#[test]
fn prop_weight_function_monotone_in_both_args() {
    forall("weight-monotone", 200, |g| {
        let s = g.f64_in(-12.0, 12.0);
        let ds = g.f64_in(0.01, 3.0);
        let wp = g.f64_in(0.001, 0.99);
        let dw = g.f64_in(0.0005, 0.009);
        prop_assert!(g, weight(s + ds, wp) >= weight(s, wp), "not monotone in s_diff");
        prop_assert!(g, weight(s, wp + dw) >= weight(s, wp), "not monotone in w_prev");
        true
    });
}

#[test]
fn prop_skip_low_is_sound() {
    // Skip-low is mathematically sound: its weight is below sigmoid(-6),
    // so each skipped update moves the output by < sigma(-6) * |v - o|.
    forall("skip-low-sound", 80, |g| {
        let n = g.usize_in(4, 128);
        let d = *g.choose(&[4usize, 8]);
        let q = g.vec_normal(d, 1.2);
        let k = g.vec_normal(n * d, 1.2);
        let v = g.vec_normal(n * d, 1.0);
        let exact = fd::attention(&q, &k, &v, n, d, 1.0);
        let (lo_only, stats) = fd::attention_instrumented(
            &q, &k, &v, n, d, 1.0,
            // low-tail-only criterion: hi = +infinity never fires
            SkipCriterion::Adaptive { lo: -6.0, hi: f64::INFINITY },
        );
        prop_assert!(g, stats.skip_high == 0, "hi must never fire");
        // |v - o| is bounded by ~max|v| spread; use an 8-sigma allowance.
        let per_skip_bound = 8.0 * sigmoid(-6.0) as f32;
        let bound = (stats.skip_low as f32 + 1.0) * per_skip_bound + 1e-3;
        let err = max_abs_diff(&exact, &lo_only);
        prop_assert!(g, err <= bound, "err {err} > bound {bound} (skips {})", stats.skip_low);
        true
    });
}

#[test]
fn prop_reduced_precision_bounded_degradation() {
    forall("precision-order", 40, |g| {
        let n = g.usize_in(8, 64);
        let d = 8usize;
        let q = g.vec_normal(d, 0.7);
        let k = g.vec_normal(n * d, 0.7);
        let v = g.vec_normal(n * d, 0.7);
        let gold = naive::attention(&q, &k, &v, n, d, 0.35);
        let b16 = fd::attention_generic::<Bf16>(&q, &k, &v, n, d, 0.35);
        let f8 = fd::attention_generic::<Fp8E4M3>(&q, &k, &v, n, d, 0.35);
        prop_assert!(g, b16.iter().all(|x| x.is_finite()), "bf16 nan");
        prop_assert!(g, f8.iter().all(|x| x.is_finite()), "fp8 nan");
        let e16 = max_abs_diff(&gold, &b16);
        let e8 = max_abs_diff(&gold, &f8);
        prop_assert!(g, e16 < 0.15, "bf16 err {e16}");
        prop_assert!(g, e8 < 0.8, "fp8 err {e8}");
        true
    });
}

#[test]
fn prop_format_roundtrip_monotone() {
    // Scalar format conversion preserves ordering (needed by the running
    // comparisons inside the kernels).
    forall("format-monotone", 300, |g| {
        let a = g.f64_in(-400.0, 400.0);
        let b = g.f64_in(-400.0, 400.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let l16 = Bf16::from_f64(lo).to_f64();
        let h16 = Bf16::from_f64(hi).to_f64();
        prop_assert!(g, l16 <= h16, "bf16 order broken: {lo} {hi}");
        let l8 = Fp8E4M3::from_f64(lo).to_f64();
        let h8 = Fp8E4M3::from_f64(hi).to_f64();
        prop_assert!(g, l8 <= h8, "fp8 order broken: {lo} {hi}");
        true
    });
}

#[test]
fn prop_flash2_multi_equals_singles() {
    forall("multi-consistency", 60, |g| {
        let nq = g.usize_in(1, 6);
        let nkv = g.usize_in(1, 48);
        let d = 8usize;
        let q = g.vec_normal(nq * d, 1.0);
        let k = g.vec_normal(nkv * d, 1.0);
        let v = g.vec_normal(nkv * d, 1.0);
        let multi = flash2::attention_multi(&q, &k, &v, nq, nkv, d, 0.5);
        for iq in 0..nq {
            let single = flash2::attention(&q[iq * d..(iq + 1) * d], &k, &v, nkv, d, 0.5);
            prop_assert!(
                g,
                max_abs_diff(&multi[iq * d..(iq + 1) * d], &single) < 1e-6,
                "query {iq} differs"
            );
        }
        true
    });
}

#[test]
fn prop_tiled_bitmatches_scalar_flashd() {
    // The tiled kernel with no skipping is the SAME sequence of float ops
    // as Alg. 3, so the outputs must be bit-identical for every tile size.
    forall("tiled-bitmatch", 80, |g| {
        let n = g.usize_in(1, 160);
        let d = *g.choose(&[2usize, 4, 8, 16, 64]);
        let std = g.f64_in(0.3, 2.5) as f32;
        let q = g.vec_normal(d, std);
        let k = g.vec_normal(n * d, std);
        let v = g.vec_normal(n * d, 1.0);
        let scale = g.f64_in(0.1, 1.2) as f32;
        let gold = fd::attention(&q, &k, &v, n, d, scale);
        for tile in [1usize, 7, 16, 64, n] {
            let got = tiled::attention_tiled(&q, &k, &v, n, d, scale, tile);
            prop_assert!(g, got == gold, "tile={tile} n={n} d={d} not bit-identical");
        }
        true
    });
}

#[test]
fn prop_tiled_adaptive_bitmatches_per_step() {
    // The tile-level fast path fires exactly when every step in the tile
    // would take the per-step adaptive skip-low branch, so output AND
    // SkipStats must be bit-identical to the per-step instrumented kernel.
    forall("tiled-adaptive-exact", 60, |g| {
        let n = g.usize_in(2, 200);
        let d = *g.choose(&[4usize, 8, 16]);
        let std = g.f64_in(0.5, 4.0) as f32;
        let q = g.vec_normal(d, std);
        let k = g.vec_normal(n * d, std);
        let v = g.vec_normal(n * d, 1.0);
        let crit = SkipCriterion::Adaptive { lo: ACTIVE_LO, hi: ACTIVE_HI };
        let (want_o, want_st) = fd::attention_instrumented(&q, &k, &v, n, d, 1.0, crit);
        for tile in [1usize, 7, 16, 64, n] {
            let (got_o, got_st) =
                tiled::attention_tiled_instrumented(&q, &k, &v, n, d, 1.0, tile, crit);
            prop_assert!(g, got_o == want_o, "tile={tile}: output differs");
            prop_assert!(g, got_st == want_st, "tile={tile}: stats differ");
        }
        true
    });
}

#[test]
fn prop_tiled_static_totals_exact_and_error_bounded() {
    // Block-skip under the static criterion: SkipStats totals stay exact
    // at every tile granularity and the output stays inside the 2e-2
    // static-skip error envelope on realistic score scales.
    forall("tiled-static-envelope", 50, |g| {
        let n = g.usize_in(8, 256);
        let d = *g.choose(&[8usize, 16]);
        let std = g.f64_in(0.4, 1.2) as f32; // trained-attention scale
        let q = g.vec_normal(d, std);
        let k = g.vec_normal(n * d, std);
        let v = g.vec_normal(n * d, 1.0);
        let exact = fd::attention(&q, &k, &v, n, d, 1.0);
        let (_, step_st) =
            fd::attention_instrumented(&q, &k, &v, n, d, 1.0, SkipCriterion::Static);
        for tile in [1usize, 7, 16, 64, n] {
            let (got, st) = tiled::attention_tiled_instrumented(
                &q, &k, &v, n, d, 1.0, tile,
                SkipCriterion::Static,
            );
            prop_assert!(
                g,
                st.total == step_st.total && st.total == (n as u64 - 1),
                "tile={tile}: total {} != {}",
                st.total,
                step_st.total
            );
            let err = max_abs_diff(&exact, &got);
            prop_assert!(g, err < 2e-2, "tile={tile}: err {err}");
        }
        true
    });
}

#[test]
fn prop_batched_driver_thread_invariant() {
    // run_rows must return bitwise-identical outputs and stats for every
    // thread count, in job order.
    forall("batch-thread-invariant", 30, |g| {
        let rows = g.usize_in(1, 10);
        let n = g.usize_in(1, 128);
        let d = *g.choose(&[8usize, 16]);
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..rows)
            .map(|_| {
                (
                    g.vec_normal(d, 0.8),
                    g.vec_normal(n * d, 0.8),
                    g.vec_normal(n * d, 1.0),
                )
            })
            .collect();
        let jobs: Vec<RowJob> = data
            .iter()
            .map(|(q, k, v)| RowJob { q, k, v, n, d, scale: 0.5 })
            .collect();
        let mk = |threads: usize| KernelConfig {
            tile: 16,
            threads,
            skip: SkipCriterion::Static,
            ..KernelConfig::default()
        };
        let (want, want_st) = batch::run_rows(&mk(1), &jobs);
        // serial reference: jobs in order through the tiled kernel
        for (i, (q, k, v)) in data.iter().enumerate() {
            let (o, _) = tiled::attention_tiled_instrumented(
                q, k, v, n, d, 0.5, 16,
                SkipCriterion::Static,
            );
            prop_assert!(g, want[i] == o, "row {i} out of order");
        }
        for threads in [2usize, 4, 8] {
            let (got, got_st) = batch::run_rows(&mk(threads), &jobs);
            prop_assert!(g, got == want, "threads={threads}: outputs differ");
            prop_assert!(g, got_st == want_st, "threads={threads}: stats differ");
        }
        true
    });
}

#[test]
fn prop_qblock_bitmatches_tiled_per_query() {
    // The query-blocked kernel carries one isolated (s_prev, ln_w, o)
    // state per query, so every query's output AND SkipStats contribution
    // must be bit-identical to the single-query tiled kernel — for every
    // block size, tile size, and skip criterion.
    forall("qblock-bitmatch", 30, |g| {
        let nq = *g.choose(&[1usize, 2, 7, 16]);
        let n = g.usize_in(1, 180);
        let d = *g.choose(&[4usize, 8, 16]);
        let std = g.f64_in(0.4, 3.0) as f32;
        let q = g.vec_normal(nq * d, std);
        let k = g.vec_normal(n * d, std);
        let v = g.vec_normal(n * d, 1.0);
        let scale = g.f64_in(0.2, 1.2) as f32;
        let crits = [
            SkipCriterion::None,
            SkipCriterion::Static,
            SkipCriterion::Adaptive { lo: ACTIVE_LO, hi: ACTIVE_HI },
        ];
        for crit in crits {
            for tile in [1usize, 7, 32, 64] {
                let (got, got_st) =
                    qblock::attention_qblock(&q, &k, &v, nq, n, d, scale, tile, crit, false);
                let mut want_st = SkipStats::default();
                for iq in 0..nq {
                    let (o, st) = tiled::attention_tiled_instrumented(
                        &q[iq * d..(iq + 1) * d],
                        &k, &v, n, d, scale, tile, crit,
                    );
                    prop_assert!(
                        g,
                        got[iq * d..(iq + 1) * d] == o[..],
                        "nq={nq} n={n} tile={tile} crit={crit:?}: query {iq} differs"
                    );
                    want_st.merge(&st);
                }
                prop_assert!(
                    g,
                    got_st == want_st,
                    "nq={nq} n={n} tile={tile} crit={crit:?}: stats differ"
                );
            }
        }
        true
    });
}

#[test]
fn prop_qblock_causal_staircase_bitmatches_per_prefix() {
    // Causal blocks: query iq attends the first n - nq + 1 + iq keys.
    // Masking a query out of later tiles must leave its op sequence
    // identical to the single-query kernel over its own prefix.
    forall("qblock-causal-bitmatch", 30, |g| {
        let nq = *g.choose(&[1usize, 2, 7, 16]);
        let extra = g.usize_in(0, 100);
        let n = nq + extra;
        let d = *g.choose(&[4usize, 8]);
        let std = g.f64_in(0.4, 2.0) as f32;
        let q = g.vec_normal(nq * d, std);
        let k = g.vec_normal(n * d, std);
        let v = g.vec_normal(n * d, 1.0);
        let crit = *g.choose(&[SkipCriterion::None, SkipCriterion::Static]);
        for tile in [1usize, 7, 32] {
            let (got, got_st) =
                qblock::attention_qblock(&q, &k, &v, nq, n, d, 0.5, tile, crit, true);
            let mut want_st = SkipStats::default();
            for iq in 0..nq {
                let ni = n - nq + 1 + iq;
                let (o, st) = tiled::attention_tiled_instrumented(
                    &q[iq * d..(iq + 1) * d],
                    &k[..ni * d],
                    &v[..ni * d],
                    ni, d, 0.5, tile, crit,
                );
                prop_assert!(
                    g,
                    got[iq * d..(iq + 1) * d] == o[..],
                    "nq={nq} n={n} tile={tile}: query {iq} differs"
                );
                want_st.merge(&st);
            }
            prop_assert!(g, got_st == want_st, "nq={nq} n={n} tile={tile}: stats differ");
        }
        true
    });
}

#[test]
fn prop_grouped_rows_bitmatch_and_thread_invariant() {
    // Rows sharing one KV context (the serving shape) are coalesced into
    // query blocks by run_rows; outputs and stats must stay bit-identical
    // to the ungrouped per-row kernel for every block size and thread
    // count, and run_rows_into must agree with run_rows.
    forall("grouped-rows-invariant", 20, |g| {
        let rows = g.usize_in(1, 24);
        let n = g.usize_in(1, 128);
        let d = *g.choose(&[8usize, 16]);
        let k = g.vec_normal(n * d, 0.8);
        let v = g.vec_normal(n * d, 1.0);
        let q = g.vec_normal(rows * d, 0.8);
        let jobs: Vec<RowJob> = (0..rows)
            .map(|r| RowJob { q: &q[r * d..(r + 1) * d], k: &k, v: &v, n, d, scale: 0.5 })
            .collect();
        for block_q in [1usize, 3, 16] {
            let mk = |threads: usize| KernelConfig {
                tile: 16,
                block_q,
                threads,
                skip: SkipCriterion::Static,
                ..KernelConfig::default()
            };
            let (want, want_st) = batch::run_rows(&mk(1), &jobs);
            for (i, j) in jobs.iter().enumerate() {
                let (o, _) = tiled::attention_tiled_instrumented(
                    j.q, j.k, j.v, n, d, 0.5, 16,
                    SkipCriterion::Static,
                );
                prop_assert!(g, want[i] == o, "block_q={block_q}: row {i} differs from tiled");
            }
            for threads in [2usize, 4, 8] {
                let (got, got_st) = batch::run_rows(&mk(threads), &jobs);
                prop_assert!(g, got == want, "block_q={block_q} threads={threads}: outputs");
                prop_assert!(g, got_st == want_st, "block_q={block_q} threads={threads}: stats");
                let mut flat = vec![0.0f32; rows * d];
                let flat_st = batch::run_rows_into(&mk(threads), &jobs, d, &mut flat);
                prop_assert!(
                    g,
                    flat == want.concat() && flat_st == want_st,
                    "block_q={block_q} threads={threads}: flat driver differs"
                );
            }
        }
        true
    });
}

#[test]
fn prop_permuting_kv_pairs_preserves_attention() {
    // Softmax attention is permutation-invariant over KV pairs; FLASH-D's
    // order-dependent recursion must still compute the same function.
    forall("kv-permutation", 60, |g| {
        let n = g.usize_in(2, 40);
        let d = 4usize;
        let q = g.vec_normal(d, 1.0);
        let k = g.vec_normal(n * d, 1.0);
        let v = g.vec_normal(n * d, 1.0);
        let base = fd::attention(&q, &k, &v, n, d, 1.0);
        // rotate the pairs by a random shift
        let shift = g.usize_in(1, n - 1);
        let mut k2 = vec![0.0f32; n * d];
        let mut v2 = vec![0.0f32; n * d];
        for i in 0..n {
            let j = (i + shift) % n;
            k2[i * d..(i + 1) * d].copy_from_slice(&k[j * d..(j + 1) * d]);
            v2[i * d..(i + 1) * d].copy_from_slice(&v[j * d..(j + 1) * d]);
        }
        let rot = fd::attention(&q, &k2, &v2, n, d, 1.0);
        prop_assert!(g, max_abs_diff(&base, &rot) < 5e-5, "order dependence detected");
        true
    });
}

#[test]
fn prop_hot_loop_primitives_bitmatch_scalar_reference() {
    // The crate-level dot / axpy_blend must be bit-identical to the scalar
    // reference for every slice length (tails included). Under
    // `--features simd` this pins the vectorized lanes to the scalar
    // unroll's accumulator order; on the default build it is an identity.
    forall("simd-scalar-bitmatch", 120, |g| {
        let len = g.usize_in(0, 70);
        let a = g.vec_normal(len, 1.3);
        let b = g.vec_normal(len, 1.3);
        prop_assert!(
            g,
            flashd::kernels::dot(&a, &b) == scalar::dot(&a, &b),
            "dot differs from scalar at len={len}"
        );
        let w = g.f64_in(0.0, 1.0) as f32;
        let mut o1 = g.vec_normal(len, 1.0);
        let mut o2 = o1.clone();
        flashd::kernels::axpy_blend(&mut o1, &a, w);
        scalar::axpy_blend(&mut o2, &a, w);
        prop_assert!(g, o1 == o2, "axpy_blend differs from scalar at len={len}");
        true
    });
}

#[test]
fn prop_quantized_kv_rows_bitmatch_dequantized_run_and_stay_enveloped() {
    // The quantized-KV contract is deterministic: running the kernel over
    // bf16/fp8 stores is the SAME sequence of f32 ops as running the plain
    // f32 kernel over dequantize(quantize(.)), so outputs and SkipStats
    // must be bit-identical. Against the unquantized f32 run the error is
    // enveloped by the format's relative precision.
    forall("kv-quantized-contract", 30, |g| {
        let rows = g.usize_in(1, 6);
        let n = g.usize_in(1, 64);
        let d = *g.choose(&[4usize, 8, 16]);
        let scale = 0.3f32;
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..rows)
            .map(|_| {
                let mut v = g.vec_normal(n * d, 0.3);
                // keep fp8's relative error an absolute envelope
                v.iter_mut().for_each(|x| *x = x.clamp(-0.6, 0.6));
                (g.vec_normal(d, 0.6), g.vec_normal(n * d, 0.6), v)
            })
            .collect();
        let cfg = KernelConfig { tile: 16, threads: 2, ..KernelConfig::default() };

        // unquantized f32 reference
        let jobs32: Vec<RowJob> = data
            .iter()
            .map(|(q, k, v)| RowJob { q, k, v, n, d, scale })
            .collect();
        let mut out32 = vec![0.0f32; rows * d];
        let mut scratch = BatchScratch::new();
        batch::run_rows_into_with(&cfg, &jobs32, d, &mut out32, &mut scratch);

        for fp8 in [false, true] {
            // quantize at rest, then dequantize to build the oracle operands
            let stores: Vec<(Vec<u16>, Vec<u8>, Vec<u16>, Vec<u8>)> = data
                .iter()
                .map(|(_, k, v)| {
                    (quantize_bf16(k), quantize_fp8(k), quantize_bf16(v), quantize_fp8(v))
                })
                .collect();
            let kvrefs: Vec<(KvRef, KvRef)> = stores
                .iter()
                .map(|(kb, k8, vb, v8)| {
                    if fp8 {
                        (KvRef::Fp8(k8.as_slice()), KvRef::Fp8(v8.as_slice()))
                    } else {
                        (KvRef::Bf16(kb.as_slice()), KvRef::Bf16(vb.as_slice()))
                    }
                })
                .collect();
            let jobs_q: Vec<KvRowJob> = data
                .iter()
                .zip(&kvrefs)
                .map(|((q, _, _), (k, v))| KvRowJob {
                    q,
                    k: KvView::Contig(*k),
                    v: KvView::Contig(*v),
                    n,
                    d,
                    scale,
                })
                .collect();
            let mut out_q = vec![0.0f32; rows * d];
            let st_q = batch::run_kv_rows_into_with(&cfg, &jobs_q, d, &mut out_q, &mut scratch);

            // oracle: plain f32 run over the dequantized operands
            let deq: Vec<(Vec<f32>, Vec<f32>)> = kvrefs
                .iter()
                .map(|(k, v)| (k.to_f32_vec(), v.to_f32_vec()))
                .collect();
            let jobs_o: Vec<RowJob> = data
                .iter()
                .zip(&deq)
                .map(|((q, _, _), (k, v))| RowJob { q, k, v, n, d, scale })
                .collect();
            let mut out_o = vec![0.0f32; rows * d];
            let st_o = batch::run_rows_into_with(&cfg, &jobs_o, d, &mut out_o, &mut scratch);
            prop_assert!(g, out_q == out_o, "fp8={fp8}: not bit-identical to dequantized run");
            prop_assert!(g, st_q == st_o, "fp8={fp8}: stats differ from dequantized run");

            // envelope vs the full-precision run
            let bound = if fp8 { 5e-2 } else { 1e-2 };
            let err = max_abs_diff(&out_q, &out32);
            prop_assert!(g, err <= bound, "fp8={fp8}: err {err} > {bound} (n={n} d={d})");
        }
        true
    });
}

#[test]
fn prop_pwl_sigmoid_end_to_end_enveloped_by_table_error() {
    // Opt-in PWL sigmoid: the end-to-end attention error is controlled by
    // the measured table errors (sigmoid + ln), scaled by the value range
    // — the output stays a convex-ish combination of values, so per-step
    // weight perturbations cannot amplify past the value spread.
    forall("pwl-envelope", 30, |g| {
        let rows = g.usize_in(1, 4);
        let n = g.usize_in(2, 64);
        let d = *g.choose(&[4usize, 8]);
        let data: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..rows)
            .map(|_| (g.vec_normal(d, 0.8), g.vec_normal(n * d, 0.8), g.vec_normal(n * d, 1.0)))
            .collect();
        let jobs: Vec<RowJob> = data
            .iter()
            .map(|(q, k, v)| RowJob { q, k, v, n, d, scale: 0.4 })
            .collect();
        let exact_cfg = KernelConfig { tile: 16, threads: 1, ..KernelConfig::default() };
        let (exact, _) = batch::run_rows(&exact_cfg, &jobs);
        for segments in [8usize, 16] {
            let tables = SigTables::new(segments);
            let es = tables.sigmoid_max_error() as f32;
            let el = tables.ln_max_error() as f32;
            let cfg = KernelConfig {
                sigmoid: SigmoidMode::Pwl { segments },
                ..exact_cfg
            };
            let (pwl, _) = batch::run_rows(&cfg, &jobs);
            let vmax = data
                .iter()
                .flat_map(|(_, _, v)| v.iter())
                .fold(0.0f32, |a, &b| a.max(b.abs()));
            let bound = (3.0 * (es + el)).max(0.25) * vmax + 1e-4;
            for (i, row) in pwl.iter().enumerate() {
                let err = max_abs_diff(row, &exact[i]);
                prop_assert!(
                    g,
                    err <= bound,
                    "segments={segments} row {i}: err {err} > {bound}"
                );
            }
        }
        true
    });
}
