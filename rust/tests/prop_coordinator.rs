//! Property-based tests of the coordinator invariants: routing, batching,
//! and KV-session state management (the L3 proptest coverage DESIGN.md
//! calls out).

use flashd::coordinator::batcher::{form_batches, member_row_spans, BatchPolicy};
use flashd::coordinator::kv_cache::SessionStore;
use flashd::coordinator::request::{AttentionRequest, RequestKind, ShapeSig, Variant};
use flashd::coordinator::router::Router;
use flashd::coordinator::scheduler::{Policy, Scheduler};
use flashd::prop_assert;
use flashd::runtime::Manifest;
use flashd::util::prop::forall;
use std::time::Instant;

fn mk_request(g: &mut flashd::util::prop::Gen, id: u64) -> AttentionRequest {
    let decode = g.bool();
    let session = g.usize_in(0, 3) as u64;
    let sig = ShapeSig { heads: 1, head_dim: 4 };
    let (kind, nq, nkv) = if decode {
        (RequestKind::Decode { session }, 1usize, 1usize)
    } else if g.bool() {
        (RequestKind::prefill(session), 1, g.usize_in(1, 8))
    } else {
        (RequestKind::Stateless, g.usize_in(1, 4), g.usize_in(1, 8))
    };
    let variant = if g.bool() { Variant::FlashD } else { Variant::Flash2 };
    AttentionRequest {
        id,
        kind,
        variant,
        sig,
        q: vec![0.1; 4 * nq],
        nq,
        k: vec![0.1; 4 * nkv],
        v: vec![0.1; 4 * nkv],
        nkv,
        submitted_at: Instant::now(),
    }
}

#[test]
fn prop_batcher_partitions_exactly() {
    forall("batcher-partition", 150, |g| {
        let n = g.usize_in(0, 24);
        let reqs: Vec<AttentionRequest> = (0..n).map(|i| mk_request(g, i as u64)).collect();
        let max_batch = g.usize_in(1, 6);
        let batches = form_batches(&reqs, &BatchPolicy { max_batch });

        // every index in exactly one batch
        let mut seen = vec![0usize; n];
        for b in &batches {
            prop_assert!(g, b.members.len() <= max_batch, "batch over max");
            prop_assert!(g, !b.members.is_empty(), "empty batch");
            for &i in &b.members {
                prop_assert!(g, i < n, "index out of range");
                seen[i] += 1;
            }
            // multi-member batches: all decode, same (session, variant, sig)
            if b.members.len() > 1 {
                let first = &reqs[b.members[0]];
                for &i in &b.members {
                    let r = &reqs[i];
                    prop_assert!(g, r.is_decode(), "non-decode in multi batch");
                    prop_assert!(
                        g,
                        r.session() == first.session()
                            && r.variant == first.variant
                            && r.sig == first.sig,
                        "mixed batch"
                    );
                }
            }
        }
        prop_assert!(g, seen.iter().all(|&c| c == 1), "partition broken: {seen:?}");
        true
    });
}

#[test]
fn prop_scheduler_conserves_requests() {
    forall("scheduler-conservation", 120, |g| {
        let cap = g.usize_in(1, 16);
        let policy = if g.bool() { Policy::Fifo } else { Policy::DecodeFirst };
        let mut s = Scheduler::new(cap, policy);
        let n = g.usize_in(0, 30);
        let mut accepted = 0u64;
        for i in 0..n {
            if s.submit(mk_request(g, i as u64)).is_ok() {
                accepted += 1;
            }
        }
        prop_assert!(g, s.len() as u64 == accepted, "len != accepted");
        prop_assert!(g, accepted <= cap as u64, "over capacity");
        let drained = s.drain(usize::MAX);
        prop_assert!(g, drained.len() as u64 == accepted, "drain lost requests");
        prop_assert!(g, s.is_empty(), "queue not empty after full drain");
        // no duplicate ids
        let mut ids: Vec<u64> = drained.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        prop_assert!(g, ids.len() == drained.len(), "duplicated request");
        true
    });
}

/// Satellite regression (PR 8): Fifo drains strictly in admission order —
/// the admission-stamped `seq` is the only tiebreak, so interleaving
/// partial cycle drains with fresh admissions (exactly what continuous
/// batching does) must never reorder requests.
#[test]
fn prop_fifo_drain_is_admission_order() {
    forall("fifo-admission-order", 120, |g| {
        let cap = g.usize_in(1, 24);
        let mut s = Scheduler::new(cap, Policy::Fifo);
        let mut admitted: Vec<u64> = Vec::new();
        let mut drained: Vec<u64> = Vec::new();
        let n = g.usize_in(0, 40);
        for i in 0..n {
            // partial mid-stream drains exercise seq ordering across cycles
            if g.bool() && g.bool() {
                s.begin_cycle();
                drained.extend(s.drain(g.usize_in(1, 4)).iter().map(|r| r.id));
            }
            if s.submit(mk_request(g, i as u64)).is_ok() {
                admitted.push(i as u64);
            }
        }
        drained.extend(s.drain(usize::MAX).iter().map(|r| r.id));
        prop_assert!(g, drained == admitted, "Fifo drained out of admission order");
        true
    });
}

/// Pool invariants survive arbitrary interleavings of every mutating
/// store operation: refcounts always equal the live table references, no
/// block leaks or double-frees, byte accounting stays block-exact, and
/// eviction/CoW under a tight budget never corrupts the structures.
#[test]
fn prop_session_store_invariants_under_random_ops() {
    use flashd::numerics::quant::KvPrecision;
    forall("kv-store-invariants", 100, |g| {
        // tiny blocks + tight budget exercise eviction and CoW constantly
        let bs = g.usize_in(1, 4);
        let bb = 2 * bs * 2 * 4; // f32 block bytes: 1 head, dim 2
        let budget = g.usize_in(2, 10) * bb;
        let mut store = SessionStore::with_block_steps(budget, KvPrecision::F32, bs);
        let ops = g.usize_in(1, 80);
        for i in 0..ops {
            let sid = g.usize_in(0, 5) as u64;
            match g.usize_in(0, 6) {
                0 => {
                    // create: 1 head, dim 2, random cap (may exceed
                    // budget), sometimes with a sliding window
                    let cap = g.usize_in(1, 12);
                    let window = if g.bool() { Some(g.usize_in(1, 8)) } else { None };
                    let _ = store.create_windowed(sid, 1, 2, cap, window);
                }
                1 => {
                    let n = g.usize_in(1, 3);
                    let x = i as f32 * 0.1;
                    let _ = store.append(sid, &vec![x; 2 * n], &vec![x; 2 * n], n);
                }
                2 => {
                    let dst = g.usize_in(0, 5) as u64;
                    let _ = store.fork(sid, dst);
                }
                3 => {
                    let dst = g.usize_in(0, 5) as u64;
                    let steps = g.usize_in(0, 8);
                    let _ = store.share_prefix(sid, dst, steps);
                }
                4 => store.remove(sid),
                5 => {
                    // retarget the window (may legally refuse: widening
                    // past already-trimmed history is a typed error)
                    let window = if g.bool() { Some(g.usize_in(1, 8)) } else { None };
                    let _ = store.set_window(sid, window);
                }
                _ => {
                    // gather builds the borrowed paged view end to end
                    if let Some(view) = store.gather(sid) {
                        let _ = view.head_k(0).to_f32_vec();
                    }
                }
            }
            if let Err(e) = store.check_invariants() {
                prop_assert!(g, false, "invariant broken after op {i}: {e}");
            }
        }
        true
    });
}

/// Copy-on-write correctness: after a fork, divergent appends on both
/// lineages never disturb the shared prefix, and full prefix blocks stay
/// physically shared (same pool slots in both tables).
#[test]
fn prop_fork_cow_preserves_both_lineages() {
    use flashd::numerics::quant::KvPrecision;
    forall("kv-fork-cow", 100, |g| {
        let bs = g.usize_in(1, 5);
        let mut store = SessionStore::with_block_steps(1 << 20, KvPrecision::F32, bs);
        store.create(1, 1, 2, 64).unwrap();
        let pre = g.usize_in(1, 12);
        for i in 0..pre {
            let x = i as f32 * 0.5 + 0.1;
            store.append(1, &[x, -x], &[-x, x], 1).unwrap();
        }
        let base = store.gather(1).unwrap().head_k(0).to_f32_vec();
        store.fork(1, 2).unwrap();
        let (na, nb) = (g.usize_in(0, 6), g.usize_in(1, 6));
        for i in 0..na {
            let x = 100.0 + i as f32;
            store.append(1, &[x, x], &[x, x], 1).unwrap();
        }
        for i in 0..nb {
            let x = 200.0 + i as f32;
            store.append(2, &[x, x], &[x, x], 1).unwrap();
        }
        let k1 = store.gather(1).unwrap().head_k(0).to_f32_vec();
        let k2 = store.gather(2).unwrap().head_k(0).to_f32_vec();
        prop_assert!(g, k1[..pre * 2] == base[..], "src prefix corrupted");
        prop_assert!(g, k2[..pre * 2] == base[..], "fork prefix corrupted");
        prop_assert!(g, k1.len() == (pre + na) * 2, "src len");
        prop_assert!(g, k2.len() == (pre + nb) * 2, "fork len");
        // full prefix blocks are stored once: both tables point at them
        let full = pre / bs;
        let t1 = store.get(1).unwrap().blocks().to_vec();
        let t2 = store.get(2).unwrap().blocks().to_vec();
        prop_assert!(g, t1[..full] == t2[..full], "full prefix blocks not shared");
        if let Err(e) = store.check_invariants() {
            prop_assert!(g, false, "invariant broken: {e}");
        }
        true
    });
}

#[test]
fn prop_router_choice_is_minimal_and_sufficient() {
    let manifest = Manifest::parse(
        r#"{"artifacts": {
        "a64": {"file":"a","kind":"attention","variant":"flashd","causal":false,
          "heads":2,"seq":64,"head_dim":8,"inputs":[],"n_outputs":1},
        "a128": {"file":"b","kind":"attention","variant":"flashd","causal":false,
          "heads":2,"seq":128,"head_dim":8,"inputs":[],"n_outputs":1},
        "a256": {"file":"c","kind":"attention","variant":"flashd","causal":false,
          "heads":2,"seq":256,"head_dim":8,"inputs":[],"n_outputs":1}
      }}"#,
    )
    .unwrap();
    let router = Router::from_manifest(&manifest);
    let sig = ShapeSig { heads: 2, head_dim: 8 };
    forall("router-minimal", 200, |g| {
        let nq = g.usize_in(1, 300);
        let nkv = g.usize_in(1, 300);
        match router.route(Variant::FlashD, sig, nq, nkv) {
            Ok(r) => {
                prop_assert!(g, r.kv_slots >= nkv, "kv doesn't fit");
                prop_assert!(g, r.q_slots >= nq, "q doesn't fit");
                // minimality: the next smaller compiled seq must not fit
                let need = nq.max(nkv);
                let smaller = [64usize, 128, 256]
                    .iter()
                    .filter(|&&s| s < r.kv_slots)
                    .max()
                    .copied();
                if let Some(s) = smaller {
                    prop_assert!(g, s < need, "route not minimal: {s} would fit {need}");
                }
            }
            Err(_) => {
                prop_assert!(g, nq.max(nkv) > 256, "spurious routing failure nq={nq} nkv={nkv}");
            }
        }
        true
    });
}

/// Fused-path lowering invariants: the batch annotations are consistent
/// with the members, and the member row spans partition the fused query
/// block — so every pending request is lowered into exactly one
/// `BlockJob` span per head, and `max_batch` / same-(session, variant,
/// signature) invariants survive lowering.
#[test]
fn prop_fused_lowering_covers_every_request_exactly_once() {
    forall("fused-lowering-cover", 150, |g| {
        let n = g.usize_in(0, 24);
        let reqs: Vec<AttentionRequest> = (0..n).map(|i| mk_request(g, i as u64)).collect();
        let max_batch = g.usize_in(1, 6);
        let batches = form_batches(&reqs, &BatchPolicy { max_batch });
        let mut covered = vec![0usize; n];
        for b in &batches {
            prop_assert!(g, b.members.len() <= max_batch, "batch over max");
            let first = &reqs[b.members[0]];
            prop_assert!(
                g,
                b.variant == first.variant && b.sig == first.sig,
                "annotation mismatch"
            );
            prop_assert!(g, b.session == first.session(), "session annotation mismatch");
            prop_assert!(g, b.decode == first.is_decode(), "decode annotation mismatch");
            if b.decode {
                for &i in &b.members {
                    prop_assert!(
                        g,
                        reqs[i].session() == b.session
                            && reqs[i].variant == b.variant
                            && reqs[i].sig == b.sig,
                        "unmergeable member survived lowering"
                    );
                }
            }
            let nqs: Vec<usize> = b.members.iter().map(|&i| reqs[i].nq).collect();
            prop_assert!(g, b.total_q == nqs.iter().sum::<usize>(), "total_q mismatch");
            let spans = member_row_spans(&nqs);
            let mut row = 0usize;
            for (k, &(row0, nq)) in spans.iter().enumerate() {
                prop_assert!(g, row0 == row && nq == nqs[k], "span broken");
                row += nq;
                covered[b.members[k]] += 1;
            }
            prop_assert!(g, row == b.total_q, "spans don't cover the query block");
        }
        prop_assert!(
            g,
            covered.iter().all(|&c| c == 1),
            "request lowered into != exactly one span: {covered:?}"
        );
        true
    });
}

/// Under `DecodeFirst` with bounded drain cycles, no admitted request
/// starves: once arrivals stop, the backlog clears in exactly
/// ceil(len / drain_max) cycles and every admitted request is drained
/// exactly once, decodes always ahead of prefill/stateless in a cycle.
#[test]
fn prop_decode_first_never_starves_across_drain_cycles() {
    forall("no-starvation", 100, |g| {
        let cap = g.usize_in(4, 24);
        let mut s = Scheduler::new(cap, Policy::DecodeFirst);
        s.drain_max = g.usize_in(1, 6);
        let drain_max = s.drain_max;
        let mut admitted: Vec<u64> = Vec::new();
        let mut drained: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let cycles = g.usize_in(1, 10);
        for _ in 0..cycles {
            for _ in 0..g.usize_in(0, 4) {
                let r = mk_request(g, next_id);
                if s.submit(r).is_ok() {
                    admitted.push(next_id);
                }
                next_id += 1;
            }
            let cycle = s.drain_cycle();
            prop_assert!(g, cycle.len() <= drain_max, "cycle over drain_max");
            if let Some(p) = cycle.iter().position(|r| !r.is_decode()) {
                prop_assert!(
                    g,
                    cycle[p..].iter().all(|r| !r.is_decode()),
                    "decode scheduled after non-decode in a DecodeFirst cycle"
                );
            }
            drained.extend(cycle.iter().map(|r| r.id));
        }
        // arrivals stop: the backlog must clear without starvation
        let backlog = s.len();
        let bound = backlog.div_ceil(drain_max);
        let mut extra = 0usize;
        while !s.is_empty() {
            let cycle = s.drain_cycle();
            prop_assert!(g, !cycle.is_empty(), "empty drain with backlog");
            prop_assert!(g, cycle.len() <= drain_max, "cycle over drain_max");
            drained.extend(cycle.iter().map(|r| r.id));
            extra += 1;
            prop_assert!(g, extra <= bound, "starved: {extra} cycles for backlog {backlog}");
        }
        admitted.sort();
        drained.sort();
        prop_assert!(g, admitted == drained, "admitted != drained exactly once");
        true
    });
}

#[test]
fn prop_kv_append_preserves_prior_content() {
    use flashd::numerics::quant::KvPrecision;
    forall("kv-append-prefix", 100, |g| {
        let bs = g.usize_in(1, 5);
        let cap = g.usize_in(2, 12);
        let mut store = SessionStore::with_block_steps(1 << 20, KvPrecision::F32, bs);
        store.create(9, 1, 2, cap).unwrap();
        let mut history: Vec<(f32, f32)> = Vec::new();
        let n_ops = g.usize_in(1, cap);
        for i in 0..n_ops {
            let kv = (i as f32 + 0.25, i as f32 * 2.0);
            store.append(9, &[kv.0, kv.1], &[kv.1, kv.0], 1).unwrap();
            history.push(kv);
            // all earlier entries still intact across block boundaries
            // (f32 store: exact)
            let kf = store.gather(9).unwrap().head_k(0).to_f32_vec();
            for (j, (a, b)) in history.iter().enumerate() {
                prop_assert!(
                    g,
                    kf[j * 2] == *a && kf[j * 2 + 1] == *b,
                    "slot {j} corrupted after append {i}"
                );
            }
        }
        prop_assert!(g, store.get(9).unwrap().len == n_ops, "len mismatch");
        true
    });
}

/// Quantized block pools: appending is a projection (quantize once, stays
/// fixed), earlier rows are never re-rounded by later appends, and the
/// block-granular byte accounting matches the precision.
#[test]
fn prop_quantized_kv_append_is_stable_projection() {
    use flashd::numerics::quant::KvPrecision;
    forall("kv-append-quantized", 100, |g| {
        let prec = if g.bool() { KvPrecision::Bf16 } else { KvPrecision::Fp8 };
        let bs = g.usize_in(1, 5);
        let cap = g.usize_in(2, 12);
        let mut store = SessionStore::with_block_steps(1 << 20, prec, bs);
        store.create(1, 1, 2, cap).unwrap();
        let n_ops = g.usize_in(1, cap);
        let mut snapshot: Vec<f32> = Vec::new();
        for i in 0..n_ops {
            // modest magnitudes so fp8 stays in range
            let a = (i as f32 * 0.37 - 1.0).sin();
            let b = (i as f32 * 0.91 + 0.5).cos();
            store.append(1, &[a, b], &[b, a], 1).unwrap();
            let kf = store.gather(1).unwrap().head_k(0).to_f32_vec();
            // earlier rows bit-stable across appends
            prop_assert!(
                g,
                kf[..snapshot.len()] == snapshot[..],
                "earlier rows re-rounded at append {i}"
            );
            // re-storing a dequantized value is a fixed point
            let row = kf[i * 2..i * 2 + 2].to_vec();
            let mut probe = SessionStore::with_block_steps(1 << 20, prec, bs);
            probe.create(1, 1, 2, 1).unwrap();
            probe.append(1, &row, &row, 1).unwrap();
            prop_assert!(
                g,
                probe.gather(1).unwrap().head_k(0).to_f32_vec() == row,
                "quantize not a projection at append {i}"
            );
            snapshot = kf;
        }
        // block-granular accounting: resident bytes are whole blocks at
        // the store precision, independent of tail fill
        let bb = store.pool().block_bytes(1, 2);
        prop_assert!(g, bb == 2 * bs * 2 * prec.bytes_per_elem(), "block bytes");
        prop_assert!(g, store.bytes() == n_ops.div_ceil(bs) * bb, "byte accounting");
        true
    });
}

/// Tentpole property (sliding windows): over random windows, block sizes,
/// storage precisions, and fork lineages, the windowed gather view is
/// bit-identical to a store holding only the trimmed-to-window suffix —
/// and the FLASH-D kernel over the windowed view is bit-identical to the
/// full kernel over that suffix. The hidden-division recursion needs no
/// rescaling fix-up anywhere.
#[test]
fn prop_windowed_kernel_bit_identical_to_trimmed_full() {
    use flashd::kernels::batch::{run_kv_rows_into_with, BatchScratch, KernelConfig, KvRowJob};
    use flashd::numerics::quant::KvPrecision;
    forall("kv-windowed-bit-identical", 100, |g| {
        let prec = match g.usize_in(0, 2) {
            0 => KvPrecision::F32,
            1 => KvPrecision::Bf16,
            _ => KvPrecision::Fp8,
        };
        let bs = g.usize_in(1, 5);
        let w = g.usize_in(1, 10);
        let mut store = SessionStore::with_block_steps(1 << 20, prec, bs);
        store.create_windowed(1, 1, 2, 64, Some(w)).unwrap();
        // modest magnitudes so fp8 stays in range
        let row = |i: usize| {
            let a = (i as f32 * 0.37 - 1.0).sin();
            let b = (i as f32 * 0.91 + 0.5).cos();
            ([a, b], [b, a])
        };
        let mut hist1: Vec<usize> = Vec::new();
        for i in 0..g.usize_in(1, 20) {
            let (k, v) = row(i);
            store.append(1, &k, &v, 1).unwrap();
            hist1.push(i);
        }
        // fork: the lineage inherits the window (and any trimmed prefix),
        // then both sides diverge
        store.fork(1, 2).unwrap();
        let mut hist2 = hist1.clone();
        for j in 0..g.usize_in(0, 10) {
            let (k, v) = row(100 + j);
            store.append(1, &k, &v, 1).unwrap();
            hist1.push(100 + j);
            let (k, v) = row(200 + j);
            store.append(2, &k, &v, 1).unwrap();
            hist2.push(200 + j);
        }
        if let Err(e) = store.check_invariants() {
            prop_assert!(g, false, "invariant broken: {e}");
        }
        for (sid, hist) in [(1u64, &hist1), (2u64, &hist2)] {
            let att = hist.len().min(w);
            // reference: a fresh store holding exactly the in-window
            // suffix (per-element quantization makes this bit-faithful)
            let mut full = SessionStore::with_block_steps(1 << 20, prec, bs);
            full.create(9, 1, 2, 64).unwrap();
            for &i in &hist[hist.len() - att..] {
                let (k, v) = row(i);
                full.append(9, &k, &v, 1).unwrap();
            }
            let view = store.gather(sid).unwrap();
            let fview = full.gather(9).unwrap();
            prop_assert!(g, view.len == att, "attended len {} != {att}", view.len);
            prop_assert!(
                g,
                view.head_k(0).to_f32_vec() == fview.head_k(0).to_f32_vec()
                    && view.head_v(0).to_f32_vec() == fview.head_v(0).to_f32_vec(),
                "windowed view != trimmed suffix (sid {sid})"
            );
            let q = [0.3f32, -0.2];
            let cfg = KernelConfig { tile: bs, threads: 1, ..KernelConfig::default() };
            let mut scratch = BatchScratch::new();
            let mut out_w = vec![0.0f32; 2];
            let job = KvRowJob { q: &q, k: view.head_k(0), v: view.head_v(0), n: att, d: 2, scale: 0.7 };
            run_kv_rows_into_with(&cfg, &[job], 2, &mut out_w, &mut scratch);
            let mut out_f = vec![0.0f32; 2];
            let job = KvRowJob { q: &q, k: fview.head_k(0), v: fview.head_v(0), n: att, d: 2, scale: 0.7 };
            run_kv_rows_into_with(&cfg, &[job], 2, &mut out_f, &mut scratch);
            prop_assert!(g, out_w == out_f, "windowed kernel != full kernel over window (sid {sid})");
        }
        true
    });
}
