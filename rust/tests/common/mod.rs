//! Shared reference machinery for the serving conformance and stress
//! suites: a per-session reference KV plus direct per-request
//! `kernels::flashd` execution, bit-comparable to the coordinator's
//! output (the tiled/query-blocked serving kernels are bit-identical per
//! query to the scalar FLASH-D kernel under `SkipCriterion::None`).
#![allow(dead_code)]

use flashd::coordinator::request::{AttentionRequest, RequestKind, ShapeSig, Variant};
use flashd::coordinator::router::Router;
use flashd::kernels::flashd as fd;
use flashd::numerics::quant::{
    dequantize_bf16_into, dequantize_fp8_into, quantize_bf16, quantize_fp8, KvPrecision,
};
use flashd::runtime::Manifest;
use flashd::util::rng::Rng;
use std::time::Instant;

pub const HEADS: usize = 2;
pub const D: usize = 8;

/// Router over a synthetic manifest covering the test signature at two
/// context capacities.
pub fn test_router() -> Router {
    Router::from_manifest(
        &Manifest::parse(
            r#"{"artifacts": {
          "a64": {"file":"x","kind":"attention","variant":"flashd","causal":false,
            "heads":2,"seq":64,"head_dim":8,"inputs":[],"n_outputs":1},
          "a256": {"file":"y","kind":"attention","variant":"flashd","causal":false,
            "heads":2,"seq":256,"head_dim":8,"inputs":[],"n_outputs":1}
        }}"#,
        )
        .expect("manifest"),
    )
}

/// Quantize-roundtrip through the serving storage format — element-wise,
/// exactly what `KvStore` applies on append, so the reference KV matches
/// the engine's dequantized operands bit for bit at every precision.
pub fn quantize_roundtrip(prec: KvPrecision, xs: &[f32]) -> Vec<f32> {
    match prec {
        KvPrecision::F32 => xs.to_vec(),
        KvPrecision::Bf16 => {
            let bits = quantize_bf16(xs);
            let mut out = vec![0.0f32; xs.len()];
            dequantize_bf16_into(&bits, &mut out);
            out
        }
        KvPrecision::Fp8 => {
            let bits = quantize_fp8(xs);
            let mut out = vec![0.0f32; xs.len()];
            dequantize_fp8_into(&bits, &mut out);
            out
        }
    }
}

/// Per-session reference KV, per-head contiguous — the layout
/// `kernels::flashd::attention` consumes directly. Rows are stored
/// quantize-roundtripped at the session precision (a no-op for `F32`).
#[derive(Clone)]
pub struct RefKv {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub prec: KvPrecision,
}

impl RefKv {
    pub fn new() -> RefKv {
        RefKv::with_precision(KvPrecision::F32)
    }

    pub fn with_precision(prec: KvPrecision) -> RefKv {
        RefKv { k: vec![Vec::new(); HEADS], v: vec![Vec::new(); HEADS], prec }
    }

    pub fn len(&self) -> usize {
        self.k[0].len() / D
    }

    /// Append `(heads, n, d)`-flat request K/V.
    pub fn append(&mut self, k: &[f32], v: &[f32], n: usize) {
        for h in 0..HEADS {
            self.k[h].extend_from_slice(&quantize_roundtrip(self.prec, &k[h * n * D..(h + 1) * n * D]));
            self.v[h].extend_from_slice(&quantize_roundtrip(self.prec, &v[h * n * D..(h + 1) * n * D]));
        }
    }
}

/// Direct per-request reference execution: `kernels::flashd` per head and
/// query row, with the serving scale 1/sqrt(d).
pub fn reference_output(q: &[f32], nq: usize, kv: &RefKv) -> Vec<f32> {
    let n = kv.len();
    let scale = (D as f32).powf(-0.5);
    let mut out = vec![0.0f32; HEADS * nq * D];
    for h in 0..HEADS {
        for r in 0..nq {
            let row = fd::attention(
                &q[(h * nq + r) * D..(h * nq + r + 1) * D],
                &kv.k[h],
                &kv.v[h],
                n,
                D,
                scale,
            );
            out[(h * nq + r) * D..(h * nq + r + 1) * D].copy_from_slice(&row);
        }
    }
    out
}

pub fn mk_req(rng: &mut Rng, id: u64, kind: RequestKind, nq: usize, nkv: usize) -> AttentionRequest {
    let sig = ShapeSig { heads: HEADS, head_dim: D };
    AttentionRequest {
        id,
        kind,
        variant: Variant::FlashD,
        sig,
        q: rng.normal_vec(sig.flat(nq), 0.6),
        nq,
        k: rng.normal_vec(sig.flat(nkv), 0.6),
        v: rng.normal_vec(sig.flat(nkv), 1.0),
        nkv,
        submitted_at: Instant::now(),
    }
}

/// Update the reference KV for a request about to be submitted and return
/// the expected (bit-exact) output. Prefill replaces the session cache;
/// decode appends one pair; stateless attends its own payload. For Fork
/// the caller must pass `kv` already cloned from the *source* session's
/// reference — the divergent payload is then appended on top.
pub fn expect_for(req: &AttentionRequest, kv: &mut RefKv) -> Vec<f32> {
    match req.kind {
        RequestKind::Prefill { .. } => {
            *kv = RefKv::with_precision(kv.prec);
            kv.append(&req.k, &req.v, req.nkv);
        }
        RequestKind::Decode { .. } => kv.append(&req.k, &req.v, 1),
        RequestKind::Fork { .. } => kv.append(&req.k, &req.v, req.nkv),
        RequestKind::Stateless => {}
    }
    match req.kind {
        RequestKind::Stateless => {
            let mut own = RefKv::new();
            own.append(&req.k, &req.v, req.nkv);
            reference_output(&req.q, req.nq, &own)
        }
        _ => reference_output(&req.q, req.nq, kv),
    }
}
