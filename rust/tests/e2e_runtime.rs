//! End-to-end runtime tests: the compiled AOT artifacts execute under the
//! Rust PJRT client and agree with the Rust golden kernels / engine.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a notice) if the artifact directory is missing.

use flashd::kernels::{self, max_abs_diff};
use flashd::model::engine::Engine;
use flashd::runtime::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32, Runtime};
use flashd::util::rng::Rng;

fn artifact_dir() -> Option<std::path::PathBuf> {
    if !cfg!(pjrt_backend) {
        eprintln!("SKIP: PJRT backend not compiled in (build with RUSTFLAGS=\"--cfg pjrt_backend\")");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn attention_artifact_matches_rust_golden_kernel() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let (h, l, d) = (4usize, 128usize, 32usize);
    let name = "attn_flashd_h4_l128_d32";
    assert!(rt.manifest.artifacts.contains_key(name), "missing {name}");

    let mut rng = Rng::new(42);
    let q = rng.normal_vec(h * l * d, 0.5);
    let k = rng.normal_vec(h * l * d, 0.5);
    let v = rng.normal_vec(h * l * d, 1.0);
    let inputs = [
        lit_f32(&q, &[h, l, d]).unwrap(),
        lit_f32(&k, &[h, l, d]).unwrap(),
        lit_f32(&v, &[h, l, d]).unwrap(),
        lit_i32(&[l as i32], &[1, 1]).unwrap(),
    ];
    let out = rt.execute(name, &inputs).unwrap();
    let got = to_vec_f32(&out[0]).unwrap();
    assert_eq!(got.len(), h * l * d);

    // golden: per-head multi-query attention with the compiled 1/sqrt(d)
    let scale = (d as f32).powf(-0.5);
    for hh in 0..h {
        let off = hh * l * d;
        let want = kernels::naive::attention_multi(
            &q[off..off + l * d], &k[off..off + l * d], &v[off..off + l * d], l, l, d, scale,
        );
        let diff = max_abs_diff(&got[off..off + l * d], &want);
        assert!(diff < 2e-4, "head {hh}: {diff}");
    }
}

#[test]
fn flashd_and_flash2_artifacts_agree() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let (h, l, d) = (4usize, 128usize, 32usize);
    let mut rng = Rng::new(7);
    let q = rng.normal_vec(h * l * d, 0.5);
    let k = rng.normal_vec(h * l * d, 0.5);
    let v = rng.normal_vec(h * l * d, 1.0);
    let inputs = [
        lit_f32(&q, &[h, l, d]).unwrap(),
        lit_f32(&k, &[h, l, d]).unwrap(),
        lit_f32(&v, &[h, l, d]).unwrap(),
        lit_i32(&[100i32], &[1, 1]).unwrap(), // also exercise kv_len mask
    ];
    let a = to_vec_f32(&rt.execute("attn_flashd_h4_l128_d32", &inputs).unwrap()[0]).unwrap();
    let b = to_vec_f32(&rt.execute("attn_flash2_h4_l128_d32", &inputs).unwrap()[0]).unwrap();
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-4, "variants disagree: {diff}");
}

#[test]
fn kv_len_mask_matches_truncated_problem() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let (h, l, d) = (4usize, 128usize, 32usize);
    let kv_len = 57usize;
    let mut rng = Rng::new(9);
    let q = rng.normal_vec(h * l * d, 0.5);
    let k = rng.normal_vec(h * l * d, 0.5);
    let v = rng.normal_vec(h * l * d, 1.0);
    let inputs = [
        lit_f32(&q, &[h, l, d]).unwrap(),
        lit_f32(&k, &[h, l, d]).unwrap(),
        lit_f32(&v, &[h, l, d]).unwrap(),
        lit_i32(&[kv_len as i32], &[1, 1]).unwrap(),
    ];
    let got = to_vec_f32(&rt.execute("attn_flashd_h4_l128_d32", &inputs).unwrap()[0]).unwrap();
    let scale = (d as f32).powf(-0.5);
    for hh in 0..h {
        let off = hh * l * d;
        let want = kernels::naive::attention_multi(
            &q[off..off + l * d],
            &k[off..off + kv_len * d],
            &v[off..off + kv_len * d],
            l,
            kv_len,
            d,
            scale,
        );
        let diff = max_abs_diff(&got[off..off + l * d], &want);
        assert!(diff < 2e-4, "head {hh}: {diff}");
    }
}

#[test]
fn rust_engine_matches_model_fwd_artifact() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let name = "phi-tiny";
    let art = format!("model_fwd_{name}");
    if !rt.manifest.artifacts.contains_key(&art) {
        eprintln!("SKIP: {art} not lowered");
        return;
    }
    let info = rt.manifest.models[name].clone();
    // use the INIT weights so this test is independent of training
    let tensors = flashd::model::weights::read_fdw(dir.join(&info.init_weights)).unwrap();

    // PJRT path
    let mut inputs: Vec<flashd::runtime::Literal> = tensors
        .iter()
        .map(|t| lit_f32(&t.data, &t.shape).unwrap())
        .collect();
    let tokens: Vec<i32> = (0..info.seq_len as i32).map(|i| (i * 13 + 5) % 251).collect();
    inputs.push(lit_i32(&tokens, &[1, info.seq_len]).unwrap());
    let out = rt.execute(&art, &inputs).unwrap();
    let pjrt_logits = to_vec_f32(&out[0]).unwrap();
    assert_eq!(pjrt_logits.len(), info.seq_len * info.vocab_size);

    // Rust engine path (exact FLASH-D, no skipping)
    let mut engine = Engine::new(info.clone(), tensors).unwrap();
    engine.criterion = flashd::kernels::flashd::SkipCriterion::None;
    let (rust_logits, _) = engine.forward(&tokens);

    let diff = max_abs_diff(&pjrt_logits, &rust_logits);
    assert!(diff < 5e-3, "engine vs artifact logits differ: {diff}");
}

#[test]
fn train_step_artifact_reduces_loss() {
    let Some(dir) = artifact_dir() else { return };
    let opts = flashd::train::TrainOptions {
        model: "phi-tiny".into(),
        steps: 6,
        seed: 123,
        log_every: 100,
        save: false,
        quiet: true,
    };
    let report = flashd::train::train(&dir, &opts).unwrap();
    assert!(report.first_loss.is_finite() && report.final_loss.is_finite());
    // byte-level vocab 256: initial loss near ln(256) ~ 5.55
    assert!((report.first_loss - 5.55).abs() < 1.2, "first {}", report.first_loss);
    assert!(
        report.final_loss < report.first_loss,
        "{} -> {}",
        report.first_loss,
        report.final_loss
    );
}

#[test]
fn scalar_step_literal_roundtrip() {
    let Some(dir) = artifact_dir() else { return };
    let _rt = Runtime::open(&dir).unwrap();
    let lit = lit_i32_scalar(41);
    assert_eq!(lit.to_vec::<i32>().unwrap(), vec![41]);
}
