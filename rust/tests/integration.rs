//! Cross-module integration tests that don't need PJRT artifacts: the
//! coordinator over the NaiveEngine, the hardware model fed by real engine
//! traces, and the Table I machinery over a synthetic model.

use flashd::bench_harness::suites::{Suite, ALL_SUITES};
use flashd::bench_harness::table1;
use flashd::coordinator::request::{RequestKind, ShapeSig, Variant};
use flashd::coordinator::server::{Coordinator, CoordinatorConfig, NaiveEngine};
use flashd::coordinator::router::Router;
use flashd::hw::{activity, power, CostDb, Design, Format};
use flashd::kernels::flashd::SkipCriterion;
use flashd::model::engine::Engine;
use flashd::model::tokenizer::ByteTokenizer;
use flashd::model::weights::NamedTensor;
use flashd::runtime::{Manifest, ModelInfo};
use flashd::util::rng::Rng;
use std::time::Instant;

fn synthetic_model(seed: u64) -> Engine {
    let (vocab, seq, dm, nh, nl, dff) = (64usize, 32usize, 32usize, 2usize, 2usize, 48usize);
    let mut spec = vec![
        ("tok_emb".to_string(), vec![vocab, dm]),
        ("pos_emb".to_string(), vec![seq, dm]),
    ];
    for i in 0..nl {
        for (n, s) in [
            ("ln1", vec![dm]),
            ("wq", vec![dm, dm]),
            ("wk", vec![dm, dm]),
            ("wv", vec![dm, dm]),
            ("wo", vec![dm, dm]),
            ("ln2", vec![dm]),
            ("w_gate", vec![dm, dff]),
            ("w_up", vec![dm, dff]),
            ("w_down", vec![dff, dm]),
        ] {
            spec.push((format!("l{i}.{n}"), s));
        }
    }
    spec.push(("ln_f".to_string(), vec![dm]));
    let n_params = spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    let info = ModelInfo {
        name: format!("synthetic-{seed}"),
        vocab_size: vocab,
        seq_len: seq,
        d_model: dm,
        n_heads: nh,
        n_layers: nl,
        d_ff: dff,
        block_q: 8,
        block_k: 8,
        qk_gain: 2.75,
        n_params,
        param_spec: spec.clone(),
        init_weights: String::new(),
        train_lr: 1e-3,
        train_batch: 2,
    };
    let mut rng = Rng::new(seed);
    let tensors = spec
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let data = if name.contains("ln") { vec![1.0; n] } else { rng.normal_vec(n, 0.09) };
            NamedTensor { name: name.clone(), shape: shape.clone(), data }
        })
        .collect();
    Engine::new(info, tensors).unwrap()
}

#[test]
fn table1_pipeline_over_synthetic_model() {
    let mut engine = synthetic_model(5);
    let opts = table1::Table1Options {
        prompts_per_suite: 2,
        decode_tokens: 4,
        seed: 3,
        criterion: SkipCriterion::Static,
    };
    let cells = table1::run_model(&mut engine, &opts);
    assert_eq!(cells.len(), ALL_SUITES.len());
    for c in &cells {
        assert!(c.total > 0, "{}: no updates measured", c.suite);
        assert!(c.skip_pct >= 0.0 && c.skip_pct <= 100.0);
        assert_eq!(c.skip_low + c.skip_high, (c.skip_pct / 100.0 * c.total as f64).round() as u64);
    }
    let rendered = table1::render_table(&cells);
    for s in ALL_SUITES {
        assert!(rendered.contains(s.name()));
    }
}

#[test]
fn engine_traces_drive_power_model_end_to_end() {
    let engine = synthetic_model(8);
    let tok = ByteTokenizer;
    let prompt = Suite::Gsm8k.prompts(1, 1).remove(0);
    let ids = tok.encode_window(&prompt, engine.info.seq_len);
    let (_, _, problems) = engine.forward_capture(&ids);
    assert_eq!(problems.len(), engine.info.n_layers * engine.info.n_heads);

    let act = activity::measure::<flashd::numerics::Bf16>(&problems);
    assert!(act.alpha_kv > 0.0 && act.alpha_kv <= 1.0);

    let db = CostDb::tsmc28();
    for &d in &[16usize, 64] {
        let fa2 = power::block_power_mw(Design::FlashAttention2, d, Format::BF16, &act, &db);
        let fd = power::block_power_mw(Design::FlashD, d, Format::BF16, &act, &db);
        assert!(fd < fa2, "d={d}: {fd} !< {fa2}");
    }
}

#[test]
fn coordinator_full_session_lifecycle_against_reference() {
    // Router over a synthetic manifest; NaiveEngine (rust FLASH-D kernel).
    let router = Router::from_manifest(
        &Manifest::parse(
            r#"{"artifacts": {
          "x": {"file":"x","kind":"attention","variant":"flashd","causal":false,
            "heads":2,"seq":64,"head_dim":8,"inputs":[],"n_outputs":1}
        }}"#,
        )
        .unwrap(),
    );
    let cfg = CoordinatorConfig {
        batch_window: std::time::Duration::from_micros(20),
        ..Default::default()
    };
    let coord = Coordinator::start_with(cfg, move || Ok(NaiveEngine::new(router))).unwrap();

    let sig = ShapeSig { heads: 2, head_dim: 8 };
    let mut rng = Rng::new(77);
    let hd = 16usize;

    // prefill 10 pairs
    let pk = rng.normal_vec(hd * 10, 0.6);
    let pv = rng.normal_vec(hd * 10, 1.0);
    let resp = coord.submit_blocking(flashd::coordinator::AttentionRequest {
        id: 1,
        kind: RequestKind::prefill(3),
        variant: Variant::FlashD,
        sig,
        q: rng.normal_vec(hd, 0.6),
        nq: 1,
        k: pk.clone(),
        v: pv.clone(),
        nkv: 10,
        submitted_at: Instant::now(),
    });
    assert!(resp.output.is_ok());

    // 20 sequential decode steps; verify the last against a from-scratch
    // reference over the accumulated KV.
    let mut all_k = pk;
    let mut all_v = pv;
    let mut last_q = Vec::new();
    let mut last_out = Vec::new();
    for step in 0..20u64 {
        let q = rng.normal_vec(hd, 0.6);
        let k = rng.normal_vec(hd, 0.6);
        let v = rng.normal_vec(hd, 1.0);
        // maintain reference copies (heads-major layout)
        let old_n = all_k.len() / hd;
        let mut nk = vec![0.0f32; (old_n + 1) * hd];
        let mut nv = vec![0.0f32; (old_n + 1) * hd];
        for h in 0..2 {
            let d = 8;
            let src = h * old_n * d;
            let dst = h * (old_n + 1) * d;
            nk[dst..dst + old_n * d].copy_from_slice(&all_k[src..src + old_n * d]);
            nv[dst..dst + old_n * d].copy_from_slice(&all_v[src..src + old_n * d]);
            nk[dst + old_n * d..dst + (old_n + 1) * d].copy_from_slice(&k[h * d..(h + 1) * d]);
            nv[dst + old_n * d..dst + (old_n + 1) * d].copy_from_slice(&v[h * d..(h + 1) * d]);
        }
        all_k = nk;
        all_v = nv;

        let resp = coord.submit_blocking(flashd::coordinator::AttentionRequest {
            id: 10 + step,
            kind: RequestKind::Decode { session: 3 },
            variant: Variant::FlashD,
            sig,
            q: q.clone(),
            nq: 1,
            k,
            v,
            nkv: 1,
            submitted_at: Instant::now(),
        });
        last_q = q;
        last_out = resp.output.expect("decode ok");
    }

    let n = all_k.len() / hd;
    let scale = (8f32).powf(-0.5);
    for h in 0..2 {
        let d = 8;
        let ks = &all_k[h * n * d..(h + 1) * n * d];
        let vs = &all_v[h * n * d..(h + 1) * n * d];
        let want = flashd::kernels::naive::attention(&last_q[h * d..(h + 1) * d], ks, vs, n, d, scale);
        let got = &last_out[h * d..(h + 1) * d];
        let diff = flashd::kernels::max_abs_diff(got, &want);
        assert!(diff < 1e-4, "head {h}: {diff}");
    }
    coord.shutdown();
}

#[test]
fn suites_cover_table1_columns() {
    let names: Vec<&str> = ALL_SUITES.iter().map(|s| s.name()).collect();
    assert_eq!(
        names,
        vec!["CSQA", "GSM8K", "QASC", "MMLU", "Date", "ObjectTracking"]
    );
}
