//! Concurrency stress + determinism: N client threads × M sessions
//! against the engine thread. Every request must get exactly one
//! response, every output must be bit-identical to direct per-request
//! reference execution (which proves independence from drain timing and
//! `KernelConfig::threads`), and the fused-dispatch metrics must sum
//! consistently with the workload.

mod common;

use common::{expect_for, mk_req, test_router, RefKv, HEADS};
use flashd::coordinator::metrics::Snapshot;
use flashd::coordinator::request::RequestKind;
use flashd::coordinator::{Coordinator, CoordinatorConfig};
use flashd::kernels::batch::KernelConfig;
use flashd::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

const CLIENTS: u64 = 4;
const SESSIONS_PER_CLIENT: u64 = 2;
const DECODE_STEPS: usize = 6;

/// Timing-independent expected totals, accumulated while driving.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct Totals {
    requests: u64,
    rows: u64,
    kv_appends: u64,
    /// FLASH-D weight-update steps: heads * nq * (n_at_execution - 1) per
    /// request. Deterministic because each client keeps at most one
    /// request in flight per session, so same-session decode fusion never
    /// changes a request's KV length.
    steps: u64,
}

impl Totals {
    fn merge(&mut self, o: Totals) {
        self.requests += o.requests;
        self.rows += o.rows;
        self.kv_appends += o.kv_appends;
        self.steps += o.steps;
    }
}

struct SessDriver {
    sid: u64,
    kv: RefKv,
    rng: Rng,
    next_id: u64,
}

impl SessDriver {
    fn new(sid: u64) -> SessDriver {
        SessDriver { sid, kv: RefKv::new(), rng: Rng::new(0xFEED ^ sid), next_id: sid * 10_000 + 1 }
    }
}

/// Submit one request blocking, assert bit-equality to the reference, and
/// account its totals.
fn step(
    coord: &Coordinator,
    dr: &mut SessDriver,
    kind: RequestKind,
    nq: usize,
    nkv: usize,
    totals: &mut Totals,
    outs: &mut Vec<(u64, Vec<f32>)>,
) {
    let req = mk_req(&mut dr.rng, dr.next_id, kind, nq, nkv);
    dr.next_id += 1;
    let expected = expect_for(&req, &mut dr.kv);
    let n_exec = match req.kind {
        RequestKind::Stateless => req.nkv,
        _ => dr.kv.len(),
    };
    totals.requests += 1;
    totals.rows += nq as u64;
    totals.kv_appends += match req.kind {
        RequestKind::Prefill { .. } | RequestKind::Fork { .. } => nkv as u64,
        RequestKind::Decode { .. } => 1,
        RequestKind::Stateless => 0,
    };
    totals.steps += (HEADS * nq * (n_exec - 1)) as u64;
    let id = req.id;
    let resp = coord.submit_blocking(req);
    let out = resp.output.expect("request failed under stress");
    assert_eq!(out, expected, "request {id} diverged from reference");
    outs.push((id, out));
}

/// One client thread: prefill its sessions, then an interleaved decode
/// stream across them with stateless requests sprinkled in.
fn drive_client(coord: &Coordinator, t: u64) -> (Totals, Vec<(u64, Vec<f32>)>) {
    let mut totals = Totals::default();
    let mut outs = Vec::new();
    let mut drivers: Vec<SessDriver> = (0..SESSIONS_PER_CLIENT)
        .map(|s| SessDriver::new(t * SESSIONS_PER_CLIENT + s))
        .collect();
    for dr in drivers.iter_mut() {
        let p = 6 + (dr.sid as usize % 4) * 2;
        let kind = RequestKind::prefill(dr.sid);
        step(coord, dr, kind, 1, p, &mut totals, &mut outs);
    }
    let mut stl = SessDriver::new(900 + t);
    for round in 0..DECODE_STEPS {
        for di in 0..drivers.len() {
            let kind = RequestKind::Decode { session: drivers[di].sid };
            step(coord, &mut drivers[di], kind, 1, 1, &mut totals, &mut outs);
        }
        if round % 3 == 0 {
            step(coord, &mut stl, RequestKind::Stateless, 2, 12, &mut totals, &mut outs);
        }
    }
    (totals, outs)
}

fn run_stress(kernel_threads: usize) -> (Vec<(u64, Vec<f32>)>, Snapshot, Totals) {
    let cfg = CoordinatorConfig {
        batch_window: Duration::from_micros(150),
        kernel: KernelConfig { tile: 8, block_q: 4, threads: kernel_threads, ..KernelConfig::default() },
        // the stress suite doubles as a pool-invariant audit per cycle
        validate_invariants: true,
        ..CoordinatorConfig::default()
    };
    let coord = Arc::new(Coordinator::start_naive(cfg, test_router()).expect("start"));
    let mut handles = Vec::new();
    for t in 0..CLIENTS {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || drive_client(&c, t)));
    }
    let mut totals = Totals::default();
    let mut outs: Vec<(u64, Vec<f32>)> = Vec::new();
    for h in handles {
        let (tt, o) = h.join().expect("client thread panicked");
        totals.merge(tt);
        outs.extend(o);
    }
    outs.sort_by_key(|(id, _)| *id);
    let snap = coord.metrics.snapshot();
    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown(),
        Err(_) => panic!("coordinator still shared"),
    }
    (outs, snap, totals)
}

fn assert_metrics_consistent(snap: &Snapshot, totals: &Totals) {
    // exactly one response per request, none lost, none failed
    assert_eq!(snap.requests, totals.requests);
    assert_eq!(snap.responses, totals.requests);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.queue_rejections, 0);
    assert_eq!(snap.batched_requests, totals.requests);
    // continuous batching observes one queue-wait sample per admission
    assert_eq!(snap.queue_wait.count, totals.requests);
    // KV accounting
    assert_eq!(snap.kv_appends, totals.kv_appends);
    // kernel-step accounting: the fused path executed every row with the
    // exact kernel; the step count is timing-independent
    assert_eq!(snap.skip_steps, totals.steps);
    assert_eq!(snap.skip_skipped, 0);
    // fused lowering invariants: every admitted batch lowered, one job per
    // (batch, head), every query row served exactly once
    assert_eq!(snap.fused_batches, snap.batches);
    assert_eq!(snap.fused_jobs, HEADS as u64 * snap.fused_batches);
    assert_eq!(snap.fused_rows, totals.rows);
    assert!(snap.fused_submissions >= snap.fused_cycles);
    assert!(snap.fused_submissions <= snap.fused_batches);
}

#[test]
fn stress_every_request_exactly_one_reference_response() {
    let (outs, snap, totals) = run_stress(2);
    // unique ids: exactly one response per submitted request
    let mut ids: Vec<u64> = outs.iter().map(|(id, _)| *id).collect();
    ids.dedup();
    assert_eq!(ids.len() as u64, totals.requests, "duplicate or missing responses");
    assert_metrics_consistent(&snap, &totals);
}

#[test]
fn stress_outputs_independent_of_kernel_threads() {
    let (o1, s1, t1) = run_stress(1);
    let (o4, s4, t4) = run_stress(4);
    assert_eq!(t1, t4, "workload must be deterministic");
    assert_eq!(o1, o4, "outputs must not depend on KernelConfig::threads");
    assert_metrics_consistent(&s1, &t1);
    assert_metrics_consistent(&s4, &t4);
    // timing-dependent metrics (cycles, batches) may differ between runs,
    // but the timing-independent sums must agree
    assert_eq!(s1.skip_steps, s4.skip_steps);
    assert_eq!(s1.fused_rows, s4.fused_rows);
    assert_eq!(s1.kv_appends, s4.kv_appends);
}
