//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this path dependency provides
//! exactly the API surface the workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait. Error values are stringly-typed (message + context
//! chain); `{}` displays the outermost context, `{:#}` and `{:?}` display
//! the full outermost-to-root chain, matching anyhow's formatting contract
//! closely enough for CLI/error-path output.

use std::fmt;

/// A stringly-typed error with a context chain.
///
/// Like the real `anyhow::Error`, this type deliberately does NOT implement
/// `std::error::Error`; that is what makes the blanket
/// `impl<E: std::error::Error> From<E> for Error` coherent.
pub struct Error {
    /// chain[0] is the root cause; the last entry is the outermost context.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The root-cause message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, part) in self.chain.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{part}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.chain.last().expect("non-empty chain"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to results whose
/// error type converts into [`Error`] (std errors and `Error` itself).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::core::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/3141592653")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.root_cause().is_empty());
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().context("loading weights").unwrap_err();
        assert_eq!(format!("{e}"), "loading weights");
        let full = format!("{e:#}");
        assert!(full.starts_with("loading weights: "), "{full}");
        assert_eq!(format!("{e:?}"), full);
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32> = Ok(7);
        let v = ok
            .with_context(|| {
                called = true;
                "ctx"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called);
    }

    #[test]
    fn macros_build_errors() {
        let name = "x7";
        let e = anyhow!("unknown artifact '{name}'");
        assert_eq!(format!("{e}"), "unknown artifact 'x7'");
        let e = anyhow!("parse {}: {}", 3, "bad");
        assert_eq!(format!("{e}"), "parse 3: bad");
        let owned: String = "oops".into();
        let e = anyhow!(owned);
        assert_eq!(format!("{e}"), "oops");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 1);
        }
        assert_eq!(format!("{}", bails(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", bails(true).unwrap_err()), "unreachable 1");
    }
}
