//! Table I reproduction: percentage of skipped output updates during
//! inference, 4 zoo models x 6 benchmark suites, under the paper's static
//! [-6, 11] criterion.
//!
//! Uses trained weights when `flashd train` (or the train_e2e example) has
//! produced them; otherwise the init weights (noted in the output, since
//! untrained attention is more diffuse and skips differ).
//!
//! Emits reports/table1.csv.

use flashd::bench_harness::table1::{self, Table1Options};

fn main() {
    println!("=== Table I: % skipped output updates during inference ===\n");
    let dir = flashd::runtime::default_artifact_dir();
    let fast = std::env::var("FLASHD_BENCH_FAST").is_ok();
    let opts = Table1Options {
        prompts_per_suite: if fast { 2 } else { 5 },
        decode_tokens: if fast { 6 } else { 14 },
        ..Default::default()
    };

    let man = match flashd::runtime::Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    for name in man.models.keys() {
        let trained = dir.join(format!("weights_{name}.fdw"));
        println!(
            "  {name}: {}",
            if trained.exists() { "trained weights" } else { "INIT weights (train first for the paper-faithful run)" }
        );
    }
    println!();

    let cells = table1::run_all(&dir, &opts).expect("table1 run");
    println!("{}", table1::render_table(&cells));
    println!("paper (for reference): 0.5%–2.8% across models/benchmarks,");
    println!("always a win (skips only remove work, never accuracy).");

    let pcts: Vec<f64> = cells.iter().map(|c| c.skip_pct).collect();
    println!(
        "ours: min {:.2}%  avg {:.2}%  max {:.2}%  ({} cells)",
        pcts.iter().cloned().fold(f64::MAX, f64::min),
        flashd::util::mean(&pcts),
        pcts.iter().cloned().fold(f64::MIN, f64::max),
        pcts.len()
    );

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/table1.csv", table1::to_csv(&cells)).unwrap();
    println!("\nwrote reports/table1.csv");
}
