//! Fig. 2 reproduction: the FLASH-D weight function
//! w_i = sigmoid(s_i - s_{i-1} + ln w_{i-1}) for w_{i-1} in
//! {0.99, 0.5, 0.1, 0.01}, swept over score differences — plus a
//! micro-benchmark of the weight update itself.
//!
//! Emits reports/fig2.csv with the four curves the paper plots.

use flashd::kernels::flashd::{log_sigmoid, sigmoid, weight, ACTIVE_HI, ACTIVE_LO};
use flashd::util::bench::{bb, Bench};

fn main() {
    println!("=== Fig. 2: weight function w_i over score differences ===\n");

    let w_prevs = [0.99, 0.5, 0.1, 0.01];
    let mut csv = String::from("s_diff,w_prev_0.99,w_prev_0.5,w_prev_0.1,w_prev_0.01\n");
    println!("{:>7}  {:>9} {:>9} {:>9} {:>9}", "s_diff", "w=0.99", "w=0.5", "w=0.1", "w=0.01");
    for i in (-100..=140).step_by(10) {
        let x = i as f64 / 10.0;
        let row: Vec<f64> = w_prevs.iter().map(|&wp| weight(x, wp)).collect();
        println!("{x:>7.1}  {:>9.5} {:>9.5} {:>9.5} {:>9.5}", row[0], row[1], row[2], row[3]);
    }
    for i in -100..=140 {
        let x = i as f64 / 10.0;
        let row: Vec<f64> = w_prevs.iter().map(|&wp| weight(x, wp)).collect();
        csv.push_str(&format!("{x},{},{},{},{}\n", row[0], row[1], row[2], row[3]));
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig2.csv", &csv).unwrap();
    println!("\nwrote reports/fig2.csv ({} rows)", csv.lines().count() - 1);

    // The paper's saturation claim: outside [-6, 11] (with any plotted
    // w_prev) the weight is within 2.5e-3 of 0 or 1.
    for &wp in &w_prevs {
        let lo = weight(ACTIVE_LO, wp);
        let hi = weight(ACTIVE_HI, wp);
        assert!(lo < 2.5e-3, "w({ACTIVE_LO}, {wp}) = {lo}");
        assert!(hi > 1.0 - 2.5e-3, "w({ACTIVE_HI}, {wp}) = {hi}");
    }
    println!("saturation check: w < 0.25% below {ACTIVE_LO}, w > 99.75% above {ACTIVE_HI} ✓\n");

    // Micro-bench: the per-step weight update (sigmoid + log-sigmoid) vs
    // the FA2 state update (max + 2 exp).
    let mut b = Bench::new("fig2_weight");
    let mut x = 0.37f64;
    b.bench("flashd_weight_update (sigmoid+ln)", || {
        let w = sigmoid(bb(x));
        let lnw = log_sigmoid(bb(x));
        x = bb(w + lnw * 1e-9 + 0.37);
    });
    let mut m = 0.0f64;
    let mut s = 0.4f64;
    b.bench("fa2_state_update (max+2exp)", || {
        let mn = m.max(bb(s));
        let a = (m - mn).exp();
        let p = (s - mn).exp();
        m = bb(mn);
        s = bb(a * 0.1 + p * 0.01 + 0.4);
    });
    b.write_csv();
}
