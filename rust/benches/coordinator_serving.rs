//! Coordinator serving benchmark: end-to-end request latency and
//! throughput through the full stack (router -> batcher -> KV cache ->
//! FLASH-D kernel), including the batching-vs-sequential ablation and the
//! fused-vs-serial cross-session dispatch sweep (whose results merge into
//! the committed `BENCH_kernels.json` perf-trajectory file under
//! `serving_*` names, with the `fused_over_serial_sessions8_nkv2048_d64`
//! headline ratio under `derived`).
//!
//! Uses the PJRT artifact engine when artifacts are built; otherwise falls
//! back to the pure-Rust tiled kernel engine (`Coordinator::start_naive`),
//! so the serving path is measurable in artifact-free environments too.

use flashd::bench_harness::traces::{bursty_arrival_gaps, poisson_arrival_gaps, BurstSpec};
use flashd::bench_harness::workload::{
    mixed_streams, session_requests, stateless_request, LengthDist, MixedSpec, WorkloadSpec,
};
use flashd::coordinator::kv_cache::SessionStore;
use flashd::coordinator::router::Router;
use flashd::coordinator::scheduler::Policy;
use flashd::coordinator::{
    AttentionRequest, Coordinator, CoordinatorConfig, ShapeSig, StreamEvent, StreamHandle, Variant,
};
use flashd::kernels::batch::{
    run_kv_blocks_flat_into_with, run_paged_kv_blocks_flat_into_with, BatchScratch, KernelConfig,
    KvBlockJob, PagedKvBlockJob,
};
use flashd::kernels::KvRef;
use flashd::numerics::quant::KvPrecision;
use flashd::runtime::Manifest;
use flashd::util::bench::{bb, Bench, Stats};
use flashd::util::json::Json;
use flashd::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Router for the fused-dispatch sweep: 2 heads, head_dim 64, one 2048
/// context capacity (the headline shape).
fn fused_sweep_router() -> Router {
    Router::from_manifest(
        &Manifest::parse(
            r#"{"artifacts": {
          "attn_flashd_h2_l2048_d64": {"file":"f","kind":"attention","variant":"flashd","causal":false,
            "heads":2,"seq":2048,"head_dim":64,"inputs":[],"n_outputs":1}
        }}"#,
        )
        .expect("fused sweep manifest"),
    )
}

/// Serve `sessions` concurrent decode streams (one client thread each,
/// prefilled to ~2048 context) and return the wall-clock seconds of the
/// decode phase. `fused` selects one-submission-per-cycle dispatch vs the
/// per-batch serial path.
fn run_serving_mode(fused: bool, sessions: usize, prefill_len: usize, steps: usize) -> f64 {
    let spec = WorkloadSpec {
        sessions,
        prefill_len,
        decode_steps: steps,
        sig: ShapeSig { heads: 2, head_dim: 64 },
        variant: Variant::FlashD,
        ..Default::default()
    };
    let cfg = CoordinatorConfig { fused, ..Default::default() };
    let coord = Arc::new(Coordinator::start_naive(cfg, fused_sweep_router()).expect("start"));

    let mut streams: Vec<_> = (0..sessions)
        .map(|s| session_requests(&spec, s as u64, 1_000_000 * (s as u64 + 1)))
        .collect();
    for stream in streams.iter_mut() {
        let prefill = stream.remove(0);
        coord.submit_blocking(prefill).output.expect("prefill ok");
    }

    let barrier = Arc::new(Barrier::new(sessions + 1));
    let mut handles = Vec::new();
    for stream in streams {
        let c = coord.clone();
        let b = barrier.clone();
        handles.push(std::thread::spawn(move || {
            b.wait();
            for req in stream {
                c.submit_blocking(req).output.expect("decode ok");
            }
        }));
    }
    barrier.wait();
    let t = Instant::now();
    for h in handles {
        h.join().expect("client thread");
    }
    t.elapsed().as_secs_f64()
}

/// Merge the serving suite's results and derived ratios into the committed
/// `BENCH_kernels.json` (idempotently regenerating the `serving_*`
/// section; the kernel_throughput bench owns the rest of the file).
fn merge_serving_into_bench_json(serving: &Bench, path: &str) {
    let mut obj: BTreeMap<String, Json> =
        match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok()) {
            Some(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        };
    let serving_json = serving.to_json();
    let mut results: Vec<Json> = match obj.remove("results") {
        Some(Json::Arr(v)) => v,
        _ => Vec::new(),
    };
    results.retain(|r| match r.get("name").and_then(Json::as_str) {
        Some(n) => !n.starts_with("serving_"),
        None => true,
    });
    if let Some(new) = serving_json.get("results").and_then(Json::as_arr) {
        results.extend(new.iter().cloned());
    }
    obj.insert("results".into(), Json::Arr(results));
    let mut derived: BTreeMap<String, Json> = match obj.remove("derived") {
        Some(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    for (k, v) in &serving.derived {
        derived.insert(k.clone(), Json::Num(*v));
    }
    obj.insert("derived".into(), Json::Obj(derived));
    obj.entry("suite".into())
        .or_insert_with(|| Json::Str("kernel_throughput+serving".into()));
    // load-bearing for CI's BENCH_kernels.json validation — fail loudly
    std::fs::write(path, Json::Obj(obj).to_string())
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("-- merged serving section into {path}");
}

/// `{p50, p99, count}` percentile block (µs) for one latency signal.
fn pctiles(xs: &[f64]) -> Json {
    Json::Obj(BTreeMap::from([
        ("p50".to_string(), Json::Num(flashd::util::percentile(xs, 50.0))),
        ("p99".to_string(), Json::Num(flashd::util::percentile(xs, 99.0))),
        ("count".to_string(), Json::Num(xs.len() as f64)),
    ]))
}

/// One scenario cell of the trace-driven load harness: a stream workload,
/// an arrival trace, and a coordinator configuration.
struct Scenario {
    name: &'static str,
    policy: Policy,
    fused: bool,
    router: Router,
    cfg: CoordinatorConfig,
    /// One request lifecycle per stream, ready for `submit_stream`.
    streams: Vec<Vec<AttentionRequest>>,
    /// Inter-arrival gap slept before each stream opens (capped at 10ms
    /// so CI smoke runs stay quick).
    gaps: Vec<Duration>,
    /// Every odd-indexed client drops its `StreamHandle` right after the
    /// first token — the abandonment stimulus for the worker's
    /// client-gone abort/slot-free path. Even-indexed clients drain to
    /// completion, keeping the TTFT/ITL blocks populated.
    abandon_odd_clients: bool,
    /// Assert zero server errors and zero abandonments (clean cells);
    /// churn-style cells tolerate and report them instead.
    expect_clean: bool,
}

/// What one stream's client observed (all client-side walltimes).
struct ClientReport {
    ttft_us: Option<f64>,
    itl_us: Vec<f64>,
    lat_us: Vec<f64>,
    errors: u64,
    abandoned: bool,
}

fn client_loop(handle: StreamHandle, opened: Instant, abandon_after_first: bool) -> ClientReport {
    let mut rep = ClientReport {
        ttft_us: None,
        itl_us: Vec::new(),
        lat_us: Vec::new(),
        errors: 0,
        abandoned: false,
    };
    let mut last: Option<Instant> = None;
    while let Some(ev) = handle.recv() {
        match ev {
            StreamEvent::Token(resp) => {
                let now = Instant::now();
                rep.lat_us.push(resp.latency_us as f64);
                if resp.output.is_err() {
                    rep.errors += 1;
                }
                if rep.ttft_us.is_none() {
                    rep.ttft_us = Some(now.duration_since(opened).as_secs_f64() * 1e6);
                } else if let Some(prev) = last {
                    rep.itl_us.push(now.duration_since(prev).as_secs_f64() * 1e6);
                }
                last = Some(now);
                if abandon_after_first {
                    // dropping the handle here is the abandonment signal:
                    // the worker's next token send fails as client-gone
                    rep.abandoned = true;
                    return rep;
                }
            }
            StreamEvent::Done { .. } => break,
        }
    }
    rep
}

/// Run one scenario cell: open-loop stream arrivals into
/// `Coordinator::submit_stream`, clients timing their own events (TTFT
/// and inter-token gaps are end-to-end). Emits the cell's SLO block —
/// client-measured TTFT/ITL/latency percentiles plus the
/// rejected/evicted/abandoned/error counters from the server snapshot.
fn run_scenario(sc: Scenario) -> Json {
    let n_streams = sc.streams.len();
    let total_reqs: usize = sc.streams.iter().map(Vec::len).sum();
    assert_eq!(sc.gaps.len(), n_streams, "one arrival gap per stream");
    let coord = Coordinator::start_naive(sc.cfg, sc.router).expect("start");

    let t0 = Instant::now();
    let mut clients = Vec::new();
    for (idx, (stream, gap)) in sc.streams.into_iter().zip(sc.gaps).enumerate() {
        std::thread::sleep(gap.min(Duration::from_millis(10)));
        let opened = Instant::now();
        let handle = coord.submit_stream(stream);
        let abandon = sc.abandon_odd_clients && idx % 2 == 1;
        clients.push(std::thread::spawn(move || client_loop(handle, opened, abandon)));
    }
    let (mut ttfts, mut itls, mut lats) = (Vec::new(), Vec::new(), Vec::new());
    let (mut client_errors, mut client_abandoned) = (0u64, 0u64);
    for c in clients {
        let rep = c.join().expect("client thread");
        ttfts.extend(rep.ttft_us);
        itls.extend(rep.itl_us);
        lats.extend(rep.lat_us);
        client_errors += rep.errors;
        client_abandoned += rep.abandoned as u64;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // Abandoning clients return before their streams terminate server-
    // side; wait for the worker to drain every stream so the snapshot's
    // counters are settled, not racing the drain.
    let settle_deadline = Instant::now() + Duration::from_secs(60);
    let snap = loop {
        let snap = coord.metrics.snapshot();
        if snap.streams_completed >= n_streams as u64 {
            break snap;
        }
        assert!(
            Instant::now() < settle_deadline,
            "{}: only {}/{n_streams} streams terminated",
            sc.name,
            snap.streams_completed
        );
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(snap.streams_completed, n_streams as u64, "{}", sc.name);
    if sc.expect_clean {
        assert_eq!(snap.errors, 0, "{}: scenario must serve cleanly", sc.name);
        assert_eq!(client_errors, 0, "{}: clients saw error tokens", sc.name);
        assert_eq!(snap.streams_abandoned, 0, "{}", sc.name);
    }
    if sc.abandon_odd_clients {
        // A dropped handle is only observed when the worker's NEXT send
        // fails, so a stream fully drained into the channel buffer before
        // the drop escapes detection — the count is bounded by the
        // clients that dropped, and with many pending decodes per
        // abandoner at least one drop always lands mid-generation.
        assert!(
            (1..=client_abandoned).contains(&snap.streams_abandoned),
            "{}: {} abandoned streams detected, {} clients dropped handles",
            sc.name,
            snap.streams_abandoned,
            client_abandoned
        );
    }
    println!(
        "{:<34} {total_reqs:>4} reqs {wall_s:6.3}s  ttft p50={:>8.0}µs p99={:>8.0}µs  \
         itl p50={:>7.0}µs p99={:>7.0}µs  rej={} evi={} aband={} err={}",
        sc.name,
        flashd::util::percentile(&ttfts, 50.0),
        flashd::util::percentile(&ttfts, 99.0),
        flashd::util::percentile(&itls, 50.0),
        flashd::util::percentile(&itls, 99.0),
        snap.queue_rejections,
        snap.kv_block_evictions,
        snap.streams_abandoned,
        snap.errors,
    );
    Json::Obj(BTreeMap::from([
        ("name".to_string(), Json::Str(sc.name.to_string())),
        ("policy".to_string(), Json::Str(format!("{:?}", sc.policy))),
        ("fused".to_string(), Json::Bool(sc.fused)),
        ("streams".to_string(), Json::Num(n_streams as f64)),
        ("requests".to_string(), Json::Num(total_reqs as f64)),
        ("wall_s".to_string(), Json::Num(wall_s)),
        // -- the per-cell SLO block ---------------------------------------
        ("ttft_us".to_string(), pctiles(&ttfts)),
        ("itl_us".to_string(), pctiles(&itls)),
        ("latency_us".to_string(), pctiles(&lats)),
        ("rejected".to_string(), Json::Num(snap.queue_rejections as f64)),
        ("evicted".to_string(), Json::Num(snap.kv_block_evictions as f64)),
        ("abandoned".to_string(), Json::Num(snap.streams_abandoned as f64)),
        ("errors".to_string(), Json::Num(snap.errors as f64)),
        ("completed".to_string(), Json::Num(snap.streams_completed as f64)),
        // server-side histogram percentiles (saturate finitely past 100ms)
        ("server_ttft_p99_us".to_string(), Json::Num(snap.ttft.percentile_us(99.0) as f64)),
        ("server_itl_p99_us".to_string(), Json::Num(snap.itl.percentile_us(99.0) as f64)),
        ("queue_wait_mean_us".to_string(), Json::Num(snap.queue_wait.mean_us())),
        ("admission_deferrals".to_string(), Json::Num(snap.admission_deferrals as f64)),
        ("fused_cycles".to_string(), Json::Num(snap.fused_cycles as f64)),
        ("fused_submissions".to_string(), Json::Num(snap.fused_submissions as f64)),
    ]))
}

/// Run one sliding-window cell: a single stream prefills `w` steps and
/// then decodes `8 * w` more, so a `Some(w)` attention window is
/// outgrown eight times over. Beyond the standard SLO block the cell
/// emits mid/late-phase ITL percentile blocks (middle vs final third of
/// the decode gaps) and the pool's window-trim gauges — the evidence CI
/// re-asserts from the committed JSON.
fn run_sliding_window_cell(name: &'static str, window: Option<usize>, w: usize) -> Json {
    let spec = WorkloadSpec {
        sessions: 1,
        prefill_len: w,
        decode_steps: 8 * w,
        sig: ShapeSig { heads: 2, head_dim: 64 },
        variant: Variant::FlashD,
        seed: 13,
    };
    let cfg = CoordinatorConfig { policy: Policy::Fifo, window, ..Default::default() };
    let coord = Coordinator::start_naive(cfg, fused_sweep_router()).expect("start");
    let stream = session_requests(&spec, 0, 8_000_000);
    let total_reqs = stream.len();

    let t0 = Instant::now();
    let handle = coord.submit_stream(stream);
    let rep = client_loop(handle, t0, false);
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(rep.errors, 0, "{name}: stream must serve cleanly");

    let settle_deadline = Instant::now() + Duration::from_secs(60);
    let snap = loop {
        let snap = coord.metrics.snapshot();
        if snap.streams_completed >= 1 {
            break snap;
        }
        assert!(Instant::now() < settle_deadline, "{name}: stream did not terminate");
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(snap.errors, 0, "{name}");

    // Phase split over the inter-token gaps: the middle third is steady
    // state for both cells; by the final third an unwindowed session has
    // outgrown `w` several times over.
    let n = rep.itl_us.len();
    assert!(n >= 30, "{name}: need enough inter-token gaps to phase-split (got {n})");
    let mid = &rep.itl_us[n / 3..2 * n / 3];
    let late = &rep.itl_us[2 * n / 3..];
    let mid_p50 = flashd::util::percentile(mid, 50.0);
    let late_p50 = flashd::util::percentile(late, 50.0);

    if window.is_some() {
        assert!(
            snap.kv_window_trims > 0 && snap.kv_blocks_trimmed > 0,
            "{name}: outgrowing the window 8x must trim leading blocks \
             (trims={} blocks={})",
            snap.kv_window_trims,
            snap.kv_blocks_trimmed
        );
        // The tentpole claim: with the window bounding attended KV, the
        // inter-token latency does not grow with total generated length.
        assert!(
            late_p50 <= 1.15 * mid_p50,
            "{name}: windowed ITL must stay flat: late p50 {late_p50:.0}µs vs \
             mid p50 {mid_p50:.0}µs"
        );
    } else {
        assert_eq!(snap.kv_window_trims, 0, "{name}: control must never trim");
        assert_eq!(snap.kv_blocks_trimmed, 0, "{name}: control must never trim");
        // The control retains every generated step: the resident pool
        // only grows, so the final gauge is also the high-water mark.
        assert_eq!(snap.kv_pool_bytes, snap.kv_pool_peak_bytes, "{name}");
    }

    let ttfts: Vec<f64> = rep.ttft_us.iter().copied().collect();
    println!(
        "{:<34} {total_reqs:>4} reqs {wall_s:6.3}s  itl p50 mid={mid_p50:>7.0}µs \
         late={late_p50:>7.0}µs  trims={} blocks_trimmed={} pool={}B",
        name, snap.kv_window_trims, snap.kv_blocks_trimmed, snap.kv_pool_bytes,
    );
    Json::Obj(BTreeMap::from([
        ("name".to_string(), Json::Str(name.to_string())),
        ("policy".to_string(), Json::Str(format!("{:?}", Policy::Fifo))),
        ("fused".to_string(), Json::Bool(true)),
        ("window".to_string(), Json::Num(window.unwrap_or(0) as f64)),
        ("streams".to_string(), Json::Num(1.0)),
        ("requests".to_string(), Json::Num(total_reqs as f64)),
        ("wall_s".to_string(), Json::Num(wall_s)),
        // -- the per-cell SLO block ---------------------------------------
        ("ttft_us".to_string(), pctiles(&ttfts)),
        ("itl_us".to_string(), pctiles(&rep.itl_us)),
        ("itl_mid_us".to_string(), pctiles(mid)),
        ("itl_late_us".to_string(), pctiles(late)),
        ("latency_us".to_string(), pctiles(&rep.lat_us)),
        ("rejected".to_string(), Json::Num(snap.queue_rejections as f64)),
        ("evicted".to_string(), Json::Num(snap.kv_block_evictions as f64)),
        ("abandoned".to_string(), Json::Num(snap.streams_abandoned as f64)),
        ("errors".to_string(), Json::Num(snap.errors as f64)),
        ("completed".to_string(), Json::Num(snap.streams_completed as f64)),
        // -- pool residency + trim gauges (the windowed-vs-control story) --
        ("kv_pool_bytes".to_string(), Json::Num(snap.kv_pool_bytes as f64)),
        ("kv_pool_peak_bytes".to_string(), Json::Num(snap.kv_pool_peak_bytes as f64)),
        ("kv_window_trims".to_string(), Json::Num(snap.kv_window_trims as f64)),
        ("kv_blocks_trimmed".to_string(), Json::Num(snap.kv_blocks_trimmed as f64)),
        ("server_ttft_p99_us".to_string(), Json::Num(snap.ttft.percentile_us(99.0) as f64)),
        ("server_itl_p99_us".to_string(), Json::Num(snap.itl.percentile_us(99.0) as f64)),
        ("queue_wait_mean_us".to_string(), Json::Num(snap.queue_wait.mean_us())),
        ("admission_deferrals".to_string(), Json::Num(snap.admission_deferrals as f64)),
        ("fused_cycles".to_string(), Json::Num(snap.fused_cycles as f64)),
        ("fused_submissions".to_string(), Json::Num(snap.fused_submissions as f64)),
    ]))
}

/// Write the scenario matrix to the committed `BENCH_serving.json`
/// (CI validates every cell's SLO block: TTFT/ITL/latency percentile
/// blocks plus the rejected/evicted/abandoned counters).
fn write_bench_serving_json(scenarios: Vec<Json>, path: &str) {
    let obj = BTreeMap::from([
        ("suite".to_string(), Json::Str("coordinator_serving_mixed".to_string())),
        ("scenarios".to_string(), Json::Arr(scenarios)),
        (
            "note".to_string(),
            Json::Str(
                "regenerate with `cargo bench --bench coordinator_serving` \
                 (FLASHD_BENCH_FAST=1 for a smoke run); trace-driven streaming \
                 scenarios through Coordinator::submit_stream under continuous \
                 batching. Cells: mixed_* = policy x dispatch matrix with every \
                 4th stream fronted by a long prefill; sampled_lengths = \
                 ShareGPT-like lognormal prompt/response lengths; bursty = \
                 on-off modulated Poisson arrivals; abandonment = clients drop \
                 their StreamHandle mid-generation; long_context_nkv64k = \
                 65536-token prefills through the paged pool; \
                 churn_tiny_sessions = hundreds of tiny sessions under a small \
                 KV budget (LRU eviction); conflict_storm = every stream on one \
                 session (fusion-group splits); sliding_window_* = one stream \
                 outgrows its attention window 8x (the windowed cell carries \
                 itl_mid_us/itl_late_us phase blocks plus kv_window_trims/\
                 kv_blocks_trimmed/kv_pool_bytes gauges and must keep late ITL \
                 p50 within 1.15x of mid; the unwindowed control shows the \
                 pool growing with history). Each cell carries an SLO block: \
                 client-measured ttft_us/itl_us/latency_us {p50,p99,count} in \
                 µs plus rejected/evicted/abandoned/errors/completed counters"
                    .to_string(),
            ),
        ),
    ]);
    // load-bearing for CI's BENCH_serving.json validation — fail loudly
    std::fs::write(path, Json::Obj(obj).to_string())
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("-- wrote {path}");
}

/// Synthetic router covering the default workload signature (4 heads,
/// head_dim 32) at a few context capacities.
fn synthetic_router() -> Router {
    Router::from_manifest(
        &Manifest::parse(
            r#"{"artifacts": {
          "attn_flashd_h4_l128_d32": {"file":"x","kind":"attention","variant":"flashd","causal":false,
            "heads":4,"seq":128,"head_dim":32,"inputs":[],"n_outputs":1},
          "attn_flashd_h4_l256_d32": {"file":"y","kind":"attention","variant":"flashd","causal":false,
            "heads":4,"seq":256,"head_dim":32,"inputs":[],"n_outputs":1},
          "attn_flash2_h4_l256_d32": {"file":"z","kind":"attention","variant":"flash2","causal":false,
            "heads":4,"seq":256,"head_dim":32,"inputs":[],"n_outputs":1}
        }}"#,
        )
        .expect("synthetic manifest"),
    )
}

fn main() {
    let dir = flashd::runtime::default_artifact_dir();
    let fast = std::env::var("FLASHD_BENCH_FAST").is_ok();

    // The PJRT engine needs BOTH compiled artifacts and the pjrt_backend
    // cfg; the default build stubs the runtime, so fall back to the
    // tiled-kernel NaiveEngine in every other configuration.
    let coord = if cfg!(pjrt_backend) && dir.join("manifest.json").exists() {
        println!("=== coordinator serving (PJRT FLASH-D engine) ===\n");
        Coordinator::start(CoordinatorConfig::default()).expect("start coordinator")
    } else {
        println!("=== coordinator serving (tiled-kernel NaiveEngine; no PJRT backend/artifacts) ===\n");
        Coordinator::start_naive(CoordinatorConfig::default(), synthetic_router())
            .expect("start coordinator")
    };

    // -- stateless prefill-style requests, varying context --------------
    for &nkv in &[32usize, 128, 256] {
        let spec = WorkloadSpec::default();
        let iters = if fast { 5 } else { 20 };
        let mut lat = Vec::new();
        for i in 0..iters {
            let req = stateless_request(&spec, 50_000 + i as u64 * 7 + nkv as u64, 1, nkv);
            let t = Instant::now();
            let resp = coord.submit_blocking(req);
            resp.output.expect("ok");
            lat.push(t.elapsed().as_secs_f64() * 1e6);
        }
        println!(
            "stateless nkv={nkv:<4} p50={:>8.0}µs p95={:>8.0}µs  ({} iters)",
            flashd::util::percentile(&lat, 50.0),
            flashd::util::percentile(&lat, 95.0),
            lat.len()
        );
    }

    // -- decode stream through the KV cache ------------------------------
    let spec = WorkloadSpec {
        sessions: 1,
        prefill_len: 64,
        decode_steps: if fast { 8 } else { 32 },
        ..Default::default()
    };
    let reqs = session_requests(&spec, 7, 100_000);
    let t = Instant::now();
    let mut lat = Vec::new();
    for req in reqs {
        let t0 = Instant::now();
        coord.submit_blocking(req).output.expect("ok");
        lat.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    println!(
        "\ndecode stream: {} steps in {:.2}s  p50={:.0}µs p95={:.0}µs",
        lat.len() - 1,
        t.elapsed().as_secs_f64(),
        flashd::util::percentile(&lat[1..], 50.0),
        flashd::util::percentile(&lat[1..], 95.0),
    );

    // -- batching ablation: concurrent burst vs sequential ---------------
    let burst = if fast { 8 } else { 32 };
    // fresh session
    let mut pre = session_requests(
        &WorkloadSpec { sessions: 1, decode_steps: 0, ..Default::default() },
        11,
        200_000,
    );
    coord.submit_blocking(pre.remove(0)).output.expect("prefill");

    // sequential
    let t = Instant::now();
    for i in 0..burst as u64 {
        let mut reqs = session_requests(&WorkloadSpec::default(), 11, 300_000 + i * 50);
        let dec = reqs.pop().unwrap();
        coord.submit_blocking(dec).output.expect("ok");
    }
    let seq_s = t.elapsed().as_secs_f64();

    // concurrent (dynamic batching window can merge them)
    let coord = std::sync::Arc::new(coord);
    let t = Instant::now();
    let mut handles = Vec::new();
    for i in 0..burst as u64 {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut reqs = session_requests(&WorkloadSpec::default(), 11, 400_000 + i * 50);
            let dec = reqs.pop().unwrap();
            c.submit_blocking(dec)
        }));
    }
    let mut max_batch = 0;
    for h in handles {
        let r = h.join().unwrap();
        r.output.expect("ok");
        max_batch = max_batch.max(r.batch_size);
    }
    let conc_s = t.elapsed().as_secs_f64();
    println!(
        "\nbatching ablation ({burst} decodes): sequential {:.3}s ({:.0} req/s) vs concurrent {:.3}s ({:.0} req/s), max batch {max_batch}, speedup {:.2}x",
        seq_s,
        burst as f64 / seq_s,
        conc_s,
        burst as f64 / conc_s,
        seq_s / conc_s
    );
    println!("\nmetrics:\n{}", coord.metrics.snapshot().render());

    // -- fused vs serial cross-session dispatch sweep --------------------
    // 8 concurrent decode streams over ~2048-token contexts: the serial
    // path issues one padded submission per batch; the fused path lowers
    // every drain cycle into one run_blocks submission over borrowed KV.
    println!("\n=== fused cross-session dispatch vs per-batch serial (8 sessions, nkv 2048, d 64) ===");
    let mut sb = Bench::new("coordinator_serving");
    let sessions = 8usize;
    let steps = if fast { 12 } else { 48 };
    let prefill_len = 2048 - steps;
    let serial_s = run_serving_mode(false, sessions, prefill_len, steps);
    let fused_s = run_serving_mode(true, sessions, prefill_len, steps);
    let total_decodes = (sessions * steps) as f64;
    for (name, secs) in [
        ("serving_decode_serial_sessions8_nkv2048_d64", serial_s),
        ("serving_decode_fused_sessions8_nkv2048_d64", fused_s),
    ] {
        println!("{name:<44} {secs:8.3}s  {:8.0} decodes/s", total_decodes / secs);
        sb.results.push(Stats {
            name: name.to_string(),
            iters: total_decodes as u64,
            mean_ns: secs * 1e9 / total_decodes,
            stddev_ns: 0.0,
            p50_ns: 0.0,
            p95_ns: 0.0,
            throughput: Some((1.0, "decode")),
        });
    }
    sb.note("fused_over_serial_sessions8_nkv2048_d64", serial_s / fused_s);

    // -- trace-driven scenario matrix (continuous batching) --------------
    // Streaming lifecycles under realistic arrival/length traces: the
    // policy x dispatch mixed matrix, plus sampled-length, bursty-arrival,
    // abandonment, long-context, churn, and conflict-storm cells — each
    // emitting its SLO block into the committed BENCH_serving.json.
    println!("\n=== trace-driven streaming scenarios (TTFT / inter-token latency / SLO counters) ===");
    let mixed_workload = |seed: u64| MixedSpec {
        spec: WorkloadSpec {
            sessions: if fast { 6 } else { 16 },
            prefill_len: 128,
            decode_steps: if fast { 8 } else { 24 },
            sig: ShapeSig { heads: 2, head_dim: 64 },
            variant: Variant::FlashD,
            seed,
        },
        long_every: 4,
        long_prefill_len: 1536,
        ..Default::default()
    };
    let mut scenarios = Vec::new();

    // (1) the policy x dispatch-mode matrix under the long-prefill salt
    for (name, policy, fused, seed) in [
        ("mixed_fifo_fused", Policy::Fifo, true, 0xA11CE_u64),
        ("mixed_fifo_serial", Policy::Fifo, false, 0xA11CF),
        ("mixed_decodefirst_fused", Policy::DecodeFirst, true, 0xA11D0),
        ("mixed_decodefirst_serial", Policy::DecodeFirst, false, 0xA11D1),
    ] {
        let streams = mixed_streams(&mixed_workload(3), 1_000_000);
        let gaps = poisson_arrival_gaps(seed, 200.0, streams.len());
        scenarios.push(run_scenario(Scenario {
            name,
            policy,
            fused,
            router: fused_sweep_router(),
            cfg: CoordinatorConfig { policy, fused, ..Default::default() },
            streams,
            gaps,
            abandon_odd_clients: false,
            expect_clean: true,
        }));
    }

    // (2) ShareGPT-like sampled lengths: lognormal prompt/response token
    // counts instead of fixed shapes — the long tail is the stimulus.
    {
        let mix = MixedSpec {
            long_every: 0,
            prompt_len: Some(LengthDist::lognormal(96.0, 0.8, 8, 1024)),
            response_len: Some(LengthDist::lognormal(
                if fast { 6.0 } else { 12.0 },
                0.7,
                2,
                if fast { 16 } else { 48 },
            )),
            ..mixed_workload(3)
        };
        let streams = mixed_streams(&mix, 2_000_000);
        let gaps = poisson_arrival_gaps(0xA11D2, 200.0, streams.len());
        scenarios.push(run_scenario(Scenario {
            name: "sampled_lengths_fifo_fused",
            policy: Policy::Fifo,
            fused: true,
            router: fused_sweep_router(),
            cfg: CoordinatorConfig { policy: Policy::Fifo, ..Default::default() },
            streams,
            gaps,
            abandon_odd_clients: false,
            expect_clean: true,
        }));
    }

    // (3) bursty arrivals: on-off modulated Poisson — packed arrival
    // bursts separated by idle dwells, the overload-then-drain stimulus.
    {
        let streams = mixed_streams(&mixed_workload(3), 3_000_000);
        let burst = BurstSpec {
            burst_rate_hz: 1_000.0,
            idle_rate_hz: 25.0,
            mean_burst_s: 0.02,
            mean_idle_s: 0.04,
        };
        let gaps = bursty_arrival_gaps(0xA11D3, &burst, streams.len());
        scenarios.push(run_scenario(Scenario {
            name: "bursty_decodefirst_fused",
            policy: Policy::DecodeFirst,
            fused: true,
            router: fused_sweep_router(),
            cfg: CoordinatorConfig::default(),
            streams,
            gaps,
            abandon_odd_clients: false,
            expect_clean: true,
        }));
    }

    // (4) client abandonment: odd-indexed clients drop their StreamHandle
    // after the first token, exercising the worker's client-gone abort
    // and slot-free path mid-generation.
    {
        let streams = mixed_streams(&mixed_workload(3), 4_000_000);
        let gaps = poisson_arrival_gaps(0xA11D4, 200.0, streams.len());
        scenarios.push(run_scenario(Scenario {
            name: "abandonment_fifo_fused",
            policy: Policy::Fifo,
            fused: true,
            router: fused_sweep_router(),
            cfg: CoordinatorConfig { policy: Policy::Fifo, ..Default::default() },
            streams,
            gaps,
            abandon_odd_clients: true,
            expect_clean: false,
        }));
    }

    // (5) long-context prefill: 64k-token contexts through the paged
    // block pool (a dedicated 1-head router keeps the per-session KV at
    // ~33 MB so a few sessions fit the default 256 MB budget).
    {
        let router = Router::from_manifest(
            &Manifest::parse(
                r#"{"artifacts": {
              "attn_flashd_h1_l66048_d64": {"file":"l","kind":"attention","variant":"flashd","causal":false,
                "heads":1,"seq":66048,"head_dim":64,"inputs":[],"n_outputs":1}
            }}"#,
            )
            .expect("long-context manifest"),
        );
        let mix = MixedSpec {
            spec: WorkloadSpec {
                sessions: if fast { 2 } else { 3 },
                prefill_len: 65_536,
                decode_steps: if fast { 3 } else { 6 },
                sig: ShapeSig { heads: 1, head_dim: 64 },
                variant: Variant::FlashD,
                seed: 5,
            },
            long_every: 0,
            ..Default::default()
        };
        let streams = mixed_streams(&mix, 5_000_000);
        let gaps = poisson_arrival_gaps(0xA11D5, 50.0, streams.len());
        let cell = run_scenario(Scenario {
            name: "long_context_nkv64k_fifo_fused",
            policy: Policy::Fifo,
            fused: true,
            router,
            cfg: CoordinatorConfig { policy: Policy::Fifo, ..Default::default() },
            streams,
            gaps,
            abandon_odd_clients: false,
            expect_clean: true,
        });
        assert!(
            cell.get("requests").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "long-context cell must serve its 64k-prefill streams"
        );
        scenarios.push(cell);
    }

    // (6) many-tiny-sessions churn: hundreds of 1-prefill/2-decode
    // lifecycles against a 16-block KV budget — completed sessions must
    // be LRU-evicted to admit new ones (evictions are the point, so the
    // cell reports rather than forbids them). A dedicated 64-context
    // router keeps the per-session worst-case reservation (2 blocks) far
    // under the budget; the 2048-context router's 64-block worst case
    // would fail session creation outright.
    {
        let router = Router::from_manifest(
            &Manifest::parse(
                r#"{"artifacts": {
              "attn_flashd_h2_l64_d64": {"file":"c","kind":"attention","variant":"flashd","causal":false,
                "heads":2,"seq":64,"head_dim":64,"inputs":[],"n_outputs":1}
            }}"#,
            )
            .expect("churn manifest"),
        );
        let mix = MixedSpec {
            spec: WorkloadSpec {
                sessions: if fast { 64 } else { 192 },
                prefill_len: 24,
                decode_steps: 2,
                sig: ShapeSig { heads: 2, head_dim: 64 },
                variant: Variant::FlashD,
                seed: 7,
            },
            long_every: 0,
            ..Default::default()
        };
        let streams = mixed_streams(&mix, 6_000_000);
        let gaps = poisson_arrival_gaps(0xA11D6, 2_000.0, streams.len());
        let cell = run_scenario(Scenario {
            name: "churn_tiny_sessions_fifo_fused",
            policy: Policy::Fifo,
            fused: true,
            router,
            cfg: CoordinatorConfig {
                policy: Policy::Fifo,
                // 16 blocks of 2 heads x 32 steps x 64 dims x 4 B x {K,V}
                kv_budget_bytes: 16 * 2 * 2 * 32 * 64 * 4,
                max_concurrent_streams: 8,
                ..Default::default()
            },
            streams,
            gaps,
            abandon_odd_clients: false,
            expect_clean: false,
        });
        assert!(
            cell.get("evicted").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "churn cell must force LRU block evictions"
        );
        scenarios.push(cell);
    }

    // (7) adversarial same-session conflict storm: every stream runs a
    // full prefill+decode lifecycle on session 0, so the fused dispatcher
    // must split its fusion groups on every cycle (re-prefills replace
    // the cache the in-group decodes borrow).
    {
        let spec = WorkloadSpec {
            sessions: 1,
            prefill_len: 64,
            decode_steps: if fast { 4 } else { 8 },
            sig: ShapeSig { heads: 2, head_dim: 64 },
            variant: Variant::FlashD,
            seed: 11,
        };
        let n = if fast { 6 } else { 12 };
        let mut next_id = 7_000_000u64;
        let streams: Vec<_> = (0..n)
            .map(|_| {
                let reqs = session_requests(&spec, 0, next_id);
                next_id += reqs.len() as u64;
                reqs
            })
            .collect();
        let gaps = vec![Duration::ZERO; n];
        let cell = run_scenario(Scenario {
            name: "conflict_storm_same_session_fused",
            policy: Policy::Fifo,
            fused: true,
            router: fused_sweep_router(),
            cfg: CoordinatorConfig { policy: Policy::Fifo, ..Default::default() },
            streams,
            gaps,
            abandon_odd_clients: false,
            expect_clean: true,
        });
        scenarios.push(cell);
    }

    // (8)+(9) sliding-window tentpole: one stream outgrows its attention
    // window eight times over. Windowed cell: block trims keep the
    // attended KV — and hence the per-token latency — flat (late-phase
    // ITL p50 must stay within 15% of mid-phase, asserted here and
    // re-checked by CI from the emitted JSON). Unwindowed control: the
    // same workload retains its whole history, so its resident pool
    // bytes keep growing instead.
    {
        let w = if fast { 32 } else { 128 }; // block-aligned (32-step blocks)
        let windowed = run_sliding_window_cell("sliding_window_flat_latency_fifo_fused", Some(w), w);
        let control = run_sliding_window_cell("sliding_window_control_unwindowed", None, w);
        let wb = windowed.get("kv_pool_bytes").and_then(Json::as_f64).expect("gauge");
        let cb = control.get("kv_pool_bytes").and_then(Json::as_f64).expect("gauge");
        assert!(
            cb >= 4.0 * wb,
            "unwindowed control must retain the whole history ({cb} B resident) \
             while the windowed pool stays near one window ({wb} B)"
        );
        scenarios.push(windowed);
        scenarios.push(control);
    }
    write_bench_serving_json(scenarios, "BENCH_serving.json");

    // -- paged KV pool: shared-prefix memory + paged vs dense streaming --
    println!("\n=== paged KV pool: shared-prefix memory (32 forks) + paged vs dense streaming ===");
    let (heads, d) = (2usize, 64usize);
    let bs = KernelConfig::default().tile;
    let scale = (d as f32).powf(-0.5);
    let mut rng = Rng::new(0x9A6ED);
    {
        // (a) memory: 32 sessions forked off one shared system prompt vs
        // 32 dense (unshared) copies of the same contexts. The prompt is a
        // multiple of the block size, so the fork boundary is
        // block-aligned and divergence costs zero copy-on-write.
        let prefix = if fast { 8 * bs } else { 64 * bs };
        let sessions32 = 32usize;
        let diverge = 8usize;
        let pk = rng.normal_vec(heads * prefix * d, 0.5);
        let pv = rng.normal_vec(heads * prefix * d, 0.5);
        let dk = rng.normal_vec(heads * diverge * d, 0.5);
        let dv = rng.normal_vec(heads * diverge * d, 0.5);
        let mut paged = SessionStore::with_block_steps(usize::MAX, KvPrecision::F32, bs);
        paged.create(0, heads, d, prefix + diverge).expect("create");
        paged.append(0, &pk, &pv, prefix).expect("prefill");
        for s in 1..sessions32 as u64 {
            paged.fork(0, s).expect("fork");
        }
        for s in 0..sessions32 as u64 {
            paged.append(s, &dk, &dv, diverge).expect("diverge");
        }
        let mut dense = SessionStore::with_block_steps(usize::MAX, KvPrecision::F32, bs);
        for s in 0..sessions32 as u64 {
            dense.create(s, heads, d, prefix + diverge).expect("create");
            dense.append(s, &pk, &pv, prefix).expect("prefill");
            dense.append(s, &dk, &dv, diverge).expect("diverge");
        }
        let ratio = dense.bytes() as f64 / paged.bytes() as f64;
        println!(
            "shared-prefix memory: dense {} bytes vs paged {} bytes -> {ratio:.2}x \
             ({} prefix blocks stored once across {sessions32} sessions, cow_copies={})",
            dense.bytes(),
            paged.bytes(),
            prefix / bs,
            paged.cow_copies,
        );
        assert_eq!(paged.cow_copies, 0, "block-aligned fork must not copy");
        sb.note("paged_shared_prefix_bytes_over_dense_sessions32", ratio);
    }
    {
        // (b) throughput: the 8-session decode gather served through the
        // paged block-table views vs the same logical KV as contiguous
        // buffers. Outputs are bit-identical by construction; the ratio
        // prices the per-tile fragment resolution.
        let (nses, nkv) = (8usize, 2048usize);
        let cfg = KernelConfig::default();
        let mut store = SessionStore::with_block_steps(usize::MAX, KvPrecision::F32, bs);
        let (mut ks, mut vs, mut qs) = (Vec::new(), Vec::new(), Vec::new());
        for s in 0..nses {
            let k = rng.normal_vec(heads * nkv * d, 0.5);
            let v = rng.normal_vec(heads * nkv * d, 0.5);
            store.create(s as u64, heads, d, nkv).expect("create");
            store.append(s as u64, &k, &v, nkv).expect("append");
            ks.push(k);
            vs.push(v);
            qs.push(rng.normal_vec(heads * d, 0.5));
        }
        let mut dense_jobs = Vec::with_capacity(nses * heads);
        for s in 0..nses {
            for h in 0..heads {
                dense_jobs.push(KvBlockJob {
                    q: &qs[s][h * d..(h + 1) * d],
                    k: KvRef::F32(&ks[s][h * nkv * d..(h + 1) * nkv * d]),
                    v: KvRef::F32(&vs[s][h * nkv * d..(h + 1) * nkv * d]),
                    nq: 1,
                    n: nkv,
                    d,
                    scale,
                    causal: false,
                });
            }
        }
        let ids: Vec<u64> = (0..nses as u64).collect();
        let views: Vec<_> = store
            .gather_many(&ids)
            .into_iter()
            .map(|o| o.expect("session exists"))
            .collect();
        let mut paged_jobs = Vec::with_capacity(nses * heads);
        for s in 0..nses {
            for h in 0..heads {
                paged_jobs.push(PagedKvBlockJob {
                    q: &qs[s][h * d..(h + 1) * d],
                    k: views[s].head_k(h),
                    v: views[s].head_v(h),
                    nq: 1,
                    n: nkv,
                    d,
                    scale,
                    causal: false,
                });
            }
        }
        let mut scratch = BatchScratch::new();
        let mut out_d = vec![0.0f32; nses * heads * d];
        let mut out_p = vec![0.0f32; nses * heads * d];
        run_kv_blocks_flat_into_with(&cfg, &dense_jobs, &mut out_d, &mut scratch);
        run_paged_kv_blocks_flat_into_with(&cfg, &paged_jobs, &mut out_p, &mut scratch);
        assert_eq!(out_d, out_p, "paged gather must be bit-identical to contiguous");
        let pairs = (nses * heads * nkv) as f64;
        let t_dense = sb.bench_throughput(
            "serving_dense_kv_blocks_sessions8_nkv2048_d64",
            pairs,
            "pair",
            || {
                bb(run_kv_blocks_flat_into_with(&cfg, &dense_jobs, &mut out_d, &mut scratch));
            },
        );
        let t_paged = sb.bench_throughput(
            "serving_paged_kv_blocks_sessions8_nkv2048_d64",
            pairs,
            "pair",
            || {
                bb(run_paged_kv_blocks_flat_into_with(&cfg, &paged_jobs, &mut out_p, &mut scratch));
            },
        );
        println!("-- paged/dense streaming throughput: {:.3}x", t_dense / t_paged);
        sb.note("paged_over_dense_sessions8_nkv2048_d64", t_dense / t_paged);
    }
    merge_serving_into_bench_json(&sb, "BENCH_kernels.json");

    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/coordinator_serving.txt",
        format!(
            "sequential_s={seq_s:.4}\nconcurrent_s={conc_s:.4}\nmax_batch={max_batch}\n\
             fused_sweep_serial_s={serial_s:.4}\nfused_sweep_fused_s={fused_s:.4}\n\
             fused_over_serial_sessions8_nkv2048_d64={:.3}\n{}\n",
            serial_s / fused_s,
            coord.metrics.snapshot().render()
        ),
    )
    .ok();
}
