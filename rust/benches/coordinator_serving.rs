//! Coordinator serving benchmark: end-to-end request latency and
//! throughput through the full stack (router -> batcher -> KV cache ->
//! FLASH-D kernel), including the batching-vs-sequential ablation.
//!
//! Uses the PJRT artifact engine when artifacts are built; otherwise falls
//! back to the pure-Rust tiled kernel engine (`Coordinator::start_naive`),
//! so the serving path is measurable in artifact-free environments too.

use flashd::bench_harness::workload::{session_requests, stateless_request, WorkloadSpec};
use flashd::coordinator::router::Router;
use flashd::coordinator::{Coordinator, CoordinatorConfig, Variant};
use flashd::runtime::Manifest;
use std::time::Instant;

/// Synthetic router covering the default workload signature (4 heads,
/// head_dim 32) at a few context capacities.
fn synthetic_router() -> Router {
    Router::from_manifest(
        &Manifest::parse(
            r#"{"artifacts": {
          "attn_flashd_h4_l128_d32": {"file":"x","kind":"attention","variant":"flashd","causal":false,
            "heads":4,"seq":128,"head_dim":32,"inputs":[],"n_outputs":1},
          "attn_flashd_h4_l256_d32": {"file":"y","kind":"attention","variant":"flashd","causal":false,
            "heads":4,"seq":256,"head_dim":32,"inputs":[],"n_outputs":1},
          "attn_flash2_h4_l256_d32": {"file":"z","kind":"attention","variant":"flash2","causal":false,
            "heads":4,"seq":256,"head_dim":32,"inputs":[],"n_outputs":1}
        }}"#,
        )
        .expect("synthetic manifest"),
    )
}

fn main() {
    let dir = flashd::runtime::default_artifact_dir();
    let fast = std::env::var("FLASHD_BENCH_FAST").is_ok();

    // The PJRT engine needs BOTH compiled artifacts and the pjrt_backend
    // cfg; the default build stubs the runtime, so fall back to the
    // tiled-kernel NaiveEngine in every other configuration.
    let coord = if cfg!(pjrt_backend) && dir.join("manifest.json").exists() {
        println!("=== coordinator serving (PJRT FLASH-D engine) ===\n");
        Coordinator::start(CoordinatorConfig::default()).expect("start coordinator")
    } else {
        println!("=== coordinator serving (tiled-kernel NaiveEngine; no PJRT backend/artifacts) ===\n");
        Coordinator::start_naive(CoordinatorConfig::default(), synthetic_router())
            .expect("start coordinator")
    };

    // -- stateless prefill-style requests, varying context --------------
    for &nkv in &[32usize, 128, 256] {
        let spec = WorkloadSpec::default();
        let iters = if fast { 5 } else { 20 };
        let mut lat = Vec::new();
        for i in 0..iters {
            let req = stateless_request(&spec, 50_000 + i as u64 * 7 + nkv as u64, 1, nkv);
            let t = Instant::now();
            let resp = coord.submit_blocking(req);
            resp.output.expect("ok");
            lat.push(t.elapsed().as_secs_f64() * 1e6);
        }
        println!(
            "stateless nkv={nkv:<4} p50={:>8.0}µs p95={:>8.0}µs  ({} iters)",
            flashd::util::percentile(&lat, 50.0),
            flashd::util::percentile(&lat, 95.0),
            lat.len()
        );
    }

    // -- decode stream through the KV cache ------------------------------
    let spec = WorkloadSpec {
        sessions: 1,
        prefill_len: 64,
        decode_steps: if fast { 8 } else { 32 },
        ..Default::default()
    };
    let reqs = session_requests(&spec, 7, 100_000);
    let t = Instant::now();
    let mut lat = Vec::new();
    for req in reqs {
        let t0 = Instant::now();
        coord.submit_blocking(req).output.expect("ok");
        lat.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    println!(
        "\ndecode stream: {} steps in {:.2}s  p50={:.0}µs p95={:.0}µs",
        lat.len() - 1,
        t.elapsed().as_secs_f64(),
        flashd::util::percentile(&lat[1..], 50.0),
        flashd::util::percentile(&lat[1..], 95.0),
    );

    // -- batching ablation: concurrent burst vs sequential ---------------
    let burst = if fast { 8 } else { 32 };
    // fresh session
    let mut pre = session_requests(
        &WorkloadSpec { sessions: 1, decode_steps: 0, ..Default::default() },
        11,
        200_000,
    );
    coord.submit_blocking(pre.remove(0)).output.expect("prefill");

    // sequential
    let t = Instant::now();
    for i in 0..burst as u64 {
        let mut reqs = session_requests(&WorkloadSpec::default(), 11, 300_000 + i * 50);
        let dec = reqs.pop().unwrap();
        coord.submit_blocking(dec).output.expect("ok");
    }
    let seq_s = t.elapsed().as_secs_f64();

    // concurrent (dynamic batching window can merge them)
    let coord = std::sync::Arc::new(coord);
    let t = Instant::now();
    let mut handles = Vec::new();
    for i in 0..burst as u64 {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut reqs = session_requests(&WorkloadSpec::default(), 11, 400_000 + i * 50);
            let dec = reqs.pop().unwrap();
            c.submit_blocking(dec)
        }));
    }
    let mut max_batch = 0;
    for h in handles {
        let r = h.join().unwrap();
        r.output.expect("ok");
        max_batch = max_batch.max(r.batch_size);
    }
    let conc_s = t.elapsed().as_secs_f64();
    println!(
        "\nbatching ablation ({burst} decodes): sequential {:.3}s ({:.0} req/s) vs concurrent {:.3}s ({:.0} req/s), max batch {max_batch}, speedup {:.2}x",
        seq_s,
        burst as f64 / seq_s,
        conc_s,
        burst as f64 / conc_s,
        seq_s / conc_s
    );
    println!("\nmetrics:\n{}", coord.metrics.snapshot().render());

    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/coordinator_serving.txt",
        format!(
            "sequential_s={seq_s:.4}\nconcurrent_s={conc_s:.4}\nmax_batch={max_batch}\n{}\n",
            coord.metrics.snapshot().render()
        ),
    )
    .ok();
}
