//! Kernel throughput: the four software attention formulations head to
//! head (f32), the tiled + batched FLASH-D engine (tile and 1/2/4/8-thread
//! sweeps, emitted to the machine-readable `BENCH_kernels.json`), the
//! query-blocked vs per-query multi-query sweep (the KV-bandwidth
//! amortization headline), the reduced-precision + PWL hardware-faithful
//! paths, and the end-to-end PJRT artifact latency of FLASH-D vs
//! FlashAttention2 — the software analogue of the paper's "no performance
//! penalty" claim.

use flashd::bench_harness::suites::{SWEEP_NQ, SWEEP_SHAPES, SWEEP_THREADS, SWEEP_TILES};
use flashd::kernels::flashd as fd;
use flashd::kernels::{
    batch, flash1, flash2, naive, scalar, tiled, AttnProblem, BlockJob, KernelConfig, KvRef,
    KvRowJob, KvView, RowJob, SigmoidMode,
};
use flashd::numerics::quant::{quantize_bf16, quantize_fp8};
use flashd::numerics::{Bf16, Fp8E4M3};
use flashd::pwl::{LnPwl, SigmoidPwl};
use flashd::util::bench::{bb, Bench};
use flashd::util::rng::Rng;

fn main() {
    let mut b = Bench::new("kernel_throughput");
    let mut rng = Rng::new(0xBEEF);

    println!("=== software kernels, f32, one query over (n, d) KV pairs ===");
    for &(n, d) in &[(128usize, 32usize), (512, 64), (2048, 64)] {
        let p = AttnProblem::random(&mut rng, 1, n, d, 2.0);
        let pairs = n as f64;
        b.bench_throughput(&format!("naive      n={n} d={d}"), pairs, "pair", || {
            bb(naive::attention(&p.q, &p.k, &p.v, n, d, 1.0));
        });
        b.bench_throughput(&format!("flash1     n={n} d={d}"), pairs, "pair", || {
            bb(flash1::attention(&p.q, &p.k, &p.v, n, d, 1.0));
        });
        b.bench_throughput(&format!("flash2     n={n} d={d}"), pairs, "pair", || {
            bb(flash2::attention(&p.q, &p.k, &p.v, n, d, 1.0));
        });
        b.bench_throughput(&format!("flashd     n={n} d={d}"), pairs, "pair", || {
            bb(fd::attention(&p.q, &p.k, &p.v, n, d, 1.0));
        });
        b.bench_throughput(&format!("flashd+skip n={n} d={d}"), pairs, "pair", || {
            bb(fd::attention_instrumented(
                &p.q, &p.k, &p.v, n, d, 1.0,
                fd::SkipCriterion::Static,
            ));
        });
    }

    println!("\n=== tiled vs scalar FLASH-D (single thread) ===");
    for &(n, d) in &SWEEP_SHAPES {
        let p = AttnProblem::random(&mut rng, 1, n, d, 2.0);
        let pairs = n as f64;
        let scalar_ns = b.bench_throughput(&format!("flashd scalar     n={n} d={d}"), pairs, "pair", || {
            bb(fd::attention(&p.q, &p.k, &p.v, n, d, 1.0));
        });
        let mut best_tiled = f64::INFINITY;
        for &tile in &SWEEP_TILES {
            let t = b.bench_throughput(
                &format!("flashd tiled B={tile:<3} n={n} d={d}"),
                pairs,
                "pair",
                || {
                    bb(tiled::attention_tiled(&p.q, &p.k, &p.v, n, d, 1.0, tile));
                },
            );
            best_tiled = best_tiled.min(t);
        }
        b.bench_throughput(&format!("flashd tiled+skip n={n} d={d}"), pairs, "pair", || {
            bb(tiled::attention_tiled_instrumented(
                &p.q, &p.k, &p.v, n, d, 1.0,
                tiled::DEFAULT_TILE,
                fd::SkipCriterion::Static,
            ));
        });
        b.note(&format!("tiled_over_scalar_n{n}_d{d}"), scalar_ns / best_tiled);
    }

    println!("\n=== query-blocked vs per-query multi-query (prefill shape) ===");
    {
        let (nkv, d) = (2048usize, 64usize);
        for &nq in &SWEEP_NQ {
            let p = AttnProblem::random(&mut rng, nq, nkv, d, 2.0);
            let pairs = (nq * nkv) as f64;
            // per-query baseline: every query streams the whole KV (the
            // PR 1 multi-query path)
            let per_query = b.bench_throughput(
                &format!("multi per-query nq={nq:<3} nkv={nkv} d={d}"),
                pairs,
                "pair",
                || {
                    for iq in 0..nq {
                        bb(tiled::attention_tiled(
                            p.q_row(iq), &p.k, &p.v, nkv, d, 1.0,
                            tiled::DEFAULT_TILE,
                        ));
                    }
                },
            );
            // query-blocked: each KV tile streams once per DEFAULT_BLOCK_Q
            // queries (bit-identical outputs, single thread)
            let blocked = b.bench_throughput(
                &format!("multi qblock    nq={nq:<3} nkv={nkv} d={d}"),
                pairs,
                "pair",
                || {
                    bb(tiled::attention_tiled_multi(
                        &p.q, &p.k, &p.v, nq, nkv, d, 1.0,
                        tiled::DEFAULT_TILE,
                    ));
                },
            );
            println!("-- blocked/per-query speedup at nq={nq}: {:.2}x", per_query / blocked);
            if nq == 512 {
                // the PR 2 acceptance headline ratio
                b.note("qblock_over_perquery_nq512_nkv2048_d64", per_query / blocked);
            }
            // grouped multi-thread driver over the same block (the serving
            // prefill path end to end)
            if nq >= 64 {
                let cfg = KernelConfig::default();
                let block = BlockJob {
                    q: &p.q, k: &p.k, v: &p.v,
                    nq, n: nkv, d,
                    scale: 1.0,
                    causal: false,
                };
                let mut out = vec![0.0f32; nq * d];
                let mut scratch = batch::BatchScratch::new();
                b.bench_throughput(
                    &format!("multi qblock+mt nq={nq:<3} nkv={nkv} d={d}"),
                    pairs,
                    "pair",
                    || {
                        bb(batch::run_blocks_into_with(&cfg, &[block], d, &mut out, &mut scratch));
                    },
                );
            }
        }
    }

    println!("\n=== batched driver thread sweep ===");
    for &(n, d) in &SWEEP_SHAPES {
        // A realistic multi-head block: 32 independent query rows sharing
        // one (n, d) KV context.
        let rows = 32usize;
        let p = AttnProblem::random(&mut rng, rows, n, d, 2.0);
        let jobs: Vec<RowJob> = (0..rows)
            .map(|r| RowJob {
                q: &p.q[r * d..(r + 1) * d],
                k: &p.k,
                v: &p.v,
                n,
                d,
                scale: 1.0,
            })
            .collect();
        let mut t1 = f64::NAN;
        for &threads in &SWEEP_THREADS {
            // block_q = 1 keeps this a pure thread-scaling measurement:
            // the 32 rows share one KV buffer, and grouping them into
            // query blocks would cap the partition at rows/block_q chunks
            // (the blocking effect has its own sweep section below).
            let cfg = KernelConfig {
                tile: tiled::DEFAULT_TILE,
                threads,
                skip: fd::SkipCriterion::None,
                block_q: 1,
                ..KernelConfig::default()
            };
            let t = b.bench_throughput(
                &format!("batch rows=32 T={threads} n={n} d={d}"),
                (rows * n) as f64,
                "pair",
                || {
                    bb(batch::run_rows(&cfg, &jobs));
                },
            );
            if threads == 1 {
                t1 = t;
            } else {
                println!("-- scaling at T={threads}: {:.2}x over T=1", t1 / t);
            }
        }
    }

    println!("\n=== hardware-faithful paths (reduced precision + PWL) ===");
    let sig = SigmoidPwl::new();
    let ln = LnPwl::new();
    let p = AttnProblem::random(&mut rng, 1, 256, 32, 2.0);
    b.bench("flashd bf16 exact-nonlin n=256 d=32", || {
        bb(fd::attention_generic::<Bf16>(&p.q, &p.k, &p.v, 256, 32, 1.0));
    });
    b.bench("flashd bf16 pwl          n=256 d=32", || {
        bb(fd::attention_pwl::<Bf16>(&p.q, &p.k, &p.v, 256, 32, 1.0, &sig, &ln));
    });
    b.bench("flashd fp8  pwl          n=256 d=32", || {
        bb(fd::attention_pwl::<Fp8E4M3>(&p.q, &p.k, &p.v, 256, 32, 1.0, &sig, &ln));
    });
    b.bench("flash2 bf16 exact-nonlin n=256 d=32", || {
        bb(flash2::attention_generic::<Bf16>(&p.q, &p.k, &p.v, 256, 32, 1.0));
    });

    println!("\n=== precision ladder: SIMD primitives / quantized KV / PWL sigmoid ===");
    {
        let (n, d) = (2048usize, 64usize);
        // (a) hot-loop primitives: crate-level dot/axpy_blend (vectorized
        // under --features simd, identical to scalar otherwise) vs the
        // always-compiled scalar reference, over one full KV stream.
        let p = AttnProblem::random(&mut rng, 1, n, d, 2.0);
        let mut o = vec![0.0f32; d];
        let t_vec =
            b.bench_throughput(&format!("primitives crate  n={n} d={d}"), n as f64, "pair", || {
                let mut s = 0.0f32;
                for i in 0..n {
                    s += flashd::kernels::dot(&p.q, &p.k[i * d..(i + 1) * d]);
                    flashd::kernels::axpy_blend(&mut o, &p.v[i * d..(i + 1) * d], 0.125);
                }
                bb((s, o[0]));
            });
        let t_sca =
            b.bench_throughput(&format!("primitives scalar n={n} d={d}"), n as f64, "pair", || {
                let mut s = 0.0f32;
                for i in 0..n {
                    s += scalar::dot(&p.q, &p.k[i * d..(i + 1) * d]);
                    scalar::axpy_blend(&mut o, &p.v[i * d..(i + 1) * d], 0.125);
                }
                bb((s, o[0]));
            });
        // == 1.0 by construction on the default (scalar) build; the real
        // ratio comes from the nightly --features simd CI leg.
        b.note("simd_over_scalar_n2048_d64", t_sca / t_vec);

        // (b) quantized KV streaming: 8 decode rows over a (2048, 64) KV
        // context each — the bandwidth-bound serving shape. Single thread
        // and no skipping so the ratio isolates the memory-path change.
        let heads = 8usize;
        let ps: Vec<AttnProblem> =
            (0..heads).map(|_| AttnProblem::random(&mut rng, 1, n, d, 2.0)).collect();
        let cfg = KernelConfig {
            skip: fd::SkipCriterion::None,
            threads: 1,
            ..KernelConfig::default()
        };
        let mut out = vec![0.0f32; heads * d];
        let mut scratch = batch::BatchScratch::new();
        let jobs32: Vec<KvRowJob> = ps
            .iter()
            .map(|p| KvRowJob {
                q: &p.q,
                k: KvView::Contig(KvRef::F32(p.k.as_slice())),
                v: KvView::Contig(KvRef::F32(p.v.as_slice())),
                n,
                d,
                scale: 1.0,
            })
            .collect();
        let pairs = (heads * n) as f64;
        let t32 = b.bench_throughput(&format!("kv-rows f32  h={heads} nkv={n} d={d}"), pairs, "pair", || {
            bb(batch::run_kv_rows_into_with(&cfg, &jobs32, d, &mut out, &mut scratch));
        });
        let st16: Vec<(Vec<u16>, Vec<u16>)> =
            ps.iter().map(|p| (quantize_bf16(&p.k), quantize_bf16(&p.v))).collect();
        let jobs16: Vec<KvRowJob> = ps
            .iter()
            .zip(&st16)
            .map(|(p, (k, v))| KvRowJob {
                q: &p.q,
                k: KvView::Contig(KvRef::Bf16(k.as_slice())),
                v: KvView::Contig(KvRef::Bf16(v.as_slice())),
                n,
                d,
                scale: 1.0,
            })
            .collect();
        let t16 = b.bench_throughput(&format!("kv-rows bf16 h={heads} nkv={n} d={d}"), pairs, "pair", || {
            bb(batch::run_kv_rows_into_with(&cfg, &jobs16, d, &mut out, &mut scratch));
        });
        b.note("bf16_kv_over_f32_nkv2048_d64", t32 / t16);
        let st8: Vec<(Vec<u8>, Vec<u8>)> =
            ps.iter().map(|p| (quantize_fp8(&p.k), quantize_fp8(&p.v))).collect();
        let jobs8: Vec<KvRowJob> = ps
            .iter()
            .zip(&st8)
            .map(|(p, (k, v))| KvRowJob {
                q: &p.q,
                k: KvView::Contig(KvRef::Fp8(k.as_slice())),
                v: KvView::Contig(KvRef::Fp8(v.as_slice())),
                n,
                d,
                scale: 1.0,
            })
            .collect();
        let t8 = b.bench_throughput(&format!("kv-rows fp8  h={heads} nkv={n} d={d}"), pairs, "pair", || {
            bb(batch::run_kv_rows_into_with(&cfg, &jobs8, d, &mut out, &mut scratch));
        });
        b.note("fp8_kv_over_f32_nkv2048_d64", t32 / t8);

        // (c) PWL sigmoid fast path: same rows, exact transcendentals
        // (the f32 baseline above) vs the 8-segment table pair.
        let cfg_pwl = KernelConfig { sigmoid: SigmoidMode::Pwl { segments: 8 }, ..cfg };
        let t_pwl = b.bench_throughput(&format!("kv-rows pwl8 h={heads} nkv={n} d={d}"), pairs, "pair", || {
            bb(batch::run_kv_rows_into_with(&cfg_pwl, &jobs32, d, &mut out, &mut scratch));
        });
        b.note("pwl_sigmoid_over_exact_n2048_d64", t32 / t_pwl);
    }

    println!("\n=== PJRT artifact latency (iso-performance check) ===");
    match flashd::runtime::open_default() {
        Err(e) => println!("(skipped: {e})"),
        Ok(rt) => {
            let (h, l, d) = (4usize, 128usize, 32usize);
            let q = Rng::new(1).normal_vec(h * l * d, 0.5);
            let inputs = [
                flashd::runtime::lit_f32(&q, &[h, l, d]).unwrap(),
                flashd::runtime::lit_f32(&q, &[h, l, d]).unwrap(),
                flashd::runtime::lit_f32(&q, &[h, l, d]).unwrap(),
                flashd::runtime::lit_i32(&[l as i32], &[1, 1]).unwrap(),
            ];
            // warm the executable cache outside the timed region
            rt.execute("attn_flashd_h4_l128_d32", &inputs).unwrap();
            rt.execute("attn_flash2_h4_l128_d32", &inputs).unwrap();
            let t_fd = b.bench_throughput("pjrt attn_flashd h4_l128_d32", (h * l) as f64, "q", || {
                bb(rt.execute("attn_flashd_h4_l128_d32", &inputs).unwrap());
            });
            let t_f2 = b.bench_throughput("pjrt attn_flash2 h4_l128_d32", (h * l) as f64, "q", || {
                bb(rt.execute("attn_flash2_h4_l128_d32", &inputs).unwrap());
            });
            let ratio = t_fd / t_f2;
            println!("flashd/flash2 latency ratio: {ratio:.3} (paper: 1.00 — same performance)");
        }
    }

    b.write_csv();
    // The committed perf-trajectory file (schema: util::bench::Bench::to_json).
    b.write_json("BENCH_kernels.json");
}
