//! Fig. 5 reproduction: average power of the two blocks under real
//! attention stimulus at 500 MHz. Activity (toggle densities + skip
//! fraction) is measured from the trained zoo models decoding suite
//! prompts — the analogue of the paper's PowerPro runs over PromptBench
//! traces. Falls back to synthetic stimulus when no weights exist yet.
//!
//! Emits reports/fig5.csv.

use flashd::bench_harness::traces;
use flashd::hw::{power, CostDb, Format};
use flashd::numerics::{Bf16, Fp8E4M3};

fn main() {
    println!("=== Fig. 5: average power at 28 nm / 500 MHz ===\n");
    let dir = flashd::runtime::default_artifact_dir();
    let db = CostDb::tsmc28();

    let prompts = if std::env::var("FLASHD_BENCH_FAST").is_ok() { 1 } else { 2 };
    println!("measuring switching activity from model traces ({prompts} prompts/suite) ...");
    let act16 = traces::measured_activity::<Bf16>(&dir, prompts);
    let act8 = traces::measured_activity::<Fp8E4M3>(&dir, prompts);
    println!(
        "  bf16: alpha_kv={:.3} alpha_score={:.3} alpha_nonlin={:.3} skip={:.2}% ({} queries)",
        act16.alpha_kv, act16.alpha_score, act16.alpha_nonlin,
        act16.skip_fraction * 100.0, act16.n_queries
    );
    println!(
        "  fp8 : alpha_kv={:.3} alpha_score={:.3} alpha_nonlin={:.3} skip={:.2}%\n",
        act8.alpha_kv, act8.alpha_score, act8.alpha_nonlin, act8.skip_fraction * 100.0
    );

    let rows = power::fig5_rows(
        &|fmt| match fmt {
            Format::BF16 => act16.clone(),
            Format::FP8_E4M3 => act8.clone(),
            Format::FP32 => act16.clone(),
        },
        &db,
    );
    println!("{}", power::render_table(&rows));

    let savings: Vec<f64> = rows.iter().map(|r| r.saving_pct).collect();
    let avg = flashd::util::mean(&savings);
    let (min, max) = savings
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &x| (a.min(x), b.max(x)));
    println!("power saving: avg {avg:.1}%  range {min:.1}%–{max:.1}%");
    println!("paper:        avg 20.3%  range ~16%–27%");
    println!("(memory/IO power excluded — identical for both designs, as in the paper)");

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig5.csv", power::to_csv(&rows)).unwrap();
    println!("\nwrote reports/fig5.csv");
}
