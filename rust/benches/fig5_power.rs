//! Fig. 5 reproduction: average power of the two blocks under real
//! attention stimulus at 500 MHz. Activity (toggle densities + skip
//! fraction) is measured from the trained zoo models decoding suite
//! prompts — the analogue of the paper's PowerPro runs over PromptBench
//! traces. Falls back to synthetic stimulus when no weights exist yet.
//!
//! Emits reports/fig5.csv plus reports/fig5.json (which records whether
//! the stimulus was measured from a real model or fell back to the
//! synthetic default, and why).

use flashd::bench_harness::traces::{self, TraceSource};
use flashd::hw::{power, CostDb, Format};
use flashd::numerics::{Bf16, Fp8E4M3};
use flashd::util::json::Json;
use std::collections::BTreeMap;

fn main() {
    println!("=== Fig. 5: average power at 28 nm / 500 MHz ===\n");
    let dir = flashd::runtime::default_artifact_dir();
    let db = CostDb::tsmc28();

    let prompts = if std::env::var("FLASHD_BENCH_FAST").is_ok() { 1 } else { 2 };
    println!("measuring switching activity from model traces ({prompts} prompts/suite) ...");
    let (act16, source) = traces::measured_activity_traced::<Bf16>(&dir, prompts);
    let (act8, _) = traces::measured_activity_traced::<Fp8E4M3>(&dir, prompts);
    match &source {
        TraceSource::Measured { model } => println!("  stimulus: traces of model {model}"),
        TraceSource::Synthetic { reason } => {
            println!("  stimulus: SYNTHETIC fallback — {reason}");
        }
    }
    println!(
        "  bf16: alpha_kv={:.3} alpha_score={:.3} alpha_nonlin={:.3} skip={:.2}% ({} queries)",
        act16.alpha_kv, act16.alpha_score, act16.alpha_nonlin,
        act16.skip_fraction * 100.0, act16.n_queries
    );
    println!(
        "  fp8 : alpha_kv={:.3} alpha_score={:.3} alpha_nonlin={:.3} skip={:.2}%\n",
        act8.alpha_kv, act8.alpha_score, act8.alpha_nonlin, act8.skip_fraction * 100.0
    );

    let rows = power::fig5_rows(
        &|fmt| match fmt {
            Format::BF16 => act16.clone(),
            Format::FP8_E4M3 => act8.clone(),
            Format::FP32 => act16.clone(),
        },
        &db,
    );
    println!("{}", power::render_table(&rows));

    let savings: Vec<f64> = rows.iter().map(|r| r.saving_pct).collect();
    let avg = flashd::util::mean(&savings);
    let (min, max) = savings
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &x| (a.min(x), b.max(x)));
    println!("power saving: avg {avg:.1}%  range {min:.1}%–{max:.1}%");
    println!("paper:        avg 20.3%  range ~16%–27%");
    println!("(memory/IO power excluded — identical for both designs, as in the paper)");

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig5.csv", power::to_csv(&rows)).unwrap();

    // Machine-readable companion: the power rows plus stimulus
    // provenance — `synthetic_fallback` is null when the activity came
    // from real model traces, else the reason measurement fell back.
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::Obj(BTreeMap::from([
                ("format".to_string(), Json::Str(r.fmt.name().to_string())),
                ("d".to_string(), Json::Num(r.d as f64)),
                ("fa2_mw".to_string(), Json::Num(r.fa2_mw)),
                ("flashd_mw".to_string(), Json::Num(r.flashd_mw)),
                ("saving_pct".to_string(), Json::Num(r.saving_pct)),
            ]))
        })
        .collect();
    let fallback = match &source {
        TraceSource::Measured { .. } => Json::Null,
        TraceSource::Synthetic { reason } => Json::Str(reason.clone()),
    };
    let stimulus_model = match &source {
        TraceSource::Measured { model } => Json::Str(model.clone()),
        TraceSource::Synthetic { .. } => Json::Null,
    };
    let obj = BTreeMap::from([
        ("suite".to_string(), Json::Str("fig5_power".to_string())),
        ("rows".to_string(), Json::Arr(json_rows)),
        ("avg_saving_pct".to_string(), Json::Num(avg)),
        ("stimulus_model".to_string(), stimulus_model),
        ("synthetic_fallback".to_string(), fallback),
    ]);
    std::fs::write("reports/fig5.json", Json::Obj(obj).to_string()).unwrap();
    println!("\nwrote reports/fig5.csv and reports/fig5.json");
}
