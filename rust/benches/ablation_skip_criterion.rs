//! Ablation: the skip criterion design space (DESIGN.md experiment index).
//!
//! The paper ships the *static* [-6, 11] score-difference test and names an
//! *adaptive* test (include ln w_{i-1}) as future work. This bench sweeps
//! both, measuring (a) how often updates are skipped and (b) the output
//! error each criterion introduces vs the exact recursion, across score
//! scales — quantifying the trade the paper describes qualitatively.

use flashd::kernels::flashd::{attention, attention_instrumented, SkipCriterion};
use flashd::kernels::{max_abs_diff, AttnProblem};
use flashd::util::rng::Rng;

fn main() {
    println!("=== ablation: skip criterion vs skip rate and output error ===\n");
    let criteria: Vec<(String, SkipCriterion)> = vec![
        ("none".into(), SkipCriterion::None),
        ("static[-6,11]".into(), SkipCriterion::Static),
        ("adaptive[-6,6]".into(), SkipCriterion::Adaptive { lo: -6.0, hi: 6.0 }),
        ("adaptive[-8,8]".into(), SkipCriterion::Adaptive { lo: -8.0, hi: 8.0 }),
        ("adaptive[-4,4]".into(), SkipCriterion::Adaptive { lo: -4.0, hi: 4.0 }),
    ];

    let fast = std::env::var("FLASHD_BENCH_FAST").is_ok();
    let queries = if fast { 8 } else { 64 };
    let (n, d) = (512usize, 32usize);

    let mut csv = String::from("score_std,criterion,skip_pct,max_err,mean_err\n");
    println!(
        "{:<10} {:<16} {:>9} {:>12} {:>12}",
        "score_std", "criterion", "skip%", "max_err", "mean_err"
    );
    for &score_std in &[1.0f32, 2.0, 4.0, 8.0] {
        let mut rng = Rng::new(0xAB1A ^ (score_std as u64));
        let problems: Vec<AttnProblem> = (0..queries)
            .map(|_| AttnProblem::random(&mut rng, 1, n, d, score_std))
            .collect();
        for (name, crit) in &criteria {
            let mut skip_pct = Vec::new();
            let mut errs = Vec::new();
            for p in &problems {
                let exact = attention(&p.q, &p.k, &p.v, n, d, p.scale);
                let (got, stats) =
                    attention_instrumented(&p.q, &p.k, &p.v, n, d, p.scale, *crit);
                skip_pct.push(stats.percent());
                errs.push(max_abs_diff(&exact, &got) as f64);
            }
            let sp = flashd::util::mean(&skip_pct);
            let maxe = errs.iter().cloned().fold(0.0, f64::max);
            let meane = flashd::util::mean(&errs);
            println!("{score_std:<10} {name:<16} {sp:>8.2}% {maxe:>12.2e} {meane:>12.2e}");
            csv.push_str(&format!("{score_std},{name},{sp:.4},{maxe:.6e},{meane:.6e}\n"));
        }
        println!();
    }

    println!("reading: the adaptive criterion (paper's future work) skips more at");
    println!("equal thresholds because ln w_{{i-1}} <= 0 shifts arguments left, and");
    println!("its skip-high test is sound where the static one is pessimistic.");

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/ablation_skip_criterion.csv", csv).unwrap();
    println!("\nwrote reports/ablation_skip_criterion.csv");
}
