//! Fig. 4 reproduction: 28 nm block area for FLASH-D vs the
//! FlashAttention2 kernel, BFloat16 and FP8-E4M3, d in {16, 64, 256} —
//! plus the iso-latency check of §V-A (8/10/12 cycles at 500 MHz).
//!
//! Emits reports/fig4.csv.

use flashd::hw::{area, datapath, CostDb, Design};

fn main() {
    println!("=== Fig. 4: hardware area at 28 nm (single-query block) ===\n");
    let db = CostDb::tsmc28();
    let rows = area::fig4_rows(&db);
    println!("{}", area::render_table(&rows));

    let savings: Vec<f64> = rows.iter().map(|r| r.saving_pct).collect();
    let avg = flashd::util::mean(&savings);
    let (min, max) = savings
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &x| (a.min(x), b.max(x)));
    println!("area saving: avg {avg:.1}%  range {min:.1}%–{max:.1}%");
    println!("paper:       avg 22.8%  range ~20%–28%\n");

    // §V-A iso-performance: identical pipelined latency for both designs.
    println!("latency (cycles @ 500 MHz), both designs:");
    for &d in &area::PAPER_DIMS {
        let fa2 = datapath::latency_cycles(Design::FlashAttention2, d);
        let fd = datapath::latency_cycles(Design::FlashD, d);
        assert_eq!(fa2, fd);
        println!(
            "  d={d:<4} {fa2:>2} cycles = {:.0} ns   (paper: {})",
            datapath::latency_ns(Design::FlashD, d, db.clock_hz),
            match d { 16 => 8, 64 => 10, _ => 12 },
        );
    }

    // Structural breakdown for DESIGN.md §Perf.
    println!("\nbreakdown bf16 d=64 (kGE):");
    for design in [Design::FlashAttention2, Design::FlashD] {
        let b = area::breakdown(design, 64, flashd::hw::Format::BF16, &db);
        println!(
            "  {:<16} dot={:.1} nonlin={:.1} update={:.1} state={:.1} epilogue={:.1} regs={:.1}",
            design.name(), b.dot / 1e3, b.nonlinear / 1e3, b.update / 1e3,
            b.state / 1e3, b.epilogue / 1e3, b.regs / 1e3
        );
    }

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig4.csv", area::to_csv(&rows)).unwrap();
    println!("\nwrote reports/fig4.csv");
}
