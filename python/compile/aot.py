"""AOT compile path: lower every Layer-1/Layer-2 computation to HLO *text*
artifacts that the Rust runtime loads via the xla crate's PJRT CPU client.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See /opt/xla-example.

Artifacts (written to ``artifacts/``):
  attn_flashd_*.hlo.txt      serving attention kernels (Pallas FLASH-D)
  attn_flash2_*.hlo.txt      baseline FlashAttention2 kernels
  model_fwd_<name>.hlo.txt   full transformer forward (Pallas FLASH-D inside)
  train_step_<name>.hlo.txt  AdamW train step (differentiable FLASH-D scan)
  init_<name>.fdw            initial parameters (FDW1 binary, shared ABI)
  manifest.json              everything the Rust side needs to load them

Run once via ``make artifacts``; Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.flash2 import flash2_attention
from compile.kernels.flashd import flashd_attention

# Serving attention shapes: (heads, seq, head_dim).  h4_l128_d32 matches the
# zoo's phi-tiny layer shape; the larger one exercises longer sequences.
ATTN_SHAPES = [(4, 128, 32), (4, 256, 32), (8, 128, 64)]
TRAIN_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def write_fdw(path: str, named: Sequence) -> None:
    """FDW1 binary weights: the flat-tensor ABI shared with rust/src/model.

    layout:  b"FDW1" | u32 n | n x ( u16 name_len | name | u8 ndim |
             ndim x u32 dim | f32-LE data )
    """
    with open(path, "wb") as f:
        f.write(b"FDW1")
        f.write(struct.pack("<I", len(named)))
        for name, arr in named:
            arr = np.asarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())


def _iospec(avals) -> List[Dict]:
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in avals]


def lower_attention(out_dir: str, manifest: Dict) -> None:
    for h, l, d in ATTN_SHAPES:
        spec = jax.ShapeDtypeStruct((h, l, d), jnp.float32)
        len_spec = jax.ShapeDtypeStruct((1, 1), jnp.int32)
        scale = float(d) ** -0.5
        for name, fn in (("flashd", flashd_attention), ("flash2", flash2_attention)):
            for causal in (False, True):
                tag = f"attn_{name}_h{h}_l{l}_d{d}" + ("_causal" if causal else "")
                lowered = jax.jit(
                    lambda q, k, v, kvl, fn=fn, causal=causal, scale=scale:
                    (fn(q, k, v, kvl, sm_scale=scale, causal=causal,
                        block_q=min(32, l), block_k=min(32, l)),)
                ).lower(spec, spec, spec, len_spec)
                path = os.path.join(out_dir, f"{tag}.hlo.txt")
                open(path, "w").write(to_hlo_text(lowered))
                manifest["artifacts"][tag] = {
                    "file": os.path.basename(path),
                    "kind": "attention",
                    "variant": name,
                    "causal": causal,
                    "heads": h, "seq": l, "head_dim": d,
                    "inputs": _iospec([spec, spec, spec, len_spec]),
                    "n_outputs": 1,
                }
                print(f"  {tag}: {os.path.getsize(path)} bytes")


def lower_model(out_dir: str, manifest: Dict, names: Sequence[str]) -> None:
    for name in names:
        cfg = M.MODEL_ZOO[name]
        spec_list = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_spec(cfg)]
        tok1 = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32)
        tokB = jax.ShapeDtypeStruct((TRAIN_BATCH, cfg.seq_len), jnp.int32)
        step_spec = jax.ShapeDtypeStruct((), jnp.int32)
        tcfg = M.TrainConfig()

        # -- forward (inference; Pallas FLASH-D kernel inside) --------------
        fwd = jax.jit(lambda ps, t: (M.forward_batch(cfg, list(ps), t, use_pallas=True),))
        lowered = fwd.lower(tuple(spec_list), tok1)
        path = os.path.join(out_dir, f"model_fwd_{name}.hlo.txt")
        open(path, "w").write(to_hlo_text(lowered))
        manifest["artifacts"][f"model_fwd_{name}"] = {
            "file": os.path.basename(path),
            "kind": "model_fwd",
            "model": name,
            "inputs": _iospec(spec_list + [tok1]),
            "n_outputs": 1,
        }
        print(f"  model_fwd_{name}: {os.path.getsize(path)} bytes")

        # -- train step ------------------------------------------------------
        def tstep(ps, m, v, step, toks):
            nps, nm, nv, loss = M.train_step(cfg, tcfg, list(ps), list(m),
                                             list(v), step, toks)
            return tuple(nps) + tuple(nm) + tuple(nv) + (loss,)

        lowered = jax.jit(tstep).lower(
            tuple(spec_list), tuple(spec_list), tuple(spec_list), step_spec, tokB)
        path = os.path.join(out_dir, f"train_step_{name}.hlo.txt")
        open(path, "w").write(to_hlo_text(lowered))
        manifest["artifacts"][f"train_step_{name}"] = {
            "file": os.path.basename(path),
            "kind": "train_step",
            "model": name,
            "batch": TRAIN_BATCH,
            "inputs": _iospec(spec_list * 3 + [step_spec, tokB]),
            "n_outputs": 3 * len(spec_list) + 1,
        }
        print(f"  train_step_{name}: {os.path.getsize(path)} bytes")

        # -- initial weights + optimizer zeros -------------------------------
        params = M.init_params(cfg, seed=hash(name) % 2**31)
        write_fdw(os.path.join(out_dir, f"init_{name}.fdw"),
                  list(zip([n for n, _ in M.param_spec(cfg)], params)))
        manifest["models"][name] = {
            "config": {
                "vocab_size": cfg.vocab_size, "seq_len": cfg.seq_len,
                "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                "n_layers": cfg.n_layers, "d_ff": cfg.d_ff,
                "block_q": cfg.block_q, "block_k": cfg.block_k,
                "qk_gain": cfg.qk_gain,
            },
            "n_params": M.n_params(cfg),
            "param_spec": [{"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)],
            "init_weights": f"init_{name}.fdw",
            "train": {"lr": tcfg.lr, "batch": TRAIN_BATCH},
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(M.MODEL_ZOO),
                    help="comma-separated zoo names (empty to skip models)")
    ap.add_argument("--skip-attn", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: Dict = {"version": 1, "artifacts": {}, "models": {}}
    if not args.skip_attn:
        print("lowering attention kernels ...")
        lower_attention(args.out, manifest)
    names = [n for n in args.models.split(",") if n]
    if names:
        print(f"lowering models {names} ...")
        lower_model(args.out, manifest, names)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
