"""Layer-2 JAX model: a tiny GPT-style causal transformer whose attention is
the FLASH-D Pallas kernel, plus the training step (fwd + bwd + AdamW) that
the Rust training driver executes through the AOT artifact.

Build-time only: this module is lowered to HLO text by aot.py and never
imported at runtime.

Architecture (configurable via ModelConfig):
  token embedding + learned positional embedding
  N x [ RMSNorm -> multi-head FLASH-D causal attention -> residual
        RMSNorm -> SwiGLU MLP -> residual ]
  final RMSNorm -> logits via tied embedding transpose

The differentiable attention used in training is the blocked FLASH-D
recursion written in plain jnp via lax.scan over KV blocks (the Pallas
kernel is forward-only; the scan form has the same math and is
differentiable, so training gradients flow through the exact FLASH-D
formulation rather than a surrogate).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.flashd import flashd_attention

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 256          # byte-level tokenizer
    seq_len: int = 128
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 344                # ~8/3 * d_model, SwiGLU
    block_q: int = 32
    block_k: int = 32
    # QK-RMSNorm (Qwen2/Gemma-class) with a fixed attention temperature:
    # q and k are RMS-normalized per head before the dot product and the
    # score is qk_gain * (q^ . k^) / sqrt(d_head). This keeps attention
    # score *differences* in the same range real LLMs exhibit (the
    # distribution Table I's skip criterion is calibrated against) —
    # without it, tiny byte-level models trained on templated text become
    # pathologically peaky.
    # 1.6 gives trained score ranges of roughly ±9 (attended-vs-background
    # transitions land just past the -6 skip threshold), reproducing the
    # low-single-digit skip rates the paper measures on production LLMs.
    qk_gain: float = 1.6

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Four tiny "LLM" variants standing in for the paper's Table I model rows
# (Phi-3-mini / Qwen-1.5B / Llama-3.1-1B / Gemma2-2B).  They differ in
# depth/width/head-count the way the real models do, which is what drives
# the spread of skip percentages across rows.
MODEL_ZOO: Dict[str, ModelConfig] = {
    "phi-tiny": ModelConfig(n_layers=4, d_model=128, n_heads=4, d_ff=344),
    "qwen-tiny": ModelConfig(n_layers=5, d_model=160, n_heads=5, d_ff=432),
    "llama-tiny": ModelConfig(n_layers=4, d_model=192, n_heads=6, d_ff=512),
    "gemma-tiny": ModelConfig(n_layers=3, d_model=224, n_heads=7, d_ff=600),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the flat parameter ABI shared with Rust.

    The Rust side (train driver, model engine, weights file) relies on this
    exact ordering; keep it stable.
    """
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab_size, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w_gate", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_up", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_down", (cfg.d_ff, cfg.d_model)),
        ]
    spec.append(("ln_f", (cfg.d_model,)))
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_spec(cfg):
        if name.endswith(("ln1", "ln2", "ln_f")) or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if "emb" in name else (2.0 / (shape[0] + shape[-1])) ** 0.5
            params.append(jnp.asarray(
                rng.normal(0.0, std, size=shape), jnp.float32))
    return params


def n_params(cfg: ModelConfig) -> int:
    return int(sum(np.prod(s) for _, s in param_spec(cfg)))


def _unflatten(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    names = [n for n, _ in param_spec(cfg)]
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Differentiable FLASH-D attention (lax.scan over KV blocks)
# ---------------------------------------------------------------------------

def flashd_attention_scan(q, k, v, sm_scale, causal=True, block_k=32):
    """Blocked FLASH-D recursion in plain jnp (differentiable).

    q, k, v: (H, L, D).  Mathematically identical to the Pallas kernel
    (same carry, same sigmoid-of-LSE-difference weight); used in the
    training graph where we need gradients.
    """
    h, lq, d = q.shape
    lk = k.shape[1]
    assert lk % block_k == 0
    nblocks = lk // block_k

    kb = k.reshape(h, nblocks, block_k, d)
    vb = v.reshape(h, nblocks, block_k, d)

    rows = jnp.arange(lq)

    def step(carry, inputs):
        o, lam = carry
        kj, vj, j = inputs
        s = jnp.einsum("hqd,hbd->hqb", q, kj) * sm_scale
        if causal:
            cols = j * block_k + jnp.arange(block_k)
            s = jnp.where(rows[None, :, None] >= cols[None, None, :], s, NEG_INF)
        mb = jnp.max(s, axis=-1)
        pb = jnp.exp(s - mb[..., None])
        lb = jnp.sum(pb, axis=-1)
        lam_b = mb + jnp.log(lb)
        ob = jnp.einsum("hqb,hbd->hqd", pb / lb[..., None], vj)
        lam_new = jnp.logaddexp(lam, lam_b)
        w = jnp.exp(lam_b - lam_new)           # = sigmoid(lam_b - lam)
        o = o + (ob - o) * w[..., None]        # Eq. (12)
        return (o, lam_new), None

    o0 = jnp.zeros((h, lq, d), jnp.float32)
    lam0 = jnp.full((h, lq), NEG_INF)
    (o, _), _ = jax.lax.scan(
        step, (o0, lam0),
        (jnp.swapaxes(kb, 0, 1), jnp.swapaxes(vb, 0, 1), jnp.arange(nblocks)))
    return o


# ---------------------------------------------------------------------------
# Transformer forward
# ---------------------------------------------------------------------------

def _rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _qknorm(x):
    """Gain-free RMS normalization over the head dimension (QK-norm)."""
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _split_heads(x, n_heads):
    l, dm = x.shape
    return jnp.swapaxes(x.reshape(l, n_heads, dm // n_heads), 0, 1)  # (H, L, Dh)


def _merge_heads(x):
    h, l, dh = x.shape
    return jnp.swapaxes(x, 0, 1).reshape(l, h * dh)


def forward(cfg: ModelConfig, flat_params: List[jnp.ndarray], tokens,
            use_pallas: bool = False):
    """Logits for one sequence. tokens: (L,) int32 -> (L, vocab)."""
    p = _unflatten(cfg, flat_params)
    l = tokens.shape[0]
    x = p["tok_emb"][tokens] + p["pos_emb"][:l]
    scale = cfg.qk_gain * cfg.d_head ** -0.5
    attn = flashd_attention if use_pallas else flashd_attention_scan
    for i in range(cfg.n_layers):
        h = _rmsnorm(x, p[f"l{i}.ln1"])
        q = _qknorm(_split_heads(h @ p[f"l{i}.wq"], cfg.n_heads))
        k = _qknorm(_split_heads(h @ p[f"l{i}.wk"], cfg.n_heads))
        v = _split_heads(h @ p[f"l{i}.wv"], cfg.n_heads)
        if use_pallas:
            o = attn(q, k, v, sm_scale=scale, causal=True,
                     block_q=cfg.block_q, block_k=cfg.block_k)
        else:
            o = attn(q, k, v, sm_scale=scale, causal=True, block_k=cfg.block_k)
        x = x + _merge_heads(o) @ p[f"l{i}.wo"]
        h = _rmsnorm(x, p[f"l{i}.ln2"])
        gate = jax.nn.silu(h @ p[f"l{i}.w_gate"])
        x = x + (gate * (h @ p[f"l{i}.w_up"])) @ p[f"l{i}.w_down"]
    x = _rmsnorm(x, p["ln_f"])
    return x @ p["tok_emb"].T


def forward_batch(cfg: ModelConfig, flat_params, tokens, use_pallas=False):
    """tokens: (B, L) -> (B, L, vocab)."""
    return jax.vmap(lambda t: forward(cfg, flat_params, t, use_pallas))(tokens)


def loss_fn(cfg: ModelConfig, flat_params, tokens):
    """Next-token cross entropy. tokens: (B, L)."""
    logits = forward_batch(cfg, flat_params, tokens)          # (B, L, V)
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# AdamW training step — flat-list ABI for the Rust driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-3
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def train_step(cfg: ModelConfig, tcfg: TrainConfig,
               params: List[jnp.ndarray], m: List[jnp.ndarray],
               v: List[jnp.ndarray], step, tokens):
    """One AdamW step. Returns (new_params, new_m, new_v, loss).

    All state crosses the Rust<->PJRT boundary as a flat list of f32
    tensors in param_spec order, plus the int32 step counter and the
    (B, L) int32 token batch.
    """
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens))(params)

    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    clip = jnp.minimum(1.0, tcfg.grad_clip / gnorm)
    b1, b2 = tcfg.betas
    t = step.astype(jnp.float32) + 1.0
    bias1 = 1.0 - b1 ** t
    bias2 = 1.0 - b2 ** t

    new_params, new_m, new_v = [], [], []
    decay_names = {n for n, s in zip([n for n, _ in param_spec(cfg)],
                                     [s for _, s in param_spec(cfg)])
                   if len(s) > 1}
    for (name, _), pi, mi, vi, gi in zip(param_spec(cfg), params, m, v, grads):
        g = gi * clip
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        upd = (mi / bias1) / (jnp.sqrt(vi / bias2) + tcfg.eps)
        if name in decay_names:
            upd = upd + tcfg.weight_decay * pi
        new_params.append(pi - tcfg.lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, loss
