"""Layer-1 Pallas kernel: FlashAttention2 (Alg. 2) — the baseline FLASH-D is
compared against.  Carries the classical (o, m, l) state across KV blocks and
performs the lazy softmax division in the epilogue, exactly mirroring the
structure of the paper's Fig. 1 datapath.

interpret=True for the same CPU-PJRT reason as flashd.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash2_kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref, o_acc, m_ref, l_ref,
                   *, sm_scale, causal, block_q, block_k, num_kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        s = jnp.where(rows >= cols, s, NEG_INF)
    s = jnp.where(cols < kvlen_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))       # running max
    alpha = jnp.exp(m_prev - m_new)                       # rescale factor
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)  # Alg.2 line 5
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_acc[...] = o_acc[...] * alpha[:, None] + pv         # Alg.2 line 6
    m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        # Alg.2 line 8: the lazy softmax division.
        o_ref[0] = (o_acc[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "causal", "block_q", "block_k"))
def flash2_attention(q, k, v, kv_len=None, sm_scale=1.0, causal=False,
                     block_q=64, block_k=64):
    """FlashAttention2 attention. q, k, v: (H, L, D) -> (H, Lq, D).

    ``kv_len``: optional (1, 1) int32 valid-KV-prefix length (serving path).
    """
    h, lq, d = q.shape
    lk = k.shape[1]
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, block_q, lk, block_k)
    num_kv_blocks = lk // block_k
    if kv_len is None:
        kv_len = jnp.full((1, 1), lk, jnp.int32)

    grid = (h, lq // block_q, lk // block_k)
    return pl.pallas_call(
        functools.partial(_flash2_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k,
                          num_kv_blocks=num_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, qi, ki: (hh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, qi, ki: (hh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, qi, ki: (hh, ki, 0)),
            pl.BlockSpec((1, 1), lambda hh, qi, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, qi, ki: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, kv_len)
